"""Layer-2 model correctness: Pallas-backed models vs pure-jnp references,
shape checks, and training-dynamics sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


# Pure-jnp reference MLP (no Pallas anywhere).
def mlp_logits_ref(params, x):
    w1, b1, w2, b2, w3, b3 = params
    h1 = ref.fused_linear_ref(x, w1, b1, "relu")
    h2 = ref.fused_linear_ref(h1, w2, b2, "relu")
    return ref.fused_linear_ref(h2, w3, b3, "none")


def mlp_loss_ref(params, x, y):
    return ref.softmax_xent_ref(mlp_logits_ref(params, x), y)


def make_batch(seed=0):
    spec = M.MLP_SPEC
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (spec["batch"], spec["in_dim"]), jnp.float32)
    y = jax.random.randint(ky, (spec["batch"],), 0, spec["classes"], jnp.int32)
    return x, y


class TestMlp:
    def test_init_shapes(self):
        params = M.mlp_init(jax.random.PRNGKey(0))
        spec = M.MLP_SPEC
        shapes = [p.shape for p in params]
        assert shapes == [
            (spec["in_dim"], spec["hidden"]), (spec["hidden"],),
            (spec["hidden"], spec["hidden"]), (spec["hidden"],),
            (spec["hidden"], spec["classes"]), (spec["classes"],),
        ]

    def test_loss_matches_pure_jnp(self):
        params = M.mlp_init(jax.random.PRNGKey(1))
        x, y = make_batch(2)
        np.testing.assert_allclose(
            M.mlp_loss(params, x, y), mlp_loss_ref(params, x, y),
            rtol=1e-5, atol=1e-6,
        )

    def test_train_step_matches_pure_jnp(self):
        params = M.mlp_init(jax.random.PRNGKey(3))
        x, y = make_batch(4)
        new_k, loss_k = M.mlp_train_step(params, x, y)
        loss_r, grads_r = jax.value_and_grad(mlp_loss_ref)(params, x, y)
        new_r = [p - M.MLP_SPEC["lr"] * g for p, g in zip(params, grads_r)]
        np.testing.assert_allclose(loss_k, loss_r, rtol=1e-5, atol=1e-6)
        for a, b in zip(new_k, new_r):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)

    def test_loss_decreases_over_steps(self):
        params = M.mlp_init(jax.random.PRNGKey(5))
        x, y = make_batch(6)
        step = jax.jit(lambda p, x, y: M.mlp_train_step(p, x, y))
        first = None
        for _ in range(10):
            params, loss = step(params, x, y)
            first = first if first is not None else float(loss)
        assert float(loss) < first, f"{float(loss)} !< {first}"

    def test_flat_wrappers_roundtrip(self):
        params = M.mlp_init(jax.random.PRNGKey(7))
        x, y = make_batch(8)
        flat = M.flat_train_step(M.mlp_train_step, len(params))
        out = flat(*params, x, y)
        assert len(out) == len(params) + 1
        direct_new, direct_loss = M.mlp_train_step(params, x, y)
        np.testing.assert_allclose(out[-1], direct_loss, rtol=1e-6)
        for a, b in zip(out[:-1], direct_new):
            np.testing.assert_allclose(a, b, rtol=1e-6)

        ev = M.flat_eval_step(M.mlp_loss, len(params))
        (loss,) = ev(*params, x, y)
        np.testing.assert_allclose(loss, M.mlp_loss(params, x, y), rtol=1e-6)


class TestTransformer:
    @pytest.fixture(scope="class")
    def setup(self):
        spec = M.TFM_SPEC
        params = M.tfm_init(jax.random.PRNGKey(0))
        kx = jax.random.PRNGKey(1)
        tokens = jax.random.randint(
            kx, (spec["batch"], spec["seq"]), 0, spec["vocab"], jnp.int32
        )
        targets = jnp.roll(tokens, -1, axis=1)
        return params, tokens, targets

    def test_param_count(self, setup):
        params, _, _ = setup
        assert len(params) == M.tfm_param_count()
        total = sum(int(np.prod(p.shape)) for p in params)
        assert total > 400_000, f"unexpectedly small model: {total}"

    def test_logits_shape(self, setup):
        params, tokens, _ = setup
        spec = M.TFM_SPEC
        logits = M.tfm_logits(params, tokens)
        assert logits.shape == (spec["batch"] * spec["seq"], spec["vocab"])

    def test_initial_loss_near_uniform(self, setup):
        params, tokens, targets = setup
        loss = float(M.tfm_loss(params, tokens, targets))
        # Untrained byte LM ≈ ln(256) ≈ 5.55
        assert 4.5 < loss < 6.5, loss

    def test_causality(self, setup):
        # Changing a future token must not affect earlier logits.
        params, tokens, _ = setup
        spec = M.TFM_SPEC
        logits_a = M.tfm_logits(params, tokens)
        tokens_b = tokens.at[:, -1].set((tokens[:, -1] + 1) % spec["vocab"])
        logits_b = M.tfm_logits(params, tokens_b)
        s = spec["seq"]
        la = logits_a.reshape(spec["batch"], s, -1)
        lb = logits_b.reshape(spec["batch"], s, -1)
        np.testing.assert_allclose(la[:, : s - 1], lb[:, : s - 1],
                                   rtol=1e-5, atol=1e-5)

    def test_train_step_reduces_loss(self, setup):
        params, tokens, targets = setup
        step = jax.jit(lambda p, x, y: M.tfm_train_step(p, x, y))
        p = params
        losses = []
        for _ in range(5):
            p, loss = step(p, tokens, targets)
            losses.append(float(loss))
        assert losses[-1] < losses[0], losses

    def test_grads_flow_to_all_params(self, setup):
        params, tokens, targets = setup
        grads = jax.grad(M.tfm_loss)(params, tokens, targets)
        for i, g in enumerate(grads):
            assert bool(jnp.all(jnp.isfinite(g))), f"param {i} grad not finite"
        # embed, qkv, mlp, head all receive signal
        nonzero = [float(jnp.max(jnp.abs(g))) > 0 for g in grads]
        assert sum(nonzero) >= len(grads) - 2, nonzero
