"""Kernel-vs-oracle correctness: the core L1 signal.

Hypothesis sweeps shapes and contents of the Pallas kernels against the
pure-jnp references in ``compile.kernels.ref``; gradients are checked
against ``jax.grad`` of the references.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fused_linear import fused_linear, matmul, _choose_block
from compile.kernels.softmax_xent import softmax_xent

jax.config.update("jax_platform_name", "cpu")

DIMS = st.sampled_from([1, 2, 3, 4, 8, 16, 32, 64, 128, 130, 256])
SMALL_DIMS = st.sampled_from([1, 2, 4, 8, 16, 32])
ACTS = st.sampled_from(["relu", "gelu", "none"])


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=SMALL_DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref(m, k, n, seed):
    x = rand(seed, (m, k))
    y = rand(seed + 1, (k, n))
    np.testing.assert_allclose(
        matmul(x, y), ref.matmul_ref(x, y), rtol=1e-5, atol=1e-5
    )


def test_matmul_large_aligned():
    x = rand(0, (256, 128))
    y = rand(1, (128, 384))
    np.testing.assert_allclose(
        matmul(x, y), ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4
    )


def test_choose_block_divides():
    for dim in [1, 7, 32, 128, 130, 384, 1000]:
        b = _choose_block(dim, 128)
        assert dim % b == 0
        assert 1 <= b <= 128


# ---------------------------------------------------------------------------
# fused_linear forward
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=SMALL_DIMS, n=DIMS, act=ACTS, seed=st.integers(0, 2**31 - 1))
def test_fused_linear_matches_ref(m, k, n, act, seed):
    x = rand(seed, (m, k))
    w = rand(seed + 1, (k, n))
    b = rand(seed + 2, (n,))
    np.testing.assert_allclose(
        fused_linear(x, w, b, act),
        ref.fused_linear_ref(x, w, b, act),
        rtol=1e-5,
        atol=1e-5,
    )


def test_fused_linear_under_jit():
    x, w, b = rand(0, (32, 16)), rand(1, (16, 64)), rand(2, (64,))
    out = jax.jit(lambda a, c, d: fused_linear(a, c, d, "relu"))(x, w, b)
    np.testing.assert_allclose(
        out, ref.fused_linear_ref(x, w, b, "relu"), rtol=1e-5, atol=1e-5
    )


def test_fused_linear_rejects_unknown_act():
    x, w, b = rand(0, (4, 4)), rand(1, (4, 4)), rand(2, (4,))
    with pytest.raises(ValueError):
        fused_linear(x, w, b, "swish")


# ---------------------------------------------------------------------------
# fused_linear backward (custom VJP through Pallas)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(m=SMALL_DIMS, k=SMALL_DIMS, n=SMALL_DIMS, act=ACTS,
       seed=st.integers(0, 2**31 - 1))
def test_fused_linear_grads_match_ref(m, k, n, act, seed):
    x = rand(seed, (m, k))
    w = rand(seed + 1, (k, n))
    b = rand(seed + 2, (n,))

    def f_kernel(x, w, b):
        return jnp.sum(fused_linear(x, w, b, act) ** 2)

    def f_ref(x, w, b):
        return jnp.sum(ref.fused_linear_ref(x, w, b, act) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, c in zip(gk, gr):
        np.testing.assert_allclose(a, c, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# softmax cross-entropy
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(rows=DIMS, classes=st.sampled_from([2, 5, 10, 17, 256]),
       seed=st.integers(0, 2**31 - 1))
def test_softmax_xent_matches_ref(rows, classes, seed):
    logits = rand(seed, (rows, classes), scale=3.0)
    labels = jax.random.randint(
        jax.random.PRNGKey(seed + 1), (rows,), 0, classes, jnp.int32
    )
    np.testing.assert_allclose(
        softmax_xent(logits, labels),
        ref.softmax_xent_ref(logits, labels),
        rtol=1e-5,
        atol=1e-6,
    )


@settings(max_examples=15, deadline=None)
@given(rows=SMALL_DIMS, classes=st.sampled_from([2, 5, 10]),
       seed=st.integers(0, 2**31 - 1))
def test_softmax_xent_grad_matches_ref(rows, classes, seed):
    logits = rand(seed, (rows, classes), scale=3.0)
    labels = jax.random.randint(
        jax.random.PRNGKey(seed + 1), (rows,), 0, classes, jnp.int32
    )
    gk = jax.grad(lambda z: softmax_xent(z, labels))(logits)
    gr = jax.grad(lambda z: ref.softmax_xent_ref(z, labels))(logits)
    np.testing.assert_allclose(gk, gr, rtol=1e-4, atol=1e-6)


def test_softmax_xent_extreme_logits_stable():
    logits = jnp.array([[1e4, -1e4], [-1e4, 1e4]], jnp.float32)
    labels = jnp.array([0, 1], jnp.int32)
    loss = softmax_xent(logits, labels)
    assert jnp.isfinite(loss)
    assert float(loss) < 1e-3


def test_softmax_xent_uniform_logits():
    logits = jnp.zeros((8, 10), jnp.float32)
    labels = jnp.arange(8, dtype=jnp.int32) % 10
    np.testing.assert_allclose(
        softmax_xent(logits, labels), np.log(10.0), rtol=1e-6
    )
