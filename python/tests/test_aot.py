"""AOT pipeline checks: manifest consistency, HLO text validity markers,
parameter dump integrity."""

import hashlib
import json
import os
import subprocess
import sys
import tempfile

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


@pytest.fixture(scope="module")
def artifacts():
    """Lower the (fast) MLP model into a temp dir once per module."""
    with tempfile.TemporaryDirectory() as d:
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", d,
             "--models", "mlp", "--seed", "3"],
            cwd=os.path.join(REPO, "python"),
            check=True,
            capture_output=True,
        )
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        files = {
            name: open(os.path.join(d, name), "rb").read()
            for name in os.listdir(d)
        }
        yield manifest, files


def test_manifest_structure(artifacts):
    manifest, _ = artifacts
    assert manifest["version"] == 1
    m = manifest["models"]["mlp"]
    for key in ["train_hlo", "eval_hlo", "params_file", "param_shapes",
                "param_count", "n_param_tensors", "batch", "lr",
                "input_shape", "label_shape", "params_sha256"]:
        assert key in m, key
    assert m["n_param_tensors"] == len(m["param_shapes"])


def test_param_dump_matches_shapes(artifacts):
    manifest, files = artifacts
    m = manifest["models"]["mlp"]
    raw = files[m["params_file"]]
    flat = np.frombuffer(raw, dtype="<f4")
    expected = sum(int(np.prod(s)) for s in m["param_shapes"])
    assert flat.size == expected == m["param_count"]
    assert np.all(np.isfinite(flat))
    assert hashlib.sha256(raw).hexdigest() == m["params_sha256"]


def test_hlo_text_is_parseable_shape(artifacts):
    manifest, files = artifacts
    m = manifest["models"]["mlp"]
    train = files[m["train_hlo"]].decode()
    # HLO text structural markers the Rust-side parser relies on.
    assert train.startswith("HloModule")
    assert "ENTRY" in train
    assert "parameter(0)" in train
    # 6 params + x + y = 8 inputs
    assert "parameter(7)" in train
    ev = files[m["eval_hlo"]].decode()
    assert ev.startswith("HloModule")
    assert len(ev) < len(train)  # eval (no backward) is smaller


def test_deterministic_given_seed(artifacts):
    manifest, _ = artifacts
    with tempfile.TemporaryDirectory() as d2:
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", d2,
             "--models", "mlp", "--seed", "3"],
            cwd=os.path.join(REPO, "python"),
            check=True,
            capture_output=True,
        )
        with open(os.path.join(d2, "manifest.json")) as f:
            manifest2 = json.load(f)
    assert (manifest["models"]["mlp"]["params_sha256"]
            == manifest2["models"]["mlp"]["params_sha256"])
