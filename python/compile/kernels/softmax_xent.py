"""Layer-1 Pallas kernel: fused row-softmax + cross-entropy.

Computes the mean negative log-likelihood of integer labels under
``softmax(logits)`` in a single pass per row-tile: the kernel produces the
per-row loss using the numerically-stable ``logsumexp`` trick without
materializing the probability matrix in HBM. The backward pass (softmax −
one-hot, scaled by the incoming cotangent) is likewise a single Pallas
kernel.

TPU mapping: the grid tiles rows (block_r rows per step); the class
dimension stays resident (vocab <= 512 here → a (128, 512) f32 tile is
256 KiB of VMEM). Lowered with ``interpret=True`` for the CPU PJRT path.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 128


def _choose_block(dim: int, block: int) -> int:
    if dim <= block:
        return dim
    b = block
    while dim % b != 0:
        b -= 1
    return b


def _xent_fwd_kernel(logits_ref, labels_ref, loss_ref):
    """Per-row loss: logsumexp(logits) − logits[label]."""
    logits = logits_ref[...]                      # (br, C)
    labels = labels_ref[...]                      # (br,)
    zmax = jnp.max(logits, axis=-1, keepdims=True)
    shifted = logits - zmax
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + zmax[:, 0]
    picked = jnp.take_along_axis(
        logits, labels[:, None].astype(jnp.int32), axis=-1
    )[:, 0]
    loss_ref[...] = lse - picked


def _xent_bwd_kernel(logits_ref, labels_ref, g_ref, dlogits_ref):
    """d loss_r / d logits = softmax(logits) − onehot(label), times g_r."""
    logits = logits_ref[...]
    labels = labels_ref[...]
    g = g_ref[...]                                # (br,)
    zmax = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - zmax)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        == labels[:, None].astype(jnp.int32)
    ).astype(logits.dtype)
    dlogits_ref[...] = (p - onehot) * g[:, None]


def _per_row_loss(logits, labels, block_rows: int):
    r, c = logits.shape
    br = _choose_block(r, block_rows)
    return pl.pallas_call(
        _xent_fwd_kernel,
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((br,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((br,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((r,), jnp.float32),
        interpret=True,
    )(logits, labels)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def softmax_xent(logits, labels, block_rows: int = BLOCK_ROWS):
    """Mean cross-entropy of int labels under softmax(logits) (scalar)."""
    return jnp.mean(_per_row_loss(logits, labels, block_rows))


def _softmax_xent_fwd(logits, labels, block_rows):
    loss = jnp.mean(_per_row_loss(logits, labels, block_rows))
    return loss, (logits, labels)


def _softmax_xent_bwd(block_rows, res, g):
    logits, labels = res
    r, c = logits.shape
    br = _choose_block(r, block_rows)
    # Mean over rows → each row's cotangent is g / r.
    grow = jnp.full((r,), g / r, dtype=logits.dtype)
    dlogits = pl.pallas_call(
        _xent_bwd_kernel,
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((br,), lambda i: (i,)),
            pl.BlockSpec((br,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((br, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.float32),
        interpret=True,
    )(logits, labels, grow)
    return dlogits, None


softmax_xent.defvjp(_softmax_xent_fwd, _softmax_xent_bwd)
