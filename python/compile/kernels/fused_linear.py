"""Layer-1 Pallas kernels: tiled fused linear (matmul + bias + activation).

This is the training hot-spot of the FL client's local step. The kernel is
written TPU-style:

* the grid tiles the output into ``(block_m, block_n)`` VMEM blocks
  (MXU-native tiles are 128x128; see DESIGN.md §Hardware-Adaptation);
* the contraction (K) dimension stays resident per tile — for the model
  sizes used here (K <= 512) a full K-slab fits VMEM comfortably
  (`block_m*K + K*block_n + block_m*block_n` floats ≈ 0.4 MiB at 128³);
* matmuls use ``preferred_element_type=float32`` so the MXU accumulates in
  f32 regardless of input precision.

Kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls, so interpret mode (which lowers to plain HLO) is the
correctness path; real-TPU efficiency is estimated from the block shapes in
DESIGN.md §Perf.

The backward pass is implemented with the same tiled matmul kernel via
``jax.custom_vjp`` (dx = g·Wᵀ, dW = xᵀ·g, db = Σg), so the *entire*
linear-layer fwd+bwd runs through Pallas.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-aligned tile. Shapes smaller than a tile use the full dim.
BLOCK = 512


def _choose_block(dim: int, block: int) -> int:
    """Largest tile <= `block` that divides `dim` (tiles must tile exactly;
    interpret mode would mask, but uniform tiles keep the TPU mapping
    honest)."""
    if dim <= block:
        return dim
    b = block
    while dim % b != 0:
        b -= 1
    return b


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (bm, bn) output tile: full-K contraction."""
    o_ref[...] = jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def matmul(x: jax.Array, y: jax.Array, *, block_m: int = BLOCK,
           block_n: int = BLOCK) -> jax.Array:
    """Tiled Pallas matmul ``x @ y`` for 2-D f32 operands."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm = _choose_block(m, block_m)
    bn = _choose_block(n, block_n)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


def _fused_linear_kernel(x_ref, w_ref, b_ref, o_ref, *, act: str):
    """One (bm, bn) output tile of act(x @ w + b)."""
    z = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    z = z + b_ref[...]
    if act == "relu":
        z = jnp.maximum(z, 0.0)
    elif act == "gelu":
        z = jax.nn.gelu(z)
    elif act != "none":
        raise ValueError(f"unknown activation {act!r}")
    o_ref[...] = z


def _fused_linear_fwd_impl(x, w, b, act: str, block_m: int, block_n: int):
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    assert b.shape == (n,)
    bm = _choose_block(m, block_m)
    bn = _choose_block(n, block_n)
    grid = (m // bm, n // bn)
    b2 = b.reshape(1, n)
    return pl.pallas_call(
        functools.partial(_fused_linear_kernel, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, b2)


def _fused_linear_gelu_z_kernel(x_ref, w_ref, b_ref, o_ref, z_ref):
    """gelu tile that also emits the pre-activation z (saved for the VJP —
    avoids recomputing x@w in the backward pass; see EXPERIMENTS.md §Perf)."""
    z = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    z = z + b_ref[...]
    z_ref[...] = z
    o_ref[...] = jax.nn.gelu(z)


def _fused_linear_fwd_with_residual(x, w, b, act, block_m, block_n):
    """Forward returning (out, residual-for-bwd)."""
    m, k = x.shape
    _, n = w.shape
    if act != "gelu":
        out = _fused_linear_fwd_impl(x, w, b, act, block_m, block_n)
        # relu: out > 0 ⟺ z > 0; none: no mask needed.
        return out, out
    bm = _choose_block(m, block_m)
    bn = _choose_block(n, block_n)
    grid = (m // bm, n // bn)
    out, z = pl.pallas_call(
        _fused_linear_gelu_z_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((m, n), jnp.float32),
        ],
        interpret=True,
    )(x, w, b.reshape(1, n))
    return out, z


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def fused_linear(x, w, b, act: str = "relu", block_m: int = BLOCK,
                 block_n: int = BLOCK):
    """``act(x @ w + b)`` as a fused Pallas kernel with a Pallas backward."""
    return _fused_linear_fwd_impl(x, w, b, act, block_m, block_n)


def _fused_linear_fwd(x, w, b, act, block_m, block_n):
    out, residual = _fused_linear_fwd_with_residual(x, w, b, act, block_m, block_n)
    return out, (x, w, residual)


def _fused_linear_bwd(act, block_m, block_n, res, g):
    x, w, residual = res
    if act == "relu":
        g = g * (residual > 0.0).astype(g.dtype)   # residual = out
    elif act == "gelu":
        # residual = z (pre-activation), saved by the forward kernel.
        g = g * jax.grad(lambda t: jnp.sum(jax.nn.gelu(t)))(residual)
    # dx = g @ w^T ; dw = x^T @ g ; db = sum_m g — all through Pallas.
    dx = matmul(g, w.T, block_m=block_m, block_n=block_n)
    dw = matmul(x.T, g, block_m=block_m, block_n=block_n)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


fused_linear.defvjp(_fused_linear_fwd, _fused_linear_bwd)
