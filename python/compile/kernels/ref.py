"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has its reference implementation here; the
pytest suite asserts ``allclose`` between kernel and oracle over shape and
content sweeps (hypothesis). These oracles are also used to build the
reference model in ``tests/test_model.py`` that certifies the full
train-step numerics.
"""

import jax
import jax.numpy as jnp


def matmul_ref(x, y):
    """Oracle for kernels.fused_linear.matmul."""
    return jnp.matmul(x, y)


def fused_linear_ref(x, w, b, act: str = "relu"):
    """Oracle for kernels.fused_linear.fused_linear."""
    z = jnp.matmul(x, w) + b
    if act == "relu":
        return jnp.maximum(z, 0.0)
    if act == "gelu":
        return jax.nn.gelu(z)
    if act == "none":
        return z
    raise ValueError(f"unknown activation {act!r}")


def softmax_xent_ref(logits, labels):
    """Oracle for kernels.softmax_xent.softmax_xent (mean NLL)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(
        logp, labels[:, None].astype(jnp.int32), axis=-1
    )[:, 0]
    return -jnp.mean(picked)
