"""AOT pipeline: lower the Layer-2 train/eval steps to HLO **text** and
write the artifact manifest consumed by the Rust runtime.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's bundled xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Outputs under ``--out-dir`` (default ``artifacts/``):

* ``<model>_train.hlo.txt`` / ``<model>_eval.hlo.txt`` — lowered steps;
* ``<model>_params.bin`` — initial parameters, raw little-endian f32,
  concatenated in flat order;
* ``manifest.json`` — shapes, dtypes, batch geometry, hyper-parameters.

Python runs only here, at build time (``make artifacts``); the Rust binary
is self-contained afterwards.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _shape_dtype(arr):
    return jax.ShapeDtypeStruct(arr.shape, arr.dtype)


def build_mlp(seed: int):
    """Specs for the MLP model: (params, x_spec, y_spec, meta)."""
    spec = M.MLP_SPEC
    params = M.mlp_init(jax.random.PRNGKey(seed))
    x = jax.ShapeDtypeStruct((spec["batch"], spec["in_dim"]), jnp.float32)
    y = jax.ShapeDtypeStruct((spec["batch"],), jnp.int32)
    meta = {
        "family": "mlp",
        "batch": spec["batch"],
        "lr": spec["lr"],
        "input_shape": [spec["batch"], spec["in_dim"]],
        "input_dtype": "f32",
        "label_shape": [spec["batch"]],
        "label_dtype": "s32",
        "classes": spec["classes"],
    }
    return params, x, y, M.mlp_train_step, M.mlp_loss, meta


def build_transformer(seed: int):
    """Specs for the transformer LM."""
    spec = M.TFM_SPEC
    params = M.tfm_init(jax.random.PRNGKey(seed))
    x = jax.ShapeDtypeStruct((spec["batch"], spec["seq"]), jnp.int32)
    y = jax.ShapeDtypeStruct((spec["batch"], spec["seq"]), jnp.int32)
    meta = {
        "family": "transformer",
        "batch": spec["batch"],
        "seq": spec["seq"],
        "lr": spec["lr"],
        "input_shape": [spec["batch"], spec["seq"]],
        "input_dtype": "s32",
        "label_shape": [spec["batch"], spec["seq"]],
        "label_dtype": "s32",
        "vocab": spec["vocab"],
    }
    return params, x, y, M.tfm_train_step, M.tfm_loss, meta


BUILDERS = {"mlp": build_mlp, "transformer": build_transformer}


def lower_model(name: str, out_dir: str, seed: int) -> dict:
    """Lower one model family; returns its manifest entry."""
    params, x_spec, y_spec, train_step, loss_fn, meta = BUILDERS[name](seed)
    n_params = len(params)
    param_specs = [_shape_dtype(p) for p in params]

    train_flat = M.flat_train_step(train_step, n_params)
    eval_flat = M.flat_eval_step(loss_fn, n_params)

    train_lowered = jax.jit(train_flat).lower(*param_specs, x_spec, y_spec)
    eval_lowered = jax.jit(eval_flat).lower(*param_specs, x_spec, y_spec)

    train_path = f"{name}_train.hlo.txt"
    eval_path = f"{name}_eval.hlo.txt"
    params_path = f"{name}_params.bin"

    with open(os.path.join(out_dir, train_path), "w") as f:
        f.write(to_hlo_text(train_lowered))
    with open(os.path.join(out_dir, eval_path), "w") as f:
        f.write(to_hlo_text(eval_lowered))

    flat = np.concatenate(
        [np.asarray(p, dtype=np.float32).reshape(-1) for p in params]
    )
    raw = flat.astype("<f4").tobytes()
    with open(os.path.join(out_dir, params_path), "wb") as f:
        f.write(raw)

    entry = dict(meta)
    entry.update(
        {
            "train_hlo": train_path,
            "eval_hlo": eval_path,
            "params_file": params_path,
            "params_sha256": hashlib.sha256(raw).hexdigest(),
            "param_shapes": [list(p.shape) for p in params],
            "param_count": int(flat.size),
            "n_param_tensors": n_params,
        }
    )
    return entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--models", default="mlp,transformer",
                    help="comma-separated model families")
    ap.add_argument("--seed", type=int, default=0, help="init PRNG seed")
    # legacy alias used by the original Makefile scaffold
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    manifest = {"version": 1, "models": {}}
    for name in args.models.split(","):
        name = name.strip()
        if not name:
            continue
        print(f"[aot] lowering {name} ...", flush=True)
        manifest["models"][name] = lower_model(name, out_dir, args.seed)
        print(f"[aot] {name}: {manifest['models'][name]['param_count']} params",
              flush=True)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"[aot] wrote manifest to {out_dir}/manifest.json")


if __name__ == "__main__":
    main()
