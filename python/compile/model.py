"""Layer-2: the FL client's local training step, in JAX, calling the
Layer-1 Pallas kernels.

Two model families are provided (both used by the end-to-end experiments):

* **MLP** — a 3-layer classifier for the synthetic gaussian-mixture
  workload. Every dense layer is a Pallas ``fused_linear`` (fwd *and* bwd),
  and the loss is the Pallas ``softmax_xent``.
* **Transformer** — a tiny byte-level causal LM (2 blocks, d=128, 4 heads):
  all projections (QKV, output, MLP up/down, LM head) run through
  ``fused_linear``; attention softmax and layernorm are plain jnp (the
  dense layers dominate FLOPs).

Each family exposes ``init(key)``, ``loss(params, x, y)`` and a
``train_step(params, x, y) -> (new_params, loss)`` performing one SGD
update. ``aot.py`` lowers flattened versions of these to HLO text; the
Rust runtime then executes them per mini-batch — the schedule `x_i` decides
*how many times* per round each simulated device runs the step.
"""

import functools

import jax
import jax.numpy as jnp

from compile.kernels.fused_linear import fused_linear
from compile.kernels.softmax_xent import softmax_xent

# ---------------------------------------------------------------------------
# MLP classifier
# ---------------------------------------------------------------------------

MLP_SPEC = {
    "in_dim": 32,
    "hidden": 128,
    "classes": 10,
    "batch": 32,
    "lr": 0.05,
}


def mlp_init(key, spec=None):
    """He-initialized parameter list [w1, b1, w2, b2, w3, b3]."""
    spec = spec or MLP_SPEC
    d_in, h, c = spec["in_dim"], spec["hidden"], spec["classes"]
    k1, k2, k3 = jax.random.split(key, 3)

    def he(k, fan_in, shape):
        return jax.random.normal(k, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)

    return [
        he(k1, d_in, (d_in, h)), jnp.zeros((h,), jnp.float32),
        he(k2, h, (h, h)), jnp.zeros((h,), jnp.float32),
        he(k3, h, (h, c)), jnp.zeros((c,), jnp.float32),
    ]


def mlp_logits(params, x):
    """Forward pass through the three Pallas fused layers."""
    w1, b1, w2, b2, w3, b3 = params
    h1 = fused_linear(x, w1, b1, "relu")
    h2 = fused_linear(h1, w2, b2, "relu")
    return fused_linear(h2, w3, b3, "none")


def mlp_loss(params, x, y):
    """Mean cross-entropy on one mini-batch."""
    return softmax_xent(mlp_logits(params, x), y)


def mlp_train_step(params, x, y, lr=None):
    """One SGD step; returns (new_params, loss)."""
    lr = MLP_SPEC["lr"] if lr is None else lr
    loss, grads = jax.value_and_grad(mlp_loss)(params, x, y)
    new_params = [p - lr * g for p, g in zip(params, grads)]
    return new_params, loss


# ---------------------------------------------------------------------------
# Tiny byte-level transformer LM
# ---------------------------------------------------------------------------

TFM_SPEC = {
    "vocab": 256,
    "d_model": 128,
    "n_head": 4,
    "n_layer": 2,
    "seq": 64,
    "batch": 8,
    "lr": 0.1,
}


def tfm_init(key, spec=None):
    """Flat parameter list:
    [embed, pos, (12 per block)×n_layer, lnf_g, lnf_b, w_head, b_head]."""
    spec = spec or TFM_SPEC
    v, d, n_layer, s = spec["vocab"], spec["d_model"], spec["n_layer"], spec["seq"]
    keys = iter(jax.random.split(key, 4 + 4 * n_layer))

    def norm(k, shape, scale):
        return jax.random.normal(k, shape, jnp.float32) * scale

    params = [
        norm(next(keys), (v, d), 0.02),          # embed
        norm(next(keys), (s, d), 0.02),          # pos
    ]
    for _ in range(n_layer):
        params += [
            jnp.ones((d,), jnp.float32), jnp.zeros((d,), jnp.float32),   # ln1
            norm(next(keys), (d, 3 * d), (2.0 / d) ** 0.5),
            jnp.zeros((3 * d,), jnp.float32),
            norm(next(keys), (d, d), (2.0 / d) ** 0.5),
            jnp.zeros((d,), jnp.float32),
            jnp.ones((d,), jnp.float32), jnp.zeros((d,), jnp.float32),   # ln2
            norm(next(keys), (d, 4 * d), (2.0 / d) ** 0.5),
            jnp.zeros((4 * d,), jnp.float32),
            norm(next(keys), (4 * d, d), (2.0 / (4 * d)) ** 0.5),
            jnp.zeros((d,), jnp.float32),
        ]
    params += [
        jnp.ones((d,), jnp.float32), jnp.zeros((d,), jnp.float32),       # lnf
        norm(next(keys), (d, v), (2.0 / d) ** 0.5),
        jnp.zeros((v,), jnp.float32),
    ]
    return params


def tfm_param_count(spec=None):
    """Number of parameter tensors in the flat list."""
    spec = spec or TFM_SPEC
    return 2 + 12 * spec["n_layer"] + 4


def _layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _attention(x2d, w_qkv, b_qkv, w_o, b_o, batch, seq, n_head):
    """Causal multi-head self-attention; projections via Pallas."""
    d = x2d.shape[-1]
    dh = d // n_head
    qkv = fused_linear(x2d, w_qkv, b_qkv, "none")          # (B*S, 3D)
    qkv = qkv.reshape(batch, seq, 3, n_head, dh)
    q = qkv[:, :, 0].transpose(0, 2, 1, 3)                 # (B, H, S, dh)
    k = qkv[:, :, 1].transpose(0, 2, 1, 3)
    v = qkv[:, :, 2].transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(dh))
    mask = jnp.tril(jnp.ones((seq, seq), jnp.bool_))
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bhkd->bhqd", probs, v)          # (B, H, S, dh)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(batch * seq, d)
    return fused_linear(ctx, w_o, b_o, "none")


def tfm_logits(params, tokens, spec=None):
    """Next-token logits, shape (B*S, vocab)."""
    spec = spec or TFM_SPEC
    d, n_head, n_layer = spec["d_model"], spec["n_head"], spec["n_layer"]
    batch, seq = tokens.shape
    embed, pos = params[0], params[1]
    h = jnp.take(embed, tokens, axis=0) + pos[None, :seq]  # (B, S, D)
    h = h.reshape(batch * seq, d)
    idx = 2
    for _ in range(n_layer):
        (ln1_g, ln1_b, w_qkv, b_qkv, w_o, b_o,
         ln2_g, ln2_b, w_up, b_up, w_down, b_down) = params[idx:idx + 12]
        idx += 12
        a = _attention(_layernorm(h, ln1_g, ln1_b), w_qkv, b_qkv, w_o, b_o,
                       batch, seq, n_head)
        h = h + a
        m = fused_linear(_layernorm(h, ln2_g, ln2_b), w_up, b_up, "gelu")
        m = fused_linear(m, w_down, b_down, "none")
        h = h + m
    lnf_g, lnf_b, w_head, b_head = params[idx:idx + 4]
    h = _layernorm(h, lnf_g, lnf_b)
    return fused_linear(h, w_head, b_head, "none")         # (B*S, V)


def tfm_loss(params, tokens, targets, spec=None):
    """Mean next-token cross-entropy."""
    logits = tfm_logits(params, tokens, spec)
    return softmax_xent(logits, targets.reshape(-1))


def tfm_train_step(params, tokens, targets, lr=None, spec=None):
    """One SGD step; returns (new_params, loss)."""
    spec = spec or TFM_SPEC
    lr = spec["lr"] if lr is None else lr
    loss, grads = jax.value_and_grad(tfm_loss)(params, tokens, targets, spec)
    new_params = [p - lr * g for p, g in zip(params, grads)]
    return new_params, loss


# ---------------------------------------------------------------------------
# Flat entry points for AOT lowering (positional tensors only)
# ---------------------------------------------------------------------------

def flat_train_step(train_step, n_params):
    """Wrap a (params, x, y) train step as f(*tensors) -> tuple of tensors.

    The lowered computation's calling convention (used by the Rust runtime):
    inputs are ``params[0..n_params), x, y``; outputs are
    ``new_params[0..n_params), loss``.
    """

    @functools.wraps(train_step)
    def wrapped(*args):
        params = list(args[:n_params])
        x, y = args[n_params], args[n_params + 1]
        new_params, loss = train_step(params, x, y)
        return tuple(new_params) + (loss,)

    return wrapped


def flat_eval_step(loss_fn, n_params):
    """Wrap a (params, x, y) loss as f(*tensors) -> (loss,)."""

    def wrapped(*args):
        params = list(args[:n_params])
        x, y = args[n_params], args[n_params + 1]
        return (loss_fn(params, x, y),)

    return wrapped
