//! Benchmark harness (the offline build has no `criterion`).
//!
//! Provides warmup + timed iterations with robust summaries (median / MAD /
//! p10 / p90), black-box value sinks to defeat dead-code elimination, and a
//! report type that renders the tables printed into `bench_output.txt`.
//!
//! Bench binaries are declared with `harness = false` in `Cargo.toml` and
//! drive this module from `main()`.

use std::hint::black_box;
use std::time::Instant;

use crate::util::stats;
use crate::util::table::{fmt_duration, Table};

/// One measured benchmark: name + per-iteration wall times (seconds).
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<f64>,
}

impl Measurement {
    /// Median per-iteration time in seconds.
    pub fn median(&self) -> f64 {
        stats::median(&self.samples)
    }

    /// Median absolute deviation.
    pub fn mad(&self) -> f64 {
        stats::mad(&self.samples)
    }

    /// p-th percentile.
    pub fn percentile(&self, p: f64) -> f64 {
        stats::percentile(&self.samples, p)
    }
}

/// Harness configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// Warmup iterations (not recorded).
    pub warmup: usize,
    /// Recorded iterations.
    pub iters: usize,
    /// Lower bound on total measured time; iterations are repeated in
    /// batches until this much time has been observed (protects very fast
    /// functions from timer resolution).
    pub min_time_s: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self { warmup: 3, iters: 15, min_time_s: 0.05 }
    }
}

impl BenchConfig {
    /// Quick preset for expensive end-to-end benches.
    pub fn quick() -> Self {
        Self { warmup: 1, iters: 5, min_time_s: 0.0 }
    }
}

/// Time `f` under `cfg`, returning per-iteration samples.
///
/// `f` must return a value; it is routed through [`black_box`] so the
/// optimizer cannot elide the benched computation.
pub fn bench<T>(name: &str, cfg: &BenchConfig, mut f: impl FnMut() -> T) -> Measurement {
    for _ in 0..cfg.warmup {
        black_box(f());
    }
    // Choose a batch size so one batch takes >= ~1ms or min_time/iters.
    let t0 = Instant::now();
    black_box(f());
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let target = (cfg.min_time_s / cfg.iters.max(1) as f64).max(1e-4);
    let batch = ((target / once).ceil() as usize).clamp(1, 1_000_000);

    let mut samples = Vec::with_capacity(cfg.iters);
    for _ in 0..cfg.iters {
        let t = Instant::now();
        for _ in 0..batch {
            black_box(f());
        }
        samples.push(t.elapsed().as_secs_f64() / batch as f64);
    }
    Measurement { name: name.to_string(), samples }
}

/// A collection of measurements rendered as one report table.
#[derive(Debug, Default)]
pub struct Report {
    title: String,
    rows: Vec<Measurement>,
}

impl Report {
    /// New empty report.
    pub fn new(title: &str) -> Self {
        Self { title: title.to_string(), rows: Vec::new() }
    }

    /// Add a measurement.
    pub fn push(&mut self, m: Measurement) {
        self.rows.push(m);
    }

    /// Run + record a benchmark in one call.
    pub fn bench<T>(&mut self, name: &str, cfg: &BenchConfig, f: impl FnMut() -> T) {
        let m = bench(name, cfg, f);
        self.push(m);
    }

    /// Render the report table.
    pub fn render(&self) -> String {
        let mut t = Table::new(&self.title, &["benchmark", "median", "mad", "p10", "p90"]);
        for m in &self.rows {
            t.rows_str(vec![
                m.name.clone(),
                fmt_duration(m.median()),
                fmt_duration(m.mad()),
                fmt_duration(m.percentile(10.0)),
                fmt_duration(m.percentile(90.0)),
            ]);
        }
        t.render()
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// Access measurements (for slope fits etc.).
    pub fn measurements(&self) -> &[Measurement] {
        &self.rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let cfg = BenchConfig { warmup: 1, iters: 5, min_time_s: 0.0 };
        let m = bench("spin", &cfg, || {
            let mut s = 0u64;
            for i in 0..1000 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert_eq!(m.samples.len(), 5);
        assert!(m.median() > 0.0);
    }

    #[test]
    fn report_renders() {
        let cfg = BenchConfig { warmup: 0, iters: 3, min_time_s: 0.0 };
        let mut r = Report::new("unit");
        r.bench("noop", &cfg, || 1u8);
        let s = r.render();
        assert!(s.contains("noop"));
        assert!(s.contains("median"));
    }
}
