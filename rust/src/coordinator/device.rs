//! The coordinator's view of a device: exactly what re-deriving each
//! round's scheduling instance needs — a base cost function, static limits,
//! and the *evolving* state (battery charge, drift multiplier) that makes
//! round `r+1`'s instance differ from round `r`'s.

use crate::energy::battery::Battery;
use crate::energy::power::PowerModel;
use crate::energy::profiles::Device;
use crate::sched::costs::CostFn;
use crate::sched::pareto::{TimeModel, DEFAULT_UPLOAD_S};

/// A device as managed by the coordinator across rounds.
#[derive(Clone, Debug)]
pub struct ManagedDevice {
    /// Fleet-unique id (ledger key).
    pub id: usize,
    /// Base energy cost function `C_i` (joules for `j` tasks). Drift is
    /// applied on top per round.
    pub cost: CostFn,
    /// Per-round lower limit `L_i` intrinsic to the device (contractual
    /// minimum participation; the §3.1 example's `L = {1, 0, 0}`).
    pub lower: usize,
    /// Static capacity cap (available data / contract) before battery.
    pub data_cap: usize,
    /// Battery state, drained by measured energy each round (`None` =
    /// mains-powered).
    pub battery: Option<Battery>,
    /// Power model, when the device has one (fleet devices do; abstract
    /// paper-style resources need not). Used for battery budgets and
    /// partial-work energy on dropout.
    pub power: Option<PowerModel>,
    /// Current multiplicative drift on the energy profile (1.0 = nominal).
    pub drift: f64,
    /// Round-deadline cap: the largest load whose compute + upload time
    /// fits within the configured round deadline (`usize::MAX` = no
    /// deadline, or no time model to enforce one with). Derived from the
    /// coordinator config at construction — NOT persisted; `restore`
    /// re-derives it from the decoded config.
    pub deadline_cap: usize,
}

impl ManagedDevice {
    /// A paper-style abstract resource: a cost function plus limits, no
    /// physical power/battery model.
    pub fn abstract_resource(id: usize, cost: CostFn, lower: usize, upper: usize) -> Self {
        Self {
            id,
            cost,
            lower,
            data_cap: upper,
            battery: None,
            power: None,
            drift: 1.0,
            deadline_cap: usize::MAX,
        }
    }

    /// Adopt a sampled fleet device, capping its capacity at `data_len`
    /// (it cannot train on more distinct mini-batches than its shard
    /// holds).
    pub fn from_device(d: &Device, data_len: usize) -> Self {
        Self {
            id: d.id,
            cost: d.cost_fn(),
            lower: 0,
            data_cap: d.data_batches.min(data_len),
            battery: d.battery.clone(),
            power: Some(d.power.clone()),
            drift: 1.0,
            deadline_cap: usize::MAX,
        }
    }

    /// The device's completion-time model, when its power model provides
    /// a batch latency: affine compute time plus the default upload
    /// window. Abstract paper-style resources have no time model (and are
    /// therefore deadline-exempt).
    pub fn time_model(&self) -> Option<TimeModel> {
        self.power
            .as_ref()
            .map(|p| TimeModel::affine(p.batch_latency_s, DEFAULT_UPLOAD_S))
    }

    /// Derive the deadline cap from a round deadline in seconds: the
    /// largest load whose compute + upload fits. A deadline too tight
    /// even for one task leaves the device schedulable at 0 tasks (it
    /// sits rounds out rather than making the fleet infeasible).
    pub fn apply_deadline(&mut self, seconds: f64) {
        self.deadline_cap = match self.time_model() {
            Some(tm) => tm.max_tasks_within(seconds, 0, self.data_cap).unwrap_or(0),
            None => usize::MAX,
        };
    }

    /// Remove any deadline cap.
    pub fn clear_deadline(&mut self) {
        self.deadline_cap = usize::MAX;
    }

    /// This round's effective upper limit: static cap, further clamped by
    /// the current battery budget. Re-evaluated every round — this is the
    /// "re-cost" input that makes schedules adapt to battery drain.
    pub fn effective_upper(&self) -> usize {
        let cap = match (&self.battery, &self.power) {
            (Some(b), Some(p)) => self.data_cap.min(b.max_batches(p)),
            _ => self.data_cap,
        };
        cap.min(self.deadline_cap)
    }

    /// This round's scheduler-visible cost function: the base cost under
    /// the current drift. Drift scales the scheduled cost exactly as it
    /// scales measured energy, so the profiler stays truthful.
    pub fn current_cost(&self) -> CostFn {
        if self.drift == 1.0 {
            self.cost.clone()
        } else {
            CostFn::Scaled { weight: self.drift, inner: Box::new(self.cost.clone()) }
        }
    }

    /// Energy burnt by `done` tasks under current drift — used for partial
    /// work on mid-round dropout. Prefers the physical power model; falls
    /// back to the cost function over its valid domain, prorating linearly
    /// below `lower` (tabulated costs may be undefined there, and a victim
    /// must never be charged for tasks it did not start).
    pub fn partial_energy_j(&self, done: usize) -> f64 {
        match &self.power {
            Some(p) => p.energy_j(done) * self.drift,
            None if done == 0 => 0.0,
            None if done < self.lower => {
                self.current_cost().eval(self.lower) * done as f64 / self.lower as f64
            }
            None => self.current_cost().eval(done.min(self.data_cap)),
        }
    }

    /// Drain the battery by measured joules (no-op when mains-powered).
    pub fn drain(&mut self, joules: f64) {
        if let Some(b) = self.battery.as_mut() {
            b.drain(joules);
        }
    }

    /// The raw class signature the persistent index
    /// ([`crate::sched::incremental::FleetIndex`]) buckets this device
    /// on: drift-scaled cost, intrinsic lower limit, battery-capped
    /// upper limit. Devices with equal signatures are interchangeable
    /// for scheduling — exactly the equivalence [`crate::sched::fleet`]
    /// collapses into classes. Any mutation that can change this triple
    /// (drains, drift re-scaling) must dirty-mark the device in the
    /// index.
    pub fn class_signature(&self) -> (CostFn, usize, usize) {
        (self.current_cost(), self.lower, self.effective_upper())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::power::Behavior;

    fn powered() -> ManagedDevice {
        ManagedDevice {
            id: 0,
            cost: CostFn::Affine { fixed: 0.0, per_task: 1.0 },
            lower: 0,
            data_cap: 100,
            battery: Some(Battery {
                capacity_wh: 1.0,
                level: 1.0,
                round_budget_frac: 0.01,
            }),
            power: Some(PowerModel {
                idle_w: 0.1,
                busy_w: 2.0,
                batch_latency_s: 0.5,
                behavior: Behavior::Linear,
                curvature: 0.0,
            }),
            drift: 1.0,
            deadline_cap: usize::MAX,
        }
    }

    #[test]
    fn battery_drain_shrinks_effective_upper() {
        let mut d = powered();
        // budget = 3600 J * 0.01 = 36 J at 1 J/batch → 36 batches.
        assert_eq!(d.effective_upper(), 36);
        d.drain(1800.0); // half the charge
        assert_eq!(d.effective_upper(), 18);
        d.drain(1e9);
        assert_eq!(d.effective_upper(), 0);
    }

    #[test]
    fn abstract_resource_uses_cost_fn_for_partial_energy() {
        let d = ManagedDevice::abstract_resource(
            3,
            CostFn::Affine { fixed: 0.0, per_task: 2.0 },
            0,
            10,
        );
        assert_eq!(d.effective_upper(), 10);
        assert!((d.partial_energy_j(4) - 8.0).abs() < 1e-12);
    }

    #[test]
    fn partial_energy_below_lower_is_prorated_not_rounded_up() {
        // Tabulated cost only defined on [2, 4] (mirrors a lower limit).
        let d = ManagedDevice::abstract_resource(
            4,
            CostFn::from_table(&[(2, 6.0), (3, 8.0), (4, 9.0)]),
            2,
            4,
        );
        assert_eq!(d.partial_energy_j(0), 0.0, "no work, no charge");
        assert!((d.partial_energy_j(1) - 3.0).abs() < 1e-12, "half of C(2)");
        assert!((d.partial_energy_j(3) - 8.0).abs() < 1e-12);
        assert!((d.partial_energy_j(9) - 9.0).abs() < 1e-12, "clamped to cap");
    }

    #[test]
    fn class_signature_tracks_drain_and_drift() {
        let mut d = powered();
        let s0 = d.class_signature();
        assert_eq!(s0.1, 0);
        assert_eq!(s0.2, 36);
        d.drain(1800.0);
        assert_eq!(d.class_signature().2, 18, "drain moves the upper");
        d.drift = 1.5;
        assert_ne!(d.class_signature().0, s0.0, "drift moves the cost");
    }

    #[test]
    fn deadline_cap_clamps_effective_upper() {
        let mut d = powered();
        assert_eq!(d.effective_upper(), 36, "battery cap before any deadline");
        // latency 0.5 s/batch + 2 s upload: 10 s fits 16 batches.
        d.apply_deadline(10.0);
        assert_eq!(d.deadline_cap, 16);
        assert_eq!(d.effective_upper(), 16, "deadline tighter than battery");
        assert_eq!(d.class_signature().2, 16, "deadline is class-visible");
        // A deadline too tight even for the upload leaves the device at 0
        // tasks (it sits out) rather than erroring.
        d.apply_deadline(1.0);
        assert_eq!(d.effective_upper(), 0);
        d.clear_deadline();
        assert_eq!(d.effective_upper(), 36);
        // Abstract resources have no time model → deadline-exempt.
        let mut a = ManagedDevice::abstract_resource(
            1,
            CostFn::Affine { fixed: 0.0, per_task: 1.0 },
            0,
            10,
        );
        a.apply_deadline(0.1);
        assert_eq!(a.effective_upper(), 10);
    }

    #[test]
    fn drift_scales_cost_and_partial_energy() {
        let mut d = powered();
        d.drift = 2.0;
        assert!((d.current_cost().eval(3) - 6.0).abs() < 1e-12);
        assert!((d.partial_energy_j(3) - 6.0).abs() < 1e-12); // 3 J * 2
    }
}
