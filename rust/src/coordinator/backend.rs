//! The training seam of the coordinator: the state machine plans rounds;
//! a [`RoundBackend`] executes them. Two backends ship in-repo — the FL
//! server's PJRT-backed backend (`fl::server`) and the pure-simulation
//! [`SimBackend`] here (schedules and energy only, no ML) — and external
//! runtimes plug in the same way.

use crate::error::Result;
use crate::runtime::pool;
use crate::sched::instance::{Instance, Schedule};
use crate::util::json::Json;

/// One surviving task assignment of a round (dropout victims are removed
/// before the plan reaches the backend; the coordinator accounts their
/// partial energy itself).
#[derive(Clone, Debug)]
pub struct Assignment {
    /// Slot index into the round's `Instance`/`Schedule`.
    pub slot: usize,
    /// Coordinator device index (into its `ManagedDevice` list).
    pub device: usize,
    /// Stable device id (ledger key).
    pub device_id: usize,
    /// Tasks to train (`x_i > 0`).
    pub tasks: usize,
    /// Multiplier the backend must apply to its measured energy (the
    /// coordinator's current drift for this device).
    pub energy_scale: f64,
}

/// The coordinator's plan for one round's Training phase.
#[derive(Clone, Debug)]
pub struct RoundPlan {
    /// Round index.
    pub round: usize,
    /// The solved scheduling instance (slot-indexed).
    pub instance: Instance,
    /// The schedule (slot-indexed, validated).
    pub schedule: Schedule,
    /// Surviving assignments with `tasks > 0`.
    pub assignments: Vec<Assignment>,
}

/// What one device reports back from local training. Besides feeding
/// the energy ledger, the measured `energy_j` drains the device's
/// battery — a Recosting input that dirty-marks the device in the
/// persistent class index ([`crate::sched::incremental::FleetIndex`])
/// when incremental re-derivation is on. Backends return exactly one
/// outcome per assignment, which is what lets the speculative path
/// predict the dirty set before outcomes exist.
#[derive(Clone, Debug)]
pub struct DeviceOutcome {
    /// Stable device id.
    pub device_id: usize,
    /// Coordinator device index.
    pub device: usize,
    /// Tasks trained.
    pub tasks: usize,
    /// Measured energy (joules, drift already applied).
    pub energy_j: f64,
    /// Simulated on-device wall time (seconds).
    pub sim_time_s: f64,
    /// Mean local training loss.
    pub mean_loss: f64,
}

/// Executes the Training/Aggregating phases the coordinator plans.
pub trait RoundBackend {
    /// Train every assignment of the plan; return one outcome per
    /// assignment. The backend holds resulting model updates internally
    /// until [`RoundBackend::aggregate`].
    fn train(&mut self, plan: &RoundPlan) -> Result<Vec<DeviceOutcome>>;

    /// Start the round's training **without blocking** — the seam the
    /// pipelined coordinator overlaps against: between `begin_train` and
    /// [`RoundBackend::finish_train`] it speculatively runs the *next*
    /// round's Scheduling on the coordinator thread. Returns whether an
    /// overlap window actually opened (`true` = training proceeds while
    /// the coordinator keeps working, so speculation is free). The
    /// default does nothing and returns `false` — training happens
    /// synchronously in `finish_train`, and the coordinator skips
    /// speculation rather than paying next-round Scheduling up front for
    /// zero overlap — so existing backends stay correct and cost-neutral
    /// without changes. Backends with real device-side latency kick their
    /// work off here (e.g. [`SimBackend`] with a simulated round latency
    /// runs it on a [`crate::runtime::pool::BackgroundTask`]).
    fn begin_train(&mut self, plan: &RoundPlan) -> Result<bool> {
        let _ = plan;
        Ok(false)
    }

    /// Complete the training started by [`RoundBackend::begin_train`];
    /// identical contract to [`RoundBackend::train`] (one outcome per
    /// surviving assignment). The default falls back to the blocking
    /// `train`, so `begin_train` + `finish_train` is always
    /// outcome-equivalent to a single `train` call — which is what keeps
    /// pipelined and serial campaigns bit-for-bit identical.
    fn finish_train(&mut self, plan: &RoundPlan) -> Result<Vec<DeviceOutcome>> {
        self.train(plan)
    }

    /// Fold the updates from the last `train` call into the global model.
    fn aggregate(&mut self) -> Result<()>;

    /// Held-out loss of the current global model.
    fn evaluate(&mut self) -> Result<f64>;
}

/// Durable backend state for the coordinator store: what a snapshot must
/// capture beyond the coordinator's own fields so
/// `Coordinator::restore` + journal replay is bit-for-bit. Backends whose
/// state cannot be persisted yet (the PJRT model runtime) return an error
/// from [`BackendState::load_state`] and are simply not resumable.
pub trait BackendState {
    /// Serialize durable state (round-boundary invariants only; transient
    /// per-round buffers need not survive).
    fn save_state(&self) -> Json;

    /// Restore state written by [`BackendState::save_state`].
    fn load_state(&mut self, state: &Json) -> Result<()>;
}

/// Pure-simulation backend: energy comes from the plan's own cost
/// functions (the "profiler is accurate" setting), there is no model, and
/// the evaluation loss is a deterministic decaying proxy. This is what
/// lets the coordinator's multi-round loop — including the §3.1 worked
/// example — run end-to-end without PJRT artifacts.
#[derive(Debug, Default)]
pub struct SimBackend {
    rounds_aggregated: usize,
    pending: usize,
    /// Simulated wall-clock cost of one training leg. Zero (the default)
    /// keeps training inline and instantaneous; non-zero makes
    /// `begin_train` run it on a background thread for `train_delay`, so
    /// the pipelined coordinator has a real window to overlap — what the
    /// `fleet_scale` pipeline bench and overlap tests drive.
    train_delay: std::time::Duration,
    /// Training leg started by `begin_train`, awaiting `finish_train`.
    inflight: Option<pool::BackgroundTask<Vec<DeviceOutcome>>>,
    /// Outcomes computed eagerly by `begin_train` when no delay is
    /// configured: the sim "trains" instantly, so the whole leg genuinely
    /// completes inside the overlap window without needing a thread.
    staged: Option<Vec<DeviceOutcome>>,
}

impl SimBackend {
    /// Fresh simulation backend.
    pub fn new() -> Self {
        Self::default()
    }

    /// A backend whose training legs take `delay` of wall-clock time,
    /// running on a background thread between `begin_train` and
    /// `finish_train`. Outcomes are identical to the instant backend —
    /// the delay is pure latency, never a result change.
    pub fn with_train_delay(delay: std::time::Duration) -> Self {
        Self { train_delay: delay, ..Self::default() }
    }

    /// Rounds aggregated so far.
    pub fn rounds_aggregated(&self) -> usize {
        self.rounds_aggregated
    }

    /// The deterministic outcome set for a plan: energy read off the
    /// plan's own (drift-inclusive) slot costs, loss a decaying proxy of
    /// the aggregation count. Pure, so the background leg computes the
    /// exact bits the inline leg would.
    fn outcomes_for(plan: &RoundPlan, rounds_aggregated: usize) -> Vec<DeviceOutcome> {
        plan.assignments
            .iter()
            .map(|a| {
                // The instance's slot cost already includes drift (the
                // coordinator builds it from `current_cost`), so it IS the
                // measured energy here; `energy_scale` must not be applied
                // twice.
                let energy_j = plan.instance.costs[a.slot].eval(a.tasks);
                DeviceOutcome {
                    device_id: a.device_id,
                    device: a.device,
                    tasks: a.tasks,
                    energy_j,
                    sim_time_s: 0.0,
                    mean_loss: 1.0 / (1.0 + rounds_aggregated as f64),
                }
            })
            .collect()
    }
}

impl RoundBackend for SimBackend {
    fn train(&mut self, plan: &RoundPlan) -> Result<Vec<DeviceOutcome>> {
        let outcomes = Self::outcomes_for(plan, self.rounds_aggregated);
        self.pending = plan.assignments.len();
        Ok(outcomes)
    }

    fn begin_train(&mut self, plan: &RoundPlan) -> Result<bool> {
        if self.train_delay.is_zero() {
            // Instant training: the leg completes right here, which makes
            // reporting an open overlap window honest — finish_train only
            // collects the result.
            self.staged = Some(Self::outcomes_for(plan, self.rounds_aggregated));
            return Ok(true);
        }
        let plan = plan.clone();
        let rounds_aggregated = self.rounds_aggregated;
        let delay = self.train_delay;
        self.inflight = Some(pool::BackgroundTask::spawn(move || {
            std::thread::sleep(delay);
            Self::outcomes_for(&plan, rounds_aggregated)
        }));
        Ok(true)
    }

    fn finish_train(&mut self, plan: &RoundPlan) -> Result<Vec<DeviceOutcome>> {
        if let Some(outcomes) = self.staged.take() {
            self.pending = plan.assignments.len();
            return Ok(outcomes);
        }
        match self.inflight.take() {
            Some(task) => {
                let outcomes = task.join();
                self.pending = plan.assignments.len();
                Ok(outcomes)
            }
            None => self.train(plan),
        }
    }

    fn aggregate(&mut self) -> Result<()> {
        if self.pending > 0 {
            self.rounds_aggregated += 1;
            self.pending = 0;
        }
        Ok(())
    }

    fn evaluate(&mut self) -> Result<f64> {
        Ok(1.0 / (1.0 + self.rounds_aggregated as f64))
    }
}

impl BackendState for SimBackend {
    fn save_state(&self) -> Json {
        Json::obj(vec![(
            "rounds_aggregated",
            Json::Num(self.rounds_aggregated as f64),
        )])
    }

    fn load_state(&mut self, state: &Json) -> Result<()> {
        self.rounds_aggregated = crate::store::get_usize(state, "rounds_aggregated")?;
        // Snapshots happen at round boundaries; no updates are in flight.
        // `train_delay` is a process-local latency knob, not campaign
        // state — it never round-trips through snapshots.
        self.pending = 0;
        self.inflight = None;
        self.staged = None;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::costs::CostFn;

    #[test]
    fn sim_backend_reads_energy_off_the_instance() {
        let inst = Instance::new(
            3,
            vec![0, 0],
            vec![3, 3],
            vec![
                CostFn::Affine { fixed: 0.0, per_task: 2.0 },
                CostFn::Affine { fixed: 0.0, per_task: 5.0 },
            ],
        )
        .unwrap();
        let plan = RoundPlan {
            round: 0,
            schedule: Schedule::new(vec![2, 1]),
            assignments: vec![
                Assignment { slot: 0, device: 0, device_id: 10, tasks: 2, energy_scale: 1.0 },
                Assignment { slot: 1, device: 1, device_id: 11, tasks: 1, energy_scale: 1.0 },
            ],
            instance: inst,
        };
        let mut b = SimBackend::new();
        let out = b.train(&plan).unwrap();
        assert_eq!(out.len(), 2);
        assert!((out[0].energy_j - 4.0).abs() < 1e-12);
        assert!((out[1].energy_j - 5.0).abs() < 1e-12);
        let l0 = b.evaluate().unwrap();
        b.aggregate().unwrap();
        assert!(b.evaluate().unwrap() < l0, "proxy loss decays per round");
    }

    #[test]
    fn delayed_training_leg_is_outcome_identical_to_inline() {
        let inst = Instance::new(
            3,
            vec![0, 0],
            vec![3, 3],
            vec![
                CostFn::Affine { fixed: 0.0, per_task: 2.0 },
                CostFn::Affine { fixed: 0.0, per_task: 5.0 },
            ],
        )
        .unwrap();
        let plan = RoundPlan {
            round: 0,
            schedule: Schedule::new(vec![2, 1]),
            assignments: vec![
                Assignment { slot: 0, device: 0, device_id: 10, tasks: 2, energy_scale: 1.0 },
                Assignment { slot: 1, device: 1, device_id: 11, tasks: 1, energy_scale: 1.0 },
            ],
            instance: inst,
        };
        let mut inline = SimBackend::new();
        let a = inline.train(&plan).unwrap();
        let mut delayed =
            SimBackend::with_train_delay(std::time::Duration::from_millis(5));
        assert!(
            delayed.begin_train(&plan).unwrap(),
            "a delayed leg opens the overlap window"
        );
        let b = delayed.finish_train(&plan).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.device_id, y.device_id);
            assert_eq!(x.tasks, y.tasks);
            assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits());
            assert_eq!(x.mean_loss.to_bits(), y.mean_loss.to_bits());
        }
        // The undelayed backend completes its leg inside begin_train —
        // still an open window, still the exact train() bits.
        let mut plain = SimBackend::new();
        assert!(plain.begin_train(&plan).unwrap());
        let c = plain.finish_train(&plan).unwrap();
        assert_eq!(c.len(), a.len());
        assert_eq!(c[0].energy_j.to_bits(), a[0].energy_j.to_bits());
    }

    #[test]
    fn sim_backend_state_roundtrips() {
        let mut b = SimBackend::new();
        b.rounds_aggregated = 7;
        let state = b.save_state();
        let mut b2 = SimBackend::new();
        b2.load_state(&Json::parse(&state.to_string()).unwrap()).unwrap();
        assert_eq!(b2.rounds_aggregated(), 7);
        assert_eq!(b2.evaluate().unwrap(), b.evaluate().unwrap());
    }
}
