//! The training seam of the coordinator: the state machine plans rounds;
//! a [`RoundBackend`] executes them. Two backends ship in-repo — the FL
//! server's PJRT-backed backend (`fl::server`) and the pure-simulation
//! [`SimBackend`] here (schedules and energy only, no ML) — and external
//! runtimes plug in the same way.

use crate::error::Result;
use crate::sched::instance::{Instance, Schedule};
use crate::util::json::Json;

/// One surviving task assignment of a round (dropout victims are removed
/// before the plan reaches the backend; the coordinator accounts their
/// partial energy itself).
#[derive(Clone, Debug)]
pub struct Assignment {
    /// Slot index into the round's `Instance`/`Schedule`.
    pub slot: usize,
    /// Coordinator device index (into its `ManagedDevice` list).
    pub device: usize,
    /// Stable device id (ledger key).
    pub device_id: usize,
    /// Tasks to train (`x_i > 0`).
    pub tasks: usize,
    /// Multiplier the backend must apply to its measured energy (the
    /// coordinator's current drift for this device).
    pub energy_scale: f64,
}

/// The coordinator's plan for one round's Training phase.
#[derive(Clone, Debug)]
pub struct RoundPlan {
    /// Round index.
    pub round: usize,
    /// The solved scheduling instance (slot-indexed).
    pub instance: Instance,
    /// The schedule (slot-indexed, validated).
    pub schedule: Schedule,
    /// Surviving assignments with `tasks > 0`.
    pub assignments: Vec<Assignment>,
}

/// What one device reports back from local training.
#[derive(Clone, Debug)]
pub struct DeviceOutcome {
    /// Stable device id.
    pub device_id: usize,
    /// Coordinator device index.
    pub device: usize,
    /// Tasks trained.
    pub tasks: usize,
    /// Measured energy (joules, drift already applied).
    pub energy_j: f64,
    /// Simulated on-device wall time (seconds).
    pub sim_time_s: f64,
    /// Mean local training loss.
    pub mean_loss: f64,
}

/// Executes the Training/Aggregating phases the coordinator plans.
pub trait RoundBackend {
    /// Train every assignment of the plan; return one outcome per
    /// assignment. The backend holds resulting model updates internally
    /// until [`RoundBackend::aggregate`].
    fn train(&mut self, plan: &RoundPlan) -> Result<Vec<DeviceOutcome>>;

    /// Fold the updates from the last `train` call into the global model.
    fn aggregate(&mut self) -> Result<()>;

    /// Held-out loss of the current global model.
    fn evaluate(&mut self) -> Result<f64>;
}

/// Durable backend state for the coordinator store: what a snapshot must
/// capture beyond the coordinator's own fields so
/// `Coordinator::restore` + journal replay is bit-for-bit. Backends whose
/// state cannot be persisted yet (the PJRT model runtime) return an error
/// from [`BackendState::load_state`] and are simply not resumable.
pub trait BackendState {
    /// Serialize durable state (round-boundary invariants only; transient
    /// per-round buffers need not survive).
    fn save_state(&self) -> Json;

    /// Restore state written by [`BackendState::save_state`].
    fn load_state(&mut self, state: &Json) -> Result<()>;
}

/// Pure-simulation backend: energy comes from the plan's own cost
/// functions (the "profiler is accurate" setting), there is no model, and
/// the evaluation loss is a deterministic decaying proxy. This is what
/// lets the coordinator's multi-round loop — including the §3.1 worked
/// example — run end-to-end without PJRT artifacts.
#[derive(Debug, Default)]
pub struct SimBackend {
    rounds_aggregated: usize,
    pending: usize,
}

impl SimBackend {
    /// Fresh simulation backend.
    pub fn new() -> Self {
        Self::default()
    }

    /// Rounds aggregated so far.
    pub fn rounds_aggregated(&self) -> usize {
        self.rounds_aggregated
    }
}

impl RoundBackend for SimBackend {
    fn train(&mut self, plan: &RoundPlan) -> Result<Vec<DeviceOutcome>> {
        let outcomes = plan
            .assignments
            .iter()
            .map(|a| {
                // The instance's slot cost already includes drift (the
                // coordinator builds it from `current_cost`), so it IS the
                // measured energy here; `energy_scale` must not be applied
                // twice.
                let energy_j = plan.instance.costs[a.slot].eval(a.tasks);
                DeviceOutcome {
                    device_id: a.device_id,
                    device: a.device,
                    tasks: a.tasks,
                    energy_j,
                    sim_time_s: 0.0,
                    mean_loss: 1.0 / (1.0 + self.rounds_aggregated as f64),
                }
            })
            .collect();
        self.pending = plan.assignments.len();
        Ok(outcomes)
    }

    fn aggregate(&mut self) -> Result<()> {
        if self.pending > 0 {
            self.rounds_aggregated += 1;
            self.pending = 0;
        }
        Ok(())
    }

    fn evaluate(&mut self) -> Result<f64> {
        Ok(1.0 / (1.0 + self.rounds_aggregated as f64))
    }
}

impl BackendState for SimBackend {
    fn save_state(&self) -> Json {
        Json::obj(vec![(
            "rounds_aggregated",
            Json::Num(self.rounds_aggregated as f64),
        )])
    }

    fn load_state(&mut self, state: &Json) -> Result<()> {
        self.rounds_aggregated = crate::store::get_usize(state, "rounds_aggregated")?;
        // Snapshots happen at round boundaries; no updates are in flight.
        self.pending = 0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::costs::CostFn;

    #[test]
    fn sim_backend_reads_energy_off_the_instance() {
        let inst = Instance::new(
            3,
            vec![0, 0],
            vec![3, 3],
            vec![
                CostFn::Affine { fixed: 0.0, per_task: 2.0 },
                CostFn::Affine { fixed: 0.0, per_task: 5.0 },
            ],
        )
        .unwrap();
        let plan = RoundPlan {
            round: 0,
            schedule: Schedule::new(vec![2, 1]),
            assignments: vec![
                Assignment { slot: 0, device: 0, device_id: 10, tasks: 2, energy_scale: 1.0 },
                Assignment { slot: 1, device: 1, device_id: 11, tasks: 1, energy_scale: 1.0 },
            ],
            instance: inst,
        };
        let mut b = SimBackend::new();
        let out = b.train(&plan).unwrap();
        assert_eq!(out.len(), 2);
        assert!((out[0].energy_j - 4.0).abs() < 1e-12);
        assert!((out[1].energy_j - 5.0).abs() < 1e-12);
        let l0 = b.evaluate().unwrap();
        b.aggregate().unwrap();
        assert!(b.evaluate().unwrap() < l0, "proxy loss decays per round");
    }

    #[test]
    fn sim_backend_state_roundtrips() {
        let mut b = SimBackend::new();
        b.rounds_aggregated = 7;
        let state = b.save_state();
        let mut b2 = SimBackend::new();
        b2.load_state(&Json::parse(&state.to_string()).unwrap()).unwrap();
        assert_eq!(b2.rounds_aggregated(), 7);
        assert_eq!(b2.evaluate().unwrap(), b.evaluate().unwrap());
    }
}
