//! The coordination layer (L3): a state-machine [`Coordinator`] that owns
//! the multi-round federated-learning loop the paper's §6 envisions —
//!
//! ```text
//! Configuring → ( Scheduling → Training → Aggregating → Recosting )*
//! ```
//!
//! Each round the coordinator **re-derives** the Minimal Cost FL Schedule
//! instance from the fleet's *current* state — battery charge, cost drift,
//! availability churn ([`crate::fl::dynamics`]) — as a class-deduplicated
//! [`FleetInstance`] (interchangeable devices collapse into one class, so
//! class-aware solvers run in the number of classes `k ≪ n`; the
//! `fleet_classes` / `fleet_devices` metrics expose the dedup ratio),
//! solves it through the [`SolverRegistry`], dispatches training to a
//! pluggable
//! [`RoundBackend`], aggregates, then re-costs the fleet for the next
//! round. When the configured solver is the (MC)²MKP DP (directly or via
//! `auto` dispatch), consecutive rounds reuse DP rows for the unchanged
//! prefix of cost tables ([`WarmMc2mkp`]) — warm-started re-solves are
//! bit-for-bit identical to cold solves.
//!
//! The design follows the explicit-phase coordinators of production FL
//! systems (cf. xaynet's state-machine `Coordinator`): every transition is
//! checked, every round emits an energy/cost metrics row, and the
//! training side is a seam (`RoundBackend`) so the same loop drives the
//! PJRT-backed FL server and the dependency-free [`SimBackend`].

pub mod backend;
pub mod device;

pub use backend::{Assignment, DeviceOutcome, RoundBackend, RoundPlan, SimBackend};
pub use device::ManagedDevice;

use crate::config::TrainConfig;
use crate::error::{FedError, Result};
use crate::fl::dynamics::DynamicsConfig;
use crate::metrics::{EnergyLedger, MetricsHub, RoundLog, Timer, TrainingLog};
use crate::sched::auto::{best_algorithm, classify_fleet};
use crate::sched::fleet::FleetInstance;
use crate::sched::instance::{Instance, Schedule};
use crate::sched::mc2mkp::WarmMc2mkp;
use crate::sched::solver::SolverRegistry;
use crate::sched::validate;
use crate::util::rng::Rng;

/// Coordinator life-cycle phases.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Validating configuration and fleet; no round has run.
    Configuring,
    /// Deriving and solving this round's scheduling instance.
    Scheduling,
    /// Devices are training their assignments.
    Training,
    /// Folding updates into the global model and evaluating.
    Aggregating,
    /// Updating device profiles (battery, drift, availability) for the
    /// next round.
    Recosting,
}

impl Phase {
    fn can_transition_to(self, next: Phase) -> bool {
        matches!(
            (self, next),
            (Phase::Configuring, Phase::Scheduling)
                | (Phase::Scheduling, Phase::Training)
                // Empty rounds (nobody online / nothing scheduled) skip
                // straight to re-costing.
                | (Phase::Scheduling, Phase::Recosting)
                | (Phase::Training, Phase::Aggregating)
                | (Phase::Aggregating, Phase::Recosting)
                | (Phase::Recosting, Phase::Scheduling)
        )
    }
}

/// What the coordinator needs to know to drive rounds (the scheduling
/// subset of [`TrainConfig`], minus the ML-side knobs).
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Rounds to run in [`Coordinator::run`].
    pub rounds: usize,
    /// Mini-batches to distribute per round (`T`).
    pub tasks_per_round: usize,
    /// Solver name resolved through the [`SolverRegistry`].
    pub algo: String,
    /// Fraction of the fleet selected per round (FedAvg's `C`).
    pub participation: f64,
    /// Config-level minimum participation per selected device (combined
    /// with each device's intrinsic lower limit).
    pub min_tasks: usize,
    /// Over-representation guard: no device may receive more than this
    /// fraction of a round's tasks (paper §6). Relaxed automatically if
    /// the capped capacity cannot absorb `T`.
    pub max_share: f64,
    /// Seed for selection/dynamics randomness.
    pub seed: u64,
    /// Early-stop target on evaluation loss.
    pub target_loss: Option<f64>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            rounds: 50,
            tasks_per_round: 64,
            algo: "auto".into(),
            participation: 1.0,
            min_tasks: 0,
            max_share: 0.25,
            seed: 7,
            target_loss: None,
        }
    }
}

impl CoordinatorConfig {
    /// Extract the coordination knobs from a full training config.
    pub fn from_train(cfg: &TrainConfig) -> Self {
        Self {
            rounds: cfg.rounds,
            tasks_per_round: cfg.tasks_per_round,
            algo: cfg.policy.to_string(),
            participation: cfg.participation,
            min_tasks: cfg.min_tasks,
            max_share: cfg.max_share,
            seed: cfg.seed,
            target_loss: cfg.target_loss,
        }
    }
}

/// The multi-round FL coordinator (see module docs).
pub struct Coordinator<B: RoundBackend> {
    cfg: CoordinatorConfig,
    devices: Vec<ManagedDevice>,
    dynamics: DynamicsConfig,
    registry: SolverRegistry,
    warm: WarmMc2mkp,
    rng: Rng,
    phase: Phase,
    /// Online device indices entering the next Scheduling phase.
    pool: Vec<usize>,
    next_round: usize,
    backend: B,
    ledger: EnergyLedger,
    metrics: MetricsHub,
    log: TrainingLog,
}

impl<B: RoundBackend> Coordinator<B> {
    /// Configure a coordinator over a managed fleet. Fails (still in
    /// `Configuring`) if the solver name is unknown or the fleet is empty.
    pub fn new(
        cfg: CoordinatorConfig,
        devices: Vec<ManagedDevice>,
        backend: B,
    ) -> Result<Self> {
        if devices.is_empty() {
            return Err(FedError::Coordinator("empty fleet".into()));
        }
        if cfg.tasks_per_round == 0 {
            return Err(FedError::Coordinator("tasks_per_round must be > 0".into()));
        }
        if !(0.0..=1.0).contains(&cfg.participation) || cfg.participation == 0.0 {
            return Err(FedError::Coordinator("participation must be in (0, 1]".into()));
        }
        if !(0.0..=1.0).contains(&cfg.max_share) || cfg.max_share == 0.0 {
            return Err(FedError::Coordinator("max_share must be in (0, 1]".into()));
        }
        let registry = SolverRegistry::with_defaults(cfg.seed);
        registry.resolve(&cfg.algo)?;
        let rng = Rng::new(cfg.seed);
        let pool = (0..devices.len()).collect();
        Ok(Self {
            cfg,
            devices,
            dynamics: DynamicsConfig::none(),
            registry,
            warm: WarmMc2mkp::new(),
            rng,
            phase: Phase::Configuring,
            pool,
            next_round: 0,
            backend,
            ledger: EnergyLedger::new(),
            metrics: MetricsHub::new(),
            log: TrainingLog::new(),
        })
    }

    /// Install dynamic fleet behaviour (availability churn, cost drift,
    /// mid-round dropout).
    pub fn set_dynamics(&mut self, dynamics: DynamicsConfig) {
        self.dynamics = dynamics;
    }

    /// Current phase.
    pub fn phase(&self) -> Phase {
        self.phase
    }

    /// The solver registry (e.g. to register custom solvers before
    /// running).
    pub fn registry_mut(&mut self) -> &mut SolverRegistry {
        &mut self.registry
    }

    /// Managed devices (current, re-costed state).
    pub fn devices(&self) -> &[ManagedDevice] {
        &self.devices
    }

    /// The training backend.
    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// Mutable training backend.
    pub fn backend_mut(&mut self) -> &mut B {
        &mut self.backend
    }

    /// Per-device / per-round energy ledger.
    pub fn ledger(&self) -> &EnergyLedger {
        &self.ledger
    }

    /// Counters and gauges.
    pub fn metrics(&self) -> &MetricsHub {
        &self.metrics
    }

    /// Per-round training log.
    pub fn log(&self) -> &TrainingLog {
        &self.log
    }

    /// The coordinator configuration.
    pub fn cfg(&self) -> &CoordinatorConfig {
        &self.cfg
    }

    fn transition(&mut self, next: Phase) -> Result<()> {
        if !self.phase.can_transition_to(next) {
            return Err(FedError::Coordinator(format!(
                "illegal transition {:?} → {next:?}",
                self.phase
            )));
        }
        self.phase = next;
        Ok(())
    }

    /// Build this round's **fleet instance** over `selected` device
    /// indices (with their already-computed `raw_uppers`, which the caller
    /// derived from current device state and checked to be non-empty in
    /// total). Devices sharing a cost signature and limits collapse into
    /// classes — on real fleets `k ≪ n`, which is what the class-aware
    /// solvers exploit.
    fn build_instance(
        &mut self,
        selected: &[usize],
        raw_uppers: &[usize],
    ) -> Result<(FleetInstance, usize)> {
        // Overflow-safe capacity: "unlimited" devices may carry
        // `usize::MAX` uppers (same encoding Instance::validate hardens
        // against), so clamp each term to T before a saturating fold.
        let t_req = self.cfg.tasks_per_round;
        let capacity: usize = raw_uppers
            .iter()
            .fold(0usize, |a, &u| a.saturating_add(u.min(t_req)));
        debug_assert!(capacity > 0, "caller degrades zero capacity to an empty round");
        let t = t_req.min(capacity);

        // Over-representation guard (§6): cap any device at max_share · T,
        // doubling the cap until the capped fleet can still absorb T.
        let mut cap = ((t as f64 * self.cfg.max_share).ceil() as usize).max(1);
        let uppers: Vec<usize> = loop {
            let capped: Vec<usize> = raw_uppers.iter().map(|&u| u.min(cap)).collect();
            if capped
                .iter()
                .fold(0usize, |a, &c| a.saturating_add(c))
                >= t
            {
                break capped;
            }
            cap *= 2;
        };

        // Lower limits: config-level minimum joined with each device's
        // intrinsic minimum, clamped to the (possibly share-capped) upper.
        let lower: Vec<usize> = selected
            .iter()
            .zip(&uppers)
            .map(|(&d, &u)| self.cfg.min_tasks.max(self.devices[d].lower).min(u))
            .collect();
        // Relax in two stages when ΣL overshoots T: first drop the
        // config-level minimum and keep only the intrinsic device minima;
        // if even those sum above T (a small round over a demanding
        // fleet), drop all lower limits rather than failing every round —
        // metered so the relaxation is observable.
        let lower = if lower.iter().sum::<usize>() > t {
            let intrinsic: Vec<usize> = selected
                .iter()
                .zip(&uppers)
                .map(|(&d, &u)| self.devices[d].lower.min(u))
                .collect();
            if intrinsic.iter().sum::<usize>() > t {
                self.metrics.inc("lower_limits_relaxed", 1);
                vec![0; uppers.len()]
            } else {
                intrinsic
            }
        } else {
            lower
        };
        let mut b = FleetInstance::builder().tasks(t);
        for ((&d, &u), &l) in selected.iter().zip(&uppers).zip(&lower) {
            b = b.device(self.devices[d].current_cost(), l, u);
        }
        Ok((b.build()?, t))
    }

    /// Solve the fleet instance with the configured algorithm,
    /// warm-starting the (MC)²MKP DP whenever the DP is what runs
    /// (configured directly or chosen by `auto` dispatch). `flat` is the
    /// slot-expanded view of `fleet` (the caller needs it for the round
    /// plan anyway); the warm DP row cache keys on it.
    fn solve(&mut self, fleet: &FleetInstance, flat: &Instance) -> Result<Schedule> {
        let canonical = self.registry.resolve(&self.cfg.algo)?.name();
        // Resolve `auto` to its concrete Table 2 pick here, once: the
        // classification is per *class* (cheap on deduplicated fleets),
        // and registry overrides of the concrete solvers are honored by
        // the dispatch.
        let effective = if canonical == "auto" && !self.registry.is_overridden("auto")
        {
            best_algorithm(&classify_fleet(fleet))
        } else {
            canonical
        };
        // The warm fast path only stands in for the *built-in* DP; a
        // caller-registered "mc2mkp" must win over it.
        if effective == "mc2mkp" && !self.registry.is_overridden("mc2mkp") {
            let (schedule, info) = self.warm.solve(flat)?;
            self.metrics.inc("dp_solves", 1);
            self.metrics.inc("dp_rows_reused", info.reused_rows as u64);
            self.metrics.inc("dp_rows_total", info.total_rows as u64);
            Ok(schedule)
        } else {
            Ok(self
                .registry
                .solve_fleet_seeded(effective, fleet, &mut self.rng)?
                .expand(fleet))
        }
    }

    /// Drive one full round through the state machine; returns the logged
    /// row. On an error mid-round the machine is returned to the ready
    /// (`Scheduling`) state, so a caller that handles the error can keep
    /// driving rounds.
    pub fn round(&mut self) -> Result<RoundLog> {
        match self.phase {
            Phase::Configuring => self.transition(Phase::Scheduling)?,
            Phase::Scheduling => {}
            other => {
                return Err(FedError::Coordinator(format!(
                    "round() may not start from {other:?}"
                )))
            }
        }
        let round_idx = self.next_round;
        self.next_round += 1;
        let result = self.round_inner(round_idx);
        if result.is_err() {
            self.phase = Phase::Scheduling;
            // The aborted round still consumed its index, and dropout
            // victims may already have burned real energy into an open
            // ledger bucket. Log an explicit aborted row (opening an empty
            // bucket if none was) so `Σ log energy == ledger total` and
            // one-row-per-round hold for callers that handle the error
            // and keep driving rounds.
            if self.ledger.rounds().len() <= self.log.rows().len() {
                self.ledger.begin_round();
            }
            let energy_j = self.ledger.rounds().last().copied().unwrap_or(0.0);
            let loss = self.log.rows().last().map(|r| r.loss).unwrap_or(f64::NAN);
            self.log.push(RoundLog {
                round: round_idx,
                policy: self.cfg.algo.clone(),
                loss,
                energy_j,
                sched_time_s: 0.0,
                train_time_s: 0.0,
                participants: 0,
                tasks: 0,
            });
            self.metrics.inc("aborted_rounds", 1);
        }
        result
    }

    fn round_inner(&mut self, round_idx: usize) -> Result<RoundLog> {
        // ---- Scheduling ------------------------------------------------
        if self.pool.is_empty() {
            // Nobody online: an empty round (no energy, model unchanged).
            self.ledger.begin_round();
            let loss = self.backend.evaluate()?;
            self.metrics.inc("empty_rounds", 1);
            let row = self.finish_round(round_idx, loss, 0.0, 0.0, 0.0, 0, 0)?;
            return Ok(row);
        }

        let n_online = self.pool.len();
        let k = ((self.devices.len() as f64 * self.cfg.participation).ceil()
            as usize)
            .clamp(1, n_online);
        let picks = self.rng.sample_indices(n_online, k);
        let mut selected: Vec<usize> = picks.iter().map(|&i| self.pool[i]).collect();
        // Stable slot order: keeps slot→device mapping canonical and
        // maximizes the unchanged class prefix the warm DP can reuse.
        selected.sort_unstable();

        // Exhausted fleet (e.g. every selected battery drained to zero):
        // degrade to an empty round instead of aborting the run.
        let raw_uppers: Vec<usize> = selected
            .iter()
            .map(|&d| self.devices[d].effective_upper())
            .collect();
        if raw_uppers.iter().all(|&u| u == 0) {
            self.ledger.begin_round();
            let loss = self.backend.evaluate()?;
            self.metrics.inc("empty_rounds", 1);
            self.metrics.inc("exhausted_rounds", 1);
            return self.finish_round(round_idx, loss, 0.0, 0.0, 0.0, 0, 0);
        }

        let (fleet, t) = self.build_instance(&selected, &raw_uppers)?;
        self.metrics.inc("fleet_devices", fleet.n_devices() as u64);
        self.metrics.inc("fleet_classes", fleet.n_classes() as u64);
        let instance = fleet.to_flat();
        let timer = Timer::start();
        let schedule = self.solve(&fleet, &instance)?;
        let sched_time_s = timer.elapsed_s();
        validate::check(&instance, &schedule)?;
        let predicted_j = validate::total_cost(&instance, &schedule);

        // ---- Training --------------------------------------------------
        self.transition(Phase::Training)?;
        self.ledger.begin_round();
        let wall = Timer::start();
        let mut assignments = Vec::new();
        for (slot, &d) in selected.iter().enumerate() {
            let tasks = schedule.get(slot);
            if tasks == 0 {
                continue;
            }
            // Mid-round dropout: the device burns energy for the fraction
            // of work it completed, but its update is lost (§6 "loss of a
            // device").
            let failed_at = self
                .dynamics
                .dropout
                .as_ref()
                .and_then(|dr| dr.sample(&mut self.rng));
            if let Some(frac) = failed_at {
                let done = ((tasks as f64) * frac).floor() as usize;
                let wasted = self.devices[d].partial_energy_j(done);
                self.ledger.record(self.devices[d].id, wasted);
                self.devices[d].drain(wasted);
                self.metrics.inc("dropouts", 1);
                continue;
            }
            assignments.push(Assignment {
                slot,
                device: d,
                device_id: self.devices[d].id,
                tasks,
                energy_scale: self.devices[d].drift,
            });
        }
        let plan = RoundPlan {
            round: round_idx,
            instance,
            schedule,
            assignments,
        };
        let outcomes = self.backend.train(&plan)?;
        let mut sim_time_s = 0.0f64;
        let mut loss_sum = 0.0;
        let mut loss_n = 0usize;
        for o in &outcomes {
            self.ledger.record(o.device_id, o.energy_j);
            self.devices[o.device].drain(o.energy_j);
            sim_time_s = sim_time_s.max(o.sim_time_s); // devices run in parallel
            loss_sum += o.mean_loss * o.tasks as f64;
            loss_n += o.tasks;
        }
        let train_time_s = wall.elapsed_s();
        self.metrics.set("sim_round_time_s", sim_time_s);
        self.metrics.set(
            "train_loss",
            if loss_n > 0 { loss_sum / loss_n as f64 } else { 0.0 },
        );

        // ---- Aggregating -----------------------------------------------
        self.transition(Phase::Aggregating)?;
        self.backend.aggregate()?;
        let eval_loss = self.backend.evaluate()?;

        self.finish_round(
            round_idx,
            eval_loss,
            sched_time_s,
            train_time_s,
            predicted_j,
            outcomes.len(),
            t,
        )
    }

    /// Recosting phase + metrics row shared by normal and empty rounds.
    #[allow(clippy::too_many_arguments)]
    fn finish_round(
        &mut self,
        round_idx: usize,
        loss: f64,
        sched_time_s: f64,
        train_time_s: f64,
        predicted_j: f64,
        participants: usize,
        tasks: usize,
    ) -> Result<RoundLog> {
        self.transition(Phase::Recosting)?;
        // Advance fleet dynamics for the NEXT round: drift the energy
        // profiles and churn availability. Battery state was already
        // re-costed in place as energy was recorded.
        if let Some(drift) = self.dynamics.drift.as_mut() {
            drift.step(&mut self.rng);
            for (i, dev) in self.devices.iter_mut().enumerate() {
                dev.drift = drift.scale(i);
            }
        }
        self.pool = match self.dynamics.availability.as_mut() {
            Some(av) => av.step(&mut self.rng),
            None => (0..self.devices.len()).collect(),
        };

        let energy_j = self.ledger.rounds().last().copied().unwrap_or(0.0);
        let row = RoundLog {
            round: round_idx,
            policy: self.cfg.algo.clone(),
            loss,
            energy_j,
            sched_time_s,
            train_time_s,
            participants,
            tasks,
        };
        self.metrics.inc("rounds", 1);
        self.metrics.inc("tasks", tasks as u64);
        self.metrics.set("eval_loss", loss);
        self.metrics.set("predicted_energy_j", predicted_j);
        self.log.push(row.clone());
        // Ready for the next round.
        self.phase = Phase::Scheduling;
        Ok(row)
    }

    /// Run the configured number of rounds (early-stopping on
    /// `target_loss`); returns the accumulated log.
    pub fn run(&mut self) -> Result<&TrainingLog> {
        for _ in 0..self.cfg.rounds {
            let row = self.round()?;
            if let Some(target) = self.cfg.target_loss {
                if row.loss <= target {
                    self.metrics.inc("early_stops", 1);
                    break;
                }
            }
        }
        Ok(&self.log)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::costs::CostFn;

    fn paper_fleet() -> Vec<ManagedDevice> {
        let inst = Instance::paper_example(5);
        (0..inst.n())
            .map(|i| {
                ManagedDevice::abstract_resource(
                    i,
                    inst.costs[i].clone(),
                    inst.lower[i],
                    inst.upper[i],
                )
            })
            .collect()
    }

    fn paper_cfg() -> CoordinatorConfig {
        CoordinatorConfig {
            rounds: 3,
            tasks_per_round: 5,
            algo: "mc2mkp".into(),
            max_share: 1.0,
            ..CoordinatorConfig::default()
        }
    }

    #[test]
    fn reproduces_the_section31_optimum_on_round_one() {
        let mut c = Coordinator::new(paper_cfg(), paper_fleet(), SimBackend::new())
            .unwrap();
        let row = c.round().unwrap();
        assert_eq!(row.tasks, 5);
        // X* = {2, 3, 0}: resource 3 sits idle, so 2 devices participate.
        assert_eq!(row.participants, 2);
        assert!((row.energy_j - 7.5).abs() < 1e-9, "ΣC = {}", row.energy_j);
        assert!((c.ledger().total() - 7.5).abs() < 1e-9);
    }

    #[test]
    fn phase_machine_rejects_illegal_transitions() {
        let mut c = Coordinator::new(paper_cfg(), paper_fleet(), SimBackend::new())
            .unwrap();
        assert_eq!(c.phase(), Phase::Configuring);
        assert!(c.transition(Phase::Training).is_err());
        assert!(c.transition(Phase::Aggregating).is_err());
        c.round().unwrap();
        assert_eq!(c.phase(), Phase::Scheduling);
        assert!(c.transition(Phase::Recosting).is_ok(), "empty-round edge");
    }

    #[test]
    fn rejects_bad_configuration() {
        assert!(Coordinator::new(paper_cfg(), vec![], SimBackend::new()).is_err());
        let mut cfg = paper_cfg();
        cfg.algo = "not-a-solver".into();
        assert!(Coordinator::new(cfg, paper_fleet(), SimBackend::new()).is_err());
        let mut cfg = paper_cfg();
        cfg.participation = 0.0;
        assert!(Coordinator::new(cfg, paper_fleet(), SimBackend::new()).is_err());
    }

    #[test]
    fn warm_start_metrics_accumulate_across_rounds() {
        let mut c = Coordinator::new(paper_cfg(), paper_fleet(), SimBackend::new())
            .unwrap();
        c.run().unwrap();
        assert_eq!(c.metrics().counter("dp_solves"), 3);
        // Static fleet, static costs: rounds 2 and 3 reuse every DP row.
        assert_eq!(c.metrics().counter("dp_rows_reused"), 6);
        assert_eq!(c.metrics().counter("dp_rows_total"), 9);
    }

    #[test]
    fn battery_drain_recosts_subsequent_rounds() {
        use crate::energy::battery::Battery;
        use crate::energy::power::{Behavior, PowerModel};
        // One battery device that can afford 4 tasks in round 1, and one
        // expensive mains device. Draining the battery must shift work.
        let cheap_power = PowerModel {
            idle_w: 0.0,
            busy_w: 2.0,
            batch_latency_s: 0.5,
            behavior: Behavior::Linear,
            curvature: 0.0,
        }; // 1 J per task
        let devices = vec![
            ManagedDevice {
                id: 0,
                cost: cheap_power.cost_fn(),
                lower: 0,
                data_cap: 10,
                battery: Some(Battery {
                    // 8 J remaining at 50% budget → 4 tasks in round 1.
                    capacity_wh: 8.0 / 3600.0,
                    level: 1.0,
                    round_budget_frac: 0.5,
                }),
                power: Some(cheap_power),
                drift: 1.0,
            },
            ManagedDevice::abstract_resource(
                1,
                CostFn::Affine { fixed: 0.0, per_task: 100.0 },
                0,
                10,
            ),
        ];
        let cfg = CoordinatorConfig {
            rounds: 2,
            tasks_per_round: 4,
            algo: "auto".into(),
            max_share: 1.0,
            ..CoordinatorConfig::default()
        };
        let mut c = Coordinator::new(cfg, devices, SimBackend::new()).unwrap();
        let r1 = c.round().unwrap();
        assert!((r1.energy_j - 4.0).abs() < 1e-9, "round 1 all on battery dev");
        // 4 J drained → 4 J remain → budget 2 J → U_0 = 2 next round.
        let r2 = c.round().unwrap();
        assert!(
            (r2.energy_j - (2.0 + 200.0)).abs() < 1e-9,
            "round 2 must overflow to the expensive device: {}",
            r2.energy_j
        );
    }

    #[test]
    fn exhausted_fleet_degrades_to_empty_rounds() {
        use crate::energy::battery::Battery;
        use crate::energy::power::{Behavior, PowerModel};
        let power = PowerModel {
            idle_w: 0.0,
            busy_w: 2.0,
            batch_latency_s: 0.5,
            behavior: Behavior::Linear,
            curvature: 0.0,
        }; // 1 J per task
        let devices = vec![ManagedDevice {
            id: 0,
            cost: power.cost_fn(),
            lower: 0,
            data_cap: 10,
            battery: Some(Battery {
                capacity_wh: 2.0 / 3600.0, // 2 J total
                level: 1.0,
                round_budget_frac: 1.0,
            }),
            power: Some(power),
            drift: 1.0,
        }];
        let cfg = CoordinatorConfig {
            rounds: 3,
            tasks_per_round: 4,
            algo: "auto".into(),
            max_share: 1.0,
            ..CoordinatorConfig::default()
        };
        let mut c = Coordinator::new(cfg, devices, SimBackend::new()).unwrap();
        c.run().unwrap();
        let rows = c.log().rows();
        assert_eq!(rows.len(), 3, "run must survive battery exhaustion");
        assert!((rows[0].energy_j - 2.0).abs() < 1e-9);
        assert_eq!(rows[1].energy_j, 0.0);
        assert_eq!(rows[2].energy_j, 0.0);
        assert_eq!(c.metrics().counter("exhausted_rounds"), 2);
    }

    #[test]
    fn round_errors_leave_the_machine_ready() {
        struct FailingBackend;
        impl RoundBackend for FailingBackend {
            fn train(&mut self, _plan: &RoundPlan) -> Result<Vec<DeviceOutcome>> {
                Err(FedError::Fl("injected training failure".into()))
            }
            fn aggregate(&mut self) -> Result<()> {
                Ok(())
            }
            fn evaluate(&mut self) -> Result<f64> {
                Ok(0.0)
            }
        }
        let mut c =
            Coordinator::new(paper_cfg(), paper_fleet(), FailingBackend).unwrap();
        let e1 = c.round().unwrap_err().to_string();
        assert!(e1.contains("injected"), "{e1}");
        // The failure must not wedge the phase machine: the next round
        // reports the same backend error, not an illegal transition.
        let e2 = c.round().unwrap_err().to_string();
        assert!(e2.contains("injected"), "{e2}");
        assert_eq!(c.phase(), Phase::Scheduling);
        // Aborted rounds are still accounted: one row + one ledger bucket
        // each, so log and ledger stay in lockstep across failures.
        assert_eq!(c.metrics().counter("aborted_rounds"), 2);
        assert_eq!(c.log().rows().len(), 2);
        assert_eq!(c.ledger().rounds().len(), 2);
        let logged: f64 = c.log().rows().iter().map(|r| r.energy_j).sum();
        assert!((logged - c.ledger().total()).abs() < 1e-12);
    }

    #[test]
    fn registry_override_of_mc2mkp_disables_the_warm_fast_path() {
        use crate::sched::solver::Solver;
        struct UniformAsDp;
        impl Solver for UniformAsDp {
            fn name(&self) -> &'static str {
                "mc2mkp"
            }
            fn solve_flat(&self, inst: &Instance) -> Result<Schedule> {
                crate::sched::baselines::uniform(inst)
            }
        }
        let mut c = Coordinator::new(paper_cfg(), paper_fleet(), SimBackend::new())
            .unwrap();
        c.registry_mut().register(Box::new(UniformAsDp));
        let row = c.round().unwrap();
        // Uniform on the §3.1 example is feasible but NOT optimal, and the
        // warm DP must not have run.
        assert!(row.energy_j > 7.5 + 1e-9, "override ignored: {}", row.energy_j);
        assert_eq!(c.metrics().counter("dp_solves"), 0);
    }

    #[test]
    fn unlimited_uppers_do_not_overflow_capacity_sums() {
        let c = CostFn::Affine { fixed: 0.0, per_task: 1.0 };
        let devices = vec![
            ManagedDevice::abstract_resource(0, c.clone(), 0, usize::MAX),
            ManagedDevice::abstract_resource(1, c, 0, usize::MAX),
        ];
        let cfg = CoordinatorConfig {
            rounds: 1,
            tasks_per_round: 40,
            algo: "auto".into(),
            max_share: 1.0,
            ..CoordinatorConfig::default()
        };
        let mut coord = Coordinator::new(cfg, devices, SimBackend::new()).unwrap();
        let row = coord.round().unwrap();
        assert_eq!(row.tasks, 40);
        assert!((row.energy_j - 40.0).abs() < 1e-9);
    }

    #[test]
    fn identical_devices_collapse_into_classes() {
        let c = CostFn::Affine { fixed: 0.0, per_task: 1.0 };
        let devices: Vec<ManagedDevice> = (0..6)
            .map(|i| ManagedDevice::abstract_resource(i, c.clone(), 0, 4))
            .collect();
        let cfg = CoordinatorConfig {
            rounds: 1,
            tasks_per_round: 12,
            algo: "auto".into(),
            max_share: 1.0,
            ..CoordinatorConfig::default()
        };
        let mut coord = Coordinator::new(cfg, devices, SimBackend::new()).unwrap();
        let row = coord.round().unwrap();
        assert_eq!(row.tasks, 12);
        assert!((row.energy_j - 12.0).abs() < 1e-9);
        // Six interchangeable devices → one scheduling class.
        assert_eq!(coord.metrics().counter("fleet_devices"), 6);
        assert_eq!(coord.metrics().counter("fleet_classes"), 1);
    }

    #[test]
    fn run_is_deterministic_for_a_seed() {
        let go = || {
            let cfg = CoordinatorConfig {
                rounds: 5,
                algo: "random".into(),
                ..paper_cfg()
            };
            let mut c =
                Coordinator::new(cfg, paper_fleet(), SimBackend::new()).unwrap();
            c.run().unwrap();
            c.log()
                .rows()
                .iter()
                .map(|r| (r.loss, r.energy_j))
                .collect::<Vec<_>>()
        };
        assert_eq!(go(), go());
    }
}
