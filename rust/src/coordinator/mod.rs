//! The coordination layer (L3): a state-machine [`Coordinator`] that owns
//! the multi-round federated-learning loop the paper's §6 envisions —
//!
//! ```text
//! Configuring → ( Scheduling → Training → Aggregating → Recosting )*
//! ```
//!
//! Each round the coordinator **re-derives** the Minimal Cost FL Schedule
//! instance from the fleet's *current* state — battery charge, cost drift,
//! availability churn ([`crate::fl::dynamics`]) — as a class-deduplicated
//! [`FleetInstance`] (interchangeable devices collapse into one class, so
//! class-aware solvers run in the number of classes `k ≪ n`; the
//! `fleet_classes` / `fleet_devices` metrics expose the dedup ratio),
//! solves it through the [`SolverRegistry`], dispatches training to a
//! pluggable
//! [`RoundBackend`], aggregates, then re-costs the fleet for the next
//! round. When the configured solver is the (MC)²MKP DP (directly or via
//! `auto` dispatch), consecutive rounds reuse DP rows for the unchanged
//! prefix of cost tables ([`WarmMc2mkp`]) — warm-started re-solves are
//! bit-for-bit identical to cold solves.
//!
//! The design follows the explicit-phase coordinators of production FL
//! systems (cf. xaynet's state-machine `Coordinator`): every transition is
//! checked, every round emits an energy/cost metrics row, and the
//! training side is a seam (`RoundBackend`) so the same loop drives the
//! PJRT-backed FL server and the dependency-free [`SimBackend`].
//!
//! # Pipelined rounds
//!
//! With [`PipelineConfig`] enabled the round is split into its two
//! halves — **prepare** (the Scheduling phase: selection, instance
//! derivation, solve) and **commit** (Training → Aggregating →
//! Recosting) — and the driver overlaps them across consecutive rounds:
//! while round `r` trains behind the [`RoundBackend::begin_train`] /
//! [`RoundBackend::finish_train`] seam (`begin_train` reports whether an
//! overlap window actually opened; synchronous backends report none and
//! the driver skips speculation rather than paying Scheduling up front
//! for zero overlap), the coordinator *speculatively*
//! prepares round `r + 1` against the **predicted** post-round state
//! (training drains guessed from the plan's own costs — exact for the
//! sim backend — and Recosting's RNG/dynamics steps, which never depend
//! on training results, replayed on clones). When round `r` commits, a
//! guard digest over everything Scheduling reads (RNG state, online
//! pool, per-device limits and drift-scaled costs) decides: equal means
//! round `r + 1`'s Scheduling would be a pure-function replay of the
//! speculation, so it is **adopted** — RNG, warm-DP cache, and metric
//! increments included — and is bit-for-bit what the serial loop would
//! have computed; unequal means the speculation is discarded and the
//! round prepares serially. Either way journal lines, digests, RNG
//! streams, and recovery are identical to the serial loop; speculation
//! is pure overlap, observable only through the `pipeline_*` metrics.

pub mod backend;
pub mod device;

pub use backend::{
    Assignment, BackendState, DeviceOutcome, RoundBackend, RoundPlan, SimBackend,
};
pub use device::ManagedDevice;

use crate::config::TrainConfig;
use crate::error::{FedError, Result};
use crate::fl::dynamics::DynamicsConfig;
use crate::metrics::{EnergyLedger, MetricsHub, RoundLog, Timer, TrainingLog};
use crate::obs::hist::{secs_to_ns, ObsHists};
use crate::obs::{NoopTracer, Tracer, COORD_LANE};
use crate::runtime::pool;
use crate::sched::auto::{best_algorithm, classify_fleet};
use crate::sched::costs::CostFn;
use crate::sched::fleet::FleetInstance;
use crate::sched::incremental::{self, FleetIndex, RoundParams};
use crate::sched::instance::{Instance, Schedule};
use crate::sched::mc2mkp::WarmMc2mkp;
use crate::sched::solver::SolverRegistry;
use crate::sched::validate;
use crate::store::journal::{round_digest, JournalEntry, ABORTED_SOLVER};
use crate::store::snapshot as snap;
use crate::store::{get, get_arr, get_f64, get_usize, jf, CampaignStore, MetricSink};
use crate::util::hash::{mix_u64, FNV_OFFSET};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Coordinator life-cycle phases.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Validating configuration and fleet; no round has run.
    Configuring,
    /// Deriving and solving this round's scheduling instance.
    Scheduling,
    /// Devices are training their assignments.
    Training,
    /// Folding updates into the global model and evaluating.
    Aggregating,
    /// Updating device profiles (battery, drift, availability) for the
    /// next round.
    Recosting,
}

impl Phase {
    fn can_transition_to(self, next: Phase) -> bool {
        matches!(
            (self, next),
            (Phase::Configuring, Phase::Scheduling)
                | (Phase::Scheduling, Phase::Training)
                // Empty rounds (nobody online / nothing scheduled) skip
                // straight to re-costing.
                | (Phase::Scheduling, Phase::Recosting)
                | (Phase::Training, Phase::Aggregating)
                | (Phase::Aggregating, Phase::Recosting)
                | (Phase::Recosting, Phase::Scheduling)
        )
    }
}

/// The one idiom behind every coordinator feature toggle. A knob is a
/// small `Copy` struct with an `enabled` flag, `on`/`off` constructors,
/// and a conversion impl: `From<bool>` for payload-free knobs,
/// `From<Option<payload>>` (its payload analogue — `Some` enables, `None`
/// disables) for knobs whose "on" state carries a value. Generating the
/// trio from one macro is what keeps the surfaces from drifting apart
/// again: the hand-written copies this replaces had grown three subtly
/// different shapes, and only one of them its `From` impl.
macro_rules! toggle_config {
    // Payload-free knob: `on()` / `off()` / `From<bool>`.
    (
        $(#[$doc:meta])*
        $name:ident {
            $(#[$edoc:meta])*
            enabled
        }
    ) => {
        $(#[$doc])*
        #[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
        pub struct $name {
            $(#[$edoc])*
            pub enabled: bool,
        }

        impl $name {
            #[doc = concat!("`", stringify!($name), "` enabled.")]
            pub fn on() -> Self {
                Self { enabled: true }
            }

            #[doc = concat!("`", stringify!($name), "` disabled (the default).")]
            pub fn off() -> Self {
                Self { enabled: false }
            }
        }

        impl From<bool> for $name {
            fn from(enabled: bool) -> Self {
                Self { enabled }
            }
        }
    };
    // Payload-carrying knob: `on(payload)` / `off()` /
    // `From<Option<payload>>`. (No `Eq`: payloads may be floats.)
    (
        $(#[$doc:meta])*
        $name:ident {
            $(#[$edoc:meta])*
            enabled,
            $(#[$fdoc:meta])*
            $field:ident: $fty:ty
        }
    ) => {
        $(#[$doc])*
        #[derive(Clone, Copy, Debug, Default, PartialEq)]
        pub struct $name {
            $(#[$edoc])*
            pub enabled: bool,
            $(#[$fdoc])*
            pub $field: $fty,
        }

        impl $name {
            #[doc = concat!(
                "`", stringify!($name), "` enabled with the given `",
                stringify!($field), "`."
            )]
            pub fn on($field: $fty) -> Self {
                Self { enabled: true, $field }
            }

            #[doc = concat!("`", stringify!($name), "` disabled (the default).")]
            pub fn off() -> Self {
                Self::default()
            }
        }

        impl From<Option<$fty>> for $name {
            fn from(payload: Option<$fty>) -> Self {
                match payload {
                    Some($field) => Self::on($field),
                    None => Self::off(),
                }
            }
        }
    };
}

toggle_config! {
    /// Round-pipelining knob (see the module docs): overlap round
    /// `r + 1`'s Scheduling with round `r`'s Training. Off by default —
    /// pipelining is pure overlap (results are bit-for-bit identical
    /// either way), but the serial loop stays the reference the
    /// equivalence suite compares against.
    PipelineConfig {
        /// Run the speculative round driver.
        enabled
    }
}

toggle_config! {
    /// Incremental round re-derivation knob: keep a persistent
    /// device→class index ([`FleetIndex`]) alive across rounds and
    /// re-classify only the devices Recosting actually touched, instead
    /// of re-bucketing all `n` devices every Scheduling phase. Off by
    /// default — like `shards` and `pipeline` it is a pure wall-clock
    /// knob (journals, digests, and RNG streams are bit-for-bit
    /// identical on or off), but the from-scratch build stays the
    /// reference the equivalence suite compares against.
    IncrementalConfig {
        /// Maintain the persistent class index.
        enabled
    }
}

toggle_config! {
    /// Round-deadline knob: minimize energy subject to every
    /// participating device finishing its compute + upload within
    /// `seconds` (ε-constrained bi-objective scheduling, see
    /// [`crate::sched::pareto`]). Applied as a per-device upper-limit
    /// cap derived from its [`TimeModel`], so every registered solver
    /// honors it. Unlike `shards`/`pipeline`/`incremental` this knob
    /// *changes schedules* — it is part of campaign identity, persisted
    /// in snapshots and honored by `resume`/`replay`.
    ///
    /// [`TimeModel`]: crate::sched::pareto::TimeModel
    DeadlineConfig {
        /// Enforce the round deadline.
        enabled,
        /// Round deadline `D` in seconds (ignored when disabled).
        seconds: f64
    }
}

/// What the coordinator needs to know to drive rounds (the scheduling
/// subset of [`TrainConfig`], minus the ML-side knobs).
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Rounds to run in [`Coordinator::run`].
    pub rounds: usize,
    /// Mini-batches to distribute per round (`T`).
    pub tasks_per_round: usize,
    /// Solver name resolved through the [`SolverRegistry`].
    pub algo: String,
    /// Fraction of the fleet selected per round (FedAvg's `C`).
    pub participation: f64,
    /// Config-level minimum participation per selected device (combined
    /// with each device's intrinsic lower limit).
    pub min_tasks: usize,
    /// Over-representation guard: no device may receive more than this
    /// fraction of a round's tasks (paper §6). Relaxed automatically if
    /// the capped capacity cannot absorb `T`.
    pub max_share: f64,
    /// Seed for selection/dynamics randomness.
    pub seed: u64,
    /// Early-stop target on evaluation loss.
    pub target_loss: Option<f64>,
    /// Instance-build shards per round (`1` = direct builder path;
    /// `> 1` = partition → concurrent per-shard class dedup → exact
    /// merge via [`crate::sched::shard`]). The derived instance is
    /// bit-for-bit identical either way, so journals/digests never
    /// depend on this knob — it is a pure build-time speedup for
    /// 10⁵–10⁶-device fleets.
    pub shards: usize,
    /// Overlap round `r + 1`'s Scheduling with round `r`'s Training
    /// (speculate → validate → adopt; see the module docs). Like
    /// `shards`, a pure wall-clock knob: journals, digests, and RNG
    /// streams are bit-for-bit identical on or off.
    pub pipeline: PipelineConfig,
    /// Derive each round's instance from the persistent class index
    /// instead of re-bucketing all devices (see [`IncrementalConfig`]).
    /// When enabled it supersedes the sharded build for round
    /// derivation — there is no `O(n)` bucketing left to shard.
    pub incremental: IncrementalConfig,
    /// Per-round completion deadline (min energy s.t. makespan ≤ D).
    /// Unlike the wall-clock knobs above, this changes schedules and is
    /// persisted with the campaign.
    pub deadline: DeadlineConfig,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            rounds: 50,
            tasks_per_round: 64,
            algo: "auto".into(),
            participation: 1.0,
            min_tasks: 0,
            max_share: 0.25,
            seed: 7,
            target_loss: None,
            shards: 1,
            pipeline: PipelineConfig::off(),
            incremental: IncrementalConfig::off(),
            deadline: DeadlineConfig::off(),
        }
    }
}

impl CoordinatorConfig {
    /// Extract the coordination knobs from a full training config.
    pub fn from_train(cfg: &TrainConfig) -> Self {
        Self {
            rounds: cfg.rounds,
            tasks_per_round: cfg.tasks_per_round,
            algo: cfg.policy.to_string(),
            participation: cfg.participation,
            min_tasks: cfg.min_tasks,
            max_share: cfg.max_share,
            seed: cfg.seed,
            target_loss: cfg.target_loss,
            shards: 1,
            pipeline: PipelineConfig::off(),
            incremental: IncrementalConfig::off(),
            deadline: DeadlineConfig::off(),
        }
    }
}

/// Every post-construction coordinator knob in one struct, applied in
/// one place. The CLI, the FL [`crate::fl::Server`], and the networked
/// service layer ([`crate::svc`]) all configure rounds by building a
/// `KnobSet` and calling [`KnobSet::apply_to`] — there is exactly one
/// ordering of the underlying setters in the codebase, instead of three
/// hand-maintained mirrors of the `set_*` surface. `resume` rebuilds
/// its `KnobSet` from store meta through this same path.
///
/// Every field is optional ("leave the coordinator as constructed");
/// `sinks` appends. Application order is fixed and load-bearing:
/// structural knobs first (dynamics, shards, pipeline, incremental,
/// deadline — these may discard in-flight speculation or the class
/// index), then log retention, then sinks, and the tracer last (pure
/// output; a failure in an earlier knob must not leave a half-attached
/// trace).
#[derive(Default)]
pub struct KnobSet {
    /// Fleet dynamics (availability churn, cost drift, dropout).
    pub dynamics: Option<DynamicsConfig>,
    /// Instance-build shard count (validated: must be ≥ 1).
    pub shards: Option<usize>,
    /// Round pipelining.
    pub pipeline: Option<PipelineConfig>,
    /// Incremental round re-derivation.
    pub incremental: Option<IncrementalConfig>,
    /// Per-round completion deadline (validated: finite seconds > 0).
    pub deadline: Option<DeadlineConfig>,
    /// In-memory log/ledger retention bound (`Some(None)` = unbounded).
    pub log_bound: Option<Option<usize>>,
    /// Streaming per-round row sinks to attach.
    pub sinks: Vec<Box<dyn MetricSink>>,
    /// Trace consumer to attach.
    pub tracer: Option<Box<dyn Tracer>>,
}

impl KnobSet {
    /// An empty knob set (applies nothing).
    pub fn new() -> Self {
        Self::default()
    }

    /// Apply every present knob to `coordinator`, in the documented
    /// order. Validation failures (zero shards, non-finite deadline)
    /// surface before any sink or tracer is attached.
    pub fn apply_to<B: RoundBackend>(
        self,
        coordinator: &mut Coordinator<B>,
    ) -> Result<()> {
        if let Some(shards) = self.shards {
            coordinator.set_shards(shards)?;
        }
        if let Some(deadline) = self.deadline {
            coordinator.set_deadline(deadline)?;
        }
        if let Some(dynamics) = self.dynamics {
            coordinator.set_dynamics(dynamics);
        }
        if let Some(pipeline) = self.pipeline {
            coordinator.set_pipeline(pipeline.enabled);
        }
        if let Some(incremental) = self.incremental {
            coordinator.set_incremental(incremental.enabled);
        }
        if let Some(bound) = self.log_bound {
            coordinator.set_log_bound(bound);
        }
        for sink in self.sinks {
            coordinator.add_sink(sink);
        }
        if let Some(tracer) = self.tracer {
            coordinator.set_tracer(tracer);
        }
        Ok(())
    }
}

/// What the last round actually ran — journaled by the store and
/// verified entry-by-entry on restore/replay.
#[derive(Clone, Debug, Default)]
pub struct RoundTrace {
    /// Effective solver that produced the schedule (`""` for empty
    /// rounds, [`ABORTED_SOLVER`] for rounds that errored mid-flight).
    pub solver: String,
    /// [`round_digest`] of the derived fleet instance + schedule (0 when
    /// no schedule was produced).
    pub digest: u64,
}

/// Output of the **prepare** (Scheduling) half of a round: either an
/// empty round (nobody online / fleet exhausted) or a solved plan ready
/// for the commit half.
enum PreparedRound {
    /// No schedulable work; commit degrades to an empty round.
    Empty {
        /// Whether devices were online but all drained (metered
        /// separately from "nobody online").
        exhausted: bool,
    },
    /// A derived, solved, validated round.
    Planned(PlannedRound),
}

/// The Scheduling phase's products, carried into the commit half.
struct PlannedRound {
    /// Selected device indices, sorted (slot order).
    selected: Vec<usize>,
    /// Class-deduplicated instance (digest input).
    fleet: FleetInstance,
    /// Slot-expanded view (what the round plan and warm DP key on).
    instance: Instance,
    /// The validated schedule.
    schedule: Schedule,
    /// Effective solver name (what the journal records).
    effective: &'static str,
    /// Wall-clock solve time (metrics row only; excluded from digests).
    sched_time_s: f64,
    /// Scheduler-predicted round energy.
    predicted_j: f64,
    /// Effective workload after capacity clamping.
    t: usize,
}

/// A speculatively prepared round `r + 1`, computed while round `r`
/// trained. Adopted only when `guard` matches the actual post-commit
/// state — the digest covers everything the Scheduling phase reads, so a
/// match proves the serial loop would have produced these exact bits.
struct Speculation {
    /// The round this speculation was prepared for.
    round: usize,
    /// [`Coordinator::scheduling_guard`] over the *predicted* post-round
    /// state the speculation solved against.
    guard: u64,
    /// RNG state after the speculative Scheduling phase (selection +
    /// seeded-solver draws) — adopted so the live stream continues
    /// exactly where the serial loop's would.
    rng_after: [u64; 4],
    /// The warm-DP cache after the speculative solve (a clone of the live
    /// cache, mutated only if the DP ran). Adopted wholesale: when the DP
    /// did not run it is byte-identical to the live cache.
    warm: WarmMc2mkp,
    /// Metric increments the serial Scheduling phase would have made,
    /// applied on adoption so counters match a serial run's.
    incs: Vec<(&'static str, u64)>,
    /// The prepared round itself.
    prepared: PlannedRound,
}

/// The multi-round FL coordinator (see module docs).
pub struct Coordinator<B: RoundBackend> {
    cfg: CoordinatorConfig,
    devices: Vec<ManagedDevice>,
    dynamics: DynamicsConfig,
    registry: SolverRegistry,
    warm: WarmMc2mkp,
    rng: Rng,
    phase: Phase,
    /// Online device indices entering the next Scheduling phase.
    pool: Vec<usize>,
    next_round: usize,
    backend: B,
    ledger: EnergyLedger,
    metrics: MetricsHub,
    log: TrainingLog,
    /// Loss of the most recent completed round (NaN before the first).
    /// Kept as its own field — not read back from `log` — so aborted-round
    /// rows are identical whether or not the log was reset by a restore.
    last_loss: f64,
    /// Streaming per-round row consumers (JSONL/CSV/custom).
    sinks: Vec<Box<dyn MetricSink>>,
    /// Durable campaign store, when attached (journal + snapshots).
    store: Option<CampaignStore>,
    /// Set when a store commit failed: the journal no longer matches the
    /// rounds driven, so further rounds must refuse to run rather than
    /// silently diverge from the store.
    store_failed: Option<String>,
    /// Trace of the last round (kept for journaling and replay checks).
    trace: Option<RoundTrace>,
    /// Compute traces even without a store (restore/replay verification).
    record_trace: bool,
    /// In-flight speculative next round (pipelining only). Never
    /// journaled, never snapshotted: a restored coordinator simply
    /// prepares its first round serially.
    speculation: Option<Speculation>,
    /// Persistent device→class index (incremental re-derivation only).
    /// Like the warm-DP cache it is pure derived state: never journaled,
    /// never snapshotted — rebuilt lazily (`incr_index_rebuilds`) on the
    /// first incremental prepare after construction or restore.
    index: Option<FleetIndex>,
    /// Trace consumer (default: the zero-cost [`NoopTracer`]). Pure
    /// output — no tracer method returns data into scheduling state, so
    /// traced and untraced campaigns are bit-identical.
    tracer: Box<dyn Tracer>,
    /// Latency histograms (phase durations, per-solver solve time,
    /// incremental dirty-set sizes). Always recorded (a record is a
    /// shift + two adds); exported as `obs_*` gauges only on traced
    /// campaigns so untraced metrics summaries stay bit-stable.
    hists: ObsHists,
}

impl<B: RoundBackend> Coordinator<B> {
    /// Configure a coordinator over a managed fleet. Fails (still in
    /// `Configuring`) if the solver name is unknown or the fleet is empty.
    pub fn new(
        cfg: CoordinatorConfig,
        mut devices: Vec<ManagedDevice>,
        backend: B,
    ) -> Result<Self> {
        if devices.is_empty() {
            return Err(FedError::Coordinator("empty fleet".into()));
        }
        if cfg.tasks_per_round == 0 {
            return Err(FedError::Coordinator("tasks_per_round must be > 0".into()));
        }
        if !(0.0..=1.0).contains(&cfg.participation) || cfg.participation == 0.0 {
            return Err(FedError::Coordinator("participation must be in (0, 1]".into()));
        }
        if !(0.0..=1.0).contains(&cfg.max_share) || cfg.max_share == 0.0 {
            return Err(FedError::Coordinator("max_share must be in (0, 1]".into()));
        }
        if cfg.shards == 0 {
            return Err(FedError::Coordinator("shards must be >= 1".into()));
        }
        if cfg.deadline.enabled
            && !(cfg.deadline.seconds.is_finite() && cfg.deadline.seconds > 0.0)
        {
            return Err(FedError::Coordinator(format!(
                "deadline must be a finite number of seconds > 0, got {}",
                cfg.deadline.seconds
            )));
        }
        // Deadline caps are derived state (config × device time model),
        // applied here so restore — which decodes devices then re-enters
        // this constructor with the decoded config — re-derives them
        // identically.
        if cfg.deadline.enabled {
            for d in &mut devices {
                d.apply_deadline(cfg.deadline.seconds);
            }
        }
        let registry = SolverRegistry::with_defaults(cfg.seed);
        registry.resolve(&cfg.algo)?;
        let rng = Rng::new(cfg.seed);
        let pool = (0..devices.len()).collect();
        Ok(Self {
            cfg,
            devices,
            dynamics: DynamicsConfig::none(),
            registry,
            warm: WarmMc2mkp::new(),
            rng,
            phase: Phase::Configuring,
            pool,
            next_round: 0,
            backend,
            ledger: EnergyLedger::new(),
            metrics: MetricsHub::new(),
            log: TrainingLog::new(),
            last_loss: f64::NAN,
            sinks: Vec::new(),
            store: None,
            store_failed: None,
            trace: None,
            record_trace: false,
            speculation: None,
            index: None,
            tracer: Box::new(NoopTracer),
            hists: ObsHists::default(),
        })
    }

    /// Install dynamic fleet behaviour (availability churn, cost drift,
    /// mid-round dropout).
    pub fn set_dynamics(&mut self, dynamics: DynamicsConfig) {
        self.dynamics = dynamics;
    }

    /// Set the per-round instance-build shard count (see
    /// [`CoordinatorConfig::shards`]). Safe to change between rounds:
    /// the derived instance is bit-for-bit identical for every count.
    /// Any in-flight speculation is discarded — it was built with the old
    /// count, and while its schedule would still be bit-identical, its
    /// deferred `fleet_shards`/`shard_merge_ns` increments would not
    /// match what a serial round under the new count records.
    pub fn set_shards(&mut self, shards: usize) -> Result<()> {
        if shards == 0 {
            return Err(FedError::Coordinator("shards must be >= 1".into()));
        }
        self.cfg.shards = shards;
        self.speculation = None;
        Ok(())
    }

    /// Enable/disable round pipelining (see [`PipelineConfig`]). Safe to
    /// flip between rounds: results are bit-for-bit identical either way
    /// (disabling discards any in-flight speculation).
    pub fn set_pipeline(&mut self, enabled: bool) {
        self.cfg.pipeline.enabled = enabled;
        if !enabled {
            self.speculation = None;
        }
    }

    /// Enable/disable incremental round re-derivation (see
    /// [`IncrementalConfig`]). Safe to flip between rounds: the derived
    /// instances are bit-for-bit identical either way. Flipping discards
    /// the index (enabling rebuilds it lazily at the next serial
    /// prepare) and any in-flight speculation — a speculation made under
    /// the other mode carries the wrong deferred metric increments and,
    /// when enabling, no index fingerprint to validate against.
    pub fn set_incremental(&mut self, enabled: bool) {
        if self.cfg.incremental.enabled == enabled {
            return;
        }
        self.cfg.incremental.enabled = enabled;
        self.speculation = None;
        self.index = None;
    }

    /// Change the round deadline (see [`DeadlineConfig`]). Unlike the
    /// wall-clock knobs this changes schedules: deadline caps shift every
    /// powered device's effective upper limit, so in-flight speculation
    /// and the persistent class index are both discarded.
    pub fn set_deadline(&mut self, deadline: DeadlineConfig) -> Result<()> {
        if deadline.enabled && !(deadline.seconds.is_finite() && deadline.seconds > 0.0) {
            return Err(FedError::Coordinator(format!(
                "deadline must be a finite number of seconds > 0, got {}",
                deadline.seconds
            )));
        }
        self.cfg.deadline = deadline;
        for d in &mut self.devices {
            if deadline.enabled {
                d.apply_deadline(deadline.seconds);
            } else {
                d.clear_deadline();
            }
        }
        self.speculation = None;
        self.index = None;
        Ok(())
    }

    /// Attach a trace consumer (e.g. [`crate::obs::ChromeTraceSink`]).
    /// Tracing is pure output: journals, digests, RNG streams, and
    /// schedules are bit-for-bit identical with any tracer attached —
    /// `tests/obs_trace.rs` proves it differentially.
    pub fn set_tracer(&mut self, tracer: Box<dyn Tracer>) {
        self.tracer = tracer;
    }

    /// Flush the attached tracer, surfacing any deferred write error.
    pub fn flush_trace(&mut self) -> Result<()> {
        self.tracer.flush()
    }

    /// The latency histograms accumulated so far.
    pub fn hists(&self) -> &ObsHists {
        &self.hists
    }

    /// Current phase.
    pub fn phase(&self) -> Phase {
        self.phase
    }

    /// The solver registry (e.g. to register custom solvers before
    /// running). Discards any in-flight speculation: it was solved
    /// through the registry as it was, and the scheduling guard does not
    /// (and need not) cover registry contents — adopting it after an
    /// override could silently bypass the caller's new solver.
    pub fn registry_mut(&mut self) -> &mut SolverRegistry {
        self.speculation = None;
        &mut self.registry
    }

    /// Managed devices (current, re-costed state).
    pub fn devices(&self) -> &[ManagedDevice] {
        &self.devices
    }

    /// The training backend.
    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// Mutable training backend.
    pub fn backend_mut(&mut self) -> &mut B {
        &mut self.backend
    }

    /// Per-device / per-round energy ledger.
    pub fn ledger(&self) -> &EnergyLedger {
        &self.ledger
    }

    /// Counters and gauges.
    pub fn metrics(&self) -> &MetricsHub {
        &self.metrics
    }

    /// Per-round training log.
    pub fn log(&self) -> &TrainingLog {
        &self.log
    }

    /// The coordinator configuration.
    pub fn cfg(&self) -> &CoordinatorConfig {
        &self.cfg
    }

    /// Rounds driven so far (== the next round index).
    pub fn rounds_run(&self) -> usize {
        self.next_round
    }

    /// Trace of the most recent round (solver + digest), when tracing is
    /// on (a store is attached, or the coordinator was restored).
    pub fn last_trace(&self) -> Option<&RoundTrace> {
        self.trace.as_ref()
    }

    /// The attached campaign store, if any.
    pub fn campaign_store(&self) -> Option<&CampaignStore> {
        self.store.as_ref()
    }

    /// Stream every committed round's row into `sink` (in addition to the
    /// in-memory log and any attached store).
    pub fn add_sink(&mut self, sink: Box<dyn MetricSink>) {
        self.sinks.push(sink);
    }

    /// Bound in-memory per-round retention (log rows and ledger series) to
    /// (at least) the most recent `bound` entries — constant memory over
    /// arbitrarily long campaigns when rows stream to a sink/store.
    /// Totals and counters stay exact. `None` restores unbounded growth.
    pub fn set_log_bound(&mut self, bound: Option<usize>) {
        self.log.set_bound(bound);
        self.ledger.set_round_bound(bound);
    }

    /// Flush all attached sinks.
    pub fn flush_sinks(&mut self) -> Result<()> {
        for sink in &mut self.sinks {
            sink.flush()?;
        }
        Ok(())
    }

    fn transition(&mut self, next: Phase) -> Result<()> {
        if !self.phase.can_transition_to(next) {
            return Err(FedError::Coordinator(format!(
                "illegal transition {:?} → {next:?}",
                self.phase
            )));
        }
        self.phase = next;
        Ok(())
    }

    /// Build one round's **fleet instance** over `selected` device
    /// indices (with their already-computed `raw_uppers`, which the caller
    /// derived from current device state and checked to be non-empty in
    /// total). Devices sharing a cost signature and limits collapse into
    /// classes — on real fleets `k ≪ n`, which is what the class-aware
    /// solvers exploit.
    ///
    /// The scheduling subset of the config the round limit transform
    /// reads (shared between the from-scratch and incremental paths).
    fn round_params(cfg: &CoordinatorConfig) -> RoundParams {
        RoundParams {
            tasks: cfg.tasks_per_round,
            min_tasks: cfg.min_tasks,
            max_share: cfg.max_share,
        }
    }

    /// State-parametric (no `&self`): the serial path passes the live
    /// fleet, the pipelined path a *predicted* clone — identical code, so
    /// an adopted speculation cannot diverge from the serial build.
    /// Metric increments go through `incs` (the speculative path defers
    /// them until adoption).
    fn build_instance_for(
        cfg: &CoordinatorConfig,
        devices: &[ManagedDevice],
        selected: &[usize],
        raw_uppers: &[usize],
        incs: &mut Vec<(&'static str, u64)>,
        tracer: &mut dyn Tracer,
    ) -> Result<(FleetInstance, usize)> {
        // The round's limit transform (capacity clamp, §6 share cap,
        // staged lower relaxation) lives in ONE place —
        // `incremental::effective_limits` — shared with the persistent
        // index's per-class derivation, so the two build paths cannot
        // drift apart.
        let raw_lowers: Vec<usize> =
            selected.iter().map(|&d| devices[d].lower).collect();
        let mut relaxed = false;
        let (t, lower, uppers) = incremental::effective_limits(
            &Self::round_params(cfg),
            &raw_lowers,
            raw_uppers,
            &mut relaxed,
        );
        if relaxed {
            incs.push(("lower_limits_relaxed", 1));
        }
        let fleet = if cfg.shards > 1 {
            // Sharded build: materialize the flat device sequence once,
            // fan the per-shard class dedup out over scoped threads, and
            // merge exactly. `fleet_shards` / `shard_merge_ns` expose the
            // fan-out; the merge timing never enters any digest.
            let costs: Vec<CostFn> = selected
                .iter()
                .map(|&d| devices[d].current_cost())
                .collect();
            let inst = Instance { tasks: t, lower, upper: uppers, costs };
            let (fleet, stats) = if tracer.enabled() {
                // Traced build: each shard worker reports its dedup
                // window as offsets on a clock anchored at `base`, then
                // renders on lanes 1..=shards beside the coordinator's
                // lane 0. Telemetry only — the fleet is bit-identical.
                let base = tracer.now_ns();
                let mut spans: Vec<(u64, u64)> = Vec::new();
                let out = pool::build_fleet_sharded_traced(
                    &inst,
                    cfg.shards,
                    0,
                    Some(&mut spans),
                )?;
                for (i, &(s, e)) in spans.iter().enumerate() {
                    tracer.span_at(
                        "shard",
                        1 + i as u32,
                        base.saturating_add(s),
                        base.saturating_add(e),
                        &|| vec![("shard", i.to_string())],
                    );
                }
                out
            } else {
                pool::build_fleet_sharded(&inst, cfg.shards, 0)?
            };
            incs.push(("fleet_shards", stats.shards as u64));
            incs.push(("shard_merge_ns", stats.merge_ns));
            fleet
        } else {
            let mut b = FleetInstance::builder().tasks(t);
            for ((&d, &u), &l) in selected.iter().zip(&uppers).zip(&lower) {
                b = b.device(devices[d].current_cost(), l, u);
            }
            b.build()?
        };
        Ok((fleet, t))
    }

    /// Solve a fleet instance with `algo`, warm-starting the (MC)²MKP DP
    /// whenever the DP is what runs (configured directly or chosen by
    /// `auto` dispatch). `flat` is the slot-expanded view of `fleet` (the
    /// caller needs it for the round plan anyway); the warm DP row cache
    /// keys on it. Returns the schedule together with the *effective*
    /// solver name (what the store journals).
    ///
    /// State-parametric like [`Coordinator::build_instance_for`]: the
    /// serial path passes the live `warm`/`rng`, the speculative path
    /// clones — same code either way.
    fn solve_with(
        registry: &SolverRegistry,
        warm: &mut WarmMc2mkp,
        rng: &mut Rng,
        algo: &str,
        fleet: &FleetInstance,
        flat: &Instance,
        incs: &mut Vec<(&'static str, u64)>,
    ) -> Result<(Schedule, &'static str)> {
        let canonical = registry.resolve(algo)?.name();
        // Resolve `auto` to its concrete Table 2 pick here, once: the
        // classification is per *class* (cheap on deduplicated fleets),
        // and registry overrides of the concrete solvers are honored by
        // the dispatch.
        let effective = if canonical == "auto" && !registry.is_overridden("auto") {
            best_algorithm(&classify_fleet(fleet))
        } else {
            canonical
        };
        // The warm fast path only stands in for the *built-in* DP; a
        // caller-registered "mc2mkp" must win over it.
        if effective == "mc2mkp" && !registry.is_overridden("mc2mkp") {
            let (schedule, info) = warm.solve(flat)?;
            incs.push(("dp_solves", 1));
            incs.push(("dp_rows_reused", info.reused_rows as u64));
            incs.push(("dp_rows_total", info.total_rows as u64));
            Ok((schedule, "mc2mkp"))
        } else {
            let schedule =
                registry.solve_fleet_seeded(effective, fleet, rng)?.expand(fleet);
            Ok((schedule, effective))
        }
    }

    /// Drive one full round through the state machine; returns the logged
    /// row. On an error mid-round the machine is returned to the ready
    /// (`Scheduling`) state, so a caller that handles the error can keep
    /// driving rounds.
    pub fn round(&mut self) -> Result<RoundLog> {
        match self.phase {
            Phase::Configuring => self.transition(Phase::Scheduling)?,
            Phase::Scheduling => {}
            other => {
                return Err(FedError::Coordinator(format!(
                    "round() may not start from {other:?}"
                )))
            }
        }
        if let Some(why) = &self.store_failed {
            // A previous commit failed: the journal is behind the rounds
            // driven. Running more rounds would burn energy and advance
            // RNG state that can never be recovered — fail fast instead.
            return Err(FedError::Store(format!(
                "campaign store failed earlier ({why}); refusing to run \
                 further un-journaled rounds"
            )));
        }
        let round_idx = self.next_round;
        self.next_round += 1;
        self.trace = None;
        let round_t0 = self.tracer.now_ns();
        let outcome = self.round_inner(round_idx);
        if self.tracer.enabled() {
            let round_t1 = self.tracer.now_ns();
            let ok = outcome.is_ok();
            self.tracer.span_at("round", COORD_LANE, round_t0, round_t1, &|| {
                vec![("round", round_idx.to_string()), ("ok", ok.to_string())]
            });
        }
        match outcome {
            Ok(row) => {
                self.record_round(&row)?;
                Ok(row)
            }
            Err(e) => {
                self.phase = Phase::Scheduling;
                // The aborted round still consumed its index, and dropout
                // victims may already have burned real energy into an open
                // ledger bucket. Log an explicit aborted row (opening an
                // empty bucket if none was: every completed round opens
                // exactly one bucket, so `rounds_opened <= round_idx`
                // means this round's bucket is missing — a comparison
                // that stays correct after a restore resets the log) so
                // `Σ log energy == ledger total` and one-row-per-round
                // hold for callers that handle the error and keep driving
                // rounds.
                if self.ledger.rounds_opened() <= round_idx {
                    self.ledger.begin_round();
                }
                let energy_j = self.ledger.rounds().last().copied().unwrap_or(0.0);
                let loss = self.last_loss;
                let row = RoundLog {
                    round: round_idx,
                    policy: self.cfg.algo.clone(),
                    loss,
                    energy_j,
                    sched_time_s: 0.0,
                    train_time_s: 0.0,
                    participants: 0,
                    tasks: 0,
                };
                self.log.push(row.clone());
                self.metrics.inc("aborted_rounds", 1);
                self.trace = Some(RoundTrace {
                    solver: ABORTED_SOLVER.into(),
                    digest: 0,
                });
                // Journal the aborted row too (one journal line per round
                // index). A secondary store error must not shadow the
                // round's own failure — record_round already poisons the
                // coordinator on a failed store commit, so the divergence
                // still fails fast on the next round.
                let _ = self.record_round(&row);
                Err(e)
            }
        }
    }

    /// Persist one committed row: journal-first into the attached store,
    /// then into every streaming sink. A failed *store* commit poisons
    /// the coordinator (the journal is now behind the rounds driven — an
    /// unrecoverable divergence); a failed sink merely surfaces its error
    /// (the stream loses a row, the campaign itself is intact).
    fn record_round(&mut self, row: &RoundLog) -> Result<()> {
        if self.store.is_some() {
            let trace = self.trace.clone().unwrap_or_default();
            let entry = JournalEntry {
                round: row.round,
                solver: trace.solver,
                digest: trace.digest,
                rng_after: self.rng.state(),
                row: row.clone(),
            };
            // The span covers the append *and* its fsync (the store
            // syncs before `commit` returns).
            let t0 = self.tracer.now_ns();
            let commit = match self.store.as_mut() {
                Some(store) => store.commit(&entry),
                None => Ok(()),
            };
            if self.tracer.enabled() {
                let t1 = self.tracer.now_ns();
                let round = row.round;
                let ok = commit.is_ok();
                self.tracer.span_at("journal_append", COORD_LANE, t0, t1, &|| {
                    vec![("round", round.to_string()), ("ok", ok.to_string())]
                });
            }
            if let Err(se) = commit {
                self.store_failed = Some(se.to_string());
                return Err(se);
            }
        }
        for sink in &mut self.sinks {
            sink.record(row)?;
        }
        Ok(())
    }

    /// True when round traces (instance/schedule digests) are computed.
    fn tracing(&self) -> bool {
        self.record_trace || self.store.is_some()
    }

    fn round_inner(&mut self, round_idx: usize) -> Result<RoundLog> {
        let prepared = if self.cfg.pipeline.enabled {
            match self.take_speculation(round_idx) {
                Some(p) => PreparedRound::Planned(p),
                None => self.prepare_round()?,
            }
        } else {
            // Pipelining may have been switched off between rounds: a
            // stale speculation must never outlive the mode that made it.
            self.speculation = None;
            self.prepare_round()?
        };
        self.commit_round(round_idx, prepared)
    }

    /// The **prepare** half: the Scheduling phase against the live state.
    /// Pure of backend and ledger effects — those belong to commit. A
    /// thin wrapper over [`Coordinator::schedule_for`], which is the ONE
    /// code body both this serial path and the speculative path run.
    fn prepare_round(&mut self) -> Result<PreparedRound> {
        if self.cfg.incremental.enabled && self.index.is_none() {
            // Lazy full classification — the one O(n) pass (first round,
            // or first after restore / toggling the knob). Every later
            // round pays only for its dirty set.
            let devices = &self.devices;
            self.index = Some(FleetIndex::build(devices.len(), |d| {
                devices[d].class_signature()
            }));
            self.metrics.inc("incr_index_rebuilds", 1);
        }
        let mut incs = Vec::new();
        let timer = Timer::start();
        let t0 = self.tracer.now_ns();
        let out = Self::schedule_for(
            &self.cfg,
            &self.registry,
            &mut self.warm,
            &mut self.rng,
            &self.pool,
            &self.devices,
            self.index.as_mut(),
            &mut incs,
            &mut *self.tracer,
        );
        if self.tracer.enabled() {
            let t1 = self.tracer.now_ns();
            self.tracer.span_at("scheduling", COORD_LANE, t0, t1, &Vec::new);
        }
        self.hists.sched_ns.record(secs_to_ns(timer.elapsed_s()));
        self.apply_incs(incs);
        out
    }

    /// Apply deferred Scheduling-phase metric increments (serial prepare
    /// or adopted speculation — same sink either way), siphoning the
    /// dirty-set sizes into their histogram on the way through.
    fn apply_incs(&mut self, incs: Vec<(&'static str, u64)>) {
        for (key, v) in incs {
            if key == "incr_dirty" {
                self.hists.incr_dirty.record(v);
            }
            self.metrics.inc(key, v);
        }
    }

    /// One Scheduling pass over an explicit state — selection draw,
    /// instance derivation, solve, validation. State-parametric on
    /// purpose: the serial prepare passes the live pool/devices/RNG/warm
    /// cache, the speculative prepare passes predicted clones, and both
    /// run THIS body. The guard digest proves equal inputs; sharing the
    /// body is what proves equal code, so the two paths cannot drift.
    /// Metric increments go through `incs` (the speculative path defers
    /// them until adoption). With incremental re-derivation on, `index`
    /// carries the persistent class index (live or a speculative clone):
    /// the pending dirty set is applied and the instance derived per
    /// class — bit-for-bit what the from-scratch branch builds.
    #[allow(clippy::too_many_arguments)]
    fn schedule_for(
        cfg: &CoordinatorConfig,
        registry: &SolverRegistry,
        warm: &mut WarmMc2mkp,
        rng: &mut Rng,
        pool: &[usize],
        devices: &[ManagedDevice],
        index: Option<&mut FleetIndex>,
        incs: &mut Vec<(&'static str, u64)>,
        tracer: &mut dyn Tracer,
    ) -> Result<PreparedRound> {
        if pool.is_empty() {
            // Nobody online: an empty round (no energy, model unchanged).
            return Ok(PreparedRound::Empty { exhausted: false });
        }
        let n_online = pool.len();
        let k = ((devices.len() as f64 * cfg.participation).ceil() as usize)
            .clamp(1, n_online);
        let picks = rng.sample_indices(n_online, k);
        let mut selected: Vec<usize> = picks.iter().map(|&i| pool[i]).collect();
        // Stable slot order: keeps slot→device mapping canonical and
        // maximizes the unchanged class prefix the warm DP can reuse.
        selected.sort_unstable();

        let (fleet, t) = match index {
            Some(ix) => {
                // Incremental path: drain the dirty set, then derive the
                // instance from raw classes — O(selected + dirty) instead
                // of O(n) heavy work. Supersedes the sharded build (there
                // is no O(n) bucketing left to fan out, so no
                // `fleet_shards` increments on this path).
                let t0 = tracer.now_ns();
                incs.push(("incr_dirty", ix.pending_len() as u64));
                let moved = ix.apply(|d| devices[d].class_signature());
                incs.push(("incr_reclassified", moved as u64));
                let mut relaxed = false;
                let built =
                    ix.derive(&selected, &Self::round_params(cfg), &mut relaxed)?;
                if relaxed {
                    incs.push(("lower_limits_relaxed", 1));
                }
                if tracer.enabled() {
                    let t1 = tracer.now_ns();
                    tracer.span_at("build_instance", COORD_LANE, t0, t1, &|| {
                        vec![
                            ("mode", "incremental".to_string()),
                            ("dirty", moved.to_string()),
                        ]
                    });
                }
                match built {
                    // Exhausted fleet (every selected battery drained to
                    // zero): degrade to an empty round.
                    None => return Ok(PreparedRound::Empty { exhausted: true }),
                    Some(bt) => bt,
                }
            }
            None => {
                // Exhausted fleet: degrade to an empty round instead of
                // aborting the run.
                let raw_uppers: Vec<usize> = selected
                    .iter()
                    .map(|&d| devices[d].effective_upper())
                    .collect();
                if raw_uppers.iter().all(|&u| u == 0) {
                    return Ok(PreparedRound::Empty { exhausted: true });
                }
                let t0 = tracer.now_ns();
                let built = Self::build_instance_for(
                    cfg,
                    devices,
                    &selected,
                    &raw_uppers,
                    incs,
                    tracer,
                );
                if tracer.enabled() {
                    let t1 = tracer.now_ns();
                    let n = selected.len();
                    tracer.span_at("build_instance", COORD_LANE, t0, t1, &|| {
                        vec![
                            ("mode", "scratch".to_string()),
                            ("devices", n.to_string()),
                        ]
                    });
                }
                built?
            }
        };
        incs.push(("fleet_devices", fleet.n_devices() as u64));
        incs.push(("fleet_classes", fleet.n_classes() as u64));
        let instance = fleet.to_flat();
        let timer = Timer::start();
        let t0 = tracer.now_ns();
        let solved = Self::solve_with(
            registry,
            warm,
            rng,
            &cfg.algo,
            &fleet,
            &instance,
            incs,
        );
        let t1 = tracer.now_ns();
        let sched_time_s = timer.elapsed_s();
        let (schedule, effective) = solved?;
        if tracer.enabled() {
            let classes = fleet.n_classes();
            tracer.span_at("solve", COORD_LANE, t0, t1, &|| {
                vec![
                    ("solver", effective.to_string()),
                    ("classes", classes.to_string()),
                    ("t", t.to_string()),
                ]
            });
        }
        validate::check(&instance, &schedule)?;
        let predicted_j = validate::total_cost(&instance, &schedule);
        Ok(PreparedRound::Planned(PlannedRound {
            selected,
            fleet,
            instance,
            schedule,
            effective,
            sched_time_s,
            predicted_j,
            t,
        }))
    }

    /// The **commit** half: Training → Aggregating → Recosting over a
    /// prepared round. With pipelining on, the speculative prepare of
    /// round `round_idx + 1` runs between the backend's `begin_train` and
    /// `finish_train` — the overlap window.
    fn commit_round(
        &mut self,
        round_idx: usize,
        prepared: PreparedRound,
    ) -> Result<RoundLog> {
        let p = match prepared {
            PreparedRound::Empty { exhausted } => {
                self.ledger.begin_round();
                self.tracer.instant("empty_round", &|| {
                    vec![(
                        "cause",
                        if exhausted { "exhausted" } else { "nobody_online" }
                            .to_string(),
                    )]
                });
                let loss = self.backend.evaluate()?;
                self.metrics.inc("empty_rounds", 1);
                if exhausted {
                    self.metrics.inc("exhausted_rounds", 1);
                }
                return self.finish_round(round_idx, loss, 0.0, 0.0, 0.0, 0, 0);
            }
            PreparedRound::Planned(p) => p,
        };
        if self.tracing() {
            self.trace = Some(RoundTrace {
                solver: p.effective.to_string(),
                digest: round_digest(&p.fleet, &p.schedule),
            });
        }
        self.hists.record_solve(p.effective, secs_to_ns(p.sched_time_s));

        // ---- Training --------------------------------------------------
        self.transition(Phase::Training)?;
        self.ledger.begin_round();
        let wall = Timer::start();
        let train_t0 = self.tracer.now_ns();
        let mut assignments = Vec::new();
        for (slot, &d) in p.selected.iter().enumerate() {
            let tasks = p.schedule.get(slot);
            if tasks == 0 {
                continue;
            }
            // Mid-round dropout: the device burns energy for the fraction
            // of work it completed, but its update is lost (§6 "loss of a
            // device").
            let failed_at = self
                .dynamics
                .dropout
                .as_ref()
                .and_then(|dr| dr.sample(&mut self.rng));
            if let Some(frac) = failed_at {
                let done = ((tasks as f64) * frac).floor() as usize;
                let wasted = self.devices[d].partial_energy_j(done);
                self.ledger.record(self.devices[d].id, wasted);
                self.devices[d].drain(wasted);
                // A drain can move a battery device's effective upper —
                // dirty-mark it for the class index (mains devices
                // no-op the drain, so their signature cannot change).
                if self.devices[d].battery.is_some() {
                    if let Some(ix) = self.index.as_mut() {
                        ix.mark(d);
                    }
                }
                self.metrics.inc("dropouts", 1);
                continue;
            }
            assignments.push(Assignment {
                slot,
                device: d,
                device_id: self.devices[d].id,
                tasks,
                energy_scale: self.devices[d].drift,
            });
        }
        let plan = RoundPlan {
            round: round_idx,
            instance: p.instance,
            schedule: p.schedule,
            assignments,
        };
        let overlap = self.backend.begin_train(&plan)?;
        if overlap && self.cfg.pipeline.enabled && round_idx + 1 < self.cfg.rounds {
            // The overlap window: the backend is training in the
            // background; prepare round_idx + 1 against the predicted
            // post-round state on this thread. Backends that train
            // synchronously in finish_train report no window, and the
            // speculation is skipped — it would be pure added latency.
            self.speculate(round_idx + 1, &plan);
        }
        let outcomes = self.backend.finish_train(&plan)?;
        let mut sim_time_s = 0.0f64;
        let mut loss_sum = 0.0;
        let mut loss_n = 0usize;
        for o in &outcomes {
            self.ledger.record(o.device_id, o.energy_j);
            self.devices[o.device].drain(o.energy_j);
            // Same dirty-marking rule as the dropout drains above.
            if self.devices[o.device].battery.is_some() {
                if let Some(ix) = self.index.as_mut() {
                    ix.mark(o.device);
                }
            }
            sim_time_s = sim_time_s.max(o.sim_time_s); // devices run in parallel
            loss_sum += o.mean_loss * o.tasks as f64;
            loss_n += o.tasks;
        }
        let train_time_s = wall.elapsed_s();
        self.hists.train_ns.record(secs_to_ns(train_time_s));
        if self.tracer.enabled() {
            let train_t1 = self.tracer.now_ns();
            let n = outcomes.len();
            self.tracer.span_at("training", COORD_LANE, train_t0, train_t1, &|| {
                vec![("outcomes", n.to_string())]
            });
        }
        self.metrics.set("sim_round_time_s", sim_time_s);
        self.metrics.set(
            "train_loss",
            if loss_n > 0 { loss_sum / loss_n as f64 } else { 0.0 },
        );

        // ---- Aggregating -----------------------------------------------
        self.transition(Phase::Aggregating)?;
        let agg_timer = Timer::start();
        let agg_t0 = self.tracer.now_ns();
        self.backend.aggregate()?;
        let eval_loss = self.backend.evaluate()?;
        self.hists.aggregate_ns.record(secs_to_ns(agg_timer.elapsed_s()));
        if self.tracer.enabled() {
            let agg_t1 = self.tracer.now_ns();
            self.tracer.span_at("aggregate", COORD_LANE, agg_t0, agg_t1, &Vec::new);
        }

        self.finish_round(
            round_idx,
            eval_loss,
            p.sched_time_s,
            train_time_s,
            p.predicted_j,
            outcomes.len(),
            p.t,
        )
    }

    /// Digest of **everything the Scheduling phase reads**: the RNG
    /// state, the fleet size, the online pool, and each pooled device's
    /// scheduling-relevant state (lower limit, battery-capped upper,
    /// drift-scaled cost signature). Scheduling is a pure function of
    /// these inputs (the registry and config are fixed within a run), so
    /// equal guards prove a speculation solved the exact problem the
    /// serial loop would — the adoption criterion.
    fn scheduling_guard(rng: &Rng, pool: &[usize], devices: &[ManagedDevice]) -> u64 {
        let mut h = FNV_OFFSET;
        for w in rng.state() {
            h = mix_u64(h, w);
        }
        h = mix_u64(h, devices.len() as u64);
        h = mix_u64(h, pool.len() as u64);
        for &i in pool {
            let d = &devices[i];
            h = mix_u64(h, i as u64);
            h = mix_u64(h, d.lower as u64);
            h = mix_u64(h, d.effective_upper() as u64);
            h = mix_u64(h, d.current_cost().structural_hash());
        }
        h
    }

    /// Validate-and-adopt an in-flight speculation for `round_idx`. On a
    /// guard match the speculative Scheduling IS the serial Scheduling
    /// (same inputs through the same code), so its RNG state, warm-DP
    /// cache, and metric increments are installed and the prepared round
    /// returned. Any mismatch discards it — correctness never depends on
    /// a speculation being adopted.
    fn take_speculation(&mut self, round_idx: usize) -> Option<PlannedRound> {
        let spec = self.speculation.take()?;
        let mut guard =
            Self::scheduling_guard(&self.rng, &self.pool, &self.devices);
        if self.cfg.incremental.enabled {
            match &self.index {
                // The incremental guard additionally covers the index
                // state (classification + un-applied dirty set): equal
                // fingerprints prove the speculative clone's apply +
                // derive was a pure-function replay of what the serial
                // prepare will now skip.
                Some(ix) => guard = mix_u64(guard, ix.fingerprint()),
                // No live index (knob just toggled on): the serial
                // prepare must build it — force a miss.
                None => {
                    self.tracer.instant("speculation_miss", &|| {
                        vec![("cause", "index_missing".to_string())]
                    });
                    self.metrics.inc("pipeline_misses", 1);
                    return None;
                }
            }
        }
        if spec.round != round_idx || spec.guard != guard {
            let cause = if spec.round != round_idx {
                "stale_round"
            } else {
                "guard_mismatch"
            };
            self.tracer.instant("speculation_miss", &|| {
                vec![("cause", cause.to_string())]
            });
            self.metrics.inc("pipeline_misses", 1);
            return None;
        }
        self.tracer.instant("speculation_adopt", &|| {
            vec![("round", round_idx.to_string())]
        });
        self.metrics.inc("pipeline_hits", 1);
        self.rng = Rng::from_state(spec.rng_after);
        self.warm = spec.warm;
        self.apply_incs(spec.incs);
        Some(spec.prepared)
    }

    /// Speculatively prepare round `round` while the backend trains.
    /// Failures are swallowed (metered as `pipeline_spec_errors`): a
    /// condition that genuinely fails Scheduling will resurface — and be
    /// handled — when the round prepares serially.
    fn speculate(&mut self, round: usize, plan: &RoundPlan) {
        let timer = Timer::start();
        let t0 = self.tracer.now_ns();
        let spec = self.speculate_inner(round, plan);
        if self.tracer.enabled() {
            let t1 = self.tracer.now_ns();
            let outcome = match &spec {
                Ok(Some(_)) => "prepared",
                Ok(None) => "skipped",
                Err(_) => "error",
            };
            self.tracer.span_at("speculate", COORD_LANE, t0, t1, &|| {
                vec![
                    ("round", round.to_string()),
                    ("outcome", outcome.to_string()),
                ]
            });
        }
        self.metrics
            .inc("pipeline_overlap_ns", (timer.elapsed_s() * 1e9) as u64);
        match spec {
            Ok(Some(s)) => {
                self.speculation = Some(s);
                self.metrics.inc("pipeline_speculations", 1);
            }
            Ok(None) => {
                self.metrics.inc("pipeline_spec_skipped", 1);
            }
            Err(_) => {
                self.metrics.inc("pipeline_spec_errors", 1);
            }
        }
    }

    /// The speculative prepare: predict the post-round state, replay
    /// Recosting on clones, then run the identical Scheduling code the
    /// serial loop would. Returns `None` when the predicted round is
    /// empty (nothing worth precomputing).
    fn speculate_inner(
        &mut self,
        round: usize,
        plan: &RoundPlan,
    ) -> Result<Option<Speculation>> {
        // Predicted training drains: each surviving assignment burns its
        // scheduled cost. Exact for the sim backend (it reads energy off
        // the same plan costs); a guess for measured-energy backends —
        // where the guess is wrong, the guard misses and the round simply
        // prepares serially. Dropout victims drained *before* the plan
        // was built, so the live device state already carries them.
        let mut devices = self.devices.clone();
        // Incremental re-derivation speculates on a CLONE of the class
        // index, discarded afterwards — a wrong prediction can never
        // corrupt the live index (the live dirty set keeps accumulating
        // from actual drains and is applied at the next serial prepare).
        let mut index = if self.cfg.incremental.enabled {
            match &self.index {
                Some(ix) => Some(ix.clone()),
                // Transient (knob just toggled on): the serial prepare
                // builds the index first; nothing to speculate against.
                None => return Ok(None),
            }
        } else {
            None
        };
        for a in &plan.assignments {
            let e = plan.instance.costs[a.slot].eval(a.tasks);
            devices[a.device].drain(e);
            // Predicted dirty marks mirror finish_train's: backends
            // return one outcome per assignment, so the marked device
            // set matches the live one exactly.
            if devices[a.device].battery.is_some() {
                if let Some(ix) = index.as_mut() {
                    ix.mark(a.device);
                }
            }
        }
        // Recosting's drift/availability steps and RNG draws depend only
        // on dynamics + RNG state — never on training results — so the
        // predicted pool, drift scales, and RNG stream are *exact*
        // replicas of what finish_round will compute.
        let mut rng = self.rng.clone();
        let mut dynamics = self.dynamics.clone();
        if let Some(drift) = dynamics.drift.as_mut() {
            drift.step(&mut rng);
            for (i, dev) in devices.iter_mut().enumerate() {
                let s = drift.scale(i);
                if dev.drift != s {
                    dev.drift = s;
                    if let Some(ix) = index.as_mut() {
                        ix.mark(i);
                    }
                }
            }
        }
        let pool: Vec<usize> = match dynamics.availability.as_mut() {
            Some(av) => av.step(&mut rng),
            None => (0..devices.len()).collect(),
        };
        let mut guard = Self::scheduling_guard(&rng, &pool, &devices);
        // Fingerprint the clone BEFORE schedule_for applies its dirty
        // set: adoption compares against the live index in the same
        // pre-apply state (classification as of the last apply + the
        // accumulated dirty set).
        if let Some(ix) = &index {
            guard = mix_u64(guard, ix.fingerprint());
        }

        // From here on: the ONE Scheduling body (`schedule_for`), against
        // the predicted state.
        let mut incs = Vec::new();
        let mut warm = self.warm.clone();
        let prepared = match Self::schedule_for(
            &self.cfg,
            &self.registry,
            &mut warm,
            &mut rng,
            &pool,
            &devices,
            index.as_mut(),
            &mut incs,
            // Live tracer, speculatively-cloned everything else: trace
            // events are pure output, so tracing the speculation as it
            // happens can never perturb the state it predicts.
            &mut *self.tracer,
        )? {
            PreparedRound::Planned(p) => p,
            // A predicted-empty round has no solve worth precomputing.
            PreparedRound::Empty { .. } => return Ok(None),
        };
        Ok(Some(Speculation {
            round,
            guard,
            rng_after: rng.state(),
            warm,
            incs,
            prepared,
        }))
    }

    /// Recosting phase + metrics row shared by normal and empty rounds.
    #[allow(clippy::too_many_arguments)]
    fn finish_round(
        &mut self,
        round_idx: usize,
        loss: f64,
        sched_time_s: f64,
        train_time_s: f64,
        predicted_j: f64,
        participants: usize,
        tasks: usize,
    ) -> Result<RoundLog> {
        self.transition(Phase::Recosting)?;
        let recost_timer = Timer::start();
        let recost_t0 = self.tracer.now_ns();
        // Advance fleet dynamics for the NEXT round: drift the energy
        // profiles and churn availability. Battery state was already
        // re-costed in place as energy was recorded (and dirty-marked).
        // Drift assignment is conditional so only devices whose scale
        // actually moved are marked — storing the same bits either way,
        // non-incremental behavior is unchanged. Availability never
        // changes a signature, so it never marks.
        if let Some(drift) = self.dynamics.drift.as_mut() {
            drift.step(&mut self.rng);
            for (i, dev) in self.devices.iter_mut().enumerate() {
                let s = drift.scale(i);
                if dev.drift != s {
                    dev.drift = s;
                    if let Some(ix) = self.index.as_mut() {
                        ix.mark(i);
                    }
                }
            }
        }
        self.pool = match self.dynamics.availability.as_mut() {
            Some(av) => av.step(&mut self.rng),
            None => (0..self.devices.len()).collect(),
        };
        self.hists.recost_ns.record(secs_to_ns(recost_timer.elapsed_s()));
        if self.tracer.enabled() {
            let recost_t1 = self.tracer.now_ns();
            self.tracer
                .span_at("recost", COORD_LANE, recost_t0, recost_t1, &Vec::new);
            // Quantile gauges are exported only on traced campaigns:
            // they are wall-clock telemetry, and untraced metrics
            // summaries stay bit-stable run-to-run without them.
            self.hists.export(&mut self.metrics);
        }

        let energy_j = self.ledger.rounds().last().copied().unwrap_or(0.0);
        let row = RoundLog {
            round: round_idx,
            policy: self.cfg.algo.clone(),
            loss,
            energy_j,
            sched_time_s,
            train_time_s,
            participants,
            tasks,
        };
        self.metrics.inc("rounds", 1);
        self.metrics.inc("tasks", tasks as u64);
        self.metrics.set("eval_loss", loss);
        self.metrics.set("predicted_energy_j", predicted_j);
        self.last_loss = loss;
        self.log.push(row.clone());
        // Ready for the next round.
        self.phase = Phase::Scheduling;
        Ok(row)
    }

    /// Run the campaign up to the configured round count (early-stopping
    /// on `target_loss`); returns the accumulated log. Counts rounds
    /// already driven — a restored coordinator finishes its campaign, it
    /// does not start a fresh `cfg.rounds` on top.
    pub fn run(&mut self) -> Result<&TrainingLog> {
        while self.next_round < self.cfg.rounds {
            let row = self.round()?;
            if let Some(target) = self.cfg.target_loss {
                if row.loss <= target {
                    self.metrics.inc("early_stops", 1);
                    break;
                }
            }
        }
        self.flush_sinks()?;
        Ok(&self.log)
    }
}

// ---- durable campaigns (store attach / snapshot / restore) -------------
//
// Everything below needs the backend to expose durable state
// ([`BackendState`]); the plain round loop above does not.

impl<B: RoundBackend + BackendState> Coordinator<B> {
    /// Attach a campaign store. From here on every round is journaled
    /// (fsync'd before `round()` returns) and [`Coordinator::round_stored`]
    /// writes periodic snapshots. The store's committed count must equal
    /// the rounds already driven, so journal indices stay contiguous.
    pub fn attach_store(&mut self, store: CampaignStore) -> Result<()> {
        if store.committed() != self.next_round {
            return Err(FedError::Store(format!(
                "store holds {} committed rounds but the coordinator is at \
                 round {}",
                store.committed(),
                self.next_round
            )));
        }
        self.store = Some(store);
        Ok(())
    }

    /// Drive one round and write the periodic snapshot when due —
    /// [`Coordinator::round`] plus durability.
    pub fn round_stored(&mut self) -> Result<RoundLog> {
        let row = self.round()?;
        if self.store.as_ref().map_or(false, |s| s.due_snapshot()) {
            let t0 = self.tracer.now_ns();
            let state = self.snapshot_json();
            if let Some(store) = self.store.as_mut() {
                store.write_snapshot(state)?;
            }
            if self.tracer.enabled() {
                let t1 = self.tracer.now_ns();
                let round = row.round;
                self.tracer.span_at("snapshot", COORD_LANE, t0, t1, &|| {
                    vec![("round", round.to_string())]
                });
            }
        }
        Ok(row)
    }

    /// Serialize the full coordinator state (round-boundary invariants:
    /// the phase machine is between rounds). The warm DP cache is not
    /// persisted — warm re-solves are bit-for-bit equal to cold ones, so
    /// a restored run merely pays one cold solve.
    pub fn snapshot_json(&self) -> Json {
        Json::obj(vec![
            ("next_round", Json::Num(self.next_round as f64)),
            ("last_loss", jf(self.last_loss)),
            // Whole-campaign log totals survive the log ring AND restore.
            ("log_rows", Json::Num(self.log.total_rows() as f64)),
            ("log_energy", jf(self.log.total_energy())),
            ("rng", snap::rng_to_json(&self.rng)),
            (
                "pool",
                Json::Arr(
                    self.pool.iter().map(|&i| Json::Num(i as f64)).collect(),
                ),
            ),
            (
                "devices",
                Json::Arr(self.devices.iter().map(snap::device_to_json).collect()),
            ),
            ("dynamics", snap::dynamics_to_json(&self.dynamics)),
            ("ledger", snap::ledger_to_json(&self.ledger)),
            ("metrics", snap::metrics_to_json(&self.metrics)),
            ("backend", self.backend.save_state()),
        ])
    }

    /// Rebuild a coordinator from a snapshot and replay the journal tail
    /// (every entry with `round >= snapshot.next_round`), **verifying**
    /// each replayed round against its journal entry — solver, instance +
    /// schedule digest, post-round RNG state, energy, loss, participants.
    /// Success therefore proves the restored coordinator is bit-for-bit
    /// at the pre-crash state: its next round will derive the same
    /// instance, produce the same schedule, and spend the same energy as
    /// the uninterrupted run.
    ///
    /// The store itself is *not* attached here; attach the writer half
    /// (from [`CampaignStore::resume`]) afterwards to continue the
    /// campaign.
    pub fn restore(
        cfg: CoordinatorConfig,
        state: &Json,
        entries: &[JournalEntry],
        backend: B,
        log_bound: Option<usize>,
    ) -> Result<Self> {
        let devices = get_arr(state, "devices")?
            .iter()
            .map(snap::device_from_json)
            .collect::<Result<Vec<ManagedDevice>>>()?;
        let mut c = Coordinator::new(cfg, devices, backend)?;
        c.backend.load_state(get(state, "backend")?)?;
        c.rng = snap::rng_from_json(get(state, "rng")?)?;
        c.pool = get_arr(state, "pool")?
            .iter()
            .map(|v| {
                v.as_usize().ok_or_else(|| {
                    FedError::Store("pool entries must be indices".into())
                })
            })
            .collect::<Result<Vec<usize>>>()?;
        c.next_round = get_usize(state, "next_round")?;
        c.last_loss = get_f64(state, "last_loss")?;
        c.dynamics = snap::dynamics_from_json(get(state, "dynamics")?)?;
        c.ledger = snap::ledger_from_json(get(state, "ledger")?)?;
        c.metrics = snap::metrics_from_json(get(state, "metrics")?)?;
        c.log = TrainingLog::new();
        c.log
            .resume_from(get_usize(state, "log_rows")?, get_f64(state, "log_energy")?);
        c.set_log_bound(log_bound);
        c.phase = if c.next_round == 0 {
            Phase::Configuring
        } else {
            Phase::Scheduling
        };
        c.record_trace = true;

        let start = c.next_round;
        for e in entries {
            if e.round < start {
                continue;
            }
            if e.round != c.next_round {
                return Err(FedError::Store(format!(
                    "journal gap: entry for round {} while replay is at {}",
                    e.round, c.next_round
                )));
            }
            c.replay_entry(e)?;
        }
        Ok(c)
    }

    /// Re-execute one journaled round and check it against the entry.
    fn replay_entry(&mut self, e: &JournalEntry) -> Result<()> {
        let mismatch = |what: String| {
            FedError::Store(format!("replay mismatch at round {}: {what}", e.round))
        };
        if e.solver == ABORTED_SOLVER {
            // The original run's backend failed this round. Deterministic
            // backends fail again on replay; a round that now *succeeds*
            // contradicts the journal. The aborted row the replay logged
            // is verified too — a forged aborted entry must not pass the
            // audit.
            return match self.round() {
                Err(_) => {
                    if self.rng.state() != e.rng_after {
                        return Err(mismatch("post-abort RNG state".into()));
                    }
                    if e.digest != 0 {
                        return Err(mismatch(
                            "aborted entry carries a schedule digest".into(),
                        ));
                    }
                    let row = self.log.rows().last().cloned().ok_or_else(|| {
                        mismatch("no aborted row was logged".into())
                    })?;
                    Self::check_row(&row, e)
                }
                Ok(_) => Err(mismatch(
                    "journaled aborted round replayed successfully".into(),
                )),
            };
        }
        let row = self.round().map_err(|err| {
            FedError::Store(format!("replay of round {} failed: {err}", e.round))
        })?;
        let trace = self.trace.clone().unwrap_or_default();
        if trace.solver != e.solver {
            return Err(mismatch(format!(
                "solver '{}' != journaled '{}'",
                trace.solver, e.solver
            )));
        }
        if trace.digest != e.digest {
            return Err(mismatch(format!(
                "instance/schedule digest {:x} != journaled {:x}",
                trace.digest, e.digest
            )));
        }
        if self.rng.state() != e.rng_after {
            return Err(mismatch("post-round RNG state".into()));
        }
        Self::check_row(&row, e)
    }

    /// Compare a replayed row against its journal entry (bit-exact energy
    /// and loss — NaN-tolerant — plus participants/tasks; timings are
    /// wall-clock noise and excluded).
    fn check_row(row: &RoundLog, e: &JournalEntry) -> Result<()> {
        let mismatch = |what: String| {
            FedError::Store(format!("replay mismatch at round {}: {what}", e.round))
        };
        if row.energy_j.to_bits() != e.row.energy_j.to_bits() {
            return Err(mismatch(format!(
                "energy {} != journaled {}",
                row.energy_j, e.row.energy_j
            )));
        }
        let loss_equal = row.loss.to_bits() == e.row.loss.to_bits()
            || (row.loss.is_nan() && e.row.loss.is_nan());
        if !loss_equal {
            return Err(mismatch(format!(
                "loss {} != journaled {}",
                row.loss, e.row.loss
            )));
        }
        if row.participants != e.row.participants || row.tasks != e.row.tasks {
            return Err(mismatch(format!(
                "participants/tasks {}/{} != journaled {}/{}",
                row.participants, row.tasks, e.row.participants, e.row.tasks
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::costs::CostFn;

    fn paper_fleet() -> Vec<ManagedDevice> {
        let inst = Instance::paper_example(5);
        (0..inst.n())
            .map(|i| {
                ManagedDevice::abstract_resource(
                    i,
                    inst.costs[i].clone(),
                    inst.lower[i],
                    inst.upper[i],
                )
            })
            .collect()
    }

    fn paper_cfg() -> CoordinatorConfig {
        CoordinatorConfig {
            rounds: 3,
            tasks_per_round: 5,
            algo: "mc2mkp".into(),
            max_share: 1.0,
            ..CoordinatorConfig::default()
        }
    }

    #[test]
    fn reproduces_the_section31_optimum_on_round_one() {
        let mut c = Coordinator::new(paper_cfg(), paper_fleet(), SimBackend::new())
            .unwrap();
        let row = c.round().unwrap();
        assert_eq!(row.tasks, 5);
        // X* = {2, 3, 0}: resource 3 sits idle, so 2 devices participate.
        assert_eq!(row.participants, 2);
        assert!((row.energy_j - 7.5).abs() < 1e-9, "ΣC = {}", row.energy_j);
        assert!((c.ledger().total() - 7.5).abs() < 1e-9);
    }

    #[test]
    fn phase_machine_rejects_illegal_transitions() {
        let mut c = Coordinator::new(paper_cfg(), paper_fleet(), SimBackend::new())
            .unwrap();
        assert_eq!(c.phase(), Phase::Configuring);
        assert!(c.transition(Phase::Training).is_err());
        assert!(c.transition(Phase::Aggregating).is_err());
        c.round().unwrap();
        assert_eq!(c.phase(), Phase::Scheduling);
        assert!(c.transition(Phase::Recosting).is_ok(), "empty-round edge");
    }

    #[test]
    fn rejects_bad_configuration() {
        assert!(Coordinator::new(paper_cfg(), vec![], SimBackend::new()).is_err());
        let mut cfg = paper_cfg();
        cfg.algo = "not-a-solver".into();
        assert!(Coordinator::new(cfg, paper_fleet(), SimBackend::new()).is_err());
        let mut cfg = paper_cfg();
        cfg.participation = 0.0;
        assert!(Coordinator::new(cfg, paper_fleet(), SimBackend::new()).is_err());
    }

    #[test]
    fn warm_start_metrics_accumulate_across_rounds() {
        let mut c = Coordinator::new(paper_cfg(), paper_fleet(), SimBackend::new())
            .unwrap();
        c.run().unwrap();
        assert_eq!(c.metrics().counter("dp_solves"), 3);
        // Static fleet, static costs: rounds 2 and 3 reuse every DP row.
        assert_eq!(c.metrics().counter("dp_rows_reused"), 6);
        assert_eq!(c.metrics().counter("dp_rows_total"), 9);
    }

    #[test]
    fn battery_drain_recosts_subsequent_rounds() {
        use crate::energy::battery::Battery;
        use crate::energy::power::{Behavior, PowerModel};
        // One battery device that can afford 4 tasks in round 1, and one
        // expensive mains device. Draining the battery must shift work.
        let cheap_power = PowerModel {
            idle_w: 0.0,
            busy_w: 2.0,
            batch_latency_s: 0.5,
            behavior: Behavior::Linear,
            curvature: 0.0,
        }; // 1 J per task
        let devices = vec![
            ManagedDevice {
                id: 0,
                cost: cheap_power.cost_fn(),
                lower: 0,
                data_cap: 10,
                battery: Some(Battery {
                    // 8 J remaining at 50% budget → 4 tasks in round 1.
                    capacity_wh: 8.0 / 3600.0,
                    level: 1.0,
                    round_budget_frac: 0.5,
                }),
                power: Some(cheap_power),
                drift: 1.0,
                deadline_cap: usize::MAX,
            },
            ManagedDevice::abstract_resource(
                1,
                CostFn::Affine { fixed: 0.0, per_task: 100.0 },
                0,
                10,
            ),
        ];
        let cfg = CoordinatorConfig {
            rounds: 2,
            tasks_per_round: 4,
            algo: "auto".into(),
            max_share: 1.0,
            ..CoordinatorConfig::default()
        };
        let mut c = Coordinator::new(cfg, devices, SimBackend::new()).unwrap();
        let r1 = c.round().unwrap();
        assert!((r1.energy_j - 4.0).abs() < 1e-9, "round 1 all on battery dev");
        // 4 J drained → 4 J remain → budget 2 J → U_0 = 2 next round.
        let r2 = c.round().unwrap();
        assert!(
            (r2.energy_j - (2.0 + 200.0)).abs() < 1e-9,
            "round 2 must overflow to the expensive device: {}",
            r2.energy_j
        );
    }

    #[test]
    fn exhausted_fleet_degrades_to_empty_rounds() {
        use crate::energy::battery::Battery;
        use crate::energy::power::{Behavior, PowerModel};
        let power = PowerModel {
            idle_w: 0.0,
            busy_w: 2.0,
            batch_latency_s: 0.5,
            behavior: Behavior::Linear,
            curvature: 0.0,
        }; // 1 J per task
        let devices = vec![ManagedDevice {
            id: 0,
            cost: power.cost_fn(),
            lower: 0,
            data_cap: 10,
            battery: Some(Battery {
                capacity_wh: 2.0 / 3600.0, // 2 J total
                level: 1.0,
                round_budget_frac: 1.0,
            }),
            power: Some(power),
            drift: 1.0,
            deadline_cap: usize::MAX,
        }];
        let cfg = CoordinatorConfig {
            rounds: 3,
            tasks_per_round: 4,
            algo: "auto".into(),
            max_share: 1.0,
            ..CoordinatorConfig::default()
        };
        let mut c = Coordinator::new(cfg, devices, SimBackend::new()).unwrap();
        c.run().unwrap();
        let rows = c.log().rows();
        assert_eq!(rows.len(), 3, "run must survive battery exhaustion");
        assert!((rows[0].energy_j - 2.0).abs() < 1e-9);
        assert_eq!(rows[1].energy_j, 0.0);
        assert_eq!(rows[2].energy_j, 0.0);
        assert_eq!(c.metrics().counter("exhausted_rounds"), 2);
    }

    #[test]
    fn round_errors_leave_the_machine_ready() {
        struct FailingBackend;
        impl RoundBackend for FailingBackend {
            fn train(&mut self, _plan: &RoundPlan) -> Result<Vec<DeviceOutcome>> {
                Err(FedError::Fl("injected training failure".into()))
            }
            fn aggregate(&mut self) -> Result<()> {
                Ok(())
            }
            fn evaluate(&mut self) -> Result<f64> {
                Ok(0.0)
            }
        }
        let mut c =
            Coordinator::new(paper_cfg(), paper_fleet(), FailingBackend).unwrap();
        let e1 = c.round().unwrap_err().to_string();
        assert!(e1.contains("injected"), "{e1}");
        // The failure must not wedge the phase machine: the next round
        // reports the same backend error, not an illegal transition.
        let e2 = c.round().unwrap_err().to_string();
        assert!(e2.contains("injected"), "{e2}");
        assert_eq!(c.phase(), Phase::Scheduling);
        // Aborted rounds are still accounted: one row + one ledger bucket
        // each, so log and ledger stay in lockstep across failures.
        assert_eq!(c.metrics().counter("aborted_rounds"), 2);
        assert_eq!(c.log().rows().len(), 2);
        assert_eq!(c.ledger().rounds().len(), 2);
        let logged: f64 = c.log().rows().iter().map(|r| r.energy_j).sum();
        assert!((logged - c.ledger().total()).abs() < 1e-12);
    }

    #[test]
    fn registry_override_of_mc2mkp_disables_the_warm_fast_path() {
        use crate::sched::solver::Solver;
        struct UniformAsDp;
        impl Solver for UniformAsDp {
            fn name(&self) -> &'static str {
                "mc2mkp"
            }
            fn solve_flat(&self, inst: &Instance) -> Result<Schedule> {
                crate::sched::baselines::uniform(inst)
            }
        }
        let mut c = Coordinator::new(paper_cfg(), paper_fleet(), SimBackend::new())
            .unwrap();
        c.registry_mut().register(Box::new(UniformAsDp));
        let row = c.round().unwrap();
        // Uniform on the §3.1 example is feasible but NOT optimal, and the
        // warm DP must not have run.
        assert!(row.energy_j > 7.5 + 1e-9, "override ignored: {}", row.energy_j);
        assert_eq!(c.metrics().counter("dp_solves"), 0);
    }

    #[test]
    fn unlimited_uppers_do_not_overflow_capacity_sums() {
        let c = CostFn::Affine { fixed: 0.0, per_task: 1.0 };
        let devices = vec![
            ManagedDevice::abstract_resource(0, c.clone(), 0, usize::MAX),
            ManagedDevice::abstract_resource(1, c, 0, usize::MAX),
        ];
        let cfg = CoordinatorConfig {
            rounds: 1,
            tasks_per_round: 40,
            algo: "auto".into(),
            max_share: 1.0,
            ..CoordinatorConfig::default()
        };
        let mut coord = Coordinator::new(cfg, devices, SimBackend::new()).unwrap();
        let row = coord.round().unwrap();
        assert_eq!(row.tasks, 40);
        assert!((row.energy_j - 40.0).abs() < 1e-9);
    }

    #[test]
    fn identical_devices_collapse_into_classes() {
        let c = CostFn::Affine { fixed: 0.0, per_task: 1.0 };
        let devices: Vec<ManagedDevice> = (0..6)
            .map(|i| ManagedDevice::abstract_resource(i, c.clone(), 0, 4))
            .collect();
        let cfg = CoordinatorConfig {
            rounds: 1,
            tasks_per_round: 12,
            algo: "auto".into(),
            max_share: 1.0,
            ..CoordinatorConfig::default()
        };
        let mut coord = Coordinator::new(cfg, devices, SimBackend::new()).unwrap();
        let row = coord.round().unwrap();
        assert_eq!(row.tasks, 12);
        assert!((row.energy_j - 12.0).abs() < 1e-9);
        // Six interchangeable devices → one scheduling class.
        assert_eq!(coord.metrics().counter("fleet_devices"), 6);
        assert_eq!(coord.metrics().counter("fleet_classes"), 1);
    }

    #[test]
    fn sharded_instance_derivation_is_bit_for_bit() {
        // Same campaign, shards=1 vs shards=3 (with churn/drift/dropout
        // engaged so per-round instances genuinely vary): every row and
        // the RNG stream must match exactly — sharding is build-time
        // only, never a scheduling change.
        let run = |shards: usize| {
            let cfg = CoordinatorConfig { rounds: 6, shards, ..paper_cfg() };
            let mut c =
                Coordinator::new(cfg, paper_fleet(), SimBackend::new()).unwrap();
            c.set_dynamics(DynamicsConfig::mobile(3));
            c.run().unwrap();
            let rows: Vec<(u64, u64, usize, usize)> = c
                .log()
                .rows()
                .iter()
                .map(|r| {
                    (r.loss.to_bits(), r.energy_j.to_bits(), r.participants, r.tasks)
                })
                .collect();
            (rows, c.rng.state(), c.ledger().total().to_bits())
        };
        let a = run(1);
        let b = run(3);
        assert_eq!(a, b);
    }

    #[test]
    fn sharded_build_is_metered() {
        let cfg = CoordinatorConfig { rounds: 2, shards: 2, ..paper_cfg() };
        let mut c =
            Coordinator::new(cfg, paper_fleet(), SimBackend::new()).unwrap();
        c.run().unwrap();
        assert_eq!(c.metrics().counter("fleet_shards"), 4, "2 rounds × 2 shards");
        // Merge time is wall-clock noise; only its presence is pinned.
        let _ = c.metrics().counter("shard_merge_ns");
        // The unsharded path must not emit shard metrics at all.
        let mut plain =
            Coordinator::new(paper_cfg(), paper_fleet(), SimBackend::new())
                .unwrap();
        plain.round().unwrap();
        assert_eq!(plain.metrics().counter("fleet_shards"), 0);
    }

    #[test]
    fn zero_shards_is_rejected() {
        let cfg = CoordinatorConfig { shards: 0, ..paper_cfg() };
        assert!(Coordinator::new(cfg, paper_fleet(), SimBackend::new()).is_err());
        let mut c =
            Coordinator::new(paper_cfg(), paper_fleet(), SimBackend::new())
                .unwrap();
        assert!(c.set_shards(0).is_err());
        c.set_shards(4).unwrap();
    }

    #[test]
    fn pipelined_campaign_is_bit_for_bit_with_dynamics() {
        // Same campaign, pipeline off vs on (churn/drift/dropout engaged
        // so speculation validation genuinely has state to check): every
        // row and the RNG stream must match exactly — pipelining is a
        // wall-clock overlap, never a scheduling change.
        let run = |pipeline: bool| {
            let cfg = CoordinatorConfig {
                rounds: 8,
                pipeline: pipeline.into(),
                ..paper_cfg()
            };
            let mut c =
                Coordinator::new(cfg, paper_fleet(), SimBackend::new()).unwrap();
            c.set_dynamics(DynamicsConfig::mobile(3));
            c.run().unwrap();
            let rows: Vec<(u64, u64, usize, usize)> = c
                .log()
                .rows()
                .iter()
                .map(|r| {
                    (r.loss.to_bits(), r.energy_j.to_bits(), r.participants, r.tasks)
                })
                .collect();
            (rows, c.rng.state(), c.ledger().total().to_bits())
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn speculation_hits_every_round_on_a_predictable_fleet() {
        // The sim backend's measured energy IS the scheduled cost, so the
        // speculative drain prediction is exact and every speculation must
        // validate — rounds 1..R-1 all adopt (round 0 has nothing to adopt,
        // the last round spawns no speculation). mc2mkp keeps the warm DP
        // adoption path honest too.
        let cfg = CoordinatorConfig {
            rounds: 5,
            pipeline: PipelineConfig::on(),
            ..paper_cfg()
        };
        let mut c =
            Coordinator::new(cfg, paper_fleet(), SimBackend::new()).unwrap();
        c.run().unwrap();
        assert_eq!(c.metrics().counter("pipeline_speculations"), 4);
        assert_eq!(c.metrics().counter("pipeline_hits"), 4);
        assert_eq!(c.metrics().counter("pipeline_misses"), 0);
        // Adopted DP solves must meter exactly like serial ones (warm
        // cache adopted across rounds: static fleet reuses every row).
        assert_eq!(c.metrics().counter("dp_solves"), 5);
        assert_eq!(c.metrics().counter("dp_rows_reused"), 12);
        // Overlap time is wall-clock noise; only its presence is pinned.
        assert!(c.metrics().counter("pipeline_overlap_ns") > 0);
        // The serial loop must not emit pipeline metrics at all.
        let mut plain =
            Coordinator::new(paper_cfg(), paper_fleet(), SimBackend::new())
                .unwrap();
        plain.run().unwrap();
        assert_eq!(plain.metrics().counter("pipeline_speculations"), 0);
        assert_eq!(plain.metrics().counter("pipeline_hits"), 0);
    }

    #[test]
    fn wrong_energy_prediction_misses_but_stays_correct() {
        use crate::energy::battery::Battery;
        use crate::energy::power::{Behavior, PowerModel};
        // A backend whose measured energy exceeds the scheduled cost: the
        // speculative battery drain under-predicts, the guard catches the
        // divergence, and the round re-prepares serially — identical rows
        // to the serial loop over the same backend, just without overlap.
        struct InflatedEnergyBackend {
            inner: SimBackend,
        }
        impl RoundBackend for InflatedEnergyBackend {
            fn train(&mut self, plan: &RoundPlan) -> Result<Vec<DeviceOutcome>> {
                let mut out = self.inner.train(plan)?;
                for o in &mut out {
                    o.energy_j *= 1.25;
                }
                Ok(out)
            }
            fn begin_train(&mut self, plan: &RoundPlan) -> Result<bool> {
                self.inner.begin_train(plan)
            }
            fn finish_train(&mut self, plan: &RoundPlan) -> Result<Vec<DeviceOutcome>> {
                let mut out = self.inner.finish_train(plan)?;
                for o in &mut out {
                    o.energy_j *= 1.25;
                }
                Ok(out)
            }
            fn aggregate(&mut self) -> Result<()> {
                self.inner.aggregate()
            }
            fn evaluate(&mut self) -> Result<f64> {
                self.inner.evaluate()
            }
        }
        let power = PowerModel {
            idle_w: 0.0,
            busy_w: 2.0,
            batch_latency_s: 0.5,
            behavior: Behavior::Linear,
            curvature: 0.0,
        }; // 1 J per task
        let fleet = || {
            vec![
                ManagedDevice {
                    id: 0,
                    cost: power.cost_fn(),
                    lower: 0,
                    data_cap: 10,
                    battery: Some(Battery {
                        capacity_wh: 24.0 / 3600.0,
                        level: 1.0,
                        round_budget_frac: 0.5,
                    }),
                    power: Some(power.clone()),
                    drift: 1.0,
                    deadline_cap: usize::MAX,
                },
                ManagedDevice::abstract_resource(
                    1,
                    CostFn::Affine { fixed: 0.0, per_task: 3.0 },
                    0,
                    10,
                ),
            ]
        };
        let cfg = |pipeline: bool| CoordinatorConfig {
            rounds: 4,
            tasks_per_round: 6,
            algo: "auto".into(),
            max_share: 1.0,
            pipeline: pipeline.into(),
            ..CoordinatorConfig::default()
        };
        let run = |pipeline: bool| {
            let mut c = Coordinator::new(
                cfg(pipeline),
                fleet(),
                InflatedEnergyBackend { inner: SimBackend::new() },
            )
            .unwrap();
            c.run().unwrap();
            let rows: Vec<(u64, u64)> = c
                .log()
                .rows()
                .iter()
                .map(|r| (r.energy_j.to_bits(), r.loss.to_bits()))
                .collect();
            (rows, c.rng.state(), c.metrics().counter("pipeline_misses"))
        };
        let (serial_rows, serial_rng, _) = run(false);
        let (piped_rows, piped_rng, misses) = run(true);
        assert_eq!(serial_rows, piped_rows);
        assert_eq!(serial_rng, piped_rng);
        assert!(misses > 0, "inflated energy must invalidate speculations");
    }

    #[test]
    fn aborted_rounds_stay_equivalent_under_pipelining() {
        // A backend that fails one round mid-campaign: the abort path and
        // the rounds after it must be bit-for-bit identical with the
        // pipeline on (the failed round's speculation is guard-checked
        // like any other and never forges state).
        struct FailNth {
            inner: SimBackend,
            fail_round: usize,
        }
        impl RoundBackend for FailNth {
            fn train(&mut self, plan: &RoundPlan) -> Result<Vec<DeviceOutcome>> {
                if plan.round == self.fail_round {
                    return Err(FedError::Fl("injected mid-campaign".into()));
                }
                self.inner.train(plan)
            }
            fn begin_train(&mut self, plan: &RoundPlan) -> Result<bool> {
                self.inner.begin_train(plan)
            }
            fn finish_train(&mut self, plan: &RoundPlan) -> Result<Vec<DeviceOutcome>> {
                if plan.round == self.fail_round {
                    return Err(FedError::Fl("injected mid-campaign".into()));
                }
                self.inner.finish_train(plan)
            }
            fn aggregate(&mut self) -> Result<()> {
                self.inner.aggregate()
            }
            fn evaluate(&mut self) -> Result<f64> {
                self.inner.evaluate()
            }
        }
        let run = |pipeline: bool| {
            let cfg = CoordinatorConfig {
                rounds: 6,
                pipeline: pipeline.into(),
                ..paper_cfg()
            };
            let mut c = Coordinator::new(
                cfg,
                paper_fleet(),
                FailNth { inner: SimBackend::new(), fail_round: 2 },
            )
            .unwrap();
            c.set_dynamics(DynamicsConfig::mobile(3));
            let mut errors = 0;
            while c.rounds_run() < 6 {
                if c.round().is_err() {
                    errors += 1;
                }
            }
            let rows: Vec<(u64, u64, usize)> = c
                .log()
                .rows()
                .iter()
                .map(|r| (r.loss.to_bits(), r.energy_j.to_bits(), r.participants))
                .collect();
            (rows, c.rng.state(), errors)
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn store_poison_with_speculation_in_flight_never_journals_it() {
        // Simulate the one failure the commit path cannot recover from —
        // a failed journal append — while a speculation is in flight: the
        // next round must refuse to run, and the speculative round must
        // never reach the journal (contiguity from disk proves it).
        let dir = std::env::temp_dir().join("fedzero_pipeline_poison_test");
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = CoordinatorConfig {
            rounds: 6,
            pipeline: PipelineConfig::on(),
            ..paper_cfg()
        };
        let mut c =
            Coordinator::new(cfg.clone(), paper_fleet(), SimBackend::new())
                .unwrap();
        let meta = Json::obj(vec![("cfg", snap::cfg_to_json(&cfg))]);
        let store = CampaignStore::create(&dir, meta, c.snapshot_json()).unwrap();
        c.attach_store(store).unwrap();
        c.round_stored().unwrap();
        assert!(c.speculation.is_some(), "round 1's speculation is in flight");
        c.store_failed = Some("injected commit failure".into());
        let err = c.round().unwrap_err().to_string();
        assert!(err.contains("refusing"), "{err}");
        let journal = std::fs::read_to_string(dir.join("journal.jsonl")).unwrap();
        assert_eq!(journal.lines().count(), 1, "only round 0 is journaled");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn registry_override_mid_campaign_discards_inflight_speculation() {
        use crate::sched::solver::Solver;
        struct UniformAsDp;
        impl Solver for UniformAsDp {
            fn name(&self) -> &'static str {
                "mc2mkp"
            }
            fn solve_flat(&self, inst: &Instance) -> Result<Schedule> {
                crate::sched::baselines::uniform(inst)
            }
        }
        let cfg = CoordinatorConfig {
            rounds: 4,
            pipeline: PipelineConfig::on(),
            ..paper_cfg()
        };
        let mut c =
            Coordinator::new(cfg, paper_fleet(), SimBackend::new()).unwrap();
        c.round().unwrap();
        assert!(c.speculation.is_some(), "round 1 was speculated with the DP");
        // The override must win from the very next round: the stale
        // speculation (solved by the built-in DP) is discarded, never
        // adopted past the new solver.
        c.registry_mut().register(Box::new(UniformAsDp));
        assert!(c.speculation.is_none());
        let row = c.round().unwrap();
        assert!(
            row.energy_j > 7.5 + 1e-9,
            "stale DP speculation adopted over the override: {}",
            row.energy_j
        );
        assert_eq!(c.metrics().counter("pipeline_hits"), 0);
    }

    #[test]
    fn disabling_the_pipeline_discards_inflight_speculation() {
        let cfg = CoordinatorConfig {
            rounds: 4,
            pipeline: PipelineConfig::on(),
            ..paper_cfg()
        };
        let mut c =
            Coordinator::new(cfg, paper_fleet(), SimBackend::new()).unwrap();
        c.record_trace = true;
        c.round().unwrap();
        assert!(c.speculation.is_some());
        c.set_pipeline(false);
        assert!(c.speculation.is_none());
        // And the serial continuation is the plain serial continuation.
        c.round().unwrap();
        assert_eq!(c.metrics().counter("pipeline_hits"), 0);
    }

    #[test]
    fn snapshot_restore_resumes_bit_for_bit() {
        // Two rounds in (with churn, drift, dropout, and the warm DP all
        // engaged), snapshot, rebuild through the JSON round-trip, and
        // drive both coordinators three more rounds: every row and the
        // final RNG state must match exactly. The restored side solves
        // cold where the original is warm — bit-for-bit by design.
        let cfg = CoordinatorConfig { rounds: 5, ..paper_cfg() };
        let mut a =
            Coordinator::new(cfg.clone(), paper_fleet(), SimBackend::new())
                .unwrap();
        a.set_dynamics(DynamicsConfig::mobile(3));
        a.round().unwrap();
        a.round().unwrap();
        let state = Json::parse(&a.snapshot_json().to_string()).unwrap();
        let mut b =
            Coordinator::restore(cfg, &state, &[], SimBackend::new(), None)
                .unwrap();
        assert_eq!(b.rounds_run(), 2);
        for _ in 0..3 {
            let ra = a.round().unwrap();
            let rb = b.round().unwrap();
            assert_eq!(ra.round, rb.round);
            assert_eq!(ra.energy_j.to_bits(), rb.energy_j.to_bits());
            assert_eq!(ra.loss.to_bits(), rb.loss.to_bits());
            assert_eq!(ra.participants, rb.participants);
            assert_eq!(ra.tasks, rb.tasks);
        }
        assert_eq!(a.rng.state(), b.rng.state(), "streams must stay in lockstep");
        assert_eq!(a.ledger().total().to_bits(), b.ledger().total().to_bits());
    }

    #[test]
    fn bounded_log_with_sink_receives_every_row() {
        use crate::store::NullSink;
        let cfg = CoordinatorConfig { rounds: 40, ..paper_cfg() };
        let mut c =
            Coordinator::new(cfg, paper_fleet(), SimBackend::new()).unwrap();
        c.add_sink(Box::new(NullSink));
        c.set_log_bound(Some(4));
        c.run().unwrap();
        assert_eq!(c.log().total_rows(), 40);
        assert!(c.log().rows().len() < 8, "retention must stay bounded");
        assert_eq!(c.metrics().counter("rounds"), 40);
        assert!(c.ledger().rounds().len() < 8);
        assert_eq!(c.ledger().rounds_opened(), 40);
    }

    #[test]
    fn run_is_deterministic_for_a_seed() {
        let go = || {
            let cfg = CoordinatorConfig {
                rounds: 5,
                algo: "random".into(),
                ..paper_cfg()
            };
            let mut c =
                Coordinator::new(cfg, paper_fleet(), SimBackend::new()).unwrap();
            c.run().unwrap();
            c.log()
                .rows()
                .iter()
                .map(|r| (r.loss, r.energy_j))
                .collect::<Vec<_>>()
        };
        assert_eq!(go(), go());
    }

    // ---- incremental round re-derivation ------------------------------

    /// Fingerprint of a finished campaign: every row's bits, the RNG
    /// stream position, and the ledger total. Metrics are deliberately
    /// excluded — the incremental/pipeline/shard knobs meter themselves
    /// differently by design, while everything here must be identical.
    fn campaign_bits<B: RoundBackend>(
        c: &Coordinator<B>,
    ) -> (Vec<(u64, u64, usize, usize)>, [u64; 4], u64) {
        let rows = c
            .log()
            .rows()
            .iter()
            .map(|r| {
                (r.loss.to_bits(), r.energy_j.to_bits(), r.participants, r.tasks)
            })
            .collect();
        (rows, c.rng.state(), c.ledger().total().to_bits())
    }

    #[test]
    fn incremental_campaign_is_bit_for_bit_with_dynamics() {
        // Same campaign under every knob combination (churn, drift, and
        // dropout engaged so the dirty set genuinely varies): rows, RNG
        // stream, and ledger must match the plain serial run exactly —
        // incremental derivation, like sharding and pipelining, is a
        // wall-clock knob, never a scheduling change.
        let run = |incremental: bool, pipeline: bool, shards: usize| {
            let cfg = CoordinatorConfig {
                rounds: 8,
                incremental: incremental.into(),
                pipeline: pipeline.into(),
                shards,
                ..paper_cfg()
            };
            let mut c =
                Coordinator::new(cfg, paper_fleet(), SimBackend::new()).unwrap();
            c.set_dynamics(DynamicsConfig::mobile(3));
            c.run().unwrap();
            campaign_bits(&c)
        };
        let reference = run(false, false, 1);
        assert_eq!(reference, run(true, false, 1), "incremental serial");
        assert_eq!(reference, run(true, true, 1), "incremental + pipeline");
        assert_eq!(reference, run(true, false, 3), "incremental + shards");
        assert_eq!(reference, run(true, true, 3), "all knobs");
    }

    /// Mains-powered fleet with distinct latencies: device 0 is fast and
    /// cheap (0.5 s, 1 J per batch), device 2 slow and expensive (2 s,
    /// 4 J per batch). Under the default 2 s upload, a 6 s deadline caps
    /// them at 8 / 4 / 2 tasks.
    fn timed_fleet() -> Vec<ManagedDevice> {
        use crate::energy::power::{Behavior, PowerModel};
        [0.5, 1.0, 2.0]
            .iter()
            .enumerate()
            .map(|(id, &latency)| {
                let power = PowerModel {
                    idle_w: 0.0,
                    busy_w: 2.0,
                    batch_latency_s: latency,
                    behavior: Behavior::Linear,
                    curvature: 0.0,
                };
                ManagedDevice {
                    id,
                    cost: power.cost_fn(),
                    lower: 0,
                    data_cap: 20,
                    battery: None,
                    power: Some(power),
                    drift: 1.0,
                    deadline_cap: usize::MAX,
                }
            })
            .collect()
    }

    fn timed_cfg() -> CoordinatorConfig {
        CoordinatorConfig {
            rounds: 6,
            tasks_per_round: 12,
            algo: "auto".into(),
            max_share: 1.0,
            ..CoordinatorConfig::default()
        }
    }

    #[test]
    fn deadline_caps_change_schedules_and_energy() {
        // Unconstrained, all 12 tasks fit the cheap fast device: 12 J per
        // round. A 6 s deadline caps it at 8, spilling 4 tasks to the
        // 2 J device: 16 J per round.
        let run = |deadline: DeadlineConfig| {
            let cfg = CoordinatorConfig { rounds: 1, deadline, ..timed_cfg() };
            let mut c =
                Coordinator::new(cfg, timed_fleet(), SimBackend::new()).unwrap();
            c.run().unwrap();
            c.log().rows()[0].energy_j
        };
        assert!((run(DeadlineConfig::off()) - 12.0).abs() < 1e-9);
        assert!((run(DeadlineConfig::on(6.0)) - 16.0).abs() < 1e-9);
        // A loose deadline caps nothing: identical to unconstrained.
        assert!((run(DeadlineConfig::on(1e6)) - 12.0).abs() < 1e-9);
    }

    #[test]
    fn deadline_campaign_is_bit_for_bit_across_knobs() {
        // The deadline *changes* schedules, but must compose with every
        // wall-clock knob without changing them further: a deadline
        // campaign's rows, RNG stream, and ledger are identical across
        // pipeline/shards/incremental, including under dynamics.
        let run = |incremental: bool, pipeline: bool, shards: usize| {
            let cfg = CoordinatorConfig {
                incremental: incremental.into(),
                pipeline: pipeline.into(),
                shards,
                deadline: DeadlineConfig::on(6.0),
                ..timed_cfg()
            };
            let mut c =
                Coordinator::new(cfg, timed_fleet(), SimBackend::new()).unwrap();
            c.set_dynamics(DynamicsConfig::mobile(3));
            c.run().unwrap();
            campaign_bits(&c)
        };
        let reference = run(false, false, 1);
        assert_eq!(reference, run(true, false, 1), "deadline + incremental");
        assert_eq!(reference, run(false, true, 1), "deadline + pipeline");
        assert_eq!(reference, run(false, false, 3), "deadline + shards");
        assert_eq!(reference, run(true, true, 3), "deadline + all knobs");
        // And the deadline itself is not a wall-clock knob: dropping it
        // changes the campaign.
        let unconstrained = {
            let mut c = Coordinator::new(timed_cfg(), timed_fleet(), SimBackend::new())
                .unwrap();
            c.set_dynamics(DynamicsConfig::mobile(3));
            c.run().unwrap();
            campaign_bits(&c)
        };
        assert_ne!(reference.0, unconstrained.0, "deadline must bind");
    }

    #[test]
    fn set_deadline_recaps_devices_and_discards_derived_state() {
        let cfg = CoordinatorConfig {
            pipeline: PipelineConfig::on(),
            ..timed_cfg()
        };
        let mut c =
            Coordinator::new(cfg, timed_fleet(), SimBackend::new()).unwrap();
        c.round().unwrap();
        assert!(c.speculation.is_some());
        c.set_deadline(DeadlineConfig::on(6.0)).unwrap();
        assert!(c.speculation.is_none(), "caps invalidate the speculation");
        assert_eq!(
            c.devices().iter().map(|d| d.effective_upper()).collect::<Vec<_>>(),
            vec![8, 4, 2]
        );
        c.set_deadline(DeadlineConfig::off()).unwrap();
        assert_eq!(
            c.devices().iter().map(|d| d.effective_upper()).collect::<Vec<_>>(),
            vec![20, 20, 20]
        );
        // Invalid deadlines are rejected at both entry points.
        assert!(c.set_deadline(DeadlineConfig::on(0.0)).is_err());
        assert!(c.set_deadline(DeadlineConfig::on(f64::NAN)).is_err());
        let bad = CoordinatorConfig {
            deadline: DeadlineConfig::on(-1.0),
            ..timed_cfg()
        };
        assert!(Coordinator::new(bad, timed_fleet(), SimBackend::new()).is_err());
    }

    #[test]
    fn incremental_is_metered_and_supersedes_sharding() {
        let cfg = CoordinatorConfig {
            rounds: 4,
            incremental: IncrementalConfig::on(),
            shards: 3,
            ..paper_cfg()
        };
        let mut c =
            Coordinator::new(cfg, paper_fleet(), SimBackend::new()).unwrap();
        c.set_dynamics(DynamicsConfig::mobile(3));
        c.run().unwrap();
        // One lazy full classification, then dirty-set-only rounds.
        assert_eq!(c.metrics().counter("incr_index_rebuilds"), 1);
        // The counters exist even when zero devices moved (inc(_, 0)
        // creates the entry), so their presence is pinned.
        let _ = c.metrics().counter("incr_dirty");
        let _ = c.metrics().counter("incr_reclassified");
        // No O(n) bucketing runs, so nothing is sharded on this path.
        assert_eq!(c.metrics().counter("fleet_shards"), 0);
        // And the from-scratch path must not emit index metrics at all.
        let mut plain =
            Coordinator::new(paper_cfg(), paper_fleet(), SimBackend::new())
                .unwrap();
        plain.run().unwrap();
        assert_eq!(plain.metrics().counter("incr_index_rebuilds"), 0);
        assert!(!plain.metrics().summary().contains("incr_"));
    }

    #[test]
    fn incremental_battery_recosting_is_bit_for_bit() {
        use crate::energy::battery::Battery;
        use crate::energy::power::{Behavior, PowerModel};
        // The battery-drain recost scenario (work shifts to the expensive
        // device as the battery empties) under incremental derivation:
        // drains dirty-mark the device, and the re-derived rounds match
        // the from-scratch run to the bit.
        let power = PowerModel {
            idle_w: 0.0,
            busy_w: 2.0,
            batch_latency_s: 0.5,
            behavior: Behavior::Linear,
            curvature: 0.0,
        }; // 1 J per task
        let fleet = || {
            vec![
                ManagedDevice {
                    id: 0,
                    cost: power.cost_fn(),
                    lower: 0,
                    data_cap: 10,
                    battery: Some(Battery {
                        capacity_wh: 8.0 / 3600.0,
                        level: 1.0,
                        round_budget_frac: 0.5,
                    }),
                    power: Some(power.clone()),
                    drift: 1.0,
                    deadline_cap: usize::MAX,
                },
                ManagedDevice::abstract_resource(
                    1,
                    CostFn::Affine { fixed: 0.0, per_task: 100.0 },
                    0,
                    10,
                ),
            ]
        };
        let run = |incremental: bool| {
            let cfg = CoordinatorConfig {
                rounds: 3,
                tasks_per_round: 4,
                algo: "auto".into(),
                max_share: 1.0,
                incremental: incremental.into(),
                ..CoordinatorConfig::default()
            };
            let mut c = Coordinator::new(cfg, fleet(), SimBackend::new()).unwrap();
            c.run().unwrap();
            campaign_bits(&c)
        };
        let (rows, _, _) = run(false);
        assert!(
            (f64::from_bits(rows[1].1) - 202.0).abs() < 1e-9,
            "round 2 must overflow to the expensive device"
        );
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn incremental_speculations_hit_on_a_predictable_fleet() {
        // Static mains fleet: no drains change signatures, the index
        // fingerprint is constant, and every speculation must still
        // adopt — the incremental guard must never spuriously miss.
        let cfg = CoordinatorConfig {
            rounds: 5,
            incremental: IncrementalConfig::on(),
            pipeline: PipelineConfig::on(),
            ..paper_cfg()
        };
        let mut c =
            Coordinator::new(cfg, paper_fleet(), SimBackend::new()).unwrap();
        c.run().unwrap();
        assert_eq!(c.metrics().counter("pipeline_speculations"), 4);
        assert_eq!(c.metrics().counter("pipeline_hits"), 4);
        assert_eq!(c.metrics().counter("pipeline_misses"), 0);
        assert_eq!(c.metrics().counter("incr_index_rebuilds"), 1);
    }

    #[test]
    fn toggling_incremental_discards_index_and_speculation() {
        let cfg = CoordinatorConfig {
            rounds: 6,
            pipeline: PipelineConfig::on(),
            ..paper_cfg()
        };
        let mut c =
            Coordinator::new(cfg, paper_fleet(), SimBackend::new()).unwrap();
        c.round().unwrap();
        assert!(c.speculation.is_some(), "round 1's speculation is in flight");
        // Enabling mid-campaign: the stale speculation (from-scratch
        // mode) must not be adopted into incremental mode.
        c.set_incremental(true);
        assert!(c.speculation.is_none());
        assert!(c.index.is_none(), "index is built lazily, not eagerly");
        c.round().unwrap();
        assert!(c.index.is_some());
        assert_eq!(c.metrics().counter("incr_index_rebuilds"), 1);
        // Disabling drops the index; re-enabling rebuilds it.
        c.set_incremental(false);
        assert!(c.index.is_none());
        c.round().unwrap();
        c.set_incremental(true);
        c.round().unwrap();
        assert_eq!(c.metrics().counter("incr_index_rebuilds"), 2);
        // A no-op set must not discard anything.
        let spec_before = c.speculation.is_some();
        c.set_incremental(true);
        assert_eq!(c.speculation.is_some(), spec_before);
        assert!(c.index.is_some());
    }

    #[test]
    fn snapshot_restore_under_incremental_resumes_bit_for_bit() {
        // Snapshot two rounds into an incremental campaign (dynamics
        // engaged), restore, and continue both: identical rows and RNG.
        // The index is never snapshotted — the restored side rebuilds it
        // lazily and must land on the same bits.
        let cfg = CoordinatorConfig {
            rounds: 5,
            incremental: IncrementalConfig::on(),
            ..paper_cfg()
        };
        let mut a =
            Coordinator::new(cfg.clone(), paper_fleet(), SimBackend::new())
                .unwrap();
        a.set_dynamics(DynamicsConfig::mobile(3));
        a.round().unwrap();
        a.round().unwrap();
        let state = Json::parse(&a.snapshot_json().to_string()).unwrap();
        // The index itself must never leak into snapshots (the incr_*
        // metrics counters legitimately persist through the metrics
        // hub; the classification state does not).
        assert!(!a.snapshot_json().to_string().contains("device_class"));
        let mut b =
            Coordinator::restore(cfg, &state, &[], SimBackend::new(), None)
                .unwrap();
        assert!(b.index.is_none(), "restore leaves the index to lazy rebuild");
        for _ in 0..3 {
            let ra = a.round().unwrap();
            let rb = b.round().unwrap();
            assert_eq!(ra.energy_j.to_bits(), rb.energy_j.to_bits());
            assert_eq!(ra.loss.to_bits(), rb.loss.to_bits());
            assert_eq!(ra.participants, rb.participants);
            assert_eq!(ra.tasks, rb.tasks);
        }
        assert_eq!(a.rng.state(), b.rng.state());
        assert!(b.index.is_some());
    }

    // ---- observability ------------------------------------------------

    #[test]
    fn traced_campaign_is_bit_for_bit_and_spans_balance() {
        use crate::obs::ChromeTraceSink;
        use std::sync::{Arc, Mutex};

        #[derive(Clone, Default)]
        struct SharedBuf(Arc<Mutex<Vec<u8>>>);
        impl std::io::Write for SharedBuf {
            fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
                self.0.lock().unwrap().extend_from_slice(buf);
                Ok(buf.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }

        // Pipeline + sharded build engaged so the speculative and
        // fan-out span paths are exercised; churn/drift/dropout so the
        // traced state genuinely varies.
        let run = |sink: Option<SharedBuf>| {
            let traced = sink.is_some();
            let cfg = CoordinatorConfig {
                rounds: 6,
                shards: 3,
                pipeline: PipelineConfig::on(),
                ..paper_cfg()
            };
            let mut c =
                Coordinator::new(cfg, paper_fleet(), SimBackend::new()).unwrap();
            c.set_dynamics(DynamicsConfig::mobile(3));
            if let Some(buf) = sink {
                c.set_tracer(Box::new(ChromeTraceSink::from_writer(
                    Box::new(buf),
                )));
            }
            c.run().unwrap();
            c.flush_trace().unwrap();
            assert!(c.hists().sched_ns.count() > 0, "hists always record");
            assert_eq!(
                c.metrics().summary().contains("obs_"),
                traced,
                "quantile gauges exported exactly when traced"
            );
            campaign_bits(&c)
        };
        let buf = SharedBuf::default();
        let untraced = run(None);
        let traced = run(Some(buf.clone()));
        assert_eq!(untraced, traced, "tracing must be pure output");

        let text = String::from_utf8(buf.0.lock().unwrap().clone()).unwrap();
        let mut open: Vec<(String, String)> = Vec::new();
        let mut names: std::collections::BTreeSet<String> = Default::default();
        for line in text.lines() {
            let v = Json::parse(line).expect("trace lines are valid JSON");
            let ph = v.req("ph").unwrap().as_str().unwrap().to_string();
            let name = v.req("name").unwrap().as_str().unwrap().to_string();
            let tid = v.req("tid").unwrap().as_f64().unwrap().to_string();
            names.insert(name.clone());
            match ph.as_str() {
                "B" => open.push((name, tid)),
                "E" => assert_eq!(
                    open.pop().expect("E without B"),
                    (name, tid),
                    "spans must nest"
                ),
                "i" => {}
                other => panic!("unexpected phase {other}"),
            }
        }
        assert!(open.is_empty(), "unbalanced spans: {open:?}");
        for expected in [
            "round",
            "scheduling",
            "build_instance",
            "solve",
            "shard",
            "training",
            "aggregate",
            "recost",
            "speculate",
        ] {
            assert!(names.contains(expected), "missing span '{expected}'");
        }
    }
}
