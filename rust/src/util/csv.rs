//! Tiny CSV writer for experiment logs.
//!
//! RFC-4180-style quoting; every experiment (energy study, FL training,
//! complexity sweeps) appends rows through this writer so results can be
//! post-processed with standard tooling.

use std::io::Write;
use std::path::Path;

use crate::error::Result;

/// In-memory CSV document with a fixed header.
#[derive(Clone, Debug)]
pub struct CsvWriter {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvWriter {
    /// New document with the given column names.
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True if no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Append a row (must match header width).
    pub fn row(&mut self, fields: &[String]) {
        assert_eq!(
            fields.len(),
            self.header.len(),
            "CSV row width {} != header width {}",
            fields.len(),
            self.header.len()
        );
        self.rows.push(fields.to_vec());
    }

    /// Append a row of display-able values.
    pub fn rowd(&mut self, fields: &[&dyn std::fmt::Display]) {
        let v: Vec<String> = fields.iter().map(|f| f.to_string()).collect();
        self.row(&v);
    }

    /// Serialize the document.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        out.push_str(&encode_row(&self.header));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&encode_row(r));
            out.push('\n');
        }
        out
    }

    /// Write to a file, creating parent directories.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_string().as_bytes())?;
        Ok(())
    }
}

fn encode_field(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

fn encode_row(fields: &[String]) -> String {
    fields
        .iter()
        .map(|f| encode_field(f))
        .collect::<Vec<_>>()
        .join(",")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_rows() {
        let mut w = CsvWriter::new(&["a", "b"]);
        w.rowd(&[&1, &2.5]);
        w.rowd(&[&"x,y", &"q\"z"]);
        let s = w.to_string();
        assert_eq!(s, "a,b\n1,2.5\n\"x,y\",\"q\"\"z\"\n");
        assert_eq!(w.len(), 2);
    }

    #[test]
    #[should_panic]
    fn width_mismatch_panics() {
        let mut w = CsvWriter::new(&["a", "b"]);
        w.row(&["only-one".to_string()]);
    }

    #[test]
    fn save_and_read_back() {
        let mut w = CsvWriter::new(&["col"]);
        w.rowd(&[&42]);
        let p = std::env::temp_dir().join("fedzero_csv_test/out.csv");
        w.save(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert_eq!(s, "col\n42\n");
        let _ = std::fs::remove_file(p);
    }
}
