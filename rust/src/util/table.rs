//! ASCII table rendering for bench reports and example output.
//!
//! All benchmark binaries print their reproduction of the paper's tables
//! and figures through this renderer so `bench_output.txt` is readable.

/// A simple column-aligned ASCII table.
#[derive(Clone, Debug)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with a title and column names.
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row of display-able cells.
    pub fn row(&mut self, cells: &[&dyn std::fmt::Display]) {
        assert_eq!(cells.len(), self.header.len(), "table row width mismatch");
        self.rows.push(cells.iter().map(|c| c.to_string()).collect());
    }

    /// Append a row of pre-formatted strings.
    pub fn rows_str(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "table row width mismatch");
        self.rows.push(cells);
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                s.push(' ');
                s.push_str(&format!("{:<width$}", cells[i], width = widths[i]));
                s.push_str(" |");
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    /// Render and print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format seconds with an adaptive unit (ns/µs/ms/s).
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.3} s", secs)
    }
}

/// Format an energy value in joules with an adaptive unit.
pub fn fmt_energy(joules: f64) -> String {
    if joules.abs() >= 3.6e6 {
        format!("{:.3} kWh", joules / 3.6e6)
    } else if joules.abs() >= 1e3 {
        format!("{:.2} kJ", joules / 1e3)
    } else {
        format!("{:.2} J", joules)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["name", "v"]);
        t.row(&[&"a", &1]);
        t.row(&[&"longer", &23]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("| name   | v  |"));
        assert!(s.contains("| longer | 23 |"));
    }

    #[test]
    fn duration_units() {
        assert_eq!(fmt_duration(2.5e-9), "2.5 ns");
        assert_eq!(fmt_duration(2.5e-6), "2.50 µs");
        assert_eq!(fmt_duration(2.5e-3), "2.50 ms");
        assert_eq!(fmt_duration(2.5), "2.500 s");
    }

    #[test]
    fn energy_units() {
        assert_eq!(fmt_energy(5.0), "5.00 J");
        assert_eq!(fmt_energy(5400.0), "5.40 kJ");
        assert_eq!(fmt_energy(7.2e6), "2.000 kWh");
    }
}
