//! Descriptive statistics and small regression helpers.
//!
//! Used by the benchmark harness (median/MAD timing summaries) and by the
//! Table-2 reproduction (log-log slope fits of runtime-vs-T and runtime-vs-n
//! to recover empirical complexity exponents).

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance; 0 for < 2 samples.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// p-th percentile (0..=100) using linear interpolation on sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p));
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Median.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Median absolute deviation (robust spread).
pub fn mad(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let m = median(xs);
    let devs: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    median(&devs)
}

/// Ordinary least squares fit `y = a + b x`; returns `(a, b, r2)`.
pub fn ols(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    let b = sxy / sxx;
    let a = my - b * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (a, b, r2)
}

/// Fit `y = c * x^k` by OLS on log-log axes; returns `(k, r2)`.
///
/// This is how the Table-2 bench recovers empirical complexity exponents:
/// slope ≈ 1 means linear in the swept variable, ≈ 2 quadratic, etc.
pub fn loglog_slope(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    let lx: Vec<f64> = xs.iter().map(|x| x.max(1e-300).ln()).collect();
    let ly: Vec<f64> = ys.iter().map(|y| y.max(1e-300).ln()).collect();
    let (_, b, r2) = ols(&lx, &ly);
    (b, r2)
}

/// Min and max of a slice (NaN-free input assumed).
pub fn min_max(xs: &[f64]) -> (f64, f64) {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &x in xs {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    (lo, hi)
}

/// Geometric mean of positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.max(1e-300).ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_variance() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
        assert!((stddev(&xs) - 1.25f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert_eq!(median(&xs), 25.0);
        assert!((percentile(&xs, 25.0) - 17.5).abs() < 1e-12);
    }

    #[test]
    fn mad_robust_to_outlier() {
        let xs = [1.0, 1.0, 1.0, 1.0, 100.0];
        assert_eq!(mad(&xs), 0.0);
    }

    #[test]
    fn ols_exact_line() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        let (a, b, r2) = ols(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn loglog_recovers_exponent() {
        let xs: Vec<f64> = (1..=10).map(|i| i as f64 * 100.0).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x * x).collect();
        let (k, r2) = loglog_slope(&xs, &ys);
        assert!((k - 2.0).abs() < 1e-9, "k={k}");
        assert!(r2 > 0.999);
    }

    #[test]
    fn geomean_of_powers() {
        let xs = [1.0, 100.0];
        assert!((geomean(&xs) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn min_max_basic() {
        assert_eq!(min_max(&[3.0, -1.0, 2.0]), (-1.0, 3.0));
    }
}
