//! A binary min-heap over `(f64 key, payload)` pairs.
//!
//! MarIn (Algorithm 2 of the paper) maintains the next marginal cost of
//! every resource in a priority queue; the paper suggests a binomial heap
//! for its Θ(1) insert, but a binary heap achieves the same
//! Θ(n + T log n) total bound for MarIn's insert/pop pattern and has far
//! better constants. `std::collections::BinaryHeap` requires `Ord` keys;
//! our keys are `f64` marginal costs, so we implement the heap directly
//! with a total order on (key, tiebreak) pairs.

/// Min-heap entry: `key` is the priority (smaller pops first), `tiebreak`
/// makes ordering total and deterministic, `value` is the payload.
#[derive(Clone, Copy, Debug)]
pub struct Entry<T> {
    pub key: f64,
    pub tiebreak: u64,
    pub value: T,
}

impl<T> Entry<T> {
    #[inline]
    fn less(&self, other: &Self) -> bool {
        match self.key.partial_cmp(&other.key) {
            Some(std::cmp::Ordering::Less) => true,
            Some(std::cmp::Ordering::Greater) => false,
            _ => self.tiebreak < other.tiebreak,
        }
    }
}

/// Binary min-heap. Keys must not be NaN (marginal costs never are;
/// asserted in debug builds).
#[derive(Clone, Debug, Default)]
pub struct MinHeap<T> {
    items: Vec<Entry<T>>,
}

impl<T> MinHeap<T> {
    /// Empty heap.
    pub fn new() -> Self {
        Self { items: Vec::new() }
    }

    /// Empty heap with reserved capacity.
    pub fn with_capacity(cap: usize) -> Self {
        Self { items: Vec::with_capacity(cap) }
    }

    /// Number of entries.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Push an entry.
    pub fn push(&mut self, key: f64, tiebreak: u64, value: T) {
        debug_assert!(!key.is_nan(), "NaN key");
        self.items.push(Entry { key, tiebreak, value });
        self.sift_up(self.items.len() - 1);
    }

    /// Smallest entry, if any.
    pub fn peek(&self) -> Option<&Entry<T>> {
        self.items.first()
    }

    /// Pop the smallest entry.
    pub fn pop(&mut self) -> Option<Entry<T>> {
        if self.items.is_empty() {
            return None;
        }
        let last = self.items.len() - 1;
        self.items.swap(0, last);
        let top = self.items.pop();
        if !self.items.is_empty() {
            self.sift_down(0);
        }
        top
    }

    /// Build a heap from a vector in O(n) (Floyd's heapify).
    pub fn heapify(entries: Vec<Entry<T>>) -> Self {
        let mut h = Self { items: entries };
        if h.items.len() > 1 {
            for i in (0..h.items.len() / 2).rev() {
                h.sift_down(i);
            }
        }
        h
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.items[i].less(&self.items[parent]) {
                self.items.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.items.len();
        loop {
            let l = 2 * i + 1;
            let r = 2 * i + 2;
            let mut smallest = i;
            if l < n && self.items[l].less(&self.items[smallest]) {
                smallest = l;
            }
            if r < n && self.items[r].less(&self.items[smallest]) {
                smallest = r;
            }
            if smallest == i {
                break;
            }
            self.items.swap(i, smallest);
            i = smallest;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn pops_in_key_order() {
        let mut h = MinHeap::new();
        for (i, k) in [5.0, 1.0, 3.0, 2.0, 4.0].iter().enumerate() {
            h.push(*k, i as u64, i);
        }
        let keys: Vec<f64> = std::iter::from_fn(|| h.pop().map(|e| e.key)).collect();
        assert_eq!(keys, vec![1.0, 2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn ties_break_deterministically() {
        let mut h = MinHeap::new();
        h.push(1.0, 2, "b");
        h.push(1.0, 1, "a");
        h.push(1.0, 3, "c");
        assert_eq!(h.pop().unwrap().value, "a");
        assert_eq!(h.pop().unwrap().value, "b");
        assert_eq!(h.pop().unwrap().value, "c");
    }

    #[test]
    fn heapify_matches_push() {
        let mut r = Rng::new(1);
        let entries: Vec<Entry<usize>> = (0..200)
            .map(|i| Entry { key: r.f64(), tiebreak: i as u64, value: i })
            .collect();
        let mut a = MinHeap::heapify(entries.clone());
        let mut b = MinHeap::new();
        for e in entries {
            b.push(e.key, e.tiebreak, e.value);
        }
        while let (Some(x), Some(y)) = (a.pop(), b.pop()) {
            assert_eq!(x.value, y.value);
        }
        assert!(a.is_empty() && b.is_empty());
    }

    #[test]
    fn random_order_sorted_output() {
        let mut r = Rng::new(2);
        let mut h = MinHeap::new();
        for i in 0..1000u64 {
            h.push(r.f64(), i, i);
        }
        let mut prev = f64::NEG_INFINITY;
        while let Some(e) = h.pop() {
            assert!(e.key >= prev);
            prev = e.key;
        }
    }

    #[test]
    fn empty_behaviour() {
        let mut h: MinHeap<u8> = MinHeap::new();
        assert!(h.is_empty());
        assert!(h.pop().is_none());
        assert!(h.peek().is_none());
    }
}
