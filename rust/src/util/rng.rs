//! Deterministic pseudo-random number generation and sampling.
//!
//! Implements SplitMix64 (seeding) and Xoshiro256** (main generator) from
//! Blackman & Vigna, plus the distributions the simulator needs: uniform,
//! normal (Box–Muller), log-normal, exponential, Dirichlet (via Gamma),
//! Zipf, categorical, shuffling and sampling without replacement.
//!
//! Everything is reproducible from a single `u64` seed; all fleet
//! generation, data synthesis and experiment sweeps thread seeds explicitly
//! so experiments are replayable.

/// SplitMix64: used to expand a single user seed into generator state.
#[derive(Clone, Copy, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a new SplitMix64 from `seed`.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — fast, high-quality, 256-bit state PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Construct from a 64-bit seed (expanded with SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = sm.next_u64();
        }
        // Avoid the (astronomically unlikely) all-zero state.
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E37_79B9_7F4A_7C15;
        }
        Self { s }
    }

    /// Derive an independent child generator (for per-device streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// The raw 256-bit generator state — what the coordinator store
    /// snapshots so a restored run continues the *exact* stream.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a persisted [`Rng::state`]. The state must
    /// come from a live generator (never all-zero), so it is restored
    /// verbatim — bit-for-bit continuation is the whole point.
    pub fn from_state(s: [u64; 4]) -> Self {
        debug_assert!(s != [0, 0, 0, 0], "restored RNG state must be non-zero");
        Self { s }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 top bits → uniform double in [0,1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` using Lemire's rejection method.
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform `usize` index in `[0, bound)`.
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform float in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli trial with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple, fine for
    /// simulation workloads).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos();
            }
        }
    }

    /// Normal with mean `mu`, std `sigma`.
    pub fn normal_ms(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Log-normal: exp(N(mu, sigma)).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_ms(mu, sigma).exp()
    }

    /// Exponential with rate `lambda`.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Gamma(shape k, scale 1) via Marsaglia–Tsang (k >= some small bound
    /// handled via boost for k < 1).
    pub fn gamma(&mut self, k: f64) -> f64 {
        assert!(k > 0.0);
        if k < 1.0 {
            // Boost: Gamma(k) = Gamma(k+1) * U^(1/k)
            let g = self.gamma(k + 1.0);
            let u = self.f64().max(1e-300);
            return g * u.powf(1.0 / k);
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.max(1e-300).ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha) over `dim` categories (symmetric concentration).
    pub fn dirichlet(&mut self, alpha: f64, dim: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..dim).map(|_| self.gamma(alpha).max(1e-30)).collect();
        let s: f64 = g.iter().sum();
        for v in g.iter_mut() {
            *v /= s;
        }
        g
    }

    /// Zipf-distributed rank in `[1, n]` with exponent `s` (rejection-free
    /// inverse-CDF over precomputed weights is overkill here; linear scan is
    /// fine for the small `n` used in workload generation).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        let h: f64 = (1..=n).map(|k| (k as f64).powf(-s)).sum();
        let mut u = self.f64() * h;
        for k in 1..=n {
            u -= (k as f64).powf(-s);
            if u <= 0.0 {
                return k;
            }
        }
        n
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical: zero total weight");
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.index(i + 1);
            v.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.index(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(11);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "count {c}");
        }
    }

    #[test]
    fn range_inclusive_bounds_hit() {
        let mut r = Rng::new(5);
        let mut lo_hit = false;
        let mut hi_hit = false;
        for _ in 0..10_000 {
            match r.range_u64(3, 6) {
                3 => lo_hit = true,
                6 => hi_hit = true,
                4 | 5 => {}
                other => panic!("out of range: {other}"),
            }
        }
        assert!(lo_hit && hi_hit);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(13);
        for &k in &[0.5, 1.0, 2.5, 9.0] {
            let n = 50_000;
            let m = (0..n).map(|_| r.gamma(k)).sum::<f64>() / n as f64;
            assert!((m - k).abs() < 0.1 * k.max(1.0), "k={k} mean={m}");
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(17);
        let p = r.dirichlet(0.5, 10);
        assert_eq!(p.len(), 10);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(p.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // overwhelmingly likely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(23);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 20);
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(29);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0u32; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((2.5..3.5).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn zipf_rank_one_most_common() {
        let mut r = Rng::new(31);
        let mut counts = vec![0u32; 11];
        for _ in 0..20_000 {
            counts[r.zipf(10, 1.2)] += 1;
        }
        assert!(counts[1] > counts[2] && counts[2] > counts[5]);
    }

    #[test]
    fn state_roundtrip_continues_the_exact_stream() {
        let mut a = Rng::new(77);
        for _ in 0..13 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(42);
        let mut a = root.fork();
        let mut b = root.fork();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
