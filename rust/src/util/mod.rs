//! Substrate utilities implemented in-repo (the build environment has no
//! network access, so `rand`, `serde`, `csv`, ... are unavailable).

pub mod csv;
pub mod hash;
pub mod heap;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
