//! Minimal JSON value model, parser, and writer.
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`) and for metrics export. Implements the full JSON
//! grammar (RFC 8259) minus `\u` surrogate-pair edge cases beyond the BMP,
//! which the manifest never uses.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{FedError, Result};

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Serialize to a compact string.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                // `-0.0` would print as "0" through the integer fast path,
                // dropping the sign bit; the store's snapshot round-trips
                // must be value-exact, so spell it out.
                if *x == 0.0 && x.is_sign_negative() {
                    out.push_str("-0.0");
                } else if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ---- typed accessors -------------------------------------------------

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Required object field, typed error otherwise.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| FedError::Artifact(format!("missing JSON key '{key}'")))
    }

    /// As string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// As usize (must be a non-negative integral number).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// As object map.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> FedError {
        FedError::Config(format!("JSON parse error at byte {}: {msg}", self.i))
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5]).unwrap();
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{
            "models": {
                "mlp": {"hlo": "mlp.hlo.txt", "params": [[32, 256], [256]],
                        "batch": 32, "lr": 0.05}
            },
            "version": 1, "ok": true, "none": null,
            "floats": [1.5, -2e3, 0.25]
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.req("version").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)));
        let m = v.req("models").unwrap().req("mlp").unwrap();
        assert_eq!(m.req("hlo").unwrap().as_str(), Some("mlp.hlo.txt"));
        let params = m.req("params").unwrap().as_arr().unwrap();
        assert_eq!(params[0].as_arr().unwrap()[1].as_usize(), Some(256));
        let floats = v.req("floats").unwrap().as_arr().unwrap();
        assert_eq!(floats[1].as_f64(), Some(-2000.0));

        // serialize → parse → equal
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(re, v);
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd\te".to_string());
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    // ---- journal-integrity round-trips ---------------------------------
    //
    // The coordinator store serializes every round through
    // `Json::to_string` and reads it back through `Json::parse`; crash
    // recovery is bit-for-bit only if that composition is the identity for
    // floats and for strings with every escape class.

    #[test]
    fn float_roundtrip_is_bit_exact() {
        let cases = [
            0.0,
            -0.0,
            0.1,
            -0.1,
            1.0 / 3.0,
            2.5,
            -2.5,
            1e-300,
            -1e-300,
            1e300,
            f64::MAX,
            f64::MIN,
            f64::MIN_POSITIVE,
            5e-324, // smallest subnormal
            1e15,   // integer fast-path boundary
            1e15 - 1.0,
            9_007_199_254_740_993.0, // 2^53 + 1 (rounds to 2^53)
            123_456_789.000_001,
            std::f64::consts::PI,
            std::f64::consts::E,
        ];
        for &x in &cases {
            let s = Json::Num(x).to_string();
            let back = Json::parse(&s).unwrap().as_f64().unwrap();
            assert_eq!(
                back.to_bits(),
                x.to_bits(),
                "float {x:?} serialized as {s:?} parsed back as {back:?}"
            );
        }
    }

    #[test]
    fn negative_zero_keeps_its_sign() {
        let s = Json::Num(-0.0).to_string();
        assert_eq!(s, "-0.0");
        let back = Json::parse(&s).unwrap().as_f64().unwrap();
        assert!(back == 0.0 && back.is_sign_negative());
    }

    #[test]
    fn string_escape_roundtrip_is_exact() {
        let cases = [
            "",
            "plain",
            "quote\" backslash\\ slash/ done",
            "newline\n return\r tab\t",
            "backspace\u{8} formfeed\u{c}",
            "low controls \u{0}\u{1}\u{1f}",
            "unicode café εζ 電池 🔋",
            "mixed \"\\\n\t\u{3} café",
            "trailing backslash \\",
            "\\\"", // looks like an escape sequence itself
        ];
        for &orig in &cases {
            let s = Json::Str(orig.to_string()).to_string();
            let back = Json::parse(&s).unwrap();
            assert_eq!(
                back.as_str(),
                Some(orig),
                "string {orig:?} serialized as {s:?}"
            );
            // And serialization is canonical: a second trip is identical.
            assert_eq!(back.to_string(), s);
        }
    }

    #[test]
    fn nested_document_roundtrip_is_canonical() {
        let doc = Json::obj(vec![
            ("z", Json::Num(-0.0)),
            ("a", Json::Arr(vec![Json::Num(0.1), Json::Str("x\ny".into())])),
            ("m", Json::obj(vec![("k", Json::Num(1e300))])),
        ]);
        let s = doc.to_string();
        let re = Json::parse(&s).unwrap();
        assert_eq!(re, doc);
        assert_eq!(re.to_string(), s, "to_string ∘ parse must be stable");
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""café""#).unwrap();
        assert_eq!(v.as_str(), Some("café"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse(r#"{"a":1} x"#).is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("3.25").unwrap().as_f64(), Some(3.25));
        assert_eq!(Json::parse("-7").unwrap().as_f64(), Some(-7.0));
        assert_eq!(Json::parse("1e2").unwrap().as_f64(), Some(100.0));
        assert_eq!(Json::parse("-7").unwrap().as_usize(), None);
    }

    #[test]
    fn nested_empty() {
        let v = Json::parse(r#"{"a":[],"b":{}}"#).unwrap();
        assert_eq!(v.req("a").unwrap().as_arr().unwrap().len(), 0);
        assert_eq!(v.req("b").unwrap().as_obj().unwrap().len(), 0);
    }
}
