//! FNV-1a hashing — the single home of the digest primitive shared by
//! the store's checksums ([`crate::store::fnv64`]), the fleet-instance
//! digest ([`crate::sched::fleet::FleetInstance::digest`]), and the
//! journal's round/campaign digests. One implementation means the
//! journal writer and the replay verifier can never drift apart.

/// FNV-1a offset basis.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a prime.
pub const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Fold raw bytes into a running FNV-1a state.
#[inline]
pub fn fold(h: u64, bytes: &[u8]) -> u64 {
    let mut h = h;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

/// Hash a byte string from the offset basis.
#[inline]
pub fn fnv1a(bytes: &[u8]) -> u64 {
    fold(FNV_OFFSET, bytes)
}

/// Fold one `u64` (little-endian bytes) into a running state.
#[inline]
pub fn mix_u64(h: u64, word: u64) -> u64 {
    fold(h, &word.to_le_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_reference_vectors() {
        // Standard FNV-1a 64-bit vectors.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn mix_u64_equals_folding_le_bytes() {
        let h = fnv1a(b"seed");
        assert_eq!(mix_u64(h, 0xDEAD_BEEF), fold(h, &0xDEAD_BEEFu64.to_le_bytes()));
    }
}
