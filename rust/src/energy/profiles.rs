//! Device archetypes and heterogeneous fleet sampling.
//!
//! Parameter ranges follow the orders of magnitude reported by the
//! measurement literature the paper cites: Kim & Wu's AutoFL device
//! clusters [13] (smartphone SoCs at single-digit watts), Walker et
//! al. [34] (mobile CPU power modeling), Lane et al. [32] (1–3 orders of
//! magnitude spread in per-inference energy across device classes), and
//! Qiu et al. [12] (training-energy spread across FL devices). Absolute
//! values are synthetic; what matters for scheduling behaviour is the
//! *relative heterogeneity*, which these ranges preserve.

use crate::energy::battery::Battery;
use crate::energy::power::{Behavior, PowerModel};
use crate::sched::costs::CostFn;
use crate::sched::instance::Instance;
use crate::util::rng::Rng;

/// A device archetype: a named parameter range.
#[derive(Clone, Debug)]
pub struct Archetype {
    pub name: &'static str,
    /// Busy power range (watts).
    pub busy_w: (f64, f64),
    /// Idle power range (watts).
    pub idle_w: (f64, f64),
    /// Per-mini-batch training latency range (seconds).
    pub batch_latency_s: (f64, f64),
    /// Local dataset size range (number of mini-batches available).
    pub data_batches: (usize, usize),
    /// Battery capacity range (watt-hours); `None` = mains-powered.
    pub battery_wh: Option<(f64, f64)>,
}

/// The built-in archetypes.
pub const ARCHETYPES: [Archetype; 5] = [
    Archetype {
        name: "smartphone-low",
        busy_w: (1.5, 3.0),
        idle_w: (0.05, 0.3),
        batch_latency_s: (0.8, 2.0),
        data_batches: (20, 120),
        battery_wh: Some((8.0, 12.0)),
    },
    Archetype {
        name: "smartphone-high",
        busy_w: (3.0, 6.5),
        idle_w: (0.1, 0.4),
        batch_latency_s: (0.2, 0.7),
        data_batches: (40, 200),
        battery_wh: Some((12.0, 20.0)),
    },
    Archetype {
        name: "edge-board",
        busy_w: (5.0, 15.0),
        idle_w: (1.0, 3.0),
        batch_latency_s: (0.1, 0.4),
        data_batches: (80, 400),
        battery_wh: None,
    },
    Archetype {
        name: "laptop",
        busy_w: (15.0, 45.0),
        idle_w: (2.0, 6.0),
        batch_latency_s: (0.05, 0.2),
        data_batches: (100, 600),
        battery_wh: Some((40.0, 70.0)),
    },
    Archetype {
        name: "cloud-vm",
        busy_w: (60.0, 150.0),
        idle_w: (10.0, 30.0),
        batch_latency_s: (0.01, 0.05),
        data_batches: (500, 2000),
        battery_wh: None,
    },
];

/// One simulated device.
#[derive(Clone, Debug)]
pub struct Device {
    /// Fleet-unique id.
    pub id: usize,
    /// Archetype name.
    pub archetype: &'static str,
    /// Power/energy model.
    pub power: PowerModel,
    /// Number of local mini-batches available (natural upper limit [18]).
    pub data_batches: usize,
    /// Battery, if battery-powered.
    pub battery: Option<Battery>,
    /// Grid region (key into [`crate::energy::carbon`] tables).
    pub region: &'static str,
}

impl Device {
    /// The device's energy cost function (joules for `j` mini-batches).
    pub fn cost_fn(&self) -> CostFn {
        self.power.cost_fn()
    }

    /// Effective per-round upper limit: available data, further capped by
    /// the battery budget if the device is battery-powered.
    pub fn upper_limit(&self) -> usize {
        let data_cap = self.data_batches;
        match &self.battery {
            Some(b) => data_cap.min(b.max_batches(&self.power)),
            None => data_cap,
        }
    }
}

/// A heterogeneous fleet of devices.
#[derive(Clone, Debug)]
pub struct Fleet {
    pub devices: Vec<Device>,
}

/// How behaviours are assigned when sampling a fleet.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BehaviorMix {
    /// Every device gets the given behaviour (the paper's §5 scenarios
    /// require all resources to share one regime).
    Homogeneous(Behavior),
    /// Behaviour drawn per device (produces "arbitrary" instances that only
    /// the DP solves optimally).
    Mixed,
}

impl Fleet {
    /// Sample `n` devices with the given behaviour mix.
    pub fn sample(n: usize, mix: BehaviorMix, rng: &mut Rng) -> Fleet {
        let regions = crate::energy::carbon::REGIONS;
        let devices = (0..n)
            .map(|id| {
                let arch = &ARCHETYPES[rng.index(ARCHETYPES.len())];
                let behavior = match mix {
                    BehaviorMix::Homogeneous(b) => b,
                    BehaviorMix::Mixed => {
                        Behavior::ALL[rng.index(Behavior::ALL.len())]
                    }
                };
                let power = PowerModel {
                    idle_w: rng.range_f64(arch.idle_w.0, arch.idle_w.1),
                    busy_w: rng.range_f64(arch.busy_w.0, arch.busy_w.1),
                    batch_latency_s: rng
                        .range_f64(arch.batch_latency_s.0, arch.batch_latency_s.1),
                    behavior,
                    curvature: rng.range_f64(0.01, 0.15),
                };
                let battery = arch.battery_wh.map(|(lo, hi)| Battery {
                    capacity_wh: rng.range_f64(lo, hi),
                    level: rng.range_f64(0.3, 1.0),
                    round_budget_frac: 0.05,
                });
                Device {
                    id,
                    archetype: arch.name,
                    power,
                    data_batches: rng.range_u64(
                        arch.data_batches.0 as u64,
                        arch.data_batches.1 as u64,
                    ) as usize,
                    battery,
                    region: regions[rng.index(regions.len())].0,
                }
            })
            .collect();
        Fleet { devices }
    }

    /// Number of devices.
    pub fn len(&self) -> usize {
        self.devices.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Build a Minimal Cost FL Schedule instance for this fleet:
    /// `T = tasks`, `L_i = min_tasks` (clamped), `U_i` from data + battery,
    /// `C_i` from the power models.
    ///
    /// If the fleet's total capacity cannot absorb `tasks`, upper limits are
    /// insufficient and the instance would be invalid — callers should size
    /// `tasks` to the fleet (the FL server samples participants until
    /// capacity suffices).
    pub fn instance(&self, tasks: usize, min_tasks: usize) -> crate::error::Result<Instance> {
        let lower: Vec<usize> = self
            .devices
            .iter()
            .map(|d| min_tasks.min(d.upper_limit()))
            .collect();
        let upper: Vec<usize> = self.devices.iter().map(|d| d.upper_limit()).collect();
        let costs: Vec<CostFn> = self.devices.iter().map(|d| d.cost_fn()).collect();
        Instance::new(tasks, lower, upper, costs)
    }

    /// Total capacity `Σ U_i`.
    pub fn capacity(&self) -> usize {
        self.devices.iter().map(|d| d.upper_limit()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::costs::{classify, MarginalRegime};

    #[test]
    fn sample_is_deterministic() {
        let mut a = Rng::new(5);
        let mut b = Rng::new(5);
        let fa = Fleet::sample(10, BehaviorMix::Mixed, &mut a);
        let fb = Fleet::sample(10, BehaviorMix::Mixed, &mut b);
        for (x, y) in fa.devices.iter().zip(&fb.devices) {
            assert_eq!(x.archetype, y.archetype);
            assert!((x.power.busy_w - y.power.busy_w).abs() < 1e-12);
            assert_eq!(x.data_batches, y.data_batches);
        }
    }

    #[test]
    fn parameters_within_archetype_ranges() {
        let mut rng = Rng::new(11);
        let fleet = Fleet::sample(50, BehaviorMix::Mixed, &mut rng);
        for d in &fleet.devices {
            let arch = ARCHETYPES.iter().find(|a| a.name == d.archetype).unwrap();
            assert!(d.power.busy_w >= arch.busy_w.0 && d.power.busy_w <= arch.busy_w.1);
            assert!(
                d.data_batches >= arch.data_batches.0
                    && d.data_batches <= arch.data_batches.1
            );
            assert_eq!(arch.battery_wh.is_some(), d.battery.is_some());
        }
    }

    #[test]
    fn homogeneous_mix_yields_single_regime() {
        let mut rng = Rng::new(3);
        let fleet = Fleet::sample(20, BehaviorMix::Homogeneous(Behavior::Concave), &mut rng);
        for d in &fleet.devices {
            let u = d.upper_limit().max(3);
            assert_eq!(
                classify(&d.cost_fn(), 0, u),
                MarginalRegime::Decreasing,
                "device {}",
                d.id
            );
        }
    }

    #[test]
    fn instance_is_valid_when_capacity_suffices() {
        let mut rng = Rng::new(7);
        let fleet = Fleet::sample(12, BehaviorMix::Homogeneous(Behavior::Linear), &mut rng);
        let t = fleet.capacity() / 2;
        let inst = fleet.instance(t, 1).unwrap();
        inst.validate().unwrap();
        assert_eq!(inst.n(), 12);
    }

    #[test]
    fn instance_rejects_oversized_workload() {
        let mut rng = Rng::new(7);
        let fleet = Fleet::sample(3, BehaviorMix::Homogeneous(Behavior::Linear), &mut rng);
        assert!(fleet.instance(fleet.capacity() + 1, 0).is_err());
    }

    #[test]
    fn battery_caps_upper_limit() {
        let mut rng = Rng::new(13);
        let fleet = Fleet::sample(40, BehaviorMix::Mixed, &mut rng);
        for d in &fleet.devices {
            assert!(d.upper_limit() <= d.data_batches);
        }
    }
}
