//! Synthetic measured-cost tables: the "arbitrary cost function" scenario.
//!
//! Real profilers return noisy per-load energy measurements that need not
//! be monotone (Khaleghzadeh et al. [27], [28] observed non-constant,
//! irregular cost curves on heterogeneous platforms). Since we have no
//! physical testbed, [`noisy_table`] produces such tables: a smooth base
//! curve plus multiplicative log-normal noise and occasional spikes
//! (thermal events, background tasks). [`isotonic`] optionally repairs a
//! table to monotone non-decreasing via the pool-adjacent-violators
//! algorithm (PAVA) — what a profiler post-processing step would do before
//! handing costs to MarIn/MarCo/MarDec.
//!
//! [`carbon_curve`] generates the time axis of the carbon objective: a
//! periodic round-indexed grid-intensity trajectory
//! ([`crate::energy::carbon::CarbonCurve`]) with a diurnal solar dip, so
//! "schedule when the grid is green" scenarios have realistic input.

use crate::energy::carbon::CarbonCurve;
use crate::error::Result;
use crate::sched::costs::CostFn;
use crate::util::rng::Rng;

/// Parameters for synthetic cost-table generation.
#[derive(Clone, Debug)]
pub struct TraceParams {
    /// Base energy per task (joules).
    pub base_per_task: f64,
    /// Base curve exponent (1 = linear, >1 convex, <1 concave).
    pub exponent: f64,
    /// Log-normal noise sigma (0 = clean).
    pub noise_sigma: f64,
    /// Probability of an additive spike at each load.
    pub spike_prob: f64,
    /// Spike magnitude relative to the local base value.
    pub spike_scale: f64,
}

impl Default for TraceParams {
    fn default() -> Self {
        Self {
            base_per_task: 2.0,
            exponent: 1.0,
            noise_sigma: 0.1,
            spike_prob: 0.05,
            spike_scale: 0.5,
        }
    }
}

/// Generate a noisy cost table over loads `0..=max_tasks`
/// (with `cost(0) = 0`).
pub fn noisy_table(max_tasks: usize, p: &TraceParams, rng: &mut Rng) -> Vec<f64> {
    let mut v = Vec::with_capacity(max_tasks + 1);
    v.push(0.0);
    for j in 1..=max_tasks {
        let base = p.base_per_task * (j as f64).powf(p.exponent);
        let noise = rng.lognormal(0.0, p.noise_sigma);
        let spike = if rng.bool(p.spike_prob) {
            base * p.spike_scale * rng.f64()
        } else {
            0.0
        };
        v.push(base * noise + spike);
    }
    v
}

/// Pool-adjacent-violators: least-squares projection onto non-decreasing
/// sequences.
pub fn isotonic(values: &[f64]) -> Vec<f64> {
    // Blocks of (sum, count) merged while out of order.
    let mut sums: Vec<f64> = Vec::with_capacity(values.len());
    let mut counts: Vec<usize> = Vec::with_capacity(values.len());
    for &v in values {
        sums.push(v);
        counts.push(1);
        while sums.len() > 1 {
            let k = sums.len();
            let mean_last = sums[k - 1] / counts[k - 1] as f64;
            let mean_prev = sums[k - 2] / counts[k - 2] as f64;
            if mean_prev <= mean_last {
                break;
            }
            let s = sums.pop().unwrap();
            let c = counts.pop().unwrap();
            *sums.last_mut().unwrap() += s;
            *counts.last_mut().unwrap() += c;
        }
    }
    let mut out = Vec::with_capacity(values.len());
    for (s, c) in sums.iter().zip(&counts) {
        let mean = s / *c as f64;
        for _ in 0..*c {
            out.push(mean);
        }
    }
    out
}

/// Build a [`CostFn::Tabulated`] from a table starting at load 0.
pub fn table_cost(values: Vec<f64>) -> CostFn {
    CostFn::Tabulated { first: 0, values }
}

/// Parameters for synthetic grid-intensity trajectories.
#[derive(Clone, Debug)]
pub struct CarbonCurveParams {
    /// Mean grid intensity, g CO₂e per kWh.
    pub mean_g_per_kwh: f64,
    /// Relative amplitude of the diurnal swing (0 = flat).
    pub swing: f64,
    /// Rounds per diurnal cycle.
    pub period: usize,
    /// Log-normal per-round noise sigma (0 = clean).
    pub noise_sigma: f64,
}

impl Default for CarbonCurveParams {
    fn default() -> Self {
        Self {
            mean_g_per_kwh: 300.0,
            swing: 0.4,
            period: 24,
            noise_sigma: 0.05,
        }
    }
}

/// Generate a `rounds`-long grid-intensity trajectory with a diurnal
/// shape: intensity peaks at the cycle boundaries ("night") and dips to
/// its minimum mid-cycle (the solar window), times multiplicative
/// log-normal noise, floored at 1 g/kWh.
pub fn carbon_curve(
    rounds: usize,
    p: &CarbonCurveParams,
    rng: &mut Rng,
) -> Result<CarbonCurve> {
    let period = p.period.max(1);
    let mut values = Vec::with_capacity(rounds);
    for r in 0..rounds {
        let phase = (r % period) as f64 / period as f64;
        let base = p.mean_g_per_kwh
            * (1.0 + p.swing * (std::f64::consts::TAU * phase).cos());
        let noise = if p.noise_sigma > 0.0 {
            rng.lognormal(0.0, p.noise_sigma)
        } else {
            1.0
        };
        values.push((base * noise).max(1.0));
    }
    CarbonCurve::new(values)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::costs::{classify, MarginalRegime};

    #[test]
    fn noisy_table_shape() {
        let mut rng = Rng::new(1);
        let t = noisy_table(50, &TraceParams::default(), &mut rng);
        assert_eq!(t.len(), 51);
        assert_eq!(t[0], 0.0);
        assert!(t[1..].iter().all(|&x| x > 0.0));
    }

    #[test]
    fn noise_makes_arbitrary_regime() {
        let mut rng = Rng::new(2);
        let p = TraceParams { noise_sigma: 0.4, ..Default::default() };
        let t = noisy_table(60, &p, &mut rng);
        let c = table_cost(t);
        assert_eq!(classify(&c, 0, 60), MarginalRegime::Arbitrary);
    }

    #[test]
    fn isotonic_is_monotone_and_preserves_sorted() {
        let sorted = vec![0.0, 1.0, 2.0, 5.0];
        assert_eq!(isotonic(&sorted), sorted);
        let messy = vec![1.0, 3.0, 2.0, 4.0, 0.0, 6.0];
        let iso = isotonic(&messy);
        for w in iso.windows(2) {
            assert!(w[0] <= w[1] + 1e-12);
        }
        // projection preserves the total (least-squares with equal weights)
        let s1: f64 = messy.iter().sum();
        let s2: f64 = iso.iter().sum();
        assert!((s1 - s2).abs() < 1e-9);
    }

    #[test]
    fn isotonic_repaired_table_has_nonnegative_marginals() {
        let mut rng = Rng::new(3);
        let p = TraceParams { noise_sigma: 0.5, spike_prob: 0.2, ..Default::default() };
        let t = isotonic(&noisy_table(40, &p, &mut rng));
        let c = table_cost(t.clone());
        // Costs are now monotonically increasing (all marginals >= 0) —
        // eq. (6)'s precondition. The marginal *regime* can still be
        // Arbitrary: PAVA makes values monotone, not their differences.
        for j in 1..=40 {
            assert!(c.marginal(j, 0) >= -1e-12);
        }
        for w in t.windows(2) {
            assert!(w[0] <= w[1] + 1e-12);
        }
    }

    #[test]
    fn carbon_curve_shape_and_diurnal_dip() {
        let mut rng = Rng::new(9);
        let p = CarbonCurveParams { noise_sigma: 0.0, ..Default::default() };
        let c = carbon_curve(48, &p, &mut rng).unwrap();
        assert_eq!(c.len(), 48);
        // Clean curve: the minimum sits mid-cycle (the solar window) and
        // the cycle repeats exactly.
        assert_eq!(c.greenest_round(), 12);
        assert!((c.g_per_kwh(0) - c.g_per_kwh(24)).abs() < 1e-9);
        assert!(c.g_per_kwh(12) < c.g_per_kwh(0));
        // swing 0.4 around a 300 mean: peak 420, trough 180.
        assert!((c.g_per_kwh(0) - 420.0).abs() < 1e-9);
        assert!((c.g_per_kwh(12) - 180.0).abs() < 1e-9);
    }

    #[test]
    fn carbon_curve_flat_when_swing_and_noise_are_zero() {
        let mut rng = Rng::new(10);
        let p = CarbonCurveParams {
            swing: 0.0,
            noise_sigma: 0.0,
            mean_g_per_kwh: 250.0,
            ..Default::default()
        };
        let c = carbon_curve(10, &p, &mut rng).unwrap();
        for r in 0..10 {
            assert!((c.g_per_kwh(r) - 250.0).abs() < 1e-9);
        }
        // Zero rounds is rejected by the curve constructor.
        assert!(carbon_curve(0, &p, &mut rng).is_err());
    }

    #[test]
    fn carbon_curve_noise_stays_positive() {
        let mut rng = Rng::new(11);
        let p = CarbonCurveParams { noise_sigma: 0.8, ..Default::default() };
        let c = carbon_curve(200, &p, &mut rng).unwrap();
        for r in 0..200 {
            assert!(c.g_per_kwh(r) >= 1.0);
        }
    }

    #[test]
    fn clean_linear_trace_is_constant_regime() {
        let mut rng = Rng::new(4);
        let p = TraceParams { noise_sigma: 0.0, spike_prob: 0.0, ..Default::default() };
        let t = noisy_table(30, &p, &mut rng);
        assert_eq!(classify(&table_cost(t), 0, 30), MarginalRegime::Constant);
    }
}
