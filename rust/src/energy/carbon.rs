//! Carbon-intensity and electricity-price adapters.
//!
//! The paper (§6, remark I) notes its algorithms minimize *any* cost, not
//! just joules: "emissions of carbon dioxide or equivalents, financial
//! costs, ... requiring only the cost estimates". Qiu et al. [12] showed
//! FL's carbon footprint varies by orders of magnitude with the energy mix
//! of participants' locations — exactly what these per-region weights
//! capture.
//!
//! Intensity values are indicative annual grid averages (g CO₂e per kWh)
//! of the kind published by electricityMap/Ember; prices are indicative
//! household rates (EUR per kWh). Absolute accuracy is irrelevant to the
//! scheduling behaviour — the *relative spread* across regions is what
//! drives the schedules.

use crate::sched::costs::CostFn;

/// `(region, g CO₂e per kWh, EUR per kWh)`.
pub const REGIONS: [(&str, f64, f64); 8] = [
    ("france", 56.0, 0.23),
    ("sweden", 41.0, 0.18),
    ("germany", 380.0, 0.40),
    ("uk", 225.0, 0.34),
    ("us-east", 390.0, 0.16),
    ("china", 550.0, 0.08),
    ("india", 630.0, 0.07),
    ("brazil", 100.0, 0.14),
];

/// Look up a region row.
pub fn region(name: &str) -> Option<(f64, f64)> {
    REGIONS
        .iter()
        .find(|(r, _, _)| *r == name)
        .map(|(_, co2, eur)| (*co2, *eur))
}

/// Grams of CO₂-equivalent per joule for a region.
pub fn co2_g_per_joule(region_name: &str) -> f64 {
    let (g_per_kwh, _) = region(region_name).unwrap_or((400.0, 0.2));
    g_per_kwh / 3.6e6
}

/// EUR per joule for a region.
pub fn eur_per_joule(region_name: &str) -> f64 {
    let (_, eur_per_kwh) = region(region_name).unwrap_or((400.0, 0.2));
    eur_per_kwh / 3.6e6
}

/// Wrap an energy (joules) cost function so its unit becomes g CO₂e.
pub fn carbon_cost(energy_cost: CostFn, region_name: &str) -> CostFn {
    CostFn::Scaled {
        weight: co2_g_per_joule(region_name),
        inner: Box::new(energy_cost),
    }
}

/// Wrap an energy (joules) cost function so its unit becomes EUR.
pub fn monetary_cost(energy_cost: CostFn, region_name: &str) -> CostFn {
    CostFn::Scaled {
        weight: eur_per_joule(region_name),
        inner: Box::new(energy_cost),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::costs::{classify, MarginalRegime};

    #[test]
    fn region_lookup() {
        let (co2, eur) = region("france").unwrap();
        assert_eq!(co2, 56.0);
        assert_eq!(eur, 0.23);
        assert!(region("atlantis").is_none());
    }

    #[test]
    fn per_joule_conversions() {
        // 1 kWh = 3.6e6 J
        assert!((co2_g_per_joule("sweden") * 3.6e6 - 41.0).abs() < 1e-9);
        assert!((eur_per_joule("india") * 3.6e6 - 0.07).abs() < 1e-9);
    }

    #[test]
    fn carbon_wrapping_preserves_regime() {
        let energy = CostFn::Quadratic { fixed: 0.0, a: 0.3, b: 1.0 };
        let carbon = carbon_cost(energy, "germany");
        assert_eq!(classify(&carbon, 0, 20), MarginalRegime::Increasing);
    }

    #[test]
    fn dirty_grid_costs_more() {
        let energy = CostFn::Affine { fixed: 0.0, per_task: 10.0 };
        let india = carbon_cost(energy.clone(), "india");
        let sweden = carbon_cost(energy, "sweden");
        assert!(india.eval(5) > 10.0 * sweden.eval(5));
    }

    #[test]
    fn unknown_region_uses_default() {
        assert!((co2_g_per_joule("atlantis") * 3.6e6 - 400.0).abs() < 1e-9);
    }
}
