//! Carbon-intensity and electricity-price adapters.
//!
//! The paper (§6, remark I) notes its algorithms minimize *any* cost, not
//! just joules: "emissions of carbon dioxide or equivalents, financial
//! costs, ... requiring only the cost estimates". Qiu et al. [12] showed
//! FL's carbon footprint varies by orders of magnitude with the energy mix
//! of participants' locations — exactly what these per-region weights
//! capture.
//!
//! Intensity values are indicative annual grid averages (g CO₂e per kWh)
//! of the kind published by electricityMap/Ember; prices are indicative
//! household rates (EUR per kWh). Absolute accuracy is irrelevant to the
//! scheduling behaviour — the *relative spread* across regions is what
//! drives the schedules.
//!
//! Region lookups are **fallible**: an unknown region name is a
//! configuration error surfaced as [`FedError::Config`], never silently
//! substituted with a default grid (a typo'd `--objective carbon` region
//! must not produce plausible-but-wrong schedules).
//!
//! [`CarbonCurve`] adds the time axis: a periodic `round → g CO₂e/kWh`
//! trajectory so "schedule when the grid is green" is a runnable
//! scenario (see [`crate::energy::tracegen::carbon_curve`] for a
//! generator with a diurnal shape).

use crate::error::{FedError, Result};
use crate::sched::costs::CostFn;

/// `(region, g CO₂e per kWh, EUR per kWh)`.
pub const REGIONS: [(&str, f64, f64); 8] = [
    ("france", 56.0, 0.23),
    ("sweden", 41.0, 0.18),
    ("germany", 380.0, 0.40),
    ("uk", 225.0, 0.34),
    ("us-east", 390.0, 0.16),
    ("china", 550.0, 0.08),
    ("india", 630.0, 0.07),
    ("brazil", 100.0, 0.14),
];

/// The known region names, `|`-joined (for error messages and CLI help).
pub fn region_list() -> String {
    REGIONS
        .iter()
        .map(|(r, _, _)| *r)
        .collect::<Vec<_>>()
        .join("|")
}

/// Look up a region row. Unknown names are a configuration error.
pub fn region(name: &str) -> Result<(f64, f64)> {
    REGIONS
        .iter()
        .find(|(r, _, _)| *r == name)
        .map(|(_, co2, eur)| (*co2, *eur))
        .ok_or_else(|| {
            FedError::Config(format!(
                "unknown grid region '{name}' (valid: {})",
                region_list()
            ))
        })
}

/// Grams of CO₂-equivalent per joule for a region.
pub fn co2_g_per_joule(region_name: &str) -> Result<f64> {
    let (g_per_kwh, _) = region(region_name)?;
    Ok(g_per_kwh / 3.6e6)
}

/// EUR per joule for a region.
pub fn eur_per_joule(region_name: &str) -> Result<f64> {
    let (_, eur_per_kwh) = region(region_name)?;
    Ok(eur_per_kwh / 3.6e6)
}

/// Wrap an energy (joules) cost function so its unit becomes g CO₂e.
pub fn carbon_cost(energy_cost: CostFn, region_name: &str) -> Result<CostFn> {
    Ok(CostFn::Scaled {
        weight: co2_g_per_joule(region_name)?,
        inner: Box::new(energy_cost),
    })
}

/// Wrap an energy (joules) cost function so its unit becomes EUR.
pub fn monetary_cost(energy_cost: CostFn, region_name: &str) -> Result<CostFn> {
    Ok(CostFn::Scaled {
        weight: eur_per_joule(region_name)?,
        inner: Box::new(energy_cost),
    })
}

/// A periodic time-varying carbon intensity: `values[r % len]` is the
/// grid's g CO₂e per kWh at round `r`. The cycle repeats for campaigns
/// longer than the stored trajectory.
#[derive(Clone, Debug, PartialEq)]
pub struct CarbonCurve {
    values: Vec<f64>,
}

impl CarbonCurve {
    /// Build a curve from explicit per-round intensities. Values must be
    /// non-empty, finite, and non-negative.
    pub fn new(values: Vec<f64>) -> Result<Self> {
        if values.is_empty() {
            return Err(FedError::Config("carbon curve must be non-empty".into()));
        }
        if let Some(v) = values.iter().find(|v| !v.is_finite() || **v < 0.0) {
            return Err(FedError::Config(format!(
                "carbon intensity must be finite and >= 0, got {v}"
            )));
        }
        Ok(Self { values })
    }

    /// A constant curve pinned to a region's annual average intensity.
    pub fn flat(region_name: &str) -> Result<Self> {
        let (g_per_kwh, _) = region(region_name)?;
        Self::new(vec![g_per_kwh])
    }

    /// Stored trajectory length (one full cycle).
    pub fn len(&self) -> usize {
        self.values.len()
    }

    /// Whether the curve is empty (never true for a constructed curve).
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Intensity at `round`, in g CO₂e per kWh (cycles past the end).
    pub fn g_per_kwh(&self, round: usize) -> f64 {
        self.values[round % self.values.len()]
    }

    /// Intensity at `round`, in g CO₂e per joule.
    pub fn g_per_joule(&self, round: usize) -> f64 {
        self.g_per_kwh(round) / 3.6e6
    }

    /// Wrap an energy (joules) cost so its unit becomes g CO₂e under the
    /// grid mix at `round`.
    pub fn carbon_cost_at(&self, energy_cost: CostFn, round: usize) -> CostFn {
        CostFn::Scaled {
            weight: self.g_per_joule(round),
            inner: Box::new(energy_cost),
        }
    }

    /// The round (within the first cycle) where the grid is cleanest.
    pub fn greenest_round(&self) -> usize {
        let mut best = 0;
        for (r, v) in self.values.iter().enumerate() {
            if *v < self.values[best] {
                best = r;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::costs::{classify, MarginalRegime};

    #[test]
    fn region_lookup() {
        let (co2, eur) = region("france").unwrap();
        assert_eq!(co2, 56.0);
        assert_eq!(eur, 0.23);
        assert!(region("atlantis").is_err());
    }

    #[test]
    fn per_joule_conversions() {
        // 1 kWh = 3.6e6 J
        assert!((co2_g_per_joule("sweden").unwrap() * 3.6e6 - 41.0).abs() < 1e-9);
        assert!((eur_per_joule("india").unwrap() * 3.6e6 - 0.07).abs() < 1e-9);
    }

    #[test]
    fn carbon_wrapping_preserves_regime() {
        let energy = CostFn::Quadratic { fixed: 0.0, a: 0.3, b: 1.0 };
        let carbon = carbon_cost(energy, "germany").unwrap();
        assert_eq!(classify(&carbon, 0, 20), MarginalRegime::Increasing);
    }

    #[test]
    fn dirty_grid_costs_more() {
        let energy = CostFn::Affine { fixed: 0.0, per_task: 10.0 };
        let india = carbon_cost(energy.clone(), "india").unwrap();
        let sweden = carbon_cost(energy, "sweden").unwrap();
        assert!(india.eval(5) > 10.0 * sweden.eval(5));
    }

    #[test]
    fn unknown_region_is_an_error_listing_valid_names() {
        // Pre-fix, a typo'd region silently fell back to a 400 g/kWh
        // default grid; it must fail loudly and name the alternatives.
        let err = co2_g_per_joule("atlantis").unwrap_err().to_string();
        assert!(err.contains("atlantis"), "{err}");
        assert!(err.contains("france"), "{err}");
        assert!(err.contains("india"), "{err}");
        assert!(eur_per_joule("atlantis").is_err());
        let energy = CostFn::Affine { fixed: 0.0, per_task: 1.0 };
        assert!(carbon_cost(energy.clone(), "atlantis").is_err());
        assert!(monetary_cost(energy, "atlantis").is_err());
    }

    #[test]
    fn curve_cycles_and_converts() {
        let c = CarbonCurve::new(vec![300.0, 100.0, 200.0]).unwrap();
        assert_eq!(c.len(), 3);
        assert!(!c.is_empty());
        assert_eq!(c.g_per_kwh(0), 300.0);
        assert_eq!(c.g_per_kwh(4), 100.0);
        assert!((c.g_per_joule(2) * 3.6e6 - 200.0).abs() < 1e-9);
        assert_eq!(c.greenest_round(), 1);
    }

    #[test]
    fn curve_weighting_tracks_the_grid() {
        let c = CarbonCurve::new(vec![400.0, 50.0]).unwrap();
        let energy = CostFn::Affine { fixed: 0.0, per_task: 10.0 };
        let dirty = c.carbon_cost_at(energy.clone(), 0);
        let green = c.carbon_cost_at(energy, 1);
        assert!(dirty.eval(5) > 7.0 * green.eval(5));
    }

    #[test]
    fn curve_rejects_bad_values() {
        assert!(CarbonCurve::new(vec![]).is_err());
        assert!(CarbonCurve::new(vec![100.0, f64::NAN]).is_err());
        assert!(CarbonCurve::new(vec![-1.0]).is_err());
        assert!(CarbonCurve::flat("atlantis").is_err());
        let flat = CarbonCurve::flat("sweden").unwrap();
        assert_eq!(flat.g_per_kwh(17), 41.0);
    }
}
