//! Battery model: turns remaining charge into a per-round task budget —
//! one concrete source of the paper's upper limits `U_i` (§2.1 notes upper
//! limits arise naturally from device constraints and contracts [18], [19]).

use crate::energy::power::PowerModel;

/// Battery state of a device.
#[derive(Clone, Debug)]
pub struct Battery {
    /// Full capacity in watt-hours.
    pub capacity_wh: f64,
    /// Current state of charge in `[0, 1]`.
    pub level: f64,
    /// Fraction of the *remaining* charge a device is willing to spend on
    /// one training round (participation incentive knob [19]).
    pub round_budget_frac: f64,
}

impl Battery {
    /// Remaining energy in joules.
    pub fn remaining_j(&self) -> f64 {
        self.capacity_wh * 3600.0 * self.level
    }

    /// Energy budget for one round in joules.
    pub fn round_budget_j(&self) -> f64 {
        self.remaining_j() * self.round_budget_frac
    }

    /// Largest `j` with `energy(j) <= round budget` for the given power
    /// model (binary search over the monotone energy curve).
    pub fn max_batches(&self, power: &PowerModel) -> usize {
        let budget = self.round_budget_j();
        if budget <= 0.0 || power.energy_j(1) > budget {
            return 0;
        }
        // Exponential probe then binary search.
        let mut hi = 1usize;
        while power.energy_j(hi * 2) <= budget && hi < 1 << 20 {
            hi *= 2;
        }
        let mut lo = hi; // energy(lo) <= budget
        hi *= 2;
        // invariant: energy(lo) <= budget < energy(hi)
        while hi - lo > 1 {
            let mid = lo + (hi - lo) / 2;
            if power.energy_j(mid) <= budget {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo
    }

    /// Drain the battery by `joules`; clamps at empty.
    pub fn drain(&mut self, joules: f64) {
        let cap_j = self.capacity_wh * 3600.0;
        self.level = ((self.level * cap_j - joules) / cap_j).max(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::power::Behavior;

    fn power(behavior: Behavior) -> PowerModel {
        PowerModel {
            idle_w: 0.1,
            busy_w: 2.0,
            batch_latency_s: 0.5,
            behavior,
            curvature: 0.05,
        }
    }

    fn battery(level: f64) -> Battery {
        Battery { capacity_wh: 10.0, level, round_budget_frac: 0.1 }
    }

    #[test]
    fn remaining_and_budget() {
        let b = battery(0.5);
        assert!((b.remaining_j() - 18_000.0).abs() < 1e-9);
        assert!((b.round_budget_j() - 1_800.0).abs() < 1e-9);
    }

    #[test]
    fn max_batches_is_tight_linear() {
        let b = battery(0.5); // budget 1800 J, 1 J/batch·W → e = 1 J
        let p = power(Behavior::Linear); // 2 W * 0.5 s = 1 J per batch
        let m = b.max_batches(&p);
        assert_eq!(m, 1800);
    }

    #[test]
    fn max_batches_boundary_exact() {
        for behavior in Behavior::ALL {
            let b = battery(0.8);
            let p = power(behavior);
            let m = b.max_batches(&p);
            assert!(p.energy_j(m) <= b.round_budget_j() + 1e-9);
            assert!(p.energy_j(m + 1) > b.round_budget_j());
        }
    }

    #[test]
    fn empty_battery_allows_nothing() {
        let b = battery(0.0);
        assert_eq!(b.max_batches(&power(Behavior::Linear)), 0);
    }

    #[test]
    fn drain_clamps_at_zero() {
        let mut b = battery(0.1);
        b.drain(1e9);
        assert_eq!(b.level, 0.0);
        let mut b2 = battery(1.0);
        b2.drain(3600.0); // 1 Wh out of 10 Wh
        assert!((b2.level - 0.9).abs() < 1e-9);
    }
}
