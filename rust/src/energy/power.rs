//! Per-device power and energy model.
//!
//! A device is characterized by an idle power, a busy power at its training
//! operating point, and a per-mini-batch training latency. Energy for `j`
//! mini-batches is `P_busy · t_batch · j` in the ideal linear case; the
//! marginal-cost *behaviour* knob superimposes the three regimes of the
//! paper's Definition 3:
//!
//! * [`Behavior::Convex`] — sustained load pushes the device into higher
//!   DVFS states / thermal envelopes, so each additional batch costs more
//!   (superlinear energy; cf. the non-constant costs measured by
//!   Khaleghzadeh et al. [28]);
//! * [`Behavior::Linear`] — the constant-cost model most of the FL
//!   literature assumes [16]–[22];
//! * [`Behavior::Concave`] — fixed wake-up/setup energy (radio, model
//!   (de)serialization, cache warm-up) amortizes over more batches
//!   (sublinear energy).

use crate::sched::costs::CostFn;

/// Marginal-cost behaviour of a device's energy curve (paper Def. 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Behavior {
    /// Increasing marginal costs (superlinear energy).
    Convex,
    /// Constant marginal costs (linear energy).
    Linear,
    /// Decreasing marginal costs (sublinear energy).
    Concave,
}

impl Behavior {
    /// All behaviours (for sweeps).
    pub const ALL: [Behavior; 3] = [Behavior::Convex, Behavior::Linear, Behavior::Concave];
}

/// Physical power/latency parameters of one device.
#[derive(Clone, Debug)]
pub struct PowerModel {
    /// Idle power draw in watts (display off, background).
    pub idle_w: f64,
    /// Busy power draw in watts at the training operating point.
    pub busy_w: f64,
    /// Seconds to train on one mini-batch.
    pub batch_latency_s: f64,
    /// Energy behaviour regime.
    pub behavior: Behavior,
    /// Regime strength: curvature of the convex term or exponent gap of the
    /// concave term. 0 degenerates to linear.
    pub curvature: f64,
}

impl PowerModel {
    /// Ideal (linear) energy per mini-batch in joules.
    pub fn joules_per_batch(&self) -> f64 {
        self.busy_w * self.batch_latency_s
    }

    /// Wall-clock time to train `j` batches (seconds). Time stays linear in
    /// `j` — only *energy* exhibits the regime curvature (frequency scaling
    /// trades power for time at second order, which we fold into energy).
    pub fn time_s(&self, j: usize) -> f64 {
        self.batch_latency_s * j as f64
    }

    /// Energy in joules to train `j` mini-batches.
    pub fn energy_j(&self, j: usize) -> f64 {
        let e = self.joules_per_batch();
        let x = j as f64;
        match self.behavior {
            // E(j) = e·j·(1 + κ·j): marginal e·(1 + κ(2j-1)) increases.
            Behavior::Convex => e * x * (1.0 + self.curvature * x),
            Behavior::Linear => e * x,
            // E(j) = e_eff·j^γ with γ = 1/(1+κ) < 1: decreasing marginals.
            // Scaled so E(1) = e (the first batch costs the ideal energy).
            Behavior::Concave => {
                let gamma = 1.0 / (1.0 + self.curvature);
                e * x.powf(gamma)
            }
        }
    }

    /// The scheduler-facing cost function (joules as the cost unit).
    pub fn cost_fn(&self) -> CostFn {
        let e = self.joules_per_batch();
        match self.behavior {
            Behavior::Convex => CostFn::Quadratic {
                fixed: 0.0,
                a: e * self.curvature,
                b: e,
            },
            Behavior::Linear => CostFn::Affine { fixed: 0.0, per_task: e },
            Behavior::Concave => CostFn::PowerLaw {
                fixed: 0.0,
                scale: e,
                exponent: 1.0 / (1.0 + self.curvature),
            },
        }
    }

    /// Idle energy over a window of `secs` seconds (used for round
    /// accounting of non-participating devices).
    pub fn idle_energy_j(&self, secs: f64) -> f64 {
        self.idle_w * secs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::costs::{classify, MarginalRegime};

    fn model(behavior: Behavior) -> PowerModel {
        PowerModel {
            idle_w: 0.5,
            busy_w: 4.0,
            batch_latency_s: 0.25,
            behavior,
            curvature: 0.05,
        }
    }

    #[test]
    fn linear_energy_is_proportional() {
        let m = model(Behavior::Linear);
        assert!((m.energy_j(10) - 10.0 * m.joules_per_batch()).abs() < 1e-12);
        assert_eq!(m.energy_j(0), 0.0);
    }

    #[test]
    fn convex_has_increasing_marginals() {
        let m = model(Behavior::Convex);
        let m1 = m.energy_j(1) - m.energy_j(0);
        let m10 = m.energy_j(10) - m.energy_j(9);
        assert!(m10 > m1);
    }

    #[test]
    fn concave_has_decreasing_marginals_and_matches_first_batch() {
        let m = model(Behavior::Concave);
        let m1 = m.energy_j(1) - m.energy_j(0);
        let m10 = m.energy_j(10) - m.energy_j(9);
        assert!(m10 < m1);
        assert!((m.energy_j(1) - m.joules_per_batch()).abs() < 1e-12);
    }

    #[test]
    fn cost_fn_matches_energy() {
        for b in Behavior::ALL {
            let m = model(b);
            let c = m.cost_fn();
            for j in 0..=20 {
                assert!(
                    (c.eval(j) - m.energy_j(j)).abs() < 1e-9,
                    "{b:?} mismatch at {j}"
                );
            }
        }
    }

    #[test]
    fn cost_fn_regimes_classify_correctly() {
        assert_eq!(
            classify(&model(Behavior::Convex).cost_fn(), 0, 30),
            MarginalRegime::Increasing
        );
        assert_eq!(
            classify(&model(Behavior::Linear).cost_fn(), 0, 30),
            MarginalRegime::Constant
        );
        assert_eq!(
            classify(&model(Behavior::Concave).cost_fn(), 0, 30),
            MarginalRegime::Decreasing
        );
    }

    #[test]
    fn time_is_linear_regardless_of_behavior() {
        for b in Behavior::ALL {
            let m = model(b);
            assert!((m.time_s(8) - 2.0).abs() < 1e-12);
        }
    }

    #[test]
    fn idle_energy() {
        let m = model(Behavior::Linear);
        assert!((m.idle_energy_j(10.0) - 5.0).abs() < 1e-12);
    }
}
