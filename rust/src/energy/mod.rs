//! Device energy simulation: the substrate that synthesizes the cost
//! functions `C_i` the paper's schedulers consume.
//!
//! The paper abstracts devices to black-box cost functions (measured in
//! practice by profilers like I-Prof [35] or frameworks like Flower [36]).
//! We do not have the authors' physical devices, so this module builds the
//! closest synthetic equivalent (see DESIGN.md §2 Substitutions):
//!
//! * [`power`] — per-device power/latency model (idle/busy watts, DVFS
//!   levels) and the three marginal-cost behaviours of paper Def. 3;
//! * [`profiles`] — device archetypes with parameter ranges taken from the
//!   measurement literature the paper cites (Kim & Wu [13], Walker et
//!   al. [34], Qiu et al. [12]), and heterogeneous fleet sampling;
//! * [`carbon`] — carbon-intensity and electricity-price tables turning
//!   energy costs into g CO₂e or currency (paper §6 remark I);
//! * [`battery`] — battery state → per-round upper limits;
//! * [`tracegen`] — noisy tabulated cost tables (the "arbitrary cost"
//!   scenario) and isotonic repair.

pub mod battery;
pub mod carbon;
pub mod power;
pub mod profiles;
pub mod tracegen;

pub use profiles::{Device, Fleet};
