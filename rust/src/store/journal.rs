//! The write-ahead round journal: one fsync'd JSONL line per committed
//! round.
//!
//! Each entry records what the round *did* — the structural digest of the
//! derived [`FleetInstance`] + schedule, the effective solver, the RNG
//! state after the round, and the full metrics row. That is enough to
//!
//! * **recover**: `Coordinator::restore` replays the journal tail from a
//!   snapshot by re-executing rounds and checking every entry, reaching
//!   the exact pre-crash state;
//! * **audit**: `fedzero replay` re-derives the whole campaign from the
//!   initial snapshot and proves (digest-by-digest, RNG-state-by-state)
//!   that the journal is an honest record.
//!
//! Crash tolerance: appends are fsync'd (`sync_data`) per round, and a
//! torn trailing line — the only damage a mid-append crash can cause — is
//! discarded on read.

use std::fs::{File, OpenOptions};
use std::io::Write as _;
use std::path::Path;

use crate::error::{FedError, Result};
use crate::metrics::RoundLog;
use crate::sched::fleet::FleetInstance;
use crate::sched::instance::Schedule;
use crate::store::sink::{row_from_json, row_to_json};
use crate::store::{get, get_str, get_u64, get_usize, ju};
use crate::util::hash::{fold, mix_u64, FNV_OFFSET};
use crate::util::json::Json;

/// Trace solver name recorded for rounds that errored mid-flight.
pub const ABORTED_SOLVER: &str = "!aborted";

/// One committed round.
#[derive(Clone, Debug)]
pub struct JournalEntry {
    /// Round index (journal lines are contiguous from 0).
    pub round: usize,
    /// Effective solver that produced the schedule (`""` for empty
    /// rounds, [`ABORTED_SOLVER`] for rounds that errored).
    pub solver: String,
    /// [`round_digest`] of the derived instance + schedule (0 when no
    /// schedule was produced).
    pub digest: u64,
    /// Coordinator RNG state after the round — the strongest replay
    /// check: equal state means every stochastic decision matched.
    pub rng_after: [u64; 4],
    /// The round's full metrics row (timings included; they are excluded
    /// from digests).
    pub row: RoundLog,
}

impl JournalEntry {
    /// Canonical JSON encoding (key-sorted, value-exact).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("round", Json::Num(self.round as f64)),
            ("solver", Json::Str(self.solver.clone())),
            ("digest", ju(self.digest)),
            (
                "rng",
                Json::Arr(self.rng_after.iter().map(|&w| ju(w)).collect()),
            ),
            ("row", row_to_json(&self.row)),
        ])
    }

    /// Decode [`JournalEntry::to_json`].
    pub fn from_json(v: &Json) -> Result<JournalEntry> {
        let rng_arr = get(v, "rng")?
            .as_arr()
            .ok_or_else(|| FedError::Store("field 'rng' is not an array".into()))?;
        if rng_arr.len() != 4 {
            return Err(FedError::Store("field 'rng' must have 4 words".into()));
        }
        let mut rng_after = [0u64; 4];
        for (i, w) in rng_arr.iter().enumerate() {
            rng_after[i] = crate::store::as_u64(w, "rng")?;
        }
        Ok(JournalEntry {
            round: get_usize(v, "round")?,
            solver: get_str(v, "solver")?.to_string(),
            digest: get_u64(v, "digest")?,
            rng_after,
            row: row_from_json(get(v, "row")?)?,
        })
    }

    /// Fold this entry's *deterministic* content into a digest state
    /// (timings excluded — they are wall-clock noise; NaN losses fold as
    /// one canonical bit pattern).
    fn fold_key(&self, h: u64) -> u64 {
        let mut h = mix_u64(h, self.round as u64);
        h = fold(h, self.solver.as_bytes());
        h = fold(h, &[0]);
        h = mix_u64(h, self.digest);
        for w in self.rng_after {
            h = mix_u64(h, w);
        }
        let loss_bits = if self.row.loss.is_nan() {
            0x7ff8_0000_0000_0000u64
        } else {
            self.row.loss.to_bits()
        };
        h = mix_u64(h, loss_bits);
        h = mix_u64(h, self.row.energy_j.to_bits());
        h = mix_u64(h, self.row.participants as u64);
        mix_u64(h, self.row.tasks as u64)
    }
}

/// Structural digest of one round's scheduling decision: the
/// [`FleetInstance::digest`] mixed with every slot's assigned load.
pub fn round_digest(fleet: &FleetInstance, schedule: &Schedule) -> u64 {
    schedule
        .assignments()
        .iter()
        .fold(fleet.digest(), |h, &x| mix_u64(h, x as u64))
}

/// Deterministic digest of a whole journaled campaign (timings excluded).
/// Two campaigns digest equal iff every round made the same decisions —
/// what the CI recovery-smoke job diffs between a clean and a
/// killed-and-resumed run.
pub fn campaign_digest(entries: &[JournalEntry]) -> u64 {
    entries.iter().fold(FNV_OFFSET, |h, e| e.fold_key(h))
}

/// Appending side of the journal (fsync per entry).
pub struct JournalWriter {
    file: File,
}

impl JournalWriter {
    /// Create/truncate the journal.
    pub fn create(path: &Path) -> Result<Self> {
        Ok(Self { file: File::create(path)? })
    }

    /// Open the journal for appending, first truncating any torn trailing
    /// fragment (crash mid-append) so the next entry starts on a fresh
    /// line — appending after partial bytes would fuse into one
    /// unparseable line and permanently corrupt the journal.
    pub fn open_append(path: &Path) -> Result<Self> {
        if let Ok(text) = std::fs::read_to_string(path) {
            let keep = text.rfind('\n').map(|i| i + 1).unwrap_or(0);
            if keep < text.len() {
                let f = OpenOptions::new().write(true).open(path)?;
                f.set_len(keep as u64)?;
                f.sync_data()?;
            }
        }
        let file = OpenOptions::new().create(true).append(true).open(path)?;
        Ok(Self { file })
    }

    /// Append one entry and fsync — the round's commit point.
    pub fn append(&mut self, entry: &JournalEntry) -> Result<()> {
        let mut line = entry.to_json().to_string();
        line.push('\n');
        self.file.write_all(line.as_bytes())?;
        self.file.sync_data()?;
        Ok(())
    }
}

/// Read a journal back: every complete line in order, rounds checked
/// contiguous from 0. A torn trailing line (crash mid-append) is
/// discarded; torn or corrupt *interior* lines are an error — the journal
/// is the source of truth and silent gaps would forge history.
pub fn read_journal(path: &Path) -> Result<Vec<JournalEntry>> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Err(FedError::Store(format!(
                "no journal at {}",
                path.display()
            )))
        }
        Err(e) => return Err(e.into()),
    };
    let complete = match text.rfind('\n') {
        Some(last) => &text[..=last],
        None => "",
    };
    let mut entries = Vec::new();
    for (i, line) in complete.lines().enumerate() {
        let v = Json::parse(line).map_err(|e| {
            FedError::Store(format!("journal line {}: {e}", i + 1))
        })?;
        let entry = JournalEntry::from_json(&v)
            .map_err(|e| FedError::Store(format!("journal line {}: {e}", i + 1)))?;
        if entry.round != i {
            return Err(FedError::Store(format!(
                "journal line {} carries round {} (expected {})",
                i + 1,
                entry.round,
                i
            )));
        }
        entries.push(entry);
    }
    Ok(entries)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::costs::CostFn;

    fn entry(round: usize) -> JournalEntry {
        JournalEntry {
            round,
            solver: "marin".into(),
            digest: 0xDEAD_BEEF ^ round as u64,
            rng_after: [1, 2, 3, 4 + round as u64],
            row: RoundLog {
                round,
                policy: "auto".into(),
                loss: 0.5 / (round + 1) as f64,
                energy_j: 10.0 + round as f64,
                sched_time_s: 0.001,
                train_time_s: 0.1,
                participants: 4,
                tasks: 32,
            },
        }
    }

    #[test]
    fn entry_json_roundtrip() {
        let e = entry(3);
        let v = Json::parse(&e.to_json().to_string()).unwrap();
        let back = JournalEntry::from_json(&v).unwrap();
        assert_eq!(back.round, e.round);
        assert_eq!(back.solver, e.solver);
        assert_eq!(back.digest, e.digest);
        assert_eq!(back.rng_after, e.rng_after);
        assert_eq!(back.row.energy_j.to_bits(), e.row.energy_j.to_bits());
    }

    #[test]
    fn torn_trailing_line_is_discarded() {
        let dir = std::env::temp_dir().join("fedzero_journal_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("journal.jsonl");
        {
            let mut w = JournalWriter::create(&p).unwrap();
            w.append(&entry(0)).unwrap();
            w.append(&entry(1)).unwrap();
        }
        // Simulate a crash mid-append: half a line, no newline.
        {
            use std::io::Write as _;
            let mut f = OpenOptions::new().append(true).open(&p).unwrap();
            f.write_all(b"{\"round\":2,\"solver\":\"mar").unwrap();
        }
        let entries = read_journal(&p).unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[1].round, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn open_append_truncates_torn_fragment_before_writing() {
        let dir = std::env::temp_dir().join("fedzero_journal_truncate_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("journal.jsonl");
        {
            let mut w = JournalWriter::create(&p).unwrap();
            w.append(&entry(0)).unwrap();
        }
        {
            use std::io::Write as _;
            let mut f = OpenOptions::new().append(true).open(&p).unwrap();
            f.write_all(b"{\"round\":1,\"solv").unwrap();
        }
        // Reopening for append must drop the fragment, so the next entry
        // parses — the resume-after-torn-crash path.
        {
            let mut w = JournalWriter::open_append(&p).unwrap();
            w.append(&entry(1)).unwrap();
        }
        let entries = read_journal(&p).unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[1].round, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn interior_corruption_is_an_error() {
        let dir = std::env::temp_dir().join("fedzero_journal_corrupt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("journal.jsonl");
        std::fs::write(&p, "garbage\n").unwrap();
        assert!(read_journal(&p).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn non_contiguous_rounds_are_an_error() {
        let dir = std::env::temp_dir().join("fedzero_journal_gap_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("journal.jsonl");
        {
            let mut w = JournalWriter::create(&p).unwrap();
            w.append(&entry(0)).unwrap();
            w.append(&entry(2)).unwrap();
        }
        assert!(read_journal(&p).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn campaign_digest_ignores_timings_but_not_decisions() {
        let a = vec![entry(0), entry(1)];
        let mut b = vec![entry(0), entry(1)];
        b[1].row.sched_time_s = 99.0;
        b[1].row.train_time_s = 99.0;
        assert_eq!(campaign_digest(&a), campaign_digest(&b));
        b[1].row.energy_j += 1.0;
        assert_ne!(campaign_digest(&a), campaign_digest(&b));
    }

    #[test]
    fn round_digest_depends_on_schedule_and_fleet() {
        let fleet = FleetInstance::builder()
            .tasks(4)
            .device_class(CostFn::Affine { fixed: 0.0, per_task: 1.0 }, 0, 4, 2)
            .build()
            .unwrap();
        let a = round_digest(&fleet, &Schedule::new(vec![3, 1]));
        let b = round_digest(&fleet, &Schedule::new(vec![1, 3]));
        assert_ne!(a, b);
        assert_eq!(a, round_digest(&fleet, &Schedule::new(vec![3, 1])));
    }
}
