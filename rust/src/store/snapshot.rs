//! Versioned, checksummed snapshots of coordinator state, and the JSON
//! codecs for every piece of that state.
//!
//! A snapshot captures everything that determines the campaign's future:
//! the managed devices (cost functions, batteries, drift), the dynamics
//! state (availability chain, drift scales), the coordinator RNG, the
//! selection pool, the energy ledger, the metrics hub, and the backend's
//! own durable state. Restoring it and replaying the journal tail
//! therefore reproduces the uninterrupted run bit-for-bit — floats
//! round-trip exactly through [`crate::util::json::Json`], `u64`s travel
//! as hex strings, and the whole state is guarded by an FNV checksum so a
//! torn snapshot degrades to "replay more journal", never to silent
//! divergence. The warm-DP row cache is deliberately *not* persisted:
//! warm re-solves are bit-for-bit equal to cold ones, so a restored run
//! merely pays one cold solve.

use std::collections::BTreeMap;

use crate::coordinator::{
    CoordinatorConfig, DeadlineConfig, IncrementalConfig, ManagedDevice, PipelineConfig,
};
use crate::energy::battery::Battery;
use crate::energy::power::{Behavior, PowerModel};
use crate::error::{FedError, Result};
use crate::fl::dynamics::{Availability, CostDrift, DynamicsConfig, Dropout};
use crate::metrics::{EnergyLedger, MetricsHub};
use crate::sched::costs::CostFn;
use crate::store::{
    as_f64, as_u64, fnv64, get, get_arr, get_f64, get_str, get_u64, get_usize,
    jf, ju,
};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Snapshot format version.
pub const VERSION: usize = 1;

/// Wrap a state object with version + checksum (the in-memory form;
/// disk writes go through [`render`], which serializes the state once).
pub fn wrap(state: Json) -> Json {
    let checksum = fnv64(state.to_string().as_bytes());
    Json::obj(vec![
        ("version", Json::Num(VERSION as f64)),
        ("checksum", ju(checksum)),
        ("state", state),
    ])
}

/// Render the on-disk snapshot document, serializing the (potentially
/// large) state subtree exactly once. Byte-identical to
/// `wrap(state).to_string()` — keys in sorted order, canonical number
/// forms — which a unit test pins.
pub fn render(state: &Json) -> String {
    let payload = state.to_string();
    let checksum = fnv64(payload.as_bytes());
    format!("{{\"checksum\":\"{checksum:x}\",\"state\":{payload},\"version\":{VERSION}}}")
}

/// Unwrap a snapshot document, verifying version and checksum. The
/// checksum is recomputed over the canonical re-serialization of the
/// state, which `Json` guarantees is identical to what [`wrap`] hashed.
pub fn unwrap(doc: &Json) -> Result<Json> {
    let version = get_usize(doc, "version")?;
    if version != VERSION {
        return Err(FedError::Store(format!(
            "snapshot version {version} (supported: {VERSION})"
        )));
    }
    let state = get(doc, "state")?;
    let expect = get_u64(doc, "checksum")?;
    let actual = fnv64(state.to_string().as_bytes());
    if actual != expect {
        return Err(FedError::Store(format!(
            "snapshot checksum mismatch ({actual:x} != {expect:x})"
        )));
    }
    Ok(state.clone())
}

// ---- cost functions ----------------------------------------------------

/// Encode a [`CostFn`] (recursively).
pub fn costfn_to_json(c: &CostFn) -> Json {
    match c {
        CostFn::Affine { fixed, per_task } => Json::obj(vec![
            ("fn", Json::Str("affine".into())),
            ("fixed", jf(*fixed)),
            ("per_task", jf(*per_task)),
        ]),
        CostFn::Quadratic { fixed, a, b } => Json::obj(vec![
            ("fn", Json::Str("quadratic".into())),
            ("fixed", jf(*fixed)),
            ("a", jf(*a)),
            ("b", jf(*b)),
        ]),
        CostFn::PowerLaw { fixed, scale, exponent } => Json::obj(vec![
            ("fn", Json::Str("powerlaw".into())),
            ("fixed", jf(*fixed)),
            ("scale", jf(*scale)),
            ("exponent", jf(*exponent)),
        ]),
        CostFn::Logarithmic { fixed, scale } => Json::obj(vec![
            ("fn", Json::Str("logarithmic".into())),
            ("fixed", jf(*fixed)),
            ("scale", jf(*scale)),
        ]),
        CostFn::Tabulated { first, values } => Json::obj(vec![
            ("fn", Json::Str("tabulated".into())),
            ("first", Json::Num(*first as f64)),
            ("values", Json::Arr(values.iter().map(|&v| jf(v)).collect())),
        ]),
        CostFn::Scaled { weight, inner } => Json::obj(vec![
            ("fn", Json::Str("scaled".into())),
            ("weight", jf(*weight)),
            ("inner", costfn_to_json(inner)),
        ]),
        CostFn::Shifted { shift, inner } => Json::obj(vec![
            ("fn", Json::Str("shifted".into())),
            ("shift", Json::Num(*shift as f64)),
            ("inner", costfn_to_json(inner)),
        ]),
    }
}

/// Decode [`costfn_to_json`].
pub fn costfn_from_json(v: &Json) -> Result<CostFn> {
    Ok(match get_str(v, "fn")? {
        "affine" => CostFn::Affine {
            fixed: get_f64(v, "fixed")?,
            per_task: get_f64(v, "per_task")?,
        },
        "quadratic" => CostFn::Quadratic {
            fixed: get_f64(v, "fixed")?,
            a: get_f64(v, "a")?,
            b: get_f64(v, "b")?,
        },
        "powerlaw" => CostFn::PowerLaw {
            fixed: get_f64(v, "fixed")?,
            scale: get_f64(v, "scale")?,
            exponent: get_f64(v, "exponent")?,
        },
        "logarithmic" => CostFn::Logarithmic {
            fixed: get_f64(v, "fixed")?,
            scale: get_f64(v, "scale")?,
        },
        "tabulated" => CostFn::Tabulated {
            first: get_usize(v, "first")?,
            values: get_arr(v, "values")?
                .iter()
                .map(|x| as_f64(x, "values"))
                .collect::<Result<Vec<f64>>>()?,
        },
        "scaled" => CostFn::Scaled {
            weight: get_f64(v, "weight")?,
            inner: Box::new(costfn_from_json(get(v, "inner")?)?),
        },
        "shifted" => CostFn::Shifted {
            shift: get_usize(v, "shift")?,
            inner: Box::new(costfn_from_json(get(v, "inner")?)?),
        },
        other => {
            return Err(FedError::Store(format!("unknown cost fn '{other}'")))
        }
    })
}

// ---- devices -----------------------------------------------------------

fn behavior_to_str(b: Behavior) -> &'static str {
    match b {
        Behavior::Convex => "convex",
        Behavior::Linear => "linear",
        Behavior::Concave => "concave",
    }
}

fn behavior_from_str(s: &str) -> Result<Behavior> {
    Ok(match s {
        "convex" => Behavior::Convex,
        "linear" => Behavior::Linear,
        "concave" => Behavior::Concave,
        other => {
            return Err(FedError::Store(format!("unknown behavior '{other}'")))
        }
    })
}

fn power_to_json(p: &PowerModel) -> Json {
    Json::obj(vec![
        ("idle_w", jf(p.idle_w)),
        ("busy_w", jf(p.busy_w)),
        ("batch_latency_s", jf(p.batch_latency_s)),
        ("behavior", Json::Str(behavior_to_str(p.behavior).into())),
        ("curvature", jf(p.curvature)),
    ])
}

fn power_from_json(v: &Json) -> Result<PowerModel> {
    Ok(PowerModel {
        idle_w: get_f64(v, "idle_w")?,
        busy_w: get_f64(v, "busy_w")?,
        batch_latency_s: get_f64(v, "batch_latency_s")?,
        behavior: behavior_from_str(get_str(v, "behavior")?)?,
        curvature: get_f64(v, "curvature")?,
    })
}

fn battery_to_json(b: &Battery) -> Json {
    Json::obj(vec![
        ("capacity_wh", jf(b.capacity_wh)),
        ("level", jf(b.level)),
        ("round_budget_frac", jf(b.round_budget_frac)),
    ])
}

fn battery_from_json(v: &Json) -> Result<Battery> {
    Ok(Battery {
        capacity_wh: get_f64(v, "capacity_wh")?,
        level: get_f64(v, "level")?,
        round_budget_frac: get_f64(v, "round_budget_frac")?,
    })
}

/// Encode one managed device's full evolving state.
pub fn device_to_json(d: &ManagedDevice) -> Json {
    let battery = match &d.battery {
        Some(b) => battery_to_json(b),
        None => Json::Null,
    };
    let power = match &d.power {
        Some(p) => power_to_json(p),
        None => Json::Null,
    };
    Json::obj(vec![
        ("id", Json::Num(d.id as f64)),
        ("cost", costfn_to_json(&d.cost)),
        ("lower", Json::Num(d.lower as f64)),
        // `usize::MAX` encodes "unlimited": hex keeps it exact.
        ("data_cap", ju(d.data_cap as u64)),
        ("battery", battery),
        ("power", power),
        ("drift", jf(d.drift)),
    ])
}

/// Decode [`device_to_json`].
pub fn device_from_json(v: &Json) -> Result<ManagedDevice> {
    let battery = match get(v, "battery")? {
        Json::Null => None,
        b => Some(battery_from_json(b)?),
    };
    let power = match get(v, "power")? {
        Json::Null => None,
        p => Some(power_from_json(p)?),
    };
    Ok(ManagedDevice {
        id: get_usize(v, "id")?,
        cost: costfn_from_json(get(v, "cost")?)?,
        lower: get_usize(v, "lower")?,
        data_cap: get_u64(v, "data_cap")? as usize,
        battery,
        power,
        drift: get_f64(v, "drift")?,
        // Not persisted: Coordinator::new re-derives it from the decoded
        // config's deadline on restore.
        deadline_cap: usize::MAX,
    })
}

// ---- dynamics ----------------------------------------------------------

/// Encode dynamics state (chain states and drift scales included).
pub fn dynamics_to_json(d: &DynamicsConfig) -> Json {
    let availability = match &d.availability {
        Some(a) => Json::obj(vec![
            ("p_join", jf(a.p_join)),
            ("p_leave", jf(a.p_leave)),
            (
                "online",
                Json::Arr(a.states().iter().map(|&o| Json::Bool(o)).collect()),
            ),
        ]),
        None => Json::Null,
    };
    let drift = match &d.drift {
        Some(c) => Json::obj(vec![
            ("sigma", jf(c.sigma)),
            (
                "scales",
                Json::Arr(c.scales().iter().map(|&s| jf(s)).collect()),
            ),
        ]),
        None => Json::Null,
    };
    let dropout = match &d.dropout {
        Some(x) => Json::obj(vec![("p_fail", jf(x.p_fail))]),
        None => Json::Null,
    };
    Json::obj(vec![
        ("availability", availability),
        ("drift", drift),
        ("dropout", dropout),
    ])
}

/// Decode [`dynamics_to_json`].
pub fn dynamics_from_json(v: &Json) -> Result<DynamicsConfig> {
    let availability = match get(v, "availability")? {
        Json::Null => None,
        a => {
            let online = get_arr(a, "online")?
                .iter()
                .map(|x| match x {
                    Json::Bool(b) => Ok(*b),
                    _ => Err(FedError::Store("'online' must be booleans".into())),
                })
                .collect::<Result<Vec<bool>>>()?;
            Some(Availability::from_states(
                get_f64(a, "p_join")?,
                get_f64(a, "p_leave")?,
                online,
            ))
        }
    };
    let drift = match get(v, "drift")? {
        Json::Null => None,
        c => Some(CostDrift::from_scales(
            get_f64(c, "sigma")?,
            get_arr(c, "scales")?
                .iter()
                .map(|x| as_f64(x, "scales"))
                .collect::<Result<Vec<f64>>>()?,
        )),
    };
    let dropout = match get(v, "dropout")? {
        Json::Null => None,
        x => Some(Dropout { p_fail: get_f64(x, "p_fail")? }),
    };
    Ok(DynamicsConfig { availability, drift, dropout })
}

// ---- coordinator substrate --------------------------------------------

/// Encode the coordinator RNG state.
pub fn rng_to_json(rng: &Rng) -> Json {
    Json::Arr(rng.state().iter().map(|&w| ju(w)).collect())
}

/// Decode [`rng_to_json`].
pub fn rng_from_json(v: &Json) -> Result<Rng> {
    let arr = v
        .as_arr()
        .ok_or_else(|| FedError::Store("rng state must be an array".into()))?;
    if arr.len() != 4 {
        return Err(FedError::Store("rng state must have 4 words".into()));
    }
    let mut s = [0u64; 4];
    for (i, w) in arr.iter().enumerate() {
        s[i] = as_u64(w, "rng")?;
    }
    Ok(Rng::from_state(s))
}

/// Encode the energy ledger (per-device totals + retained round tail).
pub fn ledger_to_json(l: &EnergyLedger) -> Json {
    let per_device: BTreeMap<String, Json> = l
        .per_device_map()
        .iter()
        .map(|(&id, &j)| (id.to_string(), jf(j)))
        .collect();
    Json::obj(vec![
        ("per_device", Json::Obj(per_device)),
        ("rounds", Json::Arr(l.rounds().iter().map(|&j| jf(j)).collect())),
        ("opened", Json::Num(l.rounds_opened() as f64)),
    ])
}

/// Decode [`ledger_to_json`].
pub fn ledger_from_json(v: &Json) -> Result<EnergyLedger> {
    let mut per_device = BTreeMap::new();
    let obj = get(v, "per_device")?
        .as_obj()
        .ok_or_else(|| FedError::Store("'per_device' must be an object".into()))?;
    for (k, val) in obj {
        let id: usize = k
            .parse()
            .map_err(|_| FedError::Store(format!("bad device id '{k}'")))?;
        per_device.insert(id, as_f64(val, "per_device")?);
    }
    let rounds = get_arr(v, "rounds")?
        .iter()
        .map(|x| as_f64(x, "rounds"))
        .collect::<Result<Vec<f64>>>()?;
    Ok(EnergyLedger::from_parts(per_device, rounds, get_usize(v, "opened")?))
}

/// Encode the metrics hub.
pub fn metrics_to_json(m: &MetricsHub) -> Json {
    let counters: BTreeMap<String, Json> = m
        .counters_map()
        .iter()
        .map(|(k, &c)| (k.clone(), ju(c)))
        .collect();
    let gauges: BTreeMap<String, Json> = m
        .gauges_map()
        .iter()
        .map(|(k, &g)| (k.clone(), jf(g)))
        .collect();
    Json::obj(vec![
        ("counters", Json::Obj(counters)),
        ("gauges", Json::Obj(gauges)),
    ])
}

/// Decode [`metrics_to_json`].
pub fn metrics_from_json(v: &Json) -> Result<MetricsHub> {
    let mut m = MetricsHub::new();
    let counters = get(v, "counters")?
        .as_obj()
        .ok_or_else(|| FedError::Store("'counters' must be an object".into()))?;
    for (k, val) in counters {
        m.set_counter(k, as_u64(val, "counters")?);
    }
    let gauges = get(v, "gauges")?
        .as_obj()
        .ok_or_else(|| FedError::Store("'gauges' must be an object".into()))?;
    for (k, val) in gauges {
        m.set(k, as_f64(val, "gauges")?);
    }
    Ok(m)
}

/// Encode a coordinator configuration (store `meta.json`'s `cfg` field).
pub fn cfg_to_json(cfg: &CoordinatorConfig) -> Json {
    let target_loss = match cfg.target_loss {
        Some(t) => jf(t),
        None => Json::Null,
    };
    let mut fields = vec![
        ("rounds", Json::Num(cfg.rounds as f64)),
        ("tasks_per_round", Json::Num(cfg.tasks_per_round as f64)),
        ("algo", Json::Str(cfg.algo.clone())),
        ("participation", jf(cfg.participation)),
        ("min_tasks", Json::Num(cfg.min_tasks as f64)),
        ("max_share", jf(cfg.max_share)),
        ("seed", ju(cfg.seed)),
        ("target_loss", target_loss),
        ("shards", Json::Num(cfg.shards as f64)),
        ("pipeline", Json::Bool(cfg.pipeline.enabled)),
        ("incremental", Json::Bool(cfg.incremental.enabled)),
    ];
    // Only emitted when enabled, so deadline-free stores stay
    // byte-identical to pre-deadline ones.
    if cfg.deadline.enabled {
        fields.push(("deadline_s", jf(cfg.deadline.seconds)));
    }
    Json::obj(fields)
}

/// Decode [`cfg_to_json`].
pub fn cfg_from_json(v: &Json) -> Result<CoordinatorConfig> {
    let target_loss = match get(v, "target_loss")? {
        Json::Null => None,
        t => Some(as_f64(t, "target_loss")?),
    };
    Ok(CoordinatorConfig {
        rounds: get_usize(v, "rounds")?,
        tasks_per_round: get_usize(v, "tasks_per_round")?,
        algo: get_str(v, "algo")?.to_string(),
        participation: get_f64(v, "participation")?,
        min_tasks: get_usize(v, "min_tasks")?,
        max_share: get_f64(v, "max_share")?,
        seed: get_u64(v, "seed")?,
        target_loss,
        // Absent in pre-shard stores: default to the direct build path.
        shards: v.get("shards").and_then(|s| s.as_usize()).unwrap_or(1),
        // Absent in pre-pipeline stores: default to the serial loop.
        pipeline: match v.get("pipeline") {
            Some(Json::Bool(b)) => {
                if *b {
                    PipelineConfig::on()
                } else {
                    PipelineConfig::off()
                }
            }
            _ => PipelineConfig::off(),
        },
        // Absent in pre-incremental stores: default to from-scratch builds.
        incremental: match v.get("incremental") {
            Some(Json::Bool(b)) => {
                if *b {
                    IncrementalConfig::on()
                } else {
                    IncrementalConfig::off()
                }
            }
            _ => IncrementalConfig::off(),
        },
        // Absent (incl. pre-deadline stores): unconstrained rounds.
        deadline: match v.get("deadline_s") {
            Some(s) => DeadlineConfig::on(as_f64(s, "deadline_s")?),
            None => DeadlineConfig::off(),
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(v: &Json) -> Json {
        Json::parse(&v.to_string()).unwrap()
    }

    #[test]
    fn wrap_unwrap_detects_tampering() {
        let state = Json::obj(vec![("x", Json::Num(1.5))]);
        let doc = wrap(state.clone());
        assert_eq!(unwrap(&roundtrip(&doc)).unwrap(), state);
        // Tamper with the state: checksum must catch it.
        let mut text = doc.to_string();
        text = text.replace("1.5", "2.5");
        assert!(unwrap(&Json::parse(&text).unwrap()).is_err());
    }

    #[test]
    fn render_is_byte_identical_to_wrap() {
        let state = Json::obj(vec![
            ("z", Json::Num(-0.0)),
            ("nested", Json::obj(vec![("k", Json::Str("a\"b".into()))])),
            ("arr", Json::Arr(vec![Json::Num(0.1), Json::Null])),
        ]);
        assert_eq!(render(&state), wrap(state.clone()).to_string());
        assert_eq!(unwrap(&Json::parse(&render(&state)).unwrap()).unwrap(), state);
    }

    #[test]
    fn costfn_roundtrips_every_family() {
        let cases = vec![
            CostFn::Affine { fixed: 0.25, per_task: 1.0 / 3.0 },
            CostFn::Quadratic { fixed: 0.0, a: 0.125, b: 2.0 },
            CostFn::PowerLaw { fixed: 1.0, scale: 0.7, exponent: 0.55 },
            CostFn::Logarithmic { fixed: 0.0, scale: 3.3 },
            CostFn::Tabulated { first: 2, values: vec![6.0, 8.0, 9.5] },
            CostFn::Scaled {
                weight: 1.5,
                inner: Box::new(CostFn::Affine { fixed: 0.0, per_task: 2.0 }),
            },
            CostFn::Shifted {
                shift: 3,
                inner: Box::new(CostFn::Logarithmic { fixed: 0.1, scale: 1.0 }),
            },
        ];
        for c in cases {
            let back = costfn_from_json(&roundtrip(&costfn_to_json(&c))).unwrap();
            assert_eq!(back, c);
        }
    }

    #[test]
    fn device_roundtrips_with_and_without_battery() {
        let abstract_dev = ManagedDevice::abstract_resource(
            7,
            CostFn::Affine { fixed: 0.0, per_task: 2.0 },
            1,
            usize::MAX,
        );
        let powered = ManagedDevice {
            id: 3,
            cost: CostFn::Quadratic { fixed: 0.0, a: 0.5, b: 0.1 },
            lower: 0,
            data_cap: 40,
            battery: Some(Battery {
                capacity_wh: 8.5,
                level: 0.62,
                round_budget_frac: 0.1,
            }),
            power: Some(PowerModel {
                idle_w: 0.1,
                busy_w: 2.5,
                batch_latency_s: 0.4,
                behavior: Behavior::Concave,
                curvature: 0.07,
            }),
            drift: 1.31,
            deadline_cap: usize::MAX,
        };
        for d in [abstract_dev, powered] {
            let back = device_from_json(&roundtrip(&device_to_json(&d))).unwrap();
            assert_eq!(back.id, d.id);
            assert_eq!(back.cost, d.cost);
            assert_eq!(back.lower, d.lower);
            assert_eq!(back.data_cap, d.data_cap);
            assert_eq!(back.drift.to_bits(), d.drift.to_bits());
            assert_eq!(back.battery.is_some(), d.battery.is_some());
            if let (Some(a), Some(b)) = (&back.battery, &d.battery) {
                assert_eq!(a.level.to_bits(), b.level.to_bits());
                assert_eq!(a.capacity_wh.to_bits(), b.capacity_wh.to_bits());
            }
            if let (Some(a), Some(b)) = (&back.power, &d.power) {
                assert_eq!(a.behavior, b.behavior);
                assert_eq!(a.busy_w.to_bits(), b.busy_w.to_bits());
            }
        }
    }

    #[test]
    fn dynamics_roundtrips_all_combinations() {
        let mut rng = Rng::new(5);
        let mut full = DynamicsConfig::mobile(6);
        full.availability.as_mut().unwrap().step(&mut rng);
        full.drift.as_mut().unwrap().step(&mut rng);
        for d in [DynamicsConfig::none(), full] {
            let back = dynamics_from_json(&roundtrip(&dynamics_to_json(&d))).unwrap();
            assert_eq!(back.availability.is_some(), d.availability.is_some());
            if let (Some(a), Some(b)) = (&back.availability, &d.availability) {
                assert_eq!(a.states(), b.states());
                assert_eq!(a.p_join.to_bits(), b.p_join.to_bits());
            }
            if let (Some(a), Some(b)) = (&back.drift, &d.drift) {
                assert_eq!(a.scales(), b.scales());
            }
            assert_eq!(back.dropout.is_some(), d.dropout.is_some());
        }
    }

    #[test]
    fn rng_ledger_metrics_cfg_roundtrip() {
        let mut rng = Rng::new(11);
        rng.next_u64();
        let back = rng_from_json(&roundtrip(&rng_to_json(&rng))).unwrap();
        assert_eq!(back.state(), rng.state());

        let mut l = EnergyLedger::new();
        l.begin_round();
        l.record(0, 2.5);
        l.record(9, 0.1);
        let lb = ledger_from_json(&roundtrip(&ledger_to_json(&l))).unwrap();
        assert_eq!(lb.total().to_bits(), l.total().to_bits());
        assert_eq!(lb.rounds(), l.rounds());
        assert_eq!(lb.rounds_opened(), l.rounds_opened());

        let mut m = MetricsHub::new();
        m.inc("rounds", 3);
        m.set("eval_loss", 0.25);
        let mb = metrics_from_json(&roundtrip(&metrics_to_json(&m))).unwrap();
        assert_eq!(mb.counter("rounds"), 3);
        assert_eq!(mb.gauge("eval_loss"), Some(0.25));

        let cfg = CoordinatorConfig {
            rounds: 9,
            tasks_per_round: 33,
            algo: "mardec".into(),
            participation: 0.75,
            min_tasks: 1,
            max_share: 0.5,
            seed: u64::MAX - 3,
            target_loss: Some(0.125),
            shards: 8,
            pipeline: PipelineConfig::on(),
            incremental: IncrementalConfig::on(),
            deadline: DeadlineConfig::on(12.5),
        };
        let cb = cfg_from_json(&roundtrip(&cfg_to_json(&cfg))).unwrap();
        assert_eq!(cb.rounds, cfg.rounds);
        assert_eq!(cb.algo, cfg.algo);
        assert_eq!(cb.seed, cfg.seed);
        assert_eq!(cb.target_loss, cfg.target_loss);
        assert_eq!(cb.participation.to_bits(), cfg.participation.to_bits());
        assert_eq!(cb.shards, 8);
        assert!(cb.pipeline.enabled, "pipeline knob must round-trip");
        assert!(cb.incremental.enabled, "incremental knob must round-trip");
        assert!(cb.deadline.enabled, "deadline knob must round-trip");
        assert_eq!(cb.deadline.seconds.to_bits(), 12.5f64.to_bits());
        // Pre-shard / pre-pipeline / pre-incremental / pre-deadline
        // stores (missing keys) default to the direct build path, the
        // serial loop, from-scratch instance builds, and unconstrained
        // rounds.
        let mut legacy = cfg_to_json(&cfg);
        if let Json::Obj(fields) = &mut legacy {
            fields.remove("shards");
            fields.remove("pipeline");
            fields.remove("incremental");
            fields.remove("deadline_s");
        }
        let lb = cfg_from_json(&roundtrip(&legacy)).unwrap();
        assert_eq!(lb.shards, 1);
        assert!(!lb.pipeline.enabled);
        assert!(!lb.incremental.enabled);
        assert!(!lb.deadline.enabled);
        // A deadline-free config emits no key at all (byte-compatible
        // with pre-deadline stores).
        let off = CoordinatorConfig { deadline: DeadlineConfig::off(), ..cfg };
        assert!(!cfg_to_json(&off).to_string().contains("deadline_s"));
    }
}
