//! The durable coordinator store (L3.5): crash-safe persistence for
//! long-horizon FL campaigns.
//!
//! The paper's schedules only pay off over campaigns that outlive any
//! single process — batteries drain, costs drift, availability churns for
//! thousands of rounds — so coordinator state must survive crashes and
//! memory must not grow with the round count. Production FL coordinators
//! (cf. xaynet) treat state persistence as a first-class service concern;
//! this module is that concern for the [`crate::coordinator`]:
//!
//! * [`journal`] — a write-ahead **round journal** (JSONL, fsync'd per
//!   round): per round the derived [`crate::sched::fleet::FleetInstance`]
//!   + schedule digest, the effective solver, the post-round RNG state,
//!   and the full metrics row;
//! * [`snapshot`] — versioned, checksummed **snapshots** of the full
//!   coordinator state (devices, ledger, metrics, dynamics, RNG, backend)
//!   written every N rounds; `Coordinator::restore` replays the journal
//!   tail from the latest snapshot to reach the exact pre-crash state —
//!   bit-for-bit: the same next-round schedule, energy, and RNG stream as
//!   an uninterrupted run;
//! * [`sink`] — streaming **metric sinks** ([`MetricSink`]: JSONL, CSV,
//!   null) that receive every [`crate::metrics::RoundLog`] row, so the
//!   in-memory [`crate::metrics::TrainingLog`] can be bounded to a ring.
//!
//! [`CampaignStore`] ties the three together under one directory:
//!
//! ```text
//! DIR/
//!   meta.json           campaign configuration (written once)
//!   snapshot.init.json  state before round 0 (replay anchor)
//!   snapshot.json       latest periodic snapshot (atomic replace)
//!   journal.jsonl       one fsync'd line per committed round
//!   rounds.jsonl        streamed metric rows (repaired from the journal)
//! ```

pub mod journal;
pub mod sink;
pub mod snapshot;

use std::path::{Path, PathBuf};

pub use journal::{campaign_digest, round_digest, JournalEntry};
pub use sink::{CsvSink, JsonlSink, MetricSink, NullSink};

use crate::error::{FedError, Result};
use crate::util::json::Json;
use journal::JournalWriter;

/// Campaign configuration, written once at store creation.
pub const META_FILE: &str = "meta.json";
/// State before round 0 — the anchor `replay` verifies from.
pub const INIT_SNAPSHOT_FILE: &str = "snapshot.init.json";
/// Latest periodic snapshot (atomically replaced).
pub const SNAPSHOT_FILE: &str = "snapshot.json";
/// The write-ahead round journal.
pub const JOURNAL_FILE: &str = "journal.jsonl";
/// Streamed per-round metric rows.
pub const ROUNDS_FILE: &str = "rounds.jsonl";

// ---- shared JSON codec helpers ----------------------------------------
//
// The store's round-trips must be *value-exact*. Finite floats round-trip
// exactly through `Json` (shortest-representation printing); the helpers
// below add the two encodings `Json` alone cannot carry: non-finite
// floats (as tagged strings) and full-width `u64`s (as hex strings —
// `f64` only holds 53 bits exactly).

/// FNV-1a over raw bytes — the store's checksum/digest primitive (the
/// shared implementation lives in [`crate::util::hash`]).
pub fn fnv64(bytes: &[u8]) -> u64 {
    crate::util::hash::fnv1a(bytes)
}

/// Encode an `f64`, including non-finite values.
pub fn jf(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else if x.is_nan() {
        Json::Str("NaN".into())
    } else if x > 0.0 {
        Json::Str("inf".into())
    } else {
        Json::Str("-inf".into())
    }
}

/// Encode a `u64` exactly (hex string).
pub fn ju(v: u64) -> Json {
    Json::Str(format!("{v:x}"))
}

/// Typed-error field lookup.
pub fn get<'a>(v: &'a Json, key: &str) -> Result<&'a Json> {
    v.get(key)
        .ok_or_else(|| FedError::Store(format!("missing field '{key}'")))
}

/// Decode [`jf`].
pub fn as_f64(v: &Json, key: &str) -> Result<f64> {
    match v {
        Json::Num(x) => Ok(*x),
        Json::Str(s) if s == "NaN" => Ok(f64::NAN),
        Json::Str(s) if s == "inf" => Ok(f64::INFINITY),
        Json::Str(s) if s == "-inf" => Ok(f64::NEG_INFINITY),
        _ => Err(FedError::Store(format!("field '{key}' is not a number"))),
    }
}

/// Decode [`jf`] from an object field.
pub fn get_f64(v: &Json, key: &str) -> Result<f64> {
    as_f64(get(v, key)?, key)
}

/// Decode [`ju`].
pub fn as_u64(v: &Json, key: &str) -> Result<u64> {
    match v {
        Json::Str(s) => u64::from_str_radix(s, 16)
            .map_err(|_| FedError::Store(format!("field '{key}': bad hex u64"))),
        _ => Err(FedError::Store(format!("field '{key}' is not a hex u64"))),
    }
}

/// Decode [`ju`] from an object field.
pub fn get_u64(v: &Json, key: &str) -> Result<u64> {
    as_u64(get(v, key)?, key)
}

/// Decode a small non-negative integer field.
pub fn get_usize(v: &Json, key: &str) -> Result<usize> {
    get(v, key)?
        .as_usize()
        .ok_or_else(|| FedError::Store(format!("field '{key}' is not a usize")))
}

/// Decode a string field.
pub fn get_str<'a>(v: &'a Json, key: &str) -> Result<&'a str> {
    get(v, key)?
        .as_str()
        .ok_or_else(|| FedError::Store(format!("field '{key}' is not a string")))
}

/// Decode an array field.
pub fn get_arr<'a>(v: &'a Json, key: &str) -> Result<&'a [Json]> {
    get(v, key)?
        .as_arr()
        .ok_or_else(|| FedError::Store(format!("field '{key}' is not an array")))
}

/// Best-effort fsync of a directory, making renames/creations inside it
/// durable (POSIX requires the parent fsync; on platforms where
/// directories cannot be opened, this silently degrades).
pub fn sync_dir(dir: &Path) {
    if let Ok(d) = std::fs::File::open(dir) {
        let _ = d.sync_all();
    }
}

/// Atomically replace `path` with `contents` (tmp + fsync + rename +
/// parent-dir fsync), so a crash mid-write can never leave a torn file
/// behind and the rename itself is durable.
pub fn atomic_write(path: &Path, contents: &str) -> Result<()> {
    use std::io::Write as _;
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(contents.as_bytes())?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    if let Some(parent) = path.parent() {
        sync_dir(parent);
    }
    Ok(())
}

/// Everything read back from a store directory.
#[derive(Clone, Debug)]
pub struct StoreContents {
    /// Campaign configuration ([`META_FILE`]).
    pub meta: Json,
    /// State before round 0 (checksum-verified).
    pub init_snapshot: Json,
    /// Latest valid periodic snapshot state, falling back to the initial
    /// state when [`SNAPSHOT_FILE`] is absent or fails its checksum.
    pub snapshot: Json,
    /// Every committed round, in order (a torn trailing line from a crash
    /// mid-append is discarded).
    pub entries: Vec<JournalEntry>,
}

/// One campaign's durable state under a single directory (see module
/// docs for the layout). Writing is strictly journal-first: a round is
/// *committed* once its journal line is fsync'd; the streamed
/// [`ROUNDS_FILE`] is derived data that [`CampaignStore::resume`] repairs
/// from the journal after a crash.
pub struct CampaignStore {
    dir: PathBuf,
    snapshot_every: usize,
    journal: JournalWriter,
    rounds: JsonlSink,
    committed: usize,
}

impl CampaignStore {
    /// Create a fresh store: write `meta` and the initial snapshot, open
    /// an empty journal. Refuses a directory that already holds a journal
    /// (use [`CampaignStore::resume`]). `meta` may carry a
    /// `snapshot_every` field (default 16) controlling the periodic
    /// snapshot cadence.
    pub fn create(dir: &Path, meta: Json, init_state: Json) -> Result<Self> {
        std::fs::create_dir_all(dir)?;
        let journal_path = dir.join(JOURNAL_FILE);
        if journal_path.exists() {
            return Err(FedError::Store(format!(
                "{} already holds a campaign journal; use `resume`",
                dir.display()
            )));
        }
        let snapshot_every = meta
            .get("snapshot_every")
            .and_then(|v| v.as_usize())
            .unwrap_or(16);
        atomic_write(&dir.join(META_FILE), &meta.to_string())?;
        atomic_write(&dir.join(INIT_SNAPSHOT_FILE), &snapshot::render(&init_state))?;
        let journal = JournalWriter::create(&journal_path)?;
        let rounds = JsonlSink::create(&dir.join(ROUNDS_FILE))?;
        // Make the freshly-created directory entries durable before the
        // first commit can rely on them.
        sync_dir(dir);
        Ok(Self {
            dir: dir.to_path_buf(),
            snapshot_every,
            journal,
            rounds,
            committed: 0,
        })
    }

    /// Read a store without opening it for writing (what `replay` uses).
    pub fn read(dir: &Path) -> Result<StoreContents> {
        let meta = read_json(&dir.join(META_FILE))?;
        let init_snapshot = snapshot::unwrap(&read_json(&dir.join(INIT_SNAPSHOT_FILE))?)?;
        let entries = journal::read_journal(&dir.join(JOURNAL_FILE))?;
        // The periodic snapshot is best-effort: a torn or stale file
        // degrades to replaying more journal, never to an error.
        let snapshot = std::fs::read_to_string(dir.join(SNAPSHOT_FILE))
            .ok()
            .and_then(|text| Json::parse(&text).ok())
            .and_then(|doc| snapshot::unwrap(&doc).ok())
            .filter(|state| {
                state
                    .get("next_round")
                    .and_then(|v| v.as_usize())
                    .map_or(false, |r| r <= entries.len())
            })
            .unwrap_or_else(|| init_snapshot.clone());
        Ok(StoreContents { meta, init_snapshot, snapshot, entries })
    }

    /// Reopen an existing store for continued writing: read everything
    /// back, repair [`ROUNDS_FILE`] against the journal (a crash between
    /// the journal fsync and the row append loses at most the derived
    /// row), and append from the committed count.
    pub fn resume(dir: &Path) -> Result<(Self, StoreContents)> {
        let contents = Self::read(dir)?;
        let snapshot_every = contents
            .meta
            .get("snapshot_every")
            .and_then(|v| v.as_usize())
            .unwrap_or(16);
        repair_rounds(&dir.join(ROUNDS_FILE), &contents.entries)?;
        let journal = JournalWriter::open_append(&dir.join(JOURNAL_FILE))?;
        let rounds = JsonlSink::open_append(&dir.join(ROUNDS_FILE))?;
        let store = Self {
            dir: dir.to_path_buf(),
            snapshot_every,
            journal,
            rounds,
            committed: contents.entries.len(),
        };
        Ok((store, contents))
    }

    /// Commit one round: fsync its journal line, then stream its row.
    pub fn commit(&mut self, entry: &JournalEntry) -> Result<()> {
        if entry.round != self.committed {
            return Err(FedError::Store(format!(
                "journal expects round {}, got {}",
                self.committed, entry.round
            )));
        }
        self.journal.append(entry)?;
        self.committed += 1;
        self.rounds.record(&entry.row)?;
        Ok(())
    }

    /// True when the periodic snapshot cadence is due.
    pub fn due_snapshot(&self) -> bool {
        self.snapshot_every > 0
            && self.committed > 0
            && self.committed % self.snapshot_every == 0
    }

    /// Atomically replace the periodic snapshot.
    pub fn write_snapshot(&mut self, state: Json) -> Result<()> {
        atomic_write(&self.dir.join(SNAPSHOT_FILE), &snapshot::render(&state))
    }

    /// Rounds committed to the journal.
    pub fn committed(&self) -> usize {
        self.committed
    }

    /// The store directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

fn read_json(path: &Path) -> Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| FedError::Store(format!("{}: {e}", path.display())))?;
    Json::parse(&text)
        .map_err(|e| FedError::Store(format!("{}: {e}", path.display())))
}

/// Rebuild [`ROUNDS_FILE`] from the journal when its complete-line count
/// disagrees (crash windows on either side of the journal fsync, or a
/// torn trailing line).
fn repair_rounds(path: &Path, entries: &[JournalEntry]) -> Result<()> {
    let needs_rewrite = match std::fs::read_to_string(path) {
        Ok(text) => {
            let torn = !text.is_empty() && !text.ends_with('\n');
            let complete = text.split('\n').count().saturating_sub(1);
            torn || complete != entries.len()
        }
        Err(_) => true,
    };
    if !needs_rewrite {
        return Ok(());
    }
    let mut sink = JsonlSink::create(path)?;
    for e in entries {
        sink.record(&e.row)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_is_stable_and_sensitive() {
        assert_eq!(fnv64(b""), 0xcbf2_9ce4_8422_2325);
        assert_ne!(fnv64(b"a"), fnv64(b"b"));
        assert_eq!(fnv64(b"round"), fnv64(b"round"));
    }

    #[test]
    fn f64_codec_covers_non_finite() {
        for x in [1.5, -0.0, f64::INFINITY, f64::NEG_INFINITY] {
            let v = Json::obj(vec![("x", jf(x))]);
            let back = get_f64(&Json::parse(&v.to_string()).unwrap(), "x").unwrap();
            assert_eq!(back.to_bits(), x.to_bits());
        }
        let v = Json::obj(vec![("x", jf(f64::NAN))]);
        assert!(get_f64(&Json::parse(&v.to_string()).unwrap(), "x")
            .unwrap()
            .is_nan());
    }

    #[test]
    fn u64_codec_is_full_width() {
        for x in [0u64, 1, u64::MAX, 0x9E37_79B9_7F4A_7C15] {
            let v = Json::obj(vec![("x", ju(x))]);
            let back = get_u64(&Json::parse(&v.to_string()).unwrap(), "x").unwrap();
            assert_eq!(back, x);
        }
    }

    #[test]
    fn atomic_write_replaces() {
        let dir = std::env::temp_dir().join("fedzero_store_atomic_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.json");
        atomic_write(&p, "one").unwrap();
        atomic_write(&p, "two").unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "two");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
