//! Streaming metric sinks: per-round [`RoundLog`] rows leave the process
//! as they happen, so the in-memory [`crate::metrics::TrainingLog`] can be
//! bounded to a ring and campaign memory stops growing with the round
//! count.
//!
//! [`Coordinator`](crate::coordinator::Coordinator) pushes every row
//! (including aborted-round rows) into each attached sink; the
//! [`CampaignStore`](crate::CampaignStore) additionally streams rows into
//! its own `rounds.jsonl` as part of the commit path.

use std::fs::{File, OpenOptions};
use std::io::Write as _;
use std::path::Path;

use crate::error::Result;
use crate::metrics::RoundLog;
use crate::store::{get_f64, get_str, get_usize, jf};
use crate::util::json::Json;

/// A consumer of per-round metric rows.
pub trait MetricSink {
    /// Receive one committed round's row.
    fn record(&mut self, row: &RoundLog) -> Result<()>;

    /// Flush any buffered output (no-op by default).
    fn flush(&mut self) -> Result<()> {
        Ok(())
    }
}

/// Canonical JSON encoding of a row (key-sorted, float-exact; what the
/// JSONL sink and the journal share).
pub fn row_to_json(row: &RoundLog) -> Json {
    Json::obj(vec![
        ("round", Json::Num(row.round as f64)),
        ("policy", Json::Str(row.policy.clone())),
        ("loss", jf(row.loss)),
        ("energy_j", jf(row.energy_j)),
        ("sched_time_s", jf(row.sched_time_s)),
        ("train_time_s", jf(row.train_time_s)),
        ("participants", Json::Num(row.participants as f64)),
        ("tasks", Json::Num(row.tasks as f64)),
    ])
}

/// Decode [`row_to_json`].
pub fn row_from_json(v: &Json) -> Result<RoundLog> {
    Ok(RoundLog {
        round: get_usize(v, "round")?,
        policy: get_str(v, "policy")?.to_string(),
        loss: get_f64(v, "loss")?,
        energy_j: get_f64(v, "energy_j")?,
        sched_time_s: get_f64(v, "sched_time_s")?,
        train_time_s: get_f64(v, "train_time_s")?,
        participants: get_usize(v, "participants")?,
        tasks: get_usize(v, "tasks")?,
    })
}

/// Discards every row — the explicit "stream nowhere" choice for runs
/// that only want the bounded in-memory ring.
#[derive(Clone, Copy, Debug, Default)]
pub struct NullSink;

impl MetricSink for NullSink {
    fn record(&mut self, _row: &RoundLog) -> Result<()> {
        Ok(())
    }
}

/// One JSON object per line, appended per round.
pub struct JsonlSink {
    file: File,
}

impl JsonlSink {
    /// Create/truncate `path` (parent directories included).
    pub fn create(path: &Path) -> Result<Self> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        Ok(Self { file: File::create(path)? })
    }

    /// Open `path` for appending (created if absent).
    pub fn open_append(path: &Path) -> Result<Self> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let file = OpenOptions::new().create(true).append(true).open(path)?;
        Ok(Self { file })
    }
}

impl MetricSink for JsonlSink {
    fn record(&mut self, row: &RoundLog) -> Result<()> {
        let mut line = row_to_json(row).to_string();
        line.push('\n');
        self.file.write_all(line.as_bytes())?;
        Ok(())
    }

    fn flush(&mut self) -> Result<()> {
        self.file.flush()?;
        Ok(())
    }
}

/// RFC-4180-style CSV, one row per round — header and fields come from
/// [`crate::metrics::ROUND_LOG_COLUMNS`] / [`RoundLog::csv_fields`], the
/// same definitions [`crate::metrics::TrainingLog::to_csv`] uses, so the
/// streamed and buffered CSV schemas cannot drift apart.
pub struct CsvSink {
    file: File,
}

impl CsvSink {
    /// Create/truncate `path` and write the header.
    pub fn create(path: &Path) -> Result<Self> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut file = File::create(path)?;
        let mut header = crate::metrics::ROUND_LOG_COLUMNS.join(",");
        header.push('\n');
        file.write_all(header.as_bytes())?;
        Ok(Self { file })
    }
}

impl MetricSink for CsvSink {
    fn record(&mut self, row: &RoundLog) -> Result<()> {
        // Policy names are registry identifiers (no commas/quotes), so no
        // field quoting is needed; assert the assumption instead of
        // silently corrupting the file.
        debug_assert!(!row.policy.contains([',', '"', '\n']));
        let mut line = row.csv_fields().join(",");
        line.push('\n');
        self.file.write_all(line.as_bytes())?;
        Ok(())
    }

    fn flush(&mut self) -> Result<()> {
        self.file.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(round: usize, loss: f64) -> RoundLog {
        RoundLog {
            round,
            policy: "auto".into(),
            loss,
            energy_j: 12.5,
            sched_time_s: 0.001,
            train_time_s: 0.25,
            participants: 3,
            tasks: 16,
        }
    }

    #[test]
    fn row_json_roundtrip_is_exact() {
        for r in [row(0, 0.75), row(7, f64::NAN), row(1, 1.0 / 3.0)] {
            let v = Json::parse(&row_to_json(&r).to_string()).unwrap();
            let back = row_from_json(&v).unwrap();
            assert_eq!(back.round, r.round);
            assert_eq!(back.policy, r.policy);
            assert!(
                back.loss.to_bits() == r.loss.to_bits()
                    || (back.loss.is_nan() && r.loss.is_nan())
            );
            assert_eq!(back.energy_j.to_bits(), r.energy_j.to_bits());
            assert_eq!(back.participants, r.participants);
            assert_eq!(back.tasks, r.tasks);
        }
    }

    #[test]
    fn jsonl_sink_appends_one_line_per_row() {
        let dir = std::env::temp_dir().join("fedzero_sink_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("rounds.jsonl");
        {
            let mut s = JsonlSink::create(&p).unwrap();
            s.record(&row(0, 0.5)).unwrap();
            s.record(&row(1, 0.4)).unwrap();
        }
        {
            let mut s = JsonlSink::open_append(&p).unwrap();
            s.record(&row(2, 0.3)).unwrap();
        }
        let text = std::fs::read_to_string(&p).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        for (i, line) in lines.iter().enumerate() {
            let r = row_from_json(&Json::parse(line).unwrap()).unwrap();
            assert_eq!(r.round, i);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn csv_sink_writes_header_and_rows() {
        let dir = std::env::temp_dir().join("fedzero_csv_sink_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("rounds.csv");
        {
            let mut s = CsvSink::create(&p).unwrap();
            s.record(&row(0, 0.5)).unwrap();
        }
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.starts_with("round,policy,loss"));
        assert!(text.lines().count() == 2);
        assert!(text.contains("auto"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn null_sink_accepts_everything() {
        let mut s = NullSink;
        s.record(&row(0, 0.1)).unwrap();
        s.flush().unwrap();
    }
}
