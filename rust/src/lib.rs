//! # fedzero
//!
//! Energy-minimal workload scheduling for Federated Learning.
//!
//! This crate reproduces the complete system from Lima Pilla (2022),
//! *"Scheduling Algorithms for Federated Learning with Minimal Energy
//! Consumption"*: the Minimal Cost FL Schedule problem, the (MC)²MKP
//! knapsack formulation with its pseudo-polynomial dynamic-programming
//! solution (Algorithm 1), and the four specialized optimal algorithms for
//! monotone marginal-cost scenarios (MarIn, MarCo, MarDecUn, MarDec —
//! Algorithms 2–7), embedded in a full federated-learning coordinator with
//! a simulated heterogeneous device fleet, per-device energy models, and a
//! PJRT runtime that executes AOT-compiled JAX/Pallas training steps.
//!
//! ## Layout
//!
//! * [`sched`] — the paper's contribution: problem model, cost functions,
//!   optimal schedulers, baselines — all reachable through the
//!   [`sched::solver::Solver`] trait and [`sched::solver::SolverRegistry`].
//!   The primary problem type is the class-deduplicated
//!   [`sched::fleet::FleetInstance`] (interchangeable devices collapse
//!   into classes with multiplicities; solvers evaluate costs lazily via
//!   [`sched::fleet::CostView`] and return class-level
//!   [`sched::fleet::Assignment`]s that expand to per-device schedules on
//!   demand); the flat per-device [`sched::instance::Instance`] adapts in
//!   both directions.
//! * [`coordinator`] — the top layer: a state-machine coordinator
//!   (Configuring → Scheduling → Training → Aggregating → Recosting) that
//!   owns the multi-round loop, re-derives each round's instance from
//!   evolving device profiles, warm-starts (MC)²MKP re-solves, and emits
//!   per-round energy/cost metrics. Training plugs in via
//!   [`coordinator::RoundBackend`].
//! * [`store`] — durable campaign state: a write-ahead round journal,
//!   checksummed snapshot/restore (crash recovery is bit-for-bit), and
//!   streaming metric sinks that keep coordinator memory bounded over
//!   long campaigns.
//! * [`obs`] — observability: phase-span tracing ([`obs::Tracer`], with
//!   a Chrome Trace Event JSONL sink and a zero-cost no-op default) and
//!   fixed-bucket log₂ latency histograms ([`obs::hist`]). Pure output:
//!   tracing can never perturb a schedule, journal byte, or digest.
//! * [`svc`] — the networked coordinator service: a transport-agnostic
//!   protocol (rendezvous / heartbeat / fetch-slice / report), a
//!   participant registry with heartbeat expiry and rejoin, and
//!   [`svc::ServiceBackend`] serving each round's run-length schedule
//!   slices over a deterministic loopback transport to simulated client
//!   fleets — partial rounds on missed deadlines, digest-identical to
//!   the in-process reference otherwise.
//! * [`energy`] — device power/energy/carbon models that synthesize the
//!   cost functions consumed by the schedulers.
//! * [`fl`] — federated-learning server (a PJRT-backed coordinator
//!   backend), clients, aggregation, data.
//! * [`runtime`] — PJRT (XLA) execution of AOT-lowered training steps.
//! * [`util`], [`config`], [`cli`], [`metrics`], [`benchkit`], [`testkit`]
//!   — substrates (PRNG, stats, JSON/CSV/TOML, CLI, metrics, benching,
//!   property testing) implemented in-repo because the build environment
//!   is offline.
//!
//! ## Quickstart
//!
//! ```
//! use fedzero::sched::{instance::Instance, mc2mkp, validate};
//!
//! // The worked example from the paper's §3.1 (Figs. 1 and 2).
//! let inst = Instance::paper_example(5);
//! let sched = mc2mkp::solve(&inst).unwrap();
//! assert_eq!(sched.assignments(), &[2, 3, 0]);
//! assert!((validate::total_cost(&inst, &sched) - 7.5).abs() < 1e-9);
//! ```

// Crate hygiene: the determinism guarantees are audited by fedlint
// (rust/tools/fedlint) at the source level; `unsafe` would let code step
// around both the type system and that audit, so it is denied outright.
#![deny(unsafe_code)]
#![warn(rust_2018_idioms)]

pub mod benchkit;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod error;
pub mod fl;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod sched;
pub mod store;
pub mod svc;
pub mod testkit;
pub mod util;

pub use error::{FedError, Result};
pub use store::CampaignStore;
