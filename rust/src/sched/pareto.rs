//! Bi-objective energy/time trade-off: the Pareto front between total
//! energy (`ΣC`, this paper's objective) and round makespan (`max t_i`,
//! OLAR's [26] objective).
//!
//! The paper positions itself against Khaleghzadeh et al. [28], who compute
//! the full time/energy Pareto front in `O(n³T³ log(nT))`. Here we exploit
//! the problem's structure with an **ε-constraint scalarization**: for a
//! candidate makespan cap `τ`, the constraint `time_i(x_i) <= τ` is exactly
//! an upper limit `U_i(τ)` per resource (times are monotone in the number
//! of tasks), so each front point is one Minimal Cost FL Schedule solve —
//! `O(P · T² n)` for `P` distinct candidate makespans, far below the
//! general-case bound.
//!
//! Candidate makespans are the distinct per-resource times `time_i(j)`,
//! `j ∈ [L_i, U_i]` — the makespan of *any* schedule is one of these, so
//! the enumeration is exact, and dominated points are filtered at the end.

use crate::error::Result;
use crate::sched::costs::CostFn;
use crate::sched::instance::{Instance, Schedule};
use crate::sched::{mc2mkp, validate};

/// A bi-objective instance: energy costs (the [`Instance`]) plus a
/// monotone time function per resource.
#[derive(Clone, Debug)]
pub struct BiInstance {
    /// The energy-minimization instance.
    pub energy: Instance,
    /// `time[i].eval(j)` = seconds resource `i` needs for `j` tasks
    /// (monotone non-decreasing in `j`).
    pub time: Vec<CostFn>,
}

/// One point on the Pareto front.
#[derive(Clone, Debug)]
pub struct ParetoPoint {
    pub schedule: Schedule,
    pub energy: f64,
    pub makespan: f64,
}

impl BiInstance {
    /// Makespan of a schedule under this instance's time functions.
    pub fn makespan(&self, sched: &Schedule) -> f64 {
        sched
            .assignments()
            .iter()
            .enumerate()
            .map(|(i, &x)| self.time[i].eval(x))
            .fold(0.0f64, f64::max)
    }

    /// Largest assignment of resource `i` whose time fits within `tau`
    /// (monotone → binary search), clamped to `[L_i, U_i]`. Returns `None`
    /// if even `L_i` tasks exceed `tau`.
    fn cap_for(&self, i: usize, tau: f64) -> Option<usize> {
        let lo = self.energy.lower[i];
        let hi = self.energy.cap(i);
        if self.time[i].eval(lo) > tau {
            return None;
        }
        let (mut lo_ok, mut hi_bad) = (lo, hi + 1);
        while hi_bad - lo_ok > 1 {
            let mid = lo_ok + (hi_bad - lo_ok) / 2;
            if self.time[i].eval(mid) <= tau {
                lo_ok = mid;
            } else {
                hi_bad = mid;
            }
        }
        Some(lo_ok)
    }

    /// Energy-minimal schedule subject to `makespan <= tau`, if feasible.
    pub fn solve_constrained(&self, tau: f64) -> Result<Option<ParetoPoint>> {
        let n = self.energy.n();
        let mut upper = Vec::with_capacity(n);
        for i in 0..n {
            match self.cap_for(i, tau) {
                Some(u) => upper.push(u),
                None => return Ok(None), // lower limit alone busts the cap
            }
        }
        let capped = Instance {
            tasks: self.energy.tasks,
            lower: self.energy.lower.clone(),
            upper,
            costs: self.energy.costs.clone(),
        };
        if capped.validate().is_err() {
            return Ok(None); // not enough capacity under this makespan
        }
        let sched = mc2mkp::solve(&capped)?;
        let energy = validate::total_cost(&self.energy, &sched);
        let makespan = self.makespan(&sched);
        Ok(Some(ParetoPoint { schedule: sched, energy, makespan }))
    }

    /// Compute the energy/makespan Pareto front.
    pub fn pareto_front(&self) -> Result<Vec<ParetoPoint>> {
        // Candidate makespans: all distinct reachable per-resource times.
        let mut candidates: Vec<f64> = Vec::new();
        for i in 0..self.energy.n() {
            for j in self.energy.lower[i]..=self.energy.cap(i) {
                candidates.push(self.time[i].eval(j));
            }
        }
        candidates.sort_by(|a, b| a.partial_cmp(b).unwrap());
        candidates.dedup_by(|a, b| (*a - *b).abs() < 1e-12);

        let mut points: Vec<ParetoPoint> = Vec::new();
        let mut best_energy = f64::INFINITY;
        // Scan caps from tightest to loosest; energy is non-increasing in τ,
        // so a point enters the front iff it strictly improves energy.
        for &tau in candidates.iter() {
            if let Some(p) = self.solve_constrained(tau)? {
                if p.energy < best_energy - 1e-12 {
                    best_energy = p.energy;
                    points.push(p);
                }
            }
        }
        // Filter any residual dominated points (defensive; candidates with
        // equal makespan can slip in out of order).
        let mut front: Vec<ParetoPoint> = Vec::new();
        for p in points {
            front.retain(|q| !(p.makespan <= q.makespan && p.energy <= q.energy));
            if !front
                .iter()
                .any(|q| q.makespan <= p.makespan && q.energy <= p.energy)
            {
                front.push(p);
            }
        }
        front.sort_by(|a, b| a.makespan.partial_cmp(&b.makespan).unwrap());
        Ok(front)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::baselines;
    use crate::util::rng::Rng;

    /// Fleet where fast devices are energy-hungry (a real trade-off).
    fn tradeoff_instance(n: usize, t: usize, seed: u64) -> BiInstance {
        let mut rng = Rng::new(seed);
        let mut costs = Vec::new();
        let mut time = Vec::new();
        for _ in 0..n {
            let speed = rng.range_f64(0.1, 2.0); // s per task
            // faster → more power-hungry (superlinear coupling)
            let energy_per_task = 2.0 / speed * rng.range_f64(0.8, 1.2);
            costs.push(CostFn::Affine { fixed: 0.0, per_task: energy_per_task });
            time.push(CostFn::Affine { fixed: 0.0, per_task: speed });
        }
        let energy = Instance::new(t, vec![0; n], vec![t; n], costs).unwrap();
        BiInstance { energy, time }
    }

    #[test]
    fn front_is_nondominated_and_sorted() {
        let bi = tradeoff_instance(4, 30, 1);
        let front = bi.pareto_front().unwrap();
        assert!(!front.is_empty());
        for w in front.windows(2) {
            assert!(w[0].makespan < w[1].makespan);
            assert!(w[0].energy > w[1].energy, "energy must strictly improve");
        }
        for p in &front {
            validate::check(&bi.energy, &p.schedule).unwrap();
        }
    }

    #[test]
    fn loosest_point_matches_unconstrained_energy_optimum() {
        let bi = tradeoff_instance(4, 30, 2);
        let front = bi.pareto_front().unwrap();
        let unconstrained = mc2mkp::solve(&bi.energy).unwrap();
        let e_opt = validate::total_cost(&bi.energy, &unconstrained);
        let last = front.last().unwrap();
        assert!((last.energy - e_opt).abs() < 1e-9);
    }

    #[test]
    fn tightest_point_at_most_olar_makespan() {
        // OLAR greedily minimizes max cost; with time as the cost it gives
        // a (near-)minimal makespan. The front's tightest point must be at
        // least as good.
        let bi = tradeoff_instance(4, 30, 3);
        let time_inst = Instance {
            tasks: bi.energy.tasks,
            lower: bi.energy.lower.clone(),
            upper: bi.energy.upper.clone(),
            costs: bi.time.clone(),
        };
        let olar = baselines::olar(&time_inst).unwrap();
        let olar_ms = bi.makespan(&olar);
        let front = bi.pareto_front().unwrap();
        assert!(front[0].makespan <= olar_ms + 1e-9);
    }

    #[test]
    fn constrained_solve_respects_cap() {
        let bi = tradeoff_instance(5, 40, 4);
        let front = bi.pareto_front().unwrap();
        let mid = &front[front.len() / 2];
        let p = bi.solve_constrained(mid.makespan).unwrap().unwrap();
        assert!(p.makespan <= mid.makespan + 1e-9);
        assert!((p.energy - mid.energy).abs() < 1e-9);
    }

    #[test]
    fn infeasible_cap_returns_none() {
        let bi = tradeoff_instance(3, 30, 5);
        assert!(bi.solve_constrained(1e-6).unwrap().is_none());
    }

    #[test]
    fn single_resource_front_is_single_point() {
        let bi = tradeoff_instance(1, 10, 6);
        let front = bi.pareto_front().unwrap();
        assert_eq!(front.len(), 1);
        assert_eq!(front[0].schedule.assignments(), &[10]);
    }
}
