//! Bi-objective energy/time trade-off: the Pareto front between total
//! energy (`ΣC`, this paper's objective) and round makespan (`max t_i`,
//! OLAR's [26] objective), at **class granularity**.
//!
//! The paper positions itself against Khaleghzadeh et al. [28], who compute
//! the full time/energy Pareto front in `O(n³T³ log(nT))`. Here we exploit
//! the problem's structure with an **ε-constraint scalarization**: for a
//! candidate makespan cap `τ`, the constraint `time_c(x) <= τ` is exactly
//! an upper limit `U_c(τ)` per device class (times are monotone in the
//! number of tasks), so each front point is one Minimal Cost FL Schedule
//! solve over the capped instance.
//!
//! Everything runs on the class-deduplicated [`FleetInstance`] API:
//!
//! * one [`TimeModel`] per *class* (`k ≪ n` — interchangeable devices
//!   share compute and upload behaviour by definition);
//! * candidate makespans are the distinct per-class times `time_c(j)`,
//!   `j ∈ [L_c, U_c]` — `O(Σ_c (U_c − L_c))` candidates instead of the
//!   flat `O(Σ_i (U_i − L_i))`, and the makespan of *any* schedule is one
//!   of them, so the enumeration stays exact;
//! * [`BiFleet::solve_constrained`] folds the `U_c(τ)` caps through the
//!   shared [`effective_limits`] round seam and dispatches through the
//!   [`SolverRegistry`] — **any** registered solver can solve the
//!   ε-constrained instance, with Table-2 applicability
//!   ([`crate::sched::auto`]) decided on the *capped* instance, whose
//!   regime may differ from the uncapped one.
//!
//! Tightening τ can *fuse* classes (distinct uppers clipped to one cap),
//! so the capped instance is re-deduplicated through the shared
//! [`ClassTable`] probe/insert core — the same code every other build
//! path uses.

use crate::error::{FedError, Result};
use crate::sched::auto::{best_algorithm, classify_fleet};
use crate::sched::costs::CostFn;
use crate::sched::fleet::{Assignment, ClassTable, FleetInstance};
use crate::sched::incremental::{effective_limits, RoundParams};
use crate::sched::instance::{Instance, Schedule};
use crate::sched::solver::SolverRegistry;

/// Default model-upload time per participating device, seconds. Used by
/// the CLI and coordinator when a device's power model provides compute
/// latency but no network profile exists.
pub const DEFAULT_UPLOAD_S: f64 = 2.0;

/// Completion-time model of one device class: seconds to train `j` tasks
/// *and* upload the model update. Monotone non-decreasing in `j`; an
/// idle device (`j = 0`) participates in nothing and takes 0 seconds.
#[derive(Clone, Debug, PartialEq)]
pub struct TimeModel {
    secs: CostFn,
}

impl TimeModel {
    /// Affine time: `upload_s + compute_s_per_task · j` (the classic
    /// compute + communication split of arXiv 2209.14900-style models).
    pub fn affine(compute_s_per_task: f64, upload_s: f64) -> Self {
        Self {
            secs: CostFn::Affine { fixed: upload_s, per_task: compute_s_per_task },
        }
    }

    /// Wrap an arbitrary monotone seconds-per-load function (e.g. a
    /// measured [`CostFn::Tabulated`] latency profile).
    pub fn from_cost(secs: CostFn) -> Self {
        Self { secs }
    }

    /// The underlying seconds-per-load function.
    pub fn cost(&self) -> &CostFn {
        &self.secs
    }

    /// Seconds for `j` tasks. `j = 0` is defined as 0 (the device sits
    /// the round out — no compute, no upload); tabulated profiles are
    /// domain-clamped rather than panicking on probe overshoot.
    pub fn seconds(&self, j: usize) -> f64 {
        if j == 0 {
            0.0
        } else {
            self.secs.eval_clamped(j)
        }
    }

    /// Largest load in `[floor, ceil]` whose time fits within `tau`
    /// (monotone → binary search). `None` if even `floor` tasks exceed
    /// `tau`.
    pub fn max_tasks_within(&self, tau: f64, floor: usize, ceil: usize) -> Option<usize> {
        if self.seconds(floor) > tau {
            return None;
        }
        if self.seconds(ceil) <= tau {
            return Some(ceil);
        }
        // Invariant: time(lo_ok) <= tau < time(hi_bad). Saturating steps
        // keep the unbounded-cap edge (`ceil = usize::MAX`) exact instead
        // of wrapping past it.
        let mut lo_ok = floor;
        let mut hi_bad = ceil;
        while hi_bad.saturating_sub(lo_ok) > 1 {
            let mid = lo_ok.saturating_add(hi_bad.saturating_sub(lo_ok) / 2);
            if self.seconds(mid) <= tau {
                lo_ok = mid;
            } else {
                hi_bad = mid;
            }
        }
        Some(lo_ok)
    }
}

/// One point on the energy/makespan Pareto front.
#[derive(Clone, Debug)]
pub struct ParetoPoint {
    /// Slot-expanded schedule (device order of the underlying fleet).
    pub schedule: Schedule,
    /// Class-level assignment (run-length form, `O(k)` on the wire).
    pub assignment: Assignment,
    /// Total energy `ΣC` of the schedule (the minimized objective).
    pub energy: f64,
    /// Realized makespan `max_i time(x_i)` of the schedule.
    pub makespan: f64,
    /// Effective solver that produced this point.
    pub solver: &'static str,
}

/// A bi-objective instance: a class-deduplicated energy fleet plus one
/// [`TimeModel`] per class.
#[derive(Clone, Debug)]
pub struct BiFleet {
    energy: FleetInstance,
    times: Vec<TimeModel>,
}

impl BiFleet {
    /// Build and validate: one model per class, all times finite,
    /// non-negative, and monotone non-decreasing over the class domain.
    pub fn new(energy: FleetInstance, times: Vec<TimeModel>) -> Result<BiFleet> {
        if times.len() != energy.n_classes() {
            return Err(FedError::InvalidInstance(format!(
                "need one time model per class: {} models for {} classes",
                times.len(),
                energy.n_classes()
            )));
        }
        for (c, class) in energy.classes().iter().enumerate() {
            let hi_c = class.upper.min(energy.tasks);
            let mut prev = 0.0f64;
            for j in class.lower..=hi_c {
                let s = times[c].seconds(j);
                if !s.is_finite() || s < 0.0 {
                    return Err(FedError::InvalidInstance(format!(
                        "class {c}: time({j}) = {s} is not a finite non-negative \
                         number of seconds"
                    )));
                }
                if s < prev {
                    return Err(FedError::InvalidInstance(format!(
                        "class {c}: time({j}) = {s} < time({}) = {prev} — time \
                         models must be monotone non-decreasing",
                        j.saturating_sub(1)
                    )));
                }
                prev = s;
            }
        }
        Ok(Self { energy, times })
    }

    /// Group a flat per-device instance plus per-device time models into
    /// a class-level bi-objective fleet. Devices that share an energy
    /// class must share a time model (structurally equal), or the class
    /// would not actually be interchangeable.
    pub fn from_flat(energy: &Instance, per_device: &[TimeModel]) -> Result<BiFleet> {
        if per_device.len() != energy.n() {
            return Err(FedError::InvalidInstance(format!(
                "need one time model per device: {} models for {} devices",
                per_device.len(),
                energy.n()
            )));
        }
        let fleet = FleetInstance::from_flat(energy)?;
        let mut times = Vec::with_capacity(fleet.n_classes());
        for (c, class) in fleet.classes().iter().enumerate() {
            let first = class.members[0];
            for &s in &class.members {
                if per_device[s] != per_device[first] {
                    return Err(FedError::InvalidInstance(format!(
                        "devices {first} and {s} share energy class {c} but \
                         disagree on time models"
                    )));
                }
            }
            times.push(per_device[first].clone());
        }
        Self::new(fleet, times)
    }

    /// The energy fleet.
    pub fn energy(&self) -> &FleetInstance {
        &self.energy
    }

    /// The per-class time models (index-aligned with
    /// [`FleetInstance::classes`]).
    pub fn times(&self) -> &[TimeModel] {
        &self.times
    }

    /// Makespan of a slot-expanded schedule under the class time models.
    pub fn makespan(&self, sched: &Schedule) -> f64 {
        let mut worst = 0.0f64;
        for (slot, &load) in sched.assignments().iter().enumerate() {
            let c = self.energy.class_of(slot);
            worst = worst.max(self.times[c].seconds(load));
        }
        worst
    }

    /// Candidate makespans: all distinct reachable per-class times
    /// `time_c(j)`, `j ∈ [L_c, min(U_c, T)]`, ascending. The makespan of
    /// any schedule equals one of these, so sweeping them is exact.
    pub fn candidate_makespans(&self) -> Vec<f64> {
        let mut candidates: Vec<f64> = Vec::new();
        for (c, class) in self.energy.classes().iter().enumerate() {
            let hi_c = class.upper.min(self.energy.tasks);
            for j in class.lower..=hi_c {
                candidates.push(self.times[c].seconds(j));
            }
        }
        candidates.sort_by(|a, b| a.partial_cmp(b).unwrap());
        candidates.dedup_by(|a, b| (*a - *b).abs() < 1e-12);
        candidates
    }

    /// The ε-constrained instance for makespan cap `tau`: per-class
    /// `U_c(τ)` caps folded through the shared [`effective_limits`] round
    /// seam, re-deduplicated (capping can fuse classes). `Ok(None)` when
    /// no schedule can meet the cap — a class's lower limit alone busts
    /// it, or the capped fleet cannot absorb `T`.
    pub fn capped_fleet(&self, tau: f64) -> Result<Option<FleetInstance>> {
        let t_req = self.energy.tasks;
        let classes = self.energy.classes();
        let mut class_caps = Vec::with_capacity(classes.len());
        for (c, class) in classes.iter().enumerate() {
            let hi_c = class.upper.min(t_req);
            match self.times[c].max_tasks_within(tau, class.lower, hi_c) {
                Some(u) => class_caps.push(u),
                None => return Ok(None),
            }
        }
        // effective_limits shrinks the workload to fit capacity instead
        // of failing; an ε-constrained solve must treat "can't absorb T
        // under this cap" as infeasible, so pre-check capacity here.
        let mut room = 0usize;
        for (class, &u) in classes.iter().zip(&class_caps) {
            room = room.saturating_add(u.saturating_mul(class.count()));
        }
        if room < t_req.max(1) {
            return Ok(None);
        }

        // Expand to per-slot limits and run the shared round transform
        // (share cap off, no config minimum) — the single home of the
        // capacity/lower math, so the capped instance obeys exactly the
        // invariants every solver already assumes.
        let n = self.energy.n_devices();
        let mut raw_caps = vec![0usize; n];
        let mut intrinsic = vec![0usize; n];
        for (c, class) in classes.iter().enumerate() {
            for &s in &class.members {
                raw_caps[s] = class_caps[c];
                intrinsic[s] = class.lower;
            }
        }
        let p = RoundParams { tasks: t_req, min_tasks: 0, max_share: 1.0 };
        let mut relaxed = false;
        let (t_eff, low_eff, up_eff) =
            effective_limits(&p, &intrinsic, &raw_caps, &mut relaxed);
        debug_assert_eq!(t_eff, t_req, "capacity was pre-checked above");
        debug_assert!(!relaxed, "class caps never fall below class lowers");

        // Re-deduplicate: a tight τ can clip distinct uppers to one cap,
        // fusing formerly-distinct classes. Probe the shared ClassTable
        // in first-occurrence class order (first members ascend, so the
        // canonical order invariant holds) and sort merged member lists.
        let mut table = ClassTable::with_capacity(classes.len());
        for class in classes {
            let first = class.members[0];
            let ci = table.class_index(&class.cost, low_eff[first], up_eff[first]);
            table.classes[ci].members.extend_from_slice(&class.members);
        }
        let mut merged = table.into_classes();
        for class in &mut merged {
            class.members.sort_unstable();
        }
        Ok(Some(FleetInstance::from_classes(t_eff, merged)?))
    }

    /// Energy-minimal schedule subject to `makespan <= tau`, solved by
    /// `algo` resolved through `registry`. `auto` (when not overridden)
    /// picks the Table-2 algorithm for the **capped** instance — capping
    /// restricts domains, so its regime can differ from the uncapped
    /// fleet's. Returns `Ok(None)` when the cap is infeasible.
    pub fn solve_constrained(
        &self,
        registry: &SolverRegistry,
        algo: &str,
        tau: f64,
    ) -> Result<Option<ParetoPoint>> {
        let Some(capped) = self.capped_fleet(tau)? else {
            return Ok(None);
        };
        let canonical = registry.resolve(algo)?.name();
        let effective = if canonical == "auto" && !registry.is_overridden("auto") {
            best_algorithm(&classify_fleet(&capped))
        } else {
            canonical
        };
        let assignment = registry.solve_fleet(effective, &capped)?;
        let schedule = assignment.expand(&capped);
        // Capped classes keep the original cost functions (only limits
        // changed), so the class-level total is the exact energy.
        let energy = assignment.total_cost(&capped);
        let makespan = self.makespan(&schedule);
        Ok(Some(ParetoPoint { schedule, assignment, energy, makespan, solver: effective }))
    }

    /// The energy/makespan Pareto front under `algo`: sweep candidate
    /// makespans tightest → loosest, keep strict energy improvements,
    /// filter residual dominated points, sort by makespan ascending.
    ///
    /// With an optimal solver the result is the exact front; with a
    /// heuristic it is that heuristic's achievable front (still mutually
    /// non-dominated).
    pub fn pareto_front(
        &self,
        registry: &SolverRegistry,
        algo: &str,
    ) -> Result<Vec<ParetoPoint>> {
        let mut points: Vec<ParetoPoint> = Vec::new();
        let mut best_energy = f64::INFINITY;
        // Energy is non-increasing in τ, so a point enters the front iff
        // it strictly improves energy.
        for &tau in self.candidate_makespans().iter() {
            if let Some(p) = self.solve_constrained(registry, algo, tau)? {
                if p.energy < best_energy - 1e-12 {
                    best_energy = p.energy;
                    points.push(p);
                }
            }
        }
        // Filter any residual dominated points (defensive; heuristic
        // solvers need not be monotone in τ).
        let mut front: Vec<ParetoPoint> = Vec::new();
        for p in points {
            front.retain(|q| !(p.makespan <= q.makespan && p.energy <= q.energy));
            if !front
                .iter()
                .any(|q| q.makespan <= p.makespan && q.energy <= p.energy)
            {
                front.push(p);
            }
        }
        front.sort_by(|a, b| a.makespan.partial_cmp(&b.makespan).unwrap());
        Ok(front)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{baselines, mc2mkp, validate};
    use crate::util::rng::Rng;

    /// Fleet where fast devices are energy-hungry (a real trade-off).
    /// Random parameters make every device its own class (`k = n`).
    fn tradeoff(n: usize, t: usize, seed: u64) -> BiFleet {
        let mut rng = Rng::new(seed);
        let mut costs = Vec::new();
        let mut models = Vec::new();
        for _ in 0..n {
            let speed = rng.range_f64(0.1, 2.0); // s per task
            // faster → more power-hungry (superlinear coupling)
            let energy_per_task = 2.0 / speed * rng.range_f64(0.8, 1.2);
            costs.push(CostFn::Affine { fixed: 0.0, per_task: energy_per_task });
            models.push(TimeModel::affine(speed, 0.0));
        }
        let energy = Instance::new(t, vec![0; n], vec![t; n], costs).unwrap();
        BiFleet::from_flat(&energy, &models).unwrap()
    }

    fn registry() -> SolverRegistry {
        SolverRegistry::with_defaults(7)
    }

    #[test]
    fn time_model_seconds_and_binary_search() {
        let tm = TimeModel::affine(0.5, 2.0);
        assert_eq!(tm.seconds(0), 0.0);
        assert!((tm.seconds(1) - 2.5).abs() < 1e-12);
        assert!((tm.seconds(10) - 7.0).abs() < 1e-12);
        // 2 + 0.5j <= 6  ⇔  j <= 8
        assert_eq!(tm.max_tasks_within(6.0, 0, 20), Some(8));
        assert_eq!(tm.max_tasks_within(6.0, 0, 5), Some(5));
        assert_eq!(tm.max_tasks_within(f64::INFINITY, 0, 20), Some(20));
        // floor = 3 needs 3.5 s: a 3 s cap is infeasible, 0 tasks is not
        // an option below the floor.
        assert_eq!(tm.max_tasks_within(3.0, 3, 20), None);
        // j = 0 is free, so a zero cap still admits sitting out.
        assert_eq!(tm.max_tasks_within(0.0, 0, 20), Some(0));
        // Saturating domain edge.
        assert_eq!(
            tm.max_tasks_within(f64::INFINITY, 0, usize::MAX),
            Some(usize::MAX)
        );
    }

    #[test]
    fn time_model_tabulated_is_domain_clamped() {
        let tm = TimeModel::from_cost(CostFn::from_table(&[
            (0, 0.0),
            (1, 1.0),
            (2, 4.0),
        ]));
        assert_eq!(tm.seconds(2), 4.0);
        // Probes past the table clamp to the last entry, so the binary
        // search over a larger ceiling cannot panic.
        assert_eq!(tm.seconds(50), 4.0);
        assert_eq!(tm.max_tasks_within(3.9, 0, 10), Some(1));
    }

    #[test]
    fn bifleet_rejects_mismatched_and_nonmonotone_models() {
        let energy = Instance::new(
            6,
            vec![0, 0],
            vec![6, 6],
            vec![
                CostFn::Affine { fixed: 0.0, per_task: 1.0 },
                CostFn::Affine { fixed: 0.0, per_task: 1.0 },
            ],
        )
        .unwrap();
        // One class, two disagreeing device models → rejected.
        let disagree =
            vec![TimeModel::affine(1.0, 0.0), TimeModel::affine(2.0, 0.0)];
        assert!(BiFleet::from_flat(&energy, &disagree).is_err());
        // Non-monotone tabulated time → rejected.
        let fleet = FleetInstance::from_flat(&energy).unwrap();
        let shrinking = TimeModel::from_cost(CostFn::from_table(&[
            (0, 0.0),
            (1, 5.0),
            (2, 1.0),
            (3, 1.5),
            (4, 2.0),
            (5, 2.5),
            (6, 3.0),
        ]));
        assert!(BiFleet::new(fleet.clone(), vec![shrinking]).is_err());
        // Wrong arity → rejected.
        assert!(BiFleet::new(fleet, vec![]).is_err());
    }

    #[test]
    fn front_is_nondominated_and_sorted() {
        let bi = tradeoff(4, 30, 1);
        let flat = bi.energy().to_flat();
        let front = bi.pareto_front(&registry(), "mc2mkp").unwrap();
        assert!(!front.is_empty());
        for w in front.windows(2) {
            assert!(w[0].makespan < w[1].makespan);
            assert!(w[0].energy > w[1].energy, "energy must strictly improve");
        }
        for p in &front {
            validate::check(&flat, &p.schedule).unwrap();
            assert_eq!(p.solver, "mc2mkp");
        }
    }

    #[test]
    fn loosest_point_matches_unconstrained_energy_optimum() {
        let bi = tradeoff(4, 30, 2);
        let reg = registry();
        let front = bi.pareto_front(&reg, "mc2mkp").unwrap();
        let unconstrained = mc2mkp::solve(&bi.energy().to_flat()).unwrap();
        let e_opt = validate::total_cost(&bi.energy().to_flat(), &unconstrained);
        let last = front.last().unwrap();
        assert!((last.energy - e_opt).abs() < 1e-9);
        // Bit-for-bit: the loosest front point is the τ = ∞ solve through
        // the identical pipeline.
        let inf = bi
            .solve_constrained(&reg, "mc2mkp", f64::INFINITY)
            .unwrap()
            .unwrap();
        assert_eq!(last.energy.to_bits(), inf.energy.to_bits());
        assert_eq!(last.schedule, inf.schedule);
    }

    #[test]
    fn tightest_point_at_most_olar_makespan() {
        // OLAR greedily minimizes max cost; with time as the cost it gives
        // a (near-)minimal makespan. The front's tightest point must be at
        // least as good.
        let bi = tradeoff(4, 30, 3);
        let flat = bi.energy().to_flat();
        let time_costs: Vec<CostFn> = (0..flat.n())
            .map(|i| bi.times()[bi.energy().class_of(i)].cost().clone())
            .collect();
        let time_inst = Instance {
            tasks: flat.tasks,
            lower: flat.lower.clone(),
            upper: flat.upper.clone(),
            costs: time_costs,
        };
        let olar = baselines::olar(&time_inst).unwrap();
        let olar_ms = bi.makespan(&olar);
        let front = bi.pareto_front(&registry(), "mc2mkp").unwrap();
        assert!(front[0].makespan <= olar_ms + 1e-9);
    }

    #[test]
    fn constrained_solve_respects_cap() {
        let bi = tradeoff(5, 40, 4);
        let reg = registry();
        let front = bi.pareto_front(&reg, "mc2mkp").unwrap();
        let mid = &front[front.len() / 2];
        let p = bi
            .solve_constrained(&reg, "mc2mkp", mid.makespan)
            .unwrap()
            .unwrap();
        assert!(p.makespan <= mid.makespan + 1e-9);
        assert!((p.energy - mid.energy).abs() < 1e-9);
    }

    #[test]
    fn infeasible_cap_returns_none() {
        let bi = tradeoff(3, 30, 5);
        let reg = registry();
        assert!(bi.solve_constrained(&reg, "mc2mkp", 1e-6).unwrap().is_none());
        // A lower limit that alone busts the cap is infeasible too.
        let energy = Instance::new(
            6,
            vec![3, 0],
            vec![6, 6],
            vec![
                CostFn::Affine { fixed: 0.0, per_task: 1.0 },
                CostFn::Affine { fixed: 0.0, per_task: 2.0 },
            ],
        )
        .unwrap();
        let models = vec![TimeModel::affine(1.0, 0.0), TimeModel::affine(1.0, 0.0)];
        let floored = BiFleet::from_flat(&energy, &models).unwrap();
        assert!(floored.solve_constrained(&reg, "auto", 2.0).unwrap().is_none());
        assert!(floored.solve_constrained(&reg, "auto", 4.0).unwrap().is_some());
    }

    #[test]
    fn single_resource_front_is_single_point() {
        let bi = tradeoff(1, 10, 6);
        let front = bi.pareto_front(&registry(), "mc2mkp").unwrap();
        assert_eq!(front.len(), 1);
        assert_eq!(front[0].schedule.assignments(), &[10]);
    }

    #[test]
    fn tight_cap_fuses_classes_through_the_shared_dedup() {
        // Two classes, same cost/lower, uppers 10 vs 8: a τ that clips
        // both to 6 fuses them into one class through ClassTable — and
        // the fused instance still expands to a valid schedule.
        let cost = CostFn::Affine { fixed: 0.0, per_task: 1.0 };
        let energy = Instance::new(
            20,
            vec![0; 4],
            vec![10, 10, 8, 8],
            vec![cost.clone(), cost.clone(), cost.clone(), cost],
        )
        .unwrap();
        let models = vec![TimeModel::affine(1.0, 0.0); 4];
        let bi = BiFleet::from_flat(&energy, &models).unwrap();
        assert_eq!(bi.energy().n_classes(), 2);
        let capped = bi.capped_fleet(6.0).unwrap().unwrap();
        assert_eq!(capped.n_classes(), 1, "equal caps must fuse the classes");
        assert_eq!(capped.classes()[0].upper, 6);
        assert_eq!(capped.classes()[0].members, vec![0, 1, 2, 3]);
        let p = bi.solve_constrained(&registry(), "auto", 6.0).unwrap().unwrap();
        validate::check(&energy, &p.schedule).unwrap();
        assert!(p.makespan <= 6.0 + 1e-9);
        assert_eq!(p.schedule.assignments().iter().sum::<usize>(), 20);
    }

    #[test]
    fn any_registered_solver_solves_the_capped_instance() {
        // The ε-constrained instance goes through the registry, so
        // heuristics work too: schedules stay feasible and within τ.
        let bi = tradeoff(4, 24, 8);
        let flat = bi.energy().to_flat();
        let reg = registry();
        let tau = bi.candidate_makespans()[12];
        for name in ["uniform", "greedy", "olar", "proportional", "auto"] {
            let p = bi
                .solve_constrained(&reg, name, tau)
                .unwrap()
                .unwrap_or_else(|| panic!("{name} found τ = {tau} infeasible"));
            validate::check(&flat, &p.schedule)
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(p.makespan <= tau + 1e-9, "{name} broke the cap");
        }
        // auto records the dispatched algorithm, not "auto" itself.
        let p = bi.solve_constrained(&reg, "auto", tau).unwrap().unwrap();
        assert_ne!(p.solver, "auto");
    }
}
