//! The `Solver` trait and `SolverRegistry`: every scheduler in the crate —
//! the paper's seven optimal algorithms (Algorithms 1–7: the (MC)²MKP DP,
//! MarIn, MarCo, MarDecUn, and the three MarDec procedures behind
//! [`mardec::solve`]) plus the five baselines and the brute-force oracle —
//! is reachable through one seam.
//!
//! The registry replaces the old `Policy`-enum `match` dispatch: callers
//! resolve a solver by name (`registry.resolve("mardec")`), ask the
//! Table 2 question (`solver.is_optimal_for(&scenario)`), or let the
//! `auto` solver classify-and-dispatch. New solvers (and external
//! backends) register without touching any call site.

use std::cell::RefCell;

use crate::error::{FedError, Result};
use crate::sched::auto::{best_algorithm, classify_instance, Scenario};
use crate::sched::costs::MarginalRegime;
use crate::sched::instance::{Instance, Schedule};
use crate::sched::{baselines, bruteforce, marco, mardec, mardecun, marin, mc2mkp};
use crate::util::rng::Rng;

/// A scheduling algorithm for the Minimal Cost FL Schedule problem.
pub trait Solver {
    /// Stable lower-case identifier (what `--algo` accepts).
    fn name(&self) -> &'static str;

    /// Solve an instance.
    fn solve(&self, inst: &Instance) -> Result<Schedule>;

    /// Whether this solver is *provably optimal* for the given scenario
    /// (the paper's Table 2 applicability column). Baselines return
    /// `false` everywhere.
    fn is_optimal_for(&self, _scenario: &Scenario) -> bool {
        false
    }

    /// Solve threading an external RNG. Deterministic solvers ignore it;
    /// the `random` baseline consumes it (so coordinator runs replay
    /// bit-for-bit from one seed).
    fn solve_with_rng(&self, inst: &Instance, _rng: &mut Rng) -> Result<Schedule> {
        self.solve(inst)
    }
}

macro_rules! fn_solver {
    ($ty:ident, $name:literal, $solve:path, optimal: |$s:ident| $opt:expr) => {
        /// Registry adapter for the identically-named module solver.
        pub struct $ty;

        impl Solver for $ty {
            fn name(&self) -> &'static str {
                $name
            }
            fn solve(&self, inst: &Instance) -> Result<Schedule> {
                $solve(inst)
            }
            fn is_optimal_for(&self, $s: &Scenario) -> bool {
                $opt
            }
        }
    };
}

fn_solver!(Mc2mkpSolver, "mc2mkp", mc2mkp::solve, optimal: |_s| true);
fn_solver!(MarInSolver, "marin", marin::solve, optimal: |s| matches!(
    s.regime,
    MarginalRegime::Increasing | MarginalRegime::Constant
));
fn_solver!(MarCoSolver, "marco", marco::solve, optimal: |s| matches!(
    s.regime,
    MarginalRegime::Constant
));
fn_solver!(MarDecUnSolver, "mardecun", mardecun::solve, optimal: |s| {
    !s.has_upper_limits
        && matches!(
            s.regime,
            MarginalRegime::Decreasing | MarginalRegime::Constant
        )
});
fn_solver!(MarDecSolver, "mardec", mardec::solve, optimal: |s| matches!(
    s.regime,
    MarginalRegime::Decreasing | MarginalRegime::Constant
));
fn_solver!(BruteforceSolver, "bruteforce", bruteforce::solve, optimal: |_s| true);
fn_solver!(UniformSolver, "uniform", baselines::uniform, optimal: |_s| false);
fn_solver!(ProportionalSolver, "proportional", baselines::proportional,
    optimal: |_s| false);
fn_solver!(GreedySolver, "greedy", baselines::greedy_cost, optimal: |_s| false);
fn_solver!(OlarSolver, "olar", baselines::olar, optimal: |_s| false);

/// The Table 2 dispatcher: classify the instance, run the cheapest optimal
/// algorithm for its scenario.
pub struct AutoSolver;

impl AutoSolver {
    /// Dispatch to the *built-in* implementation of a Table 2 algorithm.
    /// `AutoSolver` is registry-independent by design (it can be used
    /// standalone), so registry shadowing of a concrete solver does not
    /// reach this path; the coordinator resolves `auto` to its concrete
    /// Table 2 name first and dispatches that through its registry, which
    /// does honor overrides.
    fn dispatch(name: &str, inst: &Instance) -> Result<Schedule> {
        match name {
            "mc2mkp" => mc2mkp::solve(inst),
            "marin" => marin::solve(inst),
            "marco" => marco::solve(inst),
            "mardecun" => mardecun::solve(inst),
            "mardec" => mardec::solve(inst),
            other => Err(FedError::Config(format!(
                "auto dispatched to unknown solver '{other}'"
            ))),
        }
    }
}

impl Solver for AutoSolver {
    fn name(&self) -> &'static str {
        "auto"
    }
    fn solve(&self, inst: &Instance) -> Result<Schedule> {
        let scenario = classify_instance(inst);
        Self::dispatch(best_algorithm(&scenario), inst)
    }
    fn is_optimal_for(&self, _scenario: &Scenario) -> bool {
        true
    }
}

/// The seeded `random` baseline. `solve` draws from an interior RNG (so the
/// registry's plain entry points stay usable); `solve_with_rng` consumes
/// the caller's stream instead, which is what the coordinator uses for
/// reproducible rounds.
pub struct RandomSolver {
    rng: RefCell<Rng>,
}

impl RandomSolver {
    /// Seeded random baseline.
    pub fn new(seed: u64) -> Self {
        Self { rng: RefCell::new(Rng::new(seed)) }
    }
}

impl Solver for RandomSolver {
    fn name(&self) -> &'static str {
        "random"
    }
    fn solve(&self, inst: &Instance) -> Result<Schedule> {
        baselines::random(inst, &mut self.rng.borrow_mut())
    }
    fn solve_with_rng(&self, inst: &Instance, rng: &mut Rng) -> Result<Schedule> {
        baselines::random(inst, rng)
    }
}

/// Name aliases accepted by [`SolverRegistry::resolve`].
const ALIASES: [(&str, &str); 1] = [("dp", "mc2mkp")];

/// Registry of all available solvers, keyed by [`Solver::name`].
pub struct SolverRegistry {
    solvers: Vec<Box<dyn Solver>>,
    /// How many entries were installed by [`SolverRegistry::with_defaults`];
    /// anything at or past this index is a caller registration (possibly
    /// shadowing a default — see [`SolverRegistry::is_overridden`]).
    default_count: usize,
}

impl SolverRegistry {
    /// Empty registry (for fully custom line-ups).
    pub fn empty() -> Self {
        Self { solvers: Vec::new(), default_count: 0 }
    }

    /// Registry with the paper's algorithms, the brute-force oracle, and
    /// all baselines. `seed` feeds the `random` baseline's interior RNG.
    pub fn with_defaults(seed: u64) -> Self {
        let mut r = Self::empty();
        r.register(Box::new(AutoSolver));
        r.register(Box::new(Mc2mkpSolver));
        r.register(Box::new(MarInSolver));
        r.register(Box::new(MarCoSolver));
        r.register(Box::new(MarDecUnSolver));
        r.register(Box::new(MarDecSolver));
        r.register(Box::new(BruteforceSolver));
        r.register(Box::new(UniformSolver));
        r.register(Box::new(RandomSolver::new(seed)));
        r.register(Box::new(ProportionalSolver));
        r.register(Box::new(GreedySolver));
        r.register(Box::new(OlarSolver));
        r.default_count = r.solvers.len();
        r
    }

    /// Register a solver. A later registration with the same name shadows
    /// the earlier one (lookup scans back-to-front), so callers can
    /// override defaults.
    pub fn register(&mut self, solver: Box<dyn Solver>) {
        self.solvers.push(solver);
    }

    fn find_index(&self, name: &str) -> Option<usize> {
        let canonical = ALIASES
            .iter()
            .find(|(a, _)| *a == name)
            .map(|(_, c)| *c)
            .unwrap_or(name);
        self.solvers.iter().rposition(|s| s.name() == canonical)
    }

    /// Look up a solver by exact name or alias.
    pub fn get(&self, name: &str) -> Option<&dyn Solver> {
        self.find_index(name).map(|i| self.solvers[i].as_ref())
    }

    /// True when `name` currently resolves to a caller-registered solver
    /// rather than the built-in default — i.e. a default was shadowed, or
    /// the registry never had defaults. Callers with solver-specific fast
    /// paths (the coordinator's warm DP) use this to stand down when the
    /// name no longer means the implementation they optimize.
    pub fn is_overridden(&self, name: &str) -> bool {
        self.find_index(name)
            .map_or(false, |i| i >= self.default_count)
    }

    /// Registered solver names, registration order, shadowed names once.
    pub fn names(&self) -> Vec<&'static str> {
        let mut out: Vec<&'static str> = Vec::with_capacity(self.solvers.len());
        for s in &self.solvers {
            if !out.contains(&s.name()) {
                out.push(s.name());
            }
        }
        out
    }

    /// Resolve a name or fail with a message listing every valid solver —
    /// the single source of truth for `--algo` errors.
    pub fn resolve(&self, name: &str) -> Result<&dyn Solver> {
        self.get(name).ok_or_else(|| {
            FedError::Config(format!(
                "unknown solver '{name}' (valid: {})",
                self.names().join("|")
            ))
        })
    }

    /// Resolve + solve.
    pub fn solve(&self, name: &str, inst: &Instance) -> Result<Schedule> {
        self.resolve(name)?.solve(inst)
    }

    /// Resolve + solve threading the caller's RNG (reproducible `random`).
    pub fn solve_seeded(
        &self,
        name: &str,
        inst: &Instance,
        rng: &mut Rng,
    ) -> Result<Schedule> {
        self.resolve(name)?.solve_with_rng(inst, rng)
    }

    /// Solvers that are provably optimal for `scenario`.
    pub fn optimal_for(&self, scenario: &Scenario) -> Vec<&dyn Solver> {
        let names = self.names();
        names
            .into_iter()
            .filter_map(|n| self.get(n))
            .filter(|s| s.is_optimal_for(scenario))
            .collect()
    }
}

impl Default for SolverRegistry {
    fn default() -> Self {
        Self::with_defaults(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::validate;

    #[test]
    fn defaults_cover_all_twelve_solvers() {
        let r = SolverRegistry::with_defaults(1);
        let names = r.names();
        for expect in [
            "auto", "mc2mkp", "marin", "marco", "mardecun", "mardec",
            "bruteforce", "uniform", "random", "proportional", "greedy",
            "olar",
        ] {
            assert!(names.contains(&expect), "missing {expect}");
        }
        assert_eq!(names.len(), 12);
    }

    #[test]
    fn alias_dp_resolves_to_mc2mkp() {
        let r = SolverRegistry::with_defaults(1);
        assert_eq!(r.resolve("dp").unwrap().name(), "mc2mkp");
    }

    #[test]
    fn unknown_name_lists_valid_solvers() {
        let r = SolverRegistry::with_defaults(1);
        let err = r.resolve("nope").unwrap_err().to_string();
        assert!(err.contains("nope"));
        assert!(err.contains("mc2mkp") && err.contains("olar"), "{err}");
    }

    #[test]
    fn every_solver_is_feasible_on_the_paper_example() {
        let r = SolverRegistry::with_defaults(7);
        let inst = Instance::paper_example(8);
        let mut rng = Rng::new(3);
        for name in r.names() {
            let s = r.solve_seeded(name, &inst, &mut rng).unwrap();
            validate::check(&inst, &s)
                .unwrap_or_else(|e| panic!("{name} infeasible: {e}"));
        }
    }

    #[test]
    fn optimal_solvers_hit_the_fig1_optimum() {
        let r = SolverRegistry::with_defaults(7);
        let inst = Instance::paper_example(5);
        for name in ["auto", "mc2mkp", "bruteforce", "dp"] {
            let s = r.solve(name, &inst).unwrap();
            let c = validate::checked_cost(&inst, &s).unwrap();
            assert!((c - 7.5).abs() < 1e-9, "{name}: {c}");
        }
    }

    #[test]
    fn is_optimal_for_matches_table2() {
        let r = SolverRegistry::with_defaults(1);
        let dec_lim = Scenario {
            regime: MarginalRegime::Decreasing,
            has_upper_limits: true,
        };
        assert!(r.get("mc2mkp").unwrap().is_optimal_for(&dec_lim));
        assert!(r.get("mardec").unwrap().is_optimal_for(&dec_lim));
        assert!(!r.get("mardecun").unwrap().is_optimal_for(&dec_lim));
        assert!(!r.get("marin").unwrap().is_optimal_for(&dec_lim));
        assert!(!r.get("uniform").unwrap().is_optimal_for(&dec_lim));

        let con_unl = Scenario {
            regime: MarginalRegime::Constant,
            has_upper_limits: false,
        };
        let optimal: Vec<&str> =
            r.optimal_for(&con_unl).iter().map(|s| s.name()).collect();
        assert!(optimal.contains(&"marco") && optimal.contains(&"mardecun"));
        assert!(!optimal.contains(&"greedy"));
    }

    #[test]
    fn random_threads_external_rng_deterministically() {
        let r = SolverRegistry::with_defaults(1);
        let inst = Instance::paper_example(8);
        let a = r
            .solve_seeded("random", &inst, &mut Rng::new(9))
            .unwrap();
        let b = r
            .solve_seeded("random", &inst, &mut Rng::new(9))
            .unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn registration_shadows_by_name() {
        struct Fake;
        impl Solver for Fake {
            fn name(&self) -> &'static str {
                "uniform"
            }
            fn solve(&self, inst: &Instance) -> Result<Schedule> {
                bruteforce::solve(inst)
            }
        }
        let mut r = SolverRegistry::with_defaults(1);
        r.register(Box::new(Fake));
        let inst = Instance::paper_example(5);
        let c = validate::checked_cost(&inst, &r.solve("uniform", &inst).unwrap())
            .unwrap();
        assert!((c - 7.5).abs() < 1e-9, "shadowed uniform should be optimal");
        assert_eq!(r.names().len(), 12, "names() must dedupe shadowed entries");
        assert!(r.is_overridden("uniform"));
        assert!(!r.is_overridden("mc2mkp"));
        assert!(!r.is_overridden("dp"), "alias follows its target");
        assert!(!r.is_overridden("no-such-solver"));
    }
}
