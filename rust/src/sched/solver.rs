//! The `Solver` trait and `SolverRegistry`: every scheduler in the crate —
//! the paper's seven optimal algorithms (Algorithms 1–7: the (MC)²MKP DP,
//! MarIn, MarCo, MarDecUn, and the three MarDec procedures behind
//! [`mardec::solve`]) plus the five baselines and the brute-force oracle —
//! is reachable through one seam.
//!
//! Since the fleet-scale redesign the seam's **primary input is the
//! class-deduplicated [`FleetInstance`]** and its output a class-level
//! [`Assignment`]: solvers that can exploit device classes (MarIn, MarCo,
//! MarDecUn, MarDec, the DP) override [`Solver::solve`] with their
//! `solve_fleet` cores and run in the number of *classes* `k`, not
//! devices `n`. Everything else — baselines, the oracle, external
//! registrations — implements only the flat [`Solver::solve_flat`] seam
//! and is adapted automatically (flatten, solve, regroup), which keeps
//! all twelve seed solvers bit-for-bit equivalent on flat instances.
//!
//! The registry replaces the old `Policy`-enum `match` dispatch: callers
//! resolve a solver by name (`registry.resolve("mardec")`), ask the
//! Table 2 question (`solver.is_optimal_for(&scenario)`), or let the
//! `auto` solver classify-and-dispatch. New solvers (and external
//! backends) register without touching any call site.

use std::cell::RefCell;

use crate::error::{FedError, Result};
use crate::sched::auto::{
    best_algorithm, classify_fleet, classify_instance, Scenario, TABLE2_SCENARIOS,
};
use crate::sched::costs::MarginalRegime;
use crate::sched::fleet::{Assignment, FleetInstance};
use crate::sched::instance::{Instance, Schedule};
use crate::sched::{baselines, bruteforce, marco, mardec, mardecun, marin, mc2mkp};
use crate::util::rng::Rng;

/// A scheduling algorithm for the Minimal Cost FL Schedule problem.
pub trait Solver {
    /// Stable lower-case identifier (what `--algo` accepts).
    fn name(&self) -> &'static str;

    /// Solve a class-deduplicated fleet instance — the primary entry
    /// point. The default flattens to a per-device [`Instance`], runs
    /// [`Solver::solve_flat`], and regroups the schedule; class-aware
    /// solvers override it to run in `O(k)`-ish instead of `O(n)`-ish.
    fn solve(&self, fleet: &FleetInstance) -> Result<Assignment> {
        let sched = self.solve_flat(&fleet.to_flat())?;
        Ok(Assignment::from_schedule(fleet, &sched))
    }

    /// Solve a flat per-device instance (the legacy seam every solver
    /// implements; [`FleetInstance::from_flat`] adapts callers upward).
    fn solve_flat(&self, inst: &Instance) -> Result<Schedule>;

    /// True when [`Solver::solve`] is overridden with a class-aware core.
    /// The registry's flat entry points use this to skip the
    /// `from_flat`/`to_flat` round-trip for flat-only solvers.
    fn class_aware(&self) -> bool {
        false
    }

    /// Whether this solver is *provably optimal* for the given scenario
    /// (the paper's Table 2 applicability column). Baselines return
    /// `false` everywhere.
    fn is_optimal_for(&self, _scenario: &Scenario) -> bool {
        false
    }

    /// Fleet solve threading an external RNG. The default flattens and
    /// delegates to [`Solver::solve_flat_with_rng`], so a seeded solver
    /// that only implements the flat seam still consumes the caller's
    /// stream (reproducible runs). Class-aware deterministic solvers
    /// override this to keep their class core on the seeded path.
    fn solve_with_rng(
        &self,
        fleet: &FleetInstance,
        rng: &mut Rng,
    ) -> Result<Assignment> {
        let sched = self.solve_flat_with_rng(&fleet.to_flat(), rng)?;
        Ok(Assignment::from_schedule(fleet, &sched))
    }

    /// Flat solve threading an external RNG.
    fn solve_flat_with_rng(
        &self,
        inst: &Instance,
        _rng: &mut Rng,
    ) -> Result<Schedule> {
        self.solve_flat(inst)
    }
}

macro_rules! fn_solver {
    ($ty:ident, $name:literal, $solve:path,
     optimal: |$s:ident| $opt:expr) => {
        /// Registry adapter for the identically-named module solver
        /// (flat-only: fleet solves flatten through the default path).
        pub struct $ty;

        impl Solver for $ty {
            fn name(&self) -> &'static str {
                $name
            }
            fn solve_flat(&self, inst: &Instance) -> Result<Schedule> {
                $solve(inst)
            }
            fn is_optimal_for(&self, $s: &Scenario) -> bool {
                $opt
            }
        }
    };
    ($ty:ident, $name:literal, $solve:path, fleet: $fleet:path,
     optimal: |$s:ident| $opt:expr) => {
        /// Registry adapter for the identically-named module solver,
        /// class-aware: fleet solves run the `solve_fleet` core.
        pub struct $ty;

        impl Solver for $ty {
            fn name(&self) -> &'static str {
                $name
            }
            fn solve(&self, fleet: &FleetInstance) -> Result<Assignment> {
                $fleet(fleet)
            }
            fn solve_with_rng(
                &self,
                fleet: &FleetInstance,
                _rng: &mut Rng,
            ) -> Result<Assignment> {
                // Deterministic class-aware core: stay on the class path.
                $fleet(fleet)
            }
            fn solve_flat(&self, inst: &Instance) -> Result<Schedule> {
                $solve(inst)
            }
            fn class_aware(&self) -> bool {
                true
            }
            fn is_optimal_for(&self, $s: &Scenario) -> bool {
                $opt
            }
        }
    };
}

fn_solver!(Mc2mkpSolver, "mc2mkp", mc2mkp::solve, fleet: mc2mkp::solve_fleet,
    optimal: |_s| true);
fn_solver!(MarInSolver, "marin", marin::solve, fleet: marin::solve_fleet,
    optimal: |s| matches!(
        s.regime,
        MarginalRegime::Increasing | MarginalRegime::Constant
    ));
fn_solver!(MarCoSolver, "marco", marco::solve, fleet: marco::solve_fleet,
    optimal: |s| matches!(s.regime, MarginalRegime::Constant));
fn_solver!(MarDecUnSolver, "mardecun", mardecun::solve,
    fleet: mardecun::solve_fleet,
    optimal: |s| {
        !s.has_upper_limits
            && matches!(
                s.regime,
                MarginalRegime::Decreasing | MarginalRegime::Constant
            )
    });
fn_solver!(MarDecSolver, "mardec", mardec::solve, fleet: mardec::solve_fleet,
    optimal: |s| matches!(
        s.regime,
        MarginalRegime::Decreasing | MarginalRegime::Constant
    ));
fn_solver!(BruteforceSolver, "bruteforce", bruteforce::solve, optimal: |_s| true);
fn_solver!(UniformSolver, "uniform", baselines::uniform, optimal: |_s| false);
fn_solver!(ProportionalSolver, "proportional", baselines::proportional,
    optimal: |_s| false);
fn_solver!(GreedySolver, "greedy", baselines::greedy_cost, optimal: |_s| false);
fn_solver!(OlarSolver, "olar", baselines::olar, optimal: |_s| false);

/// The Table 2 dispatcher: classify the instance, run the cheapest optimal
/// algorithm for its scenario.
pub struct AutoSolver;

impl AutoSolver {
    /// Dispatch to the *built-in* implementation of a Table 2 algorithm.
    /// `AutoSolver` is registry-independent by design (it can be used
    /// standalone), so registry shadowing of a concrete solver does not
    /// reach this path; the coordinator resolves `auto` to its concrete
    /// Table 2 name first and dispatches that through its registry, which
    /// does honor overrides.
    fn dispatch(name: &str, inst: &Instance) -> Result<Schedule> {
        match name {
            "mc2mkp" => mc2mkp::solve(inst),
            "marin" => marin::solve(inst),
            "marco" => marco::solve(inst),
            "mardecun" => mardecun::solve(inst),
            "mardec" => mardec::solve(inst),
            other => Err(FedError::Config(format!(
                "auto dispatched to unknown solver '{other}'"
            ))),
        }
    }

    /// Fleet-side dispatch to the built-in class-aware cores.
    fn dispatch_fleet(name: &str, fleet: &FleetInstance) -> Result<Assignment> {
        match name {
            "mc2mkp" => mc2mkp::solve_fleet(fleet),
            "marin" => marin::solve_fleet(fleet),
            "marco" => marco::solve_fleet(fleet),
            "mardecun" => mardecun::solve_fleet(fleet),
            "mardec" => mardec::solve_fleet(fleet),
            other => Err(FedError::Config(format!(
                "auto dispatched to unknown solver '{other}'"
            ))),
        }
    }
}

impl Solver for AutoSolver {
    fn name(&self) -> &'static str {
        "auto"
    }
    fn solve(&self, fleet: &FleetInstance) -> Result<Assignment> {
        let scenario = classify_fleet(fleet);
        Self::dispatch_fleet(best_algorithm(&scenario), fleet)
    }
    fn solve_with_rng(
        &self,
        fleet: &FleetInstance,
        _rng: &mut Rng,
    ) -> Result<Assignment> {
        // Table 2 dispatch is deterministic: stay on the class path.
        self.solve(fleet)
    }
    fn solve_flat(&self, inst: &Instance) -> Result<Schedule> {
        let scenario = classify_instance(inst);
        Self::dispatch(best_algorithm(&scenario), inst)
    }
    fn class_aware(&self) -> bool {
        true
    }
    fn is_optimal_for(&self, _scenario: &Scenario) -> bool {
        true
    }
}

/// The seeded `random` baseline. `solve` draws from an interior RNG (so the
/// registry's plain entry points stay usable); the `*_with_rng` variants
/// consume the caller's stream instead — the trait's default fleet
/// `solve_with_rng` already flattens into [`Solver::solve_flat_with_rng`],
/// which is exactly right for a per-device randomizer.
pub struct RandomSolver {
    rng: RefCell<Rng>,
}

impl RandomSolver {
    /// Seeded random baseline.
    pub fn new(seed: u64) -> Self {
        Self { rng: RefCell::new(Rng::new(seed)) }
    }
}

impl Solver for RandomSolver {
    fn name(&self) -> &'static str {
        "random"
    }
    fn solve_flat(&self, inst: &Instance) -> Result<Schedule> {
        baselines::random(inst, &mut self.rng.borrow_mut())
    }
    fn solve_flat_with_rng(&self, inst: &Instance, rng: &mut Rng) -> Result<Schedule> {
        baselines::random(inst, rng)
    }
}

/// Name aliases accepted by [`SolverRegistry::resolve`].
const ALIASES: [(&str, &str); 1] = [("dp", "mc2mkp")];

/// Registry of all available solvers, keyed by [`Solver::name`].
pub struct SolverRegistry {
    solvers: Vec<Box<dyn Solver>>,
    /// How many entries were installed by [`SolverRegistry::with_defaults`];
    /// anything at or past this index is a caller registration (possibly
    /// shadowing a default — see [`SolverRegistry::is_overridden`]).
    default_count: usize,
}

impl SolverRegistry {
    /// Empty registry (for fully custom line-ups).
    pub fn empty() -> Self {
        Self { solvers: Vec::new(), default_count: 0 }
    }

    /// Registry with the paper's algorithms, the brute-force oracle, and
    /// all baselines. `seed` feeds the `random` baseline's interior RNG.
    pub fn with_defaults(seed: u64) -> Self {
        let mut r = Self::empty();
        r.register(Box::new(AutoSolver));
        r.register(Box::new(Mc2mkpSolver));
        r.register(Box::new(MarInSolver));
        r.register(Box::new(MarCoSolver));
        r.register(Box::new(MarDecUnSolver));
        r.register(Box::new(MarDecSolver));
        r.register(Box::new(BruteforceSolver));
        r.register(Box::new(UniformSolver));
        r.register(Box::new(RandomSolver::new(seed)));
        r.register(Box::new(ProportionalSolver));
        r.register(Box::new(GreedySolver));
        r.register(Box::new(OlarSolver));
        r.default_count = r.solvers.len();
        r
    }

    /// Register a solver. A later registration with the same name shadows
    /// the earlier one (lookup scans back-to-front), so callers can
    /// override defaults.
    pub fn register(&mut self, solver: Box<dyn Solver>) {
        self.solvers.push(solver);
    }

    fn find_index(&self, name: &str) -> Option<usize> {
        let canonical = ALIASES
            .iter()
            .find(|(a, _)| *a == name)
            .map(|(_, c)| *c)
            .unwrap_or(name);
        self.solvers.iter().rposition(|s| s.name() == canonical)
    }

    /// Look up a solver by exact name or alias.
    pub fn get(&self, name: &str) -> Option<&dyn Solver> {
        self.find_index(name).map(|i| self.solvers[i].as_ref())
    }

    /// True when `name` currently resolves to a caller-registered solver
    /// rather than the built-in default — i.e. a default was shadowed, or
    /// the registry never had defaults. Callers with solver-specific fast
    /// paths (the coordinator's warm DP) use this to stand down when the
    /// name no longer means the implementation they optimize.
    pub fn is_overridden(&self, name: &str) -> bool {
        self.find_index(name)
            .map_or(false, |i| i >= self.default_count)
    }

    /// Registered solver names, registration order, shadowed names once.
    pub fn names(&self) -> Vec<&'static str> {
        let mut out: Vec<&'static str> = Vec::with_capacity(self.solvers.len());
        for s in &self.solvers {
            if !out.contains(&s.name()) {
                out.push(s.name());
            }
        }
        out
    }

    /// One line per registered solver: name plus the Table 2 scenarios it
    /// is provably optimal for (`—` for pure heuristics). This is what
    /// `--algo` errors and the `solvers` subcommand print.
    pub fn describe(&self) -> Vec<String> {
        self.names()
            .into_iter()
            .filter_map(|n| self.get(n).map(|s| (n, s)))
            .map(|(n, s)| {
                let tags: Vec<&str> = TABLE2_SCENARIOS
                    .iter()
                    .filter(|(_, sc)| s.is_optimal_for(sc))
                    .map(|(label, _)| *label)
                    .collect();
                if tags.is_empty() {
                    format!("{n}[—]")
                } else {
                    format!("{n}[{}]", tags.join(","))
                }
            })
            .collect()
    }

    /// Resolve a name or fail with a message listing every valid solver
    /// and its Table 2 applicability — the single source of truth for
    /// `--algo` errors.
    pub fn resolve(&self, name: &str) -> Result<&dyn Solver> {
        self.get(name).ok_or_else(|| {
            FedError::Config(format!(
                "unknown solver '{name}' (valid, with Table 2 optimality \
                 scenarios: {})",
                self.describe().join(" ")
            ))
        })
    }

    /// Resolve + flat solve. Class-aware solvers are adapted through the
    /// fleet seam **when deduplication found anything** (`k < n`) — on
    /// all-distinct instances, and for flat-only solvers always, the
    /// solver runs directly on `inst` with no round-trip overhead (only
    /// the `O(n)` dedup probe itself).
    pub fn solve(&self, name: &str, inst: &Instance) -> Result<Schedule> {
        let solver = self.resolve(name)?;
        if !solver.class_aware() {
            return solver.solve_flat(inst);
        }
        let fleet = FleetInstance::from_flat(inst)?;
        if fleet.n_classes() == fleet.n_devices() {
            return solver.solve_flat(inst);
        }
        Ok(solver.solve(&fleet)?.expand(&fleet))
    }

    /// Resolve + flat solve threading the caller's RNG (reproducible
    /// `random`). Same adaptation rule as [`SolverRegistry::solve`].
    pub fn solve_seeded(
        &self,
        name: &str,
        inst: &Instance,
        rng: &mut Rng,
    ) -> Result<Schedule> {
        let solver = self.resolve(name)?;
        if !solver.class_aware() {
            return solver.solve_flat_with_rng(inst, rng);
        }
        let fleet = FleetInstance::from_flat(inst)?;
        if fleet.n_classes() == fleet.n_devices() {
            return solver.solve_flat_with_rng(inst, rng);
        }
        Ok(solver.solve_with_rng(&fleet, rng)?.expand(&fleet))
    }

    /// Resolve + fleet solve.
    pub fn solve_fleet(&self, name: &str, fleet: &FleetInstance) -> Result<Assignment> {
        self.resolve(name)?.solve(fleet)
    }

    /// Resolve + fleet solve threading the caller's RNG.
    pub fn solve_fleet_seeded(
        &self,
        name: &str,
        fleet: &FleetInstance,
        rng: &mut Rng,
    ) -> Result<Assignment> {
        self.resolve(name)?.solve_with_rng(fleet, rng)
    }

    /// Solvers that are provably optimal for `scenario`.
    pub fn optimal_for(&self, scenario: &Scenario) -> Vec<&dyn Solver> {
        let names = self.names();
        names
            .into_iter()
            .filter_map(|n| self.get(n))
            .filter(|s| s.is_optimal_for(scenario))
            .collect()
    }
}

impl Default for SolverRegistry {
    fn default() -> Self {
        Self::with_defaults(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::costs::CostFn;
    use crate::sched::validate;

    #[test]
    fn defaults_cover_all_twelve_solvers() {
        let r = SolverRegistry::with_defaults(1);
        let names = r.names();
        for expect in [
            "auto", "mc2mkp", "marin", "marco", "mardecun", "mardec",
            "bruteforce", "uniform", "random", "proportional", "greedy",
            "olar",
        ] {
            assert!(names.contains(&expect), "missing {expect}");
        }
        assert_eq!(names.len(), 12);
    }

    #[test]
    fn alias_dp_resolves_to_mc2mkp() {
        let r = SolverRegistry::with_defaults(1);
        assert_eq!(r.resolve("dp").unwrap().name(), "mc2mkp");
    }

    #[test]
    fn unknown_name_lists_valid_solvers_with_applicability() {
        let r = SolverRegistry::with_defaults(1);
        let err = r.resolve("nope").unwrap_err().to_string();
        assert!(err.contains("nope"));
        assert!(err.contains("mc2mkp[arb,inc,con,dec,dec∞]"), "{err}");
        assert!(err.contains("marin[inc,con]"), "{err}");
        assert!(err.contains("olar[—]"), "{err}");
    }

    #[test]
    fn every_solver_is_feasible_on_the_paper_example() {
        let r = SolverRegistry::with_defaults(7);
        let inst = Instance::paper_example(8);
        let mut rng = Rng::new(3);
        for name in r.names() {
            let s = r.solve_seeded(name, &inst, &mut rng).unwrap();
            validate::check(&inst, &s)
                .unwrap_or_else(|e| panic!("{name} infeasible: {e}"));
        }
    }

    #[test]
    fn optimal_solvers_hit_the_fig1_optimum() {
        let r = SolverRegistry::with_defaults(7);
        let inst = Instance::paper_example(5);
        for name in ["auto", "mc2mkp", "bruteforce", "dp"] {
            let s = r.solve(name, &inst).unwrap();
            let c = validate::checked_cost(&inst, &s).unwrap();
            assert!((c - 7.5).abs() < 1e-9, "{name}: {c}");
        }
    }

    #[test]
    fn fleet_entry_points_solve_class_instances() {
        // 6 devices in 2 classes; constant marginals → marco block-fills.
        let fleet = FleetInstance::builder()
            .tasks(10)
            .device_class(CostFn::Affine { fixed: 0.0, per_task: 1.0 }, 0, 3, 3)
            .device_class(CostFn::Affine { fixed: 0.0, per_task: 5.0 }, 0, 3, 3)
            .build()
            .unwrap();
        let r = SolverRegistry::with_defaults(1);
        for name in ["auto", "marco", "marin", "mc2mkp"] {
            let asg = r.solve_fleet(name, &fleet).unwrap();
            asg.check(&fleet).unwrap();
            let cost = asg.total_cost(&fleet);
            // 9 tasks on the cheap class, 1 on the expensive one.
            assert!((cost - 14.0).abs() < 1e-9, "{name}: {cost}");
            let sched = asg.expand(&fleet);
            assert_eq!(sched.total(), 10);
        }
    }

    #[test]
    fn is_optimal_for_matches_table2() {
        let r = SolverRegistry::with_defaults(1);
        let dec_lim = Scenario {
            regime: MarginalRegime::Decreasing,
            has_upper_limits: true,
        };
        assert!(r.get("mc2mkp").unwrap().is_optimal_for(&dec_lim));
        assert!(r.get("mardec").unwrap().is_optimal_for(&dec_lim));
        assert!(!r.get("mardecun").unwrap().is_optimal_for(&dec_lim));
        assert!(!r.get("marin").unwrap().is_optimal_for(&dec_lim));
        assert!(!r.get("uniform").unwrap().is_optimal_for(&dec_lim));

        let con_unl = Scenario {
            regime: MarginalRegime::Constant,
            has_upper_limits: false,
        };
        let optimal: Vec<&str> =
            r.optimal_for(&con_unl).iter().map(|s| s.name()).collect();
        assert!(optimal.contains(&"marco") && optimal.contains(&"mardecun"));
        assert!(!optimal.contains(&"greedy"));
    }

    #[test]
    fn random_threads_external_rng_deterministically() {
        let r = SolverRegistry::with_defaults(1);
        let inst = Instance::paper_example(8);
        let a = r
            .solve_seeded("random", &inst, &mut Rng::new(9))
            .unwrap();
        let b = r
            .solve_seeded("random", &inst, &mut Rng::new(9))
            .unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn default_fleet_seeded_path_threads_rng_through_flat_seam() {
        // A custom seeded solver implementing only the flat seam must
        // consume the caller's stream on the fleet entry points too — the
        // default solve_with_rng flattens into solve_flat_with_rng.
        struct SeededFlat;
        impl Solver for SeededFlat {
            fn name(&self) -> &'static str {
                "seeded-flat"
            }
            fn solve_flat(&self, inst: &Instance) -> Result<Schedule> {
                baselines::uniform(inst)
            }
            fn solve_flat_with_rng(
                &self,
                inst: &Instance,
                rng: &mut Rng,
            ) -> Result<Schedule> {
                baselines::random(inst, rng)
            }
        }
        let mut r = SolverRegistry::with_defaults(1);
        r.register(Box::new(SeededFlat));
        let inst = Instance::paper_example(8);
        let fleet = FleetInstance::from_flat(&inst).unwrap();
        let a = r
            .solve_fleet_seeded("seeded-flat", &fleet, &mut Rng::new(5))
            .unwrap();
        let b = r
            .solve_fleet_seeded("seeded-flat", &fleet, &mut Rng::new(5))
            .unwrap();
        assert_eq!(a, b);
        // ...and it is genuinely the seeded path, not the rng-less
        // interior fallback.
        let c = baselines::random(&inst, &mut Rng::new(5)).unwrap();
        assert_eq!(a.expand(&fleet), c);
    }

    #[test]
    fn registration_shadows_by_name() {
        struct Fake;
        impl Solver for Fake {
            fn name(&self) -> &'static str {
                "uniform"
            }
            fn solve_flat(&self, inst: &Instance) -> Result<Schedule> {
                bruteforce::solve(inst)
            }
        }
        let mut r = SolverRegistry::with_defaults(1);
        r.register(Box::new(Fake));
        let inst = Instance::paper_example(5);
        let c = validate::checked_cost(&inst, &r.solve("uniform", &inst).unwrap())
            .unwrap();
        assert!((c - 7.5).abs() < 1e-9, "shadowed uniform should be optimal");
        assert_eq!(r.names().len(), 12, "names() must dedupe shadowed entries");
        assert!(r.is_overridden("uniform"));
        assert!(!r.is_overridden("mc2mkp"));
        assert!(!r.is_overridden("dp"), "alias follows its target");
        assert!(!r.is_overridden("no-such-solver"));
    }
}
