//! Algorithm 4 — **MarDecUn**: optimal scheduling under *decreasing*
//! marginal costs when no resource has an effective upper limit
//! (paper §5.5).
//!
//! With concave costs, splitting work across resources can never beat
//! concentrating it (Lemma 6 — sums of contiguous intervals of decreasing
//! functions), so the optimum assigns **all** tasks to the single resource
//! with minimal `C_i(T)` (Theorem 4).
//!
//! Complexity: `Θ(n)`, `O(n)` space (the output schedule itself).

use crate::error::{FedError, Result};
use crate::sched::fleet::{Assignment, CostView, FleetInstance, LowerFree};
use crate::sched::instance::{Instance, Schedule};
use crate::sched::limits;

/// Run MarDecUn. Requires every resource to be unlimited
/// (`U'_i >= T'` after lower-limit removal); returns
/// [`FedError::ScenarioMismatch`] otherwise — use [`crate::sched::mardec`]
/// in that case.
pub fn solve(inst: &Instance) -> Result<Schedule> {
    inst.validate()?;
    let tr = limits::remove_lower_limits(inst);
    let ti = &tr.instance;
    let t = ti.tasks;

    if !(0..ti.n()).all(|i| ti.cap(i) >= t) {
        return Err(FedError::ScenarioMismatch(
            "MarDecUn requires all resources unlimited (use MarDec)".into(),
        ));
    }

    // k ← argmin_i C_i(T) (line 4 of Algorithm 4). The paper's costs are
    // normalized (C_i(0) = 0 after its §5.2 transform); ours may carry an
    // idle offset, so compare the *increase* C_i(T) − C_i(0) — the
    // Σ C_i(0) baseline is paid by every candidate alike.
    let mut best = 0usize;
    let mut best_cost = f64::INFINITY;
    for i in 0..ti.n() {
        let c = ti.costs[i].eval(t) - ti.costs[i].eval(0);
        if c < best_cost {
            best_cost = c;
            best = i;
        }
    }

    let mut x = vec![0usize; ti.n()];
    x[best] = t;
    Ok(tr.restore(&Schedule::new(x)))
}

/// Class-aware MarDecUn over a lazy [`CostView`]: Theorem 4's argmin runs
/// over `k` classes instead of `n` devices — `Θ(k)` — and one member of
/// the winning class takes everything.
///
/// Returns `Err` exactly like [`solve`] when any class has an effective
/// upper limit.
pub fn solve_view<V: CostView + ?Sized>(
    view: &V,
) -> Result<Vec<Vec<(usize, usize)>>> {
    let t = view.tasks();
    let k = view.n_classes();
    if (0..k).any(|c| view.cap(c) < t) {
        return Err(FedError::ScenarioMismatch(
            "MarDecUn requires all resources unlimited (use MarDec)".into(),
        ));
    }
    let mut best = 0usize;
    let mut best_cost = f64::INFINITY;
    for c in 0..k {
        let inc = view.eval(c, t) - view.eval(c, 0);
        if inc < best_cost {
            best_cost = inc;
            best = c;
        }
    }
    Ok((0..k)
        .map(|c| {
            if c == best {
                vec![(t, 1), (0, view.count(c) - 1)]
            } else {
                vec![(0, view.count(c))]
            }
        })
        .collect())
}

/// Run MarDecUn on a class-deduplicated fleet (same contract as
/// [`solve`]).
pub fn solve_fleet(fleet: &FleetInstance) -> Result<Assignment> {
    fleet.validate()?;
    let view = LowerFree::of(fleet);
    let groups = solve_view(&view)?;
    Ok(Assignment::from_groups(view.restore(groups)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::costs::CostFn;
    use crate::sched::{mc2mkp, validate};
    use crate::util::rng::Rng;

    fn sqrt_cost(scale: f64) -> CostFn {
        CostFn::PowerLaw { fixed: 0.0, scale, exponent: 0.5 }
    }

    #[test]
    fn concentrates_all_tasks() {
        let inst = Instance::new(
            9,
            vec![0, 0, 0],
            vec![9, 9, 9],
            vec![sqrt_cost(3.0), sqrt_cost(1.0), sqrt_cost(2.0)],
        )
        .unwrap();
        let s = solve(&inst).unwrap();
        assert_eq!(s.assignments(), &[0, 9, 0]);
        validate::check(&inst, &s).unwrap();
    }

    #[test]
    fn rejects_limited_instances() {
        let inst = Instance::new(
            9,
            vec![0, 0],
            vec![4, 9],
            vec![sqrt_cost(1.0), sqrt_cost(2.0)],
        )
        .unwrap();
        assert!(matches!(solve(&inst), Err(FedError::ScenarioMismatch(_))));
    }

    #[test]
    fn lower_limits_still_respected() {
        // Resource 0 must take at least 2 even though resource 1 is cheaper.
        let inst = Instance::new(
            10,
            vec![2, 0],
            vec![100, 100],
            vec![sqrt_cost(5.0), sqrt_cost(1.0)],
        )
        .unwrap();
        let s = solve(&inst).unwrap();
        assert_eq!(s.assignments(), &[2, 8]);
        validate::check(&inst, &s).unwrap();
    }

    #[test]
    fn fleet_concentrates_on_one_member_of_the_cheapest_class() {
        use crate::sched::fleet::FleetInstance;
        let fleet = FleetInstance::builder()
            .tasks(9)
            .device_class(sqrt_cost(3.0), 0, 9, 2)
            .device_class(sqrt_cost(1.0), 0, 9, 3)
            .build()
            .unwrap();
        let asg = solve_fleet(&fleet).unwrap();
        asg.check(&fleet).unwrap();
        assert_eq!(asg.groups()[0], vec![(0, 2)]);
        assert_eq!(asg.groups()[1], vec![(9, 1), (0, 2)]);
        assert_eq!(asg.expand(&fleet).assignments(), &[0, 0, 9, 0, 0]);
        // Limited classes must be rejected, like the flat solver.
        let limited = FleetInstance::builder()
            .tasks(9)
            .device_class(sqrt_cost(1.0), 0, 4, 2)
            .device_class(sqrt_cost(2.0), 0, 9, 1)
            .build()
            .unwrap();
        assert!(matches!(
            solve_fleet(&limited),
            Err(FedError::ScenarioMismatch(_))
        ));
    }

    #[test]
    fn matches_dp_on_concave_unlimited_instances() {
        let mut rng = Rng::new(0xDEC0);
        for _case in 0..50 {
            let n = 2 + rng.index(4);
            let t = 5 + rng.index(40);
            let costs: Vec<CostFn> = (0..n)
                .map(|_| {
                    if rng.bool(0.5) {
                        CostFn::PowerLaw {
                            fixed: rng.range_f64(0.0, 1.0),
                            scale: rng.range_f64(0.5, 4.0),
                            exponent: rng.range_f64(0.2, 0.9),
                        }
                    } else {
                        CostFn::Logarithmic {
                            fixed: rng.range_f64(0.0, 1.0),
                            scale: rng.range_f64(0.5, 4.0),
                        }
                    }
                })
                .collect();
            let inst =
                Instance::new(t, vec![0; n], vec![t; n], costs).unwrap();
            let a = validate::checked_cost(&inst, &solve(&inst).unwrap()).unwrap();
            let b = validate::checked_cost(&inst, &mc2mkp::solve(&inst).unwrap()).unwrap();
            assert!((a - b).abs() < 1e-9, "MarDecUn {a} != DP {b}");
        }
    }
}
