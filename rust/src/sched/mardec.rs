//! Algorithms 5–7 — **MarDec**: optimal scheduling under *decreasing*
//! marginal costs in the presence of upper limits (paper §5.6).
//!
//! Lemma 6 implies an optimal schedule exists in one of two shapes:
//!
//! * **(I)** all tasks on a single resource without upper limits, or
//! * **(II)** tasks only on resources at **maximum capacity**, plus at most
//!   one resource at intermediary capacity.
//!
//! MarDec enumerates both shapes exhaustively using the (MC)²MKP DP
//! matrices over two-item classes `N_i = {0, U_i}` (Algorithm 6
//! "Prepare"), scanning every possible intermediary load `t` for (a) the
//! best unlimited resource and (b) each limited resource in turn, and
//! translating the winning DP solution back to a schedule (Algorithm 7
//! "Translate"). Optimality is Theorem 5.
//!
//! Complexity: `O(T n²)` (the DP over two-item classes is `O(T n)` and is
//! recomputed once per limited resource), `O(T n)` space.
//!
//! Implementation note on fixed costs: the paper's Prepare sets
//! `c_{i0} = 0`, implicitly assuming `C_i(0) = 0` (true after its §5.2
//! transformation). We normalize explicitly — all comparisons use
//! `C̃_i(j) = C_i(j) − C_i(0)` — so instances whose zero-lower-limit
//! resources still have a non-zero idle cost are handled correctly (the
//! `Σ C_i(0)` offset is common to every candidate, so the argmin is
//! unchanged).

use crate::error::{FedError, Result};
use crate::sched::fleet::{Assignment, CostView, FleetInstance, LowerFree};
use crate::sched::instance::{Instance, Schedule};
use crate::sched::limits;
use crate::sched::mc2mkp::{dp, Classes, DpMatrices, Item};

/// Run MarDec. Optimal for decreasing marginal costs with (or without)
/// upper limits; also exact without upper limits (it degenerates to
/// MarDecUn's scenario via the `t = T` candidate).
pub fn solve(inst: &Instance) -> Result<Schedule> {
    inst.validate()?;
    let tr = limits::remove_lower_limits(inst);
    let ti = &tr.instance;
    let t_total = ti.tasks;
    let n = ti.n();

    // Normalized cost: C̃_i(j) = C_i(j) − C_i(0).
    let c0: Vec<f64> = (0..n).map(|i| ti.costs[i].eval(0)).collect();
    let cost = |i: usize, j: usize| ti.costs[i].eval(j) - c0[i];

    // Lines 1–3: split resources by the presence of an effective limit.
    let r_lim: Vec<usize> = (0..n).filter(|&i| ti.cap(i) < t_total).collect();
    let r_unl: Vec<usize> = (0..n).filter(|&i| ti.cap(i) >= t_total).collect();
    let n_lim = r_lim.len();

    // Algorithm 6 (Prepare): two-item classes {0, U_r} for limited
    // resources; γ(class index) = r_lim[class index].
    let classes = Classes {
        classes: r_lim
            .iter()
            .map(|&r| {
                vec![
                    Item { weight: 0, cost: 0.0 },
                    Item { weight: ti.cap(r), cost: cost(r, ti.cap(r)) },
                ]
            })
            .collect(),
    };

    let mut best_cost = f64::INFINITY;
    let mut best: Option<Schedule> = None;

    // DP over the full limited set — used by phase 1 and by the
    // "no intermediary resource" candidate.
    let m_full = dp(&classes, t_total);

    // Candidate: scenario (II) with *no* intermediary resource at all
    // (every used resource at max capacity, exact fill). The paper's loops
    // cover this via t = 0 whenever an intermediary candidate exists, but
    // when `R^unl = ∅` and `Σ U_r = T` it is the only feasible shape.
    if m_full.z(n_lim, t_total).is_finite() {
        let c = m_full.z(n_lim, t_total);
        if c < best_cost {
            best_cost = c;
            best = Some(translate(&m_full, &classes, &r_lim, n, t_total)?);
        }
    }

    // Lines 5–16: one resource from R^unl at intermediary capacity t,
    // limited resources at max capacity filling exactly T − t.
    if !r_unl.is_empty() {
        for t in 0..=t_total {
            let rest = m_full.z(n_lim, t_total - t);
            if !rest.is_finite() {
                continue;
            }
            // k ← argmin_{i ∈ R^unl} C̃_i(t)   (line 9)
            let mut k = r_unl[0];
            let mut ck = cost(k, t);
            for &i in &r_unl[1..] {
                let ci = cost(i, t);
                if ci < ck {
                    ck = ci;
                    k = i;
                }
            }
            let total = ck + rest;
            if total < best_cost {
                best_cost = total;
                let mut x = translate(&m_full, &classes, &r_lim, n, t_total - t)?;
                x.set(k, t);
                best = Some(x);
            }
        }
    }

    // Lines 17–28: one resource from R^lim at intermediary capacity.
    for (ci, &r) in r_lim.iter().enumerate() {
        // N' ← (N \ N_i) ∪ {N_i = {0}}   (line 18)
        let mut reduced = classes.clone();
        reduced.classes[ci] = vec![Item { weight: 0, cost: 0.0 }];
        let m_red = dp(&reduced, t_total);
        for t in 0..ti.cap(r) {
            let rest = m_red.z(n_lim, t_total - t);
            if !rest.is_finite() {
                continue;
            }
            let total = cost(r, t) + rest;
            if total < best_cost {
                best_cost = total;
                let mut x = translate(&m_red, &reduced, &r_lim, n, t_total - t)?;
                x.set(r, t);
                best = Some(x);
            }
        }
    }

    let x = best.ok_or_else(|| {
        FedError::Infeasible("MarDec found no candidate on a valid instance".into())
    })?;
    Ok(tr.restore(&x))
}

/// Class-aware MarDec over a lazy [`CostView`].
///
/// Lemma 6's two optimal shapes survive class deduplication unchanged,
/// but every enumeration shrinks from devices to classes:
///
/// * the (MC)²MKP "Prepare" classes become **multiplicity items**: a
///   limited class of `m` members with per-member cap `u` contributes
///   items `q·u` at cost `q·C̃(u)` for `q ∈ [0, min(m, ⌊T/u⌋)]` (choosing
///   `q` members at max capacity — which members is irrelevant, they are
///   interchangeable);
/// * the intermediary scan over `R^lim` needs one representative per
///   class (identical devices yield identical candidates): `k_lim` DP
///   recomputations instead of `n_lim`;
/// * the `argmin` over `R^unl` runs over `k_unl` classes.
///
/// `O(k_lim · T · Σ_c q_max)` time versus the flat `O(T n²)`.
pub fn solve_view<V: CostView + ?Sized>(
    view: &V,
) -> Result<Vec<Vec<(usize, usize)>>> {
    let t_total = view.tasks();
    let k = view.n_classes();

    // Normalized cost C̃_c(j) = C_c(j) − C_c(0) (see the module note on
    // fixed costs).
    let c0: Vec<f64> = (0..k).map(|c| view.eval(c, 0)).collect();
    let cost = |c: usize, j: usize| view.eval(c, j) - c0[c];

    let lim: Vec<usize> = (0..k).filter(|&c| view.cap(c) < t_total).collect();
    let unl: Vec<usize> = (0..k).filter(|&c| view.cap(c) >= t_total).collect();
    let k_lim = lim.len();

    // Multiplicity items: q members of class c at max capacity. `reserve`
    // holds back one member (the intermediary) for the reduced DPs.
    let items_for = |c: usize, reserve: usize| -> Vec<Item> {
        let u = view.cap(c);
        let m = view.count(c) - reserve;
        let q_max = if u == 0 { 0 } else { m.min(t_total / u) };
        (0..=q_max)
            .map(|q| Item { weight: q * u, cost: q as f64 * cost(c, u) })
            .collect()
    };
    let classes = Classes {
        classes: lim.iter().map(|&c| items_for(c, 0)).collect(),
    };

    let mut best_cost = f64::INFINITY;
    let mut best: Option<Vec<Vec<(usize, usize)>>> = None;

    // Backtrack a DP solution filling exactly `tau` into class groups
    // (chosen item index == q because items are enumerated by q).
    let translate = |m: &DpMatrices,
                     cls: &Classes,
                     intermediary: Option<(usize, usize)>,
                     tau: usize|
     -> Result<Vec<Vec<(usize, usize)>>> {
        let chosen = m.backtrack(cls, tau)?;
        let mut groups: Vec<Vec<(usize, usize)>> =
            (0..k).map(|c| vec![(0, view.count(c))]).collect();
        for (ci, &q) in chosen.iter().enumerate() {
            let c = lim[ci];
            let u = view.cap(c);
            groups[c] = vec![(u, q), (0, view.count(c) - q)];
        }
        if let Some((c, t)) = intermediary {
            // One reserved/unlimited member at load `t`; the full-capacity
            // count `q` of that class never exceeds `count − 1` here.
            let g = &mut groups[c];
            let (_, idle) = g.pop().expect("groups always end with the idle run");
            g.push((t, 1));
            g.push((0, idle - 1));
        }
        Ok(groups)
    };

    // DP over the full limited set — phase 1 and the "no intermediary"
    // candidate.
    let m_full = dp(&classes, t_total);
    if m_full.z(k_lim, t_total).is_finite() {
        let c = m_full.z(k_lim, t_total);
        if c < best_cost {
            best_cost = c;
            best = Some(translate(&m_full, &classes, None, t_total)?);
        }
    }

    // One member of an unlimited class at intermediary capacity t.
    if !unl.is_empty() {
        for t in 0..=t_total {
            let rest = m_full.z(k_lim, t_total - t);
            if !rest.is_finite() {
                continue;
            }
            let mut kc = unl[0];
            let mut ck = cost(kc, t);
            for &c in &unl[1..] {
                let cc = cost(c, t);
                if cc < ck {
                    ck = cc;
                    kc = c;
                }
            }
            let total = ck + rest;
            if total < best_cost {
                best_cost = total;
                best = Some(translate(
                    &m_full,
                    &classes,
                    Some((kc, t)),
                    t_total - t,
                )?);
            }
        }
    }

    // One member of a limited class at intermediary capacity — one DP per
    // *class* (members are interchangeable), reserving the intermediary.
    for (ci, &c) in lim.iter().enumerate() {
        let mut reduced = classes.clone();
        reduced.classes[ci] = items_for(c, 1);
        let m_red = dp(&reduced, t_total);
        for t in 0..view.cap(c) {
            let rest = m_red.z(k_lim, t_total - t);
            if !rest.is_finite() {
                continue;
            }
            let total = cost(c, t) + rest;
            if total < best_cost {
                best_cost = total;
                best = Some(translate(
                    &m_red,
                    &reduced,
                    Some((c, t)),
                    t_total - t,
                )?);
            }
        }
    }

    best.ok_or_else(|| {
        FedError::Infeasible("MarDec found no candidate on a valid instance".into())
    })
}

/// Run MarDec on a class-deduplicated fleet (same optimality contract as
/// [`solve`]).
pub fn solve_fleet(fleet: &FleetInstance) -> Result<Assignment> {
    fleet.validate()?;
    let view = LowerFree::of(fleet);
    let groups = solve_view(&view)?;
    Ok(Assignment::from_groups(view.restore(groups)))
}

/// Algorithm 7 (Translate): backtrack the DP solution filling exactly
/// `tau` into a partial schedule over all `n` resources (unlisted
/// resources get 0).
fn translate(
    m: &DpMatrices,
    classes: &Classes,
    gamma: &[usize],
    n: usize,
    tau: usize,
) -> Result<Schedule> {
    let chosen = m.backtrack(classes, tau)?;
    let mut x = Schedule::zeros(n);
    for (ci, &item_idx) in chosen.iter().enumerate() {
        x.set(gamma[ci], classes.classes[ci][item_idx].weight);
    }
    Ok(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::costs::CostFn;
    use crate::sched::{mardecun, mc2mkp, validate};
    use crate::util::rng::Rng;

    fn concave(rng: &mut Rng) -> CostFn {
        if rng.bool(0.5) {
            CostFn::PowerLaw {
                fixed: rng.range_f64(0.0, 1.0),
                scale: rng.range_f64(0.5, 4.0),
                exponent: rng.range_f64(0.2, 0.95),
            }
        } else {
            CostFn::Logarithmic {
                fixed: rng.range_f64(0.0, 1.0),
                scale: rng.range_f64(0.5, 4.0),
            }
        }
    }

    #[test]
    fn concentrates_up_to_limits() {
        // Cheapest concave resource is capped at 4; next-cheapest absorbs
        // the remainder.
        let inst = Instance::new(
            10,
            vec![0, 0, 0],
            vec![4, 10, 10],
            vec![
                CostFn::PowerLaw { fixed: 0.0, scale: 1.0, exponent: 0.5 },
                CostFn::PowerLaw { fixed: 0.0, scale: 3.0, exponent: 0.5 },
                CostFn::PowerLaw { fixed: 0.0, scale: 10.0, exponent: 0.5 },
            ],
        )
        .unwrap();
        let s = solve(&inst).unwrap();
        validate::check(&inst, &s).unwrap();
        let c = validate::total_cost(&inst, &s);
        let c_dp = validate::total_cost(&inst, &mc2mkp::solve(&inst).unwrap());
        assert!((c - c_dp).abs() < 1e-9, "MarDec {c} != DP {c_dp}");
    }

    #[test]
    fn matches_mardecun_when_unlimited() {
        let mut rng = Rng::new(77);
        for _ in 0..20 {
            let n = 2 + rng.index(4);
            let t = 5 + rng.index(30);
            let costs: Vec<CostFn> = (0..n).map(|_| concave(&mut rng)).collect();
            let inst = Instance::new(t, vec![0; n], vec![t + 5; n], costs).unwrap();
            let a = validate::checked_cost(&inst, &solve(&inst).unwrap()).unwrap();
            let b =
                validate::checked_cost(&inst, &mardecun::solve(&inst).unwrap()).unwrap();
            assert!((a - b).abs() < 1e-9, "MarDec {a} != MarDecUn {b}");
        }
    }

    #[test]
    fn fleet_matches_flat_on_multiplicity_classes() {
        use crate::sched::fleet::FleetInstance;
        let mut rng = Rng::new(0xF1DE);
        for _case in 0..15 {
            let t = 8 + rng.index(20);
            let c1 = concave(&mut rng);
            let c2 = concave(&mut rng);
            let u1 = 2 + rng.index(t / 2 + 1);
            let fleet = FleetInstance::builder()
                .tasks(t)
                .device_class(c1, 0, u1, 3)
                .device_class(c2, 0, t + 3, 2)
                .build()
                .unwrap();
            let asg = solve_fleet(&fleet).unwrap();
            asg.check(&fleet).unwrap();
            let flat = fleet.to_flat();
            let c_flat =
                validate::checked_cost(&flat, &solve(&flat).unwrap()).unwrap();
            let c_dp =
                validate::checked_cost(&flat, &mc2mkp::solve(&flat).unwrap())
                    .unwrap();
            let c_fleet = asg.total_cost(&fleet);
            assert!(
                (c_fleet - c_flat).abs() < 1e-9,
                "fleet {c_fleet} != flat {c_flat}"
            );
            assert!(
                (c_fleet - c_dp).abs() < 1e-9,
                "fleet {c_fleet} != dp {c_dp}"
            );
        }
    }

    #[test]
    fn matches_dp_on_random_concave_instances() {
        let mut rng = Rng::new(0x3A3);
        let mut tested = 0;
        while tested < 60 {
            let n = 2 + rng.index(4);
            let t = 5 + rng.index(40);
            let mut lower = Vec::new();
            let mut upper = Vec::new();
            let mut costs = Vec::new();
            for _ in 0..n {
                lower.push(rng.index(3));
                upper.push(2 + rng.index(t + 4));
                costs.push(concave(&mut rng));
            }
            let sum_l: usize = lower.iter().sum();
            let sum_u: usize = upper.iter().map(|&u| u.min(t)).sum();
            if sum_l > t || sum_u < t || lower.iter().zip(&upper).any(|(l, u)| l > u) {
                continue;
            }
            tested += 1;
            let inst = Instance::new(t, lower, upper, costs).unwrap();
            let a = validate::checked_cost(&inst, &solve(&inst).unwrap()).unwrap();
            let b = validate::checked_cost(&inst, &mc2mkp::solve(&inst).unwrap()).unwrap();
            assert!((a - b).abs() < 1e-9, "MarDec {a} != DP {b} on {inst:?}");
        }
    }

    #[test]
    fn all_resources_at_exact_max() {
        // ΣU == T and no unlimited resources: the only feasible schedule is
        // everyone at max (the shape the paper's loops reach only via the
        // explicit no-intermediary candidate).
        let inst = Instance::new(
            9,
            vec![0, 0, 0],
            vec![2, 3, 4],
            vec![
                CostFn::PowerLaw { fixed: 0.0, scale: 1.0, exponent: 0.5 },
                CostFn::PowerLaw { fixed: 0.0, scale: 2.0, exponent: 0.5 },
                CostFn::PowerLaw { fixed: 0.0, scale: 3.0, exponent: 0.5 },
            ],
        )
        .unwrap();
        let s = solve(&inst).unwrap();
        assert_eq!(s.assignments(), &[2, 3, 4]);
    }

    #[test]
    fn nonzero_idle_cost_handled() {
        // Resources with C(0) > 0 (idle energy): normalization must keep
        // the argmin correct vs the DP.
        let inst = Instance::new(
            6,
            vec![0, 0],
            vec![4, 6],
            vec![
                CostFn::PowerLaw { fixed: 5.0, scale: 1.0, exponent: 0.5 },
                CostFn::PowerLaw { fixed: 0.5, scale: 2.0, exponent: 0.5 },
            ],
        )
        .unwrap();
        let a = validate::checked_cost(&inst, &solve(&inst).unwrap()).unwrap();
        let b = validate::checked_cost(&inst, &mc2mkp::solve(&inst).unwrap()).unwrap();
        assert!((a - b).abs() < 1e-9);
    }

    #[test]
    fn lower_limits_respected() {
        let inst = Instance::new(
            12,
            vec![3, 0, 1],
            vec![5, 8, 12],
            vec![
                CostFn::Logarithmic { fixed: 0.0, scale: 8.0 },
                CostFn::Logarithmic { fixed: 0.0, scale: 1.0 },
                CostFn::Logarithmic { fixed: 0.0, scale: 4.0 },
            ],
        )
        .unwrap();
        let s = solve(&inst).unwrap();
        validate::check(&inst, &s).unwrap();
        let b = validate::checked_cost(&inst, &mc2mkp::solve(&inst).unwrap()).unwrap();
        let a = validate::total_cost(&inst, &s);
        assert!((a - b).abs() < 1e-9);
    }
}
