//! The paper's contribution: the **Minimal Cost FL Schedule** problem and
//! its optimal solvers.
//!
//! * [`instance`] — flat problem model `(R, T, U, L, C)` (paper §3, Def. 1).
//! * [`fleet`] — fleet-scale model: device classes with multiplicities
//!   ([`fleet::FleetInstance`]), lazy cost evaluation
//!   ([`fleet::CostView`]), and class-level decisions
//!   ([`fleet::Assignment`]) — the primary [`solver::Solver`] input.
//! * [`costs`] — cost-function library + marginal costs (paper §5.1, Def. 3).
//! * [`limits`] — lower-limit removal transformation (paper §5.2, eqs. 8–11).
//! * [`mc2mkp`] — Algorithm 1: the (MC)²MKP dynamic program (paper §4).
//! * [`marin`] — Algorithm 2: increasing marginal costs (paper §5.3).
//! * [`marco`] — Algorithm 3: constant marginal costs (paper §5.4).
//! * [`mardecun`] — Algorithm 4: decreasing marginal costs, no upper limits
//!   (paper §5.5).
//! * [`mardec`] — Algorithms 5–7: decreasing marginal costs with upper
//!   limits (paper §5.6).
//! * [`shard`] — sharded instance construction for 10⁵–10⁶-device
//!   fleets: partition → per-shard class dedup → exact cross-shard merge
//!   (bit-for-bit equal to the unsharded build; the scoped-thread driver
//!   is [`crate::runtime::pool`]).
//! * [`incremental`] — persistent device→class index for incremental
//!   round re-derivation: `O(selected + changed)` per-round instance
//!   builds that stay bit-for-bit equal to the from-scratch build.
//! * [`auto`] — Table 2 classification: scenario of an instance and the
//!   name of the cheapest optimal algorithm for it.
//! * [`solver`] — the [`solver::Solver`] trait and
//!   [`solver::SolverRegistry`]: the single dispatch seam through which
//!   every algorithm (optimal, oracle, baseline) is reached.
//! * [`baselines`] — non-optimal comparison policies (uniform, random,
//!   proportional, greedy) and OLAR (makespan-optimal, [26]).
//! * [`bruteforce`] — exhaustive oracle used by the test-suite.
//! * [`validate`] — feasibility checks and total-cost evaluation.

pub mod auto;
pub mod solver;
pub mod baselines;
pub mod bruteforce;
pub mod costs;
pub mod fleet;
pub mod incremental;
pub mod instance;
pub mod limits;
pub mod marco;
pub mod mardec;
pub mod pareto;
pub mod shard;
pub mod mardecun;
pub mod marin;
pub mod mc2mkp;
pub mod validate;

pub use fleet::{Assignment, CostView, FleetInstance};
pub use instance::{Instance, Schedule};
pub use solver::{Solver, SolverRegistry};
