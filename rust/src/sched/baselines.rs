//! Baseline scheduling policies used in the comparison experiments.
//!
//! None of these minimize the total cost in general — they are the
//! comparison points the paper's algorithms are evaluated against
//! (EXPERIMENTS.md §EX-A):
//!
//! * [`uniform`] — even split (what vanilla FedAvg [1] does implicitly when
//!   every device trains on all its data for the same number of epochs);
//! * [`random`] — random feasible assignment;
//! * [`proportional`] — workload proportional to each device's energy
//!   efficiency at unit load (a common heuristic);
//! * [`greedy_cost`] — incremental greedy on marginal costs *without* regime
//!   awareness: identical to MarIn, but applied blindly. Optimal for
//!   increasing marginals, arbitrarily bad for decreasing ones — the paper's
//!   §3.1 insight made executable;
//! * [`olar`] — OLAR [26]: optimal for **minimizing the maximum** cost
//!   (makespan/round duration). Included to quantify how much total energy a
//!   time-optimal schedule wastes.
//!
//! All baselines respect the instance's lower and upper limits (they are
//! feasible policies, just not total-cost-optimal).

use crate::error::Result;
use crate::sched::instance::{Instance, Schedule};
use crate::sched::limits;
use crate::util::heap::MinHeap;
use crate::util::rng::Rng;

/// Even split: start from the lower limits and hand out remaining tasks
/// round-robin to resources below their caps.
pub fn uniform(inst: &Instance) -> Result<Schedule> {
    inst.validate()?;
    let n = inst.n();
    let mut x = inst.lower.clone();
    let mut remaining = inst.tasks - x.iter().sum::<usize>();
    while remaining > 0 {
        let mut progressed = false;
        for i in 0..n {
            if remaining == 0 {
                break;
            }
            if x[i] < inst.cap(i) {
                x[i] += 1;
                remaining -= 1;
                progressed = true;
            }
        }
        debug_assert!(progressed, "valid instance: capacity must remain");
        if !progressed {
            break;
        }
    }
    Ok(Schedule::new(x))
}

/// Random feasible assignment: distribute the free tasks one by one to
/// uniformly random resources with remaining capacity.
pub fn random(inst: &Instance, rng: &mut Rng) -> Result<Schedule> {
    inst.validate()?;
    let n = inst.n();
    let mut x = inst.lower.clone();
    let mut open: Vec<usize> = (0..n).filter(|&i| x[i] < inst.cap(i)).collect();
    let mut remaining = inst.tasks - x.iter().sum::<usize>();
    while remaining > 0 {
        let pick = rng.index(open.len());
        let i = open[pick];
        x[i] += 1;
        remaining -= 1;
        if x[i] == inst.cap(i) {
            open.swap_remove(pick);
        }
    }
    Ok(Schedule::new(x))
}

/// Workload proportional to energy efficiency at unit load: weight
/// `1 / M_i(L_i + 1)` (cheaper-per-task devices get more), then repair to
/// meet `Σ x_i = T` within limits.
pub fn proportional(inst: &Instance) -> Result<Schedule> {
    inst.validate()?;
    let n = inst.n();
    let free = inst.tasks - inst.lower.iter().sum::<usize>();

    // Per-task cost at the first free task; guard zero marginals.
    let weights: Vec<f64> = (0..n)
        .map(|i| {
            if inst.cap(i) <= inst.lower[i] {
                return 0.0;
            }
            let m = inst.costs[i].eval(inst.lower[i] + 1) - inst.costs[i].eval(inst.lower[i]);
            1.0 / m.max(1e-12)
        })
        .collect();
    let wsum: f64 = weights.iter().sum();

    let mut x = inst.lower.clone();
    if wsum > 0.0 {
        // Largest-remainder apportionment of `free` tasks.
        let shares: Vec<f64> = weights.iter().map(|w| w / wsum * free as f64).collect();
        let mut given = 0usize;
        let mut rema: Vec<(f64, usize)> = Vec::with_capacity(n);
        for i in 0..n {
            let slack = inst.cap(i) - x[i];
            let give = (shares[i].floor() as usize).min(slack);
            x[i] += give;
            given += give;
            rema.push((shares[i] - shares[i].floor(), i));
        }
        rema.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let mut idx = 0;
        while given < free && idx < rema.len() * 2 {
            let i = rema[idx % rema.len()].1;
            if x[i] < inst.cap(i) {
                x[i] += 1;
                given += 1;
            }
            idx += 1;
        }
        // Final repair sweep if rounding still left tasks unassigned.
        let mut i = 0;
        while given < free {
            if x[i] < inst.cap(i) {
                x[i] += 1;
                given += 1;
            } else {
                i = (i + 1) % n;
                continue;
            }
        }
    }
    Ok(Schedule::new(x))
}

/// Regime-blind incremental greedy on marginal costs (the paper's Fig. 2
/// counterexample shows this is not optimal in general — optimal only when
/// marginals are increasing, where it coincides with MarIn).
pub fn greedy_cost(inst: &Instance) -> Result<Schedule> {
    // Identical machinery to MarIn, intentionally applied regardless of the
    // marginal regime.
    crate::sched::marin::solve(inst)
}

/// OLAR [26]: assigns each of the `T` tasks to the resource whose
/// *resulting* cost `C_i(x_i + 1)` is smallest — the greedy that minimizes
/// the **maximum** per-resource cost (round makespan), not the total.
pub fn olar(inst: &Instance) -> Result<Schedule> {
    inst.validate()?;
    let tr = limits::remove_lower_limits(inst);
    let ti = &tr.instance;
    let n = ti.n();
    let mut x = vec![0usize; n];

    let mut heap: MinHeap<usize> = MinHeap::with_capacity(n);
    for i in 0..n {
        if ti.cap(i) > 0 {
            heap.push(ti.costs[i].eval(1), i as u64, i);
        }
    }
    for _ in 0..ti.tasks {
        let e = heap.pop().expect("capacity remains");
        let i = e.value;
        x[i] += 1;
        if x[i] < ti.cap(i) {
            heap.push(ti.costs[i].eval(x[i] + 1), i as u64, i);
        }
    }
    Ok(tr.restore(&Schedule::new(x)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::costs::CostFn;
    use crate::sched::{mc2mkp, validate};
    use crate::util::rng::Rng;

    fn paper5() -> Instance {
        Instance::paper_example(5)
    }

    #[test]
    fn all_baselines_feasible_on_paper_example() {
        let inst = paper5();
        let mut rng = Rng::new(1);
        for s in [
            uniform(&inst).unwrap(),
            random(&inst, &mut rng).unwrap(),
            proportional(&inst).unwrap(),
            greedy_cost(&inst).unwrap(),
            olar(&inst).unwrap(),
        ] {
            validate::check(&inst, &s).unwrap();
        }
    }

    #[test]
    fn baselines_never_beat_optimal() {
        let mut rng = Rng::new(0xBA5E);
        for seed in 0..30u64 {
            let mut r = Rng::new(seed);
            let n = 2 + r.index(4);
            let t = 8 + r.index(40);
            let mut lower = Vec::new();
            let mut upper = Vec::new();
            let mut costs = Vec::new();
            for _ in 0..n {
                lower.push(r.index(2));
                upper.push(4 + r.index(t));
                costs.push(CostFn::Quadratic {
                    fixed: r.range_f64(0.0, 1.0),
                    a: r.range_f64(0.0, 1.0),
                    b: r.range_f64(0.1, 3.0),
                });
            }
            let sum_l: usize = lower.iter().sum();
            let sum_u: usize = upper.iter().map(|&u| u.min(t)).sum();
            if sum_l > t || sum_u < t {
                continue;
            }
            let inst = Instance::new(t, lower, upper, costs).unwrap();
            let opt = validate::checked_cost(&inst, &mc2mkp::solve(&inst).unwrap()).unwrap();
            for s in [
                uniform(&inst).unwrap(),
                random(&inst, &mut rng).unwrap(),
                proportional(&inst).unwrap(),
                olar(&inst).unwrap(),
            ] {
                let c = validate::checked_cost(&inst, &s).unwrap();
                assert!(c >= opt - 1e-9, "baseline beat optimal: {c} < {opt}");
            }
        }
    }

    #[test]
    fn greedy_suboptimal_on_decreasing() {
        // Marginal-greedy follows the locally cheapest marginal: resource 0
        // (constant 0.9/task) always beats resource 1's *first* marginal
        // (1.0), so greedy never discovers that concentrating on the
        // concave resource 1 costs only √T. This is the paper's §3.1
        // insight ("simple greedy algorithms will not find optimal
        // schedules") made executable.
        let a = CostFn::Affine { fixed: 0.0, per_task: 0.9 };
        let b = CostFn::PowerLaw { fixed: 0.0, scale: 1.0, exponent: 0.5 };
        let inst = Instance::new(16, vec![0, 0], vec![16, 16], vec![a, b]).unwrap();
        let g = validate::checked_cost(&inst, &greedy_cost(&inst).unwrap()).unwrap();
        let opt = validate::checked_cost(&inst, &mc2mkp::solve(&inst).unwrap()).unwrap();
        assert!(g > opt + 0.1, "greedy {g} should be worse than optimal {opt}");
    }

    #[test]
    fn olar_minimizes_makespan_not_total() {
        // Identical affine resources: OLAR balances (min makespan), while
        // total-cost optimum is any full assignment; both totals equal here,
        // but the max differs from a concentrated schedule.
        let c = CostFn::Affine { fixed: 0.0, per_task: 1.0 };
        let inst = Instance::new(8, vec![0, 0], vec![8, 8], vec![c.clone(), c]).unwrap();
        let s = olar(&inst).unwrap();
        assert_eq!(s.assignments(), &[4, 4]);
        let conc = Schedule::new(vec![8, 0]);
        assert!(validate::max_cost(&inst, &s) < validate::max_cost(&inst, &conc));
    }

    #[test]
    fn uniform_respects_unequal_caps() {
        let inst = Instance::new(
            10,
            vec![0, 0, 0],
            vec![2, 3, 100],
            vec![
                CostFn::Affine { fixed: 0.0, per_task: 1.0 },
                CostFn::Affine { fixed: 0.0, per_task: 1.0 },
                CostFn::Affine { fixed: 0.0, per_task: 1.0 },
            ],
        )
        .unwrap();
        let s = uniform(&inst).unwrap();
        validate::check(&inst, &s).unwrap();
        assert_eq!(s.assignments(), &[2, 3, 5]);
    }

    #[test]
    fn proportional_weights_by_efficiency() {
        let inst = Instance::new(
            12,
            vec![0, 0],
            vec![12, 12],
            vec![
                CostFn::Affine { fixed: 0.0, per_task: 1.0 },
                CostFn::Affine { fixed: 0.0, per_task: 3.0 },
            ],
        )
        .unwrap();
        let s = proportional(&inst).unwrap();
        validate::check(&inst, &s).unwrap();
        // weights 1 : 1/3 → 9 : 3
        assert_eq!(s.assignments(), &[9, 3]);
    }

    #[test]
    fn random_is_deterministic_per_seed() {
        let inst = paper5();
        let a = random(&inst, &mut Rng::new(9)).unwrap();
        let b = random(&inst, &mut Rng::new(9)).unwrap();
        assert_eq!(a, b);
    }
}
