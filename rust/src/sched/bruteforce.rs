//! Exhaustive search oracle.
//!
//! Enumerates every feasible schedule and returns a global optimum. Used by
//! the property-based test-suite to certify the optimality claims of the
//! paper's algorithms on small instances ("proof by exhaustion" as an
//! executable check of Theorems 1–5). Exponential — intended for
//! `n <= ~6`, `T <= ~40`.

use crate::error::{FedError, Result};
use crate::sched::instance::{Instance, Schedule};

/// Find an optimal schedule by exhaustive enumeration (with branch-and-bound
/// pruning on remaining-capacity feasibility).
pub fn solve(inst: &Instance) -> Result<Schedule> {
    inst.validate()?;
    let n = inst.n();
    // Suffix sums of lower and effective-upper limits for pruning.
    let mut suffix_l = vec![0usize; n + 1];
    let mut suffix_u = vec![0usize; n + 1];
    for i in (0..n).rev() {
        suffix_l[i] = suffix_l[i + 1] + inst.lower[i];
        suffix_u[i] = suffix_u[i + 1] + inst.cap(i);
    }

    let mut best_cost = f64::INFINITY;
    let mut best: Option<Vec<usize>> = None;
    let mut cur = vec![0usize; n];

    fn rec(
        inst: &Instance,
        suffix_l: &[usize],
        suffix_u: &[usize],
        i: usize,
        remaining: usize,
        cost_so_far: f64,
        cur: &mut Vec<usize>,
        best_cost: &mut f64,
        best: &mut Option<Vec<usize>>,
    ) {
        if i == inst.n() {
            if remaining == 0 && cost_so_far < *best_cost {
                *best_cost = cost_so_far;
                *best = Some(cur.clone());
            }
            return;
        }
        // x_i must leave a feasible remainder for resources i+1..n.
        let lo = inst.lower[i].max(remaining.saturating_sub(suffix_u[i + 1]));
        let hi = inst.cap(i).min(remaining.saturating_sub(suffix_l[i + 1]));
        if lo > hi {
            return;
        }
        for x in lo..=hi {
            let c = cost_so_far + inst.costs[i].eval(x);
            if c >= *best_cost {
                // all costs are non-negative → prune
                continue;
            }
            cur[i] = x;
            rec(inst, suffix_l, suffix_u, i + 1, remaining - x, c, cur, best_cost, best);
        }
        cur[i] = 0;
    }

    rec(
        inst,
        &suffix_l,
        &suffix_u,
        0,
        inst.tasks,
        0.0,
        &mut cur,
        &mut best_cost,
        &mut best,
    );

    best.map(Schedule::new)
        .ok_or_else(|| FedError::Infeasible("brute force found no schedule".into()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{mc2mkp, validate};

    #[test]
    fn paper_examples_agree_with_dp() {
        for t in [5usize, 8] {
            let inst = Instance::paper_example(t);
            let bf = solve(&inst).unwrap();
            let dp = mc2mkp::solve(&inst).unwrap();
            let cb = validate::checked_cost(&inst, &bf).unwrap();
            let cd = validate::checked_cost(&inst, &dp).unwrap();
            assert!((cb - cd).abs() < 1e-12, "T={t}: bf {cb} != dp {cd}");
        }
    }

    #[test]
    fn exact_on_tiny_instance() {
        use crate::sched::costs::CostFn;
        let inst = Instance::new(
            3,
            vec![0, 0],
            vec![3, 3],
            vec![
                CostFn::from_table(&[(0, 0.0), (1, 10.0), (2, 11.0), (3, 12.0)]),
                CostFn::from_table(&[(0, 0.0), (1, 1.0), (2, 9.0), (3, 30.0)]),
            ],
        )
        .unwrap();
        let s = solve(&inst).unwrap();
        // best: x = {2, 1} → 11 + 1 = 12  (vs {3,0}=12? C1(3)=12 — tie)
        let c = validate::checked_cost(&inst, &s).unwrap();
        assert!((c - 12.0).abs() < 1e-12);
    }

    #[test]
    fn prunes_but_stays_exact_with_lower_limits() {
        use crate::sched::costs::CostFn;
        let inst = Instance::new(
            6,
            vec![2, 1, 0],
            vec![4, 5, 6],
            vec![
                CostFn::Affine { fixed: 0.0, per_task: 3.0 },
                CostFn::Affine { fixed: 0.0, per_task: 1.0 },
                CostFn::Affine { fixed: 0.0, per_task: 2.0 },
            ],
        )
        .unwrap();
        let s = solve(&inst).unwrap();
        validate::check(&inst, &s).unwrap();
        // lower limits force {2,1,0}; the 3 free tasks go to resource 1.
        assert_eq!(s.assignments(), &[2, 4, 0]);
    }
}
