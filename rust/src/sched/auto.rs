//! Scenario classification and algorithm dispatch — the executable form of
//! the paper's **Table 2** ("solutions with the smallest complexity for the
//! variations of our scheduling problem").
//!
//! | scenario                    | algorithm  | complexity       |
//! |-----------------------------|------------|------------------|
//! | arbitrary costs             | (MC)²MKP   | `O(T² n)`        |
//! | increasing marginal costs   | MarIn      | `Θ(n + T log n)` |
//! | constant marginal costs     | MarCo      | `Θ(n log n)`     |
//! | decreasing, no upper limits | MarDecUn   | `Θ(n)`           |
//! | decreasing, upper limits    | MarDec     | `O(T n²)`        |
//!
//! Dispatch itself lives behind the [`crate::sched::solver`] seam: this
//! module classifies instances ([`classify_instance`]) and names the
//! cheapest optimal algorithm ([`best_algorithm`]); the
//! [`crate::sched::solver::SolverRegistry`] (or the registered `auto`
//! solver) turns that name into a solve.

use crate::error::Result;
use crate::sched::costs::{classify, classify_marginals, combine, MarginalRegime};
use crate::sched::fleet::{CostView, FleetInstance, LowerFree};
use crate::sched::instance::{Instance, Schedule};
use crate::sched::limits;

/// The scenario of an instance: its combined marginal regime plus whether
/// any resource has an effective upper limit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Scenario {
    pub regime: MarginalRegime,
    pub has_upper_limits: bool,
}

/// Classify an instance. Classification samples every resource's domain, so
/// it is `O(Σ(U_i - L_i))` — cheap next to any solver except MarDecUn/MarCo
/// on huge domains; callers in hot loops can classify once and reuse the
/// scenario via [`best_algorithm`].
pub fn classify_instance(inst: &Instance) -> Scenario {
    let tr = limits::remove_lower_limits(inst);
    let ti = &tr.instance;
    let regimes: Vec<MarginalRegime> = (0..ti.n())
        .map(|i| classify(&ti.costs[i], 0, ti.cap(i)))
        .collect();
    Scenario {
        regime: combine(&regimes),
        has_upper_limits: (0..ti.n()).any(|i| ti.cap(i) < ti.tasks),
    }
}

/// The five canonical Table 2 scenario rows with short labels — shared by
/// the `solvers` CLI matrix and the registry's `--algo` error text.
pub const TABLE2_SCENARIOS: [(&str, Scenario); 5] = [
    ("arb", Scenario { regime: MarginalRegime::Arbitrary, has_upper_limits: true }),
    ("inc", Scenario { regime: MarginalRegime::Increasing, has_upper_limits: true }),
    ("con", Scenario { regime: MarginalRegime::Constant, has_upper_limits: true }),
    ("dec", Scenario { regime: MarginalRegime::Decreasing, has_upper_limits: true }),
    ("dec∞", Scenario { regime: MarginalRegime::Decreasing, has_upper_limits: false }),
];

/// Classify one class of a (lower-limit-free) view over `[0, cap]` —
/// Definition 3 evaluated lazily through [`CostView`], sharing the
/// tolerance core ([`classify_marginals`]) with [`classify`].
fn classify_class<V: CostView + ?Sized>(view: &V, c: usize) -> MarginalRegime {
    let upper = view.cap(c);
    classify_marginals((1..=upper).map(|j| view.eval(c, j) - view.eval(c, j - 1)))
}

/// Classify a class-deduplicated fleet: one regime sample per **class**
/// (`O(Σ_c (U_c − L_c))` — independent of multiplicities), combined
/// exactly like [`classify_instance`].
pub fn classify_fleet(fleet: &FleetInstance) -> Scenario {
    let view = LowerFree::of(fleet);
    let regimes: Vec<MarginalRegime> = (0..view.n_classes())
        .map(|c| classify_class(&view, c))
        .collect();
    Scenario {
        regime: combine(&regimes),
        has_upper_limits: (0..view.n_classes())
            .any(|c| view.cap(c) < view.tasks()),
    }
}

/// Name of the cheapest optimal algorithm for a scenario (Table 2). The
/// name resolves through the
/// [`SolverRegistry`](crate::sched::solver::SolverRegistry).
pub fn best_algorithm(s: &Scenario) -> &'static str {
    match (s.regime, s.has_upper_limits) {
        (MarginalRegime::Constant, false) => "mardecun", // Table 2: Θ(n)
        (MarginalRegime::Constant, true) => "marco",
        (MarginalRegime::Increasing, _) => "marin",
        (MarginalRegime::Decreasing, false) => "mardecun",
        (MarginalRegime::Decreasing, true) => "mardec",
        (MarginalRegime::Arbitrary, _) => "mc2mkp",
    }
}

/// Classify + dispatch (the `auto` policy) as a plain function — usable as
/// a `fn(&Instance) -> Result<Schedule>` pointer. Identical to solving
/// through the registry's `auto` entry on a flat instance.
pub fn solve_auto(inst: &Instance) -> Result<Schedule> {
    crate::sched::solver::AutoSolver.solve_flat(inst)
}

// Re-exported so `use crate::sched::auto::...` call sites keep compiling
// while the trait lives in `solver`.
pub use crate::sched::solver::Solver;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::costs::CostFn;
    use crate::sched::{mc2mkp, validate};

    fn instance_with(costs: Vec<CostFn>, t: usize, upper: Vec<usize>) -> Instance {
        let n = costs.len();
        Instance::new(t, vec![0; n], upper, costs).unwrap()
    }

    #[test]
    fn classifies_paper_example_as_arbitrary() {
        let s = classify_instance(&Instance::paper_example(5));
        assert_eq!(s.regime, MarginalRegime::Arbitrary);
        // After lower-limit removal T' = 4 and every U'_i >= 4, so no limit
        // binds in the transformed space — but the arbitrary regime routes
        // to the DP regardless.
        assert!(!s.has_upper_limits);
        assert_eq!(best_algorithm(&s), "mc2mkp");
        // With T = 8 the limits do bind.
        let s8 = classify_instance(&Instance::paper_example(8));
        assert!(s8.has_upper_limits);
    }

    #[test]
    fn classifies_affine_constant() {
        let c = CostFn::Affine { fixed: 1.0, per_task: 2.0 };
        let inst = instance_with(vec![c.clone(), c], 10, vec![8, 8]);
        let s = classify_instance(&inst);
        assert_eq!(s.regime, MarginalRegime::Constant);
        assert!(s.has_upper_limits);
        assert_eq!(best_algorithm(&s), "marco");
    }

    #[test]
    fn constant_without_limits_uses_mardecun() {
        let c = CostFn::Affine { fixed: 0.0, per_task: 2.0 };
        let inst = instance_with(vec![c.clone(), c], 10, vec![20, 20]);
        let s = classify_instance(&inst);
        assert_eq!(best_algorithm(&s), "mardecun");
        // and it is exact: all tasks on either resource cost the same
        let x = solve_auto(&inst).unwrap();
        validate::check(&inst, &x).unwrap();
    }

    #[test]
    fn classifies_quadratic_increasing() {
        let c = CostFn::Quadratic { fixed: 0.0, a: 1.0, b: 0.0 };
        let inst = instance_with(vec![c.clone(), c], 10, vec![10, 10]);
        assert_eq!(classify_instance(&inst).regime, MarginalRegime::Increasing);
        assert_eq!(best_algorithm(&classify_instance(&inst)), "marin");
    }

    #[test]
    fn classifies_decreasing_with_and_without_limits() {
        let c = CostFn::PowerLaw { fixed: 0.0, scale: 1.0, exponent: 0.5 };
        let unl = instance_with(vec![c.clone(), c.clone()], 10, vec![30, 30]);
        let lim = instance_with(vec![c.clone(), c], 10, vec![6, 6]);
        assert_eq!(best_algorithm(&classify_instance(&unl)), "mardecun");
        assert_eq!(best_algorithm(&classify_instance(&lim)), "mardec");
    }

    #[test]
    fn mixed_regimes_fall_back_to_dp() {
        let inc = CostFn::Quadratic { fixed: 0.0, a: 1.0, b: 0.0 };
        let dec = CostFn::PowerLaw { fixed: 0.0, scale: 1.0, exponent: 0.5 };
        let inst = instance_with(vec![inc, dec], 10, vec![10, 10]);
        assert_eq!(best_algorithm(&classify_instance(&inst)), "mc2mkp");
    }

    #[test]
    fn auto_matches_dp_across_regimes() {
        let cases: Vec<Instance> = vec![
            Instance::paper_example(5),
            Instance::paper_example(8),
            instance_with(
                vec![
                    CostFn::Quadratic { fixed: 0.0, a: 0.5, b: 1.0 },
                    CostFn::Quadratic { fixed: 1.0, a: 0.2, b: 2.0 },
                ],
                12,
                vec![12, 12],
            ),
            instance_with(
                vec![
                    CostFn::Affine { fixed: 0.0, per_task: 1.0 },
                    CostFn::Affine { fixed: 0.0, per_task: 3.0 },
                ],
                12,
                vec![8, 8],
            ),
            instance_with(
                vec![
                    CostFn::Logarithmic { fixed: 0.0, scale: 3.0 },
                    CostFn::Logarithmic { fixed: 0.0, scale: 1.0 },
                ],
                12,
                vec![7, 12],
            ),
        ];
        for inst in cases {
            let a = validate::checked_cost(&inst, &solve_auto(&inst).unwrap()).unwrap();
            let d =
                validate::checked_cost(&inst, &mc2mkp::solve(&inst).unwrap()).unwrap();
            assert!((a - d).abs() < 1e-9, "auto {a} != dp {d}");
        }
    }
}
