//! Cost functions `C_i : [L_i, U_i] → R≥0` and marginal costs (paper §5.1).
//!
//! The paper treats each resource's energy consumption as a black-box cost
//! function of the number of assigned tasks. This module provides the
//! families used throughout the reproduction:
//!
//! * **Affine** — constant marginal costs (the common literature model
//!   [16]–[22]: energy linear in work);
//! * **Quadratic** / **PowerLaw(e>1)** — increasing (convex) marginal costs
//!   (e.g. DVFS ramping up under sustained load, thermal throttling
//!   overheads);
//! * **PowerLaw(e<1)** / **Logarithmic** — decreasing (concave) marginal
//!   costs (fixed wake-up/communication energy amortized over more work,
//!   caches warming up);
//! * **Tabulated** — arbitrary measured values (what a profiler like I-Prof
//!   [35] would produce); the only family that can be non-monotone.
//!
//! [`MarginalRegime`] classifies a cost function over a domain according to
//! Definition 3 of the paper (eqs. 7a–7c).

/// A cost function over task counts.
///
/// `PartialEq` is *structural* value equality (same family, same
/// parameters) — it is what [`crate::sched::fleet::FleetBuilder`] uses to
/// deduplicate interchangeable devices into classes. Two functions that
/// are pointwise equal but structurally different (e.g. an `Affine` and an
/// equivalent `Tabulated`) compare unequal; that only costs dedup
/// opportunities, never correctness.
#[derive(Clone, Debug, PartialEq)]
pub enum CostFn {
    /// `fixed + per_task * j` — constant marginal cost (7b).
    Affine { fixed: f64, per_task: f64 },
    /// `fixed + a*j² + b*j`, `a > 0` — increasing marginal cost (7a).
    Quadratic { fixed: f64, a: f64, b: f64 },
    /// `fixed + scale * j^exponent` — increasing marginal for `exponent > 1`,
    /// decreasing for `0 < exponent < 1`.
    PowerLaw { fixed: f64, scale: f64, exponent: f64 },
    /// `fixed + scale * ln(1 + j)` — decreasing marginal cost (7c).
    Logarithmic { fixed: f64, scale: f64 },
    /// Arbitrary per-count values: `values[j - first]` is the cost of `j`
    /// tasks for `j ∈ [first, first + values.len())`.
    Tabulated { first: usize, values: Vec<f64> },
    /// `weight * inner(j)` — weighted cost (carbon / money adapters,
    /// paper §6 remark I).
    Scaled { weight: f64, inner: Box<CostFn> },
    /// `inner(j + shift) - inner(shift)` — the §5.2 lower-limit removal
    /// transformation (eq. 10).
    Shifted { shift: usize, inner: Box<CostFn> },
}

impl CostFn {
    /// Evaluate the cost of assigning `j` tasks.
    ///
    /// Callers are responsible for staying within `[L_i, U_i]`; `Tabulated`
    /// panics outside its stored domain (this is a programming error, not a
    /// data error).
    pub fn eval(&self, j: usize) -> f64 {
        match self {
            CostFn::Affine { fixed, per_task } => fixed + per_task * j as f64,
            CostFn::Quadratic { fixed, a, b } => {
                let x = j as f64;
                fixed + a * x * x + b * x
            }
            CostFn::PowerLaw { fixed, scale, exponent } => {
                fixed + scale * (j as f64).powf(*exponent)
            }
            CostFn::Logarithmic { fixed, scale } => fixed + scale * (1.0 + j as f64).ln(),
            CostFn::Tabulated { first, values } => {
                assert!(
                    j >= *first && j - first < values.len(),
                    "tabulated cost queried at {j}, domain [{first}, {})",
                    first + values.len()
                );
                values[j - first]
            }
            CostFn::Scaled { weight, inner } => weight * inner.eval(j),
            CostFn::Shifted { shift, inner } => {
                // `j + shift` must never wrap, and a `Shifted`-wrapped
                // `Tabulated` may be queried past the table's stored domain
                // by callers probing the transformed range (the §5.2
                // restore path) — clamp instead of hitting `eval`'s hard
                // domain assert.
                let x = j.saturating_add(*shift);
                inner.eval_clamped(x) - inner.eval_clamped(*shift)
            }
        }
    }

    /// Evaluate like [`CostFn::eval`], but clamp out-of-domain `Tabulated`
    /// queries to the nearest stored endpoint instead of panicking (the
    /// analytic families are total and behave identically to `eval`).
    ///
    /// This is the edge-tolerant path used by [`CostFn::Shifted`] and the
    /// time-model binary search in [`crate::sched::pareto`], where probe
    /// points may legitimately exceed a measured table's domain.
    pub fn eval_clamped(&self, j: usize) -> f64 {
        match self {
            CostFn::Tabulated { first, values } => {
                let hi = values.len().saturating_sub(1);
                let idx = j.saturating_sub(*first).min(hi);
                values[idx]
            }
            CostFn::Scaled { weight, inner } => weight * inner.eval_clamped(j),
            CostFn::Shifted { shift, inner } => {
                let x = j.saturating_add(*shift);
                inner.eval_clamped(x) - inner.eval_clamped(*shift)
            }
            _ => self.eval(j),
        }
    }

    /// Marginal cost `M_i(j)` per eq. (6): the cost of the `j`-th task given
    /// the domain starts at `lower` (`M_i(lower) := 0`).
    pub fn marginal(&self, j: usize, lower: usize) -> f64 {
        if j <= lower {
            0.0
        } else {
            self.eval(j) - self.eval(j - 1)
        }
    }

    /// Structural fingerprint for class bucketing: equal functions hash
    /// equal (`f64`s hashed by bit pattern, so `0.0`/`-0.0` or NaN params
    /// may split a bucket — the follow-up `PartialEq` check keeps classes
    /// correct either way).
    pub fn structural_hash(&self) -> u64 {
        // FNV-1a via the shared primitive: persisted journal digests mix
        // this hash, so it must never drift from `util::hash`.
        use crate::util::hash::{mix_u64 as mix, FNV_OFFSET as OFFSET};
        fn go(c: &CostFn, mut h: u64) -> u64 {
            match c {
                CostFn::Affine { fixed, per_task } => {
                    h = mix(h, 1);
                    h = mix(h, fixed.to_bits());
                    mix(h, per_task.to_bits())
                }
                CostFn::Quadratic { fixed, a, b } => {
                    h = mix(h, 2);
                    h = mix(h, fixed.to_bits());
                    h = mix(h, a.to_bits());
                    mix(h, b.to_bits())
                }
                CostFn::PowerLaw { fixed, scale, exponent } => {
                    h = mix(h, 3);
                    h = mix(h, fixed.to_bits());
                    h = mix(h, scale.to_bits());
                    mix(h, exponent.to_bits())
                }
                CostFn::Logarithmic { fixed, scale } => {
                    h = mix(h, 4);
                    h = mix(h, fixed.to_bits());
                    mix(h, scale.to_bits())
                }
                CostFn::Tabulated { first, values } => {
                    h = mix(h, 5);
                    h = mix(h, *first as u64);
                    for v in values {
                        h = mix(h, v.to_bits());
                    }
                    h
                }
                CostFn::Scaled { weight, inner } => {
                    h = mix(h, 6);
                    h = mix(h, weight.to_bits());
                    go(inner, h)
                }
                CostFn::Shifted { shift, inner } => {
                    h = mix(h, 7);
                    h = mix(h, *shift as u64);
                    go(inner, h)
                }
            }
        }
        go(self, OFFSET)
    }

    /// Convenience: build a tabulated cost from `(count, cost)` pairs that
    /// must form a contiguous range.
    pub fn from_table(pairs: &[(usize, f64)]) -> CostFn {
        assert!(!pairs.is_empty());
        let first = pairs[0].0;
        for (k, (j, _)) in pairs.iter().enumerate() {
            assert_eq!(*j, first + k, "table must be contiguous");
        }
        CostFn::Tabulated { first, values: pairs.iter().map(|p| p.1).collect() }
    }
}

/// Marginal-cost regime of a cost function over a domain (paper Def. 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MarginalRegime {
    /// (7a) `M(j) <= M(j+1)` (convex costs). NOTE: constant is a special
    /// case of increasing; [`classify`] reports `Constant` only when *all*
    /// marginals are equal within tolerance.
    Increasing,
    /// (7b) all marginals equal.
    Constant,
    /// (7c) `M(j) >= M(j+1)` (concave costs).
    Decreasing,
    /// None of the above (only possible for tabulated/measured data).
    Arbitrary,
}

/// Relative tolerance used when comparing marginal costs.
pub const REGIME_TOL: f64 = 1e-9;

/// Classify a sequence of successive marginal costs `M(L+1), ..., M(U)`
/// per Definition 3 — the comparison core shared by [`classify`] (flat
/// cost functions) and the fleet-view classifier
/// ([`crate::sched::auto::classify_fleet`]), so the tolerance rules can
/// never drift apart. Sequences with fewer than two marginals are
/// vacuously `Constant`.
pub fn classify_marginals(marginals: impl IntoIterator<Item = f64>) -> MarginalRegime {
    let mut it = marginals.into_iter();
    let mut prev = match it.next() {
        Some(m) => m,
        None => return MarginalRegime::Constant,
    };
    let mut incr = true;
    let mut decr = true;
    let mut cons = true;
    for cur in it {
        let scale = prev.abs().max(cur.abs()).max(1.0);
        let tol = REGIME_TOL * scale;
        if cur < prev - tol {
            incr = false;
        }
        if cur > prev + tol {
            decr = false;
        }
        if (cur - prev).abs() > tol {
            cons = false;
        }
        prev = cur;
    }
    match (cons, incr, decr) {
        (true, _, _) => MarginalRegime::Constant,
        (false, true, false) => MarginalRegime::Increasing,
        (false, false, true) => MarginalRegime::Decreasing,
        (false, true, true) => MarginalRegime::Constant, // unreachable, kept total
        (false, false, false) => MarginalRegime::Arbitrary,
    }
}

/// Classify one cost function over `[lower, upper]`.
///
/// Follows Definition 3: compares consecutive marginal costs `M(j)` vs
/// `M(j+1)` for `j ∈ ]lower, upper[`. Domains with fewer than two marginal
/// values are vacuously `Constant`.
pub fn classify(cost: &CostFn, lower: usize, upper: usize) -> MarginalRegime {
    assert!(lower <= upper);
    // Marginals exist for j in [lower+1, upper].
    classify_marginals((lower + 1..=upper).map(|j| cost.marginal(j, lower)))
}

/// Combine per-resource regimes into the instance-wide scenario: the
/// specialized algorithms require *all* resources to follow the same
/// behavior (paper §5 intro); any mixture degrades to `Arbitrary`.
pub fn combine(regimes: &[MarginalRegime]) -> MarginalRegime {
    use MarginalRegime::*;
    let mut acc = Constant;
    for &r in regimes {
        acc = match (acc, r) {
            (Arbitrary, _) | (_, Arbitrary) => Arbitrary,
            (Constant, x) => x,
            (x, Constant) => x,
            (Increasing, Increasing) => Increasing,
            (Decreasing, Decreasing) => Decreasing,
            (Increasing, Decreasing) | (Decreasing, Increasing) => Arbitrary,
        };
        if acc == Arbitrary {
            return Arbitrary;
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn affine_eval_and_marginal() {
        let c = CostFn::Affine { fixed: 2.0, per_task: 3.0 };
        assert_eq!(c.eval(0), 2.0);
        assert_eq!(c.eval(4), 14.0);
        assert_eq!(c.marginal(1, 0), 3.0);
        assert_eq!(c.marginal(0, 0), 0.0); // M(lower) := 0
        assert_eq!(classify(&c, 0, 10), MarginalRegime::Constant);
    }

    #[test]
    fn quadratic_is_increasing() {
        let c = CostFn::Quadratic { fixed: 0.0, a: 0.5, b: 1.0 };
        assert_eq!(classify(&c, 0, 10), MarginalRegime::Increasing);
        // marginals: C(j)-C(j-1) = 0.5(2j-1) + 1, strictly increasing
        assert!(c.marginal(2, 0) > c.marginal(1, 0));
    }

    #[test]
    fn sqrt_and_log_are_decreasing() {
        let s = CostFn::PowerLaw { fixed: 1.0, scale: 2.0, exponent: 0.5 };
        let l = CostFn::Logarithmic { fixed: 0.0, scale: 5.0 };
        assert_eq!(classify(&s, 0, 20), MarginalRegime::Decreasing);
        assert_eq!(classify(&l, 0, 20), MarginalRegime::Decreasing);
    }

    #[test]
    fn powerlaw_super_linear_increasing() {
        let c = CostFn::PowerLaw { fixed: 0.0, scale: 1.0, exponent: 1.5 };
        assert_eq!(classify(&c, 0, 20), MarginalRegime::Increasing);
    }

    #[test]
    fn tabulated_domain_and_arbitrary() {
        let c = CostFn::from_table(&[(0, 0.0), (1, 5.0), (2, 6.0), (3, 10.0)]);
        assert_eq!(c.eval(2), 6.0);
        // marginals 5, 1, 4 → neither monotone direction
        assert_eq!(classify(&c, 0, 3), MarginalRegime::Arbitrary);
    }

    #[test]
    #[should_panic(expected = "tabulated cost queried")]
    fn tabulated_out_of_domain_panics() {
        let c = CostFn::from_table(&[(1, 1.0), (2, 2.0)]);
        c.eval(0);
    }

    #[test]
    fn scaled_weights_cost() {
        let c = CostFn::Scaled {
            weight: 2.0,
            inner: Box::new(CostFn::Affine { fixed: 1.0, per_task: 1.0 }),
        };
        assert_eq!(c.eval(3), 8.0);
    }

    #[test]
    fn shifted_implements_eq10() {
        // C'(j) = C(j + L) - C(L)
        let base = CostFn::Quadratic { fixed: 3.0, a: 1.0, b: 0.0 };
        let shifted = CostFn::Shifted { shift: 2, inner: Box::new(base.clone()) };
        assert_eq!(shifted.eval(0), 0.0);
        assert_eq!(shifted.eval(1), base.eval(3) - base.eval(2));
        assert_eq!(shifted.eval(3), base.eval(5) - base.eval(2));
    }

    #[test]
    fn shifted_overflow_saturates_instead_of_panicking() {
        // A shift at the top of the usize range must not wrap `j + shift`
        // around zero; the saturated point evaluates like the endpoint,
        // so the transformed cost degenerates to 0 instead of garbage.
        let shifted = CostFn::Shifted {
            shift: usize::MAX,
            inner: Box::new(CostFn::Affine { fixed: 1.0, per_task: 2.0 }),
        };
        assert_eq!(shifted.eval(0), 0.0);
        assert_eq!(shifted.eval(3), 0.0);
    }

    #[test]
    fn shifted_tabulated_out_of_domain_clamps() {
        // Pre-fix this hit `eval`'s hard domain assert: the shifted view
        // of a 4-entry table has domain [0, 1] but eq. 10's restore path
        // probes past it. Clamping pins out-of-range queries to the last
        // stored value.
        let table =
            CostFn::from_table(&[(0, 0.0), (1, 2.0), (2, 3.0), (3, 9.0)]);
        let shifted = CostFn::Shifted { shift: 2, inner: Box::new(table) };
        assert_eq!(shifted.eval(0), 0.0);
        assert_eq!(shifted.eval(1), 9.0 - 3.0);
        // j + shift = 4 and 52 both exceed the table: clamp to j = 3.
        assert_eq!(shifted.eval(2), 9.0 - 3.0);
        assert_eq!(shifted.eval(50), 9.0 - 3.0);
    }

    #[test]
    fn eval_clamped_clamps_tabulated_to_domain_edges() {
        let c = CostFn::from_table(&[(2, 4.0), (3, 6.0)]);
        assert_eq!(c.eval_clamped(0), 4.0);
        assert_eq!(c.eval_clamped(2), 4.0);
        assert_eq!(c.eval_clamped(3), 6.0);
        assert_eq!(c.eval_clamped(9), 6.0);
        // Analytic families are unchanged.
        let a = CostFn::Affine { fixed: 1.0, per_task: 3.0 };
        assert_eq!(a.eval_clamped(4), a.eval(4));
    }

    #[test]
    fn paper_example_resource1_regime() {
        // Resource 1 of §3.1: {1:2, 2:3.5, 3:5.5, 4:8, 5:10, 6:12}
        // marginals: 1.5, 2, 2.5, 2, 2 → arbitrary (not monotone)
        let c = CostFn::from_table(&[
            (1, 2.0), (2, 3.5), (3, 5.5), (4, 8.0), (5, 10.0), (6, 12.0),
        ]);
        assert_eq!(classify(&c, 1, 6), MarginalRegime::Arbitrary);
    }

    #[test]
    fn tiny_domain_is_constant() {
        let c = CostFn::from_table(&[(0, 0.0), (1, 7.0)]);
        assert_eq!(classify(&c, 0, 1), MarginalRegime::Constant);
    }

    #[test]
    fn combine_rules() {
        use MarginalRegime::*;
        assert_eq!(combine(&[Increasing, Increasing]), Increasing);
        assert_eq!(combine(&[Constant, Increasing]), Increasing);
        assert_eq!(combine(&[Constant, Constant]), Constant);
        assert_eq!(combine(&[Decreasing, Constant]), Decreasing);
        assert_eq!(combine(&[Increasing, Decreasing]), Arbitrary);
        assert_eq!(combine(&[Arbitrary, Constant]), Arbitrary);
        assert_eq!(combine(&[]), Constant);
    }
}
