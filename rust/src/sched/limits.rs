//! Lower-limit removal (paper §5.2, eqs. 8–11).
//!
//! Any instance `(R, T, U, L, C)` is transformed into an equivalent
//! zero-lower-limit instance:
//!
//! * `T' = T - Σ L_i`            (eq. 8)
//! * `U'_i = U_i - L_i`          (eq. 9)
//! * `C'_i(j) = C_i(j + L_i) - C_i(L_i)`  (eq. 10)
//!
//! and a solution `X'` maps back via `x_i = x'_i + L_i` (eq. 11). The
//! transformation is O(n); the shifted cost functions are lazy
//! ([`CostFn::Shifted`]), evaluated only where a solver needs them.

use crate::sched::costs::CostFn;
use crate::sched::instance::{Instance, Schedule};

/// The transformation record: the equivalent instance plus what is needed
/// to map schedules back.
#[derive(Clone, Debug)]
pub struct Transformed {
    /// Equivalent instance with all lower limits at zero.
    pub instance: Instance,
    /// Original lower limits (for [`Transformed::restore`]).
    lower: Vec<usize>,
}

/// Apply eqs. (8)–(10).
pub fn remove_lower_limits(inst: &Instance) -> Transformed {
    let sum_l: usize = inst.lower.iter().sum();
    let n = inst.n();
    let mut costs = Vec::with_capacity(n);
    let mut upper = Vec::with_capacity(n);
    // Valid instances satisfy Σ L ≤ T and L_i ≤ U_i (validate()): the
    // saturating forms are exact there and merely shield invalid input.
    let t_prime = inst.tasks.saturating_sub(sum_l);
    for i in 0..n {
        let l = inst.lower[i];
        upper.push(inst.upper[i].saturating_sub(l));
        if l == 0 {
            costs.push(inst.costs[i].clone());
        } else {
            costs.push(CostFn::Shifted { shift: l, inner: Box::new(inst.costs[i].clone()) });
        }
    }
    // Note: C'_i(0) = 0 for shifted costs, but original zero-lower-limit
    // resources keep their (possibly non-zero) C_i(0). Solvers only compare
    // cost *differences*, so a constant offset per resource never changes
    // the argmin; totals are always recomputed on the original instance.
    let instance = Instance {
        tasks: t_prime,
        lower: vec![0; n],
        upper,
        costs,
    };
    Transformed { instance, lower: inst.lower.clone() }
}

impl Transformed {
    /// Map a schedule of the transformed instance back (eq. 11).
    pub fn restore(&self, sched: &Schedule) -> Schedule {
        let x: Vec<usize> = sched
            .assignments()
            .iter()
            .zip(&self.lower)
            .map(|(&xp, &l)| xp + l)
            .collect();
        Schedule::new(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::validate;

    #[test]
    fn transform_shapes() {
        let inst = Instance::paper_example(8);
        let tr = remove_lower_limits(&inst);
        assert_eq!(tr.instance.tasks, 7); // 8 - (1+0+0)
        assert_eq!(tr.instance.lower, vec![0, 0, 0]);
        assert_eq!(tr.instance.upper, vec![5, 6, 5]);
        tr.instance.validate().unwrap();
    }

    #[test]
    fn shifted_costs_match_eq10() {
        let inst = Instance::paper_example(8);
        let tr = remove_lower_limits(&inst);
        // C'_1(j) = C_1(j+1) - C_1(1)
        for j in 0..=5 {
            let expect = inst.costs[0].eval(j + 1) - inst.costs[0].eval(1);
            assert!((tr.instance.costs[0].eval(j) - expect).abs() < 1e-12);
        }
        // resource 2 had L=0: unchanged
        for j in 0..=6 {
            assert_eq!(tr.instance.costs[1].eval(j), inst.costs[1].eval(j));
        }
    }

    #[test]
    fn restore_adds_lower_limits() {
        let inst = Instance::paper_example(8);
        let tr = remove_lower_limits(&inst);
        let restored = tr.restore(&Schedule::new(vec![0, 2, 5]));
        assert_eq!(restored.assignments(), &[1, 2, 5]);
        validate::check(&inst, &restored).unwrap();
    }

    #[test]
    fn feasible_schedules_map_bijectively() {
        let inst = Instance::paper_example(5);
        let tr = remove_lower_limits(&inst);
        // any feasible X' of the transformed instance restores to feasible X
        let xp = Schedule::new(vec![1, 3, 0]);
        validate::check(&tr.instance, &xp).unwrap();
        let x = tr.restore(&xp);
        validate::check(&inst, &x).unwrap();
        // and total costs differ by the constant Σ C_i(L_i) - Σ C_i(0)... —
        // cost *differences* between feasible schedules are preserved:
        let yp = Schedule::new(vec![0, 4, 0]);
        validate::check(&tr.instance, &yp).unwrap();
        let y = tr.restore(&yp);
        let d_orig = validate::total_cost(&inst, &x) - validate::total_cost(&inst, &y);
        let d_tr = validate::total_cost(&tr.instance, &xp) - validate::total_cost(&tr.instance, &yp);
        assert!((d_orig - d_tr).abs() < 1e-12);
    }
}
