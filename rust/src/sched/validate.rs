//! Schedule feasibility checks and total-cost evaluation.

use crate::error::{FedError, Result};
use crate::sched::instance::{Instance, Schedule};

/// Total cost `ΣC = Σ_i C_i(x_i)` of a schedule (paper eq. 1a).
pub fn total_cost(inst: &Instance, sched: &Schedule) -> f64 {
    debug_assert_eq!(inst.n(), sched.len());
    sched
        .assignments()
        .iter()
        .enumerate()
        .map(|(i, &x)| inst.costs[i].eval(x))
        .sum()
}

/// Maximum per-resource cost (the makespan objective of OLAR [26]; used to
/// contrast total-cost vs max-cost optimization in the benches).
pub fn max_cost(inst: &Instance, sched: &Schedule) -> f64 {
    sched
        .assignments()
        .iter()
        .enumerate()
        .map(|(i, &x)| inst.costs[i].eval(x))
        .fold(0.0f64, f64::max)
}

/// Check feasibility: `Σ x_i = T` (eq. 1b) and `L_i <= x_i <= U_i` (eq. 1c).
pub fn check(inst: &Instance, sched: &Schedule) -> Result<()> {
    if sched.len() != inst.n() {
        return Err(FedError::InvalidSchedule(format!(
            "schedule has {} entries for {} resources",
            sched.len(),
            inst.n()
        )));
    }
    for (i, &x) in sched.assignments().iter().enumerate() {
        if x < inst.lower[i] || x > inst.upper[i] {
            return Err(FedError::InvalidSchedule(format!(
                "resource {i}: x={x} outside [{}, {}]",
                inst.lower[i], inst.upper[i]
            )));
        }
    }
    let total = sched.total();
    if total != inst.tasks {
        return Err(FedError::InvalidSchedule(format!(
            "assigned {total} != T = {}",
            inst.tasks
        )));
    }
    Ok(())
}

/// `check` + return the total cost: the standard post-solve assertion.
pub fn checked_cost(inst: &Instance, sched: &Schedule) -> Result<f64> {
    check(inst, sched)?;
    Ok(total_cost(inst, sched))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_fig1_cost() {
        let inst = Instance::paper_example(5);
        let s = Schedule::new(vec![2, 3, 0]);
        check(&inst, &s).unwrap();
        assert!((total_cost(&inst, &s) - 7.5).abs() < 1e-12);
    }

    #[test]
    fn paper_fig2_cost() {
        let inst = Instance::paper_example(8);
        let s = Schedule::new(vec![1, 2, 5]);
        check(&inst, &s).unwrap();
        assert!((total_cost(&inst, &s) - 11.5).abs() < 1e-12);
    }

    #[test]
    fn rejects_limit_violations() {
        let inst = Instance::paper_example(5);
        // resource 1 below L_1 = 1
        assert!(check(&inst, &Schedule::new(vec![0, 5, 0])).is_err());
        // resource 3 above U_3 = 5
        assert!(check(&inst, &Schedule::new(vec![1, 0, 6])).is_err());
        // wrong total
        assert!(check(&inst, &Schedule::new(vec![1, 1, 1])).is_err());
        // wrong arity
        assert!(check(&inst, &Schedule::new(vec![5])).is_err());
    }

    #[test]
    fn max_cost_differs_from_total() {
        let inst = Instance::paper_example(5);
        let s = Schedule::new(vec![2, 3, 0]);
        assert!((max_cost(&inst, &s) - 4.0).abs() < 1e-12); // C2(3)=4 dominates
    }
}
