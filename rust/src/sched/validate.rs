//! Schedule feasibility checks, total-cost evaluation, and the
//! debug-build invariant auditor.
//!
//! [`audit_instance`] / [`audit_index`] run the structural deep-audits
//! (`FleetInstance::audit`, `FleetIndex::audit`) at every build and
//! derive seam — free in release builds (`cfg!(debug_assertions)` folds
//! to a constant), fatal in debug builds and the test suites, so a
//! corrupted class structure is caught where it is created, not rounds
//! later when a digest disagrees.

use crate::error::{FedError, Result};
use crate::sched::fleet::FleetInstance;
use crate::sched::incremental::FleetIndex;
use crate::sched::instance::{Instance, Schedule};

/// Total cost `ΣC = Σ_i C_i(x_i)` of a schedule (paper eq. 1a).
pub fn total_cost(inst: &Instance, sched: &Schedule) -> f64 {
    debug_assert_eq!(inst.n(), sched.len());
    sched
        .assignments()
        .iter()
        .enumerate()
        .map(|(i, &x)| inst.costs[i].eval(x))
        .sum()
}

/// Maximum per-resource cost (the makespan objective of OLAR [26]; used to
/// contrast total-cost vs max-cost optimization in the benches).
pub fn max_cost(inst: &Instance, sched: &Schedule) -> f64 {
    sched
        .assignments()
        .iter()
        .enumerate()
        .map(|(i, &x)| inst.costs[i].eval(x))
        .fold(0.0f64, f64::max)
}

/// Check feasibility: `Σ x_i = T` (eq. 1b) and `L_i <= x_i <= U_i` (eq. 1c).
pub fn check(inst: &Instance, sched: &Schedule) -> Result<()> {
    if sched.len() != inst.n() {
        return Err(FedError::InvalidSchedule(format!(
            "schedule has {} entries for {} resources",
            sched.len(),
            inst.n()
        )));
    }
    for (i, &x) in sched.assignments().iter().enumerate() {
        if x < inst.lower[i] || x > inst.upper[i] {
            return Err(FedError::InvalidSchedule(format!(
                "resource {i}: x={x} outside [{}, {}]",
                inst.lower[i], inst.upper[i]
            )));
        }
    }
    let total = sched.total();
    if total != inst.tasks {
        return Err(FedError::InvalidSchedule(format!(
            "assigned {total} != T = {}",
            inst.tasks
        )));
    }
    Ok(())
}

/// `check` + return the total cost: the standard post-solve assertion.
pub fn checked_cost(inst: &Instance, sched: &Schedule) -> Result<f64> {
    check(inst, sched)?;
    Ok(total_cost(inst, sched))
}

/// Debug-build structural audit of a freshly built [`FleetInstance`]
/// (membership/back-pointer consistency, canonical class order,
/// signature uniqueness). No-op in release builds; panics on corruption
/// otherwise — a failed audit means a builder bug, not bad user input.
pub fn audit_instance(fleet: &FleetInstance) {
    if !cfg!(debug_assertions) {
        return;
    }
    if let Err(why) = fleet.audit() {
        panic!("FleetInstance audit: {why}");
    }
}

/// Debug-build structural audit of a [`FleetIndex`] at the derive seam
/// (device→class map vs refcounts vs free list vs bucket chains). No-op
/// in release builds; panics on corruption otherwise.
pub fn audit_index(index: &FleetIndex) {
    if !cfg!(debug_assertions) {
        return;
    }
    if let Err(why) = index.audit() {
        panic!("FleetIndex audit: {why}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_fig1_cost() {
        let inst = Instance::paper_example(5);
        let s = Schedule::new(vec![2, 3, 0]);
        check(&inst, &s).unwrap();
        assert!((total_cost(&inst, &s) - 7.5).abs() < 1e-12);
    }

    #[test]
    fn paper_fig2_cost() {
        let inst = Instance::paper_example(8);
        let s = Schedule::new(vec![1, 2, 5]);
        check(&inst, &s).unwrap();
        assert!((total_cost(&inst, &s) - 11.5).abs() < 1e-12);
    }

    #[test]
    fn rejects_limit_violations() {
        let inst = Instance::paper_example(5);
        // resource 1 below L_1 = 1
        assert!(check(&inst, &Schedule::new(vec![0, 5, 0])).is_err());
        // resource 3 above U_3 = 5
        assert!(check(&inst, &Schedule::new(vec![1, 0, 6])).is_err());
        // wrong total
        assert!(check(&inst, &Schedule::new(vec![1, 1, 1])).is_err());
        // wrong arity
        assert!(check(&inst, &Schedule::new(vec![5])).is_err());
    }

    #[test]
    fn max_cost_differs_from_total() {
        let inst = Instance::paper_example(5);
        let s = Schedule::new(vec![2, 3, 0]);
        assert!((max_cost(&inst, &s) - 4.0).abs() < 1e-12); // C2(3)=4 dominates
    }

    #[test]
    fn audit_instance_accepts_built_fleets() {
        let inst = Instance::paper_example(8);
        let fleet = FleetInstance::from_flat(&inst).unwrap();
        audit_instance(&fleet); // must not panic
    }

    #[test]
    fn audit_index_accepts_built_indices() {
        use crate::sched::costs::CostFn;
        let sigs: Vec<(CostFn, usize, usize)> = (0..6)
            .map(|d| (CostFn::Affine { fixed: 0.0, per_task: (d % 2) as f64 + 1.0 }, 0, 4))
            .collect();
        let ix = FleetIndex::build(sigs.len(), |d| sigs[d].clone());
        audit_index(&ix); // must not panic
    }
}
