//! Incremental round re-derivation: a **persistent device→class index**
//! ([`FleetIndex`]) that makes per-round instance building
//! `O(selected + changed)` heavy work instead of `O(n)` re-bucketing.
//!
//! Every coordinator round derives a [`FleetInstance`] from the fleet's
//! current state. The from-scratch build clones, hashes, and probes every
//! selected device's cost function — `O(n)` expensive operations even
//! when Recosting touched only a handful of devices. The paper's
//! class-level formulation is exactly what makes deltas cheap: a device
//! whose `(C, L, U)` signature did not change stays in its bucket
//! untouched, so only the **dirty set** (battery drains, cost drift,
//! profile changes) needs re-classification.
//!
//! # Design
//!
//! The index buckets devices by their **raw signature** — the per-device
//! `(current cost, intrinsic lower, battery-capped upper)` triple,
//! *before* any per-round workload transform. Raw classes are keyed and
//! compared with the exact [`class_key`] bucketing and structural
//! equality every other dedup site uses ([`ClassTable`]).
//!
//! Each round then maps raw classes to **round classes** by applying the
//! round's limit transform (capacity clamp, `max_share` cap, lower-limit
//! staging — [`effective_limits`]) at class granularity. The transform is
//! a pure function of the raw signature and round-global scalars, so raw
//! classes *refine* round classes: distinct raw classes may merge for a
//! round (e.g. two upper limits both clipped to the same share cap), but
//! one raw class never splits. [`FleetIndex::derive`] therefore needs one
//! `O(selected)` array-lookup pass to group slots by raw class, and
//! `O(k)` hash probes to emit the round's classes — no per-device cost
//! clone or hash anywhere.
//!
//! # Exactness
//!
//! The emitted instance is **bit-for-bit identical** — class order,
//! member lists, digest — to the from-scratch build over the same
//! selection, because:
//!
//! * per-class saturating/wrapping sums compute exactly what the
//!   reference's per-device folds compute (documented at each site);
//! * round classes are created by probing a fresh [`ClassTable`] in
//!   raw-class **first-slot order**, which reproduces the builder's
//!   first-occurrence class order (a merged round class is created when
//!   its earliest-slot constituent probes);
//! * member lists concatenate constituent slot runs (each ascending) and
//!   sort on merge, reproducing the builder's ascending slot order.
//!
//! The internal class ids, bucket layout, and free-list history never
//! affect emission — the derived instance is a pure function of the
//! device signatures, the selection, and the round parameters. The
//! differential suite (`tests/incremental_equivalence.rs`) proves this
//! over generated churn scenarios; `benches/fleet_scale.rs` gates the
//! speedup (≥ 5× at 10⁶ devices, ≤ 1% churn).
//!
//! # Contract
//!
//! Correctness rests on one invariant the owner must uphold: **every
//! signature mutation is [`FleetIndex::mark`]ed** before the next
//! [`FleetIndex::apply`]. Marking an unchanged device is always safe
//! (`apply` re-reads the live signature and no-ops); failing to mark a
//! changed one silently desynchronizes the index. The coordinator marks
//! at its three mutation sites (dropout drains, training drains, drift
//! re-scaling) and proves the invariant end-to-end by campaign
//! equivalence tests.

// fedlint: allow(R1) — probe-only bucket index: emission order comes
// from first-slot order over `touched`, never from map iteration.
use std::collections::HashMap;

use crate::error::Result;
use crate::sched::costs::CostFn;
use crate::sched::fleet::{class_key, ClassTable, FleetInstance};
use crate::util::hash::{mix_u64, FNV_OFFSET};

/// The round-global knobs of one round's instance derivation (the
/// scheduling subset of `CoordinatorConfig` the limit transform reads).
#[derive(Clone, Copy, Debug)]
pub struct RoundParams {
    /// Requested workload `T` for the round.
    pub tasks: usize,
    /// Config-level minimum participation per selected device.
    pub min_tasks: usize,
    /// Over-representation guard: no device may receive more than this
    /// fraction of the round's tasks (doubled until feasible).
    pub max_share: f64,
}

/// The per-device **reference** limit transform — the single home of the
/// round math both build paths run: the coordinator's from-scratch
/// `build_instance_for` calls this directly, and
/// [`FleetIndex::derive`] computes the per-class equivalent (proven
/// equal by the differential suite).
///
/// Given each selected device's intrinsic lower limit and raw
/// (battery-capped) upper limit, returns the effective workload `t`
/// (requested `T` clamped to capacity), the staged lower limits, and the
/// share-capped upper limits. Sets `relaxed` when even the intrinsic
/// lower limits overshoot `t` and all lowers were dropped.
///
/// The caller must pre-check the exhausted case (all raw uppers zero) —
/// zero capacity degrades to an empty round, never reaches here.
pub fn effective_limits(
    p: &RoundParams,
    intrinsic_lowers: &[usize],
    raw_uppers: &[usize],
    relaxed: &mut bool,
) -> (usize, Vec<usize>, Vec<usize>) {
    // Overflow-safe capacity: "unlimited" devices may carry `usize::MAX`
    // uppers, so clamp each term to T before a saturating fold.
    let t_req = p.tasks;
    let capacity: usize = raw_uppers
        .iter()
        .fold(0usize, |a, &u| a.saturating_add(u.min(t_req)));
    debug_assert!(capacity > 0, "caller degrades zero capacity to an empty round");
    let t = t_req.min(capacity);

    // Over-representation guard: cap any device at max_share · T,
    // doubling the cap until the capped fleet can still absorb T.
    let mut cap = ((t as f64 * p.max_share).ceil() as usize).max(1);
    let uppers: Vec<usize> = loop {
        let capped: Vec<usize> = raw_uppers.iter().map(|&u| u.min(cap)).collect();
        if capped
            .iter()
            .fold(0usize, |a, &c| a.saturating_add(c))
            >= t
        {
            break capped;
        }
        cap *= 2;
    };

    // Lower limits: config-level minimum joined with each device's
    // intrinsic minimum, clamped to the (possibly share-capped) upper.
    let lower: Vec<usize> = intrinsic_lowers
        .iter()
        .zip(&uppers)
        .map(|(&l, &u)| p.min_tasks.max(l).min(u))
        .collect();
    // Relax in two stages when ΣL overshoots T: first drop the
    // config-level minimum and keep only the intrinsic device minima; if
    // even those sum above T, drop all lower limits rather than failing
    // every round.
    let lower = if lower.iter().sum::<usize>() > t {
        let intrinsic: Vec<usize> = intrinsic_lowers
            .iter()
            .zip(&uppers)
            .map(|(&l, &u)| l.min(u))
            .collect();
        if intrinsic.iter().sum::<usize>() > t {
            *relaxed = true;
            vec![0; uppers.len()]
        } else {
            intrinsic
        }
    } else {
        lower
    };
    (t, lower, uppers)
}

/// The from-scratch round derivation over an explicit signature source:
/// [`effective_limits`] plus the per-device builder loop. This is the
/// rebuild baseline the incremental path is benchmarked against, and the
/// oracle the differential suite compares [`FleetIndex::derive`] to.
/// Returns `None` for an exhausted selection (every raw upper zero).
pub fn from_scratch_round<F>(
    sig: F,
    selected: &[usize],
    p: &RoundParams,
    relaxed: &mut bool,
) -> Result<Option<(FleetInstance, usize)>>
where
    F: Fn(usize) -> (CostFn, usize, usize),
{
    let sigs: Vec<(CostFn, usize, usize)> =
        selected.iter().map(|&d| sig(d)).collect();
    if sigs.iter().all(|s| s.2 == 0) {
        return Ok(None);
    }
    let raw_lowers: Vec<usize> = sigs.iter().map(|s| s.1).collect();
    let raw_uppers: Vec<usize> = sigs.iter().map(|s| s.2).collect();
    let (t, lower, uppers) = effective_limits(p, &raw_lowers, &raw_uppers, relaxed);
    let mut b = FleetInstance::builder().tasks(t);
    for ((s, &u), &l) in sigs.into_iter().zip(&uppers).zip(&lower) {
        b = b.device(s.0, l, u);
    }
    Ok(Some((b.build()?, t)))
}

/// One persistent raw class: a `(C, L, U)` signature shared by `refs`
/// devices. Member lists are *not* kept here — membership lives in the
/// per-device `device_class` array, and per-round slot lists are grouped
/// on the fly by [`FleetIndex::derive`] (a persistent member list would
/// go stale with every selection change).
#[derive(Clone, Debug)]
struct RawClass {
    cost: CostFn,
    lower: usize,
    upper: usize,
    /// Number of devices currently in this class (0 = retired, on the
    /// free list awaiting id reuse).
    refs: usize,
}

/// The persistent device→class index (see the module docs).
///
/// Cloneable: the pipelined coordinator speculates on a clone and
/// discards it, so a wrong prediction can never corrupt the live index.
#[derive(Clone, Debug, Default)]
pub struct FleetIndex {
    /// Raw classes by internal id; retired entries are recycled through
    /// `free`. Ids are private bookkeeping — they never affect emission.
    classes: Vec<RawClass>,
    /// [`class_key`] → live class ids (collision chain) — the same
    /// bucketing every other dedup site uses.
    // fedlint: allow(R1) — probe-only: lookups via `get`/`get_mut`; ids
    // and chain order are private bookkeeping that never reach emission.
    buckets: HashMap<u64, Vec<u32>>,
    /// Retired class ids available for reuse.
    free: Vec<u32>,
    /// Current raw class of each device.
    device_class: Vec<u32>,
    /// Dirty devices awaiting [`FleetIndex::apply`] (deduplicated).
    pending: Vec<u32>,
    in_pending: Vec<bool>,
    // ---- per-round scratch, reused across derives -------------------
    /// Slot lists grouped by raw class id (valid when stamped).
    round_slots: Vec<Vec<usize>>,
    round_stamp: Vec<u64>,
    round_epoch: u64,
    /// Raw class ids present in the current selection, first-slot order.
    touched: Vec<u32>,
}

impl FleetIndex {
    /// Classify all `n` devices from scratch (the one `O(n)` pass; the
    /// coordinator meters it as `incr_index_rebuilds`).
    pub fn build<F>(n: usize, sig: F) -> Self
    where
        F: Fn(usize) -> (CostFn, usize, usize),
    {
        let mut ix = FleetIndex {
            device_class: vec![0; n],
            in_pending: vec![false; n],
            ..FleetIndex::default()
        };
        for d in 0..n {
            let (cost, lower, upper) = sig(d);
            let id = ix.find_or_create(cost, lower, upper);
            ix.classes[id as usize].refs += 1;
            ix.device_class[d] = id;
        }
        ix
    }

    /// Devices tracked.
    pub fn len(&self) -> usize {
        self.device_class.len()
    }

    /// Whether the index tracks no devices.
    pub fn is_empty(&self) -> bool {
        self.device_class.is_empty()
    }

    /// Live raw classes.
    pub fn n_classes(&self) -> usize {
        self.classes.len() - self.free.len()
    }

    /// Mark a device dirty: its signature may have changed and must be
    /// re-read at the next [`FleetIndex::apply`]. Idempotent and safe to
    /// call for unchanged devices.
    pub fn mark(&mut self, device: usize) {
        if !self.in_pending[device] {
            self.in_pending[device] = true;
            self.pending.push(device as u32);
        }
    }

    /// Size of the pending dirty set (the `incr_dirty` metric).
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Order-insensitive digest of the index state the next
    /// [`FleetIndex::apply`] will resolve: the device→class map plus the
    /// (sorted) pending dirty set. The pipelined coordinator mixes this
    /// into its scheduling guard — a speculation's pre-apply clone
    /// fingerprint equals the live fingerprint at adoption time iff the
    /// clone carried the same classification and the same dirty set, so
    /// the clone's `apply` + `derive` was a pure-function replay of what
    /// the serial path would do.
    pub fn fingerprint(&self) -> u64 {
        let mut h = FNV_OFFSET;
        h = mix_u64(h, self.device_class.len() as u64);
        for &c in &self.device_class {
            h = mix_u64(h, c as u64);
        }
        let mut dirty = self.pending.clone();
        dirty.sort_unstable();
        h = mix_u64(h, dirty.len() as u64);
        for d in dirty {
            h = mix_u64(h, d as u64);
        }
        h
    }

    /// Re-classify every pending device against its live signature:
    /// unchanged devices stay put, changed ones move between buckets
    /// (creating/retiring classes as needed). Returns how many actually
    /// moved (the `incr_reclassified` metric). The result is independent
    /// of mark order — ids are internal, and signature equality is exact.
    pub fn apply<F>(&mut self, sig: F) -> usize
    where
        F: Fn(usize) -> (CostFn, usize, usize),
    {
        let pending = std::mem::take(&mut self.pending);
        let mut moved = 0usize;
        for d32 in pending {
            let d = d32 as usize;
            self.in_pending[d] = false;
            let (cost, lower, upper) = sig(d);
            let old = self.device_class[d];
            {
                let oc = &self.classes[old as usize];
                if oc.lower == lower && oc.upper == upper && oc.cost == cost {
                    continue;
                }
            }
            moved += 1;
            self.detach(old);
            let id = self.find_or_create(cost, lower, upper);
            self.classes[id as usize].refs += 1;
            self.device_class[d] = id;
        }
        moved
    }

    /// Drop one reference to class `id`; retire it (bucket removal + id
    /// recycling) when no device references it anymore. `refs` counts
    /// exactly the devices whose `device_class` points here, so a retired
    /// class can never be reachable.
    fn detach(&mut self, id: u32) {
        let c = &mut self.classes[id as usize];
        c.refs -= 1;
        if c.refs == 0 {
            let key = class_key(&c.cost, c.lower, c.upper);
            if let Some(chain) = self.buckets.get_mut(&key) {
                chain.retain(|&x| x != id);
                if chain.is_empty() {
                    self.buckets.remove(&key);
                }
            }
            self.free.push(id);
        }
    }

    /// Id of the live class with this exact signature, creating one
    /// (reusing a retired id if available) on first occurrence. At most
    /// one live class per signature exists, so the probe is
    /// deterministic regardless of bucket-chain order.
    fn find_or_create(&mut self, cost: CostFn, lower: usize, upper: usize) -> u32 {
        let key = class_key(&cost, lower, upper);
        if let Some(chain) = self.buckets.get(&key) {
            for &id in chain {
                let c = &self.classes[id as usize];
                if c.lower == lower && c.upper == upper && c.cost == cost {
                    return id;
                }
            }
        }
        let id = match self.free.pop() {
            Some(id) => {
                self.classes[id as usize] = RawClass { cost, lower, upper, refs: 0 };
                id
            }
            None => {
                self.classes.push(RawClass { cost, lower, upper, refs: 0 });
                (self.classes.len() - 1) as u32
            }
        };
        self.buckets.entry(key).or_default().push(id);
        id
    }

    /// Structural deep-audit behind the debug-build invariant auditor
    /// ([`crate::sched::validate::audit_index`]): cross-checks the
    /// device→class map, the refcounts, the free list, and the bucket
    /// chains — by probing, never by map iteration, so the audit itself
    /// obeys the determinism rules it guards.
    pub(crate) fn audit(&self) -> std::result::Result<(), String> {
        let n = self.device_class.len();
        if self.in_pending.len() != n {
            return Err(format!(
                "in_pending tracks {} devices, device_class {n}",
                self.in_pending.len()
            ));
        }
        // Refcount histogram from the ground truth (the device map).
        let mut hist = vec![0usize; self.classes.len()];
        for (d, &c) in self.device_class.iter().enumerate() {
            let Some(slot) = hist.get_mut(c as usize) else {
                return Err(format!("device {d}: class id {c} out of range"));
            };
            *slot += 1;
        }
        for (id, class) in self.classes.iter().enumerate() {
            if class.refs != hist[id] {
                return Err(format!(
                    "class {id}: refs = {} but {} devices point at it",
                    class.refs, hist[id]
                ));
            }
            let key = class_key(&class.cost, class.lower, class.upper);
            let in_chain = self
                .buckets
                .get(&key)
                .map_or(0, |chain| chain.iter().filter(|&&x| x == id as u32).count());
            if class.refs > 0 && in_chain != 1 {
                return Err(format!("live class {id} appears {in_chain}x in its bucket chain"));
            }
            if class.refs == 0 && in_chain != 0 {
                return Err(format!("retired class {id} still sits in a bucket chain"));
            }
        }
        // Free list: exactly the retired ids, each listed once.
        let mut freed = vec![false; self.classes.len()];
        for &id in &self.free {
            let Some(slot) = freed.get_mut(id as usize) else {
                return Err(format!("free id {id} out of range"));
            };
            if *slot {
                return Err(format!("free id {id} listed twice"));
            }
            *slot = true;
            if self.classes[id as usize].refs != 0 {
                return Err(format!("free id {id} still referenced"));
            }
        }
        for (id, class) in self.classes.iter().enumerate() {
            if class.refs == 0 && !freed[id] {
                return Err(format!("retired class {id} missing from the free list"));
            }
        }
        // Pending: deduplicated and mirrored by in_pending.
        let mut queued = vec![false; n];
        for &d in &self.pending {
            let Some(slot) = queued.get_mut(d as usize) else {
                return Err(format!("pending device {d} out of range"));
            };
            if *slot {
                return Err(format!("pending device {d} queued twice"));
            }
            *slot = true;
        }
        if let Some(d) = (0..n).find(|&d| self.in_pending[d] != queued[d]) {
            return Err(format!("device {d}: in_pending flag disagrees with the queue"));
        }
        Ok(())
    }

    /// Derive one round's [`FleetInstance`] over `selected` device
    /// indices (slot `s` = position `s` in `selected`; must be
    /// non-empty). Requires [`FleetIndex::apply`] to have drained the
    /// dirty set first. Returns `None` for an exhausted selection (every
    /// selected device's raw upper is zero); sets `relaxed` exactly like
    /// [`effective_limits`].
    ///
    /// Bit-for-bit identical to [`from_scratch_round`] over the same
    /// selection — see the module docs for the argument.
    pub fn derive(
        &mut self,
        selected: &[usize],
        p: &RoundParams,
        relaxed: &mut bool,
    ) -> Result<Option<(FleetInstance, usize)>> {
        crate::sched::validate::audit_index(self);
        debug_assert!(
            self.pending.is_empty(),
            "apply() must drain the dirty set before derive()"
        );
        if self.round_slots.len() < self.classes.len() {
            self.round_slots.resize_with(self.classes.len(), Vec::new);
            self.round_stamp.resize(self.classes.len(), 0);
        }
        self.round_epoch += 1;
        let epoch = self.round_epoch;
        // Pass 1 — group slots by raw class: one array read per selected
        // device, nothing heavier. `touched` collects classes in
        // first-slot order because slots are visited ascending.
        self.touched.clear();
        for (slot, &d) in selected.iter().enumerate() {
            let c = self.device_class[d];
            let ci = c as usize;
            if self.round_stamp[ci] != epoch {
                self.round_stamp[ci] = epoch;
                self.round_slots[ci].clear();
                self.touched.push(c);
            }
            self.round_slots[ci].push(slot);
        }
        // Exhausted selection: every raw upper zero ⇔ zero capacity.
        if self.touched.iter().all(|&c| self.classes[c as usize].upper == 0) {
            return Ok(None);
        }

        // Round-global scalars, per class. Saturating per-class mul+add
        // equals the reference's per-device sequential saturating fold:
        // both compute min(true sum, usize::MAX) over non-negative terms.
        let t_req = p.tasks;
        let mut capacity = 0usize;
        for &c in &self.touched {
            let m = self.round_slots[c as usize].len();
            let u = self.classes[c as usize].upper.min(t_req);
            capacity = capacity.saturating_add(m.saturating_mul(u));
        }
        let t = t_req.min(capacity);
        let mut cap = ((t as f64 * p.max_share).ceil() as usize).max(1);
        loop {
            let mut sum = 0usize;
            for &c in &self.touched {
                let m = self.round_slots[c as usize].len();
                let u = self.classes[c as usize].upper.min(cap);
                sum = sum.saturating_add(m.saturating_mul(u));
            }
            if sum >= t {
                break;
            }
            cap *= 2;
        }
        // Lower staging. The reference sums lowers with plain `+`, which
        // wraps in release builds — wrapping per-class arithmetic is
        // congruent mod 2⁶⁴, so the `> t` comparisons agree bit-for-bit.
        // (Real lower sums never approach the wrap; this mirrors the
        // reference's semantics rather than "improving" on them.)
        let mut joined_sum = 0usize;
        let mut intrinsic_sum = 0usize;
        for &c in &self.touched {
            let m = self.round_slots[c as usize].len();
            let rc = &self.classes[c as usize];
            let u = rc.upper.min(cap);
            joined_sum = joined_sum
                .wrapping_add(m.wrapping_mul(p.min_tasks.max(rc.lower).min(u)));
            intrinsic_sum = intrinsic_sum.wrapping_add(m.wrapping_mul(rc.lower.min(u)));
        }
        #[derive(Clone, Copy)]
        enum Stage {
            Joined,
            Intrinsic,
            Zero,
        }
        let stage = if joined_sum > t {
            if intrinsic_sum > t {
                *relaxed = true;
                Stage::Zero
            } else {
                Stage::Intrinsic
            }
        } else {
            Stage::Joined
        };

        // Pass 2 — emit round classes by probing a fresh ClassTable in
        // raw-class first-slot order: O(k) probes total. A round class
        // merging several raw classes is created when its earliest-slot
        // constituent probes, which reproduces the builder's
        // first-occurrence order exactly.
        let mut table = ClassTable::with_capacity(self.touched.len());
        let mut merged: Vec<usize> = Vec::new();
        for &c in &self.touched {
            let rc = &self.classes[c as usize];
            let u = rc.upper.min(cap);
            let l = match stage {
                Stage::Joined => p.min_tasks.max(rc.lower).min(u),
                Stage::Intrinsic => rc.lower.min(u),
                Stage::Zero => 0,
            };
            let idx = table.class_index(&rc.cost, l, u);
            let members = &mut table.classes[idx].members;
            if !members.is_empty() {
                merged.push(idx);
            }
            members.extend_from_slice(&self.round_slots[c as usize]);
        }
        // Merged member lists are concatenations of ascending runs —
        // restore the builder's globally-ascending slot order.
        merged.sort_unstable();
        merged.dedup();
        for idx in merged {
            table.classes[idx].members.sort_unstable();
        }
        let fleet = FleetInstance::from_classes(t, table.into_classes())?;
        Ok(Some((fleet, t)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn affine(per_task: f64) -> CostFn {
        CostFn::Affine { fixed: 0.0, per_task }
    }

    /// A mutable signature table standing in for a managed fleet.
    struct Sigs(Vec<(CostFn, usize, usize)>);

    impl Sigs {
        fn get(&self) -> impl Fn(usize) -> (CostFn, usize, usize) + '_ {
            |d| self.0[d].clone()
        }
    }

    fn check_equal(ix: &mut FleetIndex, sigs: &Sigs, selected: &[usize], p: &RoundParams) {
        let mut r1 = false;
        let mut r2 = false;
        let inc = ix.derive(selected, p, &mut r1).unwrap();
        let scratch = from_scratch_round(sigs.get(), selected, p, &mut r2).unwrap();
        match (inc, scratch) {
            (None, None) => {}
            (Some((fi, ti)), Some((fs, ts))) => {
                assert_eq!(ti, ts, "effective workload");
                assert_eq!(fi.digest(), fs.digest(), "instance digest");
                assert_eq!(fi.n_classes(), fs.n_classes());
                for (a, b) in fi.classes().iter().zip(fs.classes()) {
                    assert_eq!(a.lower, b.lower);
                    assert_eq!(a.upper, b.upper);
                    assert_eq!(a.cost, b.cost);
                    assert_eq!(a.members, b.members);
                }
            }
            (a, b) => panic!(
                "exhausted disagreement: incremental {:?} vs scratch {:?}",
                a.is_some(),
                b.is_some()
            ),
        }
        assert_eq!(r1, r2, "lower-relaxation flag");
    }

    fn fleet_sigs() -> Sigs {
        // 8 devices, 3 raw classes, one device with a lower limit.
        Sigs(vec![
            (affine(1.0), 0, 5),
            (affine(2.0), 1, 8),
            (affine(1.0), 0, 5),
            (affine(3.0), 0, 20),
            (affine(2.0), 1, 8),
            (affine(1.0), 0, 5),
            (affine(3.0), 0, 20),
            (affine(2.0), 1, 8),
        ])
    }

    const P: RoundParams = RoundParams { tasks: 12, min_tasks: 0, max_share: 1.0 };

    #[test]
    fn fresh_index_matches_from_scratch() {
        let sigs = fleet_sigs();
        let mut ix = FleetIndex::build(sigs.0.len(), sigs.get());
        assert_eq!(ix.len(), 8);
        assert_eq!(ix.n_classes(), 3);
        let all: Vec<usize> = (0..8).collect();
        check_equal(&mut ix, &sigs, &all, &P);
        // Sub-selections too (slots re-number from 0).
        check_equal(&mut ix, &sigs, &[1, 3, 4, 6], &P);
        check_equal(&mut ix, &sigs, &[7], &RoundParams { tasks: 4, ..P });
    }

    #[test]
    fn marked_churn_stays_bit_for_bit() {
        let mut sigs = fleet_sigs();
        let mut ix = FleetIndex::build(sigs.0.len(), sigs.get());
        let all: Vec<usize> = (0..8).collect();
        // Battery-style decay on device 3, drift on device 0, death of 5.
        sigs.0[3].2 = 7;
        sigs.0[0].0 = CostFn::Scaled { weight: 1.5, inner: Box::new(affine(1.0)) };
        sigs.0[5].2 = 0;
        for d in [3usize, 0, 5] {
            ix.mark(d);
        }
        assert_eq!(ix.pending_len(), 3);
        assert_eq!(ix.apply(sigs.get()), 3);
        check_equal(&mut ix, &sigs, &all, &P);
        // A second apply with no marks is a no-op.
        assert_eq!(ix.apply(sigs.get()), 0);
        check_equal(&mut ix, &sigs, &all, &P);
    }

    #[test]
    fn marking_unchanged_devices_is_safe_and_free() {
        let sigs = fleet_sigs();
        let mut ix = FleetIndex::build(sigs.0.len(), sigs.get());
        ix.mark(2);
        ix.mark(2); // deduplicated
        ix.mark(6);
        assert_eq!(ix.pending_len(), 2);
        assert_eq!(ix.apply(sigs.get()), 0, "unchanged devices never move");
        let all: Vec<usize> = (0..8).collect();
        check_equal(&mut ix, &sigs, &all, &P);
    }

    #[test]
    fn classes_retire_and_ids_recycle() {
        let mut sigs = fleet_sigs();
        let mut ix = FleetIndex::build(sigs.0.len(), sigs.get());
        // Move the sole members of class (affine(3), 0, 20) away: the
        // class retires; a later new signature reuses its id.
        sigs.0[3] = (affine(1.0), 0, 5);
        sigs.0[6] = (affine(1.0), 0, 5);
        ix.mark(3);
        ix.mark(6);
        assert_eq!(ix.apply(sigs.get()), 2);
        assert_eq!(ix.n_classes(), 2);
        sigs.0[7] = (affine(9.0), 0, 4);
        ix.mark(7);
        assert_eq!(ix.apply(sigs.get()), 1);
        assert_eq!(ix.n_classes(), 3);
        let all: Vec<usize> = (0..8).collect();
        check_equal(&mut ix, &sigs, &all, &P);
    }

    #[test]
    fn round_transform_merges_raw_classes() {
        // Two raw classes with equal cost but different uppers merge once
        // the share cap clips both to the same effective upper.
        let sigs = Sigs(vec![
            (affine(1.0), 0, 50),
            (affine(1.0), 0, 80),
            (affine(2.0), 0, 50),
            (affine(1.0), 0, 50),
        ]);
        let mut ix = FleetIndex::build(sigs.0.len(), sigs.get());
        assert_eq!(ix.n_classes(), 3);
        let all: Vec<usize> = (0..4).collect();
        let p = RoundParams { tasks: 40, min_tasks: 0, max_share: 0.25 };
        let mut relaxed = false;
        let (fleet, _) = ix.derive(&all, &p, &mut relaxed).unwrap().unwrap();
        // cap = 10 clips 50 and 80 alike: slots 0, 1, 3 fuse into one
        // round class with ascending members despite coming from two raw
        // classes.
        assert_eq!(fleet.n_classes(), 2);
        assert_eq!(fleet.classes()[0].members, vec![0, 1, 3]);
        check_equal(&mut ix, &sigs, &all, &p);
    }

    #[test]
    fn lower_staging_and_exhaustion_match_reference() {
        let sigs = Sigs(vec![
            (affine(1.0), 4, 6),
            (affine(2.0), 4, 6),
            (affine(3.0), 4, 6),
        ]);
        let mut ix = FleetIndex::build(sigs.0.len(), sigs.get());
        let all: Vec<usize> = (0..3).collect();
        // ΣL = 12 > T = 8 with min_tasks joined; intrinsic also 12 > 8 →
        // full relaxation, flag set on both paths.
        check_equal(
            &mut ix,
            &sigs,
            &all,
            &RoundParams { tasks: 8, min_tasks: 5, max_share: 1.0 },
        );
        // Exhausted: all uppers zero.
        let dead = Sigs(vec![(affine(1.0), 0, 0), (affine(2.0), 0, 0)]);
        let mut dx = FleetIndex::build(2, dead.get());
        check_equal(&mut dx, &dead, &[0, 1], &P);
    }

    #[test]
    fn unmarked_mutation_desynchronizes_the_index() {
        // The contract, demonstrated: a signature change without a mark
        // leaves the index deriving against stale state. This is exactly
        // what the coordinator's mark-at-every-mutation sites prevent.
        let mut sigs = fleet_sigs();
        let mut ix = FleetIndex::build(sigs.0.len(), sigs.get());
        sigs.0[3].2 = 2; // mutate, do NOT mark
        let all: Vec<usize> = (0..8).collect();
        let mut r = false;
        let (stale, _) = ix.derive(&all, &P, &mut r).unwrap().unwrap();
        let (fresh, _) =
            from_scratch_round(sigs.get(), &all, &P, &mut r).unwrap().unwrap();
        assert_ne!(stale.digest(), fresh.digest());
        // Marking repairs it.
        ix.mark(3);
        ix.apply(sigs.get());
        check_equal(&mut ix, &sigs, &all, &P);
    }

    #[test]
    fn audit_holds_across_mark_apply_derive() {
        let mut sigs = fleet_sigs();
        let mut ix = FleetIndex::build(sigs.0.len(), sigs.get());
        ix.audit().unwrap();
        sigs.0[2].2 = 9;
        ix.mark(2);
        ix.audit().unwrap();
        assert_eq!(ix.apply(sigs.get()), 1);
        ix.audit().unwrap();
        let all: Vec<usize> = (0..8).collect();
        let mut relaxed = false;
        let p = RoundParams { tasks: 6, min_tasks: 0, max_share: 1.0 };
        ix.derive(&all, &p, &mut relaxed).unwrap().unwrap();
        ix.audit().unwrap();

        // Hand-corrupted states are caught.
        let mut bad = ix.clone();
        bad.classes[0].refs += 1;
        assert!(bad.audit().unwrap_err().contains("devices point at it"));
        let mut bad = ix.clone();
        bad.pending.push(1);
        assert!(bad.audit().unwrap_err().contains("disagrees"));
        let mut bad = ix.clone();
        bad.free.push(0);
        assert!(bad.audit().unwrap_err().contains("still referenced"));
    }

    #[test]
    fn fingerprint_tracks_classification_and_dirty_set() {
        let sigs = fleet_sigs();
        let mut ix = FleetIndex::build(sigs.0.len(), sigs.get());
        let f0 = ix.fingerprint();
        let clone = ix.clone();
        assert_eq!(clone.fingerprint(), f0, "clones fingerprint equal");
        ix.mark(1);
        let f1 = ix.fingerprint();
        assert_ne!(f0, f1, "pending marks are visible");
        // Mark order is invisible (the set is hashed sorted).
        let mut a = clone.clone();
        let mut b = clone.clone();
        a.mark(1);
        a.mark(4);
        b.mark(4);
        b.mark(1);
        assert_eq!(a.fingerprint(), b.fingerprint());
        // Applying a no-op mark restores the original fingerprint.
        ix.apply(sigs.get());
        assert_eq!(ix.fingerprint(), f0);
    }
}
