//! Problem model: the Minimal Cost FL Schedule instance `(R, T, U, L, C)`
//! (paper §3, Definition 1) and the schedule type.

use crate::error::{FedError, Result};
use crate::sched::costs::CostFn;

/// A Minimal Cost FL Schedule problem instance.
///
/// `n` heterogeneous resources must together train on `T` identical,
/// independent, atomic tasks (mini-batches). Resource `i` must receive
/// between `lower[i]` and `upper[i]` tasks, paying `costs[i].eval(x_i)`
/// energy. The objective is to minimize the **total** cost `Σ_i C_i(x_i)`
/// subject to `Σ_i x_i = T`.
#[derive(Clone, Debug)]
pub struct Instance {
    /// Workload size `T`.
    pub tasks: usize,
    /// Lower limits `L_i`.
    pub lower: Vec<usize>,
    /// Upper limits `U_i`. A resource "without upper limit" (paper §5.5)
    /// is encoded as `U_i >= T` (no assignment can exceed `T` anyway).
    pub upper: Vec<usize>,
    /// Cost functions `C_i`.
    pub costs: Vec<CostFn>,
}

impl Instance {
    /// Build and validate an instance.
    pub fn new(
        tasks: usize,
        lower: Vec<usize>,
        upper: Vec<usize>,
        costs: Vec<CostFn>,
    ) -> Result<Self> {
        let inst = Self { tasks, lower, upper, costs };
        inst.validate()?;
        Ok(inst)
    }

    /// Number of resources `n`.
    pub fn n(&self) -> usize {
        self.costs.len()
    }

    /// Validity conditions from §3: consistent vector lengths, `L_i <= U_i`,
    /// and `ΣL <= T <= ΣU` (otherwise no feasible schedule exists).
    pub fn validate(&self) -> Result<()> {
        let n = self.costs.len();
        if n == 0 {
            return Err(FedError::InvalidInstance("no resources".into()));
        }
        if self.lower.len() != n || self.upper.len() != n {
            return Err(FedError::InvalidInstance(format!(
                "length mismatch: costs={n} lower={} upper={}",
                self.lower.len(),
                self.upper.len()
            )));
        }
        for i in 0..n {
            if self.lower[i] > self.upper[i] {
                return Err(FedError::InvalidInstance(format!(
                    "resource {i}: L={} > U={}",
                    self.lower[i], self.upper[i]
                )));
            }
        }
        // Overflow-safe bound sums: "unlimited" resources are routinely
        // encoded as `U_i = usize::MAX`, so clamp each term to `T` first
        // (an assignment can never exceed the workload) and saturate the
        // fold. Saturation keeps both comparisons conservative: a saturated
        // ΣL is still `> T`, and a saturated ΣU is still `>= T`. Lower
        // limits are NOT clamped — a single `L_i > T` must keep the whole
        // sum above `T` (the instance is genuinely infeasible).
        let sum_l: usize = self
            .lower
            .iter()
            .fold(0usize, |acc, &l| acc.saturating_add(l));
        let sum_u: usize = self
            .upper
            .iter()
            .fold(0usize, |acc, &u| acc.saturating_add(u.min(self.tasks)));
        if sum_l > self.tasks {
            return Err(FedError::InvalidInstance(format!(
                "ΣL = {sum_l} > T = {}",
                self.tasks
            )));
        }
        if sum_u < self.tasks {
            return Err(FedError::InvalidInstance(format!(
                "ΣU = {sum_u} < T = {}",
                self.tasks
            )));
        }
        Ok(())
    }

    /// Effective upper limit of resource `i`, clamped to `T` (an assignment
    /// can never exceed the workload).
    #[inline]
    pub fn cap(&self, i: usize) -> usize {
        self.upper[i].min(self.tasks)
    }

    /// True if resource `i` has no effective upper limit (`U_i >= T`,
    /// paper §5.5's "without upper limits").
    #[inline]
    pub fn unlimited(&self, i: usize) -> bool {
        self.upper[i] >= self.tasks
    }

    /// The worked example of paper §3.1 (Figs. 1 and 2):
    /// `R = {1,2,3}`, `U = {6,6,5}`, `L = {1,0,0}`, tabulated costs.
    ///
    /// With `T = 5` the optimum is `X* = {2,3,0}`, `ΣC = 7.5` (Fig. 1);
    /// with `T = 8` it is `X* = {1,2,5}`, `ΣC = 11.5` (Fig. 2).
    pub fn paper_example(tasks: usize) -> Instance {
        let c1 = CostFn::from_table(&[
            (1, 2.0), (2, 3.5), (3, 5.5), (4, 8.0), (5, 10.0), (6, 12.0),
        ]);
        let c2 = CostFn::from_table(&[
            (0, 0.0), (1, 1.5), (2, 2.5), (3, 4.0), (4, 7.0), (5, 9.0), (6, 11.0),
        ]);
        let c3 = CostFn::from_table(&[
            (0, 0.0), (1, 3.0), (2, 4.0), (3, 5.0), (4, 6.0), (5, 7.0),
        ]);
        Instance::new(tasks, vec![1, 0, 0], vec![6, 6, 5], vec![c1, c2, c3])
            .expect("paper example is valid")
    }
}

/// A schedule `X = {x_1, ..., x_n}` assigning tasks to resources.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Schedule {
    x: Vec<usize>,
}

impl Schedule {
    /// Wrap raw assignments.
    pub fn new(x: Vec<usize>) -> Self {
        Self { x }
    }

    /// All-zero schedule for `n` resources.
    pub fn zeros(n: usize) -> Self {
        Self { x: vec![0; n] }
    }

    /// Assignment vector.
    pub fn assignments(&self) -> &[usize] {
        &self.x
    }

    /// Mutable access (used by solvers).
    pub fn assignments_mut(&mut self) -> &mut [usize] {
        &mut self.x
    }

    /// Tasks assigned to resource `i`.
    #[inline]
    pub fn get(&self, i: usize) -> usize {
        self.x[i]
    }

    /// Set resource `i`'s assignment.
    #[inline]
    pub fn set(&mut self, i: usize, v: usize) {
        self.x[i] = v;
    }

    /// Total assigned tasks.
    pub fn total(&self) -> usize {
        self.x.iter().sum()
    }

    /// Number of resources.
    pub fn len(&self) -> usize {
        self.x.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.x.is_empty()
    }
}

impl std::fmt::Display for Schedule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{{")?;
        for (i, v) in self.x.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{v}")?;
        }
        write!(f, "}}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_is_valid() {
        let inst = Instance::paper_example(5);
        assert_eq!(inst.n(), 3);
        assert_eq!(inst.tasks, 5);
        inst.validate().unwrap();
        let inst8 = Instance::paper_example(8);
        inst8.validate().unwrap();
    }

    #[test]
    fn paper_example_costs_match_figures() {
        let inst = Instance::paper_example(5);
        assert_eq!(inst.costs[0].eval(2), 3.5);
        assert_eq!(inst.costs[1].eval(3), 4.0);
        assert_eq!(inst.costs[2].eval(5), 7.0);
    }

    #[test]
    fn rejects_invalid() {
        // L > U
        assert!(Instance::new(
            3,
            vec![2],
            vec![1],
            vec![CostFn::Affine { fixed: 0.0, per_task: 1.0 }]
        )
        .is_err());
        // ΣL > T
        assert!(Instance::new(
            1,
            vec![1, 1],
            vec![5, 5],
            vec![
                CostFn::Affine { fixed: 0.0, per_task: 1.0 },
                CostFn::Affine { fixed: 0.0, per_task: 1.0 }
            ]
        )
        .is_err());
        // ΣU < T
        assert!(Instance::new(
            10,
            vec![0, 0],
            vec![3, 3],
            vec![
                CostFn::Affine { fixed: 0.0, per_task: 1.0 },
                CostFn::Affine { fixed: 0.0, per_task: 1.0 }
            ]
        )
        .is_err());
        // no resources
        assert!(Instance::new(1, vec![], vec![], vec![]).is_err());
    }

    #[test]
    fn huge_limits_do_not_overflow_validation() {
        // Unlimited resources encoded as usize::MAX must not overflow the
        // ΣU fold; the instance is perfectly valid.
        let c = CostFn::Affine { fixed: 0.0, per_task: 1.0 };
        let inst = Instance::new(
            10,
            vec![0, 0, 0],
            vec![usize::MAX, usize::MAX, usize::MAX],
            vec![c.clone(), c.clone(), c.clone()],
        )
        .unwrap();
        assert!(inst.unlimited(0));
        assert_eq!(inst.cap(0), 10);
        // A single huge lower limit must be rejected (ΣL saturates, which
        // still compares > T) rather than wrapping around to "feasible".
        assert!(Instance::new(
            10,
            vec![usize::MAX, usize::MAX],
            vec![usize::MAX, usize::MAX],
            vec![c.clone(), c],
        )
        .is_err());
    }

    #[test]
    fn cap_and_unlimited() {
        let inst = Instance::new(
            5,
            vec![0, 0],
            vec![3, 100],
            vec![
                CostFn::Affine { fixed: 0.0, per_task: 1.0 },
                CostFn::Affine { fixed: 0.0, per_task: 2.0 },
            ],
        )
        .unwrap();
        assert_eq!(inst.cap(0), 3);
        assert_eq!(inst.cap(1), 5);
        assert!(!inst.unlimited(0));
        assert!(inst.unlimited(1));
    }

    #[test]
    fn schedule_basics() {
        let mut s = Schedule::zeros(3);
        s.set(1, 4);
        assert_eq!(s.total(), 4);
        assert_eq!(s.get(1), 4);
        assert_eq!(format!("{s}"), "{0, 4, 0}");
        assert_eq!(s.len(), 3);
    }
}
