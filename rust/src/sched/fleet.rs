//! Fleet-scale problem model: device **classes** instead of per-device
//! vectors.
//!
//! The paper's tasks are identical and atomic, so two devices with the
//! same cost function and the same limits are *interchangeable*: any
//! schedule can permute their assignments without changing the total cost
//! (paper §3, Definition 1 is symmetric in equal resources). Real fleets
//! exploit this heavily — 10⁵ phones fall into a few hundred hardware/
//! battery archetypes — and related work on mobile-edge FL (Luo et al.,
//! arXiv:2109.05411; Gao et al., arXiv:2211.00481) schedules device
//! *populations*, not individuals.
//!
//! This module provides:
//!
//! * [`DeviceClass`] — one `(C, L, U)` signature plus the member devices;
//! * [`FleetInstance`] — a builder-constructed, validated instance whose
//!   size is the number of classes `k`, not the number of devices `n`;
//! * [`CostView`] — the lazy cost seam solvers evaluate through (no
//!   `O(n·T)` pre-materialized tables), including [`LowerFree`], the §5.2
//!   lower-limit removal as a zero-allocation view;
//! * [`Assignment`] — class-level decisions (run-length encoded loads)
//!   that expand to per-device [`Schedule`]s on demand.
//!
//! [`FleetInstance::from_flat`] / [`FleetInstance::to_flat`] adapt to the
//! legacy per-device [`Instance`]; the round-trip is exact (same slot
//! order, same limits, value-equal cost functions), which is what keeps
//! the seed solvers bit-for-bit equivalent through the new
//! [`crate::sched::solver::Solver`] seam.

// fedlint: allow(R1) — probe-only dedup index: class order comes from
// first-occurrence push order, never from map iteration.
use std::collections::HashMap;

use crate::error::{FedError, Result};
use crate::sched::costs::CostFn;
use crate::sched::instance::{Instance, Schedule};

/// A class of interchangeable devices: one cost signature, many members.
#[derive(Clone, Debug)]
pub struct DeviceClass {
    /// Shared cost function `C` of every member.
    pub cost: CostFn,
    /// Shared lower limit `L`.
    pub lower: usize,
    /// Shared upper limit `U` (`>= T` encodes "unlimited", as in
    /// [`Instance`]).
    pub upper: usize,
    /// Device slots belonging to this class, in ascending slot order.
    pub members: Vec<usize>,
}

impl DeviceClass {
    /// Multiplicity `m` of the class.
    #[inline]
    pub fn count(&self) -> usize {
        self.members.len()
    }
}

/// A class-deduplicated Minimal Cost FL Schedule instance.
///
/// Constructed through [`FleetInstance::builder`] (or
/// [`FleetInstance::from_flat`]); always validated. Device *slots*
/// `0..n_devices()` are the order devices were added in — the order
/// [`Assignment::expand`] and [`FleetInstance::to_flat`] reproduce.
#[derive(Clone, Debug)]
pub struct FleetInstance {
    /// Workload size `T`.
    pub tasks: usize,
    classes: Vec<DeviceClass>,
    /// Class index of each device slot.
    slot_class: Vec<usize>,
}

impl FleetInstance {
    /// Start building a fleet instance.
    pub fn builder() -> FleetBuilder {
        FleetBuilder::new()
    }

    /// The device classes (ascending first-member order).
    pub fn classes(&self) -> &[DeviceClass] {
        &self.classes
    }

    /// Number of device classes `k` (inherent so callers need not import
    /// [`CostView`]).
    pub fn n_classes(&self) -> usize {
        self.classes.len()
    }

    /// Total devices `n = Σ m_c`.
    pub fn n_devices(&self) -> usize {
        self.slot_class.len()
    }

    /// Class of a device slot.
    #[inline]
    pub fn class_of(&self, slot: usize) -> usize {
        self.slot_class[slot]
    }

    /// Adapt a flat per-device instance: group equal `(C, L, U)` devices
    /// into classes (`O(n)` expected via structural hashing), preserving
    /// slot order. The round-trip through [`FleetInstance::to_flat`] is
    /// exact.
    pub fn from_flat(inst: &Instance) -> Result<FleetInstance> {
        inst.validate()?;
        let mut b = FleetBuilder::new().tasks(inst.tasks);
        for i in 0..inst.n() {
            b = b.device(inst.costs[i].clone(), inst.lower[i], inst.upper[i]);
        }
        b.build()
    }

    /// Expand back to the flat per-device instance (slot order).
    pub fn to_flat(&self) -> Instance {
        let n = self.n_devices();
        let mut lower = Vec::with_capacity(n);
        let mut upper = Vec::with_capacity(n);
        let mut costs = Vec::with_capacity(n);
        for &c in &self.slot_class {
            let class = &self.classes[c];
            lower.push(class.lower);
            upper.push(class.upper);
            costs.push(class.cost.clone());
        }
        // Invariants guaranteed by the builder; skip re-validation.
        Instance { tasks: self.tasks, lower, upper, costs }
    }

    /// Order-sensitive structural digest (FNV-1a over `T`, every class's
    /// cost fingerprint, limits, and membership). Two fleets digest equal
    /// iff they were built from the same device sequence with
    /// structurally-equal cost functions — what the coordinator store's
    /// journal records per round so `replay`/`restore` can prove a resumed
    /// campaign re-derived the exact same instances.
    pub fn digest(&self) -> u64 {
        use crate::util::hash::{mix_u64, FNV_OFFSET};
        let mut h = mix_u64(FNV_OFFSET, self.tasks as u64);
        h = mix_u64(h, self.classes.len() as u64);
        for class in &self.classes {
            h = mix_u64(h, class.cost.structural_hash());
            h = mix_u64(h, class.lower as u64);
            h = mix_u64(h, class.upper as u64);
            h = mix_u64(h, class.members.len() as u64);
            for &m in &class.members {
                h = mix_u64(h, m as u64);
            }
        }
        h
    }

    /// Validity conditions of §3 at class granularity: `L <= U` per class
    /// and `ΣL <= T <= ΣU` over all members (overflow-safe, mirroring
    /// [`Instance::validate`]).
    pub fn validate(&self) -> Result<()> {
        if self.classes.is_empty() {
            return Err(FedError::InvalidInstance("no device classes".into()));
        }
        let mut sum_l = 0usize;
        let mut sum_u = 0usize;
        for (c, class) in self.classes.iter().enumerate() {
            if class.members.is_empty() {
                return Err(FedError::InvalidInstance(format!(
                    "class {c}: empty member list"
                )));
            }
            if class.lower > class.upper {
                return Err(FedError::InvalidInstance(format!(
                    "class {c}: L={} > U={}",
                    class.lower, class.upper
                )));
            }
            // Per-member fold keeps saturation semantics identical to the
            // flat validator (a single huge L must stay > T).
            for _ in 0..class.count() {
                sum_l = sum_l.saturating_add(class.lower);
                sum_u = sum_u.saturating_add(class.upper.min(self.tasks));
            }
        }
        if sum_l > self.tasks {
            return Err(FedError::InvalidInstance(format!(
                "ΣL = {sum_l} > T = {}",
                self.tasks
            )));
        }
        if sum_u < self.tasks {
            return Err(FedError::InvalidInstance(format!(
                "ΣU = {sum_u} < T = {}",
                self.tasks
            )));
        }
        Ok(())
    }

    /// Structural deep-audit behind the debug-build invariant auditor
    /// ([`crate::sched::validate::audit_instance`]): everything
    /// [`FleetInstance::validate`] does *not* check — membership /
    /// back-pointer consistency, canonical first-occurrence class order,
    /// and signature uniqueness. `O(n + k²)`; debug builds only.
    pub(crate) fn audit(&self) -> std::result::Result<(), String> {
        let n = self.slot_class.len();
        let mut claimed = vec![false; n];
        let mut prev_first = None;
        for (c, class) in self.classes.iter().enumerate() {
            let Some(&first) = class.members.first() else {
                return Err(format!("class {c}: empty member list"));
            };
            if class.lower > class.upper {
                return Err(format!("class {c}: L={} > U={}", class.lower, class.upper));
            }
            if prev_first.is_some_and(|p| first <= p) {
                return Err(format!(
                    "class {c}: first member {first} does not follow the previous class's \
                     (classes must sit in first-occurrence order)"
                ));
            }
            prev_first = Some(first);
            let mut prev = None;
            for &s in &class.members {
                if s >= n {
                    return Err(format!("class {c}: member slot {s} out of range 0..{n}"));
                }
                if prev.is_some_and(|p| s <= p) {
                    return Err(format!("class {c}: members not strictly ascending at slot {s}"));
                }
                prev = Some(s);
                if claimed[s] {
                    return Err(format!("slot {s} claimed by two classes"));
                }
                claimed[s] = true;
                if self.slot_class[s] != c {
                    return Err(format!(
                        "slot {s}: back-pointer {} != owning class {c}",
                        self.slot_class[s]
                    ));
                }
            }
            for d in self.classes.iter().take(c) {
                if d.lower == class.lower && d.upper == class.upper && d.cost == class.cost {
                    return Err(format!("class {c} duplicates an earlier class signature"));
                }
            }
        }
        // Back-pointers are total over 0..n, so with every membership
        // verified above an unclaimed slot is impossible unless the two
        // structures disagree in length.
        if let Some(s) = claimed.iter().position(|&done| !done) {
            return Err(format!("slot {s} belongs to no class"));
        }
        Ok(())
    }
}

impl FleetInstance {
    /// Assemble a fleet from already-grouped classes — the sharded build
    /// path ([`crate::sched::shard`]). The class member lists must
    /// partition the slot range `0..n` exactly (each slot claimed once);
    /// the result is validated like any built fleet.
    pub(crate) fn from_classes(
        tasks: usize,
        classes: Vec<DeviceClass>,
    ) -> Result<FleetInstance> {
        let n: usize = classes.iter().map(|c| c.members.len()).sum();
        let mut slot_class = vec![usize::MAX; n];
        for (ci, class) in classes.iter().enumerate() {
            for &s in &class.members {
                if s >= n || slot_class[s] != usize::MAX {
                    return Err(FedError::InvalidInstance(format!(
                        "class member lists must partition slots 0..{n} \
                         (slot {s} missing or claimed twice)"
                    )));
                }
                slot_class[s] = ci;
            }
        }
        let fleet = FleetInstance { tasks, classes, slot_class };
        fleet.validate()?;
        crate::sched::validate::audit_instance(&fleet);
        Ok(fleet)
    }
}

/// Dedup bucket key of a `(C, L, U)` device signature — shared by
/// [`FleetBuilder`] and the sharded build path
/// ([`crate::sched::shard`]), so cross-shard class fusion uses the exact
/// bucketing the direct builder uses (a prerequisite for bit-for-bit
/// merge results).
#[inline]
pub(crate) fn class_key(cost: &CostFn, lower: usize, upper: usize) -> u64 {
    cost.structural_hash().wrapping_mul(0x9e37_79b9_7f4a_7c15)
        ^ (lower as u64).wrapping_mul(0x517c_c1b7_2722_0a95)
        ^ (upper as u64)
}

/// The probe/insert core shared by **every** class-dedup site — the
/// direct [`FleetBuilder`], the per-shard dedup, the cross-shard merge
/// ([`crate::sched::shard`]), and the persistent index's per-round
/// emission ([`crate::sched::incremental`]). One bucketing, one equality
/// rule, one first-occurrence class order: the sharded and incremental
/// bit-for-bit contracts hold *by construction* because all these paths
/// run this exact code.
#[derive(Debug, Default)]
pub(crate) struct ClassTable {
    pub(crate) classes: Vec<DeviceClass>,
    /// structural hash → candidate class indices (collision chain).
    // fedlint: allow(R1) — probe-only: lookups go through `get`, and the
    // emitted class order is `classes` push order, never bucket order.
    buckets: HashMap<u64, Vec<usize>>,
}

impl ClassTable {
    pub(crate) fn with_capacity(cap: usize) -> Self {
        Self {
            classes: Vec::with_capacity(cap),
            // fedlint: allow(R1) — same probe-only index as the field.
            buckets: HashMap::with_capacity(cap),
        }
    }

    /// Index of the class with this signature, creating it (with an empty
    /// member list) on first occurrence.
    pub(crate) fn class_index(
        &mut self,
        cost: &CostFn,
        lower: usize,
        upper: usize,
    ) -> usize {
        let key = class_key(cost, lower, upper);
        let found = self.buckets.get(&key).and_then(|chain| {
            chain.iter().copied().find(|&ci| {
                let cl = &self.classes[ci];
                cl.lower == lower && cl.upper == upper && cl.cost == *cost
            })
        });
        match found {
            Some(ci) => ci,
            None => {
                let ci = self.classes.len();
                self.buckets.entry(key).or_default().push(ci);
                self.classes.push(DeviceClass {
                    cost: cost.clone(),
                    lower,
                    upper,
                    members: Vec::new(),
                });
                ci
            }
        }
    }

    /// Consume the table into its classes in first-occurrence order —
    /// what [`FleetInstance::from_classes`] expects. Used by the merge
    /// sites that probe a table and then emit
    /// ([`crate::sched::incremental`]).
    pub(crate) fn into_classes(self) -> Vec<DeviceClass> {
        self.classes
    }
}

/// Builder for [`FleetInstance`]: push devices (or whole classes), then
/// [`FleetBuilder::build`]. Devices with equal `(C, L, U)` signatures are
/// deduplicated into one class regardless of push order.
#[derive(Debug, Default)]
pub struct FleetBuilder {
    tasks: usize,
    table: ClassTable,
    n_devices: usize,
}

impl FleetBuilder {
    /// Empty builder (`T = 0` until [`FleetBuilder::tasks`]).
    pub fn new() -> Self {
        Self::default()
    }

    /// Set the workload size `T`.
    pub fn tasks(mut self, t: usize) -> Self {
        self.tasks = t;
        self
    }

    /// Add one device; returns the builder (slots are assigned in push
    /// order).
    pub fn device(self, cost: CostFn, lower: usize, upper: usize) -> Self {
        self.device_class(cost, lower, upper, 1)
    }

    /// Add `count` interchangeable devices at once (consecutive slots).
    pub fn device_class(
        mut self,
        cost: CostFn,
        lower: usize,
        upper: usize,
        count: usize,
    ) -> Self {
        if count == 0 {
            return self;
        }
        let first = self.n_devices;
        self.n_devices += count;
        let ci = self.table.class_index(&cost, lower, upper);
        self.table.classes[ci].members.extend(first..first + count);
        self
    }

    /// Validate and finish.
    pub fn build(self) -> Result<FleetInstance> {
        let mut slot_class = vec![0usize; self.n_devices];
        for (ci, class) in self.table.classes.iter().enumerate() {
            for &s in &class.members {
                slot_class[s] = ci;
            }
        }
        let fleet = FleetInstance {
            tasks: self.tasks,
            classes: self.table.classes,
            slot_class,
        };
        fleet.validate()?;
        crate::sched::validate::audit_instance(&fleet);
        Ok(fleet)
    }
}

/// Lazy cost access at class granularity — the seam solvers evaluate
/// through instead of receiving `O(n·T)` pre-materialized tables.
///
/// Implementors: [`FleetInstance`] (the instance itself) and
/// [`LowerFree`] (the §5.2 transformation as a view). Solver cores are
/// generic over `V: CostView + ?Sized`, so they never know (or care)
/// whether limits were already removed.
pub trait CostView {
    /// Workload size `T`.
    fn tasks(&self) -> usize;
    /// Number of device classes `k`.
    fn n_classes(&self) -> usize;
    /// Multiplicity of class `c`.
    fn count(&self, c: usize) -> usize;
    /// Lower limit of each member of class `c`.
    fn lower(&self, c: usize) -> usize;
    /// Upper limit of each member of class `c`.
    fn upper(&self, c: usize) -> usize;
    /// Cost of one member of class `c` running `j` tasks.
    fn eval(&self, c: usize, j: usize) -> f64;

    /// Effective per-member cap of class `c`, clamped to `T`.
    #[inline]
    fn cap(&self, c: usize) -> usize {
        self.upper(c).min(self.tasks())
    }

    /// Marginal cost `M(j)` of the `j`-th task on a member of class `c`
    /// (eq. 6; `M(j <= L) := 0`).
    #[inline]
    fn marginal(&self, c: usize, j: usize) -> f64 {
        if j <= self.lower(c) {
            0.0
        } else {
            self.eval(c, j) - self.eval(c, j - 1)
        }
    }

    /// Total devices `n = Σ m_c`.
    fn n_devices(&self) -> usize {
        (0..self.n_classes()).map(|c| self.count(c)).sum()
    }
}

impl CostView for FleetInstance {
    fn tasks(&self) -> usize {
        self.tasks
    }
    fn n_classes(&self) -> usize {
        self.classes.len()
    }
    fn count(&self, c: usize) -> usize {
        self.classes[c].count()
    }
    fn lower(&self, c: usize) -> usize {
        self.classes[c].lower
    }
    fn upper(&self, c: usize) -> usize {
        self.classes[c].upper
    }
    fn eval(&self, c: usize, j: usize) -> f64 {
        self.classes[c].cost.eval(j)
    }
}

/// The §5.2 lower-limit removal (eqs. 8–10) as a **lazy view**: no cost
/// clones, no boxed [`CostFn::Shifted`] wrappers — `T' = T − Σ m·L`,
/// `U' = U − L`, `C'(j) = C(j + L) − C(L)`, computed per query.
#[derive(Clone, Copy, Debug)]
pub struct LowerFree<'a> {
    fleet: &'a FleetInstance,
    t_prime: usize,
}

impl<'a> LowerFree<'a> {
    /// View `fleet` with all lower limits removed.
    pub fn of(fleet: &'a FleetInstance) -> Self {
        let sum_l: usize = fleet
            .classes
            .iter()
            .map(|cl| cl.lower * cl.count())
            .sum();
        // Valid instances satisfy Σ m·L ≤ T (validate()), so saturation
        // never engages; it merely shields invalid input.
        Self { fleet, t_prime: fleet.tasks.saturating_sub(sum_l) }
    }

    /// Map transformed class loads back to original loads (eq. 11:
    /// `x = x' + L`).
    pub fn restore(&self, mut groups: ClassLoads) -> ClassLoads {
        for (c, g) in groups.iter_mut().enumerate() {
            let l = self.fleet.classes[c].lower;
            if l > 0 {
                for (load, _) in g.iter_mut() {
                    *load += l;
                }
            }
        }
        groups
    }
}

impl CostView for LowerFree<'_> {
    fn tasks(&self) -> usize {
        self.t_prime
    }
    fn n_classes(&self) -> usize {
        self.fleet.classes.len()
    }
    fn count(&self, c: usize) -> usize {
        self.fleet.classes[c].count()
    }
    fn lower(&self, _c: usize) -> usize {
        0
    }
    fn upper(&self, c: usize) -> usize {
        let cl = &self.fleet.classes[c];
        // L ≤ U per class (validate()); exact there, shielded otherwise.
        cl.upper.saturating_sub(cl.lower)
    }
    fn eval(&self, c: usize, j: usize) -> f64 {
        let cl = &self.fleet.classes[c];
        if cl.lower == 0 {
            cl.cost.eval(j)
        } else {
            // fedlint: allow(R2) — eq. 10 float cost math: j ≤ U′ keeps
            // j + L ≤ U in range, and the `-` is on f64 costs, not capacity.
            cl.cost.eval(j + cl.lower) - cl.cost.eval(cl.lower)
        }
    }
}

/// Class-level loads: for each class, `(load, n_devices)` runs in member
/// order. `Σ n_devices` per class must equal the class multiplicity.
pub type ClassLoads = Vec<Vec<(usize, usize)>>;

/// A class-level scheduling decision, expandable to a per-device
/// [`Schedule`] on demand.
///
/// Stored run-length encoded: class `c`'s members receive the loads of
/// `groups()[c]` in member order, so an `Assignment` built from a flat
/// schedule ([`Assignment::from_schedule`]) expands back to exactly that
/// schedule.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Assignment {
    groups: ClassLoads,
}

impl Assignment {
    /// Wrap solver-produced class loads, merging adjacent equal runs.
    pub fn from_groups(groups: ClassLoads) -> Self {
        let groups = groups
            .into_iter()
            .map(|g| {
                let mut out: Vec<(usize, usize)> = Vec::with_capacity(g.len());
                for (load, n) in g {
                    if n == 0 {
                        continue;
                    }
                    match out.last_mut() {
                        Some((last, ln)) if *last == load => *ln += n,
                        _ => out.push((load, n)),
                    }
                }
                out
            })
            .collect();
        Self { groups }
    }

    /// Group a flat schedule's per-device loads by class (member order
    /// preserved, so [`Assignment::expand`] round-trips exactly).
    pub fn from_schedule(fleet: &FleetInstance, sched: &Schedule) -> Self {
        let groups = fleet
            .classes
            .iter()
            .map(|cl| {
                cl.members
                    .iter()
                    .map(|&s| (sched.get(s), 1))
                    .collect::<Vec<_>>()
            })
            .collect();
        Self::from_groups(groups)
    }

    /// The per-class load runs.
    pub fn groups(&self) -> &ClassLoads {
        &self.groups
    }

    /// Total assigned tasks.
    pub fn total_tasks(&self) -> usize {
        self.groups
            .iter()
            .flatten()
            .map(|&(load, n)| load * n)
            .sum()
    }

    /// Total cost `Σ_c Σ_runs n · C_c(load)` under a view.
    pub fn total_cost<V: CostView + ?Sized>(&self, view: &V) -> f64 {
        self.groups
            .iter()
            .enumerate()
            .flat_map(|(c, g)| {
                g.iter().map(move |&(load, n)| n as f64 * view.eval(c, load))
            })
            .sum()
    }

    /// Feasibility at class level: run counts match multiplicities, loads
    /// within `[L, U]`, totals sum to `T` (mirrors
    /// [`crate::sched::validate::check`]).
    pub fn check(&self, fleet: &FleetInstance) -> Result<()> {
        if self.groups.len() != fleet.n_classes() {
            return Err(FedError::InvalidSchedule(format!(
                "assignment has {} classes for {}",
                self.groups.len(),
                fleet.n_classes()
            )));
        }
        for (c, g) in self.groups.iter().enumerate() {
            let class = &fleet.classes()[c];
            let devs: usize = g.iter().map(|&(_, n)| n).sum();
            if devs != class.count() {
                return Err(FedError::InvalidSchedule(format!(
                    "class {c}: {devs} loads for {} members",
                    class.count()
                )));
            }
            for &(load, _) in g {
                if load < class.lower || load > class.upper {
                    return Err(FedError::InvalidSchedule(format!(
                        "class {c}: load {load} outside [{}, {}]",
                        class.lower, class.upper
                    )));
                }
            }
        }
        let total = self.total_tasks();
        if total != fleet.tasks {
            return Err(FedError::InvalidSchedule(format!(
                "assigned {total} != T = {}",
                fleet.tasks
            )));
        }
        Ok(())
    }

    /// Expand to a per-device schedule in slot order.
    pub fn expand(&self, fleet: &FleetInstance) -> Schedule {
        let mut x = vec![0usize; fleet.n_devices()];
        for (c, g) in self.groups.iter().enumerate() {
            let members = &fleet.classes()[c].members;
            let mut m = 0usize;
            for &(load, n) in g {
                for _ in 0..n {
                    x[members[m]] = load;
                    m += 1;
                }
            }
        }
        Schedule::new(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::validate;

    fn affine(per_task: f64) -> CostFn {
        CostFn::Affine { fixed: 0.0, per_task }
    }

    #[test]
    fn builder_dedups_equal_devices() {
        let fleet = FleetInstance::builder()
            .tasks(10)
            .device(affine(1.0), 0, 5)
            .device(affine(2.0), 0, 5)
            .device(affine(1.0), 0, 5)
            .device_class(affine(2.0), 0, 5, 3)
            .build()
            .unwrap();
        assert_eq!(fleet.n_classes(), 2);
        assert_eq!(fleet.n_devices(), 6);
        assert_eq!(fleet.classes()[0].members, vec![0, 2]);
        assert_eq!(fleet.classes()[1].members, vec![1, 3, 4, 5]);
        assert_eq!(fleet.class_of(4), 1);
    }

    #[test]
    fn different_limits_split_classes() {
        let fleet = FleetInstance::builder()
            .tasks(4)
            .device(affine(1.0), 0, 5)
            .device(affine(1.0), 1, 5)
            .device(affine(1.0), 0, 6)
            .build()
            .unwrap();
        assert_eq!(fleet.n_classes(), 3);
    }

    #[test]
    fn builder_validates() {
        // L > U
        assert!(FleetInstance::builder()
            .tasks(3)
            .device(affine(1.0), 2, 1)
            .build()
            .is_err());
        // ΣU < T
        assert!(FleetInstance::builder()
            .tasks(30)
            .device_class(affine(1.0), 0, 2, 3)
            .build()
            .is_err());
        // ΣL > T
        assert!(FleetInstance::builder()
            .tasks(3)
            .device_class(affine(1.0), 2, 4, 2)
            .build()
            .is_err());
        // empty
        assert!(FleetInstance::builder().tasks(1).build().is_err());
    }

    #[test]
    fn huge_limits_do_not_overflow() {
        let fleet = FleetInstance::builder()
            .tasks(10)
            .device_class(affine(1.0), 0, usize::MAX, 3)
            .build()
            .unwrap();
        assert_eq!(fleet.cap(0), 10);
        assert!(FleetInstance::builder()
            .tasks(10)
            .device_class(affine(1.0), usize::MAX, usize::MAX, 2)
            .build()
            .is_err());
    }

    #[test]
    fn from_flat_to_flat_roundtrips_exactly() {
        let inst = Instance::paper_example(8);
        let fleet = FleetInstance::from_flat(&inst).unwrap();
        assert_eq!(fleet.n_classes(), 3, "distinct tables → one class each");
        let back = fleet.to_flat();
        assert_eq!(back.tasks, inst.tasks);
        assert_eq!(back.lower, inst.lower);
        assert_eq!(back.upper, inst.upper);
        for i in 0..inst.n() {
            assert_eq!(back.costs[i], inst.costs[i]);
        }
    }

    #[test]
    fn from_flat_groups_duplicates_and_preserves_slots() {
        let inst = Instance::new(
            6,
            vec![0, 0, 0, 0],
            vec![3, 3, 3, 3],
            vec![affine(1.0), affine(5.0), affine(1.0), affine(5.0)],
        )
        .unwrap();
        let fleet = FleetInstance::from_flat(&inst).unwrap();
        assert_eq!(fleet.n_classes(), 2);
        let back = fleet.to_flat();
        assert_eq!(back.costs[2], affine(1.0));
        assert_eq!(back.costs[3], affine(5.0));
    }

    #[test]
    fn lower_free_view_matches_eq10() {
        let inst = Instance::paper_example(8);
        let fleet = FleetInstance::from_flat(&inst).unwrap();
        let view = LowerFree::of(&fleet);
        assert_eq!(view.tasks(), 7); // 8 - (1+0+0)
        assert_eq!(view.lower(0), 0);
        assert_eq!(view.upper(0), 5);
        for j in 0..=5 {
            let expect = inst.costs[0].eval(j + 1) - inst.costs[0].eval(1);
            assert!((view.eval(0, j) - expect).abs() < 1e-12);
        }
        // zero-lower classes are untouched
        for j in 0..=6 {
            assert_eq!(view.eval(1, j), inst.costs[1].eval(j));
        }
    }

    #[test]
    fn assignment_expand_roundtrips_a_schedule() {
        let inst = Instance::new(
            6,
            vec![0; 4],
            vec![3; 4],
            vec![affine(1.0), affine(5.0), affine(1.0), affine(5.0)],
        )
        .unwrap();
        let fleet = FleetInstance::from_flat(&inst).unwrap();
        let sched = Schedule::new(vec![3, 0, 1, 2]);
        let asg = Assignment::from_schedule(&fleet, &sched);
        asg.check(&fleet).unwrap();
        assert_eq!(asg.expand(&fleet), sched);
        assert_eq!(asg.total_tasks(), 6);
        let cost = asg.total_cost(&fleet);
        assert!((cost - validate::total_cost(&inst, &sched)).abs() < 1e-12);
    }

    #[test]
    fn assignment_check_rejects_bad_loads() {
        let fleet = FleetInstance::builder()
            .tasks(4)
            .device_class(affine(1.0), 1, 3, 2)
            .build()
            .unwrap();
        // load above U
        let bad = Assignment::from_groups(vec![vec![(4, 1), (0, 1)]]);
        assert!(bad.check(&fleet).is_err());
        // wrong member count
        let bad = Assignment::from_groups(vec![vec![(2, 1)]]);
        assert!(bad.check(&fleet).is_err());
        // wrong total
        let bad = Assignment::from_groups(vec![vec![(1, 2)]]);
        assert!(bad.check(&fleet).is_err());
        // valid
        let ok = Assignment::from_groups(vec![vec![(3, 1), (1, 1)]]);
        ok.check(&fleet).unwrap();
        assert_eq!(ok.expand(&fleet).assignments(), &[3, 1]);
    }

    #[test]
    fn digest_separates_structurally_different_fleets() {
        let base = FleetInstance::builder()
            .tasks(10)
            .device_class(affine(1.0), 0, 5, 2)
            .device(affine(2.0), 1, 6)
            .build()
            .unwrap();
        assert_eq!(base.digest(), base.digest(), "digest is deterministic");
        let same = FleetInstance::builder()
            .tasks(10)
            .device(affine(1.0), 0, 5)
            .device(affine(1.0), 0, 5)
            .device(affine(2.0), 1, 6)
            .build()
            .unwrap();
        assert_eq!(base.digest(), same.digest(), "same device sequence");
        for other in [
            FleetInstance::builder() // different T
                .tasks(9)
                .device_class(affine(1.0), 0, 5, 2)
                .device(affine(2.0), 1, 6)
                .build()
                .unwrap(),
            FleetInstance::builder() // different cost
                .tasks(10)
                .device_class(affine(1.5), 0, 5, 2)
                .device(affine(2.0), 1, 6)
                .build()
                .unwrap(),
            FleetInstance::builder() // different upper
                .tasks(10)
                .device_class(affine(1.0), 0, 6, 2)
                .device(affine(2.0), 1, 6)
                .build()
                .unwrap(),
            FleetInstance::builder() // different multiplicity
                .tasks(10)
                .device_class(affine(1.0), 0, 5, 3)
                .device(affine(2.0), 1, 6)
                .build()
                .unwrap(),
        ] {
            assert_ne!(base.digest(), other.digest());
        }
    }

    #[test]
    fn audit_rejects_corrupted_structures() {
        let inst = Instance::paper_example(5);
        let fleet = FleetInstance::from_flat(&inst).unwrap();
        assert_eq!(fleet.n_classes(), 3);
        fleet.audit().unwrap();

        // Back-pointer disagreeing with the owning member list.
        let mut bad = fleet.clone();
        bad.slot_class[0] = 2;
        assert!(bad.audit().unwrap_err().contains("back-pointer"));

        // One slot claimed by two classes.
        let mut bad = fleet.clone();
        bad.classes[1].members = bad.classes[0].members.clone();
        assert!(bad.audit().is_err());

        // Two classes carrying the same (C, L, U) signature.
        let mut bad = fleet.clone();
        bad.classes[1].cost = bad.classes[0].cost.clone();
        bad.classes[1].lower = bad.classes[0].lower;
        bad.classes[1].upper = bad.classes[0].upper;
        assert!(bad.audit().unwrap_err().contains("duplicates"));
    }

    #[test]
    fn from_groups_merges_adjacent_runs() {
        let a = Assignment::from_groups(vec![vec![(2, 1), (2, 3), (0, 1), (2, 1)]]);
        assert_eq!(a.groups()[0], vec![(2, 4), (0, 1), (2, 1)]);
    }

    #[test]
    fn cost_view_marginals_match_costfn() {
        let fleet = FleetInstance::builder()
            .tasks(6)
            .device_class(CostFn::Quadratic { fixed: 1.0, a: 0.5, b: 0.0 }, 1, 6, 2)
            .build()
            .unwrap();
        let c = &fleet.classes()[0].cost;
        assert_eq!(fleet.marginal(0, 1), 0.0, "M(L) := 0");
        assert!((fleet.marginal(0, 3) - c.marginal(3, 1)).abs() < 1e-12);
        assert_eq!(fleet.n_devices(), 2);
    }
}
