//! Algorithm 1: dynamic-programming solution to the Multiple-Choice
//! Minimum-Cost Maximal Knapsack Packing Problem, (MC)²MKP (paper §4).
//!
//! The problem (Definition 2): choose exactly one item from each disjoint
//! class so the chosen weights fit a knapsack of capacity `T`, occupancy is
//! **maximal**, and among maximal packings the cost sum is **minimal**.
//!
//! The recurrence (eqs. 3–5):
//!
//! ```text
//! Z_r(τ) = min_{j ∈ N_r, w_rj <= τ} ( Z_{r-1}(τ - w_rj) + c_rj )
//! X(T)   = Z_n(T) if finite, else X(T-1)
//! ```
//!
//! The minimal-cost tables `K` and chosen-item tables `I` are kept in flat
//! row-major storage (`(n+1) × (cap+1)`) — row `r` only reads row `r-1`, so
//! the inner `t` loop is a sequential scan (see EXPERIMENTS.md §Perf for
//! the layout ablation).
//!
//! The Minimal Cost FL Schedule problem maps onto (MC)²MKP by taking
//! `N_i = {L_i, ..., U_i}`, `w_ij = j`, `c_ij = C_i(j)` (paper §4.1.1);
//! [`solve`] implements that end-to-end (with the §5.2 lower-limit removal
//! applied first so class weights start at zero).

use crate::error::{FedError, Result};
use crate::sched::fleet::{Assignment, CostView, FleetInstance, LowerFree};
use crate::sched::instance::{Instance, Schedule};
use crate::sched::limits;

/// A knapsack item: `weight` units of capacity at `cost`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Item {
    pub weight: usize,
    pub cost: f64,
}

/// Disjoint item classes (`N_1, ..., N_n`).
#[derive(Clone, Debug, Default)]
pub struct Classes {
    pub classes: Vec<Vec<Item>>,
}

impl Classes {
    /// Total number of items `Σ |N_i|`.
    pub fn item_count(&self) -> usize {
        self.classes.iter().map(|c| c.len()).sum()
    }
}

/// Sentinel for "no item chosen / infeasible" in the items table.
const NO_ITEM: u32 = u32::MAX;

/// The DP support matrices `K` (minimal costs) and `I` (chosen items),
/// reusable by MarDec (paper Algorithm 5 calls "(MC)²MKP-matrices").
#[derive(Clone, Debug)]
pub struct DpMatrices {
    /// Number of classes.
    pub n: usize,
    /// Knapsack capacity.
    pub cap: usize,
    /// Flat `(n+1) × (cap+1)` minimal-cost table; row 0 is the base case
    /// `Z_0(0) = 0`, `Z_0(τ>0) = ∞`.
    k: Vec<f64>,
    /// Flat `(n+1) × (cap+1)` chosen-item table (index of the item within
    /// its class), `NO_ITEM` where infeasible.
    item: Vec<u32>,
}

impl DpMatrices {
    /// `Z_r(τ)` — minimal cost filling exactly `τ` with the first `r`
    /// classes (∞ if infeasible).
    #[inline]
    pub fn z(&self, r: usize, tau: usize) -> f64 {
        self.k[r * (self.cap + 1) + tau]
    }

    /// Index (within class `r-1`) of the item chosen at `Z_r(τ)`.
    #[inline]
    fn chosen(&self, r: usize, tau: usize) -> u32 {
        self.item[r * (self.cap + 1) + tau]
    }

    /// Largest `τ* <= cap_limit` with `Z_n(τ*)` finite, plus its cost —
    /// the maximal-packing selection of eq. (5).
    pub fn best_capacity(&self, cap_limit: usize) -> Option<(usize, f64)> {
        let mut t = cap_limit.min(self.cap);
        loop {
            let v = self.z(self.n, t);
            if v.is_finite() {
                return Some((t, v));
            }
            if t == 0 {
                return None;
            }
            t -= 1;
        }
    }

    /// Backtrack the chosen item index per class for the solution that
    /// fills exactly `tau` (must be finite). Returns item indices aligned
    /// with `classes.classes`.
    pub fn backtrack(&self, classes: &Classes, mut tau: usize) -> Result<Vec<usize>> {
        if !self.z(self.n, tau).is_finite() {
            return Err(FedError::Infeasible(format!("Z_n({tau}) = ∞")));
        }
        let mut chosen = vec![0usize; self.n];
        for r in (1..=self.n).rev() {
            let j = self.chosen(r, tau);
            if j == NO_ITEM {
                return Err(FedError::Infeasible(format!(
                    "no item recorded at class {r}, τ={tau}"
                )));
            }
            let item = classes.classes[r - 1][j as usize];
            chosen[r - 1] = j as usize;
            tau -= item.weight;
        }
        debug_assert_eq!(tau, 0, "backtrack must consume the full capacity");
        Ok(chosen)
    }
}

/// Compute the DP matrices for `classes` over capacity `cap`
/// (lines 1–19 of Algorithm 1, generalized to a row-0 base case).
///
/// `O(cap · Σ|N_i|)` time, `O(cap · n)` space.
///
/// Loop order (§Perf, EXPERIMENTS.md): τ-outer / item-inner on flat
/// row-major storage. Each cell `(r, τ)` is written exactly once (the
/// paper's item-outer order re-writes cells per improving item, tripling
/// memory traffic), the item scan reads `prev[τ-w]` as a contiguous
/// backward slice for the dense weight classes the scheduling reduction
/// produces, and the min-tracking stays in registers.
pub fn dp(classes: &Classes, cap: usize) -> DpMatrices {
    let n = classes.classes.len();
    let width = cap + 1;
    let mut k = vec![f64::INFINITY; (n + 1) * width];
    let mut item = vec![NO_ITEM; (n + 1) * width];
    k[0] = 0.0; // Z_0(0) = 0
    fill_rows(&mut k, &mut item, classes, cap, 0);
    DpMatrices { n, cap, k, item }
}

/// Fill DP rows `from_class+1..=n` (row `r+1` is derived from class `r`),
/// assuming rows `0..=from_class` already hold valid `Z` values. Shared by
/// the cold [`dp`] (`from_class = 0`) and the warm-start
/// [`DpMatrices::resume`].
fn fill_rows(
    k: &mut [f64],
    item: &mut [u32],
    classes: &Classes,
    cap: usize,
    from_class: usize,
) {
    let width = cap + 1;
    for (r, class) in classes.classes.iter().enumerate().skip(from_class) {
        let (prev_rows, cur_rows) = k.split_at_mut((r + 1) * width);
        let prev = &prev_rows[r * width..(r + 1) * width];
        let cur = &mut cur_rows[..width];
        let cur_items = &mut item[(r + 1) * width..(r + 2) * width];
        for t in 0..=cap {
            let mut best = f64::INFINITY;
            let mut best_j = NO_ITEM;
            for (ji, it) in class.iter().enumerate() {
                if it.weight <= t {
                    let cand = prev[t - it.weight] + it.cost;
                    if cand < best {
                        best = cand;
                        best_j = ji as u32;
                    }
                }
            }
            cur[t] = best;
            cur_items[t] = best_j;
        }
    }
}

impl DpMatrices {
    /// Warm-start: recompute only the rows invalidated by a change to
    /// classes `first_changed..` (rows `0..=first_changed` depend solely on
    /// classes `0..first_changed` and stay valid). `classes` must have the
    /// same class count and the same capacity as the original computation;
    /// the result is bit-for-bit identical to a cold [`dp`] on `classes`
    /// because the per-row arithmetic is the same code in the same order.
    pub fn resume(&mut self, classes: &Classes, first_changed: usize) {
        debug_assert_eq!(classes.classes.len(), self.n);
        fill_rows(&mut self.k, &mut self.item, classes, self.cap, first_changed);
    }
}

/// Incremental (MC)²MKP solver for the coordinator's round loop: when only
/// a *suffix* of the fleet's cost tables changed between rounds (battery
/// drain or drift touching the later devices, earlier devices stable), the
/// DP rows covering the unchanged prefix are reused instead of recomputed
/// — Algorithm 1's `O(T² n)` drops to `O(T² · changed)`.
///
/// Results are **bit-for-bit identical** to [`solve`]: the warm path runs
/// the exact same row-filling code on the exact same inputs, merely
/// skipping rows whose inputs are unchanged.
// `Clone` lets the pipelined coordinator speculate round r+1's DP solve
// on a private copy of the cache while round r trains, adopting the copy
// only when the speculation validates.
#[derive(Clone, Default)]
pub struct WarmMc2mkp {
    cache: Option<WarmState>,
}

#[derive(Clone)]
struct WarmState {
    classes: Classes,
    matrices: DpMatrices,
}

/// What the warm solver did for one solve (observability for the
/// coordinator's metrics).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WarmInfo {
    /// DP rows reused from the previous round (0 on a cold solve).
    pub reused_rows: usize,
    /// Total DP rows for this instance (`n`).
    pub total_rows: usize,
}

impl WarmMc2mkp {
    /// Empty cache: the first solve is always cold.
    pub fn new() -> Self {
        Self::default()
    }

    /// Drop the cache (e.g. when the fleet line-up changes).
    pub fn invalidate(&mut self) {
        self.cache = None;
    }

    /// Solve optimally, reusing cached DP rows where the transformed item
    /// classes match the previous solve's prefix.
    pub fn solve(&mut self, inst: &Instance) -> Result<(Schedule, WarmInfo)> {
        inst.validate()?;
        let tr = limits::remove_lower_limits(inst);
        let t_prime = tr.instance.tasks;
        let classes = classes_from_instance(&tr.instance);
        let n = classes.classes.len();

        // The fresh `classes` moves into the cache either way — no per-round
        // copy, which matters on the steady-state rounds where `resume`
        // does zero row work.
        let reused = match self.cache.as_mut() {
            Some(state)
                if state.matrices.cap == t_prime
                    && state.classes.classes.len() == n =>
            {
                // Longest unchanged class prefix = number of reusable rows.
                let prefix = state
                    .classes
                    .classes
                    .iter()
                    .zip(&classes.classes)
                    .take_while(|(a, b)| a == b)
                    .count();
                state.matrices.resume(&classes, prefix);
                state.classes = classes;
                prefix
            }
            _ => {
                self.cache = Some(WarmState {
                    matrices: dp(&classes, t_prime),
                    classes,
                });
                0
            }
        };

        let state = self.cache.as_ref().unwrap();
        let schedule =
            extract_schedule(&state.matrices, &state.classes, &tr, t_prime)?;
        Ok((schedule, WarmInfo { reused_rows: reused, total_rows: n }))
    }
}

/// Shared solve tail: select the maximal packing, require a full packing
/// (valid scheduling instances always admit one, §4.1.1), backtrack, and
/// map back through the lower-limit transformation. Used by both the cold
/// [`solve`] and [`WarmMc2mkp`], so the two paths cannot drift apart.
fn extract_schedule(
    m: &DpMatrices,
    classes: &Classes,
    tr: &limits::Transformed,
    t_prime: usize,
) -> Result<Schedule> {
    let (t_star, _) = m
        .best_capacity(t_prime)
        .ok_or_else(|| FedError::Infeasible("no feasible packing".into()))?;
    if t_star != t_prime {
        return Err(FedError::Infeasible(format!(
            "maximal packing {t_star} < T' = {t_prime} on a valid instance"
        )));
    }
    let chosen = m.backtrack(classes, t_star)?;
    let x: Vec<usize> = chosen
        .iter()
        .enumerate()
        .map(|(i, &ji)| classes.classes[i][ji].weight)
        .collect();
    Ok(tr.restore(&Schedule::new(x)))
}

/// One device class's aggregate-load table: the cheapest way to split `y`
/// tasks among the class's `m` interchangeable members, for every
/// `y ∈ [0, min(m·U, T)]` — computed by an inner DP over members, with
/// per-member choices recorded for on-demand backtracking.
struct ClassAggregate {
    /// Members `m`.
    m: usize,
    /// Aggregate domain width: `min(m·u, T) + 1`.
    width: usize,
    /// Final DP row `F_m(y)` (intermediate cost rows are rolled — only
    /// two are ever live during [`ClassAggregate::build`]).
    last: Vec<f64>,
    /// Chosen per-member load at each `(d, y)` cell — the only full
    /// `(m+1) × Y` table kept, and it is `u32` (the backtrack needs it;
    /// without it [`ClassAggregate::split`] would re-run the DP).
    choice: Vec<u32>,
}

impl ClassAggregate {
    /// Inner bounded-multiplicity DP: `O(m · Y · u)` time for aggregate
    /// domain `Y` — the same arithmetic the flat DP spends on this class's
    /// `m` rows, but kept local to the class (and clamped to `Y <= T`).
    fn build<V: CostView + ?Sized>(view: &V, c: usize, cap_total: usize) -> Self {
        let u = view.cap(c);
        let m = view.count(c);
        let width = m.saturating_mul(u).min(cap_total) + 1;
        // One lazy evaluation per needed point — the inner loops below
        // would otherwise re-query the view `m·Y` times per point.
        let point_cost: Vec<f64> = (0..=u).map(|j| view.eval(c, j)).collect();
        let mut choice = vec![0u32; (m + 1) * width];
        let mut prev = vec![f64::INFINITY; width];
        let mut cur = vec![f64::INFINITY; width];
        prev[0] = 0.0;
        for d in 1..=m {
            cur.fill(f64::INFINITY);
            let cur_choice = &mut choice[d * width..(d + 1) * width];
            let y_hi = (d.saturating_mul(u)).min(width - 1);
            for (y, cell) in cur.iter_mut().enumerate().take(y_hi + 1) {
                let mut best = f64::INFINITY;
                let mut best_j = 0u32;
                for j in 0..=u.min(y) {
                    let base = prev[y - j];
                    if !base.is_finite() {
                        continue;
                    }
                    let cand = base + point_cost[j];
                    if cand < best {
                        best = cand;
                        best_j = j as u32;
                    }
                }
                *cell = best;
                cur_choice[y] = best_j;
            }
            std::mem::swap(&mut prev, &mut cur);
        }
        Self { m, width, last: prev, choice }
    }

    /// The outer knapsack's multiple-choice items for this class:
    /// aggregate load `y` at cost `F_m(y)`.
    fn items(&self) -> Vec<Item> {
        (0..self.width)
            .filter(|&y| self.last[y].is_finite())
            .map(|y| Item { weight: y, cost: self.last[y] })
            .collect()
    }

    /// Split an aggregate load back into per-member loads (member order).
    fn split(&self, mut y: usize) -> Vec<(usize, usize)> {
        let mut loads = Vec::with_capacity(self.m);
        for d in (1..=self.m).rev() {
            let j = self.choice[d * self.width + y] as usize;
            loads.push((j, 1));
            y -= j;
        }
        debug_assert_eq!(y, 0, "inner backtrack must consume the aggregate");
        loads
    }
}

/// Class-aware (MC)²MKP over a lazy [`CostView`]: the outer DP runs over
/// `k` **classes with bounded multiplicities** instead of `n` devices —
/// each class contributes aggregate items `(y, F_m(y))` produced by an
/// inner per-class DP. Arbitrary cost functions admit no shortcut inside a
/// class (any member may take any load), so total arithmetic matches the
/// flat `O(T² n)` bound. What shrinks is the **f64 cost state**: the
/// inner DP rolls two rows and the outer keeps `k + 1` rows, i.e.
/// `O((k + max_c m_c)·T)` floats versus the flat DP's `O(n·T)`. The
/// per-member backtracking (`choice`) tables remain `O(Σ_c m_c·Y_c)`
/// (≤ `O(n·T)`) — but as 4-byte `u32`s, about a third of the flat DP's
/// combined 12-byte/cell footprint. With `m = 1` everywhere this
/// degenerates to exactly the flat DP.
pub fn solve_view<V: CostView + ?Sized>(
    view: &V,
) -> Result<Vec<Vec<(usize, usize)>>> {
    let t = view.tasks();
    let k = view.n_classes();
    let aggregates: Vec<ClassAggregate> =
        (0..k).map(|c| ClassAggregate::build(view, c, t)).collect();
    let classes = Classes {
        classes: aggregates.iter().map(|a| a.items()).collect(),
    };
    let m = dp(&classes, t);
    let (t_star, _) = m
        .best_capacity(t)
        .ok_or_else(|| FedError::Infeasible("no feasible packing".into()))?;
    if t_star != t {
        return Err(FedError::Infeasible(format!(
            "maximal packing {t_star} < T' = {t} on a valid instance"
        )));
    }
    let chosen = m.backtrack(&classes, t_star)?;
    Ok(chosen
        .iter()
        .enumerate()
        .map(|(c, &ji)| aggregates[c].split(classes.classes[c][ji].weight))
        .collect())
}

/// Solve a class-deduplicated fleet optimally (paper Theorem 1 — works
/// for arbitrary cost functions).
pub fn solve_fleet(fleet: &FleetInstance) -> Result<Assignment> {
    fleet.validate()?;
    let view = LowerFree::of(fleet);
    let groups = solve_view(&view)?;
    Ok(Assignment::from_groups(view.restore(groups)))
}

/// Solution of the knapsack problem itself.
#[derive(Clone, Debug)]
pub struct KnapsackSolution {
    /// Total cost of chosen items.
    pub cost: f64,
    /// Capacity actually used (`T*`).
    pub used_capacity: usize,
    /// Chosen item index per class.
    pub chosen: Vec<usize>,
}

/// Solve (MC)²MKP directly on item classes (Algorithm 1 end-to-end).
pub fn solve_classes(classes: &Classes, cap: usize) -> Result<KnapsackSolution> {
    let m = dp(classes, cap);
    let (t_star, cost) = m
        .best_capacity(cap)
        .ok_or_else(|| FedError::Infeasible("no feasible packing".into()))?;
    let chosen = m.backtrack(classes, t_star)?;
    Ok(KnapsackSolution { cost, used_capacity: t_star, chosen })
}

/// Build the knapsack classes for a (lower-limit-free) scheduling instance:
/// `N_i = {0, 1, ..., min(U_i, T)}`, `w_ij = j`, `c_ij = C_i(j)`
/// (paper §4.1.1).
pub fn classes_from_instance(inst: &Instance) -> Classes {
    debug_assert!(inst.lower.iter().all(|&l| l == 0));
    let classes = (0..inst.n())
        .map(|i| {
            (0..=inst.cap(i))
                .map(|j| Item { weight: j, cost: inst.costs[i].eval(j) })
                .collect()
        })
        .collect();
    Classes { classes }
}

/// Solve the Minimal Cost FL Schedule problem optimally via (MC)²MKP
/// (paper Theorem 1). Works for **arbitrary** cost functions.
///
/// Worst-case `O(T² n)` time, `O(T n)` space.
pub fn solve(inst: &Instance) -> Result<Schedule> {
    inst.validate()?;
    let tr = limits::remove_lower_limits(inst);
    let ti = &tr.instance;
    // Specialized DP: weights of class i are exactly 0..=cap(i), so the
    // chosen item index *is* the assignment — no Item materialization in
    // the backtrack.
    let classes = classes_from_instance(ti);
    let m = dp(&classes, ti.tasks);
    extract_schedule(&m, &classes, &tr, ti.tasks)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::validate;

    #[test]
    fn paper_fig1() {
        let inst = Instance::paper_example(5);
        let s = solve(&inst).unwrap();
        assert_eq!(s.assignments(), &[2, 3, 0]);
        assert!((validate::checked_cost(&inst, &s).unwrap() - 7.5).abs() < 1e-12);
    }

    #[test]
    fn paper_fig2() {
        let inst = Instance::paper_example(8);
        let s = solve(&inst).unwrap();
        assert_eq!(s.assignments(), &[1, 2, 5]);
        assert!((validate::checked_cost(&inst, &s).unwrap() - 11.5).abs() < 1e-12);
    }

    #[test]
    fn fig2_shows_greedy_nonoptimality() {
        // The T=8 optimum {1,2,5} does NOT contain the T=5 optimum {2,3,0}:
        // the paper's insight that incremental greedy fails in general.
        let s5 = solve(&Instance::paper_example(5)).unwrap();
        let s8 = solve(&Instance::paper_example(8)).unwrap();
        assert!(s8.get(0) < s5.get(0));
    }

    #[test]
    fn knapsack_prefers_maximal_packing_over_cheap_partial() {
        // One class: items weight 0 (cost 0) or weight 3 (cost 10).
        // Capacity 4: maximal packing uses weight 3 despite cost.
        let classes = Classes {
            classes: vec![vec![
                Item { weight: 0, cost: 0.0 },
                Item { weight: 3, cost: 10.0 },
            ]],
        };
        let sol = solve_classes(&classes, 4).unwrap();
        assert_eq!(sol.used_capacity, 3);
        assert_eq!(sol.cost, 10.0);
    }

    #[test]
    fn knapsack_min_cost_among_maximal() {
        // Two classes; several ways to reach capacity 4; must pick cheapest.
        let classes = Classes {
            classes: vec![
                vec![Item { weight: 1, cost: 1.0 }, Item { weight: 3, cost: 9.0 }],
                vec![Item { weight: 1, cost: 4.0 }, Item { weight: 3, cost: 5.0 }],
            ],
        };
        // combos: (1,1)→w2 c5; (1,3)→w4 c6; (3,1)→w4 c13; (3,3)→w6 >cap
        let sol = solve_classes(&classes, 4).unwrap();
        assert_eq!(sol.used_capacity, 4);
        assert!((sol.cost - 6.0).abs() < 1e-12);
        assert_eq!(sol.chosen, vec![0, 1]);
    }

    #[test]
    fn infeasible_when_min_weights_exceed_cap() {
        let classes = Classes {
            classes: vec![vec![Item { weight: 5, cost: 1.0 }]],
        };
        assert!(solve_classes(&classes, 4).is_err());
    }

    #[test]
    fn single_resource_takes_all() {
        let inst = Instance::new(
            7,
            vec![0],
            vec![10],
            vec![crate::sched::costs::CostFn::Affine { fixed: 1.0, per_task: 2.0 }],
        )
        .unwrap();
        let s = solve(&inst).unwrap();
        assert_eq!(s.assignments(), &[7]);
    }

    #[test]
    fn respects_tight_limits() {
        use crate::sched::costs::CostFn;
        // Two resources, both forced to exactly half.
        let inst = Instance::new(
            10,
            vec![5, 5],
            vec![5, 5],
            vec![
                CostFn::Affine { fixed: 0.0, per_task: 1.0 },
                CostFn::Affine { fixed: 0.0, per_task: 100.0 },
            ],
        )
        .unwrap();
        let s = solve(&inst).unwrap();
        assert_eq!(s.assignments(), &[5, 5]);
    }

    #[test]
    fn zero_weight_items_allowed() {
        // All resources may take zero; T=0 edge.
        use crate::sched::costs::CostFn;
        let inst = Instance::new(
            0,
            vec![0, 0],
            vec![3, 3],
            vec![
                CostFn::Affine { fixed: 0.0, per_task: 1.0 },
                CostFn::Affine { fixed: 0.0, per_task: 1.0 },
            ],
        )
        .unwrap();
        let s = solve(&inst).unwrap();
        assert_eq!(s.assignments(), &[0, 0]);
    }

    #[test]
    fn fleet_class_dp_matches_flat_dp() {
        use crate::sched::costs::CostFn;
        use crate::sched::fleet::FleetInstance;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xD0D0);
        for _case in 0..20 {
            // t <= 10 keeps the worst-case ΣU (both classes at minimum
            // caps) feasible.
            let t = 5 + rng.index(6);
            // Arbitrary (non-monotone) tabulated costs, duplicated.
            let table = |rng: &mut Rng| {
                let mut values = vec![0.0];
                let mut acc = 0.0;
                for _ in 1..=t {
                    acc += rng.range_f64(0.1, 2.0);
                    values.push(acc + rng.range_f64(-0.4, 0.4));
                }
                CostFn::Tabulated { first: 0, values }
            };
            let fleet = FleetInstance::builder()
                .tasks(t)
                .device_class(table(&mut rng), 1, 2 + rng.index(t), 3)
                .device_class(table(&mut rng), 0, 2 + rng.index(t), 2)
                .build()
                .unwrap();
            let asg = solve_fleet(&fleet).unwrap();
            asg.check(&fleet).unwrap();
            let flat = fleet.to_flat();
            let c_flat =
                validate::checked_cost(&flat, &solve(&flat).unwrap()).unwrap();
            let c_fleet = asg.total_cost(&fleet);
            assert!(
                (c_fleet - c_flat).abs() < 1e-9,
                "class DP {c_fleet} != flat DP {c_flat} on {flat:?}"
            );
        }
    }

    #[test]
    fn warm_first_solve_is_cold_and_matches() {
        let inst = Instance::paper_example(5);
        let mut warm = WarmMc2mkp::new();
        let (s, info) = warm.solve(&inst).unwrap();
        assert_eq!(s, solve(&inst).unwrap());
        assert_eq!(info.reused_rows, 0);
        assert_eq!(info.total_rows, 3);
    }

    #[test]
    fn warm_resolve_with_unchanged_costs_reuses_all_rows() {
        let inst = Instance::paper_example(5);
        let mut warm = WarmMc2mkp::new();
        warm.solve(&inst).unwrap();
        let (s, info) = warm.solve(&inst).unwrap();
        assert_eq!(s, solve(&inst).unwrap());
        assert_eq!(info.reused_rows, 3);
    }

    #[test]
    fn warm_suffix_change_reuses_prefix_and_matches_cold_exactly() {
        use crate::sched::costs::CostFn;
        let base = Instance::paper_example(5);
        let mut warm = WarmMc2mkp::new();
        warm.solve(&base).unwrap();

        // Change only the LAST resource's cost table (a drifted device).
        let mut drifted = base.clone();
        drifted.costs[2] =
            CostFn::Scaled { weight: 1.5, inner: Box::new(base.costs[2].clone()) };
        let (s, info) = warm.solve(&drifted).unwrap();
        assert_eq!(info.reused_rows, 2, "prefix rows for resources 0,1");
        let cold = solve(&drifted).unwrap();
        assert_eq!(s, cold, "warm and cold schedules must be identical");
        // And the costs are bit-for-bit equal, not merely within tolerance.
        assert_eq!(
            validate::checked_cost(&drifted, &s).unwrap(),
            validate::checked_cost(&drifted, &cold).unwrap()
        );
    }

    #[test]
    fn warm_cache_invalidated_by_shape_change() {
        let mut warm = WarmMc2mkp::new();
        warm.solve(&Instance::paper_example(5)).unwrap();
        // Different T → different capacity → cold solve.
        let (s8, info) = warm.solve(&Instance::paper_example(8)).unwrap();
        assert_eq!(info.reused_rows, 0);
        assert_eq!(s8.assignments(), &[1, 2, 5]);
        warm.invalidate();
        let (_, info2) = warm.solve(&Instance::paper_example(8)).unwrap();
        assert_eq!(info2.reused_rows, 0);
    }

    #[test]
    fn dp_z_values_match_manual() {
        // Classes {w0 c0, w1 c2} and {w0 c0, w1 c3}:
        let classes = Classes {
            classes: vec![
                vec![Item { weight: 0, cost: 0.0 }, Item { weight: 1, cost: 2.0 }],
                vec![Item { weight: 0, cost: 0.0 }, Item { weight: 1, cost: 3.0 }],
            ],
        };
        let m = dp(&classes, 2);
        assert_eq!(m.z(0, 0), 0.0);
        assert!(m.z(0, 1).is_infinite());
        assert_eq!(m.z(1, 0), 0.0);
        assert_eq!(m.z(1, 1), 2.0);
        assert_eq!(m.z(2, 0), 0.0);
        assert_eq!(m.z(2, 1), 2.0); // cheaper: take class-1's w1
        assert_eq!(m.z(2, 2), 5.0);
    }
}
