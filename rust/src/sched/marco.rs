//! Algorithm 3 — **MarCo**: optimal scheduling under *constant* marginal
//! costs (paper §5.4).
//!
//! With constant marginals, whole blocks of tasks can be assigned at once:
//! sort resources by their (single) marginal cost `M_i(1)` and fill each to
//! its upper limit (or to the remaining workload) in order (Lemma 5,
//! Theorem 3).
//!
//! Complexity: `Θ(n log n)` (the sort dominates), `O(n)` space.

use crate::error::Result;
use crate::sched::fleet::{Assignment, CostView, FleetInstance, LowerFree};
use crate::sched::instance::{Instance, Schedule};
use crate::sched::limits;

/// Run MarCo. Optimal when all resources have constant marginal costs;
/// feasible (but possibly suboptimal) otherwise.
pub fn solve(inst: &Instance) -> Result<Schedule> {
    inst.validate()?;
    let tr = limits::remove_lower_limits(inst);
    let ti = &tr.instance;
    let n = ti.n();
    let mut x = vec![0usize; n];

    // Sorted list of (marginal cost, resource); deterministic tie-break.
    let mut order: Vec<(f64, usize)> = (0..n)
        .filter(|&i| ti.cap(i) > 0)
        .map(|i| (ti.costs[i].marginal(1, 0), i))
        .collect();
    order.sort_by(|a, b| a.partial_cmp(b).unwrap());

    let mut remaining = ti.tasks;
    for (_m, i) in order {
        if remaining == 0 {
            break;
        }
        // Assign the most tasks possible (line 7 of Algorithm 3).
        let take = ti.cap(i).min(remaining);
        x[i] = take;
        remaining -= take;
    }
    debug_assert_eq!(remaining, 0, "valid instance must absorb all tasks");

    Ok(tr.restore(&Schedule::new(x)))
}

/// Class-aware MarCo over a lazy [`CostView`]: with constant marginals a
/// whole class absorbs `m · U` tasks at once, so the sort is over `k`
/// classes — `Θ(k log k)` versus `Θ(n log n)` flat (Lemma 5 / Theorem 3
/// are indifferent to which same-cost device takes the block).
///
/// Returns per-class `(load, n_devices)` runs in the view's domain.
pub fn solve_view<V: CostView + ?Sized>(view: &V) -> Vec<Vec<(usize, usize)>> {
    let k = view.n_classes();
    let mut order: Vec<(f64, usize)> = (0..k)
        .filter(|&c| view.cap(c) > 0)
        .map(|c| (view.eval(c, 1) - view.eval(c, 0), c))
        .collect();
    order.sort_by(|a, b| a.partial_cmp(b).unwrap());

    let mut groups: Vec<Vec<(usize, usize)>> =
        (0..k).map(|c| vec![(0, view.count(c))]).collect();
    let mut remaining = view.tasks();
    for (_m, c) in order {
        if remaining == 0 {
            break;
        }
        let u = view.cap(c);
        let m = view.count(c);
        // Fill whole members first, then one partial member.
        let full = (remaining / u).min(m);
        let part = if full < m { (remaining - full * u).min(u) } else { 0 };
        remaining -= full * u + part;
        let idle = m - full - usize::from(part > 0);
        groups[c] = vec![(u, full), (part, usize::from(part > 0)), (0, idle)];
    }
    groups
}

/// Run MarCo on a class-deduplicated fleet (same optimality contract as
/// [`solve`]).
pub fn solve_fleet(fleet: &FleetInstance) -> Result<Assignment> {
    fleet.validate()?;
    let view = LowerFree::of(fleet);
    let groups = solve_view(&view);
    Ok(Assignment::from_groups(view.restore(groups)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::costs::CostFn;
    use crate::sched::{marin, mc2mkp, validate};
    use crate::util::rng::Rng;

    fn affine(fixed: f64, per_task: f64) -> CostFn {
        CostFn::Affine { fixed, per_task }
    }

    #[test]
    fn fills_cheapest_first() {
        let inst = Instance::new(
            10,
            vec![0, 0, 0],
            vec![4, 4, 4],
            vec![affine(0.0, 3.0), affine(0.0, 1.0), affine(0.0, 2.0)],
        )
        .unwrap();
        let s = solve(&inst).unwrap();
        assert_eq!(s.assignments(), &[2, 4, 4]);
        validate::check(&inst, &s).unwrap();
    }

    #[test]
    fn partial_last_resource() {
        let inst = Instance::new(
            5,
            vec![0, 0],
            vec![4, 4],
            vec![affine(0.0, 1.0), affine(0.0, 2.0)],
        )
        .unwrap();
        let s = solve(&inst).unwrap();
        assert_eq!(s.assignments(), &[4, 1]);
    }

    #[test]
    fn fleet_block_fill_matches_flat() {
        use crate::sched::fleet::FleetInstance;
        // Cheap class absorbs whole blocks; partial member on the seam.
        let fleet = FleetInstance::builder()
            .tasks(11)
            .device_class(affine(0.0, 1.0), 0, 4, 2)
            .device_class(affine(0.0, 3.0), 0, 4, 2)
            .build()
            .unwrap();
        let asg = solve_fleet(&fleet).unwrap();
        asg.check(&fleet).unwrap();
        // 8 on the cheap class, 3 on one expensive member.
        assert_eq!(asg.groups()[0], vec![(4, 2)]);
        assert_eq!(asg.groups()[1], vec![(3, 1), (0, 1)]);
        let flat = fleet.to_flat();
        let c_flat =
            validate::checked_cost(&flat, &solve(&flat).unwrap()).unwrap();
        assert!((asg.total_cost(&fleet) - c_flat).abs() < 1e-9);
    }

    #[test]
    fn matches_marin_and_dp_on_constant_instances() {
        let mut rng = Rng::new(0xC0C0);
        for _case in 0..50 {
            let n = 2 + rng.index(4);
            let t = 10 + rng.index(50);
            let mut lower = Vec::new();
            let mut upper = Vec::new();
            let mut costs = Vec::new();
            for _ in 0..n {
                lower.push(rng.index(3));
                upper.push(3 + rng.index(t));
                costs.push(affine(rng.range_f64(0.0, 1.0), rng.range_f64(0.1, 5.0)));
            }
            let sum_l: usize = lower.iter().sum();
            let sum_u: usize = upper.iter().map(|&u| u.min(t)).sum();
            if sum_l > t || sum_u < t {
                continue;
            }
            let inst = Instance::new(t, lower, upper, costs).unwrap();
            let a = validate::checked_cost(&inst, &solve(&inst).unwrap()).unwrap();
            let b = validate::checked_cost(&inst, &marin::solve(&inst).unwrap()).unwrap();
            let c = validate::checked_cost(&inst, &mc2mkp::solve(&inst).unwrap()).unwrap();
            assert!((a - c).abs() < 1e-9, "MarCo {a} != DP {c}");
            assert!((b - c).abs() < 1e-9, "MarIn {b} != DP {c}");
        }
    }

    #[test]
    fn lower_limits_reserved_before_sorting() {
        // Expensive resource has a lower limit that must be honored.
        let inst = Instance::new(
            6,
            vec![0, 3],
            vec![10, 10],
            vec![affine(0.0, 1.0), affine(0.0, 50.0)],
        )
        .unwrap();
        let s = solve(&inst).unwrap();
        assert_eq!(s.assignments(), &[3, 3]);
    }
}
