//! Sharded fleet-instance construction for 10⁵–10⁶-device fleets.
//!
//! Since the fleet-scale redesign, the warm DP and the class-aware solver
//! cores made *solving* cheap (`k ≪ n`); what remains `O(n)` on the round
//! hot path is **building** the instance — hashing every device's
//! `(C, L, U)` signature into its class. This module splits that work:
//!
//! 1. **Partition** the slot range into contiguous shards
//!    ([`ShardPlan::contiguous`]);
//! 2. **Dedup per shard** ([`dedup_slots`]): each shard independently
//!    groups its devices into a shard-local class table (embarrassingly
//!    parallel — the scoped-thread driver lives in
//!    [`crate::runtime::pool`]);
//! 3. **Merge** ([`merge`]): shard class tables fuse into one global
//!    [`FleetInstance`]. Classes with equal structural signatures fuse
//!    across shards, so the merged fleet still has `k ≪ n` classes and
//!    the merge itself is `O(k · shards)` — independent of the device
//!    count.
//!
//! **Exactness contract**: the merged fleet is *bit-for-bit identical* to
//! the unsharded [`FleetInstance::from_flat`] result — same class order
//! (global first-occurrence order), same slot-sorted member lists, same
//! [`FleetInstance::digest`]. This holds because shards are contiguous
//! slot ranges processed in ascending order, shard-local class order is
//! first-occurrence order within the shard, and the merge walks shards in
//! order using the builder's own bucketing ([`class_key`]). Any solve of
//! the merged fleet therefore produces exactly the schedule the unsharded
//! path would — sharding is a pure build-time optimization, never an
//! approximation. `tests/shard_equivalence.rs` and the testkit
//! differential harness ([`crate::testkit::instances`]) fuzz this
//! contract across all registered solvers.

use std::ops::Range;
// fedlint: allow(R1) — metrics-only stopwatch for `merge_ns`; readings
// never reach any digest input (enforced by R5).
use std::time::Instant;

use crate::error::Result;
use crate::sched::costs::CostFn;
use crate::sched::fleet::{ClassTable, DeviceClass, FleetInstance};
use crate::sched::instance::Instance;

/// Contiguous slot ranges, one per shard, covering `0..n` in order.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    ranges: Vec<Range<usize>>,
}

impl ShardPlan {
    /// Split `n` slots into `shards` contiguous, near-even ranges (the
    /// first `n % shards` ranges carry one extra slot). `shards = 0` is
    /// treated as 1; shard counts above `n` produce trailing empty
    /// shards — degenerate but legal, the merge treats them as no-ops.
    pub fn contiguous(n: usize, shards: usize) -> ShardPlan {
        let shards = shards.max(1);
        let base = n / shards;
        let extra = n % shards;
        let mut ranges = Vec::with_capacity(shards);
        let mut lo = 0usize;
        for s in 0..shards {
            let len = base + usize::from(s < extra);
            ranges.push(lo..lo + len);
            lo += len;
        }
        ShardPlan { ranges }
    }

    /// The shard ranges, ascending and contiguous.
    pub fn ranges(&self) -> &[Range<usize>] {
        &self.ranges
    }

    /// Number of shards.
    pub fn len(&self) -> usize {
        self.ranges.len()
    }

    /// True when the plan holds no shards (never produced by
    /// [`ShardPlan::contiguous`]).
    pub fn is_empty(&self) -> bool {
        self.ranges.is_empty()
    }
}

/// A shard-local class table: classes in first-member order, member lists
/// carrying **global** slot indices (ascending within each class).
#[derive(Clone, Debug, Default)]
pub struct ShardClasses {
    classes: Vec<DeviceClass>,
}

impl ShardClasses {
    /// The shard's classes.
    pub fn classes(&self) -> &[DeviceClass] {
        &self.classes
    }

    /// Number of shard-local classes.
    pub fn n_classes(&self) -> usize {
        self.classes.len()
    }
}

/// Class-deduplicate one contiguous slot range of a device sequence
/// (`O(len)` expected via structural hashing — the per-shard work the
/// parallel driver fans out).
pub fn dedup_slots(
    costs: &[CostFn],
    lower: &[usize],
    upper: &[usize],
    range: Range<usize>,
) -> ShardClasses {
    let mut table = ClassTable::default();
    for slot in range {
        let ci = table.class_index(&costs[slot], lower[slot], upper[slot]);
        table.classes[ci].members.push(slot);
    }
    ShardClasses { classes: table.classes }
}

/// Fuse shard class tables into one global [`FleetInstance`].
///
/// The tables must come from a [`ShardPlan`]'s ranges **in plan order**
/// (ascending, contiguous). Classes with equal signatures fuse across
/// shards by concatenating member lists — which stays slot-sorted because
/// shards are ascending ranges. The result is bit-for-bit identical to
/// building the same device sequence through [`FleetInstance::from_flat`]
/// (see the module docs for why the class order matches).
pub fn merge(tasks: usize, shards: Vec<ShardClasses>) -> Result<FleetInstance> {
    // Pre-size to the largest shard table: the global k is usually close.
    let cap = shards.iter().map(|s| s.classes.len()).max().unwrap_or(0);
    let mut table = ClassTable::with_capacity(cap);
    for shard in shards {
        for class in shard.classes {
            let ci = table.class_index(&class.cost, class.lower, class.upper);
            table.classes[ci].members.extend(class.members);
        }
    }
    FleetInstance::from_classes(tasks, table.classes)
}

/// Observability of one sharded build (what the coordinator meters).
/// Deliberately `Copy`-small: per-worker span capture for the tracing
/// layer lives in the out-param of
/// [`crate::runtime::pool::build_fleet_sharded_traced`], not here.
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardStats {
    /// Shards the plan produced (== the configured count).
    pub shards: usize,
    /// Wall-clock nanoseconds spent in the cross-shard merge. Pure
    /// timing — it is metered (`shard_merge_ns`) but never enters any
    /// journal or campaign digest.
    pub merge_ns: u64,
}

/// Merge shard tables and time the merge — shared tail of the
/// single-threaded and parallel build drivers.
pub fn merge_with_stats(
    tasks: usize,
    tables: Vec<ShardClasses>,
    n_shards: usize,
) -> Result<(FleetInstance, ShardStats)> {
    // fedlint: allow(R1) — metrics-only timing of the merge.
    let t0 = Instant::now();
    let fleet = merge(tasks, tables)?;
    let merge_ns = t0.elapsed().as_nanos().min(u64::MAX as u128) as u64;
    Ok((fleet, ShardStats { shards: n_shards, merge_ns }))
}

/// Single-threaded sharded build of a flat instance: partition, per-shard
/// dedup, merge. Functionally (and bit-for-bit) equivalent to
/// [`FleetInstance::from_flat`]; the concurrent driver is
/// [`crate::runtime::pool::build_fleet_sharded`].
pub fn build_sharded(
    inst: &Instance,
    shards: usize,
) -> Result<(FleetInstance, ShardStats)> {
    inst.validate()?;
    let plan = ShardPlan::contiguous(inst.n(), shards);
    let tables: Vec<ShardClasses> = plan
        .ranges()
        .iter()
        .cloned()
        .map(|r| dedup_slots(&inst.costs, &inst.lower, &inst.upper, r))
        .collect();
    merge_with_stats(inst.tasks, tables, plan.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn affine(per_task: f64) -> CostFn {
        CostFn::Affine { fixed: 0.0, per_task }
    }

    /// A flat instance whose device classes interleave across any
    /// contiguous partition: slots alternate between three signatures.
    fn interleaved(n: usize, t: usize) -> Instance {
        let costs: Vec<CostFn> =
            (0..n).map(|i| affine(1.0 + (i % 3) as f64)).collect();
        let lower = vec![0; n];
        let upper = vec![t; n];
        Instance::new(t, lower, upper, costs).unwrap()
    }

    fn assert_identical(a: &FleetInstance, b: &FleetInstance) {
        assert_eq!(a.digest(), b.digest(), "digest mismatch");
        assert_eq!(a.n_classes(), b.n_classes());
        assert_eq!(a.n_devices(), b.n_devices());
        for (ca, cb) in a.classes().iter().zip(b.classes()) {
            assert_eq!(ca.cost, cb.cost);
            assert_eq!(ca.lower, cb.lower);
            assert_eq!(ca.upper, cb.upper);
            assert_eq!(ca.members, cb.members);
        }
        for s in 0..a.n_devices() {
            assert_eq!(a.class_of(s), b.class_of(s));
        }
    }

    #[test]
    fn contiguous_plan_covers_all_slots_in_order() {
        for (n, s) in [(10, 3), (12, 4), (5, 5), (3, 7), (0, 2), (1, 1)] {
            let plan = ShardPlan::contiguous(n, s);
            assert_eq!(plan.len(), s.max(1));
            let mut next = 0usize;
            for r in plan.ranges() {
                assert_eq!(r.start, next, "ranges must be contiguous");
                assert!(r.end >= r.start);
                next = r.end;
            }
            assert_eq!(next, n, "ranges must cover 0..n");
        }
    }

    #[test]
    fn zero_shards_degrades_to_one() {
        let plan = ShardPlan::contiguous(4, 0);
        assert_eq!(plan.len(), 1);
        assert_eq!(plan.ranges()[0], 0..4);
    }

    #[test]
    fn sharded_build_is_bit_identical_to_from_flat() {
        let inst = interleaved(17, 20);
        let flat = FleetInstance::from_flat(&inst).unwrap();
        assert_eq!(flat.n_classes(), 3);
        for shards in [1usize, 2, 3, 5, 7, 17, 23] {
            let (built, stats) = build_sharded(&inst, shards).unwrap();
            assert_eq!(stats.shards, shards);
            assert_identical(&flat, &built);
        }
    }

    #[test]
    fn empty_shards_are_no_ops() {
        // More shards than devices: trailing shards are empty ranges.
        let inst = interleaved(4, 6);
        let (built, stats) = build_sharded(&inst, 9).unwrap();
        assert_eq!(stats.shards, 9);
        assert_identical(&FleetInstance::from_flat(&inst).unwrap(), &built);
    }

    #[test]
    fn single_class_fleet_fuses_across_all_shards() {
        let n = 12;
        let inst = Instance::new(
            8,
            vec![0; n],
            vec![8; n],
            vec![affine(2.0); n],
        )
        .unwrap();
        let (built, _) = build_sharded(&inst, 5).unwrap();
        assert_eq!(built.n_classes(), 1);
        assert_eq!(
            built.classes()[0].members,
            (0..n).collect::<Vec<usize>>()
        );
        assert_identical(&FleetInstance::from_flat(&inst).unwrap(), &built);
    }

    #[test]
    fn all_unique_fleet_keeps_every_class() {
        let n = 9;
        let costs: Vec<CostFn> = (0..n).map(|i| affine(1.0 + i as f64)).collect();
        let inst = Instance::new(6, vec![0; n], vec![6; n], costs).unwrap();
        let (built, _) = build_sharded(&inst, 4).unwrap();
        assert_eq!(built.n_classes(), n);
        assert_identical(&FleetInstance::from_flat(&inst).unwrap(), &built);
    }

    #[test]
    fn merge_rejects_overlapping_member_lists() {
        // Two hand-built shard tables claiming the same slot.
        let mk = |slots: Vec<usize>| ShardClasses {
            classes: vec![DeviceClass {
                cost: affine(1.0),
                lower: 0,
                upper: 4,
                members: slots,
            }],
        };
        assert!(merge(4, vec![mk(vec![0, 1]), mk(vec![1])]).is_err());
        // A gap (slot 1 never claimed) is rejected too.
        let bad = vec![ShardClasses {
            classes: vec![DeviceClass {
                cost: affine(1.0),
                lower: 0,
                upper: 4,
                members: vec![0, 2],
            }],
        }];
        assert!(merge(4, bad).is_err());
    }

    #[test]
    fn dedup_slots_groups_within_range_only() {
        let inst = interleaved(9, 9);
        let t = dedup_slots(&inst.costs, &inst.lower, &inst.upper, 3..9);
        assert_eq!(t.n_classes(), 3);
        for class in t.classes() {
            for &m in &class.members {
                assert!((3..9).contains(&m), "member {m} outside range");
            }
        }
    }

    #[test]
    fn merged_fleet_solves_like_the_flat_fleet() {
        use crate::sched::marin;
        let inst = interleaved(12, 18);
        let flat = FleetInstance::from_flat(&inst).unwrap();
        let (built, _) = build_sharded(&inst, 4).unwrap();
        let a = marin::solve_fleet(&flat).unwrap();
        let b = marin::solve_fleet(&built).unwrap();
        assert_eq!(a, b, "same input bits must give the same assignment");
        assert_eq!(
            a.total_cost(&flat).to_bits(),
            b.total_cost(&built).to_bits()
        );
    }
}
