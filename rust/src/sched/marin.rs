//! Algorithm 2 — **MarIn**: optimal scheduling under monotonically
//! *increasing* marginal costs (paper §5.3), adapted from OLAR [26].
//!
//! After lower-limit removal, tasks are assigned one at a time to the
//! resource whose *next marginal cost* `M_i(x_i + 1)` is minimal and whose
//! upper limit is not yet reached. Because marginal costs only grow, every
//! prefix schedule is optimal (Lemma 4), hence so is the result
//! (Theorem 2).
//!
//! Complexity: `Θ(n + T log n)` with a binary min-heap, `O(n)` space.

use crate::error::Result;
use crate::sched::fleet::{Assignment, CostView, FleetInstance, LowerFree};
use crate::sched::instance::{Instance, Schedule};
use crate::sched::limits;
use crate::util::heap::MinHeap;

/// Run MarIn. The caller is responsible for the instance actually having
/// increasing marginal costs (checked by [`crate::sched::auto`]); on other
/// instances the result is feasible but may be suboptimal.
pub fn solve(inst: &Instance) -> Result<Schedule> {
    inst.validate()?;
    let tr = limits::remove_lower_limits(inst);
    let ti = &tr.instance;
    let n = ti.n();
    let mut x = vec![0usize; n];

    // Heap of (next marginal cost, resource). Tie-break on resource index
    // for determinism.
    let mut heap: MinHeap<usize> = MinHeap::with_capacity(n);
    for i in 0..n {
        if ti.cap(i) > 0 {
            heap.push(ti.costs[i].marginal(1, 0), i as u64, i);
        }
    }

    for _t in 0..ti.tasks {
        let e = heap
            .pop()
            .expect("valid instance: capacity remains while tasks remain");
        let i = e.value;
        x[i] += 1;
        if x[i] < ti.cap(i) {
            heap.push(ti.costs[i].marginal(x[i] + 1, 0), i as u64, i);
        }
    }

    Ok(tr.restore(&Schedule::new(x)))
}

/// Class-aware MarIn over a lazy [`CostView`]: the heap is keyed by
/// **class × level** instead of device. Every member of a class at fill
/// level `ℓ` shares the same next marginal `M(ℓ+1)`, and with increasing
/// marginals those equal-valued tasks can be assigned as one block — the
/// chosen marginal multiset (hence the total cost) is identical to the
/// per-device greedy, which is optimal by Theorem 2.
///
/// Heap operations: one per `(class, level)` pair actually filled, so
/// `O(k + (T/m̄) log k)` for `k` classes of mean multiplicity `m̄` —
/// versus `Θ(n + T log n)` for the flat path.
///
/// Returns per-class `(load, n_devices)` runs in the *view's* domain
/// (callers owning a [`LowerFree`] view restore lower limits).
pub fn solve_view<V: CostView + ?Sized>(view: &V) -> Vec<Vec<(usize, usize)>> {
    let k = view.n_classes();
    // Per class: (level ℓ, devices already raised to ℓ+1).
    let mut level = vec![0usize; k];
    let mut raised = vec![0usize; k];
    let mut heap: MinHeap<usize> = MinHeap::with_capacity(k);
    for c in 0..k {
        if view.cap(c) > 0 {
            heap.push(view.eval(c, 1) - view.eval(c, 0), c as u64, c);
        }
    }

    let mut remaining = view.tasks();
    while remaining > 0 {
        let e = heap
            .pop()
            .expect("valid instance: capacity remains while tasks remain");
        let c = e.value;
        let m = view.count(c);
        // All `m - raised` members still at `level` share marginal `e.key`.
        let take = (m - raised[c]).min(remaining);
        raised[c] += take;
        remaining -= take;
        if raised[c] == m {
            level[c] += 1;
            raised[c] = 0;
        }
        // Next block for this class (members still at `level`, or the whole
        // class at the incremented level) costs `M(level + 1)` each.
        if level[c] < view.cap(c) {
            let next = level[c] + 1;
            heap.push(view.eval(c, next) - view.eval(c, next - 1), c as u64, c);
        }
    }

    (0..k)
        .map(|c| {
            // `raised` members sit at level+1, the rest at level.
            let m = view.count(c);
            vec![(level[c] + 1, raised[c]), (level[c], m - raised[c])]
        })
        .collect()
}

/// Run MarIn on a class-deduplicated fleet (same optimality contract as
/// [`solve`]).
pub fn solve_fleet(fleet: &FleetInstance) -> Result<Assignment> {
    fleet.validate()?;
    let view = LowerFree::of(fleet);
    let groups = solve_view(&view);
    Ok(Assignment::from_groups(view.restore(groups)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::costs::CostFn;
    use crate::sched::{mc2mkp, validate};

    fn affine(per_task: f64) -> CostFn {
        CostFn::Affine { fixed: 0.0, per_task }
    }

    #[test]
    fn prefers_cheapest_linear_resource() {
        let inst = Instance::new(
            6,
            vec![0, 0],
            vec![10, 10],
            vec![affine(1.0), affine(5.0)],
        )
        .unwrap();
        let s = solve(&inst).unwrap();
        assert_eq!(s.assignments(), &[6, 0]);
    }

    #[test]
    fn splits_convex_costs() {
        // C(j) = j², marginals 1,3,5,...: two identical resources share
        // evenly.
        let q = CostFn::Quadratic { fixed: 0.0, a: 1.0, b: 0.0 };
        let inst = Instance::new(8, vec![0, 0], vec![8, 8], vec![q.clone(), q]).unwrap();
        let s = solve(&inst).unwrap();
        assert_eq!(s.assignments(), &[4, 4]);
    }

    #[test]
    fn respects_upper_limits() {
        let inst = Instance::new(
            10,
            vec![0, 0],
            vec![3, 10],
            vec![affine(1.0), affine(100.0)],
        )
        .unwrap();
        let s = solve(&inst).unwrap();
        assert_eq!(s.assignments(), &[3, 7]);
        validate::check(&inst, &s).unwrap();
    }

    #[test]
    fn respects_lower_limits() {
        let inst = Instance::new(
            5,
            vec![0, 4],
            vec![10, 10],
            vec![affine(1.0), affine(100.0)],
        )
        .unwrap();
        let s = solve(&inst).unwrap();
        assert_eq!(s.assignments(), &[1, 4]);
    }

    #[test]
    fn fleet_blocks_match_flat_on_multiplicity_classes() {
        use crate::sched::fleet::FleetInstance;
        // 3 + 2 identical convex devices: class path must hit the same
        // optimal cost as the flat per-device greedy.
        let q1 = CostFn::Quadratic { fixed: 0.0, a: 1.0, b: 0.0 };
        let q2 = CostFn::Quadratic { fixed: 0.0, a: 2.0, b: 1.0 };
        let fleet = FleetInstance::builder()
            .tasks(17)
            .device_class(q1, 1, 10, 3)
            .device_class(q2, 0, 10, 2)
            .build()
            .unwrap();
        let asg = solve_fleet(&fleet).unwrap();
        asg.check(&fleet).unwrap();
        let flat = fleet.to_flat();
        let s = solve(&flat).unwrap();
        let c_flat = validate::checked_cost(&flat, &s).unwrap();
        assert!((asg.total_cost(&fleet) - c_flat).abs() < 1e-9);
        // Within a class, loads are balanced to within one task.
        for g in asg.groups() {
            let loads: Vec<usize> = g.iter().map(|&(l, _)| l).collect();
            let (min, max) = (
                *loads.iter().min().unwrap(),
                *loads.iter().max().unwrap(),
            );
            assert!(max - min <= 1, "unbalanced class loads {loads:?}");
        }
    }

    #[test]
    fn matches_dp_on_convex_instances() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xA11);
        for _case in 0..50 {
            let n = 2 + rng.index(4);
            let t = 5 + rng.index(40);
            let mut lower = Vec::new();
            let mut upper = Vec::new();
            let mut costs = Vec::new();
            for _ in 0..n {
                lower.push(rng.index(3));
                upper.push(t); // unlimited
                costs.push(CostFn::Quadratic {
                    fixed: rng.range_f64(0.0, 2.0),
                    a: rng.range_f64(0.01, 2.0),
                    b: rng.range_f64(0.0, 3.0),
                });
            }
            let sum_l: usize = lower.iter().sum();
            if sum_l > t {
                continue;
            }
            let inst = Instance::new(t, lower, upper, costs).unwrap();
            let a = solve(&inst).unwrap();
            let b = mc2mkp::solve(&inst).unwrap();
            let ca = validate::checked_cost(&inst, &a).unwrap();
            let cb = validate::checked_cost(&inst, &b).unwrap();
            assert!(
                (ca - cb).abs() < 1e-9,
                "MarIn {ca} != DP {cb} on {inst:?}"
            );
        }
    }
}
