//! Algorithm 2 — **MarIn**: optimal scheduling under monotonically
//! *increasing* marginal costs (paper §5.3), adapted from OLAR [26].
//!
//! After lower-limit removal, tasks are assigned one at a time to the
//! resource whose *next marginal cost* `M_i(x_i + 1)` is minimal and whose
//! upper limit is not yet reached. Because marginal costs only grow, every
//! prefix schedule is optimal (Lemma 4), hence so is the result
//! (Theorem 2).
//!
//! Complexity: `Θ(n + T log n)` with a binary min-heap, `O(n)` space.

use crate::error::Result;
use crate::sched::instance::{Instance, Schedule};
use crate::sched::limits;
use crate::util::heap::MinHeap;

/// Run MarIn. The caller is responsible for the instance actually having
/// increasing marginal costs (checked by [`crate::sched::auto`]); on other
/// instances the result is feasible but may be suboptimal.
pub fn solve(inst: &Instance) -> Result<Schedule> {
    inst.validate()?;
    let tr = limits::remove_lower_limits(inst);
    let ti = &tr.instance;
    let n = ti.n();
    let mut x = vec![0usize; n];

    // Heap of (next marginal cost, resource). Tie-break on resource index
    // for determinism.
    let mut heap: MinHeap<usize> = MinHeap::with_capacity(n);
    for i in 0..n {
        if ti.cap(i) > 0 {
            heap.push(ti.costs[i].marginal(1, 0), i as u64, i);
        }
    }

    for _t in 0..ti.tasks {
        let e = heap
            .pop()
            .expect("valid instance: capacity remains while tasks remain");
        let i = e.value;
        x[i] += 1;
        if x[i] < ti.cap(i) {
            heap.push(ti.costs[i].marginal(x[i] + 1, 0), i as u64, i);
        }
    }

    Ok(tr.restore(&Schedule::new(x)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::costs::CostFn;
    use crate::sched::{mc2mkp, validate};

    fn affine(per_task: f64) -> CostFn {
        CostFn::Affine { fixed: 0.0, per_task }
    }

    #[test]
    fn prefers_cheapest_linear_resource() {
        let inst = Instance::new(
            6,
            vec![0, 0],
            vec![10, 10],
            vec![affine(1.0), affine(5.0)],
        )
        .unwrap();
        let s = solve(&inst).unwrap();
        assert_eq!(s.assignments(), &[6, 0]);
    }

    #[test]
    fn splits_convex_costs() {
        // C(j) = j², marginals 1,3,5,...: two identical resources share
        // evenly.
        let q = CostFn::Quadratic { fixed: 0.0, a: 1.0, b: 0.0 };
        let inst = Instance::new(8, vec![0, 0], vec![8, 8], vec![q.clone(), q]).unwrap();
        let s = solve(&inst).unwrap();
        assert_eq!(s.assignments(), &[4, 4]);
    }

    #[test]
    fn respects_upper_limits() {
        let inst = Instance::new(
            10,
            vec![0, 0],
            vec![3, 10],
            vec![affine(1.0), affine(100.0)],
        )
        .unwrap();
        let s = solve(&inst).unwrap();
        assert_eq!(s.assignments(), &[3, 7]);
        validate::check(&inst, &s).unwrap();
    }

    #[test]
    fn respects_lower_limits() {
        let inst = Instance::new(
            5,
            vec![0, 4],
            vec![10, 10],
            vec![affine(1.0), affine(100.0)],
        )
        .unwrap();
        let s = solve(&inst).unwrap();
        assert_eq!(s.assignments(), &[1, 4]);
    }

    #[test]
    fn matches_dp_on_convex_instances() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xA11);
        for _case in 0..50 {
            let n = 2 + rng.index(4);
            let t = 5 + rng.index(40);
            let mut lower = Vec::new();
            let mut upper = Vec::new();
            let mut costs = Vec::new();
            for _ in 0..n {
                lower.push(rng.index(3));
                upper.push(t); // unlimited
                costs.push(CostFn::Quadratic {
                    fixed: rng.range_f64(0.0, 2.0),
                    a: rng.range_f64(0.01, 2.0),
                    b: rng.range_f64(0.0, 3.0),
                });
            }
            let sum_l: usize = lower.iter().sum();
            if sum_l > t {
                continue;
            }
            let inst = Instance::new(t, lower, upper, costs).unwrap();
            let a = solve(&inst).unwrap();
            let b = mc2mkp::solve(&inst).unwrap();
            let ca = validate::checked_cost(&inst, &a).unwrap();
            let cb = validate::checked_cost(&inst, &b).unwrap();
            assert!(
                (ca - cb).abs() < 1e-9,
                "MarIn {ca} != DP {cb} on {inst:?}"
            );
        }
    }
}
