//! Command-line argument parsing (the offline build has no `clap`).
//!
//! Supports subcommands, `--flag value`, `--flag=value`, boolean switches,
//! repeated flags, positional arguments, and auto-generated usage text.

use std::collections::{BTreeMap, BTreeSet};

use crate::error::{FedError, Result};

/// Declared option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// Boolean switch (no value) vs valued option.
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// Declared subcommand.
#[derive(Clone, Debug)]
pub struct CmdSpec {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
    pub positional: Vec<(&'static str, &'static str)>,
}

/// Parsed arguments for one subcommand.
#[derive(Clone, Debug, Default)]
pub struct Parsed {
    pub command: String,
    values: BTreeMap<String, Vec<String>>,
    switches: BTreeMap<String, bool>,
    /// Options the user actually passed (vs seeded spec defaults) — what
    /// lets config-file values lose only to *explicit* flags.
    explicit: BTreeSet<String>,
    pub positional: Vec<String>,
}

impl Parsed {
    /// Last value of `--name`, or its default.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// All values of a repeated `--name`.
    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.values
            .get(name)
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    /// Required string value.
    pub fn req(&self, name: &str) -> Result<&str> {
        self.get(name)
            .ok_or_else(|| FedError::Config(format!("missing required option --{name}")))
    }

    /// Typed value with FromStr.
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|_| FedError::Config(format!("bad value for --{name}: '{s}'"))),
        }
    }

    /// Typed value with a fallback default.
    pub fn get_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        Ok(self.get_parse(name)?.unwrap_or(default))
    }

    /// Last value of `--name` only when it was explicitly passed on the
    /// command line (`None` when absent or merely seeded from the spec
    /// default) — so config-file values survive unless the user overrode
    /// them.
    pub fn get_explicit(&self, name: &str) -> Option<&str> {
        if self.explicit.contains(name) {
            self.get(name)
        } else {
            None
        }
    }

    /// Typed variant of [`Parsed::get_explicit`].
    pub fn get_parse_explicit<T: std::str::FromStr>(
        &self,
        name: &str,
    ) -> Result<Option<T>> {
        if !self.explicit.contains(name) {
            return Ok(None);
        }
        self.get_parse(name)
    }

    /// Boolean switch presence.
    pub fn flag(&self, name: &str) -> bool {
        self.switches.get(name).copied().unwrap_or(false)
    }
}

/// Application definition: name + subcommands.
#[derive(Clone, Debug)]
pub struct App {
    pub name: &'static str,
    pub about: &'static str,
    pub commands: Vec<CmdSpec>,
}

impl App {
    /// Render usage/help text.
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} <command> [options]\n\nCOMMANDS:\n",
            self.name, self.about, self.name);
        for c in &self.commands {
            s.push_str(&format!("  {:<16} {}\n", c.name, c.about));
        }
        for c in &self.commands {
            s.push_str(&format!("\n{} {}:\n", self.name, c.name));
            for (p, h) in &c.positional {
                s.push_str(&format!("  <{p}>  {h}\n"));
            }
            for o in &c.opts {
                let v = if o.takes_value { " <value>" } else { "" };
                let d = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
                s.push_str(&format!("  --{}{v}  {}{d}\n", o.name, o.help));
            }
        }
        s
    }

    /// Parse a raw argv (excluding program name).
    pub fn parse(&self, args: &[String]) -> Result<Parsed> {
        if args.is_empty() || args[0] == "--help" || args[0] == "-h" || args[0] == "help" {
            return Err(FedError::Config(self.usage()));
        }
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == args[0])
            .ok_or_else(|| {
                FedError::Config(format!("unknown command '{}'\n\n{}", args[0], self.usage()))
            })?;

        let mut parsed = Parsed { command: cmd.name.to_string(), ..Default::default() };
        // Seed defaults.
        for o in &cmd.opts {
            if let Some(d) = o.default {
                parsed.values.insert(o.name.to_string(), vec![d.to_string()]);
            }
        }

        let mut i = 1;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Err(FedError::Config(self.usage()));
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (stripped, None),
                };
                let spec = cmd.opts.iter().find(|o| o.name == name).ok_or_else(|| {
                    FedError::Config(format!("unknown option --{name} for '{}'", cmd.name))
                })?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| {
                                    FedError::Config(format!("--{name} requires a value"))
                                })?
                        }
                    };
                    parsed.explicit.insert(name.to_string());
                    parsed
                        .values
                        .entry(name.to_string())
                        .and_modify(|v| {
                            // Replace seeded default on first explicit use.
                            if v.len() == 1 && Some(v[0].as_str()) == spec.default {
                                v.clear();
                            }
                        })
                        .or_default()
                        .push(val);
                } else {
                    if inline_val.is_some() {
                        return Err(FedError::Config(format!("--{name} takes no value")));
                    }
                    parsed.switches.insert(name.to_string(), true);
                }
            } else {
                parsed.positional.push(a.clone());
            }
            i += 1;
        }
        if parsed.positional.len() < cmd.positional.len() {
            return Err(FedError::Config(format!(
                "'{}' expects {} positional argument(s)",
                cmd.name,
                cmd.positional.len()
            )));
        }
        Ok(parsed)
    }
}

/// The fedzero CLI definition shared by `main.rs`.
pub fn fedzero_app() -> App {
    App {
        name: "fedzero",
        about: "energy-minimal FL scheduling (Lima Pilla 2022 reproduction)",
        commands: vec![
            CmdSpec {
                name: "schedule",
                about: "solve a Minimal Cost FL Schedule instance",
                opts: vec![
                    OptSpec { name: "tasks", help: "workload size T", takes_value: true, default: Some("256") },
                    OptSpec { name: "devices", help: "number of resources n", takes_value: true, default: Some("10") },
                    OptSpec { name: "seed", help: "fleet RNG seed", takes_value: true, default: Some("1") },
                    OptSpec { name: "regime", help: "cost regime: increasing|constant|decreasing|arbitrary", takes_value: true, default: Some("increasing") },
                    OptSpec { name: "algo", help: "solver name (see `fedzero solvers`; errors list the registry)", takes_value: true, default: Some("auto") },
                    OptSpec { name: "shards", help: "instance-build shards (concurrent class dedup; 1 = direct build, identical schedule either way)", takes_value: true, default: Some("1") },
                    OptSpec { name: "json", help: "print the schedule as JSON", takes_value: false, default: None },
                ],
                positional: vec![],
            },
            CmdSpec {
                name: "train",
                about: "run federated training with a scheduler policy",
                opts: vec![
                    OptSpec { name: "config", help: "experiment config file (TOML)", takes_value: true, default: None },
                    OptSpec { name: "rounds", help: "number of FL rounds", takes_value: true, default: Some("50") },
                    OptSpec { name: "devices", help: "fleet size", takes_value: true, default: Some("16") },
                    OptSpec { name: "tasks", help: "mini-batches per round (T)", takes_value: true, default: Some("64") },
                    OptSpec { name: "model", help: "model artifact name (mlp|transformer)", takes_value: true, default: Some("mlp") },
                    OptSpec { name: "algo", help: "scheduler policy (any registered solver name)", takes_value: true, default: Some("auto") },
                    OptSpec { name: "seed", help: "RNG seed", takes_value: true, default: Some("7") },
                    OptSpec { name: "artifacts", help: "artifacts directory", takes_value: true, default: Some("artifacts") },
                    OptSpec { name: "out", help: "CSV output path", takes_value: true, default: None },
                    OptSpec { name: "backend", help: "round backend: fl (PJRT training) | sim (schedules + energy only)", takes_value: true, default: Some("fl") },
                    OptSpec { name: "store", help: "durable campaign directory (journal + snapshots; sim backend only)", takes_value: true, default: None },
                    OptSpec { name: "snapshot-every", help: "snapshot cadence in rounds (with --store)", takes_value: true, default: Some("16") },
                    OptSpec { name: "metrics-jsonl", help: "stream per-round rows to this JSONL file", takes_value: true, default: None },
                    OptSpec { name: "log-ring", help: "bound the in-memory round log to this many rows (0 = unbounded)", takes_value: true, default: None },
                    OptSpec { name: "dynamics", help: "fleet dynamics: none | mobile (churn, drift, dropout)", takes_value: true, default: Some("none") },
                    OptSpec { name: "shards", help: "per-round instance-build shards (concurrent class dedup; schedules are bit-for-bit identical for any value)", takes_value: true, default: Some("1") },
                    OptSpec { name: "pipeline", help: "overlap next-round scheduling with training: on | off (campaigns are bit-for-bit identical either way)", takes_value: true, default: Some("off") },
                    OptSpec { name: "incremental", help: "persistent class index, re-derive rounds from the dirty set: on | off (schedules are bit-for-bit identical either way)", takes_value: true, default: Some("off") },
                    OptSpec { name: "round-sleep-ms", help: "sleep between rounds (crash-recovery testing; sim only)", takes_value: true, default: Some("0") },
                    OptSpec { name: "trace", help: "write a Chrome Trace Event JSONL phase trace to this file (pure telemetry; campaigns are bit-for-bit identical with or without it)", takes_value: true, default: None },
                    OptSpec { name: "deadline", help: "per-round completion deadline in seconds (min energy s.t. makespan <= D; persisted with the campaign)", takes_value: true, default: None },
                    OptSpec { name: "objective", help: "cost unit to minimize: energy | carbon | money (carbon/money weight device costs by grid region)", takes_value: true, default: Some("energy") },
                    OptSpec { name: "transport", help: "round delivery: inproc (direct backend call) | loopback (networked service over the in-memory wire; sim backend only)", takes_value: true, default: Some("inproc") },
                    OptSpec { name: "svc-churn", help: "permille of (device, round) pairs that disconnect after reporting and rejoin (loopback transport; digest-neutral)", takes_value: true, default: Some("0") },
                    OptSpec { name: "svc-miss", help: "permille of (device, round) pairs that never report (loopback transport; hard stragglers, partial rounds)", takes_value: true, default: Some("0") },
                ],
                positional: vec![],
            },
            CmdSpec {
                name: "serve",
                about: "run a storeless loopback service campaign and print protocol/registry stats",
                opts: vec![
                    OptSpec { name: "rounds", help: "number of FL rounds", takes_value: true, default: Some("8") },
                    OptSpec { name: "devices", help: "fleet size (simulated clients)", takes_value: true, default: Some("64") },
                    OptSpec { name: "tasks", help: "mini-batches per round (T)", takes_value: true, default: Some("128") },
                    OptSpec { name: "seed", help: "RNG seed", takes_value: true, default: Some("7") },
                    OptSpec { name: "algo", help: "scheduler policy (any registered solver name)", takes_value: true, default: Some("auto") },
                    OptSpec { name: "svc-churn", help: "permille of (device, round) pairs that disconnect after reporting and rejoin", takes_value: true, default: Some("50") },
                    OptSpec { name: "svc-miss", help: "permille of (device, round) pairs that never report", takes_value: true, default: Some("0") },
                    OptSpec { name: "trace", help: "write a Chrome Trace Event JSONL service trace to this file", takes_value: true, default: None },
                    OptSpec { name: "expose", help: "also print the service metrics hub in text exposition format", takes_value: false, default: None },
                ],
                positional: vec![],
            },
            CmdSpec {
                name: "resume",
                about: "continue a crashed or stopped campaign from its store",
                opts: vec![
                    OptSpec { name: "round-sleep-ms", help: "sleep between rounds (crash-recovery testing)", takes_value: true, default: Some("0") },
                    OptSpec { name: "trace", help: "append the phase trace to this file (overrides the trace path persisted in the store meta)", takes_value: true, default: None },
                ],
                positional: vec![("dir", "campaign store directory")],
            },
            CmdSpec {
                name: "replay",
                about: "re-derive every journaled round and verify digests (deterministic audit)",
                opts: vec![],
                positional: vec![("dir", "campaign store directory")],
            },
            CmdSpec {
                name: "stats",
                about: "post-hoc campaign dashboard from a store (phases, pipeline/incremental rates, energy concentration, solver usage)",
                opts: vec![
                    OptSpec { name: "expose", help: "also print the metrics hub in text exposition format", takes_value: false, default: None },
                ],
                positional: vec![("dir", "campaign store directory")],
            },
            CmdSpec {
                name: "pareto",
                about: "dump the energy-time Pareto front of a sampled fleet (epsilon-constraint sweep)",
                opts: vec![
                    OptSpec { name: "tasks", help: "workload size T", takes_value: true, default: Some("256") },
                    OptSpec { name: "devices", help: "fleet size", takes_value: true, default: Some("10") },
                    OptSpec { name: "seed", help: "fleet RNG seed", takes_value: true, default: Some("1") },
                    OptSpec { name: "algo", help: "solver for each epsilon-constrained point (any registered name)", takes_value: true, default: Some("auto") },
                    OptSpec { name: "objective", help: "cost unit: energy | carbon | money", takes_value: true, default: Some("energy") },
                    OptSpec { name: "region", help: "pin every device to one grid region (default: spread across the region table)", takes_value: true, default: None },
                    OptSpec { name: "round", help: "round index to sample the carbon curve at (carbon objective only)", takes_value: true, default: Some("0") },
                    OptSpec { name: "upload-s", help: "model upload seconds added to every device's compute time", takes_value: true, default: Some("2") },
                    OptSpec { name: "deadline", help: "solve one epsilon-constrained point at this makespan cap instead of the full front", takes_value: true, default: None },
                    OptSpec { name: "format", help: "output format: csv | jsonl", takes_value: true, default: Some("csv") },
                    OptSpec { name: "out", help: "write points to this file instead of stdout", takes_value: true, default: None },
                ],
                positional: vec![],
            },
            CmdSpec {
                name: "fleet",
                about: "sample and describe a heterogeneous device fleet",
                opts: vec![
                    OptSpec { name: "devices", help: "fleet size", takes_value: true, default: Some("10") },
                    OptSpec { name: "seed", help: "RNG seed", takes_value: true, default: Some("1") },
                ],
                positional: vec![],
            },
            CmdSpec {
                name: "solvers",
                about: "list registered solvers and their Table 2 optimality",
                opts: vec![],
                positional: vec![],
            },
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_defaults_and_overrides() {
        let app = fedzero_app();
        let p = app.parse(&args(&["schedule", "--tasks", "500", "--json"])).unwrap();
        assert_eq!(p.command, "schedule");
        assert_eq!(p.get("tasks"), Some("500"));
        assert_eq!(p.get("devices"), Some("10")); // default
        assert!(p.flag("json"));
        assert_eq!(p.get_or::<u64>("seed", 0).unwrap(), 1);
    }

    #[test]
    fn explicit_flags_are_distinguished_from_seeded_defaults() {
        let app = fedzero_app();
        let p = app.parse(&args(&["train", "--rounds", "9"])).unwrap();
        // --rounds was passed; --seed merely carries its spec default.
        assert_eq!(p.get_explicit("rounds"), Some("9"));
        assert_eq!(p.get_explicit("seed"), None);
        assert_eq!(p.get("seed"), Some("7"), "default still readable");
        assert_eq!(p.get_parse_explicit::<usize>("rounds").unwrap(), Some(9));
        assert_eq!(p.get_parse_explicit::<u64>("seed").unwrap(), None);
        // Passing the default's exact value still counts as explicit.
        let p = app.parse(&args(&["train", "--seed=7"])).unwrap();
        assert_eq!(p.get_explicit("seed"), Some("7"));
    }

    #[test]
    fn equals_syntax() {
        let app = fedzero_app();
        let p = app.parse(&args(&["schedule", "--tasks=42"])).unwrap();
        assert_eq!(p.get_parse::<usize>("tasks").unwrap(), Some(42));
    }

    #[test]
    fn unknown_command_and_option() {
        let app = fedzero_app();
        assert!(app.parse(&args(&["nope"])).is_err());
        assert!(app.parse(&args(&["schedule", "--bogus", "1"])).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        let app = fedzero_app();
        assert!(app.parse(&args(&["schedule", "--tasks"])).is_err());
    }

    #[test]
    fn help_is_config_error_with_usage() {
        let app = fedzero_app();
        let err = app.parse(&args(&["--help"])).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("USAGE"));
        assert!(msg.contains("schedule"));
    }

    #[test]
    fn shards_flag_parses_on_schedule_and_train() {
        let app = fedzero_app();
        let p = app.parse(&args(&["schedule", "--shards", "8"])).unwrap();
        assert_eq!(p.get_parse::<usize>("shards").unwrap(), Some(8));
        let p = app.parse(&args(&["train", "--backend", "sim"])).unwrap();
        assert_eq!(p.get_or::<usize>("shards", 0).unwrap(), 1, "default");
        assert_eq!(p.get_explicit("shards"), None);
        let p = app.parse(&args(&["train", "--shards=4"])).unwrap();
        assert_eq!(p.get_parse_explicit::<usize>("shards").unwrap(), Some(4));
    }

    #[test]
    fn pipeline_flag_parses_on_train() {
        let app = fedzero_app();
        let p = app.parse(&args(&["train", "--backend", "sim"])).unwrap();
        assert_eq!(p.get("pipeline"), Some("off"), "default");
        assert_eq!(p.get_explicit("pipeline"), None);
        let p = app.parse(&args(&["train", "--pipeline", "on"])).unwrap();
        assert_eq!(p.get("pipeline"), Some("on"));
        assert_eq!(p.get_explicit("pipeline"), Some("on"));
    }

    #[test]
    fn incremental_flag_parses_on_train() {
        let app = fedzero_app();
        let p = app.parse(&args(&["train", "--backend", "sim"])).unwrap();
        assert_eq!(p.get("incremental"), Some("off"), "default");
        assert_eq!(p.get_explicit("incremental"), None);
        let p = app.parse(&args(&["train", "--incremental", "on"])).unwrap();
        assert_eq!(p.get("incremental"), Some("on"));
        assert_eq!(p.get_explicit("incremental"), Some("on"));
    }

    #[test]
    fn store_subcommands_parse() {
        let app = fedzero_app();
        let p = app
            .parse(&args(&[
                "train", "--backend", "sim", "--store", "/tmp/x",
                "--snapshot-every", "8",
            ]))
            .unwrap();
        assert_eq!(p.get("backend"), Some("sim"));
        assert_eq!(p.get("store"), Some("/tmp/x"));
        assert_eq!(p.get_or::<usize>("snapshot-every", 0).unwrap(), 8);
        let p = app.parse(&args(&["resume", "/tmp/x"])).unwrap();
        assert_eq!(p.positional, vec!["/tmp/x".to_string()]);
        let p = app.parse(&args(&["replay", "/tmp/x"])).unwrap();
        assert_eq!(p.command, "replay");
        assert!(app.parse(&args(&["resume"])).is_err(), "dir is required");
    }

    #[test]
    fn trace_flag_parses_on_train_and_resume() {
        let app = fedzero_app();
        let p = app.parse(&args(&["train", "--backend", "sim"])).unwrap();
        assert_eq!(p.get("trace"), None, "no default trace path");
        let p = app
            .parse(&args(&["train", "--trace", "/tmp/t.jsonl"]))
            .unwrap();
        assert_eq!(p.get("trace"), Some("/tmp/t.jsonl"));
        let p = app
            .parse(&args(&["resume", "/tmp/x", "--trace=/tmp/t.jsonl"]))
            .unwrap();
        assert_eq!(p.get("trace"), Some("/tmp/t.jsonl"));
    }

    #[test]
    fn stats_subcommand_parses() {
        let app = fedzero_app();
        let p = app.parse(&args(&["stats", "/tmp/x", "--expose"])).unwrap();
        assert_eq!(p.command, "stats");
        assert_eq!(p.positional, vec!["/tmp/x".to_string()]);
        assert!(p.flag("expose"));
        assert!(app.parse(&args(&["stats"])).is_err(), "dir is required");
    }

    #[test]
    fn deadline_and_objective_parse_on_train() {
        let app = fedzero_app();
        let p = app.parse(&args(&["train", "--backend", "sim"])).unwrap();
        assert_eq!(p.get("deadline"), None, "no default deadline");
        assert_eq!(p.get("objective"), Some("energy"), "default objective");
        let p = app
            .parse(&args(&["train", "--deadline", "7.5", "--objective", "carbon"]))
            .unwrap();
        assert_eq!(p.get_parse::<f64>("deadline").unwrap(), Some(7.5));
        assert_eq!(p.get("objective"), Some("carbon"));
        assert_eq!(p.get_explicit("objective"), Some("carbon"));
    }

    #[test]
    fn pareto_subcommand_parses() {
        let app = fedzero_app();
        let p = app.parse(&args(&["pareto"])).unwrap();
        assert_eq!(p.command, "pareto");
        assert_eq!(p.get_or::<usize>("tasks", 0).unwrap(), 256);
        assert_eq!(p.get_or::<usize>("devices", 0).unwrap(), 10);
        assert_eq!(p.get("format"), Some("csv"));
        assert_eq!(p.get("deadline"), None);
        let p = app
            .parse(&args(&[
                "pareto", "--objective", "carbon", "--region", "france",
                "--round", "12", "--deadline=30", "--format", "jsonl",
                "--out", "/tmp/front.jsonl",
            ]))
            .unwrap();
        assert_eq!(p.get("objective"), Some("carbon"));
        assert_eq!(p.get("region"), Some("france"));
        assert_eq!(p.get_or::<usize>("round", 0).unwrap(), 12);
        assert_eq!(p.get_parse::<f64>("deadline").unwrap(), Some(30.0));
        assert_eq!(p.get("format"), Some("jsonl"));
        assert_eq!(p.get("out"), Some("/tmp/front.jsonl"));
    }

    #[test]
    fn transport_flags_parse_on_train() {
        let app = fedzero_app();
        let p = app.parse(&args(&["train", "--backend", "sim"])).unwrap();
        assert_eq!(p.get("transport"), Some("inproc"), "default transport");
        assert_eq!(p.get_or::<u32>("svc-churn", 1).unwrap(), 0);
        assert_eq!(p.get_or::<u32>("svc-miss", 1).unwrap(), 0);
        let p = app
            .parse(&args(&[
                "train", "--backend", "sim", "--transport", "loopback",
                "--svc-churn", "120", "--svc-miss=45",
            ]))
            .unwrap();
        assert_eq!(p.get("transport"), Some("loopback"));
        assert_eq!(p.get_explicit("transport"), Some("loopback"));
        assert_eq!(p.get_or::<u32>("svc-churn", 0).unwrap(), 120);
        assert_eq!(p.get_or::<u32>("svc-miss", 0).unwrap(), 45);
    }

    #[test]
    fn serve_subcommand_parses() {
        let app = fedzero_app();
        let p = app.parse(&args(&["serve"])).unwrap();
        assert_eq!(p.command, "serve");
        assert_eq!(p.get_or::<usize>("rounds", 0).unwrap(), 8);
        assert_eq!(p.get_or::<usize>("devices", 0).unwrap(), 64);
        assert_eq!(p.get_or::<u32>("svc-churn", 0).unwrap(), 50);
        assert!(!p.flag("expose"));
        let p = app
            .parse(&args(&[
                "serve", "--devices", "100000", "--svc-miss", "10", "--expose",
            ]))
            .unwrap();
        assert_eq!(p.get_or::<usize>("devices", 0).unwrap(), 100_000);
        assert_eq!(p.get_or::<u32>("svc-miss", 0).unwrap(), 10);
        assert!(p.flag("expose"));
    }

    #[test]
    fn bad_typed_value() {
        let app = fedzero_app();
        let p = app.parse(&args(&["schedule", "--tasks", "xyz"])).unwrap();
        assert!(p.get_parse::<usize>("tasks").is_err());
    }
}
