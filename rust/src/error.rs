//! Crate-wide error type and result alias.

/// Errors produced anywhere in the fedzero stack.
#[derive(Debug, thiserror::Error)]
pub enum FedError {
    /// The problem instance is malformed (violates the validity conditions
    /// of §3: `L_i <= U_i`, `ΣL <= T <= ΣU`, empty resource set, ...).
    #[error("invalid instance: {0}")]
    InvalidInstance(String),

    /// A scheduler was invoked on an instance outside its declared scenario
    /// (e.g. MarIn on decreasing marginal costs).
    #[error("scenario mismatch: {0}")]
    ScenarioMismatch(String),

    /// No feasible schedule exists (should not happen on valid instances).
    #[error("infeasible: {0}")]
    Infeasible(String),

    /// A produced schedule failed validation.
    #[error("invalid schedule: {0}")]
    InvalidSchedule(String),

    /// Configuration file / CLI errors.
    #[error("config error: {0}")]
    Config(String),

    /// Artifact manifest or HLO loading problems.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// PJRT / XLA runtime failures.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Federated-learning loop failures (aggregation shape mismatch, ...).
    #[error("fl error: {0}")]
    Fl(String),

    /// Underlying I/O failure.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for FedError {
    fn from(e: xla::Error) -> Self {
        FedError::Runtime(format!("{e:?}"))
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, FedError>;
