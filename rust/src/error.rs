//! Crate-wide error type and result alias.
//!
//! Hand-implemented `Display`/`Error` (the offline build has no
//! `thiserror`).

use std::fmt;

/// Errors produced anywhere in the fedzero stack.
#[derive(Debug)]
pub enum FedError {
    /// The problem instance is malformed (violates the validity conditions
    /// of §3: `L_i <= U_i`, `ΣL <= T <= ΣU`, empty resource set, ...).
    InvalidInstance(String),

    /// A scheduler was invoked on an instance outside its declared scenario
    /// (e.g. MarIn on decreasing marginal costs).
    ScenarioMismatch(String),

    /// No feasible schedule exists (should not happen on valid instances).
    Infeasible(String),

    /// A produced schedule failed validation.
    InvalidSchedule(String),

    /// Configuration file / CLI errors.
    Config(String),

    /// Artifact manifest or HLO loading problems.
    Artifact(String),

    /// PJRT / XLA runtime failures.
    Runtime(String),

    /// Federated-learning loop failures (aggregation shape mismatch, ...).
    Fl(String),

    /// Coordinator state-machine violations (illegal phase transition,
    /// round driven from a non-ready state).
    Coordinator(String),

    /// Durable-store failures: journal/snapshot corruption, checksum
    /// mismatches, or a replay that diverged from the journaled campaign.
    Store(String),

    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for FedError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FedError::InvalidInstance(m) => write!(f, "invalid instance: {m}"),
            FedError::ScenarioMismatch(m) => write!(f, "scenario mismatch: {m}"),
            FedError::Infeasible(m) => write!(f, "infeasible: {m}"),
            FedError::InvalidSchedule(m) => write!(f, "invalid schedule: {m}"),
            FedError::Config(m) => write!(f, "config error: {m}"),
            FedError::Artifact(m) => write!(f, "artifact error: {m}"),
            FedError::Runtime(m) => write!(f, "runtime error: {m}"),
            FedError::Fl(m) => write!(f, "fl error: {m}"),
            FedError::Coordinator(m) => write!(f, "coordinator error: {m}"),
            FedError::Store(m) => write!(f, "store error: {m}"),
            FedError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for FedError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            FedError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for FedError {
    fn from(e: std::io::Error) -> Self {
        FedError::Io(e)
    }
}

impl From<xla::Error> for FedError {
    fn from(e: xla::Error) -> Self {
        FedError::Runtime(format!("{e:?}"))
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, FedError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes() {
        assert_eq!(
            FedError::InvalidInstance("x".into()).to_string(),
            "invalid instance: x"
        );
        assert_eq!(FedError::Config("y".into()).to_string(), "config error: y");
        assert_eq!(
            FedError::Coordinator("bad phase".into()).to_string(),
            "coordinator error: bad phase"
        );
    }

    #[test]
    fn io_source_is_preserved() {
        let e: FedError =
            std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(std::error::Error::source(&e).is_some());
    }
}
