//! Property-based testing kit (the offline build has no `proptest`).
//!
//! Supplies seeded random *generators* and a `forall` runner that executes a
//! property over many generated cases, reporting the seed and a shrunk
//! counterexample on failure. Shrinking is size-directed: generators expose
//! a `shrink` hook producing structurally smaller candidates, and the runner
//! greedily descends while the property keeps failing.
//!
//! The scheduler test-suite uses this to check, over thousands of random
//! instances, that every specialized algorithm matches the (MC)²MKP DP and
//! the brute-force oracle.
//!
//! [`instances`] supplies the shared scenario-diverse instance generator
//! (Table 2 cost families × adversarial limit patterns × duplication
//! shapes) and the shard ≡ class ≡ flat differential harness.

pub mod instances;

use crate::util::rng::Rng;

/// A generator of values of type `T` plus a shrinking strategy.
pub trait Gen<T> {
    /// Generate one value.
    fn generate(&self, rng: &mut Rng) -> T;
    /// Produce smaller candidate values (default: none).
    fn shrink(&self, _value: &T) -> Vec<T> {
        Vec::new()
    }
}

/// Generator from plain closures (no shrinking).
pub struct FnGen<F>(pub F);

impl<T, F: Fn(&mut Rng) -> T> Gen<T> for FnGen<F> {
    fn generate(&self, rng: &mut Rng) -> T {
        (self.0)(rng)
    }
}

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Number of random cases.
    pub cases: usize,
    /// Base seed; case `i` uses `seed + i`.
    pub seed: u64,
    /// Maximum shrink steps.
    pub max_shrink: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 200, seed: 0xFED0, max_shrink: 200 }
    }
}

/// Outcome of a single property check.
pub type PropResult = Result<(), String>;

/// Run `prop` over `cfg.cases` generated values; panic with diagnostics on
/// the first (shrunk) failure.
pub fn forall<T: Clone + std::fmt::Debug>(
    cfg: &Config,
    gen: &dyn Gen<T>,
    prop: impl Fn(&T) -> PropResult,
) {
    for case in 0..cfg.cases {
        let seed = cfg.seed.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        let value = gen.generate(&mut rng);
        if let Err(msg) = prop(&value) {
            // Shrink: repeatedly take any failing shrink candidate.
            let mut cur = value;
            let mut cur_msg = msg;
            let mut steps = 0;
            'outer: while steps < cfg.max_shrink {
                for cand in gen.shrink(&cur) {
                    if let Err(m) = prop(&cand) {
                        cur = cand;
                        cur_msg = m;
                        steps += 1;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case}, seed {seed:#x}, {steps} shrink steps):\n\
                 value: {cur:?}\nerror: {cur_msg}"
            );
        }
    }
}

/// Convenience assertion helpers for property bodies.
pub fn ensure(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Assert two f64 values are within `tol`.
pub fn close(a: f64, b: f64, tol: f64, what: &str) -> PropResult {
    if (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(format!("{what}: {a} != {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct VecGen {
        max_len: usize,
    }

    impl Gen<Vec<u32>> for VecGen {
        fn generate(&self, rng: &mut Rng) -> Vec<u32> {
            let n = rng.index(self.max_len + 1);
            (0..n).map(|_| rng.below(100) as u32).collect()
        }
        fn shrink(&self, v: &Vec<u32>) -> Vec<Vec<u32>> {
            let mut out = Vec::new();
            if !v.is_empty() {
                out.push(v[..v.len() / 2].to_vec());
                out.push(v[1..].to_vec());
                let mut smaller = v.clone();
                for x in smaller.iter_mut() {
                    *x /= 2;
                }
                out.push(smaller);
            }
            out
        }
    }

    #[test]
    fn passing_property() {
        let cfg = Config { cases: 100, ..Default::default() };
        forall(&cfg, &VecGen { max_len: 20 }, |v| {
            let s: u32 = v.iter().sum();
            ensure(s as usize <= v.len() * 99, "sum bound")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        let cfg = Config { cases: 100, ..Default::default() };
        forall(&cfg, &VecGen { max_len: 20 }, |v| {
            ensure(v.len() < 5, "too long")
        });
    }

    #[test]
    fn shrinks_toward_small() {
        let cfg = Config { cases: 50, ..Default::default() };
        let result = std::panic::catch_unwind(|| {
            forall(&cfg, &VecGen { max_len: 30 }, |v| ensure(v.len() < 10, "len"))
        });
        let msg = format!("{:?}", result.unwrap_err().downcast_ref::<String>().unwrap());
        // The shrunk counterexample should be exactly at the boundary (len 10).
        assert!(msg.contains("value:"));
    }

    #[test]
    fn close_helper() {
        assert!(close(1.0, 1.0 + 1e-12, 1e-9, "x").is_ok());
        assert!(close(1.0, 2.0, 1e-9, "x").is_err());
    }
}
