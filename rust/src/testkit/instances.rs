//! Scenario-diverse random instance generation and the
//! **shard ≡ class ≡ flat differential harness** — shared infrastructure
//! for every scheduler property test.
//!
//! The generator covers the paper's Table 2 axes explicitly:
//!
//! * **cost family** ([`Family`]): convex (increasing marginals), affine
//!   (constant), concave (decreasing), tabulated (arbitrary);
//! * **limit pattern** ([`LimitPattern`]): unlimited, upper-only, both,
//!   plus the adversarial shapes that historically break limit handling —
//!   `TightLower` (ΣL = T: the schedule is globally forced) and `Pinned`
//!   (L = U per device: every load is fixed, the transformed workload is
//!   zero);
//! * **duplication shape** ([`DupShape`]): random multiplicities,
//!   single-class (every device interchangeable), all-unique (k = n — the
//!   dedup fast-path boundary).
//!
//! Cases are value types carrying their derivation seed
//! ([`Case::build`] is a pure function of the case), so failures print a
//! reproducible recipe and [`crate::testkit::forall`] can shrink them.
//!
//! [`check_shard_class_flat`] is the differential oracle the shard
//! pipeline is proven with: for one instance and one registered solver it
//! checks (a) every sharded build is **bit-identical** to
//! [`FleetInstance::from_flat`], (b) sharded and class solves agree on
//! assignment *and* cost **bits**, (c) flat and class solves agree
//! (bit-for-bit for flat-delegating solvers, cost-equal within float
//! tolerance for class-aware cores), and (d) errors have parity — a path
//! that rejects an instance must be rejected by every path.

use crate::sched::bruteforce;
use crate::sched::costs::CostFn;
use crate::sched::fleet::FleetInstance;
use crate::sched::incremental::{from_scratch_round, FleetIndex, RoundParams};
use crate::sched::instance::{Instance, Schedule};
use crate::sched::pareto::TimeModel;
use crate::sched::shard;
use crate::sched::solver::{Solver as _, SolverRegistry};
use crate::sched::validate;
use crate::testkit::Gen;
use crate::util::rng::Rng;

/// Cost family of a generated instance (Table 2 rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    /// Quadratic — increasing marginal costs (7a).
    Convex,
    /// Affine — constant marginal costs (7b).
    Affine,
    /// Sub-linear power law or logarithmic — decreasing marginals (7c).
    Concave,
    /// Random tabulated values — arbitrary (possibly non-monotone).
    Tabulated,
}

/// All cost families, scenario-sweep order.
pub const ALL_FAMILIES: [Family; 4] =
    [Family::Convex, Family::Affine, Family::Concave, Family::Tabulated];

/// Sample one cost function of `family` valid on the domain `[0, t]`.
pub fn sample_cost(family: Family, t: usize, rng: &mut Rng) -> CostFn {
    match family {
        Family::Convex => CostFn::Quadratic {
            fixed: rng.range_f64(0.0, 2.0),
            a: rng.range_f64(0.01, 1.0),
            b: rng.range_f64(0.0, 3.0),
        },
        Family::Affine => CostFn::Affine {
            fixed: rng.range_f64(0.0, 2.0),
            per_task: rng.range_f64(0.1, 4.0),
        },
        Family::Concave => {
            if rng.bool(0.5) {
                CostFn::PowerLaw {
                    fixed: rng.range_f64(0.0, 1.0),
                    scale: rng.range_f64(0.3, 4.0),
                    exponent: rng.range_f64(0.2, 0.95),
                }
            } else {
                CostFn::Logarithmic {
                    fixed: rng.range_f64(0.0, 1.0),
                    scale: rng.range_f64(0.3, 4.0),
                }
            }
        }
        Family::Tabulated => {
            let mut values = vec![0.0];
            let mut acc = 0.0;
            for _ in 1..=t {
                acc += rng.range_f64(0.0, 3.0);
                // non-monotone wiggle allowed
                values.push((acc + rng.normal() * 0.5).max(0.0));
            }
            CostFn::Tabulated { first: 0, values }
        }
    }
}

/// Limit pattern imposed on a generated instance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LimitPattern {
    /// `U = T`, `L = 0` for everyone (paper §5.5's "without upper
    /// limits").
    Unlimited,
    /// `U = T` with random `L ∈ [0, T/2]`: still effectively unlimited
    /// after the §5.2 lower-limit removal (`U − L ≥ T − ΣL` always), so
    /// MarDecUn applies — this is the cell that exercises its
    /// remove/restore arithmetic with nonzero lowers.
    UnlimitedWithLower,
    /// Random `U ∈ [1, T]`, `L = 0`.
    UpperOnly,
    /// Random `U ∈ [1, T]`, random `L ∈ [0, U/2]`.
    Both,
    /// Lower limits sum to exactly `T`: every schedule is forced to
    /// `x = L` (the §5.2 transform degenerates to `T' = 0`).
    TightLower,
    /// `L = U` per device (loads pinned to a random composition of `T`);
    /// some devices may be pinned at 0.
    Pinned,
}

/// All limit patterns, scenario-sweep order.
pub const ALL_LIMIT_PATTERNS: [LimitPattern; 6] = [
    LimitPattern::Unlimited,
    LimitPattern::UnlimitedWithLower,
    LimitPattern::UpperOnly,
    LimitPattern::Both,
    LimitPattern::TightLower,
    LimitPattern::Pinned,
];

/// Duplication shape controlling the class structure.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DupShape {
    /// Each distinct spec replicated a random number of times.
    Random,
    /// One spec, many copies — the whole fleet is one class.
    SingleClass,
    /// Every spec unique — `k = n`, the dedup fast-path boundary.
    AllUnique,
}

/// All duplication shapes, scenario-sweep order.
pub const ALL_DUP_SHAPES: [DupShape; 3] =
    [DupShape::Random, DupShape::SingleClass, DupShape::AllUnique];

/// One reproducible generated case: scenario coordinates plus the
/// derivation seed. [`Case::build`] is a pure function of this value.
#[derive(Clone, Copy, Debug)]
pub struct Case {
    /// Seed for every random draw inside [`Case::build`].
    pub seed: u64,
    pub family: Family,
    pub limits: LimitPattern,
    pub dup: DupShape,
    /// Distinct device specs (≥ 1; ignored for `SingleClass`).
    pub distinct: usize,
    /// Maximum multiplicity per spec (≥ 1).
    pub max_dup: usize,
    /// Workload size `T` (≥ 2).
    pub t: usize,
}

/// Grow uppers uniformly until `Σ min(U, T) >= T` (uniform growth keeps
/// duplicated specs identical, preserving class structure).
fn repair_uppers(upper: &mut [usize], t: usize) {
    while upper.iter().map(|&u| u.min(t)).sum::<usize>() < t {
        for u in upper.iter_mut() {
            *u += 1;
        }
    }
}

impl Case {
    /// Materialize the instance (always valid: limits are repaired to
    /// feasibility after the pattern is imposed).
    pub fn build(&self) -> Instance {
        let mut rng = Rng::new(self.seed);
        let t = self.t.max(2);
        let copies: Vec<usize> = match self.dup {
            DupShape::SingleClass => vec![2 + rng.index(self.max_dup.max(2))],
            DupShape::AllUnique => vec![1; self.distinct.max(1)],
            DupShape::Random => (0..self.distinct.max(1))
                .map(|_| 1 + rng.index(self.max_dup.max(1)))
                .collect(),
        };
        let mut costs = Vec::new();
        let mut lower = Vec::new();
        let mut upper = Vec::new();
        for &m in &copies {
            let cost = sample_cost(self.family, t, &mut rng);
            let (l, u) = match self.limits {
                LimitPattern::Unlimited => (0, t),
                LimitPattern::UnlimitedWithLower => (rng.index(t / 2 + 1), t),
                LimitPattern::UpperOnly => (0, 1 + rng.index(t)),
                LimitPattern::Both => {
                    let u = 1 + rng.index(t);
                    (rng.index(u / 2 + 1), u)
                }
                LimitPattern::TightLower => {
                    let u = 1 + rng.index(t);
                    (rng.index(u + 1), u)
                }
                // Placeholder; the composition below overwrites both.
                LimitPattern::Pinned => (0, 0),
            };
            for _ in 0..m {
                costs.push(cost.clone());
                lower.push(l);
                upper.push(u);
            }
        }
        let n = costs.len();
        match self.limits {
            LimitPattern::Pinned => {
                // Pin every load **per spec** (copies share the value), so
                // pinned classes keep their multiplicity and dedup shapes
                // stay meaningful. Walk Σ mₛ·xₛ up to T in whole-spec
                // steps; the sub-multiplicity remainder tops up the first
                // `r` members of one spec (that spec splits into at most
                // two pinned classes).
                let k = copies.len();
                let mut x = vec![0usize; k];
                let mut r = t;
                let start = rng.index(k);
                loop {
                    let mut progressed = false;
                    for off in 0..k {
                        let s = (start + off) % k;
                        if x[s] < t && copies[s] <= r {
                            x[s] += 1;
                            r -= copies[s];
                            progressed = true;
                        }
                    }
                    if !progressed {
                        break;
                    }
                }
                // Per-device expansion (specs were pushed contiguously).
                let mut loads = Vec::with_capacity(n);
                for (s, &m) in copies.iter().enumerate() {
                    for _ in 0..m {
                        loads.push(x[s]);
                    }
                }
                if r > 0 {
                    // Some spec has headroom and multiplicity > r (else the
                    // loop above would have progressed); bump its first r
                    // members by one.
                    let mut off = 0usize;
                    for (s, &m) in copies.iter().enumerate() {
                        if x[s] < t && m > r {
                            for d in 0..r {
                                loads[off + d] += 1;
                            }
                            r = 0;
                            break;
                        }
                        off += m;
                    }
                    debug_assert_eq!(r, 0, "remainder spec must exist");
                }
                lower = loads.clone();
                upper = loads;
            }
            LimitPattern::TightLower => {
                repair_uppers(&mut upper, t);
                for (l, &u) in lower.iter_mut().zip(upper.iter()) {
                    *l = (*l).min(u);
                }
                // Force ΣL == T under the caps (round-robin: every full
                // cycle makes progress while capacity remains).
                let mut sum: usize = lower.iter().sum();
                let mut i = 0usize;
                while sum > t {
                    if lower[i % n] > 0 {
                        lower[i % n] -= 1;
                        sum -= 1;
                    }
                    i += 1;
                }
                while sum < t {
                    if lower[i % n] < upper[i % n].min(t) {
                        lower[i % n] += 1;
                        sum += 1;
                    }
                    i += 1;
                }
            }
            _ => {
                // Classic feasibility repair (same shape the historical
                // per-test generators used).
                let mut i = 0usize;
                while lower.iter().sum::<usize>() > t {
                    if lower[i % n] > 0 {
                        lower[i % n] -= 1;
                    }
                    i += 1;
                }
                repair_uppers(&mut upper, t);
            }
        }
        Instance::new(t, lower, upper, costs).expect("generated instance is valid")
    }
}

/// [`Gen`] over [`Case`]s for one scenario cell; shrinking walks toward
/// fewer specs / smaller workloads / weaker duplication.
#[derive(Clone, Copy, Debug)]
pub struct CaseGen {
    pub family: Family,
    pub limits: LimitPattern,
    pub dup: DupShape,
    pub max_distinct: usize,
    pub max_dup: usize,
    pub max_t: usize,
}

impl Gen<Case> for CaseGen {
    fn generate(&self, rng: &mut Rng) -> Case {
        Case {
            seed: rng.next_u64(),
            family: self.family,
            limits: self.limits,
            dup: self.dup,
            distinct: 1 + rng.index(self.max_distinct.max(1)),
            max_dup: self.max_dup.max(1),
            t: 2 + rng.index(self.max_t.max(3) - 2),
        }
    }

    fn shrink(&self, c: &Case) -> Vec<Case> {
        let mut out = Vec::new();
        if c.distinct > 1 {
            out.push(Case { distinct: c.distinct - 1, ..*c });
        }
        if c.t > 2 {
            out.push(Case { t: c.t / 2, ..*c });
            out.push(Case { t: c.t - 1, ..*c });
        }
        if c.max_dup > 1 {
            out.push(Case { max_dup: 1, ..*c });
        }
        out
    }
}

/// A prime shard count that does not divide `n` — the
/// degenerate-remainder partition the shard tests must cover.
pub fn coprime_shards(n: usize) -> usize {
    for p in [3usize, 5, 7, 11, 13] {
        if n % p != 0 {
            return p;
        }
    }
    17
}

fn assert_fleet_bits_equal(
    a: &FleetInstance,
    b: &FleetInstance,
    what: &str,
) -> Result<(), String> {
    if a.digest() != b.digest() {
        return Err(format!("{what}: digest mismatch"));
    }
    if a.n_classes() != b.n_classes() || a.n_devices() != b.n_devices() {
        return Err(format!(
            "{what}: shape mismatch ({}/{} classes, {}/{} devices)",
            a.n_classes(),
            b.n_classes(),
            a.n_devices(),
            b.n_devices()
        ));
    }
    for (i, (ca, cb)) in a.classes().iter().zip(b.classes()).enumerate() {
        if ca.cost != cb.cost
            || ca.lower != cb.lower
            || ca.upper != cb.upper
            || ca.members != cb.members
        {
            return Err(format!("{what}: class {i} differs"));
        }
    }
    Ok(())
}

/// The differential oracle: prove shard ≡ class ≡ flat for one solver on
/// one instance (see the module docs for the exact contract). `seed`
/// feeds the same RNG stream into every path so seeded solvers (the
/// `random` baseline) must reproduce bit-for-bit.
pub fn check_shard_class_flat(
    inst: &Instance,
    name: &str,
    shard_counts: &[usize],
    seed: u64,
) -> Result<(), String> {
    let registry = SolverRegistry::with_defaults(seed);
    let solver = registry.resolve(name).map_err(|e| e.to_string())?;
    let fleet = FleetInstance::from_flat(inst).map_err(|e| e.to_string())?;

    // (a) Structural: every sharded build is bit-identical to from_flat.
    let mut sharded: Vec<FleetInstance> = Vec::with_capacity(shard_counts.len());
    for &s in shard_counts {
        let (built, stats) = shard::build_sharded(inst, s)
            .map_err(|e| format!("build_sharded({s}): {e}"))?;
        if stats.shards != s.max(1) {
            return Err(format!(
                "build_sharded({s}): reported {} shards",
                stats.shards
            ));
        }
        assert_fleet_bits_equal(&built, &fleet, &format!("shards={s}"))?;
        sharded.push(built);
    }

    // (b)+(c)+(d) Behavioral.
    let stream = seed ^ 0x5EED;
    let flat_res = solver.solve_flat_with_rng(inst, &mut Rng::new(stream));
    let class_res = solver.solve_with_rng(&fleet, &mut Rng::new(stream));
    match (flat_res, class_res) {
        (Err(_), Err(_)) => {
            // Error parity: every sharded path must reject too.
            for (built, &s) in sharded.iter().zip(shard_counts) {
                if solver.solve_with_rng(built, &mut Rng::new(stream)).is_ok() {
                    return Err(format!(
                        "{name}: sharded fleet (shards={s}) solved an \
                         instance both other paths reject"
                    ));
                }
            }
            Ok(())
        }
        (Ok(_), Err(e)) => {
            Err(format!("{name}: class path failed where flat solved: {e}"))
        }
        (Err(e), Ok(_)) => {
            Err(format!("{name}: flat path failed where class solved: {e}"))
        }
        (Ok(flat_sched), Ok(asg)) => {
            validate::check(inst, &flat_sched)
                .map_err(|e| format!("{name}: flat infeasible: {e}"))?;
            asg.check(&fleet)
                .map_err(|e| format!("{name}: class-infeasible: {e}"))?;
            let expanded = asg.expand(&fleet);
            validate::check(inst, &expanded)
                .map_err(|e| format!("{name}: expansion infeasible: {e}"))?;
            let c_flat = validate::total_cost(inst, &flat_sched);
            let c_class = validate::total_cost(inst, &expanded);
            if solver.class_aware() {
                // Class-aware cores may permute interchangeable devices;
                // the contract is cost equality.
                let tol = 1e-9 * c_flat.abs().max(1.0);
                if (c_flat - c_class).abs() > tol {
                    return Err(format!(
                        "{name}: class cost {c_class} != flat cost {c_flat}"
                    ));
                }
            } else {
                // Flat-delegating adapters go through the identical code
                // on the identical bits: schedule and cost bits must match.
                if expanded != flat_sched {
                    return Err(format!(
                        "{name}: class expansion differs from the flat \
                         schedule on a flat-delegating solver"
                    ));
                }
                if c_class.to_bits() != c_flat.to_bits() {
                    return Err(format!(
                        "{name}: cost bits differ on a flat-delegating solver"
                    ));
                }
            }
            // Sharded ≡ class: identical input bits through identical code
            // must give identical assignment and cost bits.
            let c_asg = asg.total_cost(&fleet);
            for (built, &s) in sharded.iter().zip(shard_counts) {
                let asg_s = solver
                    .solve_with_rng(built, &mut Rng::new(stream))
                    .map_err(|e| {
                        format!("{name}: sharded (shards={s}) failed: {e}")
                    })?;
                if asg_s != asg {
                    return Err(format!(
                        "{name}: sharded assignment (shards={s}) differs \
                         from the class assignment"
                    ));
                }
                if asg_s.total_cost(built).to_bits() != c_asg.to_bits() {
                    return Err(format!(
                        "{name}: sharded cost bits (shards={s}) differ"
                    ));
                }
                if asg_s.expand(built) != expanded {
                    return Err(format!(
                        "{name}: sharded expansion (shards={s}) differs"
                    ));
                }
            }
            Ok(())
        }
    }
}

/// Round-over-round fleet mutation shape driven by
/// [`check_incremental_churn`] — each models one way a real campaign
/// dirties the persistent class index
/// ([`crate::sched::incremental::FleetIndex`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChurnPattern {
    /// The *selection* changes every round but no signature does: the
    /// index must re-derive correct instances for arbitrary subsets with
    /// an empty dirty set.
    AvailabilityFlip,
    /// A few devices per round halve their upper limit toward zero
    /// (battery drain through death) — the classic recosting dirty set.
    BatteryDeath,
    /// Each device independently re-scales its cost with probability
    /// `pct`% per round (the coordinator's drift recosting).
    DriftP {
        /// Per-device per-round mutation probability, percent.
        pct: u8,
    },
    /// One device per round toggles between retired (upper forced to 0,
    /// out of the selection) and re-joined (original upper restored) —
    /// classes retire and their recycled ids must never leak.
    JoinRetire,
}

/// All churn patterns, scenario-sweep order (`DriftP` at the paper-shaped
/// ≤ a-few-percent rate; the fuzz sweeps vary the rate further).
pub const ALL_CHURN_PATTERNS: [ChurnPattern; 4] = [
    ChurnPattern::AvailabilityFlip,
    ChurnPattern::BatteryDeath,
    ChurnPattern::DriftP { pct: 5 },
    ChurnPattern::JoinRetire,
];

/// A reproducible multi-round churn scenario over a generated base fleet.
/// Like [`Case`], a pure value: the whole mutation script derives from
/// `base.seed`.
#[derive(Clone, Copy, Debug)]
pub struct ChurnCase {
    /// Base fleet (costs, limits, class structure) at round 0.
    pub base: Case,
    pub pattern: ChurnPattern,
    /// Churn rounds to script.
    pub rounds: usize,
    /// Round-transform share cap fed to [`RoundParams`] (1.0 = off).
    pub max_share: f64,
    /// Config-level per-device participation floor.
    pub min_tasks: usize,
}

/// The incremental differential oracle: script `case.rounds` rounds of
/// churn over one base fleet, and at every round prove that the
/// persistent index's mark → apply → derive path emits a
/// [`FleetInstance`] **bit-identical** (digest, class order, members,
/// limits, workload, relaxation flag) to [`from_scratch_round`] over the
/// same signatures and selection — then solve both with `solver_name` on
/// one RNG stream and require identical assignment and cost bits (error
/// parity when the solver rejects).
pub fn check_incremental_churn(
    case: &ChurnCase,
    solver_name: &str,
) -> Result<(), String> {
    let registry = SolverRegistry::with_defaults(case.base.seed);
    let solver = registry.resolve(solver_name).map_err(|e| e.to_string())?;
    let inst = case.base.build();
    let n = inst.n();

    // Signature state the script evolves: drift weights over the base
    // costs, decaying uppers, and a retired/active flag per device.
    let base_costs = inst.costs.clone();
    let lowers = inst.lower.clone();
    let mut weights = vec![1.0f64; n];
    let mut uppers = inst.upper.clone();
    let mut active = vec![true; n];
    let sig_of = |ws: &[f64], us: &[usize], d: usize| -> (CostFn, usize, usize) {
        let cost = if ws[d] == 1.0 {
            base_costs[d].clone()
        } else {
            CostFn::Scaled { weight: ws[d], inner: Box::new(base_costs[d].clone()) }
        };
        (cost, lowers[d], us[d])
    };

    let mut ix = FleetIndex::build(n, |d| sig_of(&weights, &uppers, d));
    let mut rng = Rng::new(case.base.seed ^ 0xC407);
    let p = RoundParams {
        tasks: inst.tasks,
        min_tasks: case.min_tasks,
        max_share: case.max_share,
    };

    for round in 0..case.rounds {
        // 1. Mutate signatures per the pattern, marking every change.
        match case.pattern {
            ChurnPattern::AvailabilityFlip => {}
            ChurnPattern::BatteryDeath => {
                for _ in 0..1 + rng.index((n / 8).max(1)) {
                    let d = rng.index(n);
                    if uppers[d] > 0 {
                        uppers[d] /= 2;
                        ix.mark(d);
                    }
                }
            }
            ChurnPattern::DriftP { pct } => {
                for d in 0..n {
                    if rng.bool(f64::from(pct) / 100.0) {
                        weights[d] *= if rng.bool(0.5) { 1.25 } else { 0.8 };
                        ix.mark(d);
                    }
                }
            }
            ChurnPattern::JoinRetire => {
                let d = rng.index(n);
                active[d] = !active[d];
                uppers[d] = if active[d] { inst.upper[d] } else { 0 };
                ix.mark(d);
            }
        }

        // 2. Pick this round's selection.
        let selected: Vec<usize> = match case.pattern {
            ChurnPattern::AvailabilityFlip => {
                let mut s: Vec<usize> =
                    (0..n).filter(|_| rng.bool(0.75)).collect();
                if s.is_empty() {
                    s.push(rng.index(n));
                }
                s
            }
            ChurnPattern::JoinRetire => {
                (0..n).filter(|&d| active[d]).collect()
            }
            _ => (0..n).collect(),
        };
        if selected.is_empty() {
            continue;
        }

        // 3. Incremental path vs the from-scratch oracle.
        ix.apply(|d| sig_of(&weights, &uppers, d));
        let mut relaxed_inc = false;
        let mut relaxed_scratch = false;
        let inc =
            ix.derive(&selected, &p, &mut relaxed_inc).map_err(|e| e.to_string())?;
        let scratch = from_scratch_round(
            |d| sig_of(&weights, &uppers, d),
            &selected,
            &p,
            &mut relaxed_scratch,
        )
        .map_err(|e| e.to_string())?;
        let (fleet_inc, fleet_scratch, t_inc, t_scratch) = match (inc, scratch) {
            (None, None) => continue,
            (Some(_), None) | (None, Some(_)) => {
                return Err(format!(
                    "{case:?} round {round}: exhaustion disagreement"
                ));
            }
            (Some((a, ta)), Some((b, tb))) => (a, b, ta, tb),
        };
        if t_inc != t_scratch {
            return Err(format!(
                "{case:?} round {round}: workload {t_inc} != {t_scratch}"
            ));
        }
        if relaxed_inc != relaxed_scratch {
            return Err(format!(
                "{case:?} round {round}: relaxation flags diverge"
            ));
        }
        assert_fleet_bits_equal(
            &fleet_inc,
            &fleet_scratch,
            &format!("{:?} round {round}", case.pattern),
        )?;

        // 4. Per-solver zero divergence on the emitted instances.
        let stream = case.base.seed ^ 0x1A1A ^ (round as u64).wrapping_mul(0xD1);
        let res_inc = solver.solve_with_rng(&fleet_inc, &mut Rng::new(stream));
        let res_scratch =
            solver.solve_with_rng(&fleet_scratch, &mut Rng::new(stream));
        match (res_inc, res_scratch) {
            (Err(_), Err(_)) => {}
            (Ok(a), Ok(b)) => {
                if a != b {
                    return Err(format!(
                        "{solver_name}: assignments diverge at round {round} \
                         of {case:?}"
                    ));
                }
                let ca = a.total_cost(&fleet_inc);
                let cb = b.total_cost(&fleet_scratch);
                if ca.to_bits() != cb.to_bits() {
                    return Err(format!(
                        "{solver_name}: cost bits diverge at round {round} \
                         of {case:?}"
                    ));
                }
            }
            _ => {
                return Err(format!(
                    "{solver_name}: solve error parity broke at round \
                     {round} of {case:?}"
                ));
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Bi-objective (energy × time) axis: per-class time models and the
// deadline-constrained bruteforce oracle the pareto differential suite
// keys on.
// ---------------------------------------------------------------------------

/// Shape of a generated per-class completion-time model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TimeShape {
    /// Affine: fixed upload window plus constant seconds per task.
    Affine,
    /// Tabulated monotone table (random positive increments) — exercises
    /// the non-affine branch of the cap binary search.
    Tabulated,
}

/// All time-model shapes, scenario-sweep order.
pub const ALL_TIME_SHAPES: [TimeShape; 2] = [TimeShape::Affine, TimeShape::Tabulated];

/// Sample one time model per device such that devices in the same
/// scheduling class (equal `(cost, lower, upper)` signature) share a
/// model — the invariant [`crate::sched::pareto::BiFleet::from_flat`]
/// enforces. Deterministic in `(inst, shape, seed)`.
pub fn sample_time_models(inst: &Instance, shape: TimeShape, seed: u64) -> Vec<TimeModel> {
    let fleet = FleetInstance::from_flat(inst)
        .expect("sample_time_models requires a valid instance");
    let mut slots: Vec<Option<TimeModel>> = vec![None; inst.costs.len()];
    for (c, class) in fleet.classes().iter().enumerate() {
        let mut rng = Rng::new(seed ^ (c as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
        let model = match shape {
            TimeShape::Affine => {
                TimeModel::affine(rng.range_f64(0.05, 1.5), rng.range_f64(0.0, 3.0))
            }
            TimeShape::Tabulated => {
                let cap = class.upper.min(inst.tasks);
                let mut values = Vec::with_capacity(cap + 1);
                values.push(0.0);
                let mut total = 0.0;
                for _ in 1..=cap {
                    total += rng.range_f64(0.05, 1.0);
                    values.push(total);
                }
                TimeModel::from_cost(CostFn::Tabulated { first: 0, values })
            }
        };
        for &slot in &class.members {
            slots[slot] = Some(model.clone());
        }
    }
    slots
        .into_iter()
        .map(|m| m.expect("every slot belongs to a class"))
        .collect()
}

/// Deadline-constrained reference: cap every device at the largest load
/// finishing within `tau` (linear scan — no binary search to share bugs
/// with), then exhaustively solve the capped instance. Returns the
/// optimal schedule and its energy on the *original* costs, or `None`
/// when no feasible schedule meets the deadline. Exponential — keep
/// `n`/`T` tiny.
pub fn constrained_bruteforce(
    inst: &Instance,
    times: &[TimeModel],
    tau: f64,
) -> Option<(Schedule, f64)> {
    let n = inst.costs.len();
    let mut upper = Vec::with_capacity(n);
    let mut room = 0usize;
    for i in 0..n {
        if times[i].seconds(inst.lower[i]) > tau {
            return None; // forced minimum already busts the deadline
        }
        let mut u = inst.lower[i];
        while u < inst.cap(i) && times[i].seconds(u + 1) <= tau {
            u += 1;
        }
        upper.push(u);
        room = room.saturating_add(u);
    }
    if room < inst.tasks.max(1) {
        return None;
    }
    let capped =
        Instance::new(inst.tasks, inst.lower.clone(), upper, inst.costs.clone()).ok()?;
    let sched = bruteforce::solve(&capped).ok()?;
    let energy = validate::total_cost(inst, &sched);
    Some((sched, energy))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generated_cases_are_always_valid() {
        for (fi, &family) in ALL_FAMILIES.iter().enumerate() {
            for (li, &limits) in ALL_LIMIT_PATTERNS.iter().enumerate() {
                for (di, &dup) in ALL_DUP_SHAPES.iter().enumerate() {
                    for rep in 0..5u64 {
                        let case = Case {
                            seed: 0xCA5E
                                ^ ((fi as u64) << 8)
                                ^ ((li as u64) << 16)
                                ^ ((di as u64) << 24)
                                ^ rep,
                            family,
                            limits,
                            dup,
                            distinct: 3,
                            max_dup: 3,
                            t: 3 + (rep as usize) * 2,
                        };
                        let inst = case.build();
                        inst.validate().unwrap_or_else(|e| {
                            panic!("invalid instance from {case:?}: {e}")
                        });
                    }
                }
            }
        }
    }

    #[test]
    fn build_is_a_pure_function_of_the_case() {
        let case = Case {
            seed: 0xF00D,
            family: Family::Tabulated,
            limits: LimitPattern::Both,
            dup: DupShape::Random,
            distinct: 3,
            max_dup: 3,
            t: 9,
        };
        let a = case.build();
        let b = case.build();
        assert_eq!(a.tasks, b.tasks);
        assert_eq!(a.lower, b.lower);
        assert_eq!(a.upper, b.upper);
        assert_eq!(a.costs, b.costs);
    }

    #[test]
    fn tight_lower_forces_the_whole_schedule() {
        for seed in 0..10u64 {
            let case = Case {
                seed,
                family: Family::Affine,
                limits: LimitPattern::TightLower,
                dup: DupShape::Random,
                distinct: 3,
                max_dup: 2,
                t: 8,
            };
            let inst = case.build();
            assert_eq!(inst.lower.iter().sum::<usize>(), inst.tasks);
        }
    }

    #[test]
    fn pinned_fixes_every_load() {
        for seed in 20..30u64 {
            let case = Case {
                seed,
                family: Family::Concave,
                limits: LimitPattern::Pinned,
                dup: DupShape::Random,
                distinct: 3,
                max_dup: 2,
                t: 7,
            };
            let inst = case.build();
            assert_eq!(inst.lower, inst.upper);
            assert_eq!(inst.lower.iter().sum::<usize>(), inst.tasks);
        }
    }

    #[test]
    fn pinned_single_class_keeps_multiplicity() {
        // Per-spec pinning: a single-spec fleet splits into at most two
        // pinned classes (base load + a one-task remainder run), so the
        // Pinned × SingleClass cell genuinely exercises multiplicity > 1.
        let mut saw_multiplicity = false;
        for seed in 0..20u64 {
            let case = Case {
                seed,
                family: Family::Affine,
                limits: LimitPattern::Pinned,
                dup: DupShape::SingleClass,
                distinct: 1,
                max_dup: 4,
                t: 9,
            };
            let fleet = FleetInstance::from_flat(&case.build()).unwrap();
            assert!(fleet.n_classes() <= 2, "{} classes", fleet.n_classes());
            if fleet.classes().iter().any(|c| c.members.len() > 1) {
                saw_multiplicity = true;
            }
        }
        assert!(saw_multiplicity, "pinned single-class never deduped");
    }

    #[test]
    fn unlimited_with_lower_keeps_mardecun_applicable() {
        use crate::sched::mardecun;
        let mut saw_lower = false;
        for seed in 0..15u64 {
            let case = Case {
                seed,
                family: Family::Concave,
                limits: LimitPattern::UnlimitedWithLower,
                dup: DupShape::Random,
                distinct: 3,
                max_dup: 2,
                t: 10,
            };
            let inst = case.build();
            saw_lower |= inst.lower.iter().any(|&l| l > 0);
            // Effectively unlimited after the §5.2 transform: MarDecUn
            // must solve, not reject.
            mardecun::solve(&inst).unwrap_or_else(|e| {
                panic!("mardecun rejected an unlimited-with-lower case: {e}")
            });
        }
        assert!(saw_lower, "pattern never produced a nonzero lower limit");
    }

    #[test]
    fn dup_shapes_control_the_class_structure() {
        let base = Case {
            seed: 42,
            family: Family::Affine,
            limits: LimitPattern::UpperOnly,
            dup: DupShape::SingleClass,
            distinct: 4,
            max_dup: 4,
            t: 10,
        };
        let single = FleetInstance::from_flat(&base.build()).unwrap();
        assert_eq!(single.n_classes(), 1, "SingleClass must dedup to one");
        assert!(single.n_devices() >= 2);

        let unique = Case { dup: DupShape::AllUnique, ..base };
        let f = FleetInstance::from_flat(&unique.build()).unwrap();
        assert_eq!(f.n_classes(), f.n_devices(), "AllUnique must not dedup");
    }

    #[test]
    fn coprime_shards_never_divides() {
        for n in 1..200usize {
            let p = coprime_shards(n);
            assert!(n % p != 0, "{p} divides {n}");
        }
    }

    #[test]
    fn harness_passes_on_a_known_good_solver_and_catches_divergence() {
        let case = Case {
            seed: 7,
            family: Family::Affine,
            limits: LimitPattern::Both,
            dup: DupShape::Random,
            distinct: 3,
            max_dup: 3,
            t: 9,
        };
        let inst = case.build();
        let n = inst.n();
        for name in ["uniform", "marco", "auto", "random"] {
            check_shard_class_flat(
                &inst,
                name,
                &[1, n, coprime_shards(n), n + 3],
                case.seed,
            )
            .unwrap_or_else(|e| panic!("{e}"));
        }
        assert!(
            check_shard_class_flat(&inst, "no-such-solver", &[1], 7).is_err()
        );
    }

    #[test]
    fn churn_checker_passes_every_pattern() {
        let base = Case {
            seed: 0xC0FFEE,
            family: Family::Affine,
            limits: LimitPattern::Both,
            dup: DupShape::Random,
            distinct: 4,
            max_dup: 3,
            t: 12,
        };
        for (i, &pattern) in ALL_CHURN_PATTERNS.iter().enumerate() {
            let case = ChurnCase {
                base: Case { seed: base.seed ^ (i as u64) << 4, ..base },
                pattern,
                rounds: 6,
                max_share: 1.0,
                min_tasks: 0,
            };
            for solver in ["uniform", "marco", "auto", "random"] {
                check_incremental_churn(&case, solver)
                    .unwrap_or_else(|e| panic!("{e}"));
            }
        }
        let bad = ChurnCase {
            base,
            pattern: ChurnPattern::BatteryDeath,
            rounds: 2,
            max_share: 1.0,
            min_tasks: 0,
        };
        assert!(check_incremental_churn(&bad, "no-such-solver").is_err());
    }

    #[test]
    fn churn_checker_exercises_the_share_cap_and_min_tasks() {
        // max_share < 1 engages the round transform's cap doubling (the
        // raw-class *merge* case: distinct uppers clipped to one cap);
        // nonzero min_tasks engages the joined lower stage. Both must
        // stay bit-for-bit under heavy drift.
        let base = Case {
            seed: 0xCAB,
            family: Family::Convex,
            limits: LimitPattern::UpperOnly,
            dup: DupShape::Random,
            distinct: 3,
            max_dup: 3,
            t: 10,
        };
        let case = ChurnCase {
            base,
            pattern: ChurnPattern::DriftP { pct: 40 },
            rounds: 5,
            max_share: 0.3,
            min_tasks: 1,
        };
        check_incremental_churn(&case, "auto").unwrap_or_else(|e| panic!("{e}"));
    }

    #[test]
    fn time_models_are_class_consistent_and_oracle_respects_caps() {
        let case = Case {
            seed: 0x71AE,
            family: Family::Affine,
            limits: LimitPattern::Both,
            dup: DupShape::Random,
            distinct: 3,
            max_dup: 2,
            t: 8,
        };
        let inst = case.build();
        let fleet = FleetInstance::from_flat(&inst).unwrap();
        for &shape in &ALL_TIME_SHAPES {
            let times = sample_time_models(&inst, shape, 0xBEEF);
            assert_eq!(times.len(), inst.costs.len());
            // Same class → identical model (the BiFleet::from_flat invariant).
            for class in fleet.classes() {
                let first = &times[class.members[0]];
                for &m in &class.members {
                    assert_eq!(&times[m], first, "{shape:?}: class model split");
                }
            }
            // A huge deadline constrains nothing: the oracle must find a
            // feasible schedule whose per-device completion times all fit.
            let (sched, energy) =
                constrained_bruteforce(&inst, &times, 1e9).expect("loose tau feasible");
            validate::check(&inst, &sched).unwrap();
            assert!((energy - validate::total_cost(&inst, &sched)).abs() < 1e-12);
            for (i, &x) in sched.assignments().iter().enumerate() {
                assert!(times[i].seconds(x) <= 1e9);
            }
            // An impossible deadline is an explicit None, not a bogus schedule.
            assert!(constrained_bruteforce(&inst, &times, -1.0).is_none());
        }
    }
}
