//! The participant registry: who is connected, how fresh, and where in
//! the per-round lifecycle.
//!
//! Time is a logical tick counter advanced by the service pump — no
//! wall clock anywhere, so campaigns replay bit-identically. Liveness
//! is `clock - last_seen <= expiry_ticks`; expiry is evaluated at round
//! boundaries only (inside [`ParticipantRegistry::begin_round`]), so a
//! participant that was live when the round started cannot vanish
//! mid-round — within a round, the deadline governs.
//!
//! Invariants the property tests pin (`tests/svc_equivalence.rs`):
//! an expired participant is never in `Selected`/`Training`, a report
//! is accepted at most once per (device, round), and an accepted report
//! is never dropped by a later registry event.

use std::collections::{BTreeMap, BTreeSet};

use super::protocol::{ClientId, ParticipantPhase};

/// One connected participant: the client identity currently bound to a
/// device, its round phase, and when it was last heard from.
#[derive(Clone, Debug)]
pub struct Participant {
    /// Current client binding (rejoin replaces it).
    pub client: ClientId,
    /// Per-round lifecycle phase.
    pub phase: ParticipantPhase,
    /// Logical tick of the last message from this client.
    pub last_seen: u64,
}

/// Outcome of a rendezvous.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Joined {
    /// The device had no registry entry.
    New,
    /// The device was already registered; the new client supersedes the
    /// old binding (reconnect after churn or expiry).
    Rejoin,
}

/// Outcome of a report, decided by the registry's phase machine. The
/// service maps everything but `Accepted` to a [`super::protocol::RejectReason`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReportVerdict {
    /// First report from a `Training` participant for the served round.
    Accepted,
    /// No participant bound to this (client, device) pair.
    Unknown,
    /// The report named a round other than the one being served.
    WrongRound,
    /// The participant already reported this round.
    Duplicate,
    /// The participant never fetched its slice this round.
    NotTraining,
}

/// What [`ParticipantRegistry::begin_round`] did.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RoundStart {
    /// Stale participants removed at the boundary.
    pub expired: usize,
    /// Scheduled devices with a live participant at round start (the
    /// rest must rejoin mid-round or miss the deadline).
    pub connected: usize,
}

/// What [`ParticipantRegistry::finish_round`] observed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RoundEnd {
    /// Participants that reached `Done`.
    pub reported: usize,
    /// Participants still in `Selected`/`Training` at the deadline.
    pub stragglers: usize,
}

/// Connected-participant table keyed by device id, with heartbeat
/// expiry, rejoin, and the Standby→Selected→Training→Done round cycle.
#[derive(Debug, Default)]
pub struct ParticipantRegistry {
    by_device: BTreeMap<usize, Participant>,
    /// Devices scheduled in the round being served.
    selected: BTreeSet<usize>,
    round: usize,
    expiry_ticks: u64,
    clock: u64,
}

impl ParticipantRegistry {
    /// New empty registry with the given heartbeat expiry.
    pub fn new(expiry_ticks: u64) -> Self {
        ParticipantRegistry {
            expiry_ticks,
            ..ParticipantRegistry::default()
        }
    }

    /// Current logical tick.
    pub fn clock(&self) -> u64 {
        self.clock
    }

    /// Heartbeat expiry in ticks (advertised in `Welcome`).
    pub fn expiry_ticks(&self) -> u64 {
        self.expiry_ticks
    }

    /// The round currently being served.
    pub fn round(&self) -> usize {
        self.round
    }

    /// Advance the logical clock one tick.
    pub fn advance(&mut self) {
        self.clock += 1;
    }

    /// Connected participants.
    pub fn len(&self) -> usize {
        self.by_device.len()
    }

    /// Whether no participant is connected.
    pub fn is_empty(&self) -> bool {
        self.by_device.is_empty()
    }

    /// Look up the participant bound to a device.
    pub fn participant(&self, device_id: usize) -> Option<&Participant> {
        self.by_device.get(&device_id)
    }

    /// Iterate all participants (tests and stats).
    pub fn participants(&self) -> impl Iterator<Item = (usize, &Participant)> {
        self.by_device.iter().map(|(&d, p)| (d, p))
    }

    fn is_fresh(&self, p: &Participant) -> bool {
        self.clock.saturating_sub(p.last_seen) <= self.expiry_ticks
    }

    /// Bind `client` to `device_id`. An existing binding is replaced —
    /// that is the rejoin path, and it resets the phase to `Standby` so
    /// a reconnecting device re-earns selection through a heartbeat —
    /// with one exception: `Done` survives the rebind. A round's
    /// accepted report belongs to the *device*, not the connection that
    /// delivered it; preserving `Done` is what makes a
    /// report-then-rejoin interleaving unable to double-report.
    pub fn rendezvous(&mut self, client: ClientId, device_id: usize) -> Joined {
        let (phase, joined) = match self.by_device.get(&device_id) {
            Some(p) if p.phase == ParticipantPhase::Done => {
                (ParticipantPhase::Done, Joined::Rejoin)
            }
            Some(_) => (ParticipantPhase::Standby, Joined::Rejoin),
            None => (ParticipantPhase::Standby, Joined::New),
        };
        self.by_device.insert(
            device_id,
            Participant {
                client,
                phase,
                last_seen: self.clock,
            },
        );
        joined
    }

    /// Start serving `round` for the given scheduled devices: expire
    /// stale participants first (the only expiry point — so nothing
    /// selected below can already be expired), then promote live
    /// scheduled participants `Standby → Selected`. Devices that
    /// reconnect later in the round are promoted lazily by
    /// [`ParticipantRegistry::heartbeat`].
    pub fn begin_round(&mut self, round: usize, devices: &[usize]) -> RoundStart {
        let clock = self.clock;
        let expiry = self.expiry_ticks;
        let before = self.by_device.len();
        self.by_device
            .retain(|_, p| clock.saturating_sub(p.last_seen) <= expiry);
        let expired = before - self.by_device.len();

        self.round = round;
        self.selected = devices.iter().copied().collect();
        let mut connected = 0;
        for (d, p) in self.by_device.iter_mut() {
            debug_assert_eq!(p.phase, ParticipantPhase::Standby);
            if self.selected.contains(d) {
                p.phase = ParticipantPhase::Selected;
                connected += 1;
            }
        }
        RoundStart { expired, connected }
    }

    /// Record a liveness ping; returns the participant's phase and the
    /// served round, or `None` for an unknown or superseded client. A
    /// scheduled participant still in `Standby` (it rejoined after round
    /// start) is promoted to `Selected` here — it is live by
    /// construction, preserving the no-expired-selection invariant.
    pub fn heartbeat(
        &mut self,
        client: ClientId,
        device_id: usize,
    ) -> Option<(ParticipantPhase, usize)> {
        let scheduled = self.selected.contains(&device_id);
        let clock = self.clock;
        let round = self.round;
        let p = self.by_device.get_mut(&device_id)?;
        if p.client != client {
            return None;
        }
        p.last_seen = clock;
        if scheduled && p.phase == ParticipantPhase::Standby {
            p.phase = ParticipantPhase::Selected;
        }
        Some((p.phase, round))
    }

    /// Hand out the slice: `Selected → Training`. Idempotent for a
    /// participant already `Training` (a retried fetch gets the slice
    /// again); refused for any other phase, a stale round, or a
    /// superseded client.
    pub fn fetch(&mut self, client: ClientId, device_id: usize, round: usize) -> bool {
        if round != self.round {
            return false;
        }
        let clock = self.clock;
        let Some(p) = self.by_device.get_mut(&device_id) else {
            return false;
        };
        if p.client != client {
            return false;
        }
        match p.phase {
            ParticipantPhase::Selected | ParticipantPhase::Training => {
                p.last_seen = clock;
                p.phase = ParticipantPhase::Training;
                true
            }
            ParticipantPhase::Standby | ParticipantPhase::Done => false,
        }
    }

    /// Accept or refuse a report: `Training → Done` exactly once per
    /// (device, round). A live client's stale-round report still counts
    /// as liveness (the device is demonstrably up) but is refused.
    pub fn report(&mut self, client: ClientId, device_id: usize, round: usize) -> ReportVerdict {
        let clock = self.clock;
        let served = self.round;
        let Some(p) = self.by_device.get_mut(&device_id) else {
            return ReportVerdict::Unknown;
        };
        if p.client != client {
            return ReportVerdict::Unknown;
        }
        p.last_seen = clock;
        if round != served {
            return ReportVerdict::WrongRound;
        }
        match p.phase {
            ParticipantPhase::Training => {
                p.phase = ParticipantPhase::Done;
                ReportVerdict::Accepted
            }
            ParticipantPhase::Done => ReportVerdict::Duplicate,
            ParticipantPhase::Standby | ParticipantPhase::Selected => ReportVerdict::NotTraining,
        }
    }

    /// Close the round: count who reported vs. who straggled, then
    /// return every participant to `Standby` and clear the selection.
    pub fn finish_round(&mut self) -> RoundEnd {
        let mut end = RoundEnd::default();
        for p in self.by_device.values_mut() {
            match p.phase {
                ParticipantPhase::Done => end.reported += 1,
                ParticipantPhase::Selected | ParticipantPhase::Training => end.stragglers += 1,
                ParticipantPhase::Standby => {}
            }
            p.phase = ParticipantPhase::Standby;
        }
        self.selected.clear();
        end
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_happy_path() {
        let mut reg = ParticipantRegistry::new(10);
        assert_eq!(reg.rendezvous(1, 0), Joined::New);
        let start = reg.begin_round(0, &[0]);
        assert_eq!(start.connected, 1);
        assert_eq!(start.expired, 0);
        assert_eq!(
            reg.heartbeat(1, 0),
            Some((ParticipantPhase::Selected, 0))
        );
        assert!(reg.fetch(1, 0, 0));
        assert_eq!(reg.report(1, 0, 0), ReportVerdict::Accepted);
        assert_eq!(reg.report(1, 0, 0), ReportVerdict::Duplicate);
        let end = reg.finish_round();
        assert_eq!((end.reported, end.stragglers), (1, 0));
        assert_eq!(
            reg.participant(0).map(|p| p.phase),
            Some(ParticipantPhase::Standby)
        );
    }

    #[test]
    fn expiry_removes_and_rejoin_rebinds() {
        let mut reg = ParticipantRegistry::new(2);
        reg.rendezvous(1, 0);
        for _ in 0..3 {
            reg.advance();
        }
        let start = reg.begin_round(0, &[0]);
        assert_eq!(start.expired, 1);
        assert_eq!(start.connected, 0);
        assert!(reg.is_empty());
        // Rejoin mid-round under a new client id: lazily selected at
        // the next heartbeat, then the normal path works.
        assert_eq!(reg.rendezvous(2, 0), Joined::New); // entry was gone
        assert_eq!(
            reg.heartbeat(2, 0),
            Some((ParticipantPhase::Selected, 0))
        );
        assert!(reg.fetch(2, 0, 0));
        assert_eq!(reg.report(2, 0, 0), ReportVerdict::Accepted);
    }

    #[test]
    fn superseded_client_is_refused() {
        let mut reg = ParticipantRegistry::new(10);
        reg.rendezvous(1, 0);
        assert_eq!(reg.rendezvous(2, 0), Joined::Rejoin);
        assert_eq!(reg.heartbeat(1, 0), None);
        assert_eq!(reg.report(1, 0, 0), ReportVerdict::Unknown);
        assert!(reg.heartbeat(2, 0).is_some());
    }

    #[test]
    fn unselected_participant_cannot_fetch_or_report() {
        let mut reg = ParticipantRegistry::new(10);
        reg.rendezvous(1, 0);
        reg.rendezvous(2, 1);
        reg.begin_round(0, &[0]);
        assert_eq!(
            reg.heartbeat(2, 1),
            Some((ParticipantPhase::Standby, 0))
        );
        assert!(!reg.fetch(2, 1, 0));
        assert_eq!(reg.report(2, 1, 0), ReportVerdict::NotTraining);
    }

    #[test]
    fn rejoin_after_reporting_cannot_double_report() {
        let mut reg = ParticipantRegistry::new(10);
        reg.rendezvous(1, 0);
        reg.begin_round(0, &[0]);
        assert!(reg.fetch(1, 0, 0));
        assert_eq!(reg.report(1, 0, 0), ReportVerdict::Accepted);
        // Churn: the device drops and rejoins mid-round as client 2.
        assert_eq!(reg.rendezvous(2, 0), Joined::Rejoin);
        // `Done` survived the rebind: no re-selection, no second accept.
        assert_eq!(reg.heartbeat(2, 0), Some((ParticipantPhase::Done, 0)));
        assert!(!reg.fetch(2, 0, 0));
        assert_eq!(reg.report(2, 0, 0), ReportVerdict::Duplicate);
        assert_eq!(reg.finish_round().reported, 1);
    }

    #[test]
    fn stale_round_messages_are_refused_but_count_as_liveness() {
        let mut reg = ParticipantRegistry::new(4);
        reg.rendezvous(1, 0);
        reg.begin_round(0, &[0]);
        assert!(reg.fetch(1, 0, 0));
        reg.finish_round();
        for _ in 0..3 {
            reg.advance();
        }
        reg.begin_round(1, &[0]);
        assert!(!reg.fetch(1, 0, 0));
        assert_eq!(reg.report(1, 0, 0), ReportVerdict::WrongRound);
        // The stale report refreshed liveness: no expiry next boundary.
        for _ in 0..4 {
            reg.advance();
        }
        assert_eq!(reg.finish_round().stragglers, 1);
        assert_eq!(reg.begin_round(2, &[0]).expired, 0);
    }
}
