//! Transport seam + the in-memory loopback implementation.
//!
//! The service is transport-agnostic: [`super::ServiceBackend`] talks
//! only to the [`Transport`] trait — drain requests, deliver replies,
//! tick the far side. [`Loopback`] is the shipped implementation: a
//! pair of in-memory queues (one client→server FIFO, per-client reply
//! inboxes) with a pluggable [`ClientDriver`] as the far side. The pump
//! is single-threaded and frames drain in arrival order, so a loopback
//! campaign is bit-deterministic — the property the digest-equivalence
//! tests and the CI service-smoke leg rely on.

use std::collections::{BTreeMap, VecDeque};

use super::protocol::ClientId;

/// The in-memory channel pair a [`ClientDriver`] sees: send frames up,
/// receive frames addressed to a client. Byte counters feed the
/// `svc_bytes_*` stats and the wire-payload bench assertion.
#[derive(Debug, Default)]
pub struct Wire {
    requests: VecDeque<String>,
    inboxes: BTreeMap<ClientId, VecDeque<String>>,
    bytes_up: u64,
    bytes_down: u64,
}

impl Wire {
    /// Client side: send an encoded request frame to the coordinator.
    pub fn send(&mut self, frame: String) {
        self.bytes_up += frame.len() as u64;
        self.requests.push_back(frame);
    }

    /// Client side: drain every frame addressed to `client`.
    pub fn recv(&mut self, client: ClientId) -> Vec<String> {
        match self.inboxes.get_mut(&client) {
            Some(inbox) => inbox.drain(..).collect(),
            None => Vec::new(),
        }
    }

    /// Server side: deliver an encoded reply frame to a client inbox.
    pub fn deliver(&mut self, client: ClientId, frame: String) {
        self.bytes_down += frame.len() as u64;
        self.inboxes.entry(client).or_default().push_back(frame);
    }

    /// Server side: drain every queued request, in arrival order.
    pub fn drain_requests(&mut self) -> Vec<String> {
        self.requests.drain(..).collect()
    }

    /// `(client→server, server→client)` bytes carried so far.
    pub fn bytes(&self) -> (u64, u64) {
        (self.bytes_up, self.bytes_down)
    }
}

/// The far side of a loopback wire: owns the client population and
/// advances it one logical tick at a time. Implementations must be
/// deterministic functions of `(their own state, now, inbox contents)` —
/// no wall clock, no thread timing — or replay breaks.
pub trait ClientDriver {
    /// Advance every client one tick at logical time `now`: read reply
    /// frames from the inboxes, update local state, send new requests.
    fn tick(&mut self, now: u64, wire: &mut Wire);
}

/// Server-side transport handle: what [`super::ServiceBackend`] pumps.
pub trait Transport {
    /// Advance the far side one logical tick.
    fn tick(&mut self, now: u64);
    /// Drain every queued client→server frame, in arrival order.
    fn drain_requests(&mut self) -> Vec<String>;
    /// Deliver a server→client frame.
    fn deliver(&mut self, client: ClientId, frame: String);
    /// `(client→server, server→client)` bytes carried so far.
    fn bytes(&self) -> (u64, u64);
}

/// In-memory transport: a [`Wire`] with a [`ClientDriver`] attached.
#[derive(Debug)]
pub struct Loopback<D> {
    wire: Wire,
    driver: D,
}

impl<D: ClientDriver> Loopback<D> {
    /// Wrap a client driver in a fresh wire.
    pub fn new(driver: D) -> Self {
        Loopback {
            wire: Wire::default(),
            driver,
        }
    }

    /// The attached driver (stats and tests).
    pub fn driver(&self) -> &D {
        &self.driver
    }

    /// Mutable driver access (reseeding between campaigns).
    pub fn driver_mut(&mut self) -> &mut D {
        &mut self.driver
    }
}

impl<D: ClientDriver> Transport for Loopback<D> {
    fn tick(&mut self, now: u64) {
        self.driver.tick(now, &mut self.wire);
    }

    fn drain_requests(&mut self) -> Vec<String> {
        self.wire.drain_requests()
    }

    fn deliver(&mut self, client: ClientId, frame: String) {
        self.wire.deliver(client, frame);
    }

    fn bytes(&self) -> (u64, u64) {
        self.wire.bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Echo {
        sent: usize,
    }

    impl ClientDriver for Echo {
        fn tick(&mut self, now: u64, wire: &mut Wire) {
            for frame in wire.recv(7) {
                assert!(frame.starts_with("pong"));
            }
            wire.send(format!("ping {now} #{}", self.sent));
            self.sent += 1;
        }
    }

    #[test]
    fn frames_flow_in_fifo_order_and_bytes_are_counted() {
        let mut lb = Loopback::new(Echo { sent: 0 });
        lb.tick(1);
        lb.tick(2);
        let frames = lb.drain_requests();
        assert_eq!(frames, vec!["ping 1 #0".to_string(), "ping 2 #1".to_string()]);
        lb.deliver(7, "pong".into());
        lb.tick(3); // driver consumes the pong without complaint
        let (up, down) = lb.bytes();
        assert_eq!(up as usize, "ping 1 #0".len() + "ping 2 #1".len() + "ping 3 #2".len());
        assert_eq!(down as usize, "pong".len());
        assert_eq!(lb.driver().sent, 3);
    }
}
