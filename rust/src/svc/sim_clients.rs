//! Simulated client fleet — the loopback wire's far side.
//!
//! Each device is driven by a tiny per-client state machine that speaks
//! the full protocol: rendezvous, periodic heartbeats, slice fetch on
//! selection, local "training" (evaluating the slice's cost function,
//! exactly what [`crate::coordinator::SimBackend`] computes in-process),
//! and the energy/loss report. All behavior — join stagger, heartbeat
//! phase, straggler jitter, deadline misses, post-report churn — is a
//! pure FNV hash of `(seed, salt, round, device)`, never of the wall
//! clock, so a campaign killed and resumed replays the same fleet
//! behavior bit-for-bit (the CI service-smoke leg depends on this).
//!
//! Churn (`churn_permille`) disconnects a client *after* its accepted
//! report and rejoins it under a new client id a couple of ticks later:
//! it exercises rejoin/expiry without perturbing round outcomes, so a
//! churned campaign stays digest-equal to the in-process reference.
//! Misses (`miss_permille`) drop the report outright: hard stragglers,
//! partial rounds — digests then deliberately diverge from the
//! full-participation reference but remain reproducible. A missed round
//! leaves no residue in the client (it idles and heartbeats on), so
//! fleet behavior in round `r+1` never depends on what round `r` did —
//! the memorylessness that makes a killed-and-resumed campaign replay
//! the original outcome set exactly.

use crate::util::hash::{mix_u64, FNV_OFFSET};

use super::loopback::{ClientDriver, Wire};
use super::protocol::{ClientId, ParticipantPhase, Protocol, RejectReason, Reply};

/// Hash-decision salts (arbitrary, fixed: they only need to differ).
const SALT_JOIN: u64 = 0x1001;
const SALT_HB: u64 = 0x1002;
const SALT_DELAY: u64 = 0x1003;
const SALT_MISS: u64 = 0x1004;
const SALT_CHURN: u64 = 0x1005;

/// Deterministic per-(round, device) decision value.
fn decision(seed: u64, salt: u64, round: usize, device: usize) -> u64 {
    let mut h = mix_u64(FNV_OFFSET, seed);
    h = mix_u64(h, salt);
    h = mix_u64(h, round as u64);
    h = mix_u64(h, device as u64);
    h
}

/// Client ids encode `(generation, device)` so a rejoined device comes
/// back as a distinguishable connection.
fn client_id(generation: u32, device_id: usize) -> ClientId {
    ((generation as u64) << 40) | (device_id as u64)
}

/// Fleet behavior knobs. Defaults match the service defaults in
/// [`super::ServiceConfig`]: worst-case turnaround (join + heartbeat
/// discovery + fetch + `max_delay`) stays under the 32-tick deadline,
/// and the churn gap exceeds the 12-tick expiry when rounds run long.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SimClientsConfig {
    /// Seed for every hash decision.
    pub seed: u64,
    /// Permille of (device, round) pairs that disconnect after their
    /// accepted report and rejoin shortly after (digest-neutral).
    pub churn_permille: u32,
    /// Permille of (device, round) pairs whose report is dropped
    /// outright (hard stragglers; partial rounds).
    pub miss_permille: u32,
    /// Heartbeat period in ticks while idle.
    pub heartbeat_every: u64,
    /// Max straggler jitter added before a report is sent, in ticks.
    pub max_delay: u64,
    /// Ticks a churned client stays offline before re-rendezvousing.
    pub rejoin_delay: u64,
}

impl Default for SimClientsConfig {
    fn default() -> Self {
        SimClientsConfig {
            seed: 0,
            churn_permille: 0,
            miss_permille: 0,
            heartbeat_every: 8,
            max_delay: 8,
            rejoin_delay: 2,
        }
    }
}

/// Per-client connection state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum CState {
    /// Disconnected; will rendezvous at `wake_at`.
    Offline,
    /// Rendezvous sent, waiting for `Welcome`.
    Joining,
    /// Connected, heartbeating, available for selection.
    Idle,
    /// `FetchSlice` sent, waiting for the slice.
    Fetching,
    /// Slice in hand; report fires at `wake_at`.
    Training,
    /// Report sent, waiting for the ack.
    AwaitAck,
}

/// A computed local result awaiting (or surviving a refused) report.
#[derive(Clone, Copy, Debug)]
struct PendingReport {
    round: usize,
    tasks: usize,
    energy_j: f64,
    mean_loss: f64,
}

#[derive(Debug)]
struct Client {
    device_id: usize,
    generation: u32,
    client: ClientId,
    state: CState,
    wake_at: u64,
    hb_offset: u64,
    result: Option<PendingReport>,
}

fn send_heartbeat(c: &Client, wire: &mut Wire) {
    wire.send(
        Protocol::Heartbeat {
            client: c.client,
            device_id: c.device_id,
        }
        .encode(),
    );
}

/// The whole simulated fleet: one [`Client`] per device, advanced in
/// device order every tick (deterministic).
#[derive(Debug)]
pub struct SimFleet {
    cfg: SimClientsConfig,
    clients: Vec<Client>,
}

impl SimFleet {
    /// One client per device id, joining within the first few ticks.
    pub fn new(device_ids: Vec<usize>, cfg: SimClientsConfig) -> Self {
        let clients = device_ids
            .into_iter()
            .map(|device_id| Client {
                device_id,
                generation: 1,
                client: client_id(1, device_id),
                state: CState::Offline,
                wake_at: decision(cfg.seed, SALT_JOIN, 0, device_id) % 3,
                hb_offset: decision(cfg.seed, SALT_HB, 0, device_id) % cfg.heartbeat_every.max(1),
                result: None,
            })
            .collect();
        SimFleet { cfg, clients }
    }

    /// The fleet configuration.
    pub fn cfg(&self) -> &SimClientsConfig {
        &self.cfg
    }

    /// Number of simulated clients.
    pub fn len(&self) -> usize {
        self.clients.len()
    }

    /// Whether the fleet is empty.
    pub fn is_empty(&self) -> bool {
        self.clients.is_empty()
    }

    /// Total rejoins performed so far (generations beyond the first).
    pub fn rejoin_count(&self) -> u64 {
        self.clients
            .iter()
            .map(|c| (c.generation - 1) as u64)
            .sum()
    }
}

impl ClientDriver for SimFleet {
    fn tick(&mut self, now: u64, wire: &mut Wire) {
        let cfg = self.cfg;
        for c in &mut self.clients {
            // 1. Consume replies. Frames addressed to superseded client
            //    ids are never read — a churned identity is gone.
            for frame in wire.recv(c.client) {
                let Ok(reply) = Reply::decode(&frame) else {
                    continue;
                };
                match reply {
                    Reply::Welcome { .. } => {
                        if c.state == CState::Joining {
                            c.state = CState::Idle;
                            // Probe immediately: selection discovery
                            // should not wait a full heartbeat period.
                            send_heartbeat(c, wire);
                        }
                    }
                    Reply::Beat { phase, round } => {
                        if c.state == CState::Idle && phase == ParticipantPhase::Selected {
                            wire.send(
                                Protocol::FetchSlice {
                                    client: c.client,
                                    device_id: c.device_id,
                                    round,
                                }
                                .encode(),
                            );
                            c.state = CState::Fetching;
                        }
                    }
                    Reply::Slice(s) => {
                        if c.state == CState::Fetching {
                            let miss = cfg.miss_permille > 0
                                && decision(cfg.seed, SALT_MISS, s.round, c.device_id) % 1000
                                    < cfg.miss_permille as u64;
                            if miss {
                                // Hard straggler: the report never
                                // fires. Return to idle at once so the
                                // miss leaves no cross-round residue —
                                // heartbeats keep the registration
                                // alive and round r+1 proceeds exactly
                                // as if round r had completed.
                                c.state = CState::Idle;
                                continue;
                            }
                            // "Local training": evaluate the slice's
                            // drift-inclusive cost — the same bits the
                            // in-process SimBackend would produce.
                            let energy_j = s.cost.eval(s.tasks);
                            let mean_loss = 1.0 / (1.0 + s.model_version as f64);
                            c.wake_at = now
                                + decision(cfg.seed, SALT_DELAY, s.round, c.device_id)
                                    % (cfg.max_delay + 1);
                            c.result = Some(PendingReport {
                                round: s.round,
                                tasks: s.tasks,
                                energy_j,
                                mean_loss,
                            });
                            c.state = CState::Training;
                        }
                    }
                    Reply::Accepted => {
                        if c.state == CState::AwaitAck {
                            let round = c.result.take().map(|r| r.round).unwrap_or(0);
                            let churn = cfg.churn_permille > 0
                                && decision(cfg.seed, SALT_CHURN, round, c.device_id) % 1000
                                    < cfg.churn_permille as u64;
                            if churn {
                                c.generation += 1;
                                c.client = client_id(c.generation, c.device_id);
                                c.state = CState::Offline;
                                c.wake_at = now + cfg.rejoin_delay;
                            } else {
                                c.state = CState::Idle;
                            }
                        }
                    }
                    Reply::Rejected { reason } => {
                        // Recovery: drop stale work; an `Unknown` means
                        // the registry expired us — re-rendezvous.
                        c.result = None;
                        if reason == RejectReason::Unknown {
                            c.state = CState::Offline;
                            c.wake_at = now + 1;
                        } else if c.state != CState::Offline && c.state != CState::Joining {
                            c.state = CState::Idle;
                            send_heartbeat(c, wire);
                        }
                    }
                }
            }

            // 2. Act on the current state.
            match c.state {
                CState::Offline if now >= c.wake_at => {
                    wire.send(
                        Protocol::Rendezvous {
                            client: c.client,
                            device_id: c.device_id,
                        }
                        .encode(),
                    );
                    c.state = CState::Joining;
                }
                CState::Idle
                    if (now + c.hb_offset) % cfg.heartbeat_every.max(1) == 0 =>
                {
                    send_heartbeat(c, wire);
                }
                CState::Training if now >= c.wake_at => {
                    if let Some(r) = c.result {
                        wire.send(
                            Protocol::ReportResult {
                                client: c.client,
                                device_id: c.device_id,
                                round: r.round,
                                tasks: r.tasks,
                                energy_j: r.energy_j,
                                sim_time_s: 0.0,
                                mean_loss: r.mean_loss,
                            }
                            .encode(),
                        );
                        c.state = CState::AwaitAck;
                    } else {
                        // Defensive: no result to send — re-idle.
                        c.state = CState::Idle;
                    }
                }
                _ => {}
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decisions_are_deterministic_and_salt_sensitive() {
        let a = decision(7, SALT_MISS, 3, 41);
        assert_eq!(a, decision(7, SALT_MISS, 3, 41));
        assert_ne!(a, decision(7, SALT_CHURN, 3, 41));
        assert_ne!(a, decision(8, SALT_MISS, 3, 41));
    }

    #[test]
    fn client_ids_separate_generation_and_device() {
        assert_ne!(client_id(1, 5), client_id(2, 5));
        assert_ne!(client_id(1, 5), client_id(1, 6));
        assert_eq!(client_id(1, 5) & 0xFF_FFFF_FFFF, 5);
    }
}
