//! The networked coordinator service: the round loop served over a
//! wire instead of a function call.
//!
//! Layers, innermost out:
//!
//! - [`protocol`] — the four-request/five-reply message set
//!   ([`Protocol`], [`Reply`]) as single-line JSON frames; the schedule
//!   payload is one run-length [`ScheduleSlice`] per device (one class
//!   cost + scalars — O(classes) on the wire, never O(devices)).
//! - [`registry`] — connected participants with heartbeat expiry,
//!   rejoin, and the per-round Standby→Selected→Training→Done cycle
//!   ([`ParticipantRegistry`]), on a logical tick clock.
//! - [`loopback`] — the [`Transport`] seam plus the shipped in-memory
//!   implementation ([`Loopback`] + [`Wire`]) with a pluggable
//!   [`ClientDriver`] far side.
//! - [`backend`] — [`ServiceBackend`]`: RoundBackend`: `train(plan)`
//!   pumps the transport until every scheduled device reported or the
//!   tick deadline lapsed, then returns outcomes in assignment order
//!   (absentees simply missing — the partial-round shape the
//!   coordinator already journals deterministically).
//! - [`sim_clients`] — [`SimFleet`], a deterministic simulated client
//!   population (hash-driven join stagger, heartbeats, straggler
//!   jitter, deadline misses, post-report churn) that drives 10⁵–10⁶
//!   clients through the full protocol.
//!
//! The whole stack is wall-clock-free and single-threaded, so a
//! networked campaign with churn is *digest-identical* to the
//! in-process [`crate::coordinator::SimBackend`] reference on the same
//! fleet (proven at this level below, at store level in
//! `tests/svc_equivalence.rs`, and across SIGKILL in the CI
//! service-smoke leg).

pub mod backend;
pub mod loopback;
pub mod protocol;
pub mod registry;
pub mod sim_clients;

pub use backend::{ServiceBackend, ServiceConfig};
pub use loopback::{ClientDriver, Loopback, Transport, Wire};
pub use protocol::{ClientId, ParticipantPhase, Protocol, RejectReason, Reply, ScheduleSlice};
pub use registry::{Joined, Participant, ParticipantRegistry, ReportVerdict};
pub use sim_clients::{SimClientsConfig, SimFleet};

/// The shipped loopback service: simulated fleet behind the in-memory
/// transport — what `train --transport loopback` and the benches run.
pub type LoopbackService = ServiceBackend<Loopback<SimFleet>>;

/// Wire a simulated fleet for the given device ids into a loopback
/// service.
pub fn loopback_service(
    svc: ServiceConfig,
    sim: SimClientsConfig,
    device_ids: Vec<usize>,
) -> LoopbackService {
    ServiceBackend::new(svc, Loopback::new(SimFleet::new(device_ids, sim)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{
        Assignment, BackendState, RoundBackend, RoundPlan, SimBackend,
    };
    use crate::sched::costs::CostFn;
    use crate::sched::instance::{Instance, Schedule};

    fn plan(round: usize) -> RoundPlan {
        let inst = Instance::new(
            6,
            vec![0, 0, 0],
            vec![4, 4, 4],
            vec![
                CostFn::Affine { fixed: 0.5, per_task: 2.0 },
                CostFn::Quadratic { fixed: 0.7, a: 0.3, b: 1.1 },
                CostFn::Affine { fixed: 0.0, per_task: 5.0 },
            ],
        )
        .unwrap();
        RoundPlan {
            round,
            schedule: Schedule::new(vec![3, 2, 1]),
            assignments: vec![
                Assignment { slot: 0, device: 0, device_id: 10, tasks: 3, energy_scale: 1.0 },
                Assignment { slot: 1, device: 1, device_id: 11, tasks: 2, energy_scale: 1.0 },
                Assignment { slot: 2, device: 2, device_id: 12, tasks: 1, energy_scale: 1.0 },
            ],
            instance: inst,
        }
    }

    fn assert_same_outcomes(
        a: &[crate::coordinator::DeviceOutcome],
        b: &[crate::coordinator::DeviceOutcome],
    ) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.device_id, y.device_id);
            assert_eq!(x.device, y.device);
            assert_eq!(x.tasks, y.tasks);
            assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits());
            assert_eq!(x.sim_time_s.to_bits(), y.sim_time_s.to_bits());
            assert_eq!(x.mean_loss.to_bits(), y.mean_loss.to_bits());
        }
    }

    #[test]
    fn served_round_is_bit_identical_to_sim_backend() {
        let mut sim = SimBackend::new();
        let mut svc = loopback_service(
            ServiceConfig::default(),
            SimClientsConfig { seed: 42, churn_permille: 1000, ..SimClientsConfig::default() },
            vec![10, 11, 12],
        );
        for round in 0..4 {
            let p = plan(round);
            let reference = sim.train(&p).unwrap();
            let served = svc.train(&p).unwrap();
            assert_same_outcomes(&reference, &served);
            sim.aggregate().unwrap();
            svc.aggregate().unwrap();
            assert_eq!(
                sim.evaluate().unwrap().to_bits(),
                svc.evaluate().unwrap().to_bits()
            );
        }
        // Churn actually happened (rejoins observed) yet outcomes
        // stayed identical — churn is digest-neutral by construction.
        assert!(svc.stats().counter("svc_rejoins") > 0, "churn never fired");
        assert_eq!(svc.stats().counter("svc_stragglers"), 0);
    }

    #[test]
    fn missed_deadlines_yield_partial_rounds() {
        let mut svc = loopback_service(
            ServiceConfig::default(),
            SimClientsConfig { seed: 7, miss_permille: 1000, ..SimClientsConfig::default() },
            vec![10, 11, 12],
        );
        let served = svc.train(&plan(0)).unwrap();
        assert!(served.is_empty(), "every report was dropped");
        assert_eq!(svc.stats().counter("svc_partial_rounds"), 1);
        assert_eq!(svc.stats().counter("svc_stragglers"), 3);
        // A fully-missed round does not advance the model (mirrors
        // SimBackend's empty-pending rule).
        let before = svc.evaluate().unwrap();
        svc.aggregate().unwrap();
        assert_eq!(svc.evaluate().unwrap().to_bits(), before.to_bits());
    }

    #[test]
    fn state_roundtrip_matches_sim_backend_shape() {
        let mut svc = loopback_service(
            ServiceConfig::default(),
            SimClientsConfig::default(),
            vec![10, 11, 12],
        );
        svc.train(&plan(0)).unwrap();
        svc.aggregate().unwrap();
        let saved = svc.save_state();
        let mut sim = SimBackend::new();
        sim.load_state(&saved).unwrap();
        assert_eq!(
            sim.evaluate().unwrap().to_bits(),
            svc.evaluate().unwrap().to_bits(),
            "service state is interchangeable with the sim backend's"
        );
        let mut fresh = loopback_service(
            ServiceConfig::default(),
            SimClientsConfig::default(),
            vec![10, 11, 12],
        );
        fresh.load_state(&saved).unwrap();
        // The resumed service re-serves rounds from a cold registry:
        // clients re-rendezvous and the next round still completes.
        let served = fresh.train(&plan(1)).unwrap();
        assert_eq!(served.len(), 3);
    }

    #[test]
    fn slice_frames_do_not_grow_with_fleet_size() {
        let mut svc_small = loopback_service(
            ServiceConfig::default(),
            SimClientsConfig::default(),
            vec![10, 11, 12, 13],
        );
        svc_small.train(&plan(0)).unwrap();
        // The same three slices served out of a 4096-client fleet:
        // every extra client only rendezvouses and heartbeats; the
        // slice frame is unchanged.
        let mut ids: Vec<usize> = vec![10, 11, 12];
        ids.extend(100..4196usize);
        let mut svc_big = loopback_service(
            ServiceConfig::default(),
            SimClientsConfig::default(),
            ids,
        );
        svc_big.train(&plan(0)).unwrap();
        assert!(svc_small.max_slice_bytes() > 0);
        assert_eq!(
            svc_small.max_slice_bytes(),
            svc_big.max_slice_bytes(),
            "slice payload must be O(classes), independent of fleet size"
        );
    }
}
