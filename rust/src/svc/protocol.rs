//! The coordinator service wire protocol.
//!
//! Four client→coordinator messages ([`Protocol`]) and five replies
//! ([`Reply`]), encoded as single-line JSON frames. The schedule payload
//! ([`ScheduleSlice`]) is the run-length slice of the class-level
//! schedule owned by one device: *one* slot's drift-inclusive cost
//! function plus four scalars. Its size is O(classes) in the sense that
//! it names one class and carries one class cost — it never enumerates
//! devices, so the frame does not grow with fleet size (asserted by the
//! `fleet_scale` service scenario).
//!
//! Floats cross the wire through [`crate::store::jf`] — the same
//! NaN/∞-safe codec the snapshot layer uses — and cost functions through
//! [`crate::store::snapshot::costfn_to_json`], so a client-side
//! `cost.eval(tasks)` reproduces the coordinator-side energy bits
//! exactly. That exactness is what lets a networked campaign's journal
//! digest equal the in-process reference run.

use crate::error::{FedError, Result};
use crate::sched::costs::CostFn;
use crate::store::snapshot::{costfn_from_json, costfn_to_json};
use crate::store::{get, get_f64, get_str, get_u64, get_usize, jf, ju};
use crate::util::json::Json;

/// Opaque client connection identity. A device that disconnects and
/// rejoins comes back as a *new* client id bound to the same device id.
pub type ClientId = u64;

/// Per-round participant lifecycle, modeled on xaynet's coordinator
/// state machine: everyone idles in `Standby`; the round start promotes
/// scheduled, live participants to `Selected`; fetching the slice makes
/// them `Training`; an accepted report makes them `Done`; the round end
/// returns everyone to `Standby`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParticipantPhase {
    /// Connected, not part of the current round.
    Standby,
    /// Scheduled this round; slice not yet fetched.
    Selected,
    /// Slice fetched; result not yet reported.
    Training,
    /// Result accepted this round.
    Done,
}

impl ParticipantPhase {
    /// Stable wire name.
    pub fn as_str(self) -> &'static str {
        match self {
            ParticipantPhase::Standby => "standby",
            ParticipantPhase::Selected => "selected",
            ParticipantPhase::Training => "training",
            ParticipantPhase::Done => "done",
        }
    }

    /// Parse a wire name.
    pub fn parse(s: &str) -> Result<ParticipantPhase> {
        match s {
            "standby" => Ok(ParticipantPhase::Standby),
            "selected" => Ok(ParticipantPhase::Selected),
            "training" => Ok(ParticipantPhase::Training),
            "done" => Ok(ParticipantPhase::Done),
            other => Err(FedError::Config(format!("unknown phase '{other}'"))),
        }
    }
}

/// Why a request was turned down. Carried on [`Reply::Rejected`] so
/// clients can recover deterministically (a `WrongRound` report means
/// "drop it and re-poll", not "retry").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// No live participant bound to this (client, device) pair —
    /// expired or superseded by a rejoin; re-rendezvous.
    Unknown,
    /// FetchSlice from a participant the round did not select.
    NotSelected,
    /// The message named a round other than the one being served
    /// (a straggler report that missed the deadline lands here).
    WrongRound,
    /// A second report for a device that already reported this round.
    Duplicate,
    /// The reported task count does not match the assigned slice.
    TaskMismatch,
}

impl RejectReason {
    /// Stable wire name.
    pub fn as_str(self) -> &'static str {
        match self {
            RejectReason::Unknown => "unknown",
            RejectReason::NotSelected => "not-selected",
            RejectReason::WrongRound => "wrong-round",
            RejectReason::Duplicate => "duplicate",
            RejectReason::TaskMismatch => "task-mismatch",
        }
    }

    /// Parse a wire name.
    pub fn parse(s: &str) -> Result<RejectReason> {
        match s {
            "unknown" => Ok(RejectReason::Unknown),
            "not-selected" => Ok(RejectReason::NotSelected),
            "wrong-round" => Ok(RejectReason::WrongRound),
            "duplicate" => Ok(RejectReason::Duplicate),
            "task-mismatch" => Ok(RejectReason::TaskMismatch),
            other => Err(FedError::Config(format!("unknown reject reason '{other}'"))),
        }
    }
}

/// One device's run-length slice of the class-level schedule: the slot
/// (class) it belongs to, its task count, and the slot's current
/// drift-inclusive cost function. The client evaluates `cost.eval(tasks)`
/// for its measured energy and derives its loss proxy from
/// `model_version` — bit-identical to what the in-process `SimBackend`
/// computes, which is the digest-equivalence contract.
#[derive(Clone, Debug, PartialEq)]
pub struct ScheduleSlice {
    /// Round this slice belongs to.
    pub round: usize,
    /// The device the slice is addressed to.
    pub device_id: usize,
    /// Class slot in the round's deduplicated instance.
    pub slot: usize,
    /// Local training workload (number of tasks).
    pub tasks: usize,
    /// Aggregation count of the global model the client trains from.
    pub model_version: usize,
    /// Drift-inclusive cost of the device's class this round.
    pub cost: CostFn,
}

/// Client → coordinator messages.
#[derive(Clone, Debug, PartialEq)]
pub enum Protocol {
    /// First contact (and re-contact after an expiry): `client` claims
    /// the fleet identity `device_id`.
    Rendezvous { client: ClientId, device_id: usize },
    /// Liveness ping; the ack carries the participant's current phase,
    /// which is also how a client discovers it was selected.
    Heartbeat { client: ClientId, device_id: usize },
    /// Request this round's [`ScheduleSlice`] (legal once a heartbeat
    /// ack reported `Selected`).
    FetchSlice {
        client: ClientId,
        device_id: usize,
        round: usize,
    },
    /// Report the trained result: measured energy and local loss.
    ReportResult {
        client: ClientId,
        device_id: usize,
        round: usize,
        tasks: usize,
        energy_j: f64,
        sim_time_s: f64,
        mean_loss: f64,
    },
}

impl Protocol {
    /// The sender, for reply routing.
    pub fn client(&self) -> ClientId {
        match *self {
            Protocol::Rendezvous { client, .. }
            | Protocol::Heartbeat { client, .. }
            | Protocol::FetchSlice { client, .. }
            | Protocol::ReportResult { client, .. } => client,
        }
    }

    fn to_json(&self) -> Json {
        match self {
            Protocol::Rendezvous { client, device_id } => Json::obj(vec![
                ("t", Json::Str("rendezvous".into())),
                ("client", ju(*client)),
                ("device", Json::Num(*device_id as f64)),
            ]),
            Protocol::Heartbeat { client, device_id } => Json::obj(vec![
                ("t", Json::Str("heartbeat".into())),
                ("client", ju(*client)),
                ("device", Json::Num(*device_id as f64)),
            ]),
            Protocol::FetchSlice {
                client,
                device_id,
                round,
            } => Json::obj(vec![
                ("t", Json::Str("fetch".into())),
                ("client", ju(*client)),
                ("device", Json::Num(*device_id as f64)),
                ("round", Json::Num(*round as f64)),
            ]),
            Protocol::ReportResult {
                client,
                device_id,
                round,
                tasks,
                energy_j,
                sim_time_s,
                mean_loss,
            } => Json::obj(vec![
                ("t", Json::Str("report".into())),
                ("client", ju(*client)),
                ("device", Json::Num(*device_id as f64)),
                ("round", Json::Num(*round as f64)),
                ("tasks", Json::Num(*tasks as f64)),
                ("energy_j", jf(*energy_j)),
                ("sim_time_s", jf(*sim_time_s)),
                ("mean_loss", jf(*mean_loss)),
            ]),
        }
    }

    fn from_json(v: &Json) -> Result<Protocol> {
        let client = get_u64(v, "client")?;
        let device_id = get_usize(v, "device")?;
        match get_str(v, "t")? {
            "rendezvous" => Ok(Protocol::Rendezvous { client, device_id }),
            "heartbeat" => Ok(Protocol::Heartbeat { client, device_id }),
            "fetch" => Ok(Protocol::FetchSlice {
                client,
                device_id,
                round: get_usize(v, "round")?,
            }),
            "report" => Ok(Protocol::ReportResult {
                client,
                device_id,
                round: get_usize(v, "round")?,
                tasks: get_usize(v, "tasks")?,
                energy_j: get_f64(v, "energy_j")?,
                sim_time_s: get_f64(v, "sim_time_s")?,
                mean_loss: get_f64(v, "mean_loss")?,
            }),
            other => Err(FedError::Config(format!("unknown request kind '{other}'"))),
        }
    }

    /// Encode as a single-line wire frame.
    pub fn encode(&self) -> String {
        self.to_json().to_string()
    }

    /// Decode a wire frame.
    pub fn decode(frame: &str) -> Result<Protocol> {
        Protocol::from_json(&Json::parse(frame)?)
    }
}

/// Coordinator → client replies.
#[derive(Clone, Debug, PartialEq)]
pub enum Reply {
    /// Rendezvous accepted; heartbeat at least every `expiry_ticks`
    /// logical ticks or be expired from the registry.
    Welcome { expiry_ticks: u64 },
    /// Heartbeat ack: the participant's phase and the round being
    /// served.
    Beat {
        phase: ParticipantPhase,
        round: usize,
    },
    /// The requested schedule slice.
    Slice(ScheduleSlice),
    /// Report accepted — the device's energy/loss is in this round.
    Accepted,
    /// Request turned down; see [`RejectReason`].
    Rejected { reason: RejectReason },
}

impl Reply {
    fn to_json(&self) -> Json {
        match self {
            Reply::Welcome { expiry_ticks } => Json::obj(vec![
                ("t", Json::Str("welcome".into())),
                ("expiry_ticks", Json::Num(*expiry_ticks as f64)),
            ]),
            Reply::Beat { phase, round } => Json::obj(vec![
                ("t", Json::Str("beat".into())),
                ("phase", Json::Str(phase.as_str().into())),
                ("round", Json::Num(*round as f64)),
            ]),
            Reply::Slice(s) => Json::obj(vec![
                ("t", Json::Str("slice".into())),
                ("round", Json::Num(s.round as f64)),
                ("device", Json::Num(s.device_id as f64)),
                ("slot", Json::Num(s.slot as f64)),
                ("tasks", Json::Num(s.tasks as f64)),
                ("model_version", Json::Num(s.model_version as f64)),
                ("cost", costfn_to_json(&s.cost)),
            ]),
            Reply::Accepted => Json::obj(vec![("t", Json::Str("accepted".into()))]),
            Reply::Rejected { reason } => Json::obj(vec![
                ("t", Json::Str("rejected".into())),
                ("reason", Json::Str(reason.as_str().into())),
            ]),
        }
    }

    fn from_json(v: &Json) -> Result<Reply> {
        match get_str(v, "t")? {
            "welcome" => Ok(Reply::Welcome {
                expiry_ticks: get_u64(v, "expiry_ticks")?,
            }),
            "beat" => Ok(Reply::Beat {
                phase: ParticipantPhase::parse(get_str(v, "phase")?)?,
                round: get_usize(v, "round")?,
            }),
            "slice" => Ok(Reply::Slice(ScheduleSlice {
                round: get_usize(v, "round")?,
                device_id: get_usize(v, "device")?,
                slot: get_usize(v, "slot")?,
                tasks: get_usize(v, "tasks")?,
                model_version: get_usize(v, "model_version")?,
                cost: costfn_from_json(get(v, "cost")?)?,
            })),
            "accepted" => Ok(Reply::Accepted),
            "rejected" => Ok(Reply::Rejected {
                reason: RejectReason::parse(get_str(v, "reason")?)?,
            }),
            other => Err(FedError::Config(format!("unknown reply kind '{other}'"))),
        }
    }

    /// Encode as a single-line wire frame.
    pub fn encode(&self) -> String {
        self.to_json().to_string()
    }

    /// Decode a wire frame.
    pub fn decode(frame: &str) -> Result<Reply> {
        Reply::from_json(&Json::parse(frame)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_req(msg: Protocol) {
        let decoded = Protocol::decode(&msg.encode()).expect("decode");
        assert_eq!(decoded, msg);
    }

    fn roundtrip_reply(msg: Reply) {
        let decoded = Reply::decode(&msg.encode()).expect("decode");
        assert_eq!(decoded, msg);
    }

    #[test]
    fn requests_roundtrip() {
        roundtrip_req(Protocol::Rendezvous {
            client: u64::MAX,
            device_id: 7,
        });
        roundtrip_req(Protocol::Heartbeat {
            client: 3,
            device_id: 0,
        });
        roundtrip_req(Protocol::FetchSlice {
            client: 9,
            device_id: 4,
            round: 12,
        });
        roundtrip_req(Protocol::ReportResult {
            client: 0x1_0000_0001,
            device_id: 99_999,
            round: 3,
            tasks: 17,
            energy_j: 0.1 + 0.2, // non-representable sum must survive exactly
            sim_time_s: 0.0,
            mean_loss: 1.0 / 3.0,
        });
    }

    #[test]
    fn replies_roundtrip() {
        roundtrip_reply(Reply::Welcome { expiry_ticks: 12 });
        roundtrip_reply(Reply::Beat {
            phase: ParticipantPhase::Selected,
            round: 5,
        });
        roundtrip_reply(Reply::Slice(ScheduleSlice {
            round: 2,
            device_id: 41,
            slot: 3,
            tasks: 8,
            model_version: 2,
            cost: CostFn::Quadratic {
                fixed: 0.125,
                a: 0.25,
                b: 1.5,
            },
        }));
        roundtrip_reply(Reply::Accepted);
        roundtrip_reply(Reply::Rejected {
            reason: RejectReason::WrongRound,
        });
    }

    #[test]
    fn slice_cost_evaluates_identically_after_roundtrip() {
        let cost = CostFn::Quadratic {
            fixed: 5.3,
            a: 0.7,
            b: 0.31,
        };
        let slice = Reply::Slice(ScheduleSlice {
            round: 0,
            device_id: 0,
            slot: 0,
            tasks: 13,
            model_version: 0,
            cost: cost.clone(),
        });
        let decoded = Reply::decode(&slice.encode()).expect("decode");
        let Reply::Slice(s) = decoded else {
            panic!("wrong reply kind")
        };
        assert_eq!(s.cost.eval(13).to_bits(), cost.eval(13).to_bits());
    }

    #[test]
    fn malformed_frames_are_errors_not_panics() {
        assert!(Protocol::decode("not json").is_err());
        assert!(Protocol::decode("{\"t\":\"nope\",\"client\":\"0\",\"device\":1}").is_err());
        assert!(Reply::decode("{\"t\":\"beat\",\"phase\":\"bogus\",\"round\":0}").is_err());
        assert!(Reply::decode("{}").is_err());
    }
}
