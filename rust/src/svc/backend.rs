//! [`ServiceBackend`] — the networked [`RoundBackend`].
//!
//! `train(plan)` no longer computes outcomes in-process: it *serves*
//! the round over a [`Transport`]. Each pump tick advances the logical
//! clock, lets the far side act, then handles every queued frame —
//! rendezvous, heartbeats, slice fetches, reports — until either every
//! scheduled device has reported or the tick deadline lapses. Devices
//! that miss the deadline are simply absent from the returned outcome
//! vector, which is exactly the partial-round shape the coordinator
//! already handles (aggregation proceeds over reporters; absentees hit
//! the normal dropout/Recosting accounting), so journals, snapshots,
//! resume, and replay work unchanged.
//!
//! Digest-equivalence contract: when every report lands in time, the
//! outcome vector is bit-identical to the in-process
//! [`crate::coordinator::SimBackend`] on the same plan — same ordering
//! (assignment order), same energy bits (clients evaluate the slice's
//! drift-inclusive cost function, which round-trips the wire exactly),
//! same loss proxy (`1/(1+model_version)`). `aggregate`/`evaluate`/
//! [`BackendState`] mirror `SimBackend` too, so `--store`, `resume`,
//! and `replay` compose with the service layer for free.

use std::collections::BTreeMap;

use crate::coordinator::{BackendState, DeviceOutcome, RoundBackend, RoundPlan};
use crate::error::Result;
use crate::metrics::MetricsHub;
use crate::obs::{NoopTracer, Tracer};
use crate::store::get_usize;
use crate::util::json::Json;

use super::loopback::Transport;
use super::protocol::{Protocol, RejectReason, Reply, ScheduleSlice};
use super::registry::{Joined, ParticipantRegistry, ReportVerdict};

/// Service-layer knobs. Both are logical-tick counts — the service has
/// no wall clock, which is what keeps networked campaigns replayable.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServiceConfig {
    /// A participant unheard-from for more than this many ticks is
    /// expired at the next round boundary.
    pub expiry_ticks: u64,
    /// Report deadline per round, in pump ticks. Reports that miss it
    /// leave the round partial.
    pub deadline_ticks: u64,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        // Deadline comfortably above the worst-case client turnaround
        // (join + heartbeat + fetch + max straggler jitter); expiry
        // short enough that churned clients expire across a boundary.
        ServiceConfig {
            expiry_ticks: 12,
            deadline_ticks: 32,
        }
    }
}

/// A report accepted from the wire, pending round assembly.
#[derive(Clone, Copy, Debug)]
struct Report {
    tasks: usize,
    energy_j: f64,
    sim_time_s: f64,
    mean_loss: f64,
}

/// The networked round backend: participant registry + transport pump
/// bridging the coordinator's round loop to connected clients.
pub struct ServiceBackend<T: Transport> {
    transport: T,
    registry: ParticipantRegistry,
    cfg: ServiceConfig,
    /// Mirrors `SimBackend`: how many aggregations the global model has
    /// absorbed — the clients' loss proxy derives from it.
    rounds_aggregated: usize,
    /// Reports collected by the last Training phase, consumed by
    /// `aggregate`.
    pending: usize,
    stats: MetricsHub,
    tracer: Box<dyn Tracer>,
    max_slice_bytes: usize,
}

impl<T: Transport> ServiceBackend<T> {
    /// Wrap a transport in a fresh service.
    pub fn new(cfg: ServiceConfig, transport: T) -> Self {
        ServiceBackend {
            transport,
            registry: ParticipantRegistry::new(cfg.expiry_ticks),
            cfg,
            rounds_aggregated: 0,
            pending: 0,
            stats: MetricsHub::new(),
            tracer: Box::new(NoopTracer),
            max_slice_bytes: 0,
        }
    }

    /// Service counters (`svc_*`), independent of the coordinator's hub.
    pub fn stats(&self) -> &MetricsHub {
        &self.stats
    }

    /// The participant registry.
    pub fn registry(&self) -> &ParticipantRegistry {
        &self.registry
    }

    /// The transport (driver access in tests and benches).
    pub fn transport(&self) -> &T {
        &self.transport
    }

    /// Mutable transport access.
    pub fn transport_mut(&mut self) -> &mut T {
        &mut self.transport
    }

    /// The service configuration.
    pub fn service_cfg(&self) -> ServiceConfig {
        self.cfg
    }

    /// Largest encoded [`ScheduleSlice`] frame served so far — the
    /// quantity the `fleet_scale` bench pins to O(classes).
    pub fn max_slice_bytes(&self) -> usize {
        self.max_slice_bytes
    }

    /// Attach a tracer for `svc_*` spans (separate from the
    /// coordinator's tracer; same purity rule — tracing never feeds
    /// digests).
    pub fn set_tracer(&mut self, tracer: Box<dyn Tracer>) {
        self.tracer = tracer;
    }

    /// Flush the attached tracer.
    pub fn flush_trace(&mut self) -> Result<()> {
        self.tracer.flush()
    }

    fn handle(
        &mut self,
        msg: Protocol,
        plan: &RoundPlan,
        by_device: &BTreeMap<usize, usize>,
        reports: &mut BTreeMap<usize, Report>,
    ) -> Reply {
        match msg {
            Protocol::Rendezvous { client, device_id } => {
                match self.registry.rendezvous(client, device_id) {
                    Joined::New => self.stats.inc("svc_joins", 1),
                    Joined::Rejoin => self.stats.inc("svc_rejoins", 1),
                }
                Reply::Welcome {
                    expiry_ticks: self.registry.expiry_ticks(),
                }
            }
            Protocol::Heartbeat { client, device_id } => {
                self.stats.inc("svc_heartbeats", 1);
                match self.registry.heartbeat(client, device_id) {
                    Some((phase, round)) => Reply::Beat { phase, round },
                    None => Reply::Rejected {
                        reason: RejectReason::Unknown,
                    },
                }
            }
            Protocol::FetchSlice {
                client,
                device_id,
                round,
            } => {
                self.stats.inc("svc_fetches", 1);
                let assigned = by_device.get(&device_id).copied();
                match assigned {
                    Some(idx) if self.registry.fetch(client, device_id, round) => {
                        let a = &plan.assignments[idx];
                        Reply::Slice(ScheduleSlice {
                            round,
                            device_id,
                            slot: a.slot,
                            tasks: a.tasks,
                            model_version: self.rounds_aggregated,
                            cost: plan.instance.costs[a.slot].clone(),
                        })
                    }
                    Some(_) => Reply::Rejected {
                        reason: if round == self.registry.round() {
                            RejectReason::NotSelected
                        } else {
                            RejectReason::WrongRound
                        },
                    },
                    None => Reply::Rejected {
                        reason: RejectReason::NotSelected,
                    },
                }
            }
            Protocol::ReportResult {
                client,
                device_id,
                round,
                tasks,
                energy_j,
                sim_time_s,
                mean_loss,
            } => {
                // Verify the echoed task count against the assignment
                // *before* mutating the registry, so a mismatched report
                // does not burn the device's one accept.
                if let Some(&idx) = by_device.get(&device_id) {
                    if round == self.registry.round() && plan.assignments[idx].tasks != tasks {
                        self.stats.inc("svc_reports_rejected", 1);
                        return Reply::Rejected {
                            reason: RejectReason::TaskMismatch,
                        };
                    }
                }
                match self.registry.report(client, device_id, round) {
                    ReportVerdict::Accepted => {
                        let prior = reports.insert(
                            device_id,
                            Report {
                                tasks,
                                energy_j,
                                sim_time_s,
                                mean_loss,
                            },
                        );
                        debug_assert!(prior.is_none(), "registry accepted a duplicate report");
                        self.stats.inc("svc_reports_accepted", 1);
                        Reply::Accepted
                    }
                    verdict => {
                        let reason = match verdict {
                            ReportVerdict::WrongRound => {
                                self.stats.inc("svc_reports_late", 1);
                                RejectReason::WrongRound
                            }
                            ReportVerdict::Duplicate => RejectReason::Duplicate,
                            ReportVerdict::NotTraining | ReportVerdict::Unknown => {
                                RejectReason::Unknown
                            }
                            // Unreachable: Accepted is matched above.
                            ReportVerdict::Accepted => RejectReason::Unknown,
                        };
                        self.stats.inc("svc_reports_rejected", 1);
                        Reply::Rejected { reason }
                    }
                }
            }
        }
    }

    /// Serve one round over the transport; returns outcomes in
    /// assignment order for every device that reported in time.
    fn serve_round(&mut self, plan: &RoundPlan) -> Vec<DeviceOutcome> {
        let round = plan.round;
        let n = plan.assignments.len();
        self.tracer.begin_args("svc_round", &|| {
            vec![
                ("round", round.to_string()),
                ("assignments", n.to_string()),
            ]
        });

        // Assignment index by device id — slice lookups and task checks.
        let by_device: BTreeMap<usize, usize> = plan
            .assignments
            .iter()
            .enumerate()
            .map(|(i, a)| (a.device_id, i))
            .collect();
        let scheduled: Vec<usize> = plan.assignments.iter().map(|a| a.device_id).collect();
        let start = self.registry.begin_round(round, &scheduled);
        self.stats.inc("svc_expiries", start.expired as u64);

        let mut reports: BTreeMap<usize, Report> = BTreeMap::new();
        for _ in 0..self.cfg.deadline_ticks {
            self.registry.advance();
            self.transport.tick(self.registry.clock());
            for frame in self.transport.drain_requests() {
                self.stats.inc("svc_frames", 1);
                let Ok(msg) = Protocol::decode(&frame) else {
                    self.stats.inc("svc_bad_frames", 1);
                    continue;
                };
                let client = msg.client();
                let reply = self.handle(msg, plan, &by_device, &mut reports);
                let encoded = reply.encode();
                if matches!(reply, Reply::Slice(_)) {
                    self.max_slice_bytes = self.max_slice_bytes.max(encoded.len());
                }
                self.transport.deliver(client, encoded);
            }
            if reports.len() == n {
                break; // everyone reported — no need to burn the deadline
            }
        }

        let end = self.registry.finish_round();
        let missing = n - reports.len();
        if missing > 0 {
            self.stats.inc("svc_partial_rounds", 1);
            self.stats.inc("svc_stragglers", missing as u64);
        }
        let (up, down) = self.transport.bytes();
        self.stats.set_counter("svc_bytes_up", up);
        self.stats.set_counter("svc_bytes_down", down);
        self.stats
            .set_counter("svc_max_slice_bytes", self.max_slice_bytes as u64);
        self.stats.set_counter("svc_clock", self.registry.clock());

        self.tracer.instant("svc_round_served", &|| {
            vec![
                ("round", round.to_string()),
                ("reported", reports.len().to_string()),
                ("stragglers", missing.to_string()),
                ("connected_stragglers", end.stragglers.to_string()),
                ("expired", start.expired.to_string()),
            ]
        });
        self.tracer.end("svc_round");

        plan.assignments
            .iter()
            .filter_map(|a| {
                reports.get(&a.device_id).map(|r| DeviceOutcome {
                    device_id: a.device_id,
                    device: a.device,
                    tasks: r.tasks,
                    energy_j: r.energy_j,
                    sim_time_s: r.sim_time_s,
                    mean_loss: r.mean_loss,
                })
            })
            .collect()
    }
}

impl<T: Transport> RoundBackend for ServiceBackend<T> {
    fn train(&mut self, plan: &RoundPlan) -> Result<Vec<DeviceOutcome>> {
        let outcomes = self.serve_round(plan);
        self.pending = outcomes.len();
        Ok(outcomes)
    }

    fn aggregate(&mut self) -> Result<()> {
        // Mirrors `SimBackend`: a partial round still advances the
        // model as long as at least one report landed.
        if self.pending > 0 {
            self.rounds_aggregated += 1;
            self.pending = 0;
        }
        Ok(())
    }

    fn evaluate(&mut self) -> Result<f64> {
        Ok(1.0 / (1.0 + self.rounds_aggregated as f64))
    }
}

impl<T: Transport> BackendState for ServiceBackend<T> {
    fn save_state(&self) -> Json {
        // Same shape as `SimBackend`: the durable model state is the
        // aggregation count. Registry/transport state is connection
        // state — after a resume, clients re-rendezvous, which the
        // protocol handles as ordinary (re)joins.
        Json::obj(vec![(
            "rounds_aggregated",
            Json::Num(self.rounds_aggregated as f64),
        )])
    }

    fn load_state(&mut self, state: &Json) -> Result<()> {
        self.rounds_aggregated = get_usize(state, "rounds_aggregated")?;
        self.pending = 0;
        self.registry = ParticipantRegistry::new(self.cfg.expiry_ticks);
        self.max_slice_bytes = 0;
        Ok(())
    }
}
