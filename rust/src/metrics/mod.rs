//! Metrics: counters, timers, the per-device energy ledger, and round logs.
//!
//! The FL server threads a [`MetricsHub`] through every round; examples and
//! benches export the collected series as CSV for EXPERIMENTS.md.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::util::csv::CsvWriter;

/// Monotonic counters + gauges keyed by name.
#[derive(Clone, Debug, Default)]
pub struct MetricsHub {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
}

impl MetricsHub {
    /// New empty hub.
    pub fn new() -> Self {
        Self::default()
    }

    /// Increment a counter.
    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    /// Set a gauge.
    pub fn set(&mut self, name: &str, value: f64) {
        self.gauges.insert(name.to_string(), value);
    }

    /// Read a counter (0 if absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Read a gauge.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// All counters, name-sorted (what store snapshots persist).
    pub fn counters_map(&self) -> &BTreeMap<String, u64> {
        &self.counters
    }

    /// All gauges, name-sorted (what store snapshots persist).
    pub fn gauges_map(&self) -> &BTreeMap<String, f64> {
        &self.gauges
    }

    /// Overwrite a counter (store snapshot restore).
    pub fn set_counter(&mut self, name: &str, value: u64) {
        self.counters.insert(name.to_string(), value);
    }

    /// Render a compact one-line summary.
    pub fn summary(&self) -> String {
        let mut parts: Vec<String> =
            self.counters.iter().map(|(k, v)| format!("{k}={v}")).collect();
        parts.extend(self.gauges.iter().map(|(k, v)| format!("{k}={v:.4}")));
        parts.join(" ")
    }

    /// Prometheus-style text exposition: every counter then every gauge,
    /// name-sorted (the `BTreeMap` order), one `# TYPE` line each, names
    /// prefixed `fedzero_`. Floats render through the deterministic
    /// [`crate::util::json::Json`] writer, so the format is
    /// locale-independent and bit-stable — pinned by a golden test.
    pub fn expose_text(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.counters {
            out.push_str(&format!(
                "# TYPE fedzero_{k} counter\nfedzero_{k} {v}\n"
            ));
        }
        for (k, v) in &self.gauges {
            out.push_str(&format!(
                "# TYPE fedzero_{k} gauge\nfedzero_{k} {}\n",
                crate::util::json::Json::Num(*v).to_string()
            ));
        }
        out
    }
}

/// Scoped wall-clock timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing.
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    /// Elapsed seconds.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Energy ledger: accumulates joules per device and per round.
///
/// The per-round series is unbounded by default; long campaigns that
/// stream rows to a [`crate::store::MetricSink`] bound it with
/// [`EnergyLedger::set_round_bound`] so ledger memory stays constant in
/// the round count ([`EnergyLedger::rounds`] then returns only the
/// retained tail, while [`EnergyLedger::rounds_opened`] keeps the true
/// count).
#[derive(Clone, Debug, Default)]
pub struct EnergyLedger {
    /// joules per device id.
    per_device: BTreeMap<usize, f64>,
    /// (round, joules) series — possibly only the retained tail.
    per_round: Vec<f64>,
    /// Total `begin_round` calls ever (≥ `per_round.len()`).
    opened: usize,
    /// Retention bound on the per-round series (`None` = keep all).
    bound: Option<usize>,
}

impl EnergyLedger {
    /// New empty ledger.
    pub fn new() -> Self {
        Self::default()
    }

    /// Rebuild from persisted parts (store snapshot restore). `opened` is
    /// the true number of rounds ever opened; `per_round` may be only the
    /// retained tail of a bounded ledger.
    pub fn from_parts(
        per_device: BTreeMap<usize, f64>,
        per_round: Vec<f64>,
        opened: usize,
    ) -> Self {
        debug_assert!(opened >= per_round.len());
        Self { per_device, per_round, opened, bound: None }
    }

    /// Bound the retained per-round series to (at least) the most recent
    /// `bound` entries; `None` restores unbounded retention. Totals and
    /// [`EnergyLedger::rounds_opened`] are unaffected.
    pub fn set_round_bound(&mut self, bound: Option<usize>) {
        self.bound = bound.map(|b| b.max(1));
        self.trim();
    }

    fn trim(&mut self) {
        if let Some(b) = self.bound {
            // Amortized O(1): let the vec grow to 2·b, then drop the
            // oldest half in one move.
            if self.per_round.len() >= b * 2 {
                let excess = self.per_round.len() - b;
                self.per_round.drain(..excess);
            }
        }
    }

    /// Record energy for `device` in the current (last) round.
    ///
    /// Energy recorded before any [`EnergyLedger::begin_round`] opens an
    /// implicit round bucket, so the per-round series never silently
    /// drops joules that `per_device` (and thus [`EnergyLedger::total`])
    /// kept. A ledger restored mid-campaign (`opened > 0` with an empty
    /// retained tail) is *not* implicitly re-opened — round accounting
    /// there belongs to the coordinator's next `begin_round`.
    pub fn record(&mut self, device: usize, joules: f64) {
        debug_assert!(joules >= 0.0, "negative energy");
        if self.opened == 0 {
            self.begin_round();
        }
        *self.per_device.entry(device).or_insert(0.0) += joules;
        if let Some(last) = self.per_round.last_mut() {
            *last += joules;
        }
    }

    /// Open a new round bucket.
    pub fn begin_round(&mut self) {
        self.opened += 1;
        self.per_round.push(0.0);
        self.trim();
    }

    /// Total joules across all devices.
    pub fn total(&self) -> f64 {
        self.per_device.values().sum()
    }

    /// Energy consumed by one device.
    pub fn device_total(&self, device: usize) -> f64 {
        self.per_device.get(&device).copied().unwrap_or(0.0)
    }

    /// Per-round series (the retained tail, if a bound is set).
    pub fn rounds(&self) -> &[f64] {
        &self.per_round
    }

    /// Number of round buckets ever opened (immune to the retention
    /// bound).
    pub fn rounds_opened(&self) -> usize {
        self.opened
    }

    /// Per-device totals, id-sorted (what store snapshots persist).
    pub fn per_device_map(&self) -> &BTreeMap<usize, f64> {
        &self.per_device
    }

    /// Largest per-device share of total energy, in [0, 1]. A high value
    /// indicates over-reliance on one device — the over-representation risk
    /// the paper's §6 warns about.
    pub fn max_device_share(&self) -> f64 {
        let total = self.total();
        if total <= 0.0 {
            return 0.0;
        }
        self.per_device.values().fold(0.0f64, |a, &b| a.max(b)) / total
    }
}

/// Column order shared by every CSV emitter of [`RoundLog`] rows
/// ([`TrainingLog::to_csv`] and the streaming
/// [`crate::store::CsvSink`]) — one definition, so the buffered and
/// streamed schemas cannot drift.
pub const ROUND_LOG_COLUMNS: [&str; 8] = [
    "round",
    "policy",
    "loss",
    "energy_j",
    "sched_time_s",
    "train_time_s",
    "participants",
    "tasks",
];

/// One row of the per-round training log.
#[derive(Clone, Debug)]
pub struct RoundLog {
    pub round: usize,
    pub policy: String,
    pub loss: f64,
    pub energy_j: f64,
    pub sched_time_s: f64,
    pub train_time_s: f64,
    pub participants: usize,
    pub tasks: usize,
}

impl RoundLog {
    /// Field values in [`ROUND_LOG_COLUMNS`] order.
    pub fn csv_fields(&self) -> [String; 8] {
        [
            self.round.to_string(),
            self.policy.clone(),
            self.loss.to_string(),
            self.energy_j.to_string(),
            self.sched_time_s.to_string(),
            self.train_time_s.to_string(),
            self.participants.to_string(),
            self.tasks.to_string(),
        ]
    }
}

/// Accumulates [`RoundLog`]s and exports them as CSV.
///
/// Unbounded by default. When per-round rows stream to a
/// [`crate::store::MetricSink`] instead, [`TrainingLog::set_bound`] turns
/// this into a ring of the most recent rows — peak memory stops growing
/// with the round count while [`TrainingLog::total_rows`] and
/// [`TrainingLog::total_energy`] stay exact over the whole campaign.
#[derive(Clone, Debug, Default)]
pub struct TrainingLog {
    rows: Vec<RoundLog>,
    /// Retention bound (`None` = keep all rows).
    bound: Option<usize>,
    /// Rows dropped by the bound.
    dropped: usize,
    /// Running Σ energy over *all* pushed rows (drop-immune).
    energy_acc: f64,
}

impl TrainingLog {
    /// New empty log.
    pub fn new() -> Self {
        Self::default()
    }

    /// New empty log retaining (at least) the most recent `bound` rows.
    pub fn bounded(bound: usize) -> Self {
        let mut log = Self::default();
        log.set_bound(Some(bound));
        log
    }

    /// Bound the retained rows to (at least) the most recent `bound`
    /// entries; `None` restores unbounded retention.
    pub fn set_bound(&mut self, bound: Option<usize>) {
        self.bound = bound.map(|b| b.max(1));
        self.trim();
    }

    fn trim(&mut self) {
        if let Some(b) = self.bound {
            // Amortized O(1): grow to 2·b, then drop the oldest half.
            if self.rows.len() >= b * 2 {
                let excess = self.rows.len() - b;
                self.rows.drain(..excess);
                self.dropped += excess;
            }
        }
    }

    /// Resume accounting from a prior campaign segment (store restore):
    /// `prior_rows` rows totalling `prior_energy` joules were logged
    /// before this process. They count toward
    /// [`TrainingLog::total_rows`]/[`TrainingLog::total_energy`] but are
    /// not retained (the store's journal holds them).
    pub fn resume_from(&mut self, prior_rows: usize, prior_energy: f64) {
        debug_assert!(self.rows.is_empty(), "resume_from on a used log");
        self.dropped = prior_rows;
        self.energy_acc = prior_energy;
    }

    /// Append one round.
    pub fn push(&mut self, row: RoundLog) {
        self.energy_acc += row.energy_j;
        self.rows.push(row);
        self.trim();
    }

    /// Retained rows (all of them when unbounded; at least the most
    /// recent `bound` otherwise).
    pub fn rows(&self) -> &[RoundLog] {
        &self.rows
    }

    /// Rows ever pushed, including those dropped by the bound.
    pub fn total_rows(&self) -> usize {
        self.dropped + self.rows.len()
    }

    /// Rows dropped by the retention bound.
    pub fn dropped_rows(&self) -> usize {
        self.dropped
    }

    /// Final loss, if any rounds were logged.
    pub fn final_loss(&self) -> Option<f64> {
        self.rows.last().map(|r| r.loss)
    }

    /// Sum of per-round energy over the whole campaign (drop-immune).
    pub fn total_energy(&self) -> f64 {
        self.energy_acc
    }

    /// Export the retained rows to CSV ([`ROUND_LOG_COLUMNS`] schema).
    pub fn to_csv(&self) -> CsvWriter {
        let mut w = CsvWriter::new(&ROUND_LOG_COLUMNS);
        for r in &self.rows {
            w.row(&r.csv_fields());
        }
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges() {
        let mut m = MetricsHub::new();
        m.inc("rounds", 1);
        m.inc("rounds", 2);
        m.set("loss", 0.5);
        assert_eq!(m.counter("rounds"), 3);
        assert_eq!(m.gauge("loss"), Some(0.5));
        assert_eq!(m.counter("absent"), 0);
        assert!(m.summary().contains("rounds=3"));
    }

    #[test]
    fn ledger_accumulates() {
        let mut l = EnergyLedger::new();
        l.begin_round();
        l.record(0, 5.0);
        l.record(1, 3.0);
        l.begin_round();
        l.record(0, 2.0);
        assert_eq!(l.total(), 10.0);
        assert_eq!(l.device_total(0), 7.0);
        assert_eq!(l.rounds(), &[8.0, 2.0]);
        assert!((l.max_device_share() - 0.7).abs() < 1e-12);
    }

    #[test]
    fn ledger_share_empty() {
        assert_eq!(EnergyLedger::new().max_device_share(), 0.0);
    }

    #[test]
    fn record_before_begin_round_opens_an_implicit_bucket() {
        // Regression: joules recorded before any begin_round used to
        // reach per_device but silently vanish from the round series
        // (`last_mut()` was None). They must land in an implicit bucket.
        let mut l = EnergyLedger::new();
        l.record(2, 4.0);
        assert_eq!(l.rounds_opened(), 1);
        assert_eq!(l.rounds(), &[4.0]);
        assert_eq!(l.total(), 4.0);
        // The implicit bucket is the current round: later records and an
        // explicit begin_round compose normally after it.
        l.record(0, 1.0);
        l.begin_round();
        l.record(0, 2.0);
        assert_eq!(l.rounds(), &[5.0, 2.0]);
        assert_eq!(l.rounds_opened(), 2);
    }

    #[test]
    fn restored_ledger_does_not_reopen_implicitly() {
        // A mid-campaign restore can carry `opened > 0` with an empty
        // retained tail; record() must leave round accounting to the
        // coordinator's next begin_round instead of forging a bucket.
        let mut l = EnergyLedger::from_parts(BTreeMap::new(), Vec::new(), 7);
        l.record(1, 3.0);
        assert_eq!(l.rounds_opened(), 7);
        assert!(l.rounds().is_empty());
        assert_eq!(l.total(), 3.0);
    }

    #[test]
    fn training_log_csv() {
        let mut log = TrainingLog::new();
        log.push(RoundLog {
            round: 1,
            policy: "mc2mkp".into(),
            loss: 1.25,
            energy_j: 10.0,
            sched_time_s: 0.001,
            train_time_s: 0.5,
            participants: 4,
            tasks: 64,
        });
        let csv = log.to_csv().to_string();
        assert!(csv.starts_with("round,policy,loss"));
        assert!(csv.contains("mc2mkp"));
        assert_eq!(log.final_loss(), Some(1.25));
        assert_eq!(log.total_energy(), 10.0);
    }

    fn row(round: usize, energy_j: f64) -> RoundLog {
        RoundLog {
            round,
            policy: "auto".into(),
            loss: 0.5,
            energy_j,
            sched_time_s: 0.0,
            train_time_s: 0.0,
            participants: 1,
            tasks: 1,
        }
    }

    #[test]
    fn bounded_log_keeps_totals_exact() {
        let mut log = TrainingLog::bounded(8);
        for r in 0..100 {
            log.push(row(r, 1.0));
            assert!(log.rows().len() < 16, "retention must stay bounded");
        }
        assert_eq!(log.total_rows(), 100);
        assert_eq!(log.dropped_rows() + log.rows().len(), 100);
        assert!((log.total_energy() - 100.0).abs() < 1e-12);
        assert_eq!(log.rows().last().unwrap().round, 99);
        // The retained tail is contiguous and most-recent.
        let first = log.rows().first().unwrap().round;
        for (i, r) in log.rows().iter().enumerate() {
            assert_eq!(r.round, first + i);
        }
    }

    #[test]
    fn bounded_ledger_keeps_counts_and_totals() {
        let mut l = EnergyLedger::new();
        l.set_round_bound(Some(4));
        for r in 0..50 {
            l.begin_round();
            l.record(0, r as f64);
            assert!(l.rounds().len() < 8);
        }
        assert_eq!(l.rounds_opened(), 50);
        assert_eq!(l.total(), (0..50).sum::<usize>() as f64);
        assert_eq!(*l.rounds().last().unwrap(), 49.0);
    }

    #[test]
    fn ledger_from_parts_roundtrips() {
        let mut l = EnergyLedger::new();
        l.begin_round();
        l.record(3, 2.5);
        l.begin_round();
        l.record(1, 1.5);
        let back = EnergyLedger::from_parts(
            l.per_device_map().clone(),
            l.rounds().to_vec(),
            l.rounds_opened(),
        );
        assert_eq!(back.total(), l.total());
        assert_eq!(back.rounds(), l.rounds());
        assert_eq!(back.rounds_opened(), 2);
    }

    #[test]
    fn timer_runs() {
        let t = Timer::start();
        std::hint::black_box((0..10_000).sum::<u64>());
        assert!(t.elapsed_s() >= 0.0);
    }
}
