//! Metrics: counters, timers, the per-device energy ledger, and round logs.
//!
//! The FL server threads a [`MetricsHub`] through every round; examples and
//! benches export the collected series as CSV for EXPERIMENTS.md.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::util::csv::CsvWriter;

/// Monotonic counters + gauges keyed by name.
#[derive(Clone, Debug, Default)]
pub struct MetricsHub {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
}

impl MetricsHub {
    /// New empty hub.
    pub fn new() -> Self {
        Self::default()
    }

    /// Increment a counter.
    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    /// Set a gauge.
    pub fn set(&mut self, name: &str, value: f64) {
        self.gauges.insert(name.to_string(), value);
    }

    /// Read a counter (0 if absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Read a gauge.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// Render a compact one-line summary.
    pub fn summary(&self) -> String {
        let mut parts: Vec<String> =
            self.counters.iter().map(|(k, v)| format!("{k}={v}")).collect();
        parts.extend(self.gauges.iter().map(|(k, v)| format!("{k}={v:.4}")));
        parts.join(" ")
    }
}

/// Scoped wall-clock timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing.
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    /// Elapsed seconds.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Energy ledger: accumulates joules per device and per round.
#[derive(Clone, Debug, Default)]
pub struct EnergyLedger {
    /// joules per device id.
    per_device: BTreeMap<usize, f64>,
    /// (round, joules) series.
    per_round: Vec<f64>,
}

impl EnergyLedger {
    /// New empty ledger.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record energy for `device` in the current (last) round.
    pub fn record(&mut self, device: usize, joules: f64) {
        debug_assert!(joules >= 0.0, "negative energy");
        *self.per_device.entry(device).or_insert(0.0) += joules;
        if let Some(last) = self.per_round.last_mut() {
            *last += joules;
        }
    }

    /// Open a new round bucket.
    pub fn begin_round(&mut self) {
        self.per_round.push(0.0);
    }

    /// Total joules across all devices.
    pub fn total(&self) -> f64 {
        self.per_device.values().sum()
    }

    /// Energy consumed by one device.
    pub fn device_total(&self, device: usize) -> f64 {
        self.per_device.get(&device).copied().unwrap_or(0.0)
    }

    /// Per-round series.
    pub fn rounds(&self) -> &[f64] {
        &self.per_round
    }

    /// Largest per-device share of total energy, in [0, 1]. A high value
    /// indicates over-reliance on one device — the over-representation risk
    /// the paper's §6 warns about.
    pub fn max_device_share(&self) -> f64 {
        let total = self.total();
        if total <= 0.0 {
            return 0.0;
        }
        self.per_device.values().fold(0.0f64, |a, &b| a.max(b)) / total
    }
}

/// One row of the per-round training log.
#[derive(Clone, Debug)]
pub struct RoundLog {
    pub round: usize,
    pub policy: String,
    pub loss: f64,
    pub energy_j: f64,
    pub sched_time_s: f64,
    pub train_time_s: f64,
    pub participants: usize,
    pub tasks: usize,
}

/// Accumulates [`RoundLog`]s and exports them as CSV.
#[derive(Clone, Debug, Default)]
pub struct TrainingLog {
    rows: Vec<RoundLog>,
}

impl TrainingLog {
    /// New empty log.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append one round.
    pub fn push(&mut self, row: RoundLog) {
        self.rows.push(row);
    }

    /// All rows.
    pub fn rows(&self) -> &[RoundLog] {
        &self.rows
    }

    /// Final loss, if any rounds were logged.
    pub fn final_loss(&self) -> Option<f64> {
        self.rows.last().map(|r| r.loss)
    }

    /// Sum of per-round energy.
    pub fn total_energy(&self) -> f64 {
        self.rows.iter().map(|r| r.energy_j).sum()
    }

    /// Export to CSV.
    pub fn to_csv(&self) -> CsvWriter {
        let mut w = CsvWriter::new(&[
            "round", "policy", "loss", "energy_j", "sched_time_s", "train_time_s",
            "participants", "tasks",
        ]);
        for r in &self.rows {
            w.rowd(&[
                &r.round,
                &r.policy,
                &r.loss,
                &r.energy_j,
                &r.sched_time_s,
                &r.train_time_s,
                &r.participants,
                &r.tasks,
            ]);
        }
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges() {
        let mut m = MetricsHub::new();
        m.inc("rounds", 1);
        m.inc("rounds", 2);
        m.set("loss", 0.5);
        assert_eq!(m.counter("rounds"), 3);
        assert_eq!(m.gauge("loss"), Some(0.5));
        assert_eq!(m.counter("absent"), 0);
        assert!(m.summary().contains("rounds=3"));
    }

    #[test]
    fn ledger_accumulates() {
        let mut l = EnergyLedger::new();
        l.begin_round();
        l.record(0, 5.0);
        l.record(1, 3.0);
        l.begin_round();
        l.record(0, 2.0);
        assert_eq!(l.total(), 10.0);
        assert_eq!(l.device_total(0), 7.0);
        assert_eq!(l.rounds(), &[8.0, 2.0]);
        assert!((l.max_device_share() - 0.7).abs() < 1e-12);
    }

    #[test]
    fn ledger_share_empty() {
        assert_eq!(EnergyLedger::new().max_device_share(), 0.0);
    }

    #[test]
    fn training_log_csv() {
        let mut log = TrainingLog::new();
        log.push(RoundLog {
            round: 1,
            policy: "mc2mkp".into(),
            loss: 1.25,
            energy_j: 10.0,
            sched_time_s: 0.001,
            train_time_s: 0.5,
            participants: 4,
            tasks: 64,
        });
        let csv = log.to_csv().to_string();
        assert!(csv.starts_with("round,policy,loss"));
        assert!(csv.contains("mc2mkp"));
        assert_eq!(log.final_loss(), Some(1.25));
        assert_eq!(log.total_energy(), 10.0);
    }

    #[test]
    fn timer_runs() {
        let t = Timer::start();
        std::hint::black_box((0..10_000).sum::<u64>());
        assert!(t.elapsed_s() >= 0.0);
    }
}
