//! fedzero CLI — the Layer-3 entrypoint.
//!
//! Subcommands:
//! * `schedule` — build a synthetic fleet instance and solve it with any
//!   registered solver, printing the assignment and energy;
//! * `train` — run federated training end-to-end on the AOT artifacts
//!   (the coordinator round loop over the PJRT backend);
//! * `fleet` — sample and describe a heterogeneous fleet;
//! * `solvers` — list every solver in the registry.

use std::process::ExitCode;

use fedzero::cli;
use fedzero::config::{Policy, TrainConfig};
use fedzero::energy::power::Behavior;
use fedzero::energy::profiles::{BehaviorMix, Fleet};
use fedzero::fl::Server;
use fedzero::metrics::Timer;
use fedzero::sched::auto::{best_algorithm, TABLE2_SCENARIOS};
use fedzero::sched::fleet::FleetInstance;
use fedzero::sched::solver::{Solver, SolverRegistry};
use fedzero::sched::validate;
use fedzero::util::json::Json;
use fedzero::util::rng::Rng;
use fedzero::util::table::{fmt_duration, fmt_energy, Table};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("{e}");
            ExitCode::FAILURE
        }
    }
}

fn run(args: &[String]) -> fedzero::Result<()> {
    let app = cli::fedzero_app();
    let parsed = app.parse(args)?;
    match parsed.command.as_str() {
        "schedule" => cmd_schedule(&parsed),
        "train" => cmd_train(&parsed),
        "fleet" => cmd_fleet(&parsed),
        "solvers" => cmd_solvers(),
        other => Err(fedzero::FedError::Config(format!("unhandled command {other}"))),
    }
}

fn parse_mix(regime: &str) -> fedzero::Result<BehaviorMix> {
    Ok(match regime {
        "increasing" | "convex" => BehaviorMix::Homogeneous(Behavior::Convex),
        "constant" | "linear" => BehaviorMix::Homogeneous(Behavior::Linear),
        "decreasing" | "concave" => BehaviorMix::Homogeneous(Behavior::Concave),
        "arbitrary" | "mixed" => BehaviorMix::Mixed,
        other => {
            return Err(fedzero::FedError::Config(format!(
                "unknown regime '{other}' (increasing|constant|decreasing|arbitrary)"
            )))
        }
    })
}

fn cmd_schedule(p: &cli::Parsed) -> fedzero::Result<()> {
    let tasks: usize = p.get_or("tasks", 256)?;
    let devices: usize = p.get_or("devices", 10)?;
    let seed: u64 = p.get_or("seed", 1)?;
    let mix = parse_mix(p.req("regime")?)?;

    // Resolving through the registry makes `--algo` errors list every
    // valid solver name with its Table 2 applicability.
    let registry = SolverRegistry::with_defaults(seed);
    let solver = registry.resolve(p.req("algo")?)?;

    let mut rng = Rng::new(seed);
    let fleet = Fleet::sample(devices, mix, &mut rng);
    let t = tasks.min(fleet.capacity());
    let inst = fleet.instance(t, 0)?;
    // Class-deduplicate before solving: interchangeable devices collapse,
    // so class-aware solvers run in the number of classes, not devices.
    let fleet_inst = FleetInstance::from_flat(&inst)?;

    let timer = Timer::start();
    let assignment = solver.solve_with_rng(&fleet_inst, &mut rng)?;
    let sched = assignment.expand(&fleet_inst);
    let elapsed = timer.elapsed_s();
    let cost = validate::checked_cost(&inst, &sched)?;

    if p.flag("json") {
        let x: Vec<Json> = sched
            .assignments()
            .iter()
            .map(|&v| Json::Num(v as f64))
            .collect();
        let out = Json::obj(vec![
            ("policy", Json::Str(solver.name().to_string())),
            ("tasks", Json::Num(t as f64)),
            ("energy_j", Json::Num(cost)),
            ("solve_time_s", Json::Num(elapsed)),
            ("assignments", Json::Arr(x)),
        ]);
        println!("{}", out.to_string());
        return Ok(());
    }

    let mut table = Table::new(
        &format!("schedule — policy={} T={t} n={devices}", solver.name()),
        &["device", "archetype", "x_i", "U_i", "energy"],
    );
    for (i, d) in fleet.devices.iter().enumerate() {
        table.rows_str(vec![
            i.to_string(),
            d.archetype.to_string(),
            sched.get(i).to_string(),
            inst.upper[i].to_string(),
            fmt_energy(inst.costs[i].eval(sched.get(i))),
        ]);
    }
    table.print();
    println!(
        "total energy: {}   (solved in {}; {} devices in {} classes)",
        fmt_energy(cost),
        fmt_duration(elapsed),
        fleet_inst.n_devices(),
        fleet_inst.n_classes()
    );
    Ok(())
}

fn cmd_train(p: &cli::Parsed) -> fedzero::Result<()> {
    let mut cfg = match p.get("config") {
        Some(path) => TrainConfig::from_toml(&std::fs::read_to_string(path)?)?,
        None => TrainConfig::default(),
    };
    // CLI overrides. `--seed` first: it threads end-to-end (fleet
    // sampling, data partitioning, selection, and the coordinator RNG the
    // `random` baseline consumes), so runs are reproducible from the
    // command line.
    cfg.seed = p.get_or("seed", cfg.seed)?;
    cfg.rounds = p.get_or("rounds", cfg.rounds)?;
    cfg.devices = p.get_or("devices", cfg.devices)?;
    cfg.tasks_per_round = p.get_or("tasks", cfg.tasks_per_round)?;
    cfg.model = p.get("model").unwrap_or(&cfg.model).to_string();
    cfg.policy = parse_algo(p.req("algo")?, cfg.seed)?;
    cfg.artifacts_dir = p.get("artifacts").unwrap_or(&cfg.artifacts_dir).to_string();
    cfg.validate()?;

    let out = p.get("out").map(|s| s.to_string());
    let policy = cfg.policy;
    let rounds = cfg.rounds;
    let mut server = Server::new(cfg, fedzero::fl::server::DEFAULT_MIX)?;
    println!("round,policy,loss,energy_j,sched_ms,train_s");
    for r in 0..rounds {
        let row = server.round()?;
        println!(
            "{},{},{:.4},{:.2},{:.3},{:.2}",
            row.round,
            row.policy,
            row.loss,
            row.energy_j,
            row.sched_time_s * 1e3,
            row.train_time_s
        );
        if let Some(target) = server.cfg().target_loss {
            if row.loss <= target {
                println!("target loss reached at round {r}");
                break;
            }
        }
    }
    println!(
        "done: policy={policy}, total energy {}",
        fmt_energy(server.ledger().total())
    );
    if let Some(path) = out {
        server.log().to_csv().save(std::path::Path::new(&path))?;
        println!("log written to {path}");
    }
    Ok(())
}

/// Parse `--algo` through the registry, so unknown names fail with the
/// full list of valid solvers, then narrow to a training policy.
fn parse_algo(name: &str, seed: u64) -> fedzero::Result<Policy> {
    let registry = SolverRegistry::with_defaults(seed);
    let solver = registry.resolve(name)?;
    solver.name().parse::<Policy>().map_err(|_| {
        fedzero::FedError::Config(format!(
            "solver '{}' cannot drive training (pick one of: {})",
            solver.name(),
            registry
                .names()
                .into_iter()
                .filter(|n| n.parse::<Policy>().is_ok())
                .collect::<Vec<_>>()
                .join("|")
        ))
    })
}

fn cmd_fleet(p: &cli::Parsed) -> fedzero::Result<()> {
    let devices: usize = p.get_or("devices", 10)?;
    let seed: u64 = p.get_or("seed", 1)?;
    let mut rng = Rng::new(seed);
    let fleet = Fleet::sample(devices, BehaviorMix::Mixed, &mut rng);
    let mut table = Table::new(
        &format!("fleet — n={devices} seed={seed}"),
        &["id", "archetype", "busy W", "s/batch", "data", "U_i", "region", "behavior"],
    );
    for d in &fleet.devices {
        table.rows_str(vec![
            d.id.to_string(),
            d.archetype.to_string(),
            format!("{:.1}", d.power.busy_w),
            format!("{:.2}", d.power.batch_latency_s),
            d.data_batches.to_string(),
            d.upper_limit().to_string(),
            d.region.to_string(),
            format!("{:?}", d.power.behavior),
        ]);
    }
    table.print();
    println!("total capacity: {} mini-batches/round", fleet.capacity());
    Ok(())
}

fn cmd_solvers() -> fedzero::Result<()> {
    let registry = SolverRegistry::with_defaults(0);
    let mut table = Table::new(
        "registered solvers (✓ = provably optimal for the scenario)",
        &["solver", "arb", "inc", "con", "dec", "dec∞"],
    );
    for name in registry.names() {
        let s = registry.resolve(name)?;
        let mut row = vec![name.to_string()];
        for (_, sc) in &TABLE2_SCENARIOS {
            row.push(if s.is_optimal_for(sc) { "✓".into() } else { "·".into() });
        }
        table.rows_str(row);
    }
    table.print();
    // The same applicability, one line per solver (what `--algo` errors
    // print).
    println!("applicability: {}", registry.describe().join(" "));
    // Show what Table 2 dispatch would pick per scenario.
    for (label, sc) in &TABLE2_SCENARIOS {
        println!("auto dispatch [{label}] → {}", best_algorithm(sc));
    }
    Ok(())
}
