//! fedzero CLI — the Layer-3 entrypoint.
//!
//! Subcommands:
//! * `schedule` — build a synthetic fleet instance and solve it with any
//!   registered solver, printing the assignment and energy;
//! * `train` — run federated training end-to-end: the coordinator round
//!   loop over the PJRT backend (`--backend fl`) or the artifact-free
//!   simulation backend (`--backend sim`), optionally journaled into a
//!   durable campaign store (`--store DIR`);
//! * `resume` — continue a crashed/stopped campaign from its store,
//!   bit-for-bit (snapshot + verified journal replay);
//! * `replay` — re-derive every journaled round from the initial snapshot
//!   and verify digests: a deterministic audit of a finished campaign;
//! * `stats` — post-hoc campaign dashboard from a store: phase-time
//!   breakdown, per-solver usage, pipeline/incremental rates, energy
//!   concentration;
//! * `pareto` — sweep the energy–time Pareto front of a sampled fleet
//!   (ε-constraint method over class-level candidate makespans) and dump
//!   it as CSV or JSONL;
//! * `serve` — run a storeless campaign over the networked coordinator
//!   service ([`fedzero::svc`]): the round loop served as run-length
//!   schedule slices over the in-memory loopback wire to a simulated
//!   client fleet, with protocol/registry stats printed at the end;
//! * `fleet` — sample and describe a heterogeneous fleet;
//! * `solvers` — list every solver in the registry.
//!
//! `train`/`resume` additionally take `--trace FILE` to stream a Chrome
//! Trace Event phase trace ([`fedzero::obs`]) — pure telemetry, campaigns
//! are bit-for-bit identical with or without it.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use fedzero::cli;
use fedzero::config::{Policy, TrainConfig};
use fedzero::coordinator::{
    BackendState, Coordinator, CoordinatorConfig, DeadlineConfig, IncrementalConfig,
    KnobSet, ManagedDevice, PipelineConfig, RoundBackend, SimBackend,
};
use fedzero::energy::carbon::{self, CarbonCurve};
use fedzero::energy::power::Behavior;
use fedzero::energy::profiles::{BehaviorMix, Fleet};
use fedzero::energy::tracegen::{carbon_curve, CarbonCurveParams};
use fedzero::fl::dynamics::DynamicsConfig;
use fedzero::fl::Server;
use fedzero::metrics::Timer;
use fedzero::obs::ChromeTraceSink;
use fedzero::runtime::pool;
use fedzero::sched::auto::{best_algorithm, TABLE2_SCENARIOS};
use fedzero::sched::fleet::FleetInstance;
use fedzero::sched::instance::Instance;
use fedzero::sched::pareto::{BiFleet, TimeModel};
use fedzero::sched::solver::{Solver, SolverRegistry};
use fedzero::sched::validate;
use fedzero::store::journal::campaign_digest;
use fedzero::store::{
    self, snapshot as snap, CampaignStore, CsvSink, JournalEntry, JsonlSink,
    MetricSink, StoreContents,
};
use fedzero::svc::{self, LoopbackService, ServiceConfig, SimClientsConfig};
use fedzero::util::json::Json;
use fedzero::util::rng::Rng;
use fedzero::util::table::{fmt_duration, fmt_energy, Table};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("{e}");
            ExitCode::FAILURE
        }
    }
}

fn run(args: &[String]) -> fedzero::Result<()> {
    let app = cli::fedzero_app();
    let parsed = app.parse(args)?;
    match parsed.command.as_str() {
        "schedule" => cmd_schedule(&parsed),
        "train" => cmd_train(&parsed),
        "serve" => cmd_serve(&parsed),
        "resume" => cmd_resume(&parsed),
        "replay" => cmd_replay(&parsed),
        "stats" => cmd_stats(&parsed),
        "pareto" => cmd_pareto(&parsed),
        "fleet" => cmd_fleet(&parsed),
        "solvers" => cmd_solvers(),
        other => Err(fedzero::FedError::Config(format!("unhandled command {other}"))),
    }
}

fn parse_mix(regime: &str) -> fedzero::Result<BehaviorMix> {
    Ok(match regime {
        "increasing" | "convex" => BehaviorMix::Homogeneous(Behavior::Convex),
        "constant" | "linear" => BehaviorMix::Homogeneous(Behavior::Linear),
        "decreasing" | "concave" => BehaviorMix::Homogeneous(Behavior::Concave),
        "arbitrary" | "mixed" => BehaviorMix::Mixed,
        other => {
            return Err(fedzero::FedError::Config(format!(
                "unknown regime '{other}' (increasing|constant|decreasing|arbitrary)"
            )))
        }
    })
}

fn cmd_schedule(p: &cli::Parsed) -> fedzero::Result<()> {
    let tasks: usize = p.get_or("tasks", 256)?;
    let devices: usize = p.get_or("devices", 10)?;
    let seed: u64 = p.get_or("seed", 1)?;
    let mix = parse_mix(p.req("regime")?)?;

    // Resolving through the registry makes `--algo` errors list every
    // valid solver name with its Table 2 applicability.
    let registry = SolverRegistry::with_defaults(seed);
    let solver = registry.resolve(p.req("algo")?)?;

    let shards: usize = p.get_or("shards", 1)?;
    if shards == 0 {
        // Same contract as the train paths (Coordinator rejects 0).
        return Err(fedzero::FedError::Config("--shards must be >= 1".into()));
    }
    let mut rng = Rng::new(seed);
    let fleet = Fleet::sample(devices, mix, &mut rng);
    let t = tasks.min(fleet.capacity());
    let inst = fleet.instance(t, 0)?;
    // Class-deduplicate before solving: interchangeable devices collapse,
    // so class-aware solvers run in the number of classes, not devices.
    // With --shards > 1 the dedup itself fans out over scoped threads —
    // the resulting instance is bit-for-bit identical either way.
    let fleet_inst = if shards > 1 {
        pool::build_fleet_sharded(&inst, shards, 0)?.0
    } else {
        FleetInstance::from_flat(&inst)?
    };

    let timer = Timer::start();
    let assignment = solver.solve_with_rng(&fleet_inst, &mut rng)?;
    let sched = assignment.expand(&fleet_inst);
    let elapsed = timer.elapsed_s();
    let cost = validate::checked_cost(&inst, &sched)?;

    if p.flag("json") {
        let x: Vec<Json> = sched
            .assignments()
            .iter()
            .map(|&v| Json::Num(v as f64))
            .collect();
        let out = Json::obj(vec![
            ("policy", Json::Str(solver.name().to_string())),
            ("tasks", Json::Num(t as f64)),
            ("energy_j", Json::Num(cost)),
            ("solve_time_s", Json::Num(elapsed)),
            ("assignments", Json::Arr(x)),
        ]);
        println!("{}", out.to_string());
        return Ok(());
    }

    let mut table = Table::new(
        &format!("schedule — policy={} T={t} n={devices}", solver.name()),
        &["device", "archetype", "x_i", "U_i", "energy"],
    );
    for (i, d) in fleet.devices.iter().enumerate() {
        table.rows_str(vec![
            i.to_string(),
            d.archetype.to_string(),
            sched.get(i).to_string(),
            inst.upper[i].to_string(),
            fmt_energy(inst.costs[i].eval(sched.get(i))),
        ]);
    }
    table.print();
    println!(
        "total energy: {}   (solved in {}; {} devices in {} classes)",
        fmt_energy(cost),
        fmt_duration(elapsed),
        fleet_inst.n_devices(),
        fleet_inst.n_classes()
    );
    Ok(())
}

fn cmd_train(p: &cli::Parsed) -> fedzero::Result<()> {
    match p.req("backend")? {
        "fl" => cmd_train_fl(p),
        "sim" => cmd_train_sim(p),
        other => Err(fedzero::FedError::Config(format!(
            "unknown backend '{other}' (fl|sim)"
        ))),
    }
}

fn cmd_train_fl(p: &cli::Parsed) -> fedzero::Result<()> {
    if p.get("store").is_some() {
        return Err(fedzero::FedError::Config(
            "--store requires --backend sim (the PJRT backend cannot restore \
             model state from a snapshot yet)"
                .into(),
        ));
    }
    if p.get("deadline").is_some() || parse_objective(p.req("objective")?)? != Objective::Energy {
        return Err(fedzero::FedError::Config(
            "--deadline/--objective require --backend sim".into(),
        ));
    }
    if p.req("transport")? != "inproc" {
        return Err(fedzero::FedError::Config(
            "--transport loopback requires --backend sim (the networked \
             service serves the simulated fleet)"
                .into(),
        ));
    }
    let mut cfg = match p.get("config") {
        Some(path) => TrainConfig::from_toml(&std::fs::read_to_string(path)?)?,
        None => TrainConfig::default(),
    };
    // Explicit CLI flags override the config file; seeded CLI defaults do
    // not (otherwise `--config` values would silently lose to them).
    // `--seed` first: it threads end-to-end (fleet sampling, data
    // partitioning, selection, and the coordinator RNG the `random`
    // baseline consumes), so runs are reproducible from the command line.
    cfg.seed = p.get_parse_explicit("seed")?.unwrap_or(cfg.seed);
    cfg.rounds = p.get_parse_explicit("rounds")?.unwrap_or(cfg.rounds);
    cfg.devices = p.get_parse_explicit("devices")?.unwrap_or(cfg.devices);
    cfg.tasks_per_round =
        p.get_parse_explicit("tasks")?.unwrap_or(cfg.tasks_per_round);
    if let Some(model) = p.get_explicit("model") {
        cfg.model = model.to_string();
    }
    if let Some(algo) = p.get_explicit("algo") {
        cfg.policy = parse_algo(algo, cfg.seed)?;
    }
    if let Some(dir) = p.get_explicit("artifacts") {
        cfg.artifacts_dir = dir.to_string();
    }
    cfg.validate()?;

    let out = p.get("out").map(|s| s.to_string());
    let policy = cfg.policy;
    let rounds = cfg.rounds;
    let devices_n = cfg.devices;
    let mut server = Server::new(cfg, fedzero::fl::server::DEFAULT_MIX)?;
    // Every post-construction knob rides in one `KnobSet`, applied in one
    // call — the same seam the sim path, `resume`, and the service layer
    // configure through.
    let mut knobs = KnobSet {
        dynamics: parse_dynamics(p.req("dynamics")?, devices_n)?,
        shards: Some(p.get_or("shards", 1)?),
        pipeline: Some(PipelineConfig::from(parse_pipeline(p.req("pipeline")?)?)),
        incremental: Some(parse_incremental(p.req("incremental")?)?.into()),
        ..KnobSet::default()
    };
    if let Some(path) = p.get("trace") {
        knobs.tracer = Some(Box::new(ChromeTraceSink::create(Path::new(path))?));
    }
    if let Some(path) = p.get("metrics-jsonl") {
        knobs.sinks.push(Box::new(JsonlSink::create(Path::new(path))?));
    }
    if let Some(path) = &out {
        // Streamed, not materialized at the end — so `--out` stays
        // complete even when `--log-ring` bounds the in-memory log.
        knobs.sinks.push(Box::new(CsvSink::create(Path::new(path))?));
    }
    if let Some(ring) = p.get_parse::<usize>("log-ring")? {
        if ring > 0 {
            knobs.log_bound = Some(Some(ring));
        }
    }
    server.apply_knobs(knobs)?;
    println!("round,policy,loss,energy_j,sched_ms,train_s");
    for r in 0..rounds {
        let row = server.round()?;
        println!(
            "{},{},{:.4},{:.2},{:.3},{:.2}",
            row.round,
            row.policy,
            row.loss,
            row.energy_j,
            row.sched_time_s * 1e3,
            row.train_time_s
        );
        if let Some(target) = server.cfg().target_loss {
            if row.loss <= target {
                println!("target loss reached at round {r}");
                break;
            }
        }
    }
    server.flush_sinks()?;
    server.flush_trace()?;
    println!(
        "done: policy={policy}, total energy {}",
        fmt_energy(server.ledger().total())
    );
    if let Some(path) = out {
        println!("log written to {path}");
    }
    Ok(())
}

fn parse_dynamics(name: &str, n: usize) -> fedzero::Result<Option<DynamicsConfig>> {
    match name {
        "none" => Ok(None),
        "mobile" => Ok(Some(DynamicsConfig::mobile(n))),
        other => Err(fedzero::FedError::Config(format!(
            "unknown dynamics '{other}' (none|mobile)"
        ))),
    }
}

fn parse_pipeline(v: &str) -> fedzero::Result<bool> {
    match v {
        "on" => Ok(true),
        "off" => Ok(false),
        other => Err(fedzero::FedError::Config(format!(
            "unknown pipeline mode '{other}' (on|off)"
        ))),
    }
}

fn parse_incremental(v: &str) -> fedzero::Result<bool> {
    match v {
        "on" => Ok(true),
        "off" => Ok(false),
        other => Err(fedzero::FedError::Config(format!(
            "unknown incremental mode '{other}' (on|off)"
        ))),
    }
}

/// The cost unit `--objective` asks the scheduler to minimize.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Objective {
    Energy,
    Carbon,
    Money,
}

fn parse_objective(v: &str) -> fedzero::Result<Objective> {
    match v {
        "energy" => Ok(Objective::Energy),
        "carbon" => Ok(Objective::Carbon),
        "money" => Ok(Objective::Money),
        other => Err(fedzero::FedError::Config(format!(
            "unknown objective '{other}' (energy|carbon|money)"
        ))),
    }
}

fn parse_deadline(p: &cli::Parsed) -> fedzero::Result<DeadlineConfig> {
    Ok(match p.get_parse::<f64>("deadline")? {
        Some(s) => DeadlineConfig::on(s),
        None => DeadlineConfig::off(),
    })
}

/// Drive an artifact-free coordinator to `rounds` — over the in-process
/// sim backend or the loopback service, the loop is the same — printing
/// one CSV-ish line per round and honoring periodic snapshots when a
/// store is attached.
fn drive_rounds<B: RoundBackend + BackendState>(
    coord: &mut Coordinator<B>,
    rounds: usize,
    sleep_ms: u64,
) -> fedzero::Result<()> {
    while coord.rounds_run() < rounds {
        let row = coord.round_stored()?;
        println!(
            "{},{},{:.4},{:.2},{:.3},{:.2}",
            row.round,
            row.policy,
            row.loss,
            row.energy_j,
            row.sched_time_s * 1e3,
            row.train_time_s
        );
        if let Some(target) = coord.cfg().target_loss {
            if row.loss <= target {
                println!("target loss reached at round {}", row.round);
                break;
            }
        }
        if sleep_ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(sleep_ms));
        }
    }
    coord.flush_sinks()?;
    // Surface any deferred trace-write error; a no-op without `--trace`.
    coord.flush_trace()?;
    Ok(())
}

/// `train --backend sim`: the coordinator round loop over the
/// artifact-free simulation backend — schedules, energy, dynamics, and
/// (with `--store`) a durable journaled campaign.
fn cmd_train_sim(p: &cli::Parsed) -> fedzero::Result<()> {
    // `--config` supplies the scheduling-side knobs (participation,
    // min_tasks, max_share, target_loss, ...); the ML-side keys (model,
    // artifacts, dirichlet_alpha, workers) have no sim equivalent and are
    // ignored here. CLI flags override, exactly as on the fl path.
    let base = match p.get("config") {
        Some(path) => TrainConfig::from_toml(&std::fs::read_to_string(path)?)?,
        None => TrainConfig::default(),
    };
    let rounds: usize = p.get_parse_explicit("rounds")?.unwrap_or(base.rounds);
    let devices_n: usize = p.get_parse_explicit("devices")?.unwrap_or(base.devices);
    let tasks: usize =
        p.get_parse_explicit("tasks")?.unwrap_or(base.tasks_per_round);
    let seed: u64 = p.get_parse_explicit("seed")?.unwrap_or(base.seed);
    let algo = match p.get_explicit("algo") {
        Some(a) => a.to_string(),
        None => base.policy.to_string(),
    };
    // Resolve early so `--algo` errors list the registry. Any registered
    // solver works here (the sim backend is not limited to `Policy`).
    SolverRegistry::with_defaults(seed).resolve(&algo)?;
    let cfg = CoordinatorConfig {
        rounds,
        tasks_per_round: tasks,
        algo,
        participation: base.participation,
        min_tasks: base.min_tasks,
        max_share: base.max_share,
        seed,
        target_loss: base.target_loss,
        shards: p.get_or("shards", 1)?,
        // These knobs land in cfg (and thus the store meta), so `resume`
        // and `replay` pick the same modes back up from the campaign.
        pipeline: PipelineConfig::from(parse_pipeline(p.req("pipeline")?)?),
        incremental: parse_incremental(p.req("incremental")?)?.into(),
        // The deadline is campaign identity, not a wall-clock knob: it
        // changes schedules, so it persists in the store meta and is
        // re-applied to the restored fleet by `resume`/`replay`.
        deadline: parse_deadline(p)?,
    };
    let dynamics_name = p.req("dynamics")?.to_string();
    let dynamics = parse_dynamics(&dynamics_name, devices_n)?;
    let objective = parse_objective(p.req("objective")?)?;
    if objective != Objective::Energy && dynamics.is_some() {
        return Err(fedzero::FedError::Config(
            "--objective carbon|money requires --dynamics none: mid-round \
             dropout accounting is joule-based and must not mix units"
                .into(),
        ));
    }

    // The fleet is sampled from the seed; its full evolving state lives in
    // the snapshots thereafter, so `resume` never needs to resample.
    let mut rng = Rng::new(seed);
    let fleet = Fleet::sample(devices_n, BehaviorMix::Mixed, &mut rng);
    let mut managed: Vec<ManagedDevice> = fleet
        .devices
        .iter()
        .map(|d| ManagedDevice::from_device(d, usize::MAX))
        .collect();
    // Non-energy objectives weight each device's joule cost by its grid
    // region (annual-average intensity/price). The wrapped costs are what
    // the snapshot codec persists, so restored campaigns keep the unit.
    if objective != Objective::Energy {
        for (m, d) in managed.iter_mut().zip(&fleet.devices) {
            m.cost = match objective {
                Objective::Carbon => carbon::carbon_cost(m.cost.clone(), d.region)?,
                Objective::Money => carbon::monetary_cost(m.cost.clone(), d.region)?,
                Objective::Energy => unreachable!(),
            };
        }
    }
    // The backend the rounds run over is picked by `--transport`: a
    // direct in-process call (`inproc`) or the networked coordinator
    // service over the in-memory loopback wire (`loopback`). Both paths
    // share `run_train_sim` — the round loop, knobs, and store wiring
    // are identical; only the backend differs.
    let transport = p.req("transport")?.to_string();
    let svc_churn: u32 = p.get_or("svc-churn", 0)?;
    let svc_miss: u32 = p.get_or("svc-miss", 0)?;
    match transport.as_str() {
        "inproc" => {
            if svc_churn != 0 || svc_miss != 0 {
                return Err(fedzero::FedError::Config(
                    "--svc-churn/--svc-miss require --transport loopback".into(),
                ));
            }
            run_train_sim(p, cfg, managed, dynamics, &dynamics_name, "inproc", SimBackend::new())
        }
        "loopback" => {
            let backend = svc::loopback_service(
                ServiceConfig::default(),
                SimClientsConfig {
                    seed,
                    churn_permille: svc_churn,
                    miss_permille: svc_miss,
                    ..SimClientsConfig::default()
                },
                managed.iter().map(|m| m.id).collect(),
            );
            run_train_sim(p, cfg, managed, dynamics, &dynamics_name, "loopback", backend)
        }
        other => Err(fedzero::FedError::Config(format!(
            "unknown transport '{other}' (inproc|loopback)"
        ))),
    }
}

/// The shared tail of `train --backend sim`: knobs, optional store, and
/// the round loop, generic over the round backend (in-process sim or
/// the loopback service).
fn run_train_sim<B: RoundBackend + BackendState>(
    p: &cli::Parsed,
    cfg: CoordinatorConfig,
    managed: Vec<ManagedDevice>,
    dynamics: Option<DynamicsConfig>,
    dynamics_name: &str,
    transport: &str,
    backend: B,
) -> fedzero::Result<()> {
    let snapshot_every: usize = p.get_or("snapshot-every", 16)?;
    let sleep_ms: u64 = p.get_or("round-sleep-ms", 0)?;
    let rounds = cfg.rounds;
    let devices_n = managed.len();
    let mut coord = Coordinator::new(cfg.clone(), managed, backend)?;

    // One `KnobSet`, one application — the same seam the fl path,
    // `resume`, and the service layer configure through.
    let mut knobs = KnobSet { dynamics, ..KnobSet::default() };
    if let Some(path) = p.get("metrics-jsonl") {
        knobs.sinks.push(Box::new(JsonlSink::create(Path::new(path))?));
    }
    if let Some(path) = p.get("out") {
        // The sim path streams the CSV instead of materializing the full
        // log at the end — same columns as TrainingLog::to_csv.
        knobs.sinks.push(Box::new(CsvSink::create(Path::new(path))?));
    }
    if let Some(path) = p.get("trace") {
        // Pure output: the traced campaign is bit-for-bit identical to an
        // untraced one (journal bytes and replay digest included).
        knobs.tracer = Some(Box::new(ChromeTraceSink::create(Path::new(path))?));
    }
    let ring = p.get_parse::<usize>("log-ring")?;
    let store_dir = p.get("store").map(PathBuf::from);
    if store_dir.is_some() {
        // Storing streams every row to disk; default the in-memory log to
        // a small ring so campaign memory is flat in the round count.
        let ring = ring.unwrap_or(64);
        knobs.log_bound = Some(if ring == 0 { None } else { Some(ring) });
    } else if let Some(ring) = ring {
        if ring > 0 {
            knobs.log_bound = Some(Some(ring));
        }
    }
    knobs.apply_to(&mut coord)?;

    if let Some(dir) = &store_dir {
        let ring = ring.unwrap_or(64);
        // Absolutized: `resume` may run from a different cwd, and must
        // re-attach the *same* files the crashed process was streaming.
        let opt_path = |key: &str| match p.get(key) {
            Some(s) => {
                let pb = PathBuf::from(s);
                let abs = if pb.is_absolute() {
                    pb
                } else {
                    std::env::current_dir()
                        .map(|cwd| cwd.join(&pb))
                        .unwrap_or(pb)
                };
                Json::Str(abs.to_string_lossy().into_owned())
            }
            None => Json::Null,
        };
        // The service knobs persist with the campaign: `resume`/`replay`
        // rebuild the identical loopback service (fleet behavior is a
        // pure function of the seed) from these keys.
        let svc_meta = if transport == "loopback" {
            Json::obj(vec![
                ("churn_permille", Json::Num(p.get_or::<u32>("svc-churn", 0)? as f64)),
                ("miss_permille", Json::Num(p.get_or::<u32>("svc-miss", 0)? as f64)),
            ])
        } else {
            Json::Null
        };
        let meta = Json::obj(vec![
            ("version", Json::Num(1.0)),
            ("kind", Json::Str("sim".into())),
            ("devices", Json::Num(devices_n as f64)),
            ("dynamics", Json::Str(dynamics_name.to_string())),
            ("snapshot_every", Json::Num(snapshot_every as f64)),
            ("log_ring", Json::Num(ring as f64)),
            // Sink paths are part of the campaign: `resume` re-attaches
            // them so streamed outputs stay complete across crashes.
            ("metrics_jsonl", opt_path("metrics-jsonl")),
            ("out", opt_path("out")),
            // The trace file too: `resume` re-attaches it in append mode
            // so one campaign yields one continuous trace across crashes.
            ("trace", opt_path("trace")),
            ("objective", Json::Str(p.req("objective")?.to_string())),
            ("transport", Json::Str(transport.to_string())),
            ("svc", svc_meta),
            ("cfg", snap::cfg_to_json(&cfg)),
        ]);
        let store = CampaignStore::create(dir, meta, coord.snapshot_json())?;
        coord.attach_store(store)?;
    }

    println!("round,policy,loss,energy_j,sched_ms,train_s");
    drive_rounds(&mut coord, rounds, sleep_ms)?;
    println!(
        "done: policy={}, total energy {}",
        cfg.algo,
        fmt_energy(coord.ledger().total())
    );
    if let Some(dir) = &store_dir {
        println!("campaign store: {}", dir.display());
    }
    Ok(())
}

/// The round backend a stored campaign was trained over, from its meta.
/// Legacy metas (no "transport" key) are in-process sim campaigns.
fn transport_of(meta: &Json) -> String {
    meta.get("transport")
        .and_then(|v| v.as_str())
        .unwrap_or("inproc")
        .to_string()
}

/// Rebuild the loopback service (simulated fleet included) that a
/// `--transport loopback` campaign was served over. The fleet's behavior
/// is a pure function of `(seed, round, device)` — never of history or
/// the wall clock — so the reconstructed service re-serves the exact
/// outcome bits the journal recorded.
fn loopback_from_meta(
    meta: &Json,
    cfg: &CoordinatorConfig,
) -> fedzero::Result<LoopbackService> {
    let svc_meta = store::get(meta, "svc")?;
    let devices = store::get_usize(meta, "devices")?;
    Ok(svc::loopback_service(
        ServiceConfig::default(),
        SimClientsConfig {
            seed: cfg.seed,
            churn_permille: store::get_usize(svc_meta, "churn_permille")? as u32,
            miss_permille: store::get_usize(svc_meta, "miss_permille")? as u32,
            ..SimClientsConfig::default()
        },
        // Fleet::sample ids are 0..n; the client fleet mirrors them.
        (0..devices).collect(),
    ))
}

/// Rebuild a resumed campaign's runtime knobs from its store meta — the
/// same `KnobSet` seam `train` configures through. Sink files are
/// re-created and rewound from the journal (their derived content is
/// fully journaled, timings included); the persisted trace file is
/// re-opened in append mode. cfg-level knobs (shards, pipeline,
/// incremental, deadline) travel inside the persisted cfg and dynamics
/// state lives in the snapshot — `Coordinator::restore` re-applies both.
fn knobs_from_meta(
    meta: &Json,
    entries: &[JournalEntry],
    trace_override: Option<&str>,
) -> fedzero::Result<KnobSet> {
    let mut knobs = KnobSet::new();
    if let Some(path) = meta.get("metrics_jsonl").and_then(|v| v.as_str()) {
        let mut sink = JsonlSink::create(Path::new(path))?;
        for e in entries {
            sink.record(&e.row)?;
        }
        knobs.sinks.push(Box::new(sink));
    }
    if let Some(path) = meta.get("out").and_then(|v| v.as_str()) {
        let mut sink = CsvSink::create(Path::new(path))?;
        for e in entries {
            sink.record(&e.row)?;
        }
        knobs.sinks.push(Box::new(sink));
    }
    // Trace re-attach: an explicit `--trace` overrides the path persisted
    // in the store meta. The knobs are applied only *after* `restore`
    // replayed the journal tail, so replayed rounds never duplicate spans
    // in the file; `open_append` truncates any line torn by the crash.
    let trace_path = trace_override.map(str::to_string).or_else(|| {
        meta.get("trace").and_then(|v| v.as_str()).map(str::to_string)
    });
    if let Some(path) = trace_path {
        knobs.tracer =
            Some(Box::new(ChromeTraceSink::open_append(Path::new(&path))?));
    }
    Ok(knobs)
}

/// `resume DIR`: rebuild the coordinator from the latest snapshot, replay
/// and verify the journal tail, and continue the remaining rounds — over
/// the same backend the campaign was trained on (loopback campaigns get
/// their service and simulated fleet reconstructed from the meta).
fn cmd_resume(p: &cli::Parsed) -> fedzero::Result<()> {
    let dir = PathBuf::from(&p.positional[0]);
    let (campaign, contents) = CampaignStore::resume(&dir)?;
    let cfg = snap::cfg_from_json(store::get(&contents.meta, "cfg")?)?;
    match transport_of(&contents.meta).as_str() {
        "loopback" => {
            let backend = loopback_from_meta(&contents.meta, &cfg)?;
            resume_campaign(p, &dir, campaign, &contents, cfg, backend)
        }
        _ => resume_campaign(p, &dir, campaign, &contents, cfg, SimBackend::new()),
    }
}

/// The backend-generic tail of `resume`.
fn resume_campaign<B: RoundBackend + BackendState>(
    p: &cli::Parsed,
    dir: &Path,
    campaign: CampaignStore,
    contents: &StoreContents,
    cfg: CoordinatorConfig,
    backend: B,
) -> fedzero::Result<()> {
    let sleep_ms: u64 = p.get_or("round-sleep-ms", 0)?;
    let ring = contents
        .meta
        .get("log_ring")
        .and_then(|v| v.as_usize())
        .unwrap_or(64);
    let log_bound = if ring == 0 { None } else { Some(ring) };
    let committed = contents.entries.len();
    println!(
        "resuming {}: {} of {} rounds journaled, replaying from round {}",
        dir.display(),
        committed,
        cfg.rounds,
        contents
            .snapshot
            .get("next_round")
            .and_then(|v| v.as_usize())
            .unwrap_or(0)
    );
    let rounds = cfg.rounds;
    let target_reached = cfg
        .target_loss
        .map_or(false, |t| {
            contents.entries.last().map_or(false, |e| e.row.loss <= t)
        });
    let mut coord = Coordinator::restore(
        cfg,
        &contents.snapshot,
        &contents.entries,
        backend,
        log_bound,
    )?;
    coord.attach_store(campaign)?;
    knobs_from_meta(&contents.meta, &contents.entries, p.get("trace"))?
        .apply_to(&mut coord)?;
    if coord.rounds_run() >= rounds || target_reached {
        println!("campaign already complete ({committed} rounds)");
        return Ok(());
    }
    println!("round,policy,loss,energy_j,sched_ms,train_s");
    drive_rounds(&mut coord, rounds, sleep_ms)?;
    println!(
        "done: policy={}, total energy {}",
        coord.cfg().algo,
        fmt_energy(coord.ledger().total())
    );
    Ok(())
}

/// `replay DIR`: re-derive every journaled round from the *initial*
/// snapshot, verifying solver, instance/schedule digests, RNG states, and
/// energy per round — a deterministic audit of the whole campaign. For
/// loopback campaigns every round is re-*served* through a reconstructed
/// service, so the audit covers the wire path too.
fn cmd_replay(p: &cli::Parsed) -> fedzero::Result<()> {
    let dir = PathBuf::from(&p.positional[0]);
    let contents = CampaignStore::read(&dir)?;
    let cfg = snap::cfg_from_json(store::get(&contents.meta, "cfg")?)?;
    match transport_of(&contents.meta).as_str() {
        "loopback" => {
            let backend = loopback_from_meta(&contents.meta, &cfg)?;
            replay_campaign(&dir, &contents, cfg, backend)
        }
        _ => replay_campaign(&dir, &contents, cfg, SimBackend::new()),
    }
}

/// The backend-generic tail of `replay`.
fn replay_campaign<B: RoundBackend + BackendState>(
    dir: &Path,
    contents: &StoreContents,
    cfg: CoordinatorConfig,
    backend: B,
) -> fedzero::Result<()> {
    let n = contents.entries.len();
    // `restore` re-executes and checks every entry; reaching Ok *is* the
    // audit passing.
    let coord = Coordinator::restore(
        cfg,
        &contents.init_snapshot,
        &contents.entries,
        backend,
        None,
    )?;
    let total_energy: f64 = contents.entries.iter().map(|e| e.row.energy_j).sum();
    let final_loss = contents
        .entries
        .last()
        .map(|e| e.row.loss.to_string())
        .unwrap_or_else(|| "none".into());
    println!(
        "replayed {n} rounds from {}: every solver, instance/schedule digest, \
         RNG state, and energy value matched the journal",
        dir.display()
    );
    println!(
        "campaign digest {:016x} rounds {n} energy_j {total_energy} \
         final_loss {final_loss}",
        campaign_digest(&contents.entries)
    );
    debug_assert_eq!(coord.rounds_run(), n);
    Ok(())
}

/// `serve`: a storeless loopback campaign — the round loop served as
/// run-length schedule slices over the in-memory wire to a simulated
/// client fleet — followed by a protocol/registry stats report. The
/// quickest way to watch the networked service (rendezvous, heartbeats,
/// slices, partial rounds) without creating a campaign store.
fn cmd_serve(p: &cli::Parsed) -> fedzero::Result<()> {
    let rounds: usize = p.get_or("rounds", 8)?;
    let devices_n: usize = p.get_or("devices", 64)?;
    let tasks: usize = p.get_or("tasks", 128)?;
    let seed: u64 = p.get_or("seed", 7)?;
    let algo = p.req("algo")?.to_string();
    SolverRegistry::with_defaults(seed).resolve(&algo)?;
    let churn: u32 = p.get_or("svc-churn", 50)?;
    let miss: u32 = p.get_or("svc-miss", 0)?;

    let base = TrainConfig::default();
    let cfg = CoordinatorConfig {
        rounds,
        tasks_per_round: tasks,
        algo,
        participation: base.participation,
        min_tasks: base.min_tasks,
        max_share: base.max_share,
        seed,
        target_loss: None,
        shards: 1,
        pipeline: PipelineConfig::off(),
        incremental: IncrementalConfig::off(),
        deadline: DeadlineConfig::off(),
    };
    let mut rng = Rng::new(seed);
    let fleet = Fleet::sample(devices_n, BehaviorMix::Mixed, &mut rng);
    let managed: Vec<ManagedDevice> = fleet
        .devices
        .iter()
        .map(|d| ManagedDevice::from_device(d, usize::MAX))
        .collect();
    let backend = svc::loopback_service(
        ServiceConfig::default(),
        SimClientsConfig {
            seed,
            churn_permille: churn,
            miss_permille: miss,
            ..SimClientsConfig::default()
        },
        managed.iter().map(|m| m.id).collect(),
    );
    let mut coord = Coordinator::new(cfg, managed, backend)?;
    if let Some(path) = p.get("trace") {
        // The service's own spans (svc_round begin/end, per-round pump
        // instants) are the interesting ones here — the tracer goes to
        // the backend, not the coordinator.
        coord
            .backend_mut()
            .set_tracer(Box::new(ChromeTraceSink::create(Path::new(path))?));
    }
    println!("round,policy,loss,energy_j,sched_ms,train_s");
    drive_rounds(&mut coord, rounds, 0)?;
    coord.backend_mut().flush_trace()?;

    let service = coord.backend();
    let stats = service.stats();
    let (up, down) = service.transport().bytes();
    println!(
        "service: {devices_n} clients — {} joins ({} rejoins), {} heartbeats, \
         {} fetches, {} reports accepted ({} late, {} rejected)",
        stats.counter("svc_joins"),
        stats.counter("svc_rejoins"),
        stats.counter("svc_heartbeats"),
        stats.counter("svc_fetches"),
        stats.counter("svc_reports_accepted"),
        stats.counter("svc_reports_late"),
        stats.counter("svc_reports_rejected"),
    );
    println!(
        "rounds: {} partial, {} stragglers, {} expiries; wire: {up} B up, \
         {down} B down, max slice frame {} B (O(classes), never O(devices))",
        stats.counter("svc_partial_rounds"),
        stats.counter("svc_stragglers"),
        stats.counter("svc_expiries"),
        service.max_slice_bytes(),
    );
    println!("total energy {}", fmt_energy(coord.ledger().total()));
    if p.flag("expose") {
        print!("{}", stats.expose_text());
    }
    Ok(())
}

/// `stats DIR`: a post-hoc dashboard over a campaign store — phase-time
/// breakdown and per-solver usage from the journal (complete for every
/// committed round), plus pipeline/incremental effectiveness and energy
/// concentration from the latest snapshot's metrics hub and ledger.
fn cmd_stats(p: &cli::Parsed) -> fedzero::Result<()> {
    let dir = PathBuf::from(&p.positional[0]);
    let contents = CampaignStore::read(&dir)?;
    let cfg = snap::cfg_from_json(store::get(&contents.meta, "cfg")?)?;
    let entries = &contents.entries;
    let n = entries.len();

    // Journal-derived aggregates: exact for all n committed rounds.
    let mut sched_s = 0.0f64;
    let mut train_s = 0.0f64;
    let mut energy_j = 0.0f64;
    let mut tasks = 0u64;
    // (rounds, Σ sched s) per effective solver; BTreeMap for stable order.
    let mut solvers: std::collections::BTreeMap<&str, (u64, f64)> =
        std::collections::BTreeMap::new();
    for e in entries {
        sched_s += e.row.sched_time_s;
        train_s += e.row.train_time_s;
        energy_j += e.row.energy_j;
        tasks += e.row.tasks as u64;
        let name =
            if e.solver.is_empty() { "(empty round)" } else { e.solver.as_str() };
        let slot = solvers.entry(name).or_insert((0, 0.0));
        slot.0 += 1;
        slot.1 += e.row.sched_time_s;
    }

    println!(
        "campaign {} — {n} of {} rounds journaled, policy {}",
        dir.display(),
        cfg.rounds,
        cfg.algo
    );
    if cfg.deadline.enabled {
        println!(
            "deadline: {} s per round (min cost s.t. makespan <= D)",
            cfg.deadline.seconds
        );
    }
    if let Some(obj) = contents.meta.get("objective").and_then(|v| v.as_str()) {
        if obj != "energy" {
            println!("objective: {obj} (device costs weighted by grid region)");
        }
    }
    println!(
        "energy: {} over {tasks} tasks ({} per task)",
        fmt_energy(energy_j),
        fmt_energy(if tasks > 0 { energy_j / tasks as f64 } else { 0.0 })
    );
    let wall = sched_s + train_s;
    let pct = |x: f64| if wall > 0.0 { 100.0 * x / wall } else { 0.0 };
    println!(
        "phases: scheduling {} ({:.1}%), training {} ({:.1}%)",
        fmt_duration(sched_s),
        pct(sched_s),
        fmt_duration(train_s),
        pct(train_s)
    );

    let mut table = Table::new(
        "per-solver usage (from the journal)",
        &["solver", "rounds", "share", "Σ sched", "mean sched"],
    );
    for (name, (count, time_s)) in &solvers {
        table.rows_str(vec![
            name.to_string(),
            count.to_string(),
            format!("{:.1}%", 100.0 * *count as f64 / n.max(1) as f64),
            fmt_duration(*time_s),
            fmt_duration(time_s / (*count).max(1) as f64),
        ]);
    }
    table.print();

    // Snapshot-derived rates: the hub and ledger are periodic, so they
    // cover the first `snap_rounds` rounds (≤ n after a crash window).
    let snap_rounds = contents
        .snapshot
        .get("next_round")
        .and_then(|v| v.as_usize())
        .unwrap_or(0);
    let metrics = snap::metrics_from_json(store::get(&contents.snapshot, "metrics")?)?;
    let ledger = snap::ledger_from_json(store::get(&contents.snapshot, "ledger")?)?;
    if snap_rounds < n {
        println!(
            "(rates below are from the snapshot at round {snap_rounds}; \
             the journal is ahead at {n})"
        );
    }

    let spec = metrics.counter("pipeline_speculations");
    if cfg.pipeline.enabled || spec > 0 {
        let hits = metrics.counter("pipeline_hits");
        let misses = metrics.counter("pipeline_misses");
        let judged = hits + misses;
        println!(
            "pipeline: {spec} speculations, {hits} adopted, {misses} missed \
             ({:.1}% hit rate), {:.3}s overlap reclaimed",
            if judged > 0 { 100.0 * hits as f64 / judged as f64 } else { 0.0 },
            metrics.counter("pipeline_overlap_ns") as f64 / 1e9
        );
    }
    let scheduled = metrics.counter("fleet_devices");
    if cfg.incremental.enabled {
        let dirty = metrics.counter("incr_dirty");
        println!(
            "incremental: {} index rebuilds, {dirty} dirty devices across \
             {scheduled} scheduled ({:.1}% dirty rate)",
            metrics.counter("incr_index_rebuilds"),
            if scheduled > 0 { 100.0 * dirty as f64 / scheduled as f64 } else { 0.0 }
        );
    }
    let classes = metrics.counter("fleet_classes");
    if scheduled > 0 {
        println!(
            "dedup: {scheduled} device-slots solved as {classes} classes \
             ({:.1}× collapse)",
            scheduled as f64 / classes.max(1) as f64
        );
    }
    println!(
        "energy concentration: max device share {:.3} (cap {:.3}) over {} \
         ledger rounds",
        ledger.max_device_share(),
        cfg.max_share,
        ledger.rounds_opened()
    );
    // Latency gauges exported by a traced run (`--trace`): log₂-bucketed
    // phase/solve quantiles, absent on untraced campaigns by design.
    let obs: Vec<(&String, &f64)> = metrics
        .gauges_map()
        .iter()
        .filter(|(k, _)| k.starts_with("obs_"))
        .collect();
    if !obs.is_empty() {
        let mut table =
            Table::new("traced latency gauges (ns)", &["gauge", "value"]);
        for (k, v) in obs {
            table.rows_str(vec![k.clone(), format!("{v:.0}")]);
        }
        table.print();
    }
    if p.flag("expose") {
        print!("{}", metrics.expose_text());
    }
    Ok(())
}

/// Parse `--algo` through the registry, so unknown names fail with the
/// full list of valid solvers, then narrow to a training policy.
fn parse_algo(name: &str, seed: u64) -> fedzero::Result<Policy> {
    let registry = SolverRegistry::with_defaults(seed);
    let solver = registry.resolve(name)?;
    solver.name().parse::<Policy>().map_err(|_| {
        fedzero::FedError::Config(format!(
            "solver '{}' cannot drive training (pick one of: {})",
            solver.name(),
            registry
                .names()
                .into_iter()
                .filter(|n| n.parse::<Policy>().is_ok())
                .collect::<Vec<_>>()
                .join("|")
        ))
    })
}

/// `pareto`: sample a fleet, build its bi-objective instance under the
/// chosen cost unit, and dump either the full energy–time front or (with
/// `--deadline`) the single ε-constrained point at that cap.
fn cmd_pareto(p: &cli::Parsed) -> fedzero::Result<()> {
    let tasks: usize = p.get_or("tasks", 256)?;
    let devices_n: usize = p.get_or("devices", 10)?;
    let seed: u64 = p.get_or("seed", 1)?;
    let algo = p.req("algo")?;
    let objective = parse_objective(p.req("objective")?)?;
    let round: usize = p.get_or("round", 0)?;
    let upload_s: f64 = p.get_or("upload-s", 2.0)?;
    let format = p.req("format")?;
    if format != "csv" && format != "jsonl" {
        return Err(fedzero::FedError::Config(format!(
            "unknown format '{format}' (csv|jsonl)"
        )));
    }
    let registry = SolverRegistry::with_defaults(seed);
    registry.resolve(algo)?;
    // Validate a pinned region before doing any work (unknown names are
    // a hard error — never a silently-substituted default grid).
    let region_override = p.get("region");
    if let Some(r) = region_override {
        carbon::region(r)?;
    }

    let mut rng = Rng::new(seed);
    let fleet = Fleet::sample(devices_n, BehaviorMix::Mixed, &mut rng);
    let t = tasks.min(fleet.capacity());

    // Per-region diurnal carbon curves, deterministic from the seed: the
    // time axis of the carbon objective. `--round` picks where on the
    // cycle the front is computed — the "schedule when the grid is
    // green" scenario is `--objective carbon --round <trough>`.
    let mut curves: std::collections::BTreeMap<&str, CarbonCurve> =
        std::collections::BTreeMap::new();
    if objective == Objective::Carbon {
        for (i, &(name, g_per_kwh, _)) in carbon::REGIONS.iter().enumerate() {
            let params = CarbonCurveParams {
                mean_g_per_kwh: g_per_kwh,
                ..CarbonCurveParams::default()
            };
            let mut crng = Rng::new(seed ^ (i as u64 + 1).wrapping_mul(0x9e37_79b9));
            curves.insert(name, carbon_curve(48, &params, &mut crng)?);
        }
    }

    let mut costs = Vec::with_capacity(fleet.len());
    let mut uppers = Vec::with_capacity(fleet.len());
    let mut times = Vec::with_capacity(fleet.len());
    for d in &fleet.devices {
        let region = region_override.unwrap_or(d.region);
        let energy = d.cost_fn();
        costs.push(match objective {
            Objective::Energy => energy,
            Objective::Carbon => curves[region].carbon_cost_at(energy, round),
            Objective::Money => carbon::monetary_cost(energy, region)?,
        });
        uppers.push(d.upper_limit());
        times.push(TimeModel::affine(d.power.batch_latency_s, upload_s));
    }
    let inst = Instance::new(t, vec![0; fleet.len()], uppers, costs)?;
    let bi = BiFleet::from_flat(&inst, &times)?;

    let points = match p.get_parse::<f64>("deadline")? {
        Some(tau) => match bi.solve_constrained(&registry, algo, tau)? {
            Some(pt) => vec![pt],
            None => {
                return Err(fedzero::FedError::Infeasible(format!(
                    "no schedule meets a {tau} s deadline (tightest feasible \
                     makespan exceeds it)"
                )))
            }
        },
        None => bi.pareto_front(&registry, algo)?,
    };

    let unit = match objective {
        Objective::Energy => "J",
        Objective::Carbon => "gCO2e",
        Objective::Money => "EUR",
    };
    let mut out = String::new();
    if format == "csv" {
        out.push_str("point,makespan_s,cost,unit,solver,assignments\n");
        for (i, pt) in points.iter().enumerate() {
            let loads: Vec<String> =
                pt.schedule.assignments().iter().map(|x| x.to_string()).collect();
            out.push_str(&format!(
                "{i},{},{},{unit},{},{}\n",
                pt.makespan,
                pt.energy,
                pt.solver,
                loads.join(" ")
            ));
        }
    } else {
        for (i, pt) in points.iter().enumerate() {
            let loads: Vec<Json> = pt
                .schedule
                .assignments()
                .iter()
                .map(|&x| Json::Num(x as f64))
                .collect();
            let obj = Json::obj(vec![
                ("point", Json::Num(i as f64)),
                ("makespan_s", Json::Num(pt.makespan)),
                ("cost", Json::Num(pt.energy)),
                ("unit", Json::Str(unit.to_string())),
                ("solver", Json::Str(pt.solver.to_string())),
                ("assignments", Json::Arr(loads)),
            ]);
            out.push_str(&obj.to_string());
            out.push('\n');
        }
    }
    match p.get("out") {
        Some(path) => std::fs::write(path, &out)?,
        None => print!("{out}"),
    }
    // Human summary on stderr so stdout stays machine-parseable.
    let k = bi.energy().n_classes();
    eprintln!(
        "{} point(s) over {} candidate makespans — n={} in {k} classes, T={t}, \
         objective {unit}",
        points.len(),
        bi.candidate_makespans().len(),
        fleet.len()
    );
    if let (Some(first), Some(last)) = (points.first(), points.last()) {
        eprintln!(
            "tightest: {:.3} s at {:.3} {unit}; loosest: {:.3} s at {:.3} {unit}",
            first.makespan, first.energy, last.makespan, last.energy
        );
    }
    if let Some(path) = p.get("out") {
        eprintln!("front written to {path}");
    }
    Ok(())
}

fn cmd_fleet(p: &cli::Parsed) -> fedzero::Result<()> {
    let devices: usize = p.get_or("devices", 10)?;
    let seed: u64 = p.get_or("seed", 1)?;
    let mut rng = Rng::new(seed);
    let fleet = Fleet::sample(devices, BehaviorMix::Mixed, &mut rng);
    let mut table = Table::new(
        &format!("fleet — n={devices} seed={seed}"),
        &["id", "archetype", "busy W", "s/batch", "data", "U_i", "region", "behavior"],
    );
    for d in &fleet.devices {
        table.rows_str(vec![
            d.id.to_string(),
            d.archetype.to_string(),
            format!("{:.1}", d.power.busy_w),
            format!("{:.2}", d.power.batch_latency_s),
            d.data_batches.to_string(),
            d.upper_limit().to_string(),
            d.region.to_string(),
            format!("{:?}", d.power.behavior),
        ]);
    }
    table.print();
    println!("total capacity: {} mini-batches/round", fleet.capacity());
    Ok(())
}

fn cmd_solvers() -> fedzero::Result<()> {
    let registry = SolverRegistry::with_defaults(0);
    let mut table = Table::new(
        "registered solvers (✓ = provably optimal for the scenario)",
        &["solver", "arb", "inc", "con", "dec", "dec∞"],
    );
    for name in registry.names() {
        let s = registry.resolve(name)?;
        let mut row = vec![name.to_string()];
        for (_, sc) in &TABLE2_SCENARIOS {
            row.push(if s.is_optimal_for(sc) { "✓".into() } else { "·".into() });
        }
        table.rows_str(row);
    }
    table.print();
    // The same applicability, one line per solver (what `--algo` errors
    // print).
    println!("applicability: {}", registry.describe().join(" "));
    // Show what Table 2 dispatch would pick per scenario.
    for (label, sc) in &TABLE2_SCENARIOS {
        println!("auto dispatch [{label}] → {}", best_algorithm(sc));
    }
    Ok(())
}
