//! Observability: phase-span tracing for campaigns, with a zero-cost
//! default.
//!
//! The coordinator (and everything it drives — the sharded instance
//! builder, the speculative pipeline, the durable store) reports *what
//! happened when* through the [`Tracer`] trait. Two implementations:
//!
//! * [`NoopTracer`] — the default. Every method is an empty default
//!   body, and every argument-carrying event takes its arguments as a
//!   closure, so an untraced campaign never materializes a single
//!   string or reads a clock on the tracer's behalf. Untraced runs are
//!   bit-identical to pre-observability builds.
//! * [`ChromeTraceSink`] — writes Trace Event Format JSONL (one event
//!   object per line) loadable directly in `chrome://tracing` or
//!   Perfetto. Duration events are `B`/`E` pairs on lane (`tid`) 0 for
//!   the coordinator; shard-build workers get one complete span per
//!   worker on lanes 1.. via [`Tracer::span_at`]; speculation lifecycle
//!   events are instants carrying the miss cause.
//!
//! **The invariant**: tracing is pure *output*. No tracer method returns
//! data to the caller (other than [`Tracer::now_ns`], used only to
//! timestamp other trace events), so no schedule, journal byte, RNG
//! state, or digest can depend on whether a tracer is attached. fedlint
//! R5 additionally fences the `trace_`/`span_`/`obs_` prefixes out of
//! every digest function, and `tests/obs_trace.rs` proves journal byte
//! identity differentially.

pub mod hist;

use std::io::{Read as _, Seek, SeekFrom, Write};
use std::path::Path;
use std::time::Instant;

use crate::error::Result;
use crate::util::json::Json;

/// Lazily-built event arguments: short key/value pairs rendered into the
/// trace line's `args` object.
pub type ArgList = Vec<(&'static str, String)>;

/// Structured trace consumer. All methods default to no-ops so that
/// [`NoopTracer`] (and any partial implementation) costs nothing.
pub trait Tracer: Send {
    /// Whether events will actually be recorded — callers use this to
    /// skip argument preparation that even the closure indirection can't
    /// make free (e.g. snapshotting per-worker timing offsets).
    fn enabled(&self) -> bool {
        false
    }

    /// Nanoseconds since this tracer's anchor instant (0 when disabled).
    /// Only ever used to place [`Tracer::span_at`] events on the same
    /// clock as live `begin`/`end` pairs — never returned into
    /// scheduling state.
    fn now_ns(&self) -> u64 {
        0
    }

    /// Open a duration span on the coordinator lane.
    fn begin(&mut self, _name: &'static str) {}

    /// Open a duration span with arguments (built only when recording).
    fn begin_args(&mut self, _name: &'static str, _args: &dyn Fn() -> ArgList) {}

    /// Close the innermost open span with this name.
    fn end(&mut self, _name: &'static str) {}

    /// A point-in-time event with arguments.
    fn instant(&mut self, _name: &'static str, _args: &dyn Fn() -> ArgList) {}

    /// A complete span on lane `lane` with explicit timestamps (offsets
    /// on this tracer's [`Tracer::now_ns`] clock) — how concurrent shard
    /// workers report after the fact without sharing the sink.
    fn span_at(
        &mut self,
        _name: &'static str,
        _lane: u32,
        _start_ns: u64,
        _end_ns: u64,
        _args: &dyn Fn() -> ArgList,
    ) {
    }

    /// Flush buffered events to the sink, surfacing any deferred write
    /// error.
    fn flush(&mut self) -> Result<()> {
        Ok(())
    }
}

/// The default tracer: records nothing, costs nothing.
#[derive(Clone, Copy, Debug, Default)]
pub struct NoopTracer;

impl Tracer for NoopTracer {}

/// The coordinator's lane (`tid`) in the trace; shard workers use
/// lanes 1..=shards.
pub const COORD_LANE: u32 = 0;

/// Trace Event Format JSONL writer.
///
/// One JSON object per line (`B`/`E` duration events, `i` instants) with
/// `pid` fixed at 1 and `tid` carrying the lane. Timestamps are
/// microseconds (fractional) from the sink's anchor instant. The stream
/// is plain JSONL — no surrounding array — which both `chrome://tracing`
/// and Perfetto accept.
///
/// Write errors never interrupt a campaign: they are deferred and
/// surfaced by [`Tracer::flush`] (a trace is telemetry, not state — a
/// full disk must not kill training the journal can survive).
pub struct ChromeTraceSink {
    out: Box<dyn Write + Send>,
    anchor: Instant,
    err: Option<std::io::Error>,
}

impl ChromeTraceSink {
    /// Create (truncate) a trace file at `path`.
    pub fn create(path: &Path) -> Result<Self> {
        let file = std::fs::File::create(path)?;
        Ok(Self::from_writer(Box::new(std::io::BufWriter::new(file))))
    }

    /// Re-open an existing trace for append (`resume` re-attaching the
    /// campaign's trace). A crash can tear the trailing line mid-write;
    /// like the journal's `open_append`, anything after the last newline
    /// is truncated away so the stream stays valid JSONL.
    pub fn open_append(path: &Path) -> Result<Self> {
        let mut file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(path)?;
        let mut buf = Vec::new();
        file.read_to_end(&mut buf)?;
        let keep = match buf.iter().rposition(|&b| b == b'\n') {
            Some(pos) => (pos + 1) as u64,
            None => 0,
        };
        if keep != buf.len() as u64 {
            file.set_len(keep)?;
            file.sync_data()?;
        }
        file.seek(SeekFrom::Start(keep))?;
        Ok(Self::from_writer(Box::new(std::io::BufWriter::new(file))))
    }

    /// Build over any writer (tests capture the byte stream this way).
    pub fn from_writer(out: Box<dyn Write + Send>) -> Self {
        Self { out, anchor: Instant::now(), err: None }
    }

    fn emit(
        &mut self,
        ph: &str,
        name: &str,
        lane: u32,
        ts_ns: u64,
        args: Option<ArgList>,
    ) {
        let mut fields: Vec<(&str, Json)> = vec![
            ("cat", Json::Str("fedzero".into())),
            ("name", Json::Str(name.into())),
            ("ph", Json::Str(ph.into())),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(lane as f64)),
            ("ts", Json::Num(ts_ns as f64 / 1000.0)),
        ];
        if ph == "i" {
            // Instant scope: thread.
            fields.push(("s", Json::Str("t".into())));
        }
        if let Some(a) = args {
            fields.push((
                "args",
                Json::Obj(
                    a.into_iter()
                        .map(|(k, v)| (k.to_string(), Json::Str(v)))
                        .collect(),
                ),
            ));
        }
        let mut line = Json::obj(fields).to_string();
        line.push('\n');
        if self.err.is_none() {
            if let Err(e) = self.out.write_all(line.as_bytes()) {
                self.err = Some(e);
            }
        }
    }
}

impl Tracer for ChromeTraceSink {
    fn enabled(&self) -> bool {
        true
    }

    fn now_ns(&self) -> u64 {
        self.anchor.elapsed().as_nanos().min(u64::MAX as u128) as u64
    }

    fn begin(&mut self, name: &'static str) {
        let ts = self.now_ns();
        self.emit("B", name, COORD_LANE, ts, None);
    }

    fn begin_args(&mut self, name: &'static str, args: &dyn Fn() -> ArgList) {
        let ts = self.now_ns();
        self.emit("B", name, COORD_LANE, ts, Some(args()));
    }

    fn end(&mut self, name: &'static str) {
        let ts = self.now_ns();
        self.emit("E", name, COORD_LANE, ts, None);
    }

    fn instant(&mut self, name: &'static str, args: &dyn Fn() -> ArgList) {
        let ts = self.now_ns();
        self.emit("i", name, COORD_LANE, ts, Some(args()));
    }

    fn span_at(
        &mut self,
        name: &'static str,
        lane: u32,
        start_ns: u64,
        end_ns: u64,
        args: &dyn Fn() -> ArgList,
    ) {
        self.emit("B", name, lane, start_ns, Some(args()));
        self.emit("E", name, lane, end_ns.max(start_ns), None);
    }

    fn flush(&mut self) -> Result<()> {
        if let Some(e) = self.err.take() {
            return Err(e.into());
        }
        self.out.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    /// A writer handing its bytes back to the test through a shared
    /// buffer (the sink owns its writer, so tests read via the clone).
    #[derive(Clone, Default)]
    struct SharedBuf(Arc<Mutex<Vec<u8>>>);

    impl SharedBuf {
        fn text(&self) -> String {
            String::from_utf8(self.0.lock().unwrap().clone()).unwrap()
        }
    }

    impl Write for SharedBuf {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn noop_tracer_is_inert() {
        let mut t = NoopTracer;
        assert!(!t.enabled());
        assert_eq!(t.now_ns(), 0);
        t.begin("x");
        t.end("x");
        t.instant("y", &Vec::new);
        t.span_at("z", 3, 10, 20, &Vec::new);
        t.flush().unwrap();
    }

    #[test]
    fn span_at_lines_are_pinned() {
        let buf = SharedBuf::default();
        let mut sink = ChromeTraceSink::from_writer(Box::new(buf.clone()));
        sink.span_at("shard", 2, 1500, 2750, &|| {
            vec![("range", "0..8".to_string())]
        });
        sink.flush().unwrap();
        assert_eq!(
            buf.text(),
            concat!(
                r#"{"args":{"range":"0..8"},"cat":"fedzero","name":"shard","ph":"B","pid":1,"tid":2,"ts":1.5}"#,
                "\n",
                r#"{"cat":"fedzero","name":"shard","ph":"E","pid":1,"tid":2,"ts":2.75}"#,
                "\n",
            )
        );
    }

    #[test]
    fn every_line_parses_and_durations_balance() {
        let buf = SharedBuf::default();
        let mut sink = ChromeTraceSink::from_writer(Box::new(buf.clone()));
        sink.begin("round");
        sink.begin_args("solve", &|| vec![("solver", "mc2mkp".into())]);
        sink.instant("speculation", &|| vec![("cause", "guard_mismatch".into())]);
        sink.end("solve");
        sink.end("round");
        sink.span_at("shard", 1, 5, 9, &Vec::new);
        sink.flush().unwrap();

        let mut open: Vec<(String, String)> = Vec::new();
        for line in buf.text().lines() {
            let v = Json::parse(line).expect("valid JSON line");
            let ph = v.req("ph").unwrap().as_str().unwrap().to_string();
            let name = v.req("name").unwrap().as_str().unwrap().to_string();
            let tid = v.req("tid").unwrap().as_f64().unwrap().to_string();
            assert_eq!(v.req("cat").unwrap().as_str(), Some("fedzero"));
            assert!(v.req("ts").unwrap().as_f64().unwrap() >= 0.0);
            match ph.as_str() {
                "B" => open.push((name, tid)),
                "E" => {
                    let top = open.pop().expect("E without open B");
                    assert_eq!(top, (name, tid), "spans must nest");
                }
                "i" => {}
                other => panic!("unexpected phase {other}"),
            }
        }
        assert!(open.is_empty(), "unbalanced B/E events: {open:?}");
    }

    #[test]
    fn open_append_truncates_a_torn_tail() {
        let dir = std::env::temp_dir().join("fedzero_obs_torn");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.jsonl");
        let whole = r#"{"cat":"fedzero","name":"a","ph":"B","pid":1,"tid":0,"ts":1}"#;
        std::fs::write(&path, format!("{whole}\n{{\"cat\":\"fedz")).unwrap();
        let mut sink = ChromeTraceSink::open_append(&path).unwrap();
        sink.end("a");
        sink.flush().unwrap();
        drop(sink);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2, "torn fragment dropped: {text:?}");
        assert_eq!(lines[0], whole);
        for line in lines {
            Json::parse(line).unwrap();
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn write_errors_defer_to_flush() {
        struct FailWriter;
        impl Write for FailWriter {
            fn write(&mut self, _: &[u8]) -> std::io::Result<usize> {
                Err(std::io::Error::other("disk full"))
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let mut sink = ChromeTraceSink::from_writer(Box::new(FailWriter));
        sink.begin("x"); // must not panic or error here
        sink.end("x");
        assert!(sink.flush().is_err(), "deferred error surfaces at flush");
        assert!(sink.flush().is_ok(), "error reported once");
    }
}
