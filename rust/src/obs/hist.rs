//! Fixed-bucket log₂ latency histograms (no deps, no allocation per
//! record).
//!
//! A [`LogHist`] buckets `u64` samples by bit length: bucket `i` holds
//! values in `[2^(i-1), 2^i - 1]` (bucket 0 holds exactly 0), so 65
//! fixed buckets cover the whole `u64` range and `record` is a shift +
//! two adds. Quantiles are answered as the bucket upper bound clamped to
//! the exact observed maximum — coarse (one power of two) but stable,
//! allocation-free, and cheap enough to leave on unconditionally.
//!
//! None of this state ever feeds a digest: histograms live beside the
//! [`crate::metrics::MetricsHub`] and are exported as `obs_*` gauges,
//! which the store snapshots persist but no journal entry, guard, or
//! campaign digest ever reads (fedlint R5 fences the `obs_` prefix out
//! of digest functions).

use std::collections::BTreeMap;

use crate::metrics::MetricsHub;

/// Number of buckets: bit lengths 0 (the value 0) through 64.
pub const BUCKETS: usize = 65;

/// A fixed-bucket log₂ histogram over `u64` samples.
#[derive(Clone, Debug)]
pub struct LogHist {
    buckets: [u64; BUCKETS],
    count: u64,
    max: u64,
}

impl Default for LogHist {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHist {
    /// New empty histogram.
    pub const fn new() -> Self {
        Self { buckets: [0; BUCKETS], count: 0, max: 0 }
    }

    /// Record one sample.
    pub fn record(&mut self, v: u64) {
        let idx = (64 - v.leading_zeros()) as usize;
        self.buckets[idx] += 1;
        self.count += 1;
        if v > self.max {
            self.max = v;
        }
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact maximum sample (0 when empty).
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Upper bound of the bucket holding bit length `i`.
    fn bucket_upper(i: usize) -> u64 {
        if i == 0 {
            0
        } else if i >= 64 {
            u64::MAX
        } else {
            (1u64 << i) - 1
        }
    }

    /// Quantile estimate for `q` in `[0, 1]`: the upper bound of the
    /// first bucket whose cumulative count reaches `⌈q·count⌉`, clamped
    /// to the exact maximum. Returns 0 on an empty histogram.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target =
            ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut acc = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            acc += b;
            if acc >= target {
                return Self::bucket_upper(i).min(self.max);
            }
        }
        self.max
    }

    /// Median estimate.
    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    /// 95th-percentile estimate.
    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }
}

/// Convert a wall-clock duration in seconds to whole nanoseconds
/// (negative or non-finite inputs clamp to 0).
pub fn secs_to_ns(s: f64) -> u64 {
    if s.is_finite() && s > 0.0 {
        (s * 1e9) as u64
    } else {
        0
    }
}

/// The coordinator's histogram set: phase durations, per-solver solve
/// time, and incremental dirty-set sizes. Exported to `obs_*` gauges on
/// the metrics hub (p50/p95/max per series); never read by any digest.
#[derive(Clone, Debug, Default)]
pub struct ObsHists {
    /// Scheduling-phase duration per round (ns).
    pub sched_ns: LogHist,
    /// Training-phase duration per round (ns).
    pub train_ns: LogHist,
    /// Aggregating-phase duration per round (ns).
    pub aggregate_ns: LogHist,
    /// Recosting-phase duration per round (ns).
    pub recost_ns: LogHist,
    /// Incremental dirty-set size per derived round (devices).
    pub incr_dirty: LogHist,
    /// Solve duration per effective solver (ns).
    pub solve_ns: BTreeMap<&'static str, LogHist>,
}

impl ObsHists {
    /// Record one solve duration under its effective solver name.
    pub fn record_solve(&mut self, solver: &'static str, ns: u64) {
        self.solve_ns.entry(solver).or_default().record(ns);
    }

    /// Export every non-empty series as `obs_<name>_{p50,p95,max}`
    /// gauges.
    pub fn export(&self, hub: &mut MetricsHub) {
        fn put(hub: &mut MetricsHub, name: &str, h: &LogHist) {
            if h.count() == 0 {
                return;
            }
            hub.set(&format!("obs_{name}_p50"), h.p50() as f64);
            hub.set(&format!("obs_{name}_p95"), h.p95() as f64);
            hub.set(&format!("obs_{name}_max"), h.max() as f64);
        }
        put(hub, "sched_ns", &self.sched_ns);
        put(hub, "train_ns", &self.train_ns);
        put(hub, "aggregate_ns", &self.aggregate_ns);
        put(hub, "recost_ns", &self.recost_ns);
        put(hub, "incr_dirty", &self.incr_dirty);
        for (solver, h) in &self.solve_ns {
            put(hub, &format!("solve_ns_{solver}"), h);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_hist_is_all_zero() {
        let h = LogHist::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.p50(), 0);
        assert_eq!(h.p95(), 0);
    }

    #[test]
    fn buckets_by_bit_length() {
        let mut h = LogHist::new();
        for v in [0u64, 1, 2, 3, 4, 7, 8, 1000] {
            h.record(v);
        }
        assert_eq!(h.count(), 8);
        assert_eq!(h.max(), 1000);
        // Median target is the 4th sample (value 3 → bucket upper 3).
        assert_eq!(h.p50(), 3);
        // p95 target is the 8th sample; bucket upper 1023 clamps to max.
        assert_eq!(h.p95(), 1000);
    }

    #[test]
    fn extreme_values_do_not_overflow() {
        let mut h = LogHist::new();
        h.record(u64::MAX);
        h.record(u64::MAX - 1);
        assert_eq!(h.max(), u64::MAX);
        assert_eq!(h.p95(), u64::MAX);
    }

    #[test]
    fn secs_conversion_clamps() {
        assert_eq!(secs_to_ns(1.5e-3), 1_500_000);
        assert_eq!(secs_to_ns(-1.0), 0);
        assert_eq!(secs_to_ns(f64::NAN), 0);
    }

    #[test]
    fn export_writes_quantile_gauges() {
        let mut o = ObsHists::default();
        o.sched_ns.record(1_000);
        o.record_solve("mc2mkp", 2_000);
        o.record_solve("mc2mkp", 4_000);
        let mut hub = MetricsHub::new();
        o.export(&mut hub);
        assert_eq!(hub.gauge("obs_sched_ns_max"), Some(1_000.0));
        assert!(hub.gauge("obs_solve_ns_mc2mkp_p50").is_some());
        assert_eq!(hub.gauge("obs_solve_ns_mc2mkp_max"), Some(4_000.0));
        // Empty series stay absent.
        assert_eq!(hub.gauge("obs_train_ns_p50"), None);
    }
}
