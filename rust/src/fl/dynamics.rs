//! Dynamic fleet behaviour (paper §6 future work: "handle dynamic changes
//! in the system — changes in the cost behavior or loss of a device").
//!
//! * [`Availability`] — per-device online/offline churn (a two-state
//!   Markov chain) deciding who can be selected each round;
//! * [`CostDrift`] — multiplicative drift of a device's energy profile
//!   over rounds (thermal conditions, battery aging, co-running apps);
//! * [`Dropout`] — mid-round failure: the device burns energy for the
//!   tasks it completed but its update is lost.
//!
//! The server consumes these through [`DynamicsConfig`]; all effects are
//! seeded and reproducible.

use crate::util::rng::Rng;

/// Two-state (online/offline) Markov availability model.
#[derive(Clone, Debug)]
pub struct Availability {
    /// P(offline → online) per round.
    pub p_join: f64,
    /// P(online → offline) per round.
    pub p_leave: f64,
    online: Vec<bool>,
}

impl Availability {
    /// All devices start online.
    pub fn new(n: usize, p_join: f64, p_leave: f64) -> Self {
        Self { p_join, p_leave, online: vec![true; n] }
    }

    /// Rebuild from persisted per-device states (store snapshot restore).
    pub fn from_states(p_join: f64, p_leave: f64, online: Vec<bool>) -> Self {
        Self { p_join, p_leave, online }
    }

    /// Current per-device online flags (what store snapshots persist).
    pub fn states(&self) -> &[bool] {
        &self.online
    }

    /// Advance one round; returns the indices of online devices.
    pub fn step(&mut self, rng: &mut Rng) -> Vec<usize> {
        for state in self.online.iter_mut() {
            *state = if *state {
                !rng.bool(self.p_leave)
            } else {
                rng.bool(self.p_join)
            };
        }
        self.onlines()
    }

    /// Currently-online device indices.
    pub fn onlines(&self) -> Vec<usize> {
        self.online
            .iter()
            .enumerate()
            .filter(|(_, &o)| o)
            .map(|(i, _)| i)
            .collect()
    }

    /// Whether device `i` is online.
    pub fn is_online(&self, i: usize) -> bool {
        self.online[i]
    }

    /// Force a state (tests / trace replay).
    pub fn set(&mut self, i: usize, online: bool) {
        self.online[i] = online;
    }

    /// Stationary online probability of the chain.
    pub fn stationary(&self) -> f64 {
        if self.p_join + self.p_leave == 0.0 {
            1.0
        } else {
            self.p_join / (self.p_join + self.p_leave)
        }
    }
}

/// Multiplicative random-walk drift on per-device energy scale.
#[derive(Clone, Debug)]
pub struct CostDrift {
    /// Per-round log-normal drift sigma (0 disables).
    pub sigma: f64,
    scale: Vec<f64>,
}

impl CostDrift {
    /// Unit scales for `n` devices.
    pub fn new(n: usize, sigma: f64) -> Self {
        Self { sigma, scale: vec![1.0; n] }
    }

    /// Rebuild from persisted per-device scales (store snapshot restore).
    pub fn from_scales(sigma: f64, scale: Vec<f64>) -> Self {
        Self { sigma, scale }
    }

    /// Current per-device scales (what store snapshots persist).
    pub fn scales(&self) -> &[f64] {
        &self.scale
    }

    /// Advance one round.
    pub fn step(&mut self, rng: &mut Rng) {
        if self.sigma == 0.0 {
            return;
        }
        for s in self.scale.iter_mut() {
            *s = (*s * rng.lognormal(0.0, self.sigma)).clamp(0.25, 4.0);
        }
    }

    /// Current energy multiplier of device `i`.
    pub fn scale(&self, i: usize) -> f64 {
        self.scale[i]
    }
}

/// Mid-round dropout model.
#[derive(Clone, Copy, Debug)]
pub struct Dropout {
    /// Probability that a participating device fails before uploading.
    pub p_fail: f64,
}

impl Dropout {
    /// Sample whether a device fails this round, and if so, the fraction of
    /// its assigned work it completed before dying (energy is still burnt
    /// for that fraction).
    pub fn sample(&self, rng: &mut Rng) -> Option<f64> {
        if rng.bool(self.p_fail) {
            Some(rng.f64())
        } else {
            None
        }
    }
}

/// Bundle consumed by the server.
#[derive(Clone, Debug)]
pub struct DynamicsConfig {
    pub availability: Option<Availability>,
    pub drift: Option<CostDrift>,
    pub dropout: Option<Dropout>,
}

impl DynamicsConfig {
    /// Static fleet: everything disabled.
    pub fn none() -> Self {
        Self { availability: None, drift: None, dropout: None }
    }

    /// A realistic mobile-fleet preset.
    pub fn mobile(n: usize) -> Self {
        Self {
            availability: Some(Availability::new(n, 0.3, 0.1)),
            drift: Some(CostDrift::new(n, 0.05)),
            dropout: Some(Dropout { p_fail: 0.05 }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn availability_stationary_fraction() {
        let mut av = Availability::new(500, 0.3, 0.1);
        let mut rng = Rng::new(1);
        // Burn in, then measure.
        for _ in 0..50 {
            av.step(&mut rng);
        }
        let mut total = 0usize;
        let rounds = 200;
        for _ in 0..rounds {
            total += av.step(&mut rng).len();
        }
        let frac = total as f64 / (rounds * 500) as f64;
        let expect = av.stationary(); // 0.75
        assert!((frac - expect).abs() < 0.05, "frac {frac} vs {expect}");
    }

    #[test]
    fn availability_deterministic() {
        let mut a = Availability::new(20, 0.5, 0.5);
        let mut b = Availability::new(20, 0.5, 0.5);
        let mut ra = Rng::new(9);
        let mut rb = Rng::new(9);
        for _ in 0..10 {
            assert_eq!(a.step(&mut ra), b.step(&mut rb));
        }
    }

    #[test]
    fn zero_churn_keeps_everyone_online() {
        let mut av = Availability::new(10, 0.0, 0.0);
        let mut rng = Rng::new(2);
        assert_eq!(av.step(&mut rng).len(), 10);
        assert_eq!(av.stationary(), 1.0);
    }

    #[test]
    fn drift_stays_in_bounds_and_moves() {
        let mut d = CostDrift::new(10, 0.2);
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            d.step(&mut rng);
            for i in 0..10 {
                assert!((0.25..=4.0).contains(&d.scale(i)));
            }
        }
        // After many steps scales should have diversified.
        let distinct = (0..10)
            .map(|i| (d.scale(i) * 1e6) as i64)
            .collect::<std::collections::BTreeSet<_>>();
        assert!(distinct.len() > 5);
    }

    #[test]
    fn zero_sigma_never_moves() {
        let mut d = CostDrift::new(4, 0.0);
        let mut rng = Rng::new(4);
        d.step(&mut rng);
        assert!((0..4).all(|i| d.scale(i) == 1.0));
    }

    #[test]
    fn state_roundtrip_continues_identically() {
        // Persist-and-rebuild mid-run must continue the exact trajectory —
        // the property coordinator snapshot/restore relies on.
        let mut av = Availability::new(16, 0.4, 0.2);
        let mut dr = CostDrift::new(16, 0.1);
        let mut rng = Rng::new(8);
        for _ in 0..7 {
            av.step(&mut rng);
            dr.step(&mut rng);
        }
        let mut av2 =
            Availability::from_states(av.p_join, av.p_leave, av.states().to_vec());
        let mut dr2 = CostDrift::from_scales(dr.sigma, dr.scales().to_vec());
        let mut rng2 = Rng::from_state(rng.state());
        for _ in 0..7 {
            assert_eq!(av.step(&mut rng), av2.step(&mut rng2));
            dr.step(&mut rng);
            dr2.step(&mut rng2);
            assert_eq!(dr.scales(), dr2.scales());
        }
    }

    #[test]
    fn dropout_rate_matches() {
        let dropout = Dropout { p_fail: 0.3 };
        let mut rng = Rng::new(5);
        let fails = (0..10_000)
            .filter(|_| dropout.sample(&mut rng).is_some())
            .count();
        assert!((2_700..3_300).contains(&fails), "{fails}");
    }

    #[test]
    fn dropout_fraction_in_unit_interval() {
        let dropout = Dropout { p_fail: 1.0 };
        let mut rng = Rng::new(6);
        for _ in 0..100 {
            let f = dropout.sample(&mut rng).unwrap();
            assert!((0.0..1.0).contains(&f));
        }
    }
}
