//! Federated learning: server, simulated clients, aggregation, and
//! synthetic data — the experiment platform the paper's §6 envisions
//! ("conduct experiments in FL platforms to evaluate the impact of our
//! algorithms compared to other solutions ... in energy consumption,
//! execution time, and accuracy").
//!
//! The round loop itself lives in [`crate::coordinator`]; this module
//! contributes the ML half — [`server::FlBackend`], a
//! [`crate::coordinator::RoundBackend`] where every device with `x_i > 0`
//! runs `x_i` real PJRT training steps on its own (non-IID) shard from the
//! global model, followed by FedAvg aggregation weighted by `x_i` and
//! held-out evaluation — plus [`server::Server`], the façade that wires
//! artifacts, data, and a sampled fleet into a coordinator.

pub mod aggregate;
pub mod client;
pub mod data;
pub mod dynamics;
pub mod server;

pub use server::Server;
