//! Federated learning: server, simulated clients, aggregation, and
//! synthetic data — the experiment platform the paper's §6 envisions
//! ("conduct experiments in FL platforms to evaluate the impact of our
//! algorithms compared to other solutions ... in energy consumption,
//! execution time, and accuracy").
//!
//! Per round (`server::Server::round`):
//! 1. sample participating devices;
//! 2. derive the Minimal Cost FL Schedule instance `(R, T, U, L, C)` from
//!    their power models, data sizes and batteries;
//! 3. run the configured scheduler policy (one of the paper's optimal
//!    algorithms or a baseline);
//! 4. every device with `x_i > 0` runs `x_i` real PJRT training steps on
//!    its own (non-IID) shard, starting from the global model;
//! 5. energy is integrated per device from its power model;
//! 6. FedAvg aggregation weighted by `x_i`;
//! 7. the global model is evaluated on held-out data.

pub mod aggregate;
pub mod client;
pub mod data;
pub mod dynamics;
pub mod server;

pub use server::Server;
