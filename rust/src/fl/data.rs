//! Synthetic datasets and non-IID partitioning.
//!
//! * **Gaussian mixture** (for the MLP): `classes` isotropic clusters in
//!   `dim` dimensions with unit noise — learnable but not trivial.
//! * **Markov bytes** (for the transformer LM): an order-1 Markov chain
//!   over 256 symbols with a sparse transition table (each state has few
//!   likely successors), giving a per-token entropy far below `ln 256` so
//!   the loss curve has room to fall.
//!
//! Partitioning follows the FL literature's standard non-IID protocol:
//! Dirichlet(α) label/state skew per device — small α gives each device a
//! peaked distribution (heterogeneous data), large α approaches IID.

use crate::error::{FedError, Result};
use crate::runtime::{Dtype, ModelSpec};
use crate::util::rng::Rng;

/// A batch ready for the runtime: features XOR tokens, plus labels.
#[derive(Clone, Debug)]
pub struct Batch {
    pub x_f32: Vec<f32>,
    pub x_i32: Vec<i32>,
    pub y: Vec<i32>,
}

/// A device's local data: indices into the global dataset.
#[derive(Clone, Debug, Default)]
pub struct Shard {
    pub indices: Vec<usize>,
}

impl Shard {
    /// Number of local samples.
    pub fn len(&self) -> usize {
        self.indices.len()
    }

    /// True if the shard is empty.
    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }
}

/// The global synthetic dataset.
#[derive(Clone, Debug)]
pub enum Dataset {
    /// Gaussian-mixture classification: row-major `features[n * dim]`.
    Mixture {
        features: Vec<f32>,
        labels: Vec<i32>,
        n: usize,
        dim: usize,
        classes: usize,
    },
    /// Markov byte stream: windows of `seq + 1` tokens are training
    /// samples (`x = w[..seq]`, `y = w[1..]`).
    Bytes { stream: Vec<i32>, seq: usize },
}

impl Dataset {
    /// Synthesize a dataset matching a model spec.
    pub fn synth(spec: &ModelSpec, n_samples: usize, rng: &mut Rng) -> Dataset {
        match spec.input_dtype {
            Dtype::F32 => {
                let dim = spec.input_shape[1];
                let classes = spec.num_classes;
                // Cluster centers at radius 2 (unit noise → Bayes error small
                // but nonzero, features O(1) so He-init logits start tame).
                let centers: Vec<f64> = (0..classes * dim)
                    .map(|_| rng.normal() * 2.0)
                    .collect();
                let mut features = Vec::with_capacity(n_samples * dim);
                let mut labels = Vec::with_capacity(n_samples);
                for _ in 0..n_samples {
                    let c = rng.index(classes);
                    labels.push(c as i32);
                    for d in 0..dim {
                        features.push((centers[c * dim + d] + rng.normal()) as f32);
                    }
                }
                Dataset::Mixture { features, labels, n: n_samples, dim, classes }
            }
            Dtype::S32 => {
                let seq = spec.input_shape[1];
                let vocab = spec.num_classes;
                // Sparse Markov chain: each state transitions to one of 4
                // preferred successors with prob 0.85, else uniform.
                let fanout = 4;
                let succ: Vec<usize> =
                    (0..vocab * fanout).map(|_| rng.index(vocab)).collect();
                let len = n_samples * (seq + 1);
                let mut stream = Vec::with_capacity(len);
                let mut state = rng.index(vocab);
                for _ in 0..len {
                    stream.push(state as i32);
                    state = if rng.bool(0.85) {
                        succ[state * fanout + rng.index(fanout)]
                    } else {
                        rng.index(vocab)
                    };
                }
                Dataset::Bytes { stream, seq }
            }
        }
    }

    /// Number of addressable samples (mixture rows or token windows).
    pub fn len(&self) -> usize {
        match self {
            Dataset::Mixture { n, .. } => *n,
            Dataset::Bytes { stream, seq } => stream.len().saturating_sub(*seq),
        }
    }

    /// True if no samples.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Split into a train shard and a held-out eval shard (`eval_count`
    /// samples from the tail — same distribution, disjoint indices).
    pub fn split(&self, eval_count: usize) -> (Shard, Shard) {
        let n = self.len();
        let eval_count = eval_count.min(n / 4);
        let n_train = n - eval_count;
        (
            Shard { indices: (0..n_train).collect() },
            Shard { indices: (n_train..n).collect() },
        )
    }

    /// Dirichlet(α) non-IID partition of a shard into `n_devices` shards.
    ///
    /// Mixture: per-class Dirichlet proportions (label skew).
    /// Bytes: contiguous stream segments with Dirichlet sizes (each device
    /// sees its own region of the chain — topic skew).
    pub fn partition(
        &self,
        within: &Shard,
        n_devices: usize,
        alpha: f64,
        rng: &mut Rng,
    ) -> Vec<Shard> {
        assert!(n_devices > 0);
        let mut shards = vec![Shard::default(); n_devices];
        match self {
            Dataset::Mixture { labels, classes, .. } => {
                for c in 0..*classes {
                    let idx: Vec<usize> = within
                        .indices
                        .iter()
                        .copied()
                        .filter(|&i| labels[i] as usize == c)
                        .collect();
                    let props = rng.dirichlet(alpha, n_devices);
                    // Assign each sample of class c to a device drawn from
                    // the class's device distribution.
                    for &i in &idx {
                        shards[rng.categorical(&props)].indices.push(i);
                    }
                }
            }
            Dataset::Bytes { .. } => {
                let n = within.len();
                let props = rng.dirichlet(alpha, n_devices);
                let mut start = 0usize;
                for (d, p) in props.iter().enumerate() {
                    let take = if d == n_devices - 1 {
                        n - start
                    } else {
                        ((p * n as f64) as usize).min(n - start)
                    };
                    shards[d].indices = within.indices[start..start + take].to_vec();
                    start += take;
                }
            }
        }
        shards
    }

    /// Sample one mini-batch from a shard (with replacement — FL clients
    /// commonly run multiple local epochs over small shards).
    pub fn batch(&self, spec: &ModelSpec, shard: &Shard, rng: &mut Rng) -> Result<Batch> {
        if shard.is_empty() {
            return Err(FedError::Fl("cannot batch from empty shard".into()));
        }
        let b = spec.batch;
        match self {
            Dataset::Mixture { features, labels, dim, .. } => {
                let mut x = Vec::with_capacity(b * dim);
                let mut y = Vec::with_capacity(b);
                for _ in 0..b {
                    let i = shard.indices[rng.index(shard.len())];
                    x.extend_from_slice(&features[i * dim..(i + 1) * dim]);
                    y.push(labels[i]);
                }
                Ok(Batch { x_f32: x, x_i32: Vec::new(), y })
            }
            Dataset::Bytes { stream, seq } => {
                let mut x = Vec::with_capacity(b * seq);
                let mut y = Vec::with_capacity(b * seq);
                for _ in 0..b {
                    let w = shard.indices[rng.index(shard.len())];
                    x.extend_from_slice(&stream[w..w + seq]);
                    y.extend_from_slice(&stream[w + 1..w + seq + 1]);
                }
                Ok(Batch { x_f32: Vec::new(), x_i32: x, y })
            }
        }
    }

    /// A shard covering the whole dataset (held-out evaluation uses a
    /// fresh dataset instance, IID by construction).
    pub fn full_shard(&self) -> Shard {
        Shard { indices: (0..self.len()).collect() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{Dtype, ModelSpec};

    fn mlp_spec() -> ModelSpec {
        ModelSpec {
            name: "mlp".into(),
            family: "mlp".into(),
            train_hlo: "/tmp/a".into(),
            eval_hlo: "/tmp/b".into(),
            params_file: "/tmp/c".into(),
            param_shapes: vec![vec![4, 8], vec![8]],
            param_count: 40,
            n_param_tensors: 2,
            batch: 16,
            lr: 0.1,
            input_shape: vec![16, 4],
            input_dtype: Dtype::F32,
            label_shape: vec![16],
            label_dtype: Dtype::S32,
            num_classes: 3,
        }
    }

    fn tfm_spec() -> ModelSpec {
        ModelSpec {
            input_shape: vec![4, 8],
            input_dtype: Dtype::S32,
            label_shape: vec![4, 8],
            batch: 4,
            num_classes: 32,
            ..mlp_spec()
        }
    }

    #[test]
    fn mixture_shapes_and_labels() {
        let mut rng = Rng::new(1);
        let ds = Dataset::synth(&mlp_spec(), 500, &mut rng);
        assert_eq!(ds.len(), 500);
        if let Dataset::Mixture { features, labels, dim, classes, .. } = &ds {
            assert_eq!(features.len(), 500 * dim);
            assert!(labels.iter().all(|&l| (l as usize) < *classes));
        } else {
            panic!("wrong variant");
        }
    }

    #[test]
    fn mixture_is_learnable_structure() {
        // Same-class points are closer to their class mean than to others
        // (sanity that clusters actually separate).
        let mut rng = Rng::new(2);
        let spec = mlp_spec();
        let ds = Dataset::synth(&spec, 2000, &mut rng);
        if let Dataset::Mixture { features, labels, dim, classes, n } = &ds {
            let mut means = vec![0.0f64; classes * dim];
            let mut counts = vec![0usize; *classes];
            for i in 0..*n {
                let c = labels[i] as usize;
                counts[c] += 1;
                for d in 0..*dim {
                    means[c * dim + d] += features[i * dim + d] as f64;
                }
            }
            for c in 0..*classes {
                for d in 0..*dim {
                    means[c * dim + d] /= counts[c].max(1) as f64;
                }
            }
            let mut correct = 0;
            for i in 0..200 {
                let mut best = 0;
                let mut best_d = f64::INFINITY;
                for c in 0..*classes {
                    let dist: f64 = (0..*dim)
                        .map(|d| {
                            let diff = features[i * dim + d] as f64 - means[c * dim + d];
                            diff * diff
                        })
                        .sum();
                    if dist < best_d {
                        best_d = dist;
                        best = c;
                    }
                }
                if best == labels[i] as usize {
                    correct += 1;
                }
            }
            assert!(correct > 150, "nearest-mean acc {correct}/200");
        }
    }

    #[test]
    fn bytes_windows() {
        let mut rng = Rng::new(3);
        let ds = Dataset::synth(&tfm_spec(), 100, &mut rng);
        assert!(!ds.is_empty());
        if let Dataset::Bytes { stream, seq } = &ds {
            assert_eq!(*seq, 8);
            assert!(stream.iter().all(|&t| (0..32).contains(&t)));
        }
    }

    #[test]
    fn partition_covers_everything_once_mixture() {
        let mut rng = Rng::new(4);
        let ds = Dataset::synth(&mlp_spec(), 1000, &mut rng);
        let shards = ds.partition(&ds.full_shard(), 8, 0.5, &mut rng);
        let mut all: Vec<usize> =
            shards.iter().flat_map(|s| s.indices.iter().copied()).collect();
        all.sort_unstable();
        assert_eq!(all.len(), 1000);
        all.dedup();
        assert_eq!(all.len(), 1000);
    }

    #[test]
    fn low_alpha_is_skewed() {
        let mut rng = Rng::new(5);
        let ds = Dataset::synth(&mlp_spec(), 3000, &mut rng);
        let shards = ds.partition(&ds.full_shard(), 6, 0.1, &mut rng);
        // With α = 0.1 at least one device should be heavily skewed toward
        // one class.
        if let Dataset::Mixture { labels, classes, .. } = &ds {
            let mut max_frac = 0.0f64;
            for s in &shards {
                if s.len() < 30 {
                    continue;
                }
                let mut counts = vec![0usize; *classes];
                for &i in &s.indices {
                    counts[labels[i] as usize] += 1;
                }
                let m = *counts.iter().max().unwrap() as f64 / s.len() as f64;
                max_frac = max_frac.max(m);
            }
            assert!(max_frac > 0.5, "no skew found: {max_frac}");
        }
    }

    #[test]
    fn batch_shapes() {
        let mut rng = Rng::new(6);
        let spec = mlp_spec();
        let ds = Dataset::synth(&spec, 200, &mut rng);
        let shard = ds.full_shard();
        let b = ds.batch(&spec, &shard, &mut rng).unwrap();
        assert_eq!(b.x_f32.len(), 16 * 4);
        assert_eq!(b.y.len(), 16);

        let tspec = tfm_spec();
        let tds = Dataset::synth(&tspec, 100, &mut rng);
        let tb = tds.batch(&tspec, &tds.full_shard(), &mut rng).unwrap();
        assert_eq!(tb.x_i32.len(), 4 * 8);
        assert_eq!(tb.y.len(), 4 * 8);
    }

    #[test]
    fn empty_shard_errors() {
        let mut rng = Rng::new(7);
        let spec = mlp_spec();
        let ds = Dataset::synth(&spec, 50, &mut rng);
        assert!(ds.batch(&spec, &Shard::default(), &mut rng).is_err());
    }
}
