//! The FL coordinator: device registry, per-round scheduling, dispatch,
//! aggregation, evaluation, and energy accounting.

use std::path::Path;

use crate::config::{Policy, TrainConfig};
use crate::energy::power::Behavior;
use crate::energy::profiles::{BehaviorMix, Fleet};
use crate::error::{FedError, Result};
use crate::fl::aggregate::fedavg;
use crate::fl::client::SimClient;
use crate::fl::data::Dataset;
use crate::fl::dynamics::DynamicsConfig;
use crate::sched::costs::CostFn;
use crate::metrics::{EnergyLedger, MetricsHub, RoundLog, Timer, TrainingLog};
use crate::sched::instance::Instance;
use crate::sched::{auto, validate};
use crate::runtime::{Dtype, ModelRuntime, ParamSet};
use crate::util::rng::Rng;

/// Behaviour mix used when the config does not pin one (kept homogeneous so
/// the specialized algorithms apply; `Mixed` exercises the DP).
pub const DEFAULT_MIX: BehaviorMix = BehaviorMix::Homogeneous(Behavior::Linear);

/// The federated-learning server.
pub struct Server {
    cfg: TrainConfig,
    runtime: ModelRuntime,
    dataset: Dataset,
    /// Fixed held-out batches (as PJRT literals) reused every round, so the
    /// eval series is comparable across rounds and policies.
    eval_batches: Vec<(xla::Literal, xla::Literal)>,
    clients: Vec<SimClient>,
    global: ParamSet,
    rng: Rng,
    dynamics: DynamicsConfig,
    pub ledger: EnergyLedger,
    pub metrics: MetricsHub,
    pub log: TrainingLog,
}

impl Server {
    /// Build a server: load artifacts, synthesize + partition data, sample
    /// the fleet.
    pub fn new(cfg: TrainConfig, mix: BehaviorMix) -> Result<Server> {
        cfg.validate()?;
        let runtime = ModelRuntime::load(Path::new(&cfg.artifacts_dir), &cfg.model)?;
        let mut rng = Rng::new(cfg.seed);

        let n_samples = 4000.max(cfg.devices * 64) + 512;
        let mut data_rng = rng.fork();
        let dataset = Dataset::synth(runtime.spec(), n_samples, &mut data_rng);
        // Same distribution, disjoint tail indices for evaluation.
        let (train_shard, eval_shard) = dataset.split(512);

        // Freeze 8 held-out batches as literals once, so the eval series is
        // comparable across rounds and policies.
        let mut eval_batches = Vec::with_capacity(8);
        for _ in 0..8 {
            let b = dataset.batch(runtime.spec(), &eval_shard, &mut data_rng)?;
            let x = match runtime.spec().input_dtype {
                Dtype::F32 => runtime.input_literal_f32(&b.x_f32)?,
                Dtype::S32 => runtime.input_literal_i32(&b.x_i32)?,
            };
            let y = runtime.label_literal(&b.y)?;
            eval_batches.push((x, y));
        }

        let fleet = Fleet::sample(cfg.devices, mix, &mut rng);
        let shards =
            dataset.partition(&train_shard, cfg.devices, cfg.dirichlet_alpha, &mut rng);
        let clients: Vec<SimClient> = fleet
            .devices
            .into_iter()
            .zip(shards)
            .map(|(d, s)| {
                let crng = rng.fork();
                SimClient::new(d, s, crng)
            })
            .collect();

        let global = runtime.initial_params();
        Ok(Server {
            cfg,
            runtime,
            dataset,
            eval_batches,
            clients,
            global,
            rng,
            dynamics: DynamicsConfig::none(),
            ledger: EnergyLedger::new(),
            metrics: MetricsHub::new(),
            log: TrainingLog::new(),
        })
    }

    /// Current global parameters.
    pub fn global_params(&self) -> &ParamSet {
        &self.global
    }

    /// The training configuration.
    pub fn cfg(&self) -> &TrainConfig {
        &self.cfg
    }

    /// Install dynamic fleet behaviour (availability churn, cost drift,
    /// mid-round dropout — paper §6 future work).
    pub fn set_dynamics(&mut self, dynamics: DynamicsConfig) {
        self.dynamics = dynamics;
    }

    /// The runtime (for external evaluation).
    pub fn runtime(&self) -> &ModelRuntime {
        &self.runtime
    }

    /// Build this round's scheduling instance over the selected clients.
    ///
    /// `U_i` = device data/battery cap, further clamped to the device's
    /// *shard* size (can't train on more distinct batches than it has
    /// data for — over-representation guard [3]); `L_i` = configured
    /// minimum participation; `T` clamped to fleet capacity.
    fn build_instance(&self, selected: &[usize]) -> Result<(Instance, usize)> {
        let raw_uppers: Vec<usize> = selected
            .iter()
            .map(|&c| {
                let cl = &self.clients[c];
                cl.device.upper_limit().min(cl.data_len())
            })
            .collect();
        let capacity: usize = raw_uppers.iter().sum();
        if capacity == 0 {
            return Err(FedError::Fl("selected devices have no capacity".into()));
        }
        let t = self.cfg.tasks_per_round.min(capacity);

        // Over-representation guard (§6): cap any device at max_share · T,
        // doubling the cap until the capped fleet can still absorb T.
        let mut cap = ((t as f64 * self.cfg.max_share).ceil() as usize).max(1);
        let uppers: Vec<usize> = loop {
            let capped: Vec<usize> = raw_uppers.iter().map(|&u| u.min(cap)).collect();
            if capped.iter().sum::<usize>() >= t {
                break capped;
            }
            cap *= 2;
        };

        // Cost drift scales the scheduler-visible cost exactly as it scales
        // the measured energy — the profiler tracks the drift.
        let drift_scale = |slot: usize, c: usize| -> CostFn {
            let base = self.clients[c].device.cost_fn();
            match &self.dynamics.drift {
                Some(d) => {
                    let _ = slot;
                    CostFn::Scaled { weight: d.scale(c), inner: Box::new(base) }
                }
                None => base,
            }
        };
        let lower: Vec<usize> = uppers
            .iter()
            .map(|&u| self.cfg.min_tasks.min(u))
            .collect();
        // ΣL must not exceed T; relax lower limits if the config overshoots.
        let sum_l: usize = lower.iter().sum();
        let lower = if sum_l > t { vec![0; uppers.len()] } else { lower };
        let costs = selected
            .iter()
            .enumerate()
            .map(|(slot, &c)| drift_scale(slot, c))
            .collect();
        Ok((Instance::new(t, lower, uppers, costs)?, t))
    }

    /// Execute one round; returns the logged row.
    pub fn round(&mut self, round_idx: usize) -> Result<RoundLog> {
        // 0. advance fleet dynamics.
        if let Some(d) = self.dynamics.drift.as_mut() {
            d.step(&mut self.rng);
        }
        let pool: Vec<usize> = match self.dynamics.availability.as_mut() {
            Some(av) => av.step(&mut self.rng),
            None => (0..self.clients.len()).collect(),
        };
        if pool.is_empty() {
            // Nobody online: an empty round (no energy, model unchanged).
            self.ledger.begin_round();
            let eval_loss = self.evaluate()?;
            let row = RoundLog {
                round: round_idx,
                policy: self.cfg.policy.to_string(),
                loss: eval_loss,
                energy_j: 0.0,
                sched_time_s: 0.0,
                train_time_s: 0.0,
                participants: 0,
                tasks: 0,
            };
            self.metrics.inc("empty_rounds", 1);
            self.log.push(row.clone());
            return Ok(row);
        }

        // 1. participant selection (FedAvg's client fraction C) from the
        //    online pool.
        let n = pool.len();
        let k = ((self.clients.len() as f64 * self.cfg.participation).ceil() as usize)
            .clamp(1, n);
        let picks = self.rng.sample_indices(n, k);
        let selected: Vec<usize> = picks.iter().map(|&i| pool[i]).collect();

        // 2–3. schedule.
        let (instance, t) = self.build_instance(&selected)?;
        let timer = Timer::start();
        let schedule = auto::solve_with(&instance, self.cfg.policy, &mut self.rng)?;
        let sched_time_s = timer.elapsed_s();
        validate::check(&instance, &schedule)?;
        let predicted_j = validate::total_cost(&instance, &schedule);

        // 4. local training on every device with x_i > 0.
        self.ledger.begin_round();
        let wall = Timer::start();
        let mut updates = Vec::new();
        let mut sim_time_s = 0.0f64;
        let mut loss_sum = 0.0;
        let mut loss_n = 0usize;
        for (slot, &c) in selected.iter().enumerate() {
            let tasks = schedule.get(slot);
            if tasks == 0 {
                continue;
            }
            // Mid-round dropout: the device burns energy for the fraction
            // of work it completed, but its update is lost (paper §6's
            // "loss of a device").
            let failed_at = self
                .dynamics
                .dropout
                .as_ref()
                .and_then(|d| d.sample(&mut self.rng));
            let drift = self
                .dynamics
                .drift
                .as_ref()
                .map(|d| d.scale(c))
                .unwrap_or(1.0);
            if let Some(frac) = failed_at {
                let done = ((tasks as f64) * frac).floor() as usize;
                let wasted = self.clients[c].device.power.energy_j(done) * drift;
                self.ledger.record(self.clients[c].device.id, wasted);
                self.metrics.inc("dropouts", 1);
                continue;
            }
            let mut update = {
                let client = &mut self.clients[c];
                client.local_train(&self.runtime, &self.dataset, &self.global, tasks)?
            };
            update.energy_j *= drift;
            self.ledger.record(update.device, update.energy_j);
            sim_time_s = sim_time_s.max(update.sim_time_s); // devices run in parallel
            loss_sum += update.mean_loss * update.tasks as f64;
            loss_n += update.tasks;
            updates.push((update.params.clone(), update.tasks as f64));
        }
        let train_time_s = wall.elapsed_s();

        // 5. aggregate.
        if !updates.is_empty() {
            self.global = fedavg(&updates)?;
        }

        // 6. held-out evaluation.
        let eval_loss = self.evaluate()?;

        let row = RoundLog {
            round: round_idx,
            policy: self.cfg.policy.to_string(),
            loss: eval_loss,
            energy_j: self.ledger.rounds().last().copied().unwrap_or(0.0),
            sched_time_s,
            train_time_s,
            participants: updates.len(),
            tasks: t,
        };
        self.metrics.inc("rounds", 1);
        self.metrics.inc("tasks", t as u64);
        self.metrics.set("train_loss", if loss_n > 0 { loss_sum / loss_n as f64 } else { 0.0 });
        self.metrics.set("eval_loss", eval_loss);
        self.metrics.set("sim_round_time_s", sim_time_s);
        self.metrics.set("predicted_energy_j", predicted_j);
        self.log.push(row.clone());
        Ok(row)
    }

    /// Held-out loss of the global model: mean over the frozen eval batches.
    pub fn evaluate(&mut self) -> Result<f64> {
        let mut sum = 0.0f64;
        for (x, y) in &self.eval_batches {
            sum += self.runtime.eval_step(&self.global, x, y)? as f64;
        }
        Ok(sum / self.eval_batches.len() as f64)
    }

    /// Run the full configured training; returns the log.
    pub fn run(&mut self) -> Result<&TrainingLog> {
        for r in 0..self.cfg.rounds {
            let row = self.round(r)?;
            if let Some(target) = self.cfg.target_loss {
                if row.loss <= target {
                    log::info!("target loss {target} reached at round {r}");
                    break;
                }
            }
        }
        Ok(&self.log)
    }

    /// Convenience: run training with a given policy, returning
    /// `(final_loss, total_energy_j)` — used by the comparison experiments.
    pub fn train_once(
        mut cfg: TrainConfig,
        policy: Policy,
        mix: BehaviorMix,
    ) -> Result<(f64, f64)> {
        cfg.policy = policy;
        let mut server = Server::new(cfg, mix)?;
        server.run()?;
        Ok((
            server.log.final_loss().unwrap_or(f64::NAN),
            server.log.total_energy(),
        ))
    }
}
