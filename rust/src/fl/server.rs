//! The FL server: the PJRT-backed [`RoundBackend`] plus a thin façade over
//! the [`Coordinator`] state machine.
//!
//! The server no longer owns the round loop — scheduling, dropout,
//! energy accounting, battery re-costing, and per-round metrics all live
//! in [`crate::coordinator`], which derives each round's instance as a
//! class-deduplicated [`crate::sched::fleet::FleetInstance`] (identical
//! simulated clients collapse into classes; see [`Server::fleet_dedup`]).
//! What remains here is the ML side:
//! loading artifacts, partitioning data, running real PJRT training steps
//! on simulated clients, FedAvg aggregation, and held-out evaluation.

use std::path::Path;

use crate::config::{Policy, TrainConfig};
use crate::coordinator::{
    BackendState, Coordinator, CoordinatorConfig, DeviceOutcome, KnobSet,
    ManagedDevice, RoundBackend, RoundPlan,
};
use crate::energy::power::Behavior;
use crate::energy::profiles::{BehaviorMix, Fleet};
use crate::error::{FedError, Result};
use crate::fl::aggregate::fedavg;
use crate::fl::client::SimClient;
use crate::fl::data::Dataset;
use crate::metrics::{EnergyLedger, MetricsHub, RoundLog, TrainingLog};
use crate::runtime::{Dtype, ModelRuntime, ParamSet};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Behaviour mix used when the config does not pin one (kept homogeneous so
/// the specialized algorithms apply; `Mixed` exercises the DP).
pub const DEFAULT_MIX: BehaviorMix = BehaviorMix::Homogeneous(Behavior::Linear);

/// The PJRT-backed training backend: simulated clients running real
/// AOT-compiled training steps, FedAvg aggregation, frozen-eval-batch
/// evaluation.
pub struct FlBackend {
    runtime: ModelRuntime,
    dataset: Dataset,
    /// Fixed held-out batches (as PJRT literals) reused every round, so the
    /// eval series is comparable across rounds and policies.
    eval_batches: Vec<(xla::Literal, xla::Literal)>,
    clients: Vec<SimClient>,
    global: ParamSet,
    /// Updates from the last Training phase, consumed by `aggregate`.
    pending: Vec<(ParamSet, f64)>,
}

impl RoundBackend for FlBackend {
    fn train(&mut self, plan: &RoundPlan) -> Result<Vec<DeviceOutcome>> {
        // A failed previous round may have left partial updates behind;
        // they must never leak into this round's aggregation.
        self.pending.clear();
        let mut outcomes = Vec::with_capacity(plan.assignments.len());
        for a in &plan.assignments {
            let update = {
                let client = &mut self.clients[a.device];
                client.local_train(&self.runtime, &self.dataset, &self.global, a.tasks)?
            };
            let energy_j = update.energy_j * a.energy_scale;
            self.pending.push((update.params, update.tasks as f64));
            outcomes.push(DeviceOutcome {
                device_id: a.device_id,
                device: a.device,
                tasks: update.tasks,
                energy_j,
                sim_time_s: update.sim_time_s,
                mean_loss: update.mean_loss,
            });
        }
        Ok(outcomes)
    }

    fn aggregate(&mut self) -> Result<()> {
        if !self.pending.is_empty() {
            self.global = fedavg(&self.pending)?;
            self.pending.clear();
        }
        Ok(())
    }

    fn evaluate(&mut self) -> Result<f64> {
        let mut sum = 0.0f64;
        for (x, y) in &self.eval_batches {
            sum += self.runtime.eval_step(&self.global, x, y)? as f64;
        }
        Ok(sum / self.eval_batches.len() as f64)
    }
}

impl BackendState for FlBackend {
    fn save_state(&self) -> Json {
        // Model parameters and client RNGs are not persisted yet; a
        // snapshot of an FL-backed campaign records the coordinator side
        // only (see ROADMAP: PJRT state persistence).
        Json::Null
    }

    fn load_state(&mut self, _state: &Json) -> Result<()> {
        Err(FedError::Store(
            "the PJRT FL backend cannot restore from a snapshot yet \
             (model parameters are not persisted); use the sim backend \
             for durable campaigns"
                .into(),
        ))
    }
}

/// The federated-learning server: artifacts + data + fleet wired into a
/// [`Coordinator`].
pub struct Server {
    cfg: TrainConfig,
    coord: Coordinator<FlBackend>,
}

impl Server {
    /// Build a server: load artifacts, synthesize + partition data, sample
    /// the fleet, and hand everything to a coordinator.
    pub fn new(cfg: TrainConfig, mix: BehaviorMix) -> Result<Server> {
        cfg.validate()?;
        let runtime = ModelRuntime::load(Path::new(&cfg.artifacts_dir), &cfg.model)?;
        let mut rng = Rng::new(cfg.seed);

        let n_samples = 4000.max(cfg.devices * 64) + 512;
        let mut data_rng = rng.fork();
        let dataset = Dataset::synth(runtime.spec(), n_samples, &mut data_rng);
        // Same distribution, disjoint tail indices for evaluation.
        let (train_shard, eval_shard) = dataset.split(512);

        // Freeze 8 held-out batches as literals once, so the eval series is
        // comparable across rounds and policies.
        let mut eval_batches = Vec::with_capacity(8);
        for _ in 0..8 {
            let b = dataset.batch(runtime.spec(), &eval_shard, &mut data_rng)?;
            let x = match runtime.spec().input_dtype {
                Dtype::F32 => runtime.input_literal_f32(&b.x_f32)?,
                Dtype::S32 => runtime.input_literal_i32(&b.x_i32)?,
            };
            let y = runtime.label_literal(&b.y)?;
            eval_batches.push((x, y));
        }

        let fleet = Fleet::sample(cfg.devices, mix, &mut rng);
        let shards =
            dataset.partition(&train_shard, cfg.devices, cfg.dirichlet_alpha, &mut rng);
        let clients: Vec<SimClient> = fleet
            .devices
            .into_iter()
            .zip(shards)
            .map(|(d, s)| {
                let crng = rng.fork();
                SimClient::new(d, s, crng)
            })
            .collect();

        // The coordinator's fleet view: same devices, capacity further
        // clamped to each client's shard (can't train on more distinct
        // batches than it has data for).
        let managed: Vec<ManagedDevice> = clients
            .iter()
            .map(|c| ManagedDevice::from_device(&c.device, c.data_len()))
            .collect();

        let global = runtime.initial_params();
        let backend = FlBackend {
            runtime,
            dataset,
            eval_batches,
            clients,
            global,
            pending: Vec::new(),
        };
        let mut coord_cfg = CoordinatorConfig::from_train(&cfg);
        // Decorrelate coordination randomness from the fleet/data streams
        // already drawn from `cfg.seed`.
        coord_cfg.seed = rng.next_u64();
        let coord = Coordinator::new(coord_cfg, managed, backend)?;
        Ok(Server { cfg, coord })
    }

    /// Current global parameters.
    pub fn global_params(&self) -> &ParamSet {
        &self.coord.backend().global
    }

    /// The training configuration.
    pub fn cfg(&self) -> &TrainConfig {
        &self.cfg
    }

    /// Apply a [`KnobSet`] to the underlying coordinator — the single
    /// configuration seam shared with the CLI `train`/`resume` paths and
    /// the networked service layer. This replaced a hand-maintained
    /// mirror of seven coordinator setters.
    ///
    /// Note on `pipeline`: the PJRT backend still trains synchronously
    /// inside the `begin_train`/`finish_train` seam (its runtime is not
    /// yet thread-movable — see ROADMAP: wire `TrainConfig.workers`), so
    /// its `begin_train` reports no overlap window and the coordinator
    /// skips speculation entirely — the knob is plumbed and persisted so
    /// campaigns record the intended mode today at zero cost, and the
    /// overlap engages the moment the backend starts deferring work.
    pub fn apply_knobs(&mut self, knobs: KnobSet) -> Result<()> {
        knobs.apply_to(&mut self.coord)
    }

    /// Flush the attached tracer, surfacing any deferred write error.
    pub fn flush_trace(&mut self) -> Result<()> {
        self.coord.flush_trace()
    }

    /// The runtime (for external evaluation).
    pub fn runtime(&self) -> &ModelRuntime {
        &self.coord.backend().runtime
    }

    /// The underlying coordinator (phase, devices, registry).
    pub fn coordinator(&self) -> &Coordinator<FlBackend> {
        &self.coord
    }

    /// Per-device / per-round energy ledger.
    pub fn ledger(&self) -> &EnergyLedger {
        self.coord.ledger()
    }

    /// Counters and gauges collected across rounds.
    pub fn metrics(&self) -> &MetricsHub {
        self.coord.metrics()
    }

    /// Scheduling dedup accumulated across rounds:
    /// `(devices scheduled, classes solved)`. Classes ≪ devices is the
    /// ratio the class-aware solvers exploit; equal values mean the fleet
    /// had no interchangeable devices.
    pub fn fleet_dedup(&self) -> (u64, u64) {
        (
            self.coord.metrics().counter("fleet_devices"),
            self.coord.metrics().counter("fleet_classes"),
        )
    }

    /// Per-round training log.
    pub fn log(&self) -> &TrainingLog {
        self.coord.log()
    }

    /// Flush all attached sinks.
    pub fn flush_sinks(&mut self) -> Result<()> {
        self.coord.flush_sinks()
    }

    /// Execute one round through the coordinator; returns the logged row.
    pub fn round(&mut self) -> Result<RoundLog> {
        self.coord.round()
    }

    /// Held-out loss of the global model: mean over the frozen eval
    /// batches.
    pub fn evaluate(&mut self) -> Result<f64> {
        self.coord.backend_mut().evaluate()
    }

    /// Run the full configured training; returns the log.
    pub fn run(&mut self) -> Result<&TrainingLog> {
        self.coord.run()
    }

    /// Convenience: run training with a given policy, returning
    /// `(final_loss, total_energy_j)` — used by the comparison experiments.
    pub fn train_once(
        mut cfg: TrainConfig,
        policy: Policy,
        mix: BehaviorMix,
    ) -> Result<(f64, f64)> {
        cfg.policy = policy;
        let mut server = Server::new(cfg, mix)?;
        server.run()?;
        Ok((
            server.log().final_loss().unwrap_or(f64::NAN),
            server.log().total_energy(),
        ))
    }
}
