//! Model aggregation: FedAvg (McMahan et al. [1]).
//!
//! The server combines device updates weighted by the amount of data each
//! trained on — here the scheduler's assignment `x_i`, so the workload
//! distribution directly drives both the energy cost *and* the aggregation
//! weights.

use crate::error::{FedError, Result};
use crate::runtime::ParamSet;

/// Weighted average of parameter sets: `Σ w_i · p_i / Σ w_i`.
pub fn fedavg(updates: &[(ParamSet, f64)]) -> Result<ParamSet> {
    let total: f64 = updates.iter().map(|(_, w)| *w).sum();
    if updates.is_empty() || total <= 0.0 {
        return Err(FedError::Fl("fedavg: no positively-weighted updates".into()));
    }
    let mut acc = updates[0].0.clone();
    acc.scale((updates[0].1 / total) as f32);
    for (p, w) in &updates[1..] {
        acc.add_scaled(p, (*w / total) as f32)?;
    }
    Ok(acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{Dtype, ModelSpec};

    fn spec() -> ModelSpec {
        ModelSpec {
            name: "t".into(),
            family: "mlp".into(),
            train_hlo: "/tmp/a".into(),
            eval_hlo: "/tmp/b".into(),
            params_file: "/tmp/c".into(),
            param_shapes: vec![vec![2], vec![2]],
            param_count: 4,
            n_param_tensors: 2,
            batch: 1,
            lr: 0.1,
            input_shape: vec![1, 2],
            input_dtype: Dtype::F32,
            label_shape: vec![1],
            label_dtype: Dtype::S32,
            num_classes: 2,
        }
    }

    fn params(v: f32) -> ParamSet {
        ParamSet::from_flat(&spec(), &[v; 4]).unwrap()
    }

    #[test]
    fn equal_weights_average() {
        let avg = fedavg(&[(params(1.0), 1.0), (params(3.0), 1.0)]).unwrap();
        assert_eq!(avg.tensor(0), &[2.0, 2.0]);
    }

    #[test]
    fn weights_proportional_to_tasks() {
        // x_1 = 3, x_2 = 1 → weights 0.75 / 0.25
        let avg = fedavg(&[(params(0.0), 3.0), (params(4.0), 1.0)]).unwrap();
        assert_eq!(avg.tensor(1), &[1.0, 1.0]);
    }

    #[test]
    fn single_update_identity() {
        let avg = fedavg(&[(params(7.0), 5.0)]).unwrap();
        assert_eq!(avg.tensor(0), &[7.0, 7.0]);
    }

    #[test]
    fn rejects_empty_or_zero_weight() {
        assert!(fedavg(&[]).is_err());
        assert!(fedavg(&[(params(1.0), 0.0)]).is_err());
    }

    #[test]
    fn idempotent_on_identical_updates() {
        let avg = fedavg(&[
            (params(2.5), 1.0),
            (params(2.5), 2.0),
            (params(2.5), 7.0),
        ])
        .unwrap();
        for t in 0..2 {
            for &x in avg.tensor(t) {
                assert!((x - 2.5).abs() < 1e-6);
            }
        }
    }
}
