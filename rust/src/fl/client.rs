//! Simulated FL client: a device that runs real PJRT training steps on its
//! local shard and accounts the energy its power model predicts.

use crate::energy::profiles::Device;
use crate::error::Result;
use crate::fl::data::{Dataset, Shard};
use crate::runtime::{Dtype, ModelRuntime, ParamSet};
use crate::util::rng::Rng;

/// Result of one device's local training in one round.
#[derive(Clone, Debug)]
pub struct LocalUpdate {
    /// Device id.
    pub device: usize,
    /// Tasks (mini-batches) actually trained.
    pub tasks: usize,
    /// Updated local parameters.
    pub params: ParamSet,
    /// Simulated energy drawn from the device's power model (joules).
    pub energy_j: f64,
    /// Simulated wall-clock training time on the device (seconds).
    pub sim_time_s: f64,
    /// Mean training loss over the local steps.
    pub mean_loss: f64,
}

/// One simulated client.
pub struct SimClient {
    pub device: Device,
    pub shard: Shard,
    rng: Rng,
}

impl SimClient {
    /// Create a client with its own RNG stream.
    pub fn new(device: Device, shard: Shard, rng: Rng) -> Self {
        Self { device, shard, rng }
    }

    /// Number of locally available mini-batch samples.
    pub fn data_len(&self) -> usize {
        self.shard.len()
    }

    /// Run `tasks` sequential training steps from `global`, returning the
    /// local update. Energy/time come from the device's power model — the
    /// same model the scheduler's cost function was built from, so measured
    /// energy matches scheduled cost by construction (the "profiler is
    /// accurate" setting; `tracegen` covers the noisy case).
    pub fn local_train(
        &mut self,
        runtime: &ModelRuntime,
        dataset: &Dataset,
        global: &ParamSet,
        tasks: usize,
    ) -> Result<LocalUpdate> {
        let mut params = global.clone();
        let mut loss_sum = 0.0f64;
        for _ in 0..tasks {
            let batch = dataset.batch(runtime.spec(), &self.shard, &mut self.rng)?;
            let x = match runtime.spec().input_dtype {
                Dtype::F32 => runtime.input_literal_f32(&batch.x_f32)?,
                Dtype::S32 => runtime.input_literal_i32(&batch.x_i32)?,
            };
            let y = runtime.label_literal(&batch.y)?;
            let (next, loss) = runtime.train_step(&params, &x, &y)?;
            params = next;
            loss_sum += loss as f64;
        }
        // Battery accounting lives in the coordinator's `ManagedDevice`
        // view (one source of truth for re-costing); the client only
        // reports measured energy.
        let energy_j = self.device.power.energy_j(tasks);
        Ok(LocalUpdate {
            device: self.device.id,
            tasks,
            params,
            energy_j,
            sim_time_s: self.device.power.time_s(tasks),
            mean_loss: if tasks > 0 { loss_sum / tasks as f64 } else { 0.0 },
        })
    }
}
