//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime (`artifacts/manifest.json`).

use std::path::{Path, PathBuf};

use crate::error::{FedError, Result};
use crate::util::json::Json;

/// Element type of a model input.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    S32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(Dtype::F32),
            "s32" => Ok(Dtype::S32),
            other => Err(FedError::Artifact(format!("unknown dtype '{other}'"))),
        }
    }
}

/// One model family's artifact entry.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub family: String,
    pub train_hlo: PathBuf,
    pub eval_hlo: PathBuf,
    pub params_file: PathBuf,
    /// Shape of each parameter tensor, in flat order.
    pub param_shapes: Vec<Vec<usize>>,
    /// Total scalar parameter count.
    pub param_count: usize,
    /// Number of parameter tensors.
    pub n_param_tensors: usize,
    /// Mini-batch rows.
    pub batch: usize,
    /// SGD learning rate baked into the lowered step.
    pub lr: f64,
    pub input_shape: Vec<usize>,
    pub input_dtype: Dtype,
    pub label_shape: Vec<usize>,
    pub label_dtype: Dtype,
    /// MLP: number of classes; transformer: vocab size.
    pub num_classes: usize,
}

impl ModelSpec {
    /// Scalars per input batch.
    pub fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Scalars per label batch.
    pub fn label_len(&self) -> usize {
        self.label_shape.iter().product()
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: Vec<ModelSpec>,
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            FedError::Artifact(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        let root = Json::parse(&text)?;
        let version = root.req("version")?.as_usize().unwrap_or(0);
        if version != 1 {
            return Err(FedError::Artifact(format!(
                "unsupported manifest version {version}"
            )));
        }
        let models_obj = root
            .req("models")?
            .as_obj()
            .ok_or_else(|| FedError::Artifact("'models' is not an object".into()))?;

        let mut models = Vec::new();
        for (name, m) in models_obj {
            let shapes = m
                .req("param_shapes")?
                .as_arr()
                .ok_or_else(|| FedError::Artifact("param_shapes not array".into()))?
                .iter()
                .map(|s| {
                    s.as_arr()
                        .map(|dims| {
                            dims.iter().filter_map(|d| d.as_usize()).collect::<Vec<_>>()
                        })
                        .ok_or_else(|| FedError::Artifact("bad shape entry".into()))
                })
                .collect::<Result<Vec<_>>>()?;

            let get_usize = |key: &str| -> Result<usize> {
                m.req(key)?
                    .as_usize()
                    .ok_or_else(|| FedError::Artifact(format!("bad '{key}'")))
            };
            let get_str = |key: &str| -> Result<String> {
                Ok(m.req(key)?
                    .as_str()
                    .ok_or_else(|| FedError::Artifact(format!("bad '{key}'")))?
                    .to_string())
            };
            let get_shape = |key: &str| -> Result<Vec<usize>> {
                Ok(m.req(key)?
                    .as_arr()
                    .ok_or_else(|| FedError::Artifact(format!("bad '{key}'")))?
                    .iter()
                    .filter_map(|d| d.as_usize())
                    .collect())
            };

            let family = get_str("family")?;
            let num_classes = if family == "transformer" {
                get_usize("vocab")?
            } else {
                get_usize("classes")?
            };

            let spec = ModelSpec {
                name: name.clone(),
                family,
                train_hlo: dir.join(get_str("train_hlo")?),
                eval_hlo: dir.join(get_str("eval_hlo")?),
                params_file: dir.join(get_str("params_file")?),
                param_shapes: shapes,
                param_count: get_usize("param_count")?,
                n_param_tensors: get_usize("n_param_tensors")?,
                batch: get_usize("batch")?,
                lr: m
                    .req("lr")?
                    .as_f64()
                    .ok_or_else(|| FedError::Artifact("bad 'lr'".into()))?,
                input_shape: get_shape("input_shape")?,
                input_dtype: Dtype::parse(&get_str("input_dtype")?)?,
                label_shape: get_shape("label_shape")?,
                label_dtype: Dtype::parse(&get_str("label_dtype")?)?,
                num_classes,
            };

            // Cross-checks: shapes must account for every scalar.
            let total: usize = spec
                .param_shapes
                .iter()
                .map(|s| s.iter().product::<usize>())
                .sum();
            if total != spec.param_count {
                return Err(FedError::Artifact(format!(
                    "model '{name}': param_shapes sum {total} != param_count {}",
                    spec.param_count
                )));
            }
            if spec.param_shapes.len() != spec.n_param_tensors {
                return Err(FedError::Artifact(format!(
                    "model '{name}': {} shapes != n_param_tensors {}",
                    spec.param_shapes.len(),
                    spec.n_param_tensors
                )));
            }
            models.push(spec);
        }
        Ok(Manifest { dir: dir.to_path_buf(), models })
    }

    /// Find a model by name.
    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| {
                let names: Vec<&str> = self.models.iter().map(|m| m.name.as_str()).collect();
                FedError::Artifact(format!(
                    "model '{name}' not in manifest (available: {names:?})"
                ))
            })
    }

    /// Load a model's initial parameters (flat little-endian f32 dump).
    pub fn load_params(&self, spec: &ModelSpec) -> Result<Vec<f32>> {
        let raw = std::fs::read(&spec.params_file)?;
        if raw.len() != spec.param_count * 4 {
            return Err(FedError::Artifact(format!(
                "params file {} has {} bytes, expected {}",
                spec.params_file.display(),
                raw.len(),
                spec.param_count * 4
            )));
        }
        Ok(raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest(dir: &Path) {
        let manifest = r#"{
          "version": 1,
          "models": {
            "toy": {
              "family": "mlp", "classes": 2,
              "train_hlo": "toy_train.hlo.txt",
              "eval_hlo": "toy_eval.hlo.txt",
              "params_file": "toy_params.bin",
              "param_shapes": [[2, 3], [3]],
              "param_count": 9, "n_param_tensors": 2,
              "batch": 4, "lr": 0.1,
              "input_shape": [4, 2], "input_dtype": "f32",
              "label_shape": [4], "label_dtype": "s32"
            }
          }
        }"#;
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        let params: Vec<u8> = (0..9i32)
            .flat_map(|i| (i as f32 * 0.5).to_le_bytes())
            .collect();
        std::fs::write(dir.join("toy_params.bin"), params).unwrap();
    }

    #[test]
    fn parses_and_validates() {
        let dir = std::env::temp_dir().join("fedzero_manifest_test");
        fake_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        let spec = m.model("toy").unwrap();
        assert_eq!(spec.param_count, 9);
        assert_eq!(spec.batch, 4);
        assert_eq!(spec.input_dtype, Dtype::F32);
        assert_eq!(spec.input_len(), 8);
        assert_eq!(spec.label_len(), 4);
        let params = m.load_params(spec).unwrap();
        assert_eq!(params.len(), 9);
        assert_eq!(params[2], 1.0);
        assert!(m.model("nope").is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_bad_param_count() {
        let dir = std::env::temp_dir().join("fedzero_manifest_bad");
        fake_manifest(&dir);
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .unwrap()
            .replace("\"param_count\": 9", "\"param_count\": 10");
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        assert!(Manifest::load(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_dir_is_clear_error() {
        let err = Manifest::load(Path::new("/nonexistent/fedzero")).unwrap_err();
        assert!(format!("{err}").contains("make artifacts"));
    }
}
